#!/usr/bin/env python3
"""Compare a bench JSON artifact against the previous run's artifact and
flag perf regressions as GitHub Actions warnings.

Rows are joined on their string-valued identity fields (policy, trace,
network, mix, ...) plus integer cardinalities (replicas, shards); numeric
fields are compared directionally:

* latency-like fields (``*_ms``, higher is worse) warn above ``--lat-tol``
  (ratio current/previous);
* throughput-like fields (``throughput_fps``, ``sim_fps``, ``analytic_fps``,
  ``completed``, lower is worse) warn below ``--tp-tol``.

Perf deltas never fail the job: these benches run on shared CI runners
where wall-clock noise is real, so the comparison *flags* rather than
fails — the same philosophy as serve_scaling's soft scaling check. Rows
present in only one file are reported informationally, and a missing
baseline (the first run of a new bench artifact) is a notice. The one
failing case (exit 1) is a missing or corrupt *current* artifact: that
means the bench itself broke, not that perf moved.
"""

import argparse
import json
import os
import sys

LATENCY_SUFFIXES = ("_ms",)
THROUGHPUT_FIELDS = {
    "throughput_fps", "sim_fps", "analytic_fps", "completed", "chain_completed",
    "fps", "vs_analytic", "goodput",
}
SKIP_FIELDS = {"partition_ms"}  # machine-speed dependent, not a serving metric
INT_IDENTITY = ("replicas", "shards", "chains", "stages", "window", "tenants")


def identity_fields(row):
    """The fields of ``row`` that participate in its join key."""
    out = set()
    for k, v in row.items():
        if isinstance(v, bool):
            continue
        if isinstance(v, str):
            out.add(k)
        elif isinstance(v, int) and k in INT_IDENTITY:
            out.add(k)
    return out


def row_key(row, fields=None):
    # identity = string fields + structural cardinalities; booleans like
    # `feasible` are OUTCOMES, not identity — a feasibility flip must
    # compare against the old row and warn, not dodge the join. When
    # `fields` is given (schema-change reconciliation) only those
    # identity fields are keyed on.
    parts = []
    for k in sorted(row):
        v = row[k]
        if isinstance(v, bool):
            continue
        if fields is not None and k not in fields:
            continue
        if isinstance(v, str):
            parts.append(f"{k}={v}")
        elif isinstance(v, int) and k in INT_IDENTITY:
            parts.append(f"{k}={v}")
    return "|".join(parts)


def load(path):
    with open(path) as f:
        rows = json.load(f)
    if not isinstance(rows, list):
        raise ValueError(f"{path}: expected a JSON array of rows")
    return rows


def index_rows(rows, fields=None):
    return {row_key(r, fields): r for r in rows}


def reconcile_schemas(prev_rows, curr_rows, label):
    """Index both row lists for the join, detecting identity-schema drift.

    A bench that adds or renames an identity field (say a new ``policy``
    column) would otherwise make *every* row key miss — each row reports
    as "new", no metric is compared, and a regression sails through
    silently. Instead: say so loudly with a ``::notice``, then join on
    the intersection of the two schemas so the shared identity still
    anchors a comparison.
    """
    prev_fields = set()
    for r in prev_rows:
        prev_fields |= identity_fields(r)
    curr_fields = set()
    for r in curr_rows:
        curr_fields |= identity_fields(r)
    if prev_fields == curr_fields:
        return index_rows(prev_rows), index_rows(curr_rows)

    added = sorted(curr_fields - prev_fields)
    removed = sorted(prev_fields - curr_fields)
    shared = prev_fields & curr_fields
    print(f"::notice::{label}: bench identity schema changed — "
          f"added {added or 'none'}, removed {removed or 'none'}; "
          f"joining rows on the shared fields {sorted(shared)}")
    if not shared:
        print(f"::notice::{label}: no identity fields in common — "
              f"treating every row as new")
        return {}, index_rows(curr_rows)
    prev = index_rows(prev_rows, shared)
    curr = index_rows(curr_rows, shared)
    collapsed = (len(prev_rows) - len(prev)) + (len(curr_rows) - len(curr))
    if collapsed:
        print(f"::notice::{label}: {collapsed} row(s) collapsed onto the "
              f"shared identity key — their metrics compare against the "
              f"last row with that key")
    return prev, curr


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("previous")
    ap.add_argument("current")
    ap.add_argument("--lat-tol", type=float, default=1.5,
                    help="warn when latency grows past this ratio")
    ap.add_argument("--tp-tol", type=float, default=0.7,
                    help="warn when throughput falls below this ratio")
    ap.add_argument("--label", default="bench")
    args = ap.parse_args(argv)

    # A missing *baseline* is expected on the first run of a new bench
    # artifact (nothing to download yet): warn-and-pass. A missing or
    # corrupt *current* artifact means the bench itself broke: fail.
    if not os.path.exists(args.previous):
        print(f"::notice::{args.label}: no baseline artifact yet "
              f"({args.previous}) — first run of this bench, comparison skipped")
        return 0
    try:
        prev_rows = load(args.previous)
    except (OSError, ValueError) as e:
        print(f"::warning::{args.label}: baseline unreadable ({e}) — "
              f"comparison skipped")
        return 0
    try:
        curr_rows = load(args.current)
    except (OSError, ValueError) as e:
        print(f"::error::{args.label}: current bench artifact missing or "
              f"corrupt ({e})")
        return 1

    prev, curr = reconcile_schemas(prev_rows, curr_rows, args.label)

    warned = 0
    for key, crow in sorted(curr.items()):
        prow = prev.get(key)
        if prow is None:
            print(f"{args.label}: new row (no baseline): {key}")
            continue
        for field, cval in crow.items():
            if field in SKIP_FIELDS or not isinstance(cval, (int, float)):
                continue
            pval = prow.get(field)
            if isinstance(cval, bool) or isinstance(pval, bool):
                # boolean outcome flip (e.g. a plan stopped fitting) is the
                # most severe regression class
                if pval is True and cval is False:
                    print(f"::warning::{args.label} regression: {key} {field} "
                          f"flipped true -> false")
                    warned += 1
                continue
            if not isinstance(pval, (int, float)):
                continue
            if field.endswith(LATENCY_SUFFIXES):
                if pval > 1e-9 and cval / pval > args.lat_tol:
                    print(f"::warning::{args.label} regression: {key} {field} "
                          f"{pval:.3f} -> {cval:.3f} ({cval / pval:.2f}x)")
                    warned += 1
            elif field in THROUGHPUT_FIELDS:
                if pval > 1e-9 and cval / pval < args.tp_tol:
                    print(f"::warning::{args.label} regression: {key} {field} "
                          f"{pval:.1f} -> {cval:.1f} ({cval / pval:.2f}x)")
                    warned += 1
    for key in sorted(set(prev) - set(curr)):
        print(f"{args.label}: row disappeared: {key}")

    print(f"{args.label}: compared {len(curr)} rows against baseline, "
          f"{warned} regression flag(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
