#!/usr/bin/env python3
"""Validate a Prometheus textfile exposition written by ``--metrics-out``.

The serving drivers rewrite ``PATH`` (Prometheus text) and append one
JSON object per emission to ``PATH.jsonl``. This checker enforces the
textfile grammar the way a node-exporter textfile collector would:

* every line is a ``# HELP``/``# TYPE`` comment or a
  ``name[{labels}] value`` sample;
* every sample's metric family has a preceding ``# TYPE`` of ``counter``
  or ``gauge``;
* every sample value parses as a finite float, counters non-negative;
* the required families are present (the fleet cannot serve without
  admitting, completing, and pooling);
* if the JSONL trajectory exists, every line parses as JSON and the
  snapshot timestamps never go backwards.

``--health PATH`` additionally (or instead) validates a health journal
written by ``--health-out``: a ``kind:"health"`` header, then downsampled
``kind:"cell"`` lines (known series, one resolution per series, cell
starts aligned to that resolution's grid and strictly increasing per
series, finite min/mean/max ordered min <= mean <= max, positive count)
and ``kind:"alert"`` transitions (known signal/severity, boolean firing,
non-decreasing timestamps, finite burn rates).

Exit 1 on any violation: an unparsable exposition means the observability
surface itself broke, which is exactly what this step guards.
"""

import argparse
import json
import math
import os
import re
import sys

SAMPLE_RE = re.compile(
    r'^([a-zA-Z_:][a-zA-Z0-9_:]*)'          # metric name
    r'(\{[a-zA-Z_][a-zA-Z0-9_]*="[^"]*"'    # optional {label="v",...}
    r'(?:,[a-zA-Z_][a-zA-Z0-9_]*="[^"]*")*\})?'
    r' (\S+)$'                               # value
)
HELP_RE = re.compile(r"^# HELP ([a-zA-Z_:][a-zA-Z0-9_:]*) .+$")
TYPE_RE = re.compile(r"^# TYPE ([a-zA-Z_:][a-zA-Z0-9_:]*) (counter|gauge)$")

REQUIRED = (
    "fcmp_submitted_total",
    "fcmp_completed_total",
    "fcmp_pool_misses_total",
)


def check_prom(path, errors):
    with open(path) as f:
        text = f.read()
    if not text.strip():
        errors.append(f"{path}: empty exposition")
        return
    types = {}
    seen = set()
    for ln, line in enumerate(text.splitlines(), 1):
        if not line:
            continue
        if line.startswith("#"):
            if HELP_RE.match(line):
                continue
            m = TYPE_RE.match(line)
            if m:
                types[m.group(1)] = m.group(2)
                continue
            errors.append(f"{path}:{ln}: malformed comment line: {line!r}")
            continue
        m = SAMPLE_RE.match(line)
        if not m:
            errors.append(f"{path}:{ln}: malformed sample line: {line!r}")
            continue
        name, _, value = m.groups()
        seen.add(name)
        if name not in types:
            errors.append(f"{path}:{ln}: sample {name} has no preceding # TYPE")
            continue
        try:
            v = float(value)
        except ValueError:
            errors.append(f"{path}:{ln}: non-numeric value {value!r}")
            continue
        if not math.isfinite(v):
            errors.append(f"{path}:{ln}: non-finite value for {name}")
        elif types[name] == "counter" and v < 0:
            errors.append(f"{path}:{ln}: negative counter {name} = {v}")
    for name in REQUIRED:
        if name not in seen:
            errors.append(f"{path}: required family {name} missing")


def check_jsonl(path, errors):
    last_t = None
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                snap = json.loads(line)
            except json.JSONDecodeError as e:
                errors.append(f"{path}:{ln}: bad JSON ({e})")
                continue
            t = snap.get("t_s")
            if not isinstance(t, (int, float)):
                errors.append(f"{path}:{ln}: snapshot lacks a numeric t_s")
                continue
            if last_t is not None and t < last_t:
                errors.append(
                    f"{path}:{ln}: snapshot time went backwards "
                    f"({last_t} -> {t})"
                )
            last_t = t
    if last_t is None:
        errors.append(f"{path}: no snapshots in trajectory")


HEALTH_SERIES = ("offered", "shed", "completed", "late", "p99_ms")
HEALTH_SIGNALS = ("shed_rate", "latency_p99")
HEALTH_SEVERITIES = ("page", "ticket")


def check_health(path, errors):
    def finite(rec, key, ln):
        v = rec.get(key)
        if not isinstance(v, (int, float)) or isinstance(v, bool) or not math.isfinite(v):
            errors.append(f"{path}:{ln}: {key} is not a finite number: {v!r}")
            return None
        return v

    header = None
    res_by_series = {}
    last_t_by_series = {}
    last_alert_t = None
    cells = 0
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                errors.append(f"{path}:{ln}: bad JSON ({e})")
                continue
            kind = rec.get("kind")
            if kind == "health":
                if header is not None:
                    errors.append(f"{path}:{ln}: duplicate health header")
                header = rec
                if rec.get("version") != 1:
                    errors.append(f"{path}:{ln}: unknown journal version {rec.get('version')!r}")
                finite(rec, "shed_slo", ln)
                finite(rec, "latency_slo", ln)
                # p99_budget_ms is null when latency alerting is off
            elif kind == "cell":
                if header is None:
                    errors.append(f"{path}:{ln}: cell before the health header")
                cells += 1
                series = rec.get("series")
                if series not in HEALTH_SERIES:
                    errors.append(f"{path}:{ln}: unknown series {series!r}")
                    continue
                res = finite(rec, "res_s", ln)
                t = finite(rec, "t_s", ln)
                if res is None or t is None:
                    continue
                if res <= 0:
                    errors.append(f"{path}:{ln}: non-positive res_s {res}")
                    continue
                want = res_by_series.setdefault(series, res)
                if res != want:
                    errors.append(
                        f"{path}:{ln}: series {series} changed resolution "
                        f"({want} -> {res})"
                    )
                if abs(t / res - round(t / res)) > 1e-6:
                    errors.append(
                        f"{path}:{ln}: cell start {t} not aligned to the "
                        f"{res} s grid"
                    )
                last_t = last_t_by_series.get(series)
                if last_t is not None and t <= last_t:
                    errors.append(
                        f"{path}:{ln}: series {series} cell time not "
                        f"increasing ({last_t} -> {t})"
                    )
                last_t_by_series[series] = t
                lo = finite(rec, "min", ln)
                mid = finite(rec, "mean", ln)
                hi = finite(rec, "max", ln)
                if None not in (lo, mid, hi) and not (lo <= mid + 1e-9 and mid <= hi + 1e-9):
                    errors.append(
                        f"{path}:{ln}: aggregates out of order "
                        f"(min {lo}, mean {mid}, max {hi})"
                    )
                count = rec.get("count")
                if not isinstance(count, int) or isinstance(count, bool) or count < 1:
                    errors.append(f"{path}:{ln}: cell count must be a positive int: {count!r}")
                finite(rec, "sum", ln)
            elif kind == "alert":
                if rec.get("signal") not in HEALTH_SIGNALS:
                    errors.append(f"{path}:{ln}: unknown signal {rec.get('signal')!r}")
                if rec.get("severity") not in HEALTH_SEVERITIES:
                    errors.append(f"{path}:{ln}: unknown severity {rec.get('severity')!r}")
                if not isinstance(rec.get("firing"), bool):
                    errors.append(f"{path}:{ln}: firing must be a bool")
                t = finite(rec, "at_s", ln)
                if t is not None:
                    if last_alert_t is not None and t < last_alert_t:
                        errors.append(
                            f"{path}:{ln}: alert time went backwards "
                            f"({last_alert_t} -> {t})"
                        )
                    last_alert_t = t
                for key in ("burn_long", "burn_short"):
                    v = finite(rec, key, ln)
                    if v is not None and v < 0:
                        errors.append(f"{path}:{ln}: negative {key} {v}")
            # foreign kinds are tolerated: journals may share a sink
    if header is None:
        errors.append(f"{path}: no health header line")
    if cells == 0:
        errors.append(f"{path}: no downsampled cells in journal")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "prom",
        nargs="?",
        help="Prometheus textfile written by --metrics-out",
    )
    ap.add_argument(
        "--jsonl",
        help="JSONL trajectory (default: PROM.jsonl, checked when present)",
    )
    ap.add_argument(
        "--health",
        help="health journal written by --health-out (validated when given)",
    )
    args = ap.parse_args(argv)
    if not args.prom and not args.health:
        ap.error("nothing to check: give PROM and/or --health")

    errors = []
    if args.prom:
        if not os.path.exists(args.prom):
            errors.append(f"{args.prom}: exposition file was never written")
        else:
            check_prom(args.prom, errors)
            jsonl = args.jsonl or args.prom + ".jsonl"
            if os.path.exists(jsonl):
                check_jsonl(jsonl, errors)
            elif args.jsonl:
                errors.append(f"{jsonl}: trajectory file was never written")
    if args.health:
        if not os.path.exists(args.health):
            errors.append(f"{args.health}: health journal was never written")
        else:
            check_health(args.health, errors)

    for e in errors:
        print(f"::error::exposition: {e}")
    if not errors:
        checked = " and ".join(p for p in (args.prom, args.health) if p)
        print(f"exposition OK: {checked}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
