#!/usr/bin/env python3
"""Validate a Prometheus textfile exposition written by ``--metrics-out``.

The serving drivers rewrite ``PATH`` (Prometheus text) and append one
JSON object per emission to ``PATH.jsonl``. This checker enforces the
textfile grammar the way a node-exporter textfile collector would:

* every line is a ``# HELP``/``# TYPE`` comment or a
  ``name[{labels}] value`` sample;
* every sample's metric family has a preceding ``# TYPE`` of ``counter``
  or ``gauge``;
* every sample value parses as a finite float, counters non-negative;
* the required families are present (the fleet cannot serve without
  admitting, completing, and pooling);
* if the JSONL trajectory exists, every line parses as JSON and the
  snapshot timestamps never go backwards.

Exit 1 on any violation: an unparsable exposition means the observability
surface itself broke, which is exactly what this step guards.
"""

import argparse
import json
import math
import os
import re
import sys

SAMPLE_RE = re.compile(
    r'^([a-zA-Z_:][a-zA-Z0-9_:]*)'          # metric name
    r'(\{[a-zA-Z_][a-zA-Z0-9_]*="[^"]*"'    # optional {label="v",...}
    r'(?:,[a-zA-Z_][a-zA-Z0-9_]*="[^"]*")*\})?'
    r' (\S+)$'                               # value
)
HELP_RE = re.compile(r"^# HELP ([a-zA-Z_:][a-zA-Z0-9_:]*) .+$")
TYPE_RE = re.compile(r"^# TYPE ([a-zA-Z_:][a-zA-Z0-9_:]*) (counter|gauge)$")

REQUIRED = (
    "fcmp_submitted_total",
    "fcmp_completed_total",
    "fcmp_pool_misses_total",
)


def check_prom(path, errors):
    with open(path) as f:
        text = f.read()
    if not text.strip():
        errors.append(f"{path}: empty exposition")
        return
    types = {}
    seen = set()
    for ln, line in enumerate(text.splitlines(), 1):
        if not line:
            continue
        if line.startswith("#"):
            if HELP_RE.match(line):
                continue
            m = TYPE_RE.match(line)
            if m:
                types[m.group(1)] = m.group(2)
                continue
            errors.append(f"{path}:{ln}: malformed comment line: {line!r}")
            continue
        m = SAMPLE_RE.match(line)
        if not m:
            errors.append(f"{path}:{ln}: malformed sample line: {line!r}")
            continue
        name, _, value = m.groups()
        seen.add(name)
        if name not in types:
            errors.append(f"{path}:{ln}: sample {name} has no preceding # TYPE")
            continue
        try:
            v = float(value)
        except ValueError:
            errors.append(f"{path}:{ln}: non-numeric value {value!r}")
            continue
        if not math.isfinite(v):
            errors.append(f"{path}:{ln}: non-finite value for {name}")
        elif types[name] == "counter" and v < 0:
            errors.append(f"{path}:{ln}: negative counter {name} = {v}")
    for name in REQUIRED:
        if name not in seen:
            errors.append(f"{path}: required family {name} missing")


def check_jsonl(path, errors):
    last_t = None
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                snap = json.loads(line)
            except json.JSONDecodeError as e:
                errors.append(f"{path}:{ln}: bad JSON ({e})")
                continue
            t = snap.get("t_s")
            if not isinstance(t, (int, float)):
                errors.append(f"{path}:{ln}: snapshot lacks a numeric t_s")
                continue
            if last_t is not None and t < last_t:
                errors.append(
                    f"{path}:{ln}: snapshot time went backwards "
                    f"({last_t} -> {t})"
                )
            last_t = t
    if last_t is None:
        errors.append(f"{path}: no snapshots in trajectory")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("prom", help="Prometheus textfile written by --metrics-out")
    ap.add_argument(
        "--jsonl",
        help="JSONL trajectory (default: PROM.jsonl, checked when present)",
    )
    args = ap.parse_args(argv)

    errors = []
    if not os.path.exists(args.prom):
        errors.append(f"{args.prom}: exposition file was never written")
    else:
        check_prom(args.prom, errors)
        jsonl = args.jsonl or args.prom + ".jsonl"
        if os.path.exists(jsonl):
            check_jsonl(jsonl, errors)
        elif args.jsonl:
            errors.append(f"{jsonl}: trajectory file was never written")

    for e in errors:
        print(f"::error::exposition: {e}")
    if not errors:
        print(f"exposition OK: {args.prom} parses as Prometheus text")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
