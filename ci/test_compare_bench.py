#!/usr/bin/env python3
"""Tests for compare_bench.py — in particular the identity-schema-change
path: a bench that renames or adds an identity field must emit an
explicit ``::notice`` and still compare metrics on the shared fields,
never silently report every row as "new".

Runs under pytest, or standalone: ``python3 ci/test_compare_bench.py``.
"""

import io
import json
import os
import sys
import tempfile
from contextlib import redirect_stdout

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import compare_bench  # noqa: E402


def _run(prev_rows, curr_rows, *extra):
    """Drive main() over two temp artifacts; return (exit_code, stdout)."""
    with tempfile.TemporaryDirectory() as td:
        prev = os.path.join(td, "prev.json")
        curr = os.path.join(td, "curr.json")
        with open(prev, "w") as f:
            json.dump(prev_rows, f)
        with open(curr, "w") as f:
            json.dump(curr_rows, f)
        buf = io.StringIO()
        with redirect_stdout(buf):
            code = compare_bench.main([prev, curr, "--label", "t", *extra])
        return code, buf.getvalue()


def test_identical_schema_flags_regression():
    prev = [{"arm": "a", "chains": 2, "throughput_fps": 1000.0, "p99_ms": 5.0}]
    curr = [{"arm": "a", "chains": 2, "throughput_fps": 100.0, "p99_ms": 50.0}]
    code, out = _run(prev, curr)
    assert code == 0
    assert "::warning::t regression" in out
    assert "throughput_fps" in out and "p99_ms" in out
    assert "schema changed" not in out


def test_schema_change_emits_notice_and_still_compares():
    # the old artifact had no `policy` identity column; the new one does.
    # Before the fix every row was "new" and the 10x throughput collapse
    # sailed through without a single warning.
    prev = [{"arm": "a", "chains": 2, "throughput_fps": 1000.0}]
    curr = [{"arm": "a", "policy": "rr", "chains": 2, "throughput_fps": 100.0}]
    code, out = _run(prev, curr)
    assert code == 0
    assert "::notice::t: bench identity schema changed" in out
    assert "added ['policy']" in out
    assert "::warning::t regression" in out and "throughput_fps" in out
    assert "new row" not in out


def test_schema_change_removed_field_reported():
    prev = [{"arm": "a", "trace": "poisson", "completed": 500}]
    curr = [{"arm": "a", "completed": 480}]
    code, out = _run(prev, curr)
    assert code == 0
    assert "removed ['trace']" in out
    # 480/500 = 0.96 is inside --tp-tol: joined on the shared field, no warn
    assert "::warning::" not in out


def test_disjoint_schemas_treat_rows_as_new():
    prev = [{"old_name": "x", "fps": 10.0}]
    curr = [{"new_name": "y", "fps": 10.0}]
    code, out = _run(prev, curr)
    assert code == 0
    assert "no identity fields in common" in out
    assert "new row" in out


def test_unchanged_rows_stay_quiet():
    rows = [
        {"arm": "a", "chains": 2, "throughput_fps": 1000.0, "p99_ms": 5.0},
        {"arm": "b", "chains": 4, "throughput_fps": 2000.0, "p99_ms": 3.0},
    ]
    code, out = _run(rows, rows)
    assert code == 0
    assert "::warning::" not in out
    assert "compared 2 rows" in out


def test_missing_baseline_is_a_pass():
    with tempfile.TemporaryDirectory() as td:
        curr = os.path.join(td, "curr.json")
        with open(curr, "w") as f:
            json.dump([{"arm": "a", "fps": 1.0}], f)
        buf = io.StringIO()
        with redirect_stdout(buf):
            code = compare_bench.main(
                [os.path.join(td, "nope.json"), curr, "--label", "t"])
        assert code == 0
        assert "no baseline artifact" in buf.getvalue()


def test_corrupt_current_fails():
    with tempfile.TemporaryDirectory() as td:
        prev = os.path.join(td, "prev.json")
        curr = os.path.join(td, "curr.json")
        with open(prev, "w") as f:
            json.dump([], f)
        with open(curr, "w") as f:
            f.write("{not json")
        buf = io.StringIO()
        with redirect_stdout(buf):
            code = compare_bench.main([prev, curr, "--label", "t"])
        assert code == 1
        assert "::error::" in buf.getvalue()


def test_tenants_is_identity_not_metric():
    # `tenants` is a structural cardinality like `chains`: two rows that
    # differ only in tenant count must NOT join (no bogus comparison),
    # and a goodput collapse within the same tenant count must warn
    prev = [
        {"arm": "zoo", "tenants": 1, "goodput": 900},
        {"arm": "zoo", "tenants": 2, "goodput": 400},
    ]
    curr = [
        {"arm": "zoo", "tenants": 1, "goodput": 900},
        {"arm": "zoo", "tenants": 2, "goodput": 100},
        {"arm": "zoo", "tenants": 3, "goodput": 50},
    ]
    code, out = _run(prev, curr)
    assert code == 0
    assert "schema changed" not in out
    assert "::warning::t regression" in out and "tenants=2" in out
    assert "tenants=1" not in out.split("regression")[1].splitlines()[0]
    assert "new row" in out and "tenants=3" in out


def test_bool_outcome_flip_warns_despite_schema_change():
    prev = [{"arm": "a", "feasible": True, "fps": 5.0}]
    curr = [{"arm": "a", "mode": "packed", "feasible": False, "fps": 5.0}]
    code, out = _run(prev, curr)
    assert code == 0
    assert "flipped true -> false" in out


def main():
    failures = 0
    tests = [(n, f) for n, f in sorted(globals().items())
             if n.startswith("test_") and callable(f)]
    for name, fn in tests:
        try:
            fn()
            print(f"ok   {name}")
        except AssertionError as e:
            failures += 1
            print(f"FAIL {name}: {e}")
    print(f"{len(tests) - failures}/{len(tests)} passed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
