//! Bench: regenerate paper Fig. 2 — physical RAM mapping efficiency
//! decreases as compute parallelism scales (1x/2x/4x).
use fcmp::util::bench::{bench, report, BenchConfig};

fn main() {
    println!("== Fig 2: efficiency vs parallelism ==");
    let t = fcmp::report::fig2();
    println!("{}", t.render());
    println!("\ncsv:\n{}", t.to_csv());
    let r = bench("fig2_mapping", BenchConfig::default(), || {
        std::hint::black_box(fcmp::report::fig2());
    });
    report(&r);
}
