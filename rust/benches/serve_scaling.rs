//! Bench: multi-replica serving-coordinator scaling — replicas × scheduling
//! policy × arrival trace on the deterministic mock backend, with a
//! heterogeneous fleet (per-replica speed factors model the paper's
//! cross-device porting story: the same design serves faster on a U250 than
//! on a 99%-full U280). Reports fleet throughput, shed counts and latency
//! percentiles per cell.
//!
//! Three arms share one row format:
//!
//! * `sync` — the original open-loop trace replay (window 1, blocking
//!   backends): the scaling-with-replicas signal.
//! * `closed-sync` — closed-loop (submit_blocking to saturation) on the
//!   blocking mock at window 1: the saturated-throughput baseline.
//! * `async-window` — the same closed loop on an overlapping backend
//!   (transfer ∥ compute) across window ∈ {1, 2, 4}: window 1 must match
//!   `closed-sync` within noise, window 4 at one replica should approach
//!   the 2× analytic overlap speedup.
//!
//! Flags: `--smoke` shrinks the load for CI; `--json` writes the cells to
//! `BENCH_serving.json` (the serving perf-trajectory artifact).

use std::path::Path;
use std::time::Duration;

use fcmp::coordinator::{
    bursty, diurnal, heavy_tail, poisson, BatcherConfig, Deployment, Metrics, MockBackend,
    PipelinedMockBackend, Policy, Server, Trace, WorkerId,
};
use fcmp::obs::ObsConfig;
use fcmp::util::args::Args;
use fcmp::util::bench::Table;

/// Heterogeneous per-replica speed factors (capacity weights): replica i is
/// `SPEEDS[i % 4]`× a reference replica, mirroring a mixed U250/U280/Zynq
/// fleet where the analytic model would assign exactly these weights.
const SPEEDS: [f64; 4] = [1.0, 0.5, 1.5, 0.75];

/// Per-item service time of a speed-1.0 replica, microseconds (the mock's
/// batch overhead is zero, so capacity is exactly `1e6/PER_ITEM_US` req/s
/// per unit of speed). Chosen so a single reference replica saturates below
/// the offered rate (it must shed) while four replicas absorb the full
/// trace — the scaling signal.
const PER_ITEM_US: f64 = 1800.0;

/// Per-item service of the closed-loop arms, microseconds. The async arm
/// splits it into equal transfer and compute legs, so the analytic overlap
/// speedup at window 2+ is exactly 2×.
const CLOSED_ITEM_US: f64 = 500.0;

struct Cell {
    arm: &'static str,
    replicas: usize,
    window: usize,
    policy: &'static str,
    trace: &'static str,
    offered_rps: f64,
    submitted: usize,
    completed: usize,
    shed: usize,
    throughput_fps: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
}

/// `trace_sample > 0` arms the span tracer (rings only, no sink): the
/// `sync-traced` arm measures the observability overhead against `sync`.
fn run_cell(
    replicas: usize,
    policy_name: &'static str,
    trace_name: &'static str,
    trace: &Trace,
    trace_sample: f64,
) -> Cell {
    let weights: Vec<f64> = (0..replicas).map(|i| SPEEDS[i % SPEEDS.len()]).collect();
    let policy = Policy::by_name(policy_name, weights.clone()).expect("policy name");
    let plan = Deployment::replicated(replicas)
        .with_policy(policy)
        .with_batcher(BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) })
        .with_queue_depth(32)
        .with_window(1);
    let svc: Vec<Duration> = weights
        .iter()
        .map(|w| Duration::from_secs_f64(PER_ITEM_US * 1e-6 / w))
        .collect();
    let mut srv = Server::deploy_with_obs(
        move |id: WorkerId| MockBackend::with_service(Duration::ZERO, svc[id.group]),
        plan,
        &ObsConfig { sample: trace_sample, ..ObsConfig::default() },
    );
    let fm = srv.replay(trace, 4, 7);
    srv.shutdown();
    let s = fm.summary();
    let (completed, throughput_fps, p50_ms, p95_ms, p99_ms) = match &s.fleet {
        Some(f) => (
            f.requests,
            f.throughput_fps,
            f.latency_ms.median,
            f.latency_ms.p95,
            f.latency_ms.p99,
        ),
        None => (0, 0.0, 0.0, 0.0, 0.0),
    };
    Cell {
        arm: if trace_sample > 0.0 { "sync-traced" } else { "sync" },
        replicas,
        window: 1,
        policy: policy_name,
        trace: trace_name,
        offered_rps: trace.offered_rate(),
        submitted: s.submitted,
        completed,
        shed: s.shed,
        throughput_fps,
        p50_ms,
        p95_ms,
        p99_ms,
    }
}

/// Closed-loop cell: `n` requests through `submit_blocking` (backpressure
/// paces the submitter, nothing sheds), wall-clocked end to end. `window`
/// only matters on the overlapping backend — that contrast *is* the arm.
fn run_closed_cell(
    arm: &'static str,
    replicas: usize,
    window: usize,
    policy_name: &'static str,
    n: usize,
) -> Cell {
    let weights: Vec<f64> = (0..replicas).map(|i| SPEEDS[i % SPEEDS.len()]).collect();
    let policy = Policy::by_name(policy_name, weights.clone()).expect("policy name");
    let plan = Deployment::replicated(replicas)
        .with_policy(policy)
        .with_batcher(BatcherConfig { max_batch: 4, max_wait: Duration::from_micros(200) })
        .with_queue_depth(32)
        .with_window(window);
    let overlapping = arm == "async-window";
    let svc: Vec<Duration> = weights
        .iter()
        .map(|w| Duration::from_secs_f64(CLOSED_ITEM_US * 1e-6 / w))
        .collect();
    let mut srv = Server::deploy(
        move |id: WorkerId| -> Box<dyn fcmp::coordinator::InferBackend> {
            let s = svc[id.group];
            if overlapping {
                Box::new(PipelinedMockBackend::overlapped(s / 2, s / 2))
            } else {
                Box::new(MockBackend::with_service(Duration::ZERO, s))
            }
        },
        plan,
    );
    let mut m = Metrics::new();
    m.start();
    for i in 0..n {
        srv.submit_blocking(i as u64, vec![1.0]).expect("closed-loop submit");
    }
    srv.shutdown();
    let mut completed = 0usize;
    while let Some(c) = srv.next_completion() {
        m.record(c.latency, c.batch_size);
        completed += 1;
    }
    let s = m.try_summary().expect("closed-loop cell completed nothing");
    Cell {
        arm,
        replicas,
        window,
        policy: policy_name,
        trace: "closed",
        offered_rps: 0.0,
        submitted: n,
        completed,
        shed: 0,
        throughput_fps: s.throughput_fps,
        p50_ms: s.latency_ms.median,
        p95_ms: s.latency_ms.p95,
        p99_ms: s.latency_ms.p99,
    }
}

fn cells_json(cells: &[Cell]) -> String {
    let mut out = String::from("[");
    for (k, c) in cells.iter().enumerate() {
        if k > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"arm\":{:?},\"replicas\":{},\"window\":{},\"policy\":{:?},\"trace\":{:?},\
             \"offered_rps\":{:.1},\"submitted\":{},\"completed\":{},\"shed\":{},\
             \"throughput_fps\":{:.1},\"p50_ms\":{:.3},\"p95_ms\":{:.3},\"p99_ms\":{:.3}}}",
            c.arm,
            c.replicas,
            c.window,
            c.policy,
            c.trace,
            c.offered_rps,
            c.submitted,
            c.completed,
            c.shed,
            c.throughput_fps,
            c.p50_ms,
            c.p95_ms,
            c.p99_ms
        ));
    }
    out.push(']');
    out
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has_flag("smoke");
    let n = if smoke { 120 } else { 360 };
    let rate = 900.0;
    let closed_n = if smoke { 240 } else { 720 };

    let traces: Vec<(&'static str, Trace)> = vec![
        ("poisson", poisson(n, rate, 42)),
        ("bursty", bursty(n, rate, rate * 8.0, 24, 42)),
        ("heavy-tail", heavy_tail(n, rate, 1.5, 42)),
        // day/night drift: trough rate/2, peak 2*rate, two cycles per trace
        ("diurnal", diurnal(n, rate / 2.0, rate * 2.0, n as f64 / rate / 2.0, 42)),
    ];
    let policies: [&'static str; 3] = ["round-robin", "jsq", "weighted"];

    let mut cells: Vec<Cell> = Vec::new();
    let mut t = Table::new([
        "arm", "replicas", "win", "policy", "trace", "offered", "completed", "shed", "fps",
        "p50 ms", "p95 ms", "p99 ms",
    ]);
    let push = |t: &mut Table, cells: &mut Vec<Cell>, c: Cell| {
        t.row([
            c.arm.to_string(),
            format!("{}", c.replicas),
            format!("{}", c.window),
            c.policy.to_string(),
            c.trace.to_string(),
            format!("{:.0}", c.offered_rps),
            format!("{}", c.completed),
            format!("{}", c.shed),
            format!("{:.0}", c.throughput_fps),
            format!("{:.2}", c.p50_ms),
            format!("{:.2}", c.p95_ms),
            format!("{:.2}", c.p99_ms),
        ]);
        cells.push(c);
    };
    for &replicas in &[1usize, 2, 4] {
        for policy in policies {
            for (tname, trace) in &traces {
                let c = run_cell(replicas, policy, *tname, trace, 0.0);
                push(&mut t, &mut cells, c);
            }
        }
    }
    // tracing-overhead arm: the same replay with the span tracer armed at
    // 1% (round-robin only — the overhead sits on the submit/dispatch
    // path, not in the policy)
    for &replicas in &[1usize, 2, 4] {
        for (tname, trace) in &traces {
            let c = run_cell(replicas, "round-robin", *tname, trace, 0.01);
            push(&mut t, &mut cells, c);
        }
    }
    // closed-loop arms: the in-flight-window contrast
    for &replicas in &[1usize, 2, 4] {
        for policy in policies {
            let c = run_closed_cell("closed-sync", replicas, 1, policy, closed_n);
            push(&mut t, &mut cells, c);
            for &window in &[1usize, 2, 4] {
                let c = run_closed_cell("async-window", replicas, window, policy, closed_n);
                push(&mut t, &mut cells, c);
            }
        }
    }
    println!("== Serving scaling (mock backend, heterogeneous fleet) ==");
    println!("{}", t.render());

    // scaling signal: at fixed policy/trace, the 4-replica fleet must
    // complete at least as much of the offered load as the single replica
    for policy in policies {
        for (tname, _) in &traces {
            let find = |r: usize| {
                cells
                    .iter()
                    .find(|c| {
                        c.arm == "sync" && c.replicas == r && c.policy == policy
                            && c.trace == *tname
                    })
                    .expect("cell")
            };
            let (c1, c4) = (find(1), find(4));
            println!(
                "scaling {policy}/{tname}: completed {}->{} (shed {}->{}), fps {:.0}->{:.0}",
                c1.completed, c4.completed, c1.shed, c4.shed, c1.throughput_fps,
                c4.throughput_fps
            );
            // soft check: this is a wall-clock bench on sleep-based mocks,
            // so a hard assert would make CI flaky on oversubscribed
            // runners — report the anomaly loudly instead
            if c4.completed + 8 < c1.completed {
                eprintln!(
                    "WARNING {policy}/{tname}: 4 replicas completed {} < 1 replica's {} — \
                     no scaling (noisy runner, or a real routing regression)",
                    c4.completed, c1.completed
                );
            }
        }
    }

    // tracing-overhead signal: the 1%-sampled arm must complete as much
    // of the offered load as the untraced one (same soft-check rationale)
    for (tname, _) in &traces {
        let find = |arm: &str| {
            cells
                .iter()
                .find(|c| {
                    c.arm == arm
                        && c.replicas == 4
                        && c.policy == "round-robin"
                        && c.trace == *tname
                })
                .expect("cell")
        };
        let (plain, traced) = (find("sync"), find("sync-traced"));
        println!(
            "tracing round-robin/{tname}: completed {} -> {} (fps {:.0} -> {:.0})",
            plain.completed, traced.completed, plain.throughput_fps, traced.throughput_fps
        );
        if traced.completed + 8 < plain.completed {
            eprintln!(
                "WARNING round-robin/{tname}: tracing at 1% completed {} < untraced {} — \
                 span sampling is costing throughput",
                traced.completed, plain.completed
            );
        }
    }

    // overlap signal: at one replica, window 4 on the overlapping backend
    // should run ≥1.5× the throughput of window 1 (analytic bound 2.0 for
    // equal legs) — soft check, same wall-clock-noise rationale
    for policy in policies {
        let find = |w: usize| {
            cells
                .iter()
                .find(|c| {
                    c.arm == "async-window" && c.replicas == 1 && c.window == w
                        && c.policy == policy
                })
                .expect("cell")
        };
        let (w1, w4) = (find(1), find(4));
        println!(
            "overlap {policy}: fps {:.0} (w1) -> {:.0} (w4), {:.2}x",
            w1.throughput_fps,
            w4.throughput_fps,
            w4.throughput_fps / w1.throughput_fps.max(1e-9)
        );
        if w4.throughput_fps < 1.5 * w1.throughput_fps {
            eprintln!(
                "WARNING {policy}: async window 4 at {:.0} fps < 1.5x window 1's {:.0} — \
                 the in-flight window is not overlapping transfer with compute",
                w4.throughput_fps, w1.throughput_fps
            );
        }
    }

    if args.has_flag("json") {
        let path = Path::new("BENCH_serving.json");
        std::fs::write(path, cells_json(&cells)).expect("writing BENCH_serving.json");
        println!("wrote {} ({} cells)", path.display(), cells.len());
    }
}
