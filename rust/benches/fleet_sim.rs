//! Bench: the discrete-event fleet simulator at scales the thread-backed
//! server cannot reach — the point of simulating is sweeping topologies
//! that would need thousands of OS threads and minutes of wall time.
//!
//! Arms:
//!
//! * `flat-rr-1000`  — 1000 flat chain groups, round-robin, 1M requests.
//!   The acceptance arm: it must finish in under 10 s of wall clock
//!   (checked loudly on stderr), and it runs at FULL size even under
//!   `--smoke` — shrinking it would defeat the point.
//! * `flat-jsq`      — join-shortest-queue over a smaller fleet (JSQ
//!   inspects every group's load per arrival, so it is the policy whose
//!   dispatch cost grows with fleet size);
//! * `chain-swrr`    — replicated 4-stage chains under the weighted
//!   policy (stresses inter-stage links, blocked-forward backpressure
//!   and in-flight windows);
//! * `auto-diurnal`  — a replicated-chain fleet with the autoscaler and
//!   virtual-tick control plane riding a diurnal trace (the control-path
//!   arm; must scale out at the peak and back in at the trough).
//!
//! Flags: `--smoke` shrinks the non-acceptance arms for CI; `--json`
//! writes the cells to `BENCH_fleetsim.json`.

use std::path::Path;
use std::time::Duration;

use fcmp::control::{AutoscalerConfig, SignalConfig};
use fcmp::coordinator::{diurnal, poisson, BatcherConfig, Deployment, Policy, Trace};
use fcmp::obs::ObsConfig;
use fcmp::sim::{FleetSim, SimBackend, SimConfig, SimControl};
use fcmp::util::args::Args;
use fcmp::util::bench::Table;

struct Cell {
    arm: &'static str,
    policy: &'static str,
    trace: &'static str,
    chains: usize,
    stages: usize,
    window: usize,
    requests: usize,
    completed: usize,
    shed: usize,
    virtual_s: f64,
    wall_s: f64,
    sim_fps: f64,
    events: u64,
    p99_ms: f64,
    groups_peak: usize,
    groups_final: usize,
}

#[allow(clippy::too_many_arguments)]
fn run_arm(
    arm: &'static str,
    plan: Deployment,
    backend: SimBackend,
    standby: usize,
    control: Option<SimControl>,
    trace: &Trace,
    trace_name: &'static str,
    trace_sample: f64,
) -> Cell {
    let chains = plan.groups.len();
    let stages = plan.groups.first().map_or(1, |g| g.stages);
    let window = plan.window;
    let policy = plan.policy.name();
    let cfg = SimConfig {
        input_len: 4,
        seed: 42,
        control,
        obs: ObsConfig { sample: trace_sample, ..ObsConfig::default() },
        health: None,
    };
    let t0 = std::time::Instant::now();
    let rep = FleetSim::uniform_with_standby(plan, backend, standby, cfg).run(trace);
    let wall = t0.elapsed().as_secs_f64();
    let p99_ms = rep.summary.fleet.as_ref().map_or(0.0, |f| f.latency_ms.p99);
    Cell {
        arm,
        policy,
        trace: trace_name,
        chains,
        stages,
        window,
        requests: trace.arrivals_s.len(),
        completed: rep.completed,
        shed: rep.shed,
        virtual_s: rep.sim_seconds,
        wall_s: wall,
        sim_fps: rep.submitted as f64 / wall.max(1e-9),
        events: rep.events_processed,
        p99_ms,
        groups_peak: rep.max_groups_seen,
        groups_final: rep.final_groups,
    }
}

fn cells_json(cells: &[Cell]) -> String {
    let mut out = String::from("[");
    for (k, c) in cells.iter().enumerate() {
        if k > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"arm\":{:?},\"policy\":{:?},\"trace\":{:?},\"chains\":{},\"stages\":{},\
             \"window\":{},\"requests\":{},\"completed\":{},\"shed\":{},\
             \"virtual_s\":{:.4},\"wall_s\":{:.3},\"sim_fps\":{:.0},\"events\":{},\
             \"p99_ms\":{:.3},\"groups_peak\":{},\"groups_final\":{}}}",
            c.arm,
            c.policy,
            c.trace,
            c.chains,
            c.stages,
            c.window,
            c.requests,
            c.completed,
            c.shed,
            c.virtual_s,
            c.wall_s,
            c.sim_fps,
            c.events,
            c.p99_ms,
            c.groups_peak,
            c.groups_final
        ));
    }
    out.push(']');
    out
}

fn mock(per_item_us: f64) -> SimBackend {
    SimBackend::Mock {
        base: Duration::ZERO,
        per_item: Duration::from_secs_f64(per_item_us * 1e-6),
    }
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has_flag("smoke");
    let batcher = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) };

    // acceptance arm: 1000 groups x 1M requests, full size even in smoke.
    // Each group serves 5000 req/s (200 µs/item); RR spreads 2M req/s
    // offered to 2000 req/s per group, comfortably under capacity.
    let big_trace = poisson(1_000_000, 2.0e6, 42);
    let big = run_arm(
        "flat-rr-1000",
        Deployment::replicated(1000)
            .with_policy(Policy::RoundRobin)
            .with_batcher(batcher)
            .with_queue_depth(64)
            .with_window(2),
        mock(200.0),
        0,
        None,
        &big_trace,
        "poisson",
        0.0,
    );
    if big.wall_s >= 10.0 {
        eprintln!(
            "WARNING flat-rr-1000 took {:.1} s wall for {} requests — the \
             acceptance bound is < 10 s (noisy runner, or a sim perf regression)",
            big.wall_s, big.requests
        );
    }

    // JSQ pays O(groups) per arrival, so its fleet stays moderate
    let (jsq_groups, jsq_n) = if smoke { (64, 100_000) } else { (128, 400_000) };
    let jsq_trace = poisson(jsq_n, 2_000.0 * jsq_groups as f64, 43);
    let jsq = run_arm(
        "flat-jsq",
        Deployment::replicated(jsq_groups)
            .with_policy(Policy::JoinShortestQueue)
            .with_batcher(batcher)
            .with_queue_depth(64)
            .with_window(2),
        mock(200.0),
        0,
        None,
        &jsq_trace,
        "poisson",
        0.0,
    );

    // replicated 4-stage chains under SWRR: per-stage 50 µs, so a chain
    // still sustains 5000 req/s end to end (bottleneck = slowest stage)
    let (chain_groups, chain_n) = if smoke { (32, 100_000) } else { (128, 400_000) };
    let chain_trace = poisson(chain_n, 2_000.0 * chain_groups as f64, 44);
    let chain = run_arm(
        "chain-swrr",
        Deployment::replicated_chains(chain_groups, 4)
            .with_policy(Policy::Weighted(vec![1.0; chain_groups]))
            .with_batcher(batcher)
            .with_queue_depth(64)
            .with_window(2),
        mock(50.0),
        0,
        None,
        &chain_trace,
        "poisson",
        0.0,
    );

    // the same chain sweep with the span tracer armed at 1% (rings only):
    // the observability-overhead arm — sim_fps must hold against
    // chain-swrr across runs
    let chain_traced = run_arm(
        "chain-swrr-traced",
        Deployment::replicated_chains(chain_groups, 4)
            .with_policy(Policy::Weighted(vec![1.0; chain_groups]))
            .with_batcher(batcher)
            .with_queue_depth(64)
            .with_window(2),
        mock(50.0),
        0,
        None,
        &chain_trace,
        "poisson",
        0.01,
    );
    if chain_traced.sim_fps < 0.7 * chain.sim_fps {
        eprintln!(
            "WARNING chain-swrr-traced ran at {:.0} sim req/s vs untraced {:.0} — \
             1% span sampling is costing more than 30% of sim throughput",
            chain_traced.sim_fps, chain.sim_fps
        );
    }

    // control-path arm: 2-stage chains, 1 active + 3 standby, diurnal
    // trace whose peak (2000 req/s) overruns one chain (1000 req/s at
    // 1 ms/item) so the autoscaler must scale out, then back in at the
    // trough (500 req/s)
    let auto_n = if smoke { 20_000 } else { 60_000 };
    let auto_trace = diurnal(auto_n, 500.0, 2_000.0, 8.0, 45);
    let auto = run_arm(
        "auto-diurnal",
        Deployment::replicated_chains(1, 2)
            .with_policy(Policy::RoundRobin)
            .with_batcher(batcher)
            .with_queue_depth(64)
            .with_window(2),
        mock(500.0),
        3,
        Some(SimControl {
            tick: Duration::from_millis(25),
            signal: SignalConfig { window_ticks: 3 },
            autoscaler: Some(AutoscalerConfig {
                min_groups: 1,
                max_groups: 4,
                shed_out: 0.02,
                p99_out_ms: f64::INFINITY,
                util_in: 0.25,
                cooldown_ticks: 3,
                step: 1,
            }),
            slo: None,
            trailing_ticks: 8,
        }),
        &auto_trace,
        "diurnal",
        0.0,
    );
    if auto.groups_peak <= 1 {
        eprintln!(
            "WARNING auto-diurnal never scaled past 1 chain group under a 2x \
             overload peak — the simulated control plane is not reacting"
        );
    }
    if auto.groups_final >= auto.groups_peak && auto.groups_peak > 1 {
        eprintln!(
            "WARNING auto-diurnal finished at {} groups (peak {}) — expected a \
             scale-in at the trough",
            auto.groups_final, auto.groups_peak
        );
    }

    let cells = vec![big, jsq, chain, chain_traced, auto];

    let mut t = Table::new([
        "arm", "policy", "chains", "stages", "req", "completed", "shed", "virt s",
        "wall s", "sim req/s", "events", "p99 ms", "g peak", "g final",
    ]);
    for c in &cells {
        t.row([
            c.arm.to_string(),
            c.policy.to_string(),
            format!("{}", c.chains),
            format!("{}", c.stages),
            format!("{}", c.requests),
            format!("{}", c.completed),
            format!("{}", c.shed),
            format!("{:.3}", c.virtual_s),
            format!("{:.2}", c.wall_s),
            format!("{:.0}", c.sim_fps),
            format!("{}", c.events),
            format!("{:.2}", c.p99_ms),
            format!("{}", c.groups_peak),
            format!("{}", c.groups_final),
        ]);
    }
    println!("== Fleet DES sweep (virtual-clock Deployment execution) ==");
    println!("{}", t.render());
    println!(
        "headline: {} requests across {} chain groups in {:.2} s wall \
         ({:.0} simulated req/s of wall time)",
        big.requests, big.chains, big.wall_s, big.sim_fps
    );

    if args.has_flag("json") {
        let path = Path::new("BENCH_fleetsim.json");
        std::fs::write(path, cells_json(&cells)).expect("writing BENCH_fleetsim.json");
        println!("wrote {} ({} cells)", path.display(), cells.len());
    }
}
