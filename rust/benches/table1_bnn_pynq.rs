//! Bench: regenerate paper Table I — BNN-Pynq resource utilization on
//! Zynq 7020 (BRAM/LUT/DSP percent per CNV variant).
use fcmp::util::bench::{bench, report, BenchConfig};

fn main() {
    println!("== Table I: FINN dataflow accelerators on Zynq 7020 ==");
    println!("{}", fcmp::report::table1().render());
    let r = bench("table1_model_eval", BenchConfig::default(), || {
        std::hint::black_box(fcmp::report::table1());
    });
    report(&r);
}
