//! Bench: pipeline-parallel sharding scaling — (shard count × device mix
//! × chain-group replication) → analytic and simulated FPS, per-shard OCM
//! pressure, link utilization, and partitioner wall time. Every cell
//! partitions a network over a device list with per-shard FCMP packing
//! (FFD engine: deterministic and fast, and the process-wide packing
//! cache dedups repeated ranges), then validates the plan with the
//! discrete-event staged-pipeline simulator and a diurnal serving replay
//! of `chains` replicated copies of the stage chain on calibrated mocks —
//! the replicated-chain rows are the throughput-beyond-one-pipeline
//! signal, with the worst per-group e2e p99 reported alongside.
//!
//! Flags: `--smoke` shrinks frames/requests for CI; `--json` writes the
//! cells to `BENCH_sharding.json` (the sharding perf-trajectory artifact).

use std::path::Path;
use std::time::{Duration, Instant};

use fcmp::coordinator::{
    diurnal, shard_service_times, BatcherConfig, Deployment, MockBackend, Server, WorkerId,
};
use fcmp::device;
use fcmp::nn::{cnv, resnet50, CnvVariant, Network};
use fcmp::sharding::{partition, PartitionConfig, ShardPlan};
use fcmp::sim;
use fcmp::util::args::Args;
use fcmp::util::bench::Table;

struct Cell {
    network: String,
    mix: String,
    shards: usize,
    chains: usize,
    feasible: bool,
    analytic_fps: f64,
    sim_fps: f64,
    vs_analytic: f64,
    max_ocm_pct: f64,
    max_link_pct: f64,
    partition_ms: f64,
    chain_p99_ms: f64,
    group_p99_ms: f64,
    chain_offered: usize,
    chain_completed: usize,
}

fn infeasible_cell(network: &str, mix: &str, shards: usize, chains: usize, elapsed_ms: f64) -> Cell {
    Cell {
        network: network.to_string(),
        mix: mix.to_string(),
        shards,
        chains,
        feasible: false,
        analytic_fps: 0.0,
        sim_fps: 0.0,
        vs_analytic: 0.0,
        max_ocm_pct: 0.0,
        max_link_pct: 0.0,
        partition_ms: elapsed_ms,
        chain_p99_ms: 0.0,
        group_p99_ms: 0.0,
        chain_offered: 0,
        chain_completed: 0,
    }
}

/// Replay a diurnal trace through `chains` replicated copies of the
/// plan's stage chain on mocks whose per-stage service equals the
/// analytic shard intervals; returns (fleet e2e p99 ms, worst per-group
/// e2e p99 ms, completed requests). The offered rate scales with the
/// chain count, so the replicated rows demonstrate throughput beyond one
/// pipeline at comparable latency.
fn chain_replay(plan: &ShardPlan, requests: usize, chains: usize) -> (f64, f64, usize) {
    let svc = shard_service_times(plan);
    // keep mock sleeps sane on CI: cap per-stage service at 2 ms
    let svc: Vec<Duration> = svc.into_iter().map(|d| d.min(Duration::from_millis(2))).collect();
    let dep = Deployment::replicated_chains(chains, plan.shards.len())
        .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) })
        .with_queue_depth(32);
    let bottleneck = svc.iter().cloned().max().unwrap_or(Duration::from_micros(100));
    let rate = (0.7 * chains as f64 / bottleneck.as_secs_f64()).min(4000.0 * chains as f64);
    let svc_backend = svc.clone();
    let mut srv = Server::deploy(
        move |id: WorkerId| MockBackend::with_service(Duration::ZERO, svc_backend[id.stage]),
        dep,
    );
    let trace = diurnal(requests, (rate * 0.5).max(1.0), rate, 2.0, 42);
    let fm = srv.replay(&trace, 4, 42);
    srv.shutdown();
    let s = fm.summary();
    let group_p99 = s
        .per_group
        .iter()
        .flatten()
        .map(|g| g.latency_ms.p99)
        .fold(0.0f64, f64::max);
    match s.fleet {
        Some(f) => (f.latency_ms.p99, group_p99, f.requests),
        None => (0.0, 0.0, 0),
    }
}

fn run_cell(net: &Network, mix: &str, chains: usize, frames: u64, requests: usize) -> Cell {
    let devices: Vec<device::Device> =
        mix.split('+').map(|n| device::by_name(n).expect("device name")).collect();
    let cfg = PartitionConfig { generations: 0, ..PartitionConfig::default() };
    let t0 = Instant::now();
    let plan = partition(net, &devices, cfg);
    let partition_ms = t0.elapsed().as_secs_f64() * 1e3;
    let plan = match plan {
        Err(_) => return infeasible_cell(&net.name, mix, devices.len(), chains, partition_ms),
        Ok(p) => p,
    };
    let r = sim::simulate_sharded(net, &plan, frames, 8);
    let chain_offered = requests * chains;
    let (chain_p99_ms, group_p99_ms, chain_completed) =
        chain_replay(&plan, chain_offered, chains);
    Cell {
        network: net.name.clone(),
        mix: mix.to_string(),
        shards: plan.shards.len(),
        chains,
        feasible: true,
        analytic_fps: plan.fps,
        sim_fps: r.fps,
        vs_analytic: r.vs_analytic,
        max_ocm_pct: 100.0 * plan.shards.iter().map(|s| s.bram_pressure()).fold(0.0, f64::max),
        max_link_pct: 100.0 * plan.link_utilization().into_iter().fold(0.0, f64::max),
        partition_ms,
        chain_p99_ms,
        group_p99_ms,
        chain_offered,
        chain_completed,
    }
}

fn cells_json(cells: &[Cell]) -> String {
    let mut out = String::from("[");
    for (k, c) in cells.iter().enumerate() {
        if k > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"network\":{:?},\"mix\":{:?},\"shards\":{},\"chains\":{},\"feasible\":{},\
             \"analytic_fps\":{:.1},\"sim_fps\":{:.1},\"vs_analytic\":{:.4},\
             \"max_ocm_pct\":{:.1},\"max_link_pct\":{:.1},\"partition_ms\":{:.3},\
             \"chain_p99_ms\":{:.3},\"group_p99_ms\":{:.3},\"chain_offered\":{},\
             \"chain_completed\":{}}}",
            c.network,
            c.mix,
            c.shards,
            c.chains,
            c.feasible,
            c.analytic_fps,
            c.sim_fps,
            c.vs_analytic,
            c.max_ocm_pct,
            c.max_link_pct,
            c.partition_ms,
            c.chain_p99_ms,
            c.group_p99_ms,
            c.chain_offered,
            c.chain_completed
        ));
    }
    out.push(']');
    out
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has_flag("smoke");
    let frames = if smoke { 150 } else { 400 };
    let requests = if smoke { 80 } else { 256 };

    let cnv2 = cnv(CnvVariant::W2A2);
    let rn50 = resnet50(1);
    // (network, device mix, chain-group copies): chains > 1 rows serve N
    // replicated copies of the partitioned chain behind one router
    let cases: Vec<(&Network, &str, usize)> = vec![
        (&cnv2, "7012s", 1),
        (&cnv2, "7012s+7012s", 1),
        (&cnv2, "7012s+7012s", 2),
        (&cnv2, "7020+7012s", 1),
        (&cnv2, "7012s+7012s+7012s", 1),
        (&rn50, "u280", 1),
        (&rn50, "u280+u280", 1),
        (&rn50, "u250+u280", 1),
        (&rn50, "u250+u280", 2),
    ];

    let mut cells = Vec::new();
    let mut t = Table::new([
        "network", "mix", "k", "chains", "feasible", "analytic fps", "sim fps",
        "sim/analytic", "max OCM %", "link %", "partition ms", "chain p99 ms",
        "group p99 ms",
    ]);
    for (net, mix, chains) in cases {
        let c = run_cell(net, mix, chains, frames, requests);
        t.row([
            c.network.clone(),
            c.mix.clone(),
            format!("{}", c.shards),
            format!("{}", c.chains),
            format!("{}", c.feasible),
            format!("{:.0}", c.analytic_fps),
            format!("{:.0}", c.sim_fps),
            format!("{:.3}", c.vs_analytic),
            format!("{:.0}", c.max_ocm_pct),
            format!("{:.0}", c.max_link_pct),
            format!("{:.1}", c.partition_ms),
            format!("{:.2}", c.chain_p99_ms),
            format!("{:.2}", c.group_p99_ms),
        ]);
        cells.push(c);
    }
    println!("== Sharding scaling (FFD engine, {frames} sim frames) ==");
    println!("{}", t.render());

    // hard signal: every feasible plan's sim must track the analytic model
    for c in &cells {
        if c.feasible && (c.vs_analytic - 1.0).abs() > 0.02 {
            eprintln!(
                "WARNING {}/{}: sim {:.1} fps vs analytic {:.1} ({:.3}) — \
                 staged-pipeline model drift",
                c.network, c.mix, c.sim_fps, c.analytic_fps, c.vs_analytic
            );
        }
    }
    // replicated-chain signal: at fixed mix, the 2-chain cell is offered
    // 2x the requests, so compare completion *rates* (completed/offered)
    // — absolute counts would stay green even if the router pinned all
    // traffic to one chain of the pair. Soft check (sleep-based mocks on
    // shared CI runners).
    for (a, b) in [("CNV-W2A2", "7012s+7012s"), ("RN50-W1", "u250+u280")] {
        let one = cells.iter().find(|c| c.network.starts_with(a) && c.mix == b && c.chains == 1);
        let two = cells.iter().find(|c| c.network.starts_with(a) && c.mix == b && c.chains == 2);
        if let (Some(one), Some(two)) = (one, two) {
            let rate = |c: &Cell| c.chain_completed as f64 / c.chain_offered.max(1) as f64;
            println!(
                "replicated chains {a}/{b}: completed {}/{} (1 chain) -> {}/{} (2 chains), \
                 group p99 {:.2} -> {:.2} ms",
                one.chain_completed,
                one.chain_offered,
                two.chain_completed,
                two.chain_offered,
                one.group_p99_ms,
                two.group_p99_ms
            );
            if rate(two) + 0.02 < rate(one) {
                eprintln!(
                    "WARNING {a}/{b}: 2 chains completed {:.0}% of their 2x-offered trace \
                     vs {:.0}% for 1 chain — replication is not holding the completion \
                     rate (noisy runner, or a routing regression)",
                    100.0 * rate(two),
                    100.0 * rate(one)
                );
            }
        }
    }

    if args.has_flag("json") {
        let path = Path::new("BENCH_sharding.json");
        std::fs::write(path, cells_json(&cells)).expect("writing BENCH_sharding.json");
        println!("wrote {} ({} cells)", path.display(), cells.len());
    }
}
