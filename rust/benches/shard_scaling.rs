//! Bench: pipeline-parallel sharding scaling — shard count × device mix →
//! analytic and simulated FPS, per-shard OCM pressure, link utilization,
//! and partitioner wall time. Every cell partitions a network over a
//! device list with per-shard FCMP packing (FFD engine: deterministic and
//! fast, and the process-wide packing cache dedups repeated ranges), then
//! validates the plan with the discrete-event staged-pipeline simulator
//! and a diurnal stage-chain serving replay on calibrated mocks.
//!
//! Flags: `--smoke` shrinks frames/requests for CI; `--json` writes the
//! cells to `BENCH_sharding.json` (the sharding perf-trajectory artifact).

use std::path::Path;
use std::time::{Duration, Instant};

use fcmp::coordinator::{
    diurnal, shard_service_times, BatcherConfig, MockBackend, Policy, Server, ServerConfig,
};
use fcmp::device;
use fcmp::nn::{cnv, resnet50, CnvVariant, Network};
use fcmp::sharding::{partition, PartitionConfig, ShardPlan};
use fcmp::sim;
use fcmp::util::args::Args;
use fcmp::util::bench::Table;

struct Cell {
    network: String,
    mix: String,
    shards: usize,
    feasible: bool,
    analytic_fps: f64,
    sim_fps: f64,
    vs_analytic: f64,
    max_ocm_pct: f64,
    max_link_pct: f64,
    partition_ms: f64,
    chain_p99_ms: f64,
    chain_completed: usize,
}

fn infeasible_cell(network: &str, mix: &str, shards: usize, elapsed_ms: f64) -> Cell {
    Cell {
        network: network.to_string(),
        mix: mix.to_string(),
        shards,
        feasible: false,
        analytic_fps: 0.0,
        sim_fps: 0.0,
        vs_analytic: 0.0,
        max_ocm_pct: 0.0,
        max_link_pct: 0.0,
        partition_ms: elapsed_ms,
        chain_p99_ms: 0.0,
        chain_completed: 0,
    }
}

/// Replay a diurnal trace through the plan's stage chain on mocks whose
/// per-stage service equals the analytic shard intervals; returns
/// (end-to-end p99 ms, completed requests).
fn chain_replay(plan: &ShardPlan, requests: usize) -> (f64, usize) {
    let svc = shard_service_times(plan);
    // keep mock sleeps sane on CI: cap per-stage service at 2 ms
    let svc: Vec<Duration> = svc.into_iter().map(|d| d.min(Duration::from_millis(2))).collect();
    let cfg = ServerConfig {
        batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) },
        queue_depth: 32,
        replicas: plan.shards.len(),
        policy: Policy::StageChain,
    };
    let bottleneck = svc.iter().cloned().max().unwrap_or(Duration::from_micros(100));
    let rate = (0.7 / bottleneck.as_secs_f64()).min(4000.0);
    let mut srv = Server::start_chain(
        move |i| MockBackend::with_service(Duration::ZERO, svc[i]),
        cfg,
    );
    let trace = diurnal(requests, (rate * 0.5).max(1.0), rate, 2.0, 42);
    let fm = srv.replay(&trace, 4, 42);
    srv.shutdown();
    let s = fm.summary();
    match s.fleet {
        Some(f) => (f.latency_ms.p99, f.requests),
        None => (0.0, 0),
    }
}

fn run_cell(net: &Network, mix: &str, frames: u64, requests: usize) -> Cell {
    let devices: Vec<device::Device> =
        mix.split('+').map(|n| device::by_name(n).expect("device name")).collect();
    let cfg = PartitionConfig { generations: 0, ..PartitionConfig::default() };
    let t0 = Instant::now();
    let plan = partition(net, &devices, cfg);
    let partition_ms = t0.elapsed().as_secs_f64() * 1e3;
    let plan = match plan {
        Err(_) => return infeasible_cell(&net.name, mix, devices.len(), partition_ms),
        Ok(p) => p,
    };
    let r = sim::simulate_sharded(net, &plan, frames, 8);
    let (chain_p99_ms, chain_completed) = chain_replay(&plan, requests);
    Cell {
        network: net.name.clone(),
        mix: mix.to_string(),
        shards: plan.shards.len(),
        feasible: true,
        analytic_fps: plan.fps,
        sim_fps: r.fps,
        vs_analytic: r.vs_analytic,
        max_ocm_pct: 100.0 * plan.shards.iter().map(|s| s.bram_pressure()).fold(0.0, f64::max),
        max_link_pct: 100.0 * plan.link_utilization().into_iter().fold(0.0, f64::max),
        partition_ms,
        chain_p99_ms,
        chain_completed,
    }
}

fn cells_json(cells: &[Cell]) -> String {
    let mut out = String::from("[");
    for (k, c) in cells.iter().enumerate() {
        if k > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"network\":{:?},\"mix\":{:?},\"shards\":{},\"feasible\":{},\
             \"analytic_fps\":{:.1},\"sim_fps\":{:.1},\"vs_analytic\":{:.4},\
             \"max_ocm_pct\":{:.1},\"max_link_pct\":{:.1},\"partition_ms\":{:.3},\
             \"chain_p99_ms\":{:.3},\"chain_completed\":{}}}",
            c.network,
            c.mix,
            c.shards,
            c.feasible,
            c.analytic_fps,
            c.sim_fps,
            c.vs_analytic,
            c.max_ocm_pct,
            c.max_link_pct,
            c.partition_ms,
            c.chain_p99_ms,
            c.chain_completed
        ));
    }
    out.push(']');
    out
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has_flag("smoke");
    let frames = if smoke { 150 } else { 400 };
    let requests = if smoke { 80 } else { 256 };

    let cnv2 = cnv(CnvVariant::W2A2);
    let rn50 = resnet50(1);
    let cases: Vec<(&Network, &str)> = vec![
        (&cnv2, "7012s"),
        (&cnv2, "7012s+7012s"),
        (&cnv2, "7020+7012s"),
        (&cnv2, "7012s+7012s+7012s"),
        (&rn50, "u280"),
        (&rn50, "u280+u280"),
        (&rn50, "u250+u280"),
    ];

    let mut cells = Vec::new();
    let mut t = Table::new([
        "network", "mix", "k", "feasible", "analytic fps", "sim fps", "sim/analytic",
        "max OCM %", "link %", "partition ms", "chain p99 ms",
    ]);
    for (net, mix) in cases {
        let c = run_cell(net, mix, frames, requests);
        t.row([
            c.network.clone(),
            c.mix.clone(),
            format!("{}", c.shards),
            format!("{}", c.feasible),
            format!("{:.0}", c.analytic_fps),
            format!("{:.0}", c.sim_fps),
            format!("{:.3}", c.vs_analytic),
            format!("{:.0}", c.max_ocm_pct),
            format!("{:.0}", c.max_link_pct),
            format!("{:.1}", c.partition_ms),
            format!("{:.2}", c.chain_p99_ms),
        ]);
        cells.push(c);
    }
    println!("== Sharding scaling (FFD engine, {frames} sim frames) ==");
    println!("{}", t.render());

    // hard signal: every feasible plan's sim must track the analytic model
    for c in &cells {
        if c.feasible && (c.vs_analytic - 1.0).abs() > 0.02 {
            eprintln!(
                "WARNING {}/{}: sim {:.1} fps vs analytic {:.1} ({:.3}) — \
                 staged-pipeline model drift",
                c.network, c.mix, c.sim_fps, c.analytic_fps, c.vs_analytic
            );
        }
    }

    if args.has_flag("json") {
        let path = Path::new("BENCH_sharding.json");
        std::fs::write(path, cells_json(&cells)).expect("writing BENCH_sharding.json");
        println!("wrote {} ({} cells)", path.display(), cells.len());
    }
}
