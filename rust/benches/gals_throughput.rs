//! Bench: the GALS streamer schedules of Fig. 7 — per-stream read rates at
//! every (N_b, R_F) configuration the paper discusses, plus the adaptive
//! vs static slot-allocation comparison and simulator speed.
use fcmp::gals::{Ratio, StreamerConfig, StreamerSim};
use fcmp::util::bench::{bench, report, BenchConfig, Table};

fn main() {
    let cycles = 20_000;
    let mut t = Table::new(["config", "min rate", "max rate", "wasted slots", "expected"]);
    let cases: Vec<(String, StreamerConfig, &str)> = vec![
        (
            "7a: Nb=2 RF=1".into(),
            StreamerConfig::fig7a(2, 128, Ratio::new(1, 1)),
            "1.0 (dual port)",
        ),
        ("7a: Nb=4 RF=2".into(), StreamerConfig::fig7a(4, 128, Ratio::two()), "1.0 (2RF/Nb)"),
        ("7a: Nb=4 RF=1".into(), StreamerConfig::fig7a(4, 128, Ratio::new(1, 1)), "0.5 (2RF/Nb)"),
        ("7a: Nb=6 RF=3".into(), StreamerConfig::fig7a(6, 128, Ratio::new(3, 1)), "1.0 (2RF/Nb)"),
        ("7a: Nb=8 RF=2".into(), StreamerConfig::fig7a(8, 128, Ratio::two()), "0.5 (over Eq.2)"),
        ("7b: Nb=3 RF=1.5 adaptive".into(), StreamerConfig::fig7b(3, 128), "1.0 (redistributed)"),
        ("7b: Nb=5 RF=2.5 adaptive".into(), StreamerConfig::fig7b(5, 128), "1.0 (redistributed)"),
        (
            "7b: Nb=3 RF=1.5 static".into(),
            {
                let mut c = StreamerConfig::fig7b(3, 128);
                c.adaptive = false;
                c
            },
            "0.75 (wasted slots)",
        ),
    ];
    for (name, cfg, expected) in cases {
        let r = StreamerSim::new(cfg).run(cycles);
        let max = r.per_stream.iter().map(|s| s.rate).fold(0.0f64, f64::max);
        t.row([
            name,
            format!("{:.3}", r.min_rate()),
            format!("{max:.3}"),
            format!("{}", r.wasted_slots),
            expected.to_string(),
        ]);
    }
    println!("== Fig 7: GALS streamer schedules ({cycles} compute cycles) ==");
    println!("{}", t.render());

    let r = bench(
        "gals_sim_100k_cycles_nb4",
        BenchConfig { warmup_iters: 1, samples: 10, iters_per_sample: 1 },
        || {
            let mut sim = StreamerSim::new(StreamerConfig::fig7a(4, 256, Ratio::two()));
            std::hint::black_box(sim.run(100_000));
        },
    );
    report(&r);
    let cps = 100_000.0 / r.per_iter_secs.mean;
    println!("simulator speed: {:.1} M compute-cycles/s", cps / 1e6);
}
