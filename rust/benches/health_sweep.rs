//! Bench: week-long diurnal fleet-health sweeps through the
//! discrete-event simulator — the acceptance run of the long-horizon
//! observability layer. 168 simulated hours of diurnal load, sampled
//! every virtual minute into the fixed-memory time-series store, with
//! the multiwindow SLO burn alerters firing/clearing across each daily
//! peak and `obs::health::correlate` attributing every incident to the
//! control plane's response (or flagging it unmitigated).
//!
//! Arms:
//!
//! * `week-diurnal-auto`   — 1 active + 2 standby chain groups with the
//!   autoscaler on: each morning's peak overruns the active fleet, the
//!   scaler steps out, the burn page fires while the wave still exceeds
//!   max capacity and clears on the descent — incidents here must be
//!   **mitigated** (a ScaleOut lands inside the breach window);
//! * `week-diurnal-static` — the same week against a frozen 1-group
//!   fleet: no control events, so every incident must come back
//!   **unresponded** (the baseline an SRE dashboard shows without
//!   autoscaling);
//! * `day-diurnal-auto`    — a 24 h version with the alert windows
//!   compressed 10× (`window_scale 0.1`), the CI smoke shape.
//!
//! The full week must finish in wall-clock seconds (warned loudly if it
//! exceeds 60 s). `--smoke` shrinks the week arms to one day; `--json`
//! writes `BENCH_health.json`.

use std::path::Path;
use std::time::Duration;

use fcmp::control::{AutoscalerConfig, SignalConfig};
use fcmp::coordinator::{diurnal, BatcherConfig, Deployment, Policy, Trace};
use fcmp::obs::health::{correlate, stats};
use fcmp::obs::HealthConfig;
use fcmp::sim::{FleetSim, SimBackend, SimConfig, SimControl};
use fcmp::util::args::Args;
use fcmp::util::bench::Table;

/// Per-group service: 1.8 s/item, so one single-stage group sustains
/// ~0.55 req/s and the 3-group ceiling ~1.66 req/s — the diurnal peak
/// (2.5 req/s) overruns even the fully scaled fleet, keeping the burn
/// alert lit until the wave descends (mitigation != instant recovery).
const PER_ITEM_S: f64 = 1.8;
const BASE_RATE: f64 = 0.25;
const PEAK_RATE: f64 = 2.5;
const DAY_S: f64 = 86_400.0;

struct Cell {
    arm: &'static str,
    policy: &'static str,
    trace: &'static str,
    chains: usize,
    stages: usize,
    window: usize,
    requests: usize,
    completed: usize,
    shed: usize,
    incidents: usize,
    mitigated: usize,
    unresponded: usize,
    alerts: usize,
    mean_ttd_s: f64,
    mean_ttm_s: f64,
    virtual_s: f64,
    wall_s: f64,
}

fn run_arm(
    arm: &'static str,
    standby: usize,
    control: Option<SimControl>,
    trace: &Trace,
    health: HealthConfig,
) -> Cell {
    let plan = Deployment::replicated(1)
        .with_policy(Policy::RoundRobin)
        .with_batcher(BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(100) })
        .with_queue_depth(64)
        .with_window(1);
    let chains = plan.groups.len();
    let stages = plan.groups.first().map_or(1, |g| g.stages);
    let window = plan.window;
    let policy = plan.policy.name();
    let backend =
        SimBackend::Mock { base: Duration::ZERO, per_item: Duration::from_secs_f64(PER_ITEM_S) };
    let cfg = SimConfig {
        input_len: 4,
        seed: 42,
        control,
        obs: fcmp::obs::ObsConfig::default(),
        health: Some(health),
    };
    let t0 = std::time::Instant::now();
    let rep = FleetSim::uniform_with_standby(plan, backend, standby, cfg).run(trace);
    let wall = t0.elapsed().as_secs_f64();
    let journal = rep.health.expect("health collection was configured");
    let incidents = correlate(&journal, &rep.events);
    let st = stats(&incidents);
    Cell {
        arm,
        policy,
        trace: "diurnal",
        chains,
        stages,
        window,
        requests: trace.arrivals_s.len(),
        completed: rep.completed,
        shed: rep.shed,
        incidents: st.incidents,
        mitigated: st.mitigated,
        unresponded: st.unresponded,
        alerts: journal.alerts.len(),
        mean_ttd_s: st.mean_ttd_s,
        mean_ttm_s: st.mean_ttm_s,
        virtual_s: rep.sim_seconds,
        wall_s: wall,
    }
}

/// The virtual-tick control plane shared by the auto arms: one-minute
/// ticks, scale-out on >2 % shed, scale-in below 25 % utilization. The
/// four-hour cooldown is deliberately slower than the morning ramp: the
/// second scale-out lands while the fleet is *still* shedding, inside
/// the contiguous breach run the burn alert dates — a mitigated
/// incident, not a response that predates the breach.
fn auto_control() -> SimControl {
    SimControl {
        tick: Duration::from_secs(60),
        signal: SignalConfig { window_ticks: 3 },
        autoscaler: Some(AutoscalerConfig {
            min_groups: 1,
            max_groups: 3,
            shed_out: 0.02,
            p99_out_ms: f64::INFINITY,
            util_in: 0.25,
            cooldown_ticks: 240,
            step: 1,
        }),
        slo: None,
        trailing_ticks: 8,
    }
}

/// Health collection at a one-minute cadence persisting one-minute
/// cells — the default SRE windows (1 h/5 m page, 6 h/30 m ticket)
/// scaled by `window_scale`.
fn health_cfg(window_scale: f64) -> HealthConfig {
    HealthConfig {
        sample_s: 60.0,
        p99_budget_ms: 30_000.0,
        window_scale,
        ..HealthConfig::default()
    }
}

fn diurnal_trace(days: f64, seed: u64) -> Trace {
    let n = ((BASE_RATE + PEAK_RATE) / 2.0 * days * DAY_S) as usize;
    diurnal(n, BASE_RATE, PEAK_RATE, DAY_S, seed)
}

fn cells_json(cells: &[Cell]) -> String {
    let mut out = String::from("[");
    for (k, c) in cells.iter().enumerate() {
        if k > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"arm\":{:?},\"policy\":{:?},\"trace\":{:?},\"chains\":{},\"stages\":{},\
             \"window\":{},\"requests\":{},\"completed\":{},\"shed\":{},\"incidents\":{},\
             \"mitigated\":{},\"unresponded\":{},\"alerts\":{},\"mean_ttd_s\":{:.1},\
             \"mean_ttm_s\":{:.1},\"virtual_s\":{:.1},\"wall_s\":{:.3}}}",
            c.arm,
            c.policy,
            c.trace,
            c.chains,
            c.stages,
            c.window,
            c.requests,
            c.completed,
            c.shed,
            c.incidents,
            c.mitigated,
            c.unresponded,
            c.alerts,
            c.mean_ttd_s,
            c.mean_ttm_s,
            c.virtual_s,
            c.wall_s
        ));
    }
    out.push(']');
    out
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has_flag("smoke");
    // --smoke compresses the "week" arms to one day so CI stays fast;
    // the alert windows compress with them
    let (days, scale) = if smoke { (1.0, 0.1) } else { (7.0, 1.0) };
    let trace = diurnal_trace(days, 42);

    let auto = run_arm(
        "week-diurnal-auto",
        2,
        Some(auto_control()),
        &trace,
        health_cfg(scale),
    );
    if auto.wall_s >= 60.0 {
        eprintln!(
            "WARNING week-diurnal-auto took {:.1} s wall for {:.0} virtual s — the \
             week-long sweep is expected to finish in wall-clock seconds",
            auto.wall_s, auto.virtual_s
        );
    }
    if auto.incidents == 0 {
        eprintln!(
            "WARNING week-diurnal-auto produced no incidents — the diurnal peak \
             should overrun even the scaled fleet and trip the burn alerts"
        );
    }
    if auto.mitigated == 0 {
        eprintln!(
            "WARNING week-diurnal-auto has no mitigated incident — the autoscaler's \
             ScaleOut should land inside every breach window"
        );
    }

    // the baseline arm: same week, frozen fleet, no control plane — the
    // health ticks still run (paced by the sample interval) and every
    // incident must come back unresponded
    let stat = run_arm("week-diurnal-static", 0, None, &trace, health_cfg(scale));
    if stat.incidents == 0 || stat.unresponded != stat.incidents {
        eprintln!(
            "WARNING week-diurnal-static expected only unresponded incidents, got \
             {} of {} unresponded",
            stat.unresponded, stat.incidents
        );
    }

    // the CI smoke shape at full size: one day, windows compressed 10x
    let day_trace = diurnal_trace(1.0, 43);
    let day = run_arm("day-diurnal-auto", 2, Some(auto_control()), &day_trace, health_cfg(0.1));

    let cells = vec![auto, stat, day];

    let mut t = Table::new([
        "arm", "req", "completed", "shed", "incidents", "mitigated", "unresp", "alerts",
        "ttd s", "ttm s", "virt s", "wall s",
    ]);
    for c in &cells {
        t.row([
            c.arm.to_string(),
            format!("{}", c.requests),
            format!("{}", c.completed),
            format!("{}", c.shed),
            format!("{}", c.incidents),
            format!("{}", c.mitigated),
            format!("{}", c.unresponded),
            format!("{}", c.alerts),
            format!("{:.0}", c.mean_ttd_s),
            format!("{:.0}", c.mean_ttm_s),
            format!("{:.0}", c.virtual_s),
            format!("{:.2}", c.wall_s),
        ]);
    }
    println!("== Fleet health sweep (long-horizon store + SLO burn alerts) ==");
    println!("{}", t.render());
    println!(
        "headline: {:.0} simulated hours in {:.2} s wall — {} incident(s), \
         {} mitigated, mean TTD {:.0} s, mean TTM {:.0} s",
        cells[0].virtual_s / 3600.0,
        cells[0].wall_s,
        cells[0].incidents,
        cells[0].mitigated,
        cells[0].mean_ttd_s,
        cells[0].mean_ttm_s
    );

    if args.has_flag("json") {
        let path = Path::new("BENCH_health.json");
        std::fs::write(path, cells_json(&cells)).expect("writing BENCH_health.json");
        println!("wrote {} ({} cells)", path.display(), cells.len());
    }
}
