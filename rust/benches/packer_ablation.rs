//! Bench: packing-engine ablation (paper §II.C landscape) — GA [18] vs
//! first-fit-decreasing vs simulated annealing (MPack) vs exact
//! branch-and-bound (MemPacker, small inputs only): solution quality and
//! runtime on CNV/RN50 workloads plus synthetic heterogeneous sets.
use fcmp::memory;
use fcmp::packing::{anneal::Anneal, bnb::Bnb, ffd::Ffd, ga, run_packer, Constraints, Packer};
use fcmp::util::bench::Table;
use fcmp::util::rng::Rng;

fn engines(gens: usize) -> Vec<(&'static str, Box<dyn Packer>)> {
    vec![
        ("ffd", Box::new(Ffd::new())),
        ("anneal", Box::new(Anneal::default())),
        ("ga[18]", Box::new(ga::Ga::new(ga::GaParams { generations: gens, ..ga::GaParams::cnv() }))),
    ]
}

fn main() {
    let mut t = Table::new(["workload", "engine", "BRAM18", "E %", "time"]);

    // real workloads
    for (name, net, dev) in [
        ("CNV-W1A1/7020", fcmp::nn::cnv(fcmp::nn::CnvVariant::W1A1), fcmp::device::zynq_7020()),
        ("RN50-W1A2/U250", fcmp::nn::resnet50(1), fcmp::device::alveo_u250()),
    ] {
        let bufs = memory::weight_buffers(&net, dev.slrs.len());
        let items = memory::all_columns(&bufs);
        let c = Constraints::new(4, !dev.is_monolithic());
        for (ename, e) in engines(60) {
            let (_, r) = run_packer(e.as_ref(), &items, &c);
            t.row([
                name.to_string(),
                ename.to_string(),
                format!("{}", r.brams),
                format!("{:.1}", 100.0 * r.efficiency),
                format!("{:.1?}", r.elapsed),
            ]);
        }
    }

    // synthetic heterogeneous workload where grouping quality matters,
    // small enough for the exact BnB oracle
    let mut rng = Rng::new(11);
    let items: Vec<memory::PackItem> = (0..12)
        .map(|i| memory::PackItem {
            id: i,
            layer: format!("s{i}"),
            width_bits: 36,
            depth: 24 + rng.below(480),
            slr: 0,
        })
        .collect();
    let c = Constraints::new(4, false);
    for (ename, e) in engines(120) {
        let (_, r) = run_packer(e.as_ref(), &items, &c);
        t.row([
            "synthetic-12".into(),
            ename.to_string(),
            format!("{}", r.brams),
            format!("{:.1}", 100.0 * r.efficiency),
            format!("{:.1?}", r.elapsed),
        ]);
    }
    let (_, r) = run_packer(&Bnb::default(), &items, &c);
    t.row([
        "synthetic-12".into(),
        "bnb (exact)".into(),
        format!("{}", r.brams),
        format!("{:.1}", 100.0 * r.efficiency),
        format!("{:.1?}", r.elapsed),
    ]);

    println!("== Packer ablation ==");
    println!("{}", t.render());
}
