//! Bench: packing-engine ablation (paper §II.C landscape) — GA [18] vs
//! first-fit-decreasing vs simulated annealing (MPack) vs exact
//! branch-and-bound (MemPacker, small inputs only): solution quality and
//! runtime on CNV/RN50 workloads plus synthetic heterogeneous sets.
//!
//! The second half ablates the island-model GA engine itself on the
//! RN50-sized item set: legacy full-refit fitness vs incremental delta-cost
//! fitness, one island vs eight, one worker thread vs eight, plus a
//! microbench of the memoized vs uncached `brams_for` mode search — and
//! verifies the determinism contract (identical packings for identical
//! `(seed, islands)` across runs and thread counts) on every row.
//!
//! Flags: `--smoke` shrinks generations/samples for CI; `--json` writes the
//! timing rows to `BENCH_packing.json` (the perf-trajectory artifact).

use std::path::Path;

use fcmp::device::bram::{brams_for, brams_for_uncached};
use fcmp::memory;
use fcmp::packing::{anneal::Anneal, bnb::Bnb, ffd::Ffd, ga, run_packer, Constraints, Packer};
use fcmp::util::args::Args;
use fcmp::util::bench::{bench, write_json, BenchConfig, BenchResult, Table};
use fcmp::util::rng::Rng;

fn ga_engine(gens: usize, islands: usize, threads: usize, full_recompute: bool) -> ga::Ga {
    let params = ga::GaParams { generations: gens, full_recompute, ..ga::GaParams::rn50() }
        .with_islands(islands);
    ga::Ga::new(params).with_threads(threads)
}

fn quality_row(
    t: &mut Table,
    workload: &str,
    engine: &str,
    items: &[memory::PackItem],
    c: &Constraints,
    e: &dyn Packer,
) {
    let (_, r) = run_packer(e, items, c);
    t.row([
        workload.to_string(),
        engine.to_string(),
        format!("{}", r.brams),
        format!("{:.1}", 100.0 * r.efficiency),
        format!("{:.1?}", r.elapsed),
    ]);
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has_flag("smoke");
    let gens = if smoke { 10 } else { 60 };

    // ---- solution quality across engines --------------------------------
    let mut t = Table::new(["workload", "engine", "BRAM18", "E %", "time"]);
    for (name, net, dev) in [
        ("CNV-W1A1/7020", fcmp::nn::cnv(fcmp::nn::CnvVariant::W1A1), fcmp::device::zynq_7020()),
        ("RN50-W1A2/U250", fcmp::nn::resnet50(1), fcmp::device::alveo_u250()),
    ] {
        if smoke && name.starts_with("RN50") {
            continue; // CI smoke: CNV + synthetic only
        }
        let bufs = memory::weight_buffers(&net, dev.slrs.len());
        let items = memory::all_columns(&bufs);
        let c = Constraints::new(4, !dev.is_monolithic());
        quality_row(&mut t, name, "ffd", &items, &c, &Ffd::new());
        quality_row(&mut t, name, "anneal", &items, &c, &Anneal::default());
        quality_row(&mut t, name, "ga[18] seq", &items, &c, &ga_engine(gens, 1, 1, false));
        quality_row(&mut t, name, "ga[18] isl=8", &items, &c, &ga_engine(gens, 8, 0, false));
    }

    // synthetic heterogeneous workload where grouping quality matters,
    // small enough for the exact BnB oracle
    let mut rng = Rng::new(11);
    let items12: Vec<memory::PackItem> = (0..12)
        .map(|i| memory::PackItem {
            id: i,
            layer: format!("s{i}"),
            width_bits: 36,
            depth: 24 + rng.below(480),
            slr: 0,
            tenant: 0,
        })
        .collect();
    let c12 = Constraints::new(4, false);
    quality_row(&mut t, "synthetic-12", "ffd", &items12, &c12, &Ffd::new());
    quality_row(&mut t, "synthetic-12", "anneal", &items12, &c12, &Anneal::default());
    let ga_seq = ga_engine(120, 1, 1, false);
    quality_row(&mut t, "synthetic-12", "ga[18] seq", &items12, &c12, &ga_seq);
    let ga_isl4 = ga_engine(120, 4, 0, false);
    quality_row(&mut t, "synthetic-12", "ga[18] isl=4", &items12, &c12, &ga_isl4);
    quality_row(&mut t, "synthetic-12", "bnb (exact)", &items12, &c12, &Bnb::default());

    println!("== Packer ablation: solution quality ==");
    println!("{}", t.render());

    // ---- island-model / incremental-fitness ablation --------------------
    // RN50-sized item set (the CI smoke uses CNV to stay fast)
    let (abl_name, net, dev) = if smoke {
        ("CNV-W1A1/7020", fcmp::nn::cnv(fcmp::nn::CnvVariant::W1A1), fcmp::device::zynq_7020())
    } else {
        ("RN50-W1A2/U250", fcmp::nn::resnet50(1), fcmp::device::alveo_u250())
    };
    let bufs = memory::weight_buffers(&net, dev.slrs.len());
    let items = memory::all_columns(&bufs);
    let c = Constraints::new(4, !dev.is_monolithic());
    let abl_gens = if smoke { 6 } else { 24 };
    let cfg = BenchConfig {
        warmup_iters: if smoke { 0 } else { 1 },
        samples: if smoke { 2 } else { 3 },
        iters_per_sample: 1,
    };

    let arms: Vec<(&str, ga::Ga)> = vec![
        ("ga-seed-full-seq", ga_engine(abl_gens, 1, 1, true)),
        ("ga-incremental-seq", ga_engine(abl_gens, 1, 1, false)),
        ("ga-isl8-thr1", ga_engine(abl_gens, 8, 1, false)),
        ("ga-isl8-thr8", ga_engine(abl_gens, 8, 8, false)),
    ];
    println!("== Island-model ablation on {abl_name} ({} items) ==", items.len());
    let mut results: Vec<BenchResult> = Vec::new();
    let mut packings: Vec<fcmp::packing::Packing> = Vec::new();
    for (name, e) in &arms {
        // keep the last timed packing: its cost feeds the quality columns
        // and the determinism check without re-running the engine
        let mut last = fcmp::packing::Packing::default();
        let r = bench(&format!("{abl_name}/{name}"), cfg, || {
            last = e.pack(&items, &c);
        });
        fcmp::util::bench::report(&r);
        results.push(r);
        packings.push(last);
    }
    let seed_ms = results[0].mean_ms();
    let isl8_ms = results[results.len() - 1].mean_ms();
    let seed_cost = packings[0].total_brams(&items);
    let isl8_cost = packings[packings.len() - 1].total_brams(&items);
    println!(
        "island GA (8 islands, 8 threads) vs seed sequential GA: {:.2}x wall-clock, \
         BRAM18 {} vs {} ({})",
        seed_ms / isl8_ms,
        isl8_cost,
        seed_cost,
        if isl8_cost <= seed_cost { "equal-or-better" } else { "WORSE" }
    );

    // determinism contract: identical (seed, islands) => identical packing
    // across thread counts — the isl8-thr1 and isl8-thr8 arms already ran
    // the same params, so their packings must be byte-identical
    assert_eq!(
        packings[2], packings[3],
        "island GA output depends on thread count"
    );
    println!("determinism: OK (isl=8 identical at 1 and 8 threads)");

    // ---- brams_for memoization microbench -------------------------------
    let shapes: Vec<(u64, u64)> =
        items.iter().map(|i| (i.width_bits, i.depth)).take(512).collect();
    let micro_cfg = BenchConfig { warmup_iters: 1, samples: 5, iters_per_sample: 50 };
    let memo = bench("brams_for/memoized", micro_cfg, || {
        let mut acc = 0u64;
        for &(w, d) in &shapes {
            acc = acc.wrapping_add(brams_for(w, d));
        }
        std::hint::black_box(acc);
    });
    let raw = bench("brams_for/uncached", micro_cfg, || {
        let mut acc = 0u64;
        for &(w, d) in &shapes {
            acc = acc.wrapping_add(brams_for_uncached(w, d));
        }
        std::hint::black_box(acc);
    });
    fcmp::util::bench::report(&memo);
    fcmp::util::bench::report(&raw);
    results.push(memo);
    results.push(raw);

    if args.has_flag("json") {
        let path = Path::new("BENCH_packing.json");
        write_json(path, &results).expect("writing BENCH_packing.json");
        println!("wrote {} ({} rows)", path.display(), results.len());
    }
}
