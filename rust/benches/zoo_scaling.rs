//! Bench: the multi-tenant model zoo's two headline claims.
//!
//! **Device cost**: the witness catalog (CNV-W2A2 + SFC) on a Zynq 7020
//! — co-packed it fits one board, unpacked it overflows, and a
//! dedicated per-tenant fleet needs a board per tenant. Arms:
//!
//! * `copack`    — one FCMP run over the union item set (FFD-seeded GA);
//! * `direct`    — the same catalog without packing;
//! * `dedicated` — each tenant packs alone on its own board(s).
//!
//! **Shed goodput**: tenant 0 rides a flash crowd 8x over its group's
//! capacity while tenant 1 stays healthy, replayed on the DES's virtual
//! clock (deterministic, so the arms differ only in admission policy):
//!
//! * `flash-deadline` — admission sheds by deadline feasibility against
//!   each tenant's SLO budget;
//! * `flash-fifo`     — the keep-everything baseline (zero service
//!   estimate: nothing is ever projected to miss, so nothing sheds).
//!
//! The deadline arm must show strictly higher goodput (completions
//! inside the tenant's SLO) — warned loudly if it does not, same
//! philosophy as health_sweep. `--smoke` shrinks the traces and the GA
//! budget; `--json` writes `BENCH_tenancy.json` (row identity carries
//! the `tenants` cardinality for ci/compare_bench.py).

use std::path::Path;
use std::time::Duration;

use fcmp::coordinator::{flash_crowd, poisson, BatcherConfig, ChainGroup, Deployment, Policy, Trace};
use fcmp::device::zynq_7020;
use fcmp::nn::{cnv, sfc_w1a1, CnvVariant, Network};
use fcmp::sim::{FleetSim, SimBackend, SimConfig};
use fcmp::tenancy::{co_pack, dedicated_devices};
use fcmp::util::args::Args;
use fcmp::util::bench::Table;
use fcmp::util::ceil_div;

struct Cell {
    arm: &'static str,
    device: &'static str,
    trace: &'static str,
    tenants: usize,
    devices: usize,
    brams: u64,
    fits: bool,
    requests: usize,
    completed: usize,
    shed: usize,
    deadline_shed: usize,
    goodput: usize,
    wall_s: f64,
}

impl Cell {
    fn packing(arm: &'static str, devices: usize, brams: u64, fits: bool) -> Cell {
        Cell {
            arm,
            device: "7020",
            trace: "none",
            tenants: 2,
            devices,
            brams,
            fits,
            requests: 0,
            completed: 0,
            shed: 0,
            deadline_shed: 0,
            goodput: 0,
            wall_s: 0.0,
        }
    }
}

/// The three device-cost arms over the witness catalog.
fn packing_cells(generations: usize) -> Vec<Cell> {
    let cnv22 = cnv(CnvVariant::W2A2);
    let sfc = sfc_w1a1();
    let nets: Vec<&Network> = vec![&cnv22, &sfc];
    let dev = zynq_7020();
    let cap = dev.bram18.max(1);

    let cp = co_pack(&nets, &dev, 4, generations, 7);
    let dedicated = dedicated_devices(&nets, &dev, 4, generations, 7);
    let dedicated_brams: u64 =
        nets.iter().map(|n| co_pack(&[n], &dev, 4, generations, 7).total_brams()).sum();

    if !cp.fits() || dedicated < 2 {
        eprintln!(
            "WARNING witness catalog should co-pack onto one {} ({} of {} BRAM18) \
             while the dedicated fleet needs {} board(s)",
            cp.device,
            cp.total_brams(),
            cp.device_brams,
            dedicated
        );
    }
    if cp.fits_direct() {
        eprintln!(
            "WARNING unpacked catalog should overflow the {} ({} of {} BRAM18) — \
             consolidation is supposed to be packing-enabled",
            cp.device,
            cp.total_direct_brams(),
            cp.device_brams
        );
    }

    let copack_devices = ceil_div(cp.total_brams(), cap) as usize;
    let direct_devices = ceil_div(cp.total_direct_brams(), cap) as usize;
    vec![
        Cell::packing("copack", copack_devices, cp.total_brams(), cp.fits()),
        Cell::packing("direct", direct_devices, cp.total_direct_brams(), cp.fits_direct()),
        Cell::packing("dedicated", dedicated, dedicated_brams, true),
    ]
}

/// One flash-crowd serving arm on the DES: tenant 0 bursts 8x over a
/// ~500 req/s group, tenant 1 offers steady in-budget traffic.
fn flash_arm(arm: &'static str, n: usize, est_zero: bool) -> Cell {
    let t0 = flash_crowd(n, 300.0, 8.0, 0.2, n as f64 / 2400.0, 41);
    let t1 = poisson(n / 2, 300.0, 42);
    let (trace, tags) = Trace::merge(&[(0, &t0), (1, &t1)]);
    let per_item = Duration::from_millis(2);
    let budgets = vec![Some(Duration::from_millis(40)), Some(Duration::from_millis(100))];
    let groups = vec![ChainGroup::new(1).for_tenant(0), ChainGroup::new(1).for_tenant(1)];
    let plan = Deployment { groups, ..Deployment::default() }
        .with_policy(Policy::RoundRobin)
        .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::ZERO })
        .with_queue_depth(32)
        .with_window(2);
    let est = if est_zero { vec![Duration::ZERO; 2] } else { vec![per_item; 2] };

    let cfg = SimConfig { input_len: 8, seed: 9, ..SimConfig::default() };
    let backend = SimBackend::Mock { base: Duration::ZERO, per_item };
    let start = std::time::Instant::now();
    let mut sim = FleetSim::uniform(plan, backend, cfg);
    sim.set_tenancy(budgets, est);
    let rep = sim.run_tagged(&trace, &tags);
    let wall = start.elapsed().as_secs_f64();
    let goodput: usize = rep.summary.per_tenant.iter().map(|t| t.goodput).sum();
    Cell {
        arm,
        device: "mock",
        trace: "flash",
        tenants: 2,
        devices: 1,
        brams: 0,
        fits: true,
        requests: trace.len(),
        completed: rep.completed,
        shed: rep.shed,
        deadline_shed: rep.deadline_shed,
        goodput,
        wall_s: wall,
    }
}

fn cells_json(cells: &[Cell]) -> String {
    let mut out = String::from("[");
    for (k, c) in cells.iter().enumerate() {
        if k > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"arm\":{:?},\"device\":{:?},\"trace\":{:?},\"tenants\":{},\"devices\":{},\
             \"brams\":{},\"fits\":{},\"requests\":{},\"completed\":{},\"shed\":{},\
             \"deadline_shed\":{},\"goodput\":{},\"wall_s\":{:.3}}}",
            c.arm,
            c.device,
            c.trace,
            c.tenants,
            c.devices,
            c.brams,
            c.fits,
            c.requests,
            c.completed,
            c.shed,
            c.deadline_shed,
            c.goodput,
            c.wall_s
        ));
    }
    out.push(']');
    out
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has_flag("smoke");
    let (generations, n) = if smoke { (8, 600) } else { (40, 3000) };

    let mut cells = packing_cells(generations);

    let fifo = flash_arm("flash-fifo", n, true);
    let dl = flash_arm("flash-deadline", n, false);
    if dl.goodput <= fifo.goodput {
        eprintln!(
            "WARNING deadline-aware shedding should strictly beat FIFO goodput \
             under the flash crowd (deadline {} vs fifo {})",
            dl.goodput, fifo.goodput
        );
    }
    cells.push(fifo);
    cells.push(dl);

    let mut t = Table::new([
        "arm", "tenants", "devices", "brams", "fits", "req", "completed", "shed", "dl-shed",
        "goodput", "wall s",
    ]);
    for c in &cells {
        t.row([
            c.arm.to_string(),
            format!("{}", c.tenants),
            format!("{}", c.devices),
            format!("{}", c.brams),
            format!("{}", c.fits),
            format!("{}", c.requests),
            format!("{}", c.completed),
            format!("{}", c.shed),
            format!("{}", c.deadline_shed),
            format!("{}", c.goodput),
            format!("{:.3}", c.wall_s),
        ]);
    }
    println!("== Multi-tenant model zoo (co-packed consolidation + deadline goodput) ==");
    println!("{}", t.render());
    println!(
        "headline: catalog needs {} board(s) co-packed vs {} dedicated; \
         deadline goodput {} vs FIFO {} ({} deadline sheds)",
        cells[0].devices,
        cells[2].devices,
        cells[4].goodput,
        cells[3].goodput,
        cells[4].deadline_shed
    );

    if args.has_flag("json") {
        let path = Path::new("BENCH_tenancy.json");
        std::fs::write(path, cells_json(&cells)).expect("writing BENCH_tenancy.json");
        println!("wrote {} ({} cells)", path.display(), cells.len());
    }
}
