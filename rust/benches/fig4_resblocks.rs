//! Bench: regenerate paper Fig. 4 (per-resblock LUT/BRAM) and the Fig. 5
//! SLR floorplan column: memory grows towards the output of the network.
use fcmp::util::bench::{bench, report, BenchConfig};

fn main() {
    println!("== Fig 4 + Fig 5: RN50 per-resblock resources and floorplan ==");
    let t = fcmp::report::fig4();
    println!("{}", t.render());
    println!("\ncsv:\n{}", t.to_csv());
    let r = bench("fig4_model_eval", BenchConfig::default(), || {
        std::hint::black_box(fcmp::report::fig4());
    });
    report(&r);
}
