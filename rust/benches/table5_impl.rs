//! Bench: regenerate paper Table V — packed vs folded implementations
//! (LUT/BRAM %, achieved clocks, delta FPS) via the calibrated
//! timing-closure model.
use fcmp::util::bench::{bench, report, BenchConfig};

fn main() {
    let gens = std::env::var("FCMP_GA_GENERATIONS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(80);
    println!("== Table V: packed vs folded accelerators (GA generations={gens}) ==");
    println!("{}", fcmp::report::table5(gens).render());
    println!("\nheadline: FCMP on U280 is ~1.4x faster than 2x folding (paper: 1.38x)");
    let r = bench(
        "table5_eval",
        BenchConfig { warmup_iters: 0, samples: 3, iters_per_sample: 1 },
        || {
            std::hint::black_box(fcmp::report::table5(20));
        },
    );
    report(&r);
}
