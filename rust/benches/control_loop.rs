//! Bench: the adaptive control plane under a flash crowd — five arms over
//! the same trace and the same per-device capacity:
//!
//! * `static-1`   — fixed fleet at the initial size (the no-control-plane
//!   baseline; sheds through the whole burst);
//! * `static-max` — fixed fleet at the autoscaler's maximum (the
//!   always-overprovisioned reference);
//! * `autoscaled` — starts at 1 group, hysteresis autoscaler reshapes;
//! * `failure`    — starts at 2, one group dies mid-burst, the
//!   autoscaler re-absorbs the load from standby;
//! * `chained-auto` — the replicated-chain shape: 2-stage chain groups,
//!   the autoscaler adds/retires whole chains (2 devices at a time).
//!
//! The headline signal: the autoscaled arm must beat `static-1` on shed
//! rate at comparable peak p99 (both arms bound p99 by the same queue
//! depth × service time), while finishing the run scaled back down.
//!
//! Flags: `--smoke` shrinks the trace for CI; `--json` writes the cells
//! to `BENCH_control.json` (the control-plane perf-trajectory artifact).

use std::path::Path;
use std::time::Duration;

use fcmp::control::{
    run_loop, AutoscalerConfig, ControlledFleet, FailureEvent, LoopConfig, SignalConfig,
};
use fcmp::coordinator::{flash_crowd, BatcherConfig, ReplicaSpec, Trace};
use fcmp::device::zynq_7020;
use fcmp::nn::{cnv, CnvVariant};
use fcmp::util::args::Args;
use fcmp::util::bench::Table;

/// Per-item mock service time (µs): one 1-stage group sustains ~555
/// req/s, so the 250 req/s baseline fits one group and the 6x burst
/// needs ~3 (a 2-stage chain group sustains ~1111 req/s, so the chained
/// arm needs 2).
const PER_ITEM_US: f64 = 1800.0;

struct Cell {
    arm: &'static str,
    trace: &'static str,
    stages: usize,
    groups_init: usize,
    groups_peak: usize,
    groups_final: usize,
    scale_outs: usize,
    scale_ins: usize,
    failures: usize,
    offered_rps: f64,
    submitted: usize,
    completed: usize,
    shed: usize,
    shed_rate: f64,
    throughput_fps: f64,
    p50_ms: f64,
    p99_ms: f64,
}

fn specs(k: usize) -> Vec<ReplicaSpec> {
    (0..k).map(|_| ReplicaSpec::paper_point(zynq_7020())).collect()
}

fn scaler(max: usize) -> AutoscalerConfig {
    AutoscalerConfig {
        min_groups: 1,
        max_groups: max,
        shed_out: 0.02,
        p99_out_ms: f64::INFINITY,
        util_in: 0.2,
        cooldown_ticks: 2,
        step: 1,
    }
}

#[allow(clippy::too_many_arguments)]
fn run_arm(
    arm: &'static str,
    trace: &Trace,
    stages: usize,
    active_groups: usize,
    standby_devices: usize,
    autoscale: Option<AutoscalerConfig>,
    failures: Vec<FailureEvent>,
) -> Cell {
    let net = cnv(CnvVariant::W1A1);
    let batcher = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) };
    let groups: Vec<Vec<ReplicaSpec>> = (0..active_groups).map(|_| specs(stages)).collect();
    let mut fleet = ControlledFleet::start_chained(
        net,
        groups,
        specs(standby_devices),
        PER_ITEM_US,
        batcher,
        32,
    );
    let cfg = LoopConfig {
        tick: Duration::from_millis(20),
        signal: SignalConfig { window_ticks: 2 },
        autoscaler: autoscale,
        slo: None,
        failures,
        trailing_ticks: 8,
        input_len: 4,
        seed: 42,
    };
    let rep = run_loop(&mut fleet, trace, &cfg);
    fleet.shutdown();
    let (throughput_fps, p50_ms, p99_ms) = match &rep.summary.fleet {
        Some(f) => (f.throughput_fps, f.latency_ms.median, f.latency_ms.p99),
        None => (0.0, 0.0, 0.0),
    };
    Cell {
        arm,
        trace: "flash",
        stages,
        groups_init: rep.initial_groups,
        groups_peak: rep.max_groups_seen,
        groups_final: rep.final_groups,
        scale_outs: rep.scale_outs(),
        scale_ins: rep.scale_ins(),
        failures: rep.failures(),
        offered_rps: trace.offered_rate(),
        submitted: rep.submitted,
        completed: rep.completed,
        shed: rep.shed,
        shed_rate: rep.shed_rate(),
        throughput_fps,
        p50_ms,
        p99_ms,
    }
}

fn cells_json(cells: &[Cell]) -> String {
    let mut out = String::from("[");
    for (k, c) in cells.iter().enumerate() {
        if k > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"arm\":{:?},\"trace\":{:?},\"stages\":{},\"groups_init\":{},\
             \"groups_peak\":{},\"groups_final\":{},\"scale_outs\":{},\"scale_ins\":{},\
             \"failures\":{},\"offered_rps\":{:.1},\"submitted\":{},\"completed\":{},\
             \"shed\":{},\"shed_rate\":{:.4},\"throughput_fps\":{:.1},\"p50_ms\":{:.3},\
             \"p99_ms\":{:.3}}}",
            c.arm,
            c.trace,
            c.stages,
            c.groups_init,
            c.groups_peak,
            c.groups_final,
            c.scale_outs,
            c.scale_ins,
            c.failures,
            c.offered_rps,
            c.submitted,
            c.completed,
            c.shed,
            c.shed_rate,
            c.throughput_fps,
            c.p50_ms,
            c.p99_ms
        ));
    }
    out.push(']');
    out
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has_flag("smoke");
    // base 250 req/s with a 6x step burst mid-trace, quiet tail after
    let (n, burst_start, burst_len) = if smoke { (260, 0.3, 0.4) } else { (700, 0.5, 0.8) };
    let trace = flash_crowd(n, 250.0, 6.0, burst_start, burst_len, 42);
    let kill_at = burst_start + 0.5 * burst_len;

    let cells = vec![
        run_arm("static-1", &trace, 1, 1, 0, None, vec![]),
        run_arm("static-max", &trace, 1, 4, 0, None, vec![]),
        run_arm("autoscaled", &trace, 1, 1, 3, Some(scaler(4)), vec![]),
        // scale-in disabled so the pre-burst lull cannot vacate the kill
        // target; the arm measures failure recovery, not the full cycle
        run_arm(
            "failure",
            &trace,
            1,
            2,
            2,
            Some(AutoscalerConfig { util_in: 0.0, ..scaler(4) }),
            vec![FailureEvent { at_s: kill_at, group: 1 }],
        ),
        // replicated chains: 2-stage groups, whole-chain scaling (each
        // decision moves 2 devices); a chain group is ~2x one replica's
        // capacity, so the burst needs one extra group
        run_arm("chained-auto", &trace, 2, 1, 2, Some(scaler(2)), vec![]),
    ];

    let mut t = Table::new([
        "arm", "stages", "g init", "g peak", "g final", "out", "in", "fail", "offered",
        "completed", "shed", "shed %", "fps", "p50 ms", "p99 ms",
    ]);
    for c in &cells {
        t.row([
            c.arm.to_string(),
            format!("{}", c.stages),
            format!("{}", c.groups_init),
            format!("{}", c.groups_peak),
            format!("{}", c.groups_final),
            format!("{}", c.scale_outs),
            format!("{}", c.scale_ins),
            format!("{}", c.failures),
            format!("{:.0}", c.offered_rps),
            format!("{}", c.completed),
            format!("{}", c.shed),
            format!("{:.1}", 100.0 * c.shed_rate),
            format!("{:.0}", c.throughput_fps),
            format!("{:.2}", c.p50_ms),
            format!("{:.2}", c.p99_ms),
        ]);
    }
    println!("== Control loop (flash crowd, mock chain-group fleet, {n} requests) ==");
    println!("{}", t.render());

    // headline: autoscaling must beat the static baseline on shed rate —
    // soft check (sleep-based mocks on shared CI runners), loud warning
    let find = |arm: &str| cells.iter().find(|c| c.arm == arm).expect("arm");
    let (s1, auto) = (find("static-1"), find("autoscaled"));
    println!(
        "flash: static-1 shed {:.1}% vs autoscaled {:.1}% (peak p99 {:.1} vs {:.1} ms, \
         peak fleet {} -> final {})",
        100.0 * s1.shed_rate,
        100.0 * auto.shed_rate,
        s1.p99_ms,
        auto.p99_ms,
        auto.groups_peak,
        auto.groups_final
    );
    if auto.shed >= s1.shed {
        eprintln!(
            "WARNING autoscaled arm shed {} >= static arm's {} — the control loop \
             is not absorbing the burst (noisy runner, or a real control regression)",
            auto.shed, s1.shed
        );
    }
    if auto.scale_outs == 0 || auto.scale_ins == 0 {
        eprintln!(
            "WARNING autoscaled arm saw {} scale-outs / {} scale-ins — expected a \
             full out-then-in cycle over the flash crowd",
            auto.scale_outs, auto.scale_ins
        );
    }
    let fail = find("failure");
    if fail.failures != 1 {
        eprintln!("WARNING failure arm fired {} failures, expected 1", fail.failures);
    }
    let chained = find("chained-auto");
    println!(
        "chained-auto: {} -> peak {} chain groups of {} stages, shed {:.1}%",
        chained.groups_init,
        chained.groups_peak,
        chained.stages,
        100.0 * chained.shed_rate
    );
    if chained.scale_outs == 0 {
        eprintln!(
            "WARNING chained-auto arm never added a chain group under the 6x burst"
        );
    }

    if args.has_flag("json") {
        let path = Path::new("BENCH_control.json");
        std::fs::write(path, cells_json(&cells)).expect("writing BENCH_control.json");
        println!("wrote {} ({} cells)", path.display(), cells.len());
    }
}
