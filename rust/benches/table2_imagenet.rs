//! Bench: regenerate paper Table II — ImageNet dataflow accelerator
//! comparison; our RN50-W1A2 row is produced by the analytic pipeline
//! model at 195 MHz (published rows included for shape comparison).
use fcmp::util::bench::{bench, report, BenchConfig};

fn main() {
    println!("== Table II: ImageNet dataflow accelerators ==");
    println!("{}", fcmp::report::table2().render());
    let e = fcmp::sim::estimate(&fcmp::nn::resnet50(1), 195.0);
    println!(
        "\nheadline: {:.0} FPS (paper 2703), {:.2} ms latency (paper 1.9), {:.1} TOp/s (paper 18.3)",
        e.fps, e.latency_ms, e.tops
    );
    let r = bench("table2_model_eval", BenchConfig::default(), || {
        std::hint::black_box(fcmp::report::table2());
    });
    report(&r);
}
