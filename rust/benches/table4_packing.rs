//! Bench: regenerate paper Table IV — packed memory subsystems for every
//! accelerator/bin-height combination the paper evaluates, using the GA of
//! [18] with the Table III hyper-parameters.
use fcmp::util::bench::{bench, report, BenchConfig};

fn main() {
    let gens = std::env::var("FCMP_GA_GENERATIONS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(80);
    println!("== Table III: GA hyper-parameters in use ==");
    println!("CNV : {:?}", fcmp::packing::ga::GaParams::cnv());
    println!("RN50: {:?}\n", fcmp::packing::ga::GaParams::rn50());
    println!("== Table IV: packed memory subsystems (GA generations={gens}) ==");
    println!("{}", fcmp::report::table4(gens).render());

    // time one representative packing run (CNV-W1A1 P4)
    let net = fcmp::nn::cnv(fcmp::nn::CnvVariant::W1A1);
    let dev = fcmp::device::zynq_7020();
    let r = bench(
        "pack_cnv_w1a1_p4_ga",
        BenchConfig { warmup_iters: 1, samples: 5, iters_per_sample: 1 },
        || {
            let mut ga = fcmp::report::default_ga(&net);
            ga.params.generations = 40;
            std::hint::black_box(fcmp::report::pack_network(&net, &dev, &ga, 4));
        },
    );
    report(&r);
}
