//! The paper's headline story: port an accelerator to a *smaller* device.
//!
//! * CNV-W1A1: Zynq 7020 → 7012S (§V: "we were able to successfully port
//!   the CNV-W1A1-P4 accelerator to a smaller Zynq device, the 7012S,
//!   without any loss of throughput").
//! * RN50-W1A2: Alveo U250 → U280 — FCMP (P4) vs the folding alternative
//!   (F2); the paper finds FCMP is 38% faster than folding.
//!
//! Run: `cargo run --release --example port_device`

use fcmp::device::{alveo_u250, alveo_u280, zynq_7012s, zynq_7020};
use fcmp::folding::network_resources;
use fcmp::memory;
use fcmp::nn::{cnv, resnet50, CnvVariant};
use fcmp::report::{default_ga, pack_network};
use fcmp::timing;

fn port_cnv() {
    println!("--- CNV-W1A1: Zynq 7020 -> 7012S ---");
    let net = cnv(CnvVariant::W1A1);
    let (big, small) = (zynq_7020(), zynq_7012s());
    let r = network_resources(&net, &big);

    // unpacked on the small device: does not fit
    let unpacked_total = r.total_brams();
    println!(
        "unpacked needs {} BRAM18: 7020 has {} (fits), 7012S has {} ({})",
        unpacked_total,
        big.bram18,
        small.bram18,
        if unpacked_total <= small.bram18 { "fits" } else { "DOES NOT FIT" }
    );

    // FCMP-packed at H_B=4
    let out = pack_network(&net, &big, &default_ga(&net), 4);
    let packed_total = out.report.brams + memory::activation_brams(&net) / 2;
    println!(
        "packed (P4) needs {} weight BRAM18 (+{} act/FIFO) -> 7012S {}",
        out.report.brams,
        memory::activation_brams(&net) / 2,
        if packed_total <= small.bram18 { "FITS" } else { "does not fit" }
    );

    // throughput on the small device
    let lut_util = r.luts / small.luts as f64;
    let t = timing::evaluate(&small, lut_util, 100.0, 2.0, 100.0);
    println!(
        "7012S implementation: LUT {:.0}%, Fc {:.0} MHz, Fm {:.0} MHz, dFPS {:.0}% (paper: 0%)",
        100.0 * lut_util,
        t.fc_mhz,
        t.fm_mhz,
        t.delta_fps_pct
    );
    assert!(t.delta_fps_pct < 2.0, "port must preserve throughput");
    assert!(unpacked_total > small.bram18, "unpacked should NOT fit 7012S");
    assert!(packed_total <= small.bram18, "packed should fit 7012S");
}

fn port_rn50() {
    println!("\n--- RN50-W1A2: Alveo U250 -> U280, FCMP vs folding ---");
    let net = resnet50(1);
    let (u250, u280) = (alveo_u250(), alveo_u280());
    let r = network_resources(&net, &u250);

    // NOTE: the paper counts 3870 BRAM18 for the whole unpacked design
    // (weights + all stream FIFOs), which exceeds the U280; our FIFO model
    // is thinner (see EXPERIMENTS.md deltas), so the porting pressure here
    // shows up as the throughput comparison below rather than a hard
    // capacity wall.
    println!(
        "unpacked weights {} BRAM18 (paper: 3870 total incl. FIFOs) vs U280 {}",
        r.weight_brams, u280.bram18,
    );

    // option A: FCMP P4 on U280
    let out = pack_network(&net, &u280, &default_ga(&net), 4);
    let lut_util_p4 =
        (r.luts + out.logic_kluts * 1e3 + u280.shell_luts as f64) / u280.luts as f64;
    let tp4 = timing::evaluate(&u280, lut_util_p4, 200.0, 2.0, 200.0);
    let fps_p4 = tp4.effective_fc_mhz; // per-cycle work unchanged

    // option B: fold by 2 on U280
    let f2 = net.fold2();
    let rf2 = network_resources(&f2, &u280);
    let lut_util_f2 = (rf2.luts + u280.shell_luts as f64) / u280.luts as f64;
    let tf2 = timing::evaluate(&u280, lut_util_f2, 200.0, 1.0, 200.0);
    let fps_f2 = tf2.effective_fc_mhz / 2.0; // half the per-cycle work

    println!(
        "U280 via FCMP P4 : {} BRAM18 (E {:.1}%), LUT {:.0}%, Fc {:.0} => relative FPS {:.1}",
        out.report.brams,
        100.0 * out.report.efficiency,
        100.0 * lut_util_p4,
        tp4.fc_mhz,
        fps_p4,
    );
    println!(
        "U280 via folding : {} BRAM18, LUT {:.0}%, Fc {:.0} => relative FPS {:.1}",
        rf2.weight_brams,
        100.0 * lut_util_f2,
        tf2.fc_mhz,
        fps_f2,
    );
    println!("FCMP / folding speedup: {:.2}x (paper: ~1.38x)", fps_p4 / fps_f2);
    assert!(out.report.brams <= u280.bram18, "P4 weights must fit U280");
    assert!(fps_p4 / fps_f2 > 1.2, "FCMP must beat folding on U280");
}

fn main() {
    port_cnv();
    port_rn50();
    println!("\nport_device OK");
}
