//! Pack the full-size quantized ResNet-50 — the paper's largest experiment
//! (§V, Table IV rows RN50-*): per-SLR inter-layer packing on Alveo,
//! engine comparison, and the resulting required memory frequency.
//!
//! Run: `cargo run --release --example pack_resnet50 -- [generations]`

use fcmp::device::{alveo_u250, alveo_u280};
use fcmp::memory;
use fcmp::nn::resnet50;
use fcmp::packing::{anneal::Anneal, ffd::Ffd, ga, run_packer, Constraints, Packer};
use fcmp::report::pack_network;

fn main() {
    let generations: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);

    let net = resnet50(1);
    let u250 = alveo_u250();
    println!(
        "{}: {} packable conv layers, {:.1}M resblock weights",
        net.name,
        net.packable_layers().len(),
        net.packable_layers().iter().map(|l| l.params()).sum::<u64>() as f64 / 1e6
    );

    // buffers + column slices with the Fig. 5 SLR floorplan
    let bufs = memory::weight_buffers(&net, u250.slrs.len());
    let items = memory::all_columns(&bufs);
    let baseline = memory::direct_brams(&bufs);
    println!(
        "baseline: {} buffers -> {} column slices -> {} BRAM18 (E={:.1}%)",
        bufs.len(),
        items.len(),
        baseline,
        100.0 * memory::efficiency(memory::total_bits(&bufs), baseline)
    );

    // engine comparison at H_B = 4 (the paper's preferred setting)
    let c = Constraints::new(4, true);
    let engines: Vec<(&str, Box<dyn Packer>)> = vec![
        ("ffd", Box::new(Ffd::new())),
        ("anneal", Box::new(Anneal::default())),
        (
            "ga[18]",
            Box::new(ga::Ga::new(ga::GaParams { generations, ..ga::GaParams::rn50() })),
        ),
    ];
    for (name, engine) in &engines {
        let (_, r) = run_packer(engine.as_ref(), &items, &c);
        println!(
            "  {name:>7}: {} BRAM18  E={:.1}%  (max height {}, {:.2?})",
            r.brams,
            100.0 * r.efficiency,
            r.max_height,
            r.elapsed
        );
    }

    // P3 vs P4 trade-off (Table IV + the R_F requirement of Eq. 2)
    for hb in [3usize, 4] {
        let ga_engine =
            ga::Ga::new(ga::GaParams { generations, ..ga::GaParams::rn50() });
        let out = pack_network(&net, &u250, &ga_engine, hb);
        println!(
            "U250 P{hb}: {} BRAM18, E={:.1}%, logic {:.1} kLUT, needs R_F >= {:.1} (F_mem >= {:.0} MHz)",
            out.report.brams,
            100.0 * out.report.efficiency,
            out.logic_kluts,
            hb as f64 / 2.0,
            u250.nominal_compute_mhz * hb as f64 / 2.0,
        );
    }

    // the U280 port: does P4 fit the smaller card?
    let u280 = alveo_u280();
    let ga_engine = ga::Ga::new(ga::GaParams { generations, ..ga::GaParams::rn50() });
    let out = pack_network(&net, &u280, &ga_engine, 4);
    println!(
        "U280 P4: {} BRAM18 of {} available -> {}",
        out.report.brams,
        u280.bram18,
        if out.report.brams <= u280.bram18 { "FITS (the paper's port)" } else { "does not fit" }
    );
    println!("pack_resnet50 OK");
}
