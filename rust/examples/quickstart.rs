//! Quickstart: the FCMP flow in ~40 lines.
//!
//! Builds the CNV-W1A1 accelerator model, measures its OCM mapping
//! efficiency, packs the weight buffers with the genetic algorithm of [18]
//! at bin height 4 (requires R_F = 2, Eq. 2), and checks the throughput
//! implications with the GALS streamer simulator and the timing model.
//!
//! Run: `cargo run --release --example quickstart`

use fcmp::device::zynq_7020;
use fcmp::gals::{Ratio, StreamerConfig, StreamerSim};
use fcmp::nn::{cnv, CnvVariant};
use fcmp::report::{default_ga, pack_network};
use fcmp::timing::evaluate;

fn main() {
    // 1. the accelerator: BNN-Pynq CNV, binary weights, CIFAR-10
    let net = cnv(CnvVariant::W1A1);
    let dev = zynq_7020();
    println!("network {}: {} weight params", net.name, net.total_params());

    // 2. FCMP packing: up to 4 logical buffers per physical BRAM
    let ga = default_ga(&net);
    let out = pack_network(&net, &dev, &ga, 4);
    println!(
        "baseline {} BRAM18 at E={:.1}% -> packed {} BRAM18 at E={:.1}% ({:.0}% fewer)",
        out.baseline_brams,
        100.0 * out.baseline_eff,
        out.report.brams,
        100.0 * out.report.efficiency,
        100.0 * (1.0 - out.report.brams as f64 / out.baseline_brams as f64),
    );

    // 3. Eq. 2: H_B = 4 needs R_F = 2 — verify with the cycle simulator
    let sim = StreamerSim::new(StreamerConfig::fig7a(4, 256, Ratio::two())).run(5_000);
    println!(
        "GALS streamer: 4 buffers/BRAM at R_F=2 sustain min rate {:.3} words/cycle",
        sim.min_rate()
    );

    // 4. can the memory domain close timing at 2x the compute clock?
    let t = evaluate(&dev, 0.58, dev.nominal_compute_mhz, 2.0, dev.nominal_compute_mhz);
    println!(
        "timing on {}: Fc {:.0} MHz, Fm {:.0} MHz => dFPS {:.1}% (BRAM Fmax cap {:.0} MHz)",
        dev.name, t.fc_mhz, t.fm_mhz, t.delta_fps_pct, dev.bram_fmax_mhz,
    );
    assert!(sim.min_rate() >= 0.98, "packing must not cost throughput");
    println!("quickstart OK");
}
