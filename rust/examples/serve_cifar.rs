//! End-to-end driver (DESIGN.md E2E): serve batched CIFAR-10 inference
//! requests through the full three-layer stack —
//!
//!   rust serving fleet (router → policy → per-replica batcher → worker)
//!     → PJRT runtime executing the AOT HLO artifact
//!       → which embeds the Pallas MVAU kernels of the quantized CNV
//!
//! and report fleet + per-replica throughput and latency percentiles.
//! Requires `make artifacts`. The run is recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `cargo run --release --example serve_cifar -- [requests] [rate]
//! [replicas]` (from `rust/`; the artifacts/ directory must exist).

use fcmp::coordinator::{poisson, BatcherConfig, Deployment, Policy, Server};
use fcmp::runtime::Engine;
use std::path::Path;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(96);
    let rate: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(40.0);
    let replicas: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(2);
    let arts = Path::new("artifacts");

    // verify numerics against the python golden output before serving
    let probe = Engine::load(arts, "cnv_w1a1")?;
    probe.check_golden()?;
    println!(
        "engine: cnv_w1a1 on {} — golden check OK, batch variants {:?}",
        probe.platform(),
        probe.batch_sizes()
    );
    let per = probe.manifest.input_elements_per_sample() as usize;
    drop(probe);

    // the replicas all load the same artifact, so join-shortest-queue keeps
    // the homogeneous fleet balanced without capacity estimates; the flat
    // fleet is the N x 1 case of the Deployment topology
    let plan = Deployment::replicated(replicas)
        .with_policy(Policy::JoinShortestQueue)
        .with_batcher(BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(3) })
        .with_queue_depth(256);
    let mut srv = Server::deploy(
        |_id| Engine::load(Path::new("artifacts"), "cnv_w1a1").expect("engine"),
        plan,
    );

    // open-loop Poisson arrivals at `rate` req/s (synthetic CIFAR-10 images)
    let trace = poisson(n, rate, 2020);
    let fm = srv.replay(&trace, per, 2020);
    srv.shutdown();

    let s = fm.summary();
    println!("E2E serve ({replicas} replicas):");
    println!("{s}");
    // every request is either served or counted as shed — none vanish;
    // shedding is legitimate at user-chosen rates beyond fleet capacity
    assert_eq!(fm.completed() + fm.shed(), n, "requests lost in flight");
    if fm.shed() > 0 {
        println!("note: {} requests shed — offered rate exceeds fleet capacity", fm.shed());
    }
    println!("serve_cifar OK");
    Ok(())
}
