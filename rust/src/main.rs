//! fcmp — command-line entry point.
//!
//! ```text
//! fcmp pack     --network cnv-w1a1|cnv-w2a2|rn50-w1|rn50-w2 --device 7020|7012s|u250|u280
//!               [--hb 4] [--engine ga|ffd|anneal] [--generations 120] [--seed 2020]
//!               [--islands 1] [--threads 0 (auto)] [--migrate 10]
//! fcmp report   --table 1|2|4|5|fig2|fig4|all [--generations 120]
//! fcmp perf     --network ... [--mhz 195]
//! fcmp gals     [--nb 4] [--rf 2.0] [--depth 128] [--cycles 10000] [--static]
//! fcmp golden   [--artifacts artifacts] [--model all|cnv_w1a1|cnv_w2a2|rn50_lite_w1a2]
//! fcmp serve    [--backend mock|pipelined|pjrt] [--model cnv_w1a1] [--chains 1]
//!               [--stages 1] [--policy round-robin|jsq|weighted]
//!               [--trace poisson|bursty|heavy|diurnal|uniform|file:PATH]
//!               [--trace-out PATH] [--requests 256] [--rate 400] [--batch 4]
//!               [--queue 64] [--window 2] [--xfer-frac 0.5]
//!               [--devices u250,u280,7020,7012s]
//!               [--service-us 400] [--point paper|packed]
//! fcmp shard    --network cnv-w2a2 --devices 7012s,7012s [--shards 2]
//!               [--hb 4] [--engine ga|ffd] [--generations 40]
//!               [--link-gbps 100] [--link-us 2] [--frames 400] [--fifo 8]
//!               [--serve] [--chains 1] [--requests 256]
//!               [--rate N*FPS*0.8] [--kill-stage I]
//! fcmp autoscale [--trace flash|diurnal|...|file:PATH] [--requests 600]
//!               [--rate 300] [--devices 7020,7020,7020,7020] [--chains 1]
//!               [--stages 1] [--min 1] [--max POOL/STAGES]
//!               [--shed-out 0.02] [--p99-out MS] [--util-in 0.25]
//!               [--cooldown 3] [--tick-ms 25] [--window 3] [--slo-p99 MS]
//!               [--kill T:G,...] [--static] [--events-out PATH]
//!               [--require-scale-cycle]
//! fcmp simulate [--chains 4] [--stages 1] [--requests 100000] [--rate 2000]
//!               [--trace poisson|bursty|heavy|diurnal|uniform|file:PATH]
//!               [--policy round-robin|jsq|weighted] [--batch 4] [--wait-ms 2]
//!               [--queue 64] [--window 2] [--service-us 400] [--base-us 0]
//!               [--backend mock|pipelined] [--xfer-frac 0.5] [--seed 2020]
//!               [--autoscale] [--max 4*CHAINS] [--min 1] [--shed-out 0.02]
//!               [--p99-out MS] [--util-in 0.25] [--cooldown 3] [--step 1]
//!               [--tick-ms 25] [--signal-window 3] [--slo-p99 MS]
//!               [--trailing 8] [--events-out PATH] [--require-scale-cycle]
//!               (serve + simulate also take the tracing/metrics flags:
//!               [--trace-sample P] [--trace-seed S] [--trace-ring N]
//!               [--spans-out PATH] [--p99-budget MS] [--shed-burst N]
//!               [--metrics-out PATH] [--metrics-interval S])
//! fcmp zoo      [--tenants NAME:NET:RATE:SLO_MS,...] [--device 7020]
//!               [--hb 4] [--generations 40] [--chains-per-tenant 1]
//!               [--policy jsq] [--trace poisson] [--requests 400]
//!               [--queue 16] [--batch 4] [--wait-ms 1] [--service-us 400]
//!               [--sim] [--fifo] [--require-consolidation]
//!               [--require-goodput F] (+ the serve/simulate obs flags)
//! fcmp tracereport --spans PATH (critical-path breakdown of a span file)
//! fcmp healthreport --health PATH [--events PATH] [--require-incidents]
//!               (serve + simulate write the journal via [--health-out PATH]
//!               [--health] [--shed-slo F] [--latency-slo F]
//!               [--health-sample S] [--health-window-scale X])
//! fcmp dse      --network ... --device ... [--budget 0.85]
//! ```

use fcmp::control::{
    load_events, replan, run_loop, save_events, splice_mock_chain, AutoscalerConfig, ControlEvent,
    ControlledFleet, FailureEvent, LoopConfig, SignalConfig, SloConfig,
};
use fcmp::coordinator::{
    bursty, chain_fps, diurnal, flash_crowd, group_weights, heavy_tail,
    mock_chain_service_from_fps, overlap_speedup, poisson, replica_fps, shard_service_times,
    uniform, BatcherConfig, ChainGroup, Deployment, FleetSummary, MockBackend,
    PipelinedMockBackend, Policy, ReplicaSpec, Server, Trace, WorkerId,
};
use fcmp::device;
use fcmp::gals::{Ratio, StreamerConfig, StreamerSim};
use fcmp::nn::{cnv, lfc_w1a1, resnet50, sfc_w1a1, CnvVariant, Network};
use fcmp::obs::{
    health, tracereport, AnomalyConfig, Exposition, HealthConfig, HealthJournal, ObsConfig,
};
use fcmp::packing::{anneal::Anneal, ffd::Ffd, Packer};
use fcmp::sharding::{self, LinkSpec, PartitionConfig};
use fcmp::sim::{FleetSim, SimBackend, SimConfig, SimControl};
use fcmp::tenancy;
use fcmp::util::args::Args;
use fcmp::{folding, report, runtime, sim};
use std::path::{Path, PathBuf};
use std::time::Duration;

fn network_by_name(name: &str) -> Option<Network> {
    match name {
        "cnv-w1a1" | "cnv_w1a1" => Some(cnv(CnvVariant::W1A1)),
        "cnv-w1a2" | "cnv_w1a2" => Some(cnv(CnvVariant::W1A2)),
        "cnv-w2a2" | "cnv_w2a2" => Some(cnv(CnvVariant::W2A2)),
        "rn50-w1" | "rn50" => Some(resnet50(1)),
        "rn50-w2" => Some(resnet50(2)),
        _ => None,
    }
}

/// Island-model execution knobs for the GA engine (CLI surface of the
/// parallel packer; see `packing::ga` for the determinism contract).
#[derive(Clone, Copy, Debug)]
struct GaTopology {
    islands: usize,
    threads: usize,
    migration_interval: usize,
}

fn engine_by_name(
    name: &str,
    net: &Network,
    generations: usize,
    seed: u64,
    topo: GaTopology,
) -> Box<dyn Packer> {
    match name {
        "ffd" => Box::new(Ffd::new()),
        "anneal" => Box::new(Anneal { seed, ..Anneal::default() }),
        _ => {
            let mut g = report::default_ga(net);
            g.params.generations = generations;
            g.params.seed = seed;
            g.params.islands = topo.islands.max(1);
            g.params.migration_interval = topo.migration_interval.max(1);
            g.threads = topo.threads;
            Box::new(g)
        }
    }
}

fn cmd_pack(a: &Args) -> anyhow::Result<()> {
    let net = network_by_name(a.get_or("network", "cnv-w1a1"))
        .ok_or_else(|| anyhow::anyhow!("unknown network"))?;
    let dev = device::by_name(a.get_or("device", "7020"))
        .ok_or_else(|| anyhow::anyhow!("unknown device"))?;
    let hb = a.get_usize("hb", 4);
    let topo = GaTopology {
        islands: a.get_usize("islands", 1),
        threads: a.get_usize("threads", 0),
        migration_interval: a.get_usize("migrate", 10),
    };
    let engine_name = a.get_or("engine", "ga");
    let engine = engine_by_name(
        engine_name,
        &net,
        a.get_usize("generations", 120),
        a.get_usize("seed", 2020) as u64,
        topo,
    );
    // only the GA engine has island/thread knobs
    let topo_note = if matches!(engine_name, "ffd" | "anneal") {
        String::new()
    } else {
        format!(
            ", islands={}, threads={}",
            topo.islands.max(1),
            if topo.threads == 0 { "auto".to_string() } else { topo.threads.to_string() }
        )
    };
    let out = report::pack_network(&net, &dev, engine.as_ref(), hb);
    println!(
        "{} on {} (H_B={hb}, R_F>={:.1}{topo_note}):",
        net.name,
        dev.name,
        hb as f64 / 2.0
    );
    println!(
        "  baseline : {:4} BRAM18  E={:5.1}%",
        out.baseline_brams,
        100.0 * out.baseline_eff
    );
    println!(
        "  packed   : {:4} BRAM18  E={:5.1}%  ({} bins, logic {:.1} kLUT, {:.2?})",
        out.report.brams,
        100.0 * out.report.efficiency,
        out.packing.bins.len(),
        out.logic_kluts,
        out.report.elapsed
    );
    println!(
        "  reduction: {:.1}%",
        100.0 * (1.0 - out.report.brams as f64 / out.baseline_brams as f64)
    );
    Ok(())
}

fn cmd_report(a: &Args) -> anyhow::Result<()> {
    let generations = a.get_usize("generations", 120);
    let which = a.get_or("table", "all");
    let show = |name: &str, t: fcmp::util::bench::Table| {
        println!("== {name} ==\n{}\n", t.render());
    };
    match which {
        "1" => show("Table I", report::table1()),
        "2" => show("Table II", report::table2()),
        "4" => show("Table IV", report::table4(generations)),
        "5" => show("Table V", report::table5(generations)),
        "fig2" => show("Fig 2", report::fig2()),
        "fig4" => show("Fig 4", report::fig4()),
        "shard" => show("Sharding", report::shard_table(generations)),
        _ => {
            show("Table I", report::table1());
            show("Fig 2", report::fig2());
            show("Table II", report::table2());
            show("Fig 4", report::fig4());
            show("Table IV", report::table4(generations));
            show("Table V", report::table5(generations));
            show("Sharding", report::shard_table(generations));
        }
    }
    Ok(())
}

fn cmd_perf(a: &Args) -> anyhow::Result<()> {
    let net = network_by_name(a.get_or("network", "rn50-w1"))
        .ok_or_else(|| anyhow::anyhow!("unknown network"))?;
    let mhz = a.get_f64("mhz", 195.0);
    let e = sim::estimate(&net, mhz);
    println!(
        "{} @ {mhz} MHz: {:.0} FPS, {:.2} ms latency, {:.1} TOp/s, II {} cycles (bottleneck {})",
        net.name, e.fps, e.latency_ms, e.tops, e.ii_cycles, e.bottleneck
    );
    Ok(())
}

fn cmd_gals(a: &Args) -> anyhow::Result<()> {
    let nb = a.get_usize("nb", 4);
    let rf = a.get_f64("rf", 2.0);
    let depth = a.get_usize("depth", 128) as u64;
    let cycles = a.get_usize("cycles", 10_000) as u64;
    let ratio = if (rf - 1.5).abs() < 1e-9 {
        Ratio::three_halves()
    } else {
        Ratio::new(rf.round() as u64, 1)
    };
    let mut cfg = if nb % 2 == 1 && (rf * 2.0).round() as usize == nb {
        StreamerConfig::fig7b(nb, depth)
    } else {
        StreamerConfig::fig7a(nb, depth, ratio)
    };
    if a.has_flag("static") {
        cfg.adaptive = false;
    }
    let r = StreamerSim::new(cfg).run(cycles);
    println!(
        "N_b={nb} R_F={rf} ({} compute cycles, {} memory cycles, {} wasted slots)",
        r.compute_cycles, r.memory_cycles, r.wasted_slots
    );
    for (i, s) in r.per_stream.iter().enumerate() {
        println!("  stream {i}: rate {:.3} words/cycle ({} stalls)", s.rate, s.stalls);
    }
    println!("  min rate {:.3} (>= 1.0 sustains full throughput)", r.min_rate());
    Ok(())
}

fn cmd_golden(a: &Args) -> anyhow::Result<()> {
    let arts = Path::new(a.get_or("artifacts", "artifacts"));
    let model = a.get_or("model", "all");
    runtime::check_mvau_unit(arts)?;
    println!("mvau_unit: golden OK");
    for m in ["cnv_w1a1", "cnv_w2a2", "rn50_lite_w1a2"] {
        if model != "all" && model != m {
            continue;
        }
        let eng = runtime::Engine::load(arts, m)?;
        eng.check_golden()?;
        println!("{m}: golden OK (batches {:?})", eng.batch_sizes());
    }
    Ok(())
}

/// Map a servable model name to its [`Network`] and the artifact name the
/// AOT exporter actually emits (`python/compile/aot.py`): only
/// artifact-backed models are accepted, and aliases (`rn50`, hyphen forms)
/// canonicalize so the `pjrt` backend never sees a name without artifacts.
fn serve_model(name: &str) -> Option<(Network, &'static str)> {
    match name {
        "cnv_w1a1" | "cnv-w1a1" => Some((cnv(CnvVariant::W1A1), "cnv_w1a1")),
        "cnv_w2a2" | "cnv-w2a2" => Some((cnv(CnvVariant::W2A2), "cnv_w2a2")),
        "rn50" | "rn50-w1" | "rn50_lite_w1a2" => Some((resnet50(1), "rn50_lite_w1a2")),
        _ => None,
    }
}

fn trace_by_name(name: &str, n: usize, rate: f64, seed: u64) -> anyhow::Result<Trace> {
    if let Some(path) = name.strip_prefix("file:") {
        return Trace::load(Path::new(path));
    }
    // flash[:MULT[:START_S[:LEN_S]]] — step burst at MULT x the base rate;
    // window defaults to the middle fifth of the (pre-burst) trace span
    if name == "flash" || name.starts_with("flash:") {
        let span = n as f64 / rate;
        let mut mult = 6.0;
        let mut start = 0.25 * span;
        let mut len = 0.2 * span;
        if let Some(rest) = name.strip_prefix("flash:") {
            let parts: Vec<&str> = rest.split(':').collect();
            anyhow::ensure!(
                parts.len() <= 3,
                "flash trace wants flash[:MULT[:START_S[:LEN_S]]], got {name:?}"
            );
            let want = |s: &str| -> anyhow::Result<f64> {
                s.parse().map_err(|_| anyhow::anyhow!("bad flash parameter {s:?} in {name:?}"))
            };
            if !parts.is_empty() {
                mult = want(parts[0])?;
            }
            if parts.len() > 1 {
                start = want(parts[1])?;
            }
            if parts.len() > 2 {
                len = want(parts[2])?;
            }
        }
        // range-check here so bad CLI input gets a clean error, not the
        // generator's assert
        anyhow::ensure!(rate > 0.0, "flash trace wants --rate > 0, got {rate}");
        anyhow::ensure!(mult >= 1.0, "flash burst multiplier must be >= 1, got {mult}");
        anyhow::ensure!(
            start >= 0.0 && len >= 0.0,
            "flash burst window must be non-negative, got start {start}, len {len}"
        );
        return Ok(flash_crowd(n, rate, mult, start, len, seed));
    }
    Ok(match name {
        "poisson" => poisson(n, rate, seed),
        "bursty" => bursty(n, rate, rate * 8.0, 32, seed),
        "heavy" | "heavy-tail" => heavy_tail(n, rate, 1.5, seed),
        // rate swings between rate/2 (trough) and 2*rate (peak), two
        // full day/night cycles over the trace
        "diurnal" => {
            let peak = rate * 2.0;
            let mean = (rate / 2.0 + peak) / 2.0;
            let period = n as f64 / mean / 2.0;
            diurnal(n, rate / 2.0, peak, period.max(1e-3), seed)
        }
        "uniform" => uniform(n, rate),
        other => {
            anyhow::bail!(
                "unknown trace {other} \
                 (poisson|bursty|heavy|diurnal|flash[:M[:S[:L]]]|uniform|file:PATH)"
            )
        }
    })
}

/// Span-tracing knobs shared by the serving drivers: `--trace-sample P`
/// samples that fraction of requests into pooled spans, `--spans-out
/// PATH` is the JSONL flight-recorder sink (distinct from `--trace-out`,
/// which records the *arrival* trace), `--p99-budget MS` and
/// `--shed-burst N` arm the anomaly flush triggers.
fn obs_by_args(a: &Args) -> ObsConfig {
    ObsConfig {
        sample: a.get_f64("trace-sample", 0.0).clamp(0.0, 1.0),
        seed: a.get_usize("trace-seed", 0x5eed) as u64,
        ring: a.get_usize("trace-ring", 256).max(1),
        trace_out: a.get("spans-out").map(PathBuf::from),
        anomaly: AnomalyConfig {
            p99_budget_ms: a.get_f64("p99-budget", f64::INFINITY),
            shed_burst: a.get_usize("shed-burst", usize::MAX) as u64,
            ..AnomalyConfig::default()
        },
    }
}

/// Live metrics exposition: `--metrics-out PATH` rewrites a Prometheus
/// text file (and appends JSONL snapshots next to it) every
/// `--metrics-interval` seconds of driver time.
fn exposition_by_args(a: &Args) -> Option<Exposition> {
    a.get("metrics-out")
        .map(|p| Exposition::new(p, a.get_f64("metrics-interval", 0.25).max(1e-6)))
}

/// Long-horizon fleet health: `--health-out PATH` (or bare `--health`)
/// downsamples the fleet counters into the fixed-memory time-series
/// store and evaluates multiwindow SLO burn-rate alerts on the snapshot
/// cadence. `--shed-slo F` / `--latency-slo F` set the error budgets,
/// `--p99-budget MS` (shared with the anomaly trigger) arms the latency
/// signal, `--health-sample S` sets the cadence, and
/// `--health-window-scale X` compresses the SRE alert windows for short
/// runs (CI smokes).
fn health_by_args(a: &Args) -> Option<HealthConfig> {
    let out = a.get("health-out").map(PathBuf::from);
    if out.is_none() && !a.has_flag("health") {
        return None;
    }
    Some(HealthConfig {
        sample_s: a.get_f64("health-sample", 1.0).max(1e-3),
        shed_slo: a.get_f64("shed-slo", 0.02),
        latency_slo: a.get_f64("latency-slo", 0.05),
        p99_budget_ms: a.get_f64("p99-budget", f64::INFINITY),
        window_scale: a.get_f64("health-window-scale", 1.0).max(1e-6),
        out,
        ..HealthConfig::default()
    })
}

/// Shared epilogue for serve/simulate: incident attribution of the
/// run's health journal against its control events, printed so smokes
/// can grep for the incident count.
fn print_health_summary(a: &Args, journal: Option<&HealthJournal>, events: &[ControlEvent]) {
    let Some(j) = journal else { return };
    let incidents = health::correlate(j, events);
    let st = health::stats(&incidents);
    println!(
        "health: {} cell(s), {} alert transition(s) | {} incident(s): {} mitigated, \
         {} unresponded",
        j.cells.len(),
        j.alerts.len(),
        st.incidents,
        st.mitigated,
        st.unresponded
    );
    if !incidents.is_empty() {
        println!("{}", health::table(&incidents).render());
    }
    if let Some(p) = a.get("health-out") {
        println!("health: journal to {p}");
    }
}

/// One-line tracing epilogue: pool health and flush count, printed by
/// the drivers so CI smokes can grep for the zero-miss invariant.
fn print_obs_summary(obs: &fcmp::obs::Obs) {
    if !obs.active() {
        return;
    }
    let (hits, misses) = obs.span_pool_stats();
    let sink = match obs.recorder().out_path() {
        Some(p) => format!(" -> {}", p.display()),
        None => String::new(),
    };
    println!(
        "tracing: {hits} span(s) sampled ({misses} pool miss(es)), {} recorder flush(es){sink}",
        obs.recorder().flush_count()
    );
}

/// Parse a failure-injection schedule: `T:G[,T:G...]` (at `T` seconds,
/// kill active chain group `G`).
fn parse_failures(spec: &str) -> anyhow::Result<Vec<FailureEvent>> {
    let mut out = Vec::new();
    for part in spec.split(',') {
        let (t, g) = part
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("--kill wants T:G[,T:G...], got {part:?}"))?;
        out.push(FailureEvent {
            at_s: t.parse().map_err(|_| anyhow::anyhow!("bad --kill time {t:?}"))?,
            group: g.parse().map_err(|_| anyhow::anyhow!("bad --kill group {g:?}"))?,
        });
    }
    Ok(out)
}

/// `fcmp autoscale`: the adaptive control plane end to end — replay a
/// trace through a mock fleet of chain groups while the autoscaler
/// reshapes it whole groups at a time, the SLO controller retunes
/// batching windows per group, and the failure schedule kills chain
/// groups mid-run.
fn cmd_autoscale(a: &Args) -> anyhow::Result<()> {
    let (net, model) = serve_model(a.get_or("model", "cnv_w1a1")).ok_or_else(|| {
        anyhow::anyhow!("unknown model (cnv_w1a1|cnv_w2a2|rn50_lite_w1a2 or aliases)")
    })?;
    let n = a.get_usize("requests", 600);
    let rate = a.get_f64("rate", 300.0);
    let seed = cfg_seed(a);
    let trace_name = a.get_or("trace", "flash");
    let trace = trace_by_name(trace_name, n, rate, seed)?;
    if let Some(out) = a.get("trace-out") {
        trace.save(Path::new(out))?;
        println!("recorded trace ({} arrivals) to {out}", trace.len());
    }

    // topology + device pool: the first --chains × --stages entries start
    // active (grouped consecutively into chains), the rest are the standby
    // pool whole-group scale-out draws from (capacity-ranked, --stages
    // devices at a time)
    let stages = a.get_usize("stages", 1).max(1);
    let chains = a.get_usize("chains", a.get_usize("replicas", 1)).max(1);
    let dev_names: Vec<&str> = a.get_or("devices", "7020,7020,7020,7020").split(',').collect();
    anyhow::ensure!(
        chains * stages <= dev_names.len(),
        "--chains {chains} x --stages {stages} exceeds the {}-device pool",
        dev_names.len()
    );
    let mut pool = Vec::with_capacity(dev_names.len());
    for name in &dev_names {
        let dev = device::by_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown device {name} in --devices"))?;
        pool.push(ReplicaSpec::paper_point(dev));
    }
    let standby = pool.split_off(chains * stages);
    let active: Vec<Vec<ReplicaSpec>> =
        pool.chunks(stages).map(|c| c.to_vec()).collect();

    let batcher = BatcherConfig {
        max_batch: a.get_usize("batch", 4),
        max_wait: Duration::from_secs_f64(a.get_f64("wait-ms", 1.0) * 1e-3),
    };
    let queue_depth = a.get_usize("queue", 32);
    let service_us = a.get_f64("service-us", 1800.0);
    let mut fleet = ControlledFleet::start_chained(
        net.clone(),
        active,
        standby,
        service_us,
        batcher,
        queue_depth,
    );

    let scaler = AutoscalerConfig {
        min_groups: a.get_usize("min", 1),
        max_groups: a.get_usize("max", dev_names.len() / stages),
        shed_out: a.get_f64("shed-out", 0.02),
        p99_out_ms: a.get_f64("p99-out", f64::INFINITY),
        util_in: a.get_f64("util-in", 0.25),
        cooldown_ticks: a.get_usize("cooldown", 3),
        step: a.get_usize("step", 1),
    };
    let slo = a.get("slo-p99").map(|_| SloConfig {
        p99_budget_ms: a.get_f64("slo-p99", 50.0),
        ..SloConfig::default()
    });
    let lcfg = LoopConfig {
        tick: Duration::from_millis(a.get_usize("tick-ms", 25) as u64),
        signal: SignalConfig { window_ticks: a.get_usize("window", 3) },
        autoscaler: if a.has_flag("static") { None } else { Some(scaler) },
        slo,
        failures: match a.get("kill") {
            Some(spec) => parse_failures(spec)?,
            None => Vec::new(),
        },
        trailing_ticks: a.get_usize("trailing", 8),
        input_len: 8,
        seed,
    };

    println!(
        "autoscale [{model}]: {chains} group(s) x {stages} stage(s) active of {} devices, \
         trace {trace_name} ({:.0} req/s offered), tick {:?}, window {} ticks",
        dev_names.len(),
        trace.offered_rate(),
        lcfg.tick,
        lcfg.signal.window_ticks
    );
    let rep = run_loop(&mut fleet, &trace, &lcfg);
    fleet.shutdown();

    if rep.events.is_empty() {
        println!("events: none");
    } else {
        println!("events:");
        for e in &rep.events {
            println!("  {e}");
        }
    }
    if let Some(out) = a.get("events-out") {
        save_events(&rep.events, Path::new(out))?;
        println!("journaled {} control events to {out}", rep.events.len());
    }
    println!(
        "result: submitted {} shed {} ({:.1}% of offered) completed {} | \
         chain groups {} -> {} (peak {}) over {} ticks",
        rep.submitted,
        rep.shed,
        100.0 * rep.shed_rate(),
        rep.completed,
        rep.initial_groups,
        rep.final_groups,
        rep.max_groups_seen,
        rep.ticks
    );
    println!("{}", rep.summary);

    // CI smoke contract: the run must have scaled out under load and back
    // in afterwards
    if a.has_flag("require-scale-cycle") {
        anyhow::ensure!(
            rep.scale_outs() >= 1,
            "--require-scale-cycle: no scale-out occurred"
        );
        anyhow::ensure!(
            rep.scale_ins() >= 1,
            "--require-scale-cycle: no scale-in occurred"
        );
        let first_out = rep
            .events
            .iter()
            .find_map(|e| match e.kind {
                fcmp::control::ControlEventKind::ScaleOut { .. } => Some(e.tick),
                _ => None,
            })
            .unwrap();
        let first_in = rep
            .events
            .iter()
            .find_map(|e| match e.kind {
                fcmp::control::ControlEventKind::ScaleIn { .. } => Some(e.tick),
                _ => None,
            })
            .unwrap();
        anyhow::ensure!(
            first_out < first_in,
            "--require-scale-cycle: scale-in (tick {first_in}) preceded scale-out \
             (tick {first_out})"
        );
        println!("scale cycle OK: out at tick {first_out}, in at tick {first_in}");
    }
    Ok(())
}

fn cmd_serve(a: &Args) -> anyhow::Result<()> {
    let backend = a.get_or("backend", "mock");
    // topology: --chains N groups of --stages k each (N×1 is the flat
    // replicated fleet; --replicas R is the flat-fleet alias for -chains)
    let chains = a.get_usize("chains", a.get_usize("replicas", 1)).max(1);
    let stages = a.get_usize("stages", 1).max(1);
    let n = a.get_usize("requests", 256);
    let rate = a.get_f64("rate", 400.0); // offered requests/s
    let seed = a.get_usize("seed", 2020) as u64;
    let max_batch = a.get_usize("batch", 4);
    let queue_depth = a.get_usize("queue", 64);
    let window = a.get_usize("window", 2).max(1);
    let trace_name = a.get_or("trace", "poisson");
    let (net, model) = serve_model(a.get_or("model", "cnv_w1a1")).ok_or_else(|| {
        anyhow::anyhow!("unknown model (cnv_w1a1|cnv_w2a2|rn50_lite_w1a2 or aliases)")
    })?;

    // heterogeneous fleet: worker (g, s) runs on entry g*stages+s of
    // --devices (cycled) at the paper's Table V operating point (--point
    // paper) or at the actually-packed design point (--point packed,
    // cross-replica cached); the analytic sim/timing model turns each
    // chain group's points into the capacity weight of `weighted`
    let point = a.get_or("point", "paper");
    let dev_names: Vec<&str> = a.get_or("devices", "u250,u280,7020,7012s").split(',').collect();
    let mut specs: Vec<Vec<ReplicaSpec>> = Vec::with_capacity(chains);
    for g in 0..chains {
        let mut group = Vec::with_capacity(stages);
        for s in 0..stages {
            let name = dev_names[(g * stages + s) % dev_names.len()];
            let dev = device::by_name(name)
                .ok_or_else(|| anyhow::anyhow!("unknown device {name} in --devices"))?;
            group.push(match point {
                "paper" => ReplicaSpec::paper_point(dev),
                "packed" => ReplicaSpec::packed_point(
                    &net,
                    dev,
                    a.get_usize("hb", 4),
                    a.get_usize("generations", 40),
                    seed,
                ),
                other => anyhow::bail!("unknown --point {other} (paper|packed)"),
            });
        }
        specs.push(group);
    }
    // per-stage mock service via the shared calibration (the same one
    // the control plane's ControlledFleet uses): a k-stage chain splits
    // the network, so each stage serves in 1/k of its device's
    // full-network interval; the fastest device anchors --service-us
    let service_us = a.get_f64("service-us", 400.0);
    let fps: Vec<Vec<f64>> = specs
        .iter()
        .map(|g| g.iter().map(|s| replica_fps(&net, s)).collect())
        .collect();
    let ref_fps = fps.iter().flatten().copied().fold(0.0f64, f64::max).max(1e-9);
    let svc: Vec<Vec<Duration>> = fps
        .iter()
        .map(|g| mock_chain_service_from_fps(g, service_us, ref_fps))
        .collect();
    let weights = group_weights(&svc.iter().map(|g| chain_fps(g)).collect::<Vec<f64>>());
    let policy = Policy::by_name(a.get_or("policy", "round-robin"), weights.clone())
        .ok_or_else(|| anyhow::anyhow!("unknown policy (round-robin|jsq|weighted)"))?;
    let policy_name = policy.name();

    let trace = trace_by_name(trace_name, n, rate, seed)?;
    if let Some(out) = a.get("trace-out") {
        trace.save(Path::new(out))?;
        println!("recorded trace ({} arrivals) to {out}", trace.len());
    }
    let plan = Deployment::replicated_chains(chains, stages)
        .with_policy(policy)
        .with_batcher(BatcherConfig { max_batch, max_wait: Duration::from_millis(2) })
        .with_queue_depth(queue_depth)
        .with_window(window);

    println!(
        "fleet: {chains} chain group(s) x {stages} stage(s), policy {policy_name}, \
         trace {trace_name}, window {window}"
    );
    for (g, group) in specs.iter().enumerate() {
        println!("  group {g} (weight {:.2}):", weights[g]);
        for (s, spec) in group.iter().enumerate() {
            println!(
                "    stage {s}: {} (R_F={:.1}, LUT {:.0}%) — analytic {:.0} FPS",
                spec.device.name,
                spec.rf,
                100.0 * spec.lut_util,
                fps[g][s]
            );
        }
    }

    // span tracing + live exposition (no-ops unless --trace-sample /
    // --metrics-out are given); the exposition moves into whichever
    // backend arm runs
    let ocfg = obs_by_args(a);
    let expo = exposition_by_args(a);
    let hcfg = health_by_args(a);
    let (mut srv, fm) = match backend {
        "mock" => {
            let mut srv = Server::deploy_with_obs(
                move |id: WorkerId| {
                    MockBackend::with_service(Duration::ZERO, svc[id.group][id.stage])
                },
                plan,
                &ocfg,
            );
            if let Some(e) = expo {
                srv.set_exposition(e);
            }
            if let Some(h) = hcfg {
                srv.set_health(h);
            }
            let fm = srv.replay(&trace, 8, seed);
            (srv, fm)
        }
        "pipelined" => {
            // same calibrated per-stage service, split into an overlapping
            // transfer leg and a compute leg: --window 2+ hides the
            // transfer behind the previous batch's compute
            let xfer_frac = a.get_f64("xfer-frac", 0.5).clamp(0.0, 1.0);
            let speedup = overlap_speedup(xfer_frac, 1.0 - xfer_frac, window);
            println!(
                "pipelined backend: {:.0}% transfer / {:.0}% compute per item, \
                 analytic overlap speedup {speedup:.2}x at window {window}",
                100.0 * xfer_frac,
                100.0 * (1.0 - xfer_frac)
            );
            let mut srv = Server::deploy_with_obs(
                move |id: WorkerId| {
                    let s = svc[id.group][id.stage];
                    PipelinedMockBackend::overlapped(
                        s.mul_f64(xfer_frac),
                        s.mul_f64(1.0 - xfer_frac),
                    )
                },
                plan,
                &ocfg,
            );
            if let Some(e) = expo {
                srv.set_exposition(e);
            }
            if let Some(h) = hcfg {
                srv.set_health(h);
            }
            let fm = srv.replay(&trace, 8, seed);
            (srv, fm)
        }
        "pjrt" => {
            anyhow::ensure!(
                stages == 1,
                "--backend pjrt serves flat fleets only (--stages 1): pipeline stages \
                 need per-shard artifacts, which the AOT exporter does not emit yet"
            );
            let arts = Path::new(a.get_or("artifacts", "artifacts")).to_path_buf();
            let probe = runtime::Engine::load(&arts, model)?;
            let per = probe.manifest.input_elements_per_sample() as usize;
            drop(probe);
            let mut srv = Server::deploy_with_obs(
                move |_| runtime::Engine::load(&arts, model).expect("engine"),
                plan,
                &ocfg,
            );
            if let Some(e) = expo {
                srv.set_exposition(e);
            }
            if let Some(h) = hcfg {
                srv.set_health(h);
            }
            let fm = srv.replay(&trace, per, seed);
            (srv, fm)
        }
        other => anyhow::bail!("unknown backend {other} (mock|pipelined|pjrt)"),
    };
    srv.shutdown();
    println!(
        "serve [{model} {chains}x{stages} {policy_name}/{trace_name}] offered {:.0} req/s:",
        trace.offered_rate()
    );
    println!("{}", fm.summary());
    print_obs_summary(srv.obs());
    if let Some(e) = srv.exposition() {
        println!("metrics: {} snapshot(s) to {}", e.emits(), e.path().display());
    }
    // serve has no control plane, so incidents correlate against an
    // empty event stream (every breach reports as unresponded)
    let hj = srv.take_health();
    print_health_summary(a, hj.as_ref(), &[]);
    Ok(())
}

/// `fcmp shard`: partition one network across a device fleet and validate
/// the staged pipeline (analytic plan, discrete-event sim, optionally the
/// stage-chain serving coordinator on calibrated mocks).
fn cmd_shard(a: &Args) -> anyhow::Result<()> {
    let net = network_by_name(a.get_or("network", "cnv-w2a2"))
        .ok_or_else(|| anyhow::anyhow!("unknown network"))?;
    let dev_names: Vec<&str> = a.get_or("devices", "7012s,7012s").split(',').collect();
    let shards = a.get_usize("shards", dev_names.len()).max(1);
    let mut devices = Vec::with_capacity(shards);
    for i in 0..shards {
        let name = dev_names[i % dev_names.len()];
        devices.push(
            device::by_name(name)
                .ok_or_else(|| anyhow::anyhow!("unknown device {name} in --devices"))?,
        );
    }
    let cfg = PartitionConfig {
        bin_height: a.get_usize("hb", 4),
        generations: if a.get_or("engine", "ga") == "ffd" {
            0
        } else {
            a.get_usize("generations", 40)
        },
        seed: a.get_usize("seed", 2020) as u64,
        link: LinkSpec {
            gbps: a.get_f64("link-gbps", 100.0),
            latency_us: a.get_f64("link-us", 2.0),
        },
    };

    // why shard at all? report single-device feasibility per distinct part
    let mut seen: Vec<&str> = Vec::new();
    for dev in &devices {
        if seen.contains(&dev.name) {
            continue;
        }
        seen.push(dev.name);
        let solo = sharding::Evaluator::new(&net, cfg).shard(0, net.stages.len(), dev);
        println!(
            "{} packed on one {}: {} of {} BRAM18, LUT {:.0}% -> {}",
            net.name,
            dev.name,
            solo.bram_demand,
            solo.bram_capacity,
            100.0 * solo.lut_util,
            if solo.fits() { "fits (sharding optional)" } else { "DOES NOT FIT" }
        );
    }

    let plan = sharding::partition(&net, &devices, cfg)?;
    println!(
        "\nplan: {} over {} shards, analytic bottleneck {:.1} us -> {:.0} FPS{}",
        plan.network,
        plan.shards.len(),
        plan.bottleneck_s * 1e6,
        plan.fps,
        if plan.bottleneck_is_link() { " (link-bound)" } else { "" }
    );
    for (j, s) in plan.shards.iter().enumerate() {
        let stages: Vec<&str> =
            net.stages[s.stages.0..s.stages.1].iter().map(|st| st.name()).collect();
        println!(
            "  shard {j} on {}: stages {}..{} [{}]",
            s.device.name,
            s.stages.0,
            s.stages.1,
            stages.join(", ")
        );
        println!(
            "    OCM {} of {} BRAM18 ({:.0}%, packed weights {}), LUT {:.0}%, \
             II {} cy @ {:.0} MHz -> {:.1} us/frame",
            s.bram_demand,
            s.bram_capacity,
            100.0 * s.bram_pressure(),
            s.packed_brams,
            100.0 * s.lut_util,
            s.ii_cycles,
            s.effective_mhz,
            s.seconds_per_frame * 1e6
        );
        if j < plan.links.len() {
            let l = &plan.links[j];
            println!(
                "    link {j}: {:.1} Kbit/frame, {:.2} us/frame, {:.0}% of bottleneck",
                l.bits_per_frame as f64 / 1e3,
                l.seconds_per_frame * 1e6,
                100.0 * plan.link_utilization()[j]
            );
        }
    }

    // the sharded sim needs a steady-state window; quietly clamp tiny values
    let frames = a.get_usize("frames", 400).max(8) as u64;
    let fifo = a.get_usize("fifo", 8) as u64;
    let r = sim::simulate_sharded(&net, &plan, frames, fifo);
    println!(
        "\nsim ({frames} frames, link FIFO {fifo}): {:.0} FPS = {:.2}% of analytic, \
         fill latency {:.1} us",
        r.fps,
        100.0 * r.vs_analytic,
        r.first_out_ns as f64 / 1e3
    );

    if a.has_flag("serve") {
        // --chains N serves N parallel copies of the k-stage chain behind
        // the router (the replicated-chain topology): offered capacity
        // scales with N while each frame still traverses one full chain
        let chains = a.get_usize("chains", 1).max(1);
        let requests = a.get_usize("requests", 256);
        let cap = plan.fps * 0.8 * chains as f64;
        let rate = a.get_f64("rate", cap);
        let batcher = BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) };
        let svc = shard_service_times(&plan);
        let dep = Deployment::replicated_chains(chains, plan.shards.len())
            .with_batcher(batcher)
            .with_queue_depth(fifo as usize);
        let svc_backend = svc.clone();
        let mut srv = Server::deploy(
            move |id: WorkerId| {
                MockBackend::with_service(Duration::ZERO, svc_backend[id.stage])
            },
            dep,
        );
        let trace = poisson(requests, rate, cfg_seed(a));
        let fm = srv.replay(&trace, 8, cfg_seed(a));
        println!(
            "\nchain serve [{} chain(s) x {} stages, {:.0} req/s offered]:",
            chains,
            plan.shards.len(),
            trace.offered_rate()
        );
        println!("{}", fm.summary());

        // --kill-stage I: simulate losing shard I's device mid-deployment,
        // re-partition over the survivors (migrating cached packed
        // manifests) and splice the repaired plan into the running chain
        if let Some(kill) = a.get("kill-stage") {
            let dead: usize = kill
                .parse()
                .map_err(|_| anyhow::anyhow!("--kill-stage wants a shard index, got {kill:?}"))?;
            anyhow::ensure!(
                dead < devices.len(),
                "--kill-stage {dead} out of range for {} devices",
                devices.len()
            );
            println!("\nFAILURE: device {} ({}) lost", dead, devices[dead].name);
            let out = replan(&net, &devices, dead, cfg);
            match &out.plan {
                None => println!(
                    "re-partition over {:?}: INFEASIBLE — {}",
                    out.survivors.iter().map(|d| d.name).collect::<Vec<_>>(),
                    out.infeasible.as_deref().unwrap_or("unknown")
                ),
                Some(new_plan) => {
                    println!(
                        "re-partition over {:?}: {} shards, {:.0} FPS analytic \
                         ({} manifests migrated from cache, {} re-packed)",
                        out.survivors.iter().map(|d| d.name).collect::<Vec<_>>(),
                        new_plan.shards.len(),
                        new_plan.fps,
                        out.migrated_shards,
                        out.repacked_shards
                    );
                    splice_mock_chain(
                        &mut srv,
                        new_plan,
                        batcher,
                        fifo as usize,
                        Duration::from_millis(2),
                    )?;
                    let cap2 = new_plan.fps * 0.8 * chains as f64;
                    let rate2 = a.get_f64("rate", cap2).min(cap2);
                    let trace2 = poisson(requests, rate2.max(1.0), cfg_seed(a) + 1);
                    let fm2 = srv.replay(&trace2, 8, cfg_seed(a) + 1);
                    println!(
                        "post-repair chain serve [{} stages, {:.0} req/s offered]:",
                        new_plan.shards.len(),
                        trace2.offered_rate()
                    );
                    println!("{}", fm2.summary());
                }
            }
        }
        srv.shutdown();
    }
    Ok(())
}

fn cfg_seed(a: &Args) -> u64 {
    a.get_usize("seed", 2020) as u64
}

/// `fcmp simulate`: the discrete-event fleet simulator — the same
/// Deployment topology, policies, batching and control plane as `serve` /
/// `autoscale`, but on a virtual clock: thousands of chain groups and
/// millions of requests simulate in wall-clock seconds, bit-reproducibly.
fn cmd_simulate(a: &Args) -> anyhow::Result<()> {
    let chains = a.get_usize("chains", a.get_usize("replicas", 4)).max(1);
    let stages = a.get_usize("stages", 1).max(1);
    let n = a.get_usize("requests", 100_000);
    let rate = a.get_f64("rate", 2000.0);
    let seed = cfg_seed(a);
    let trace_name = a.get_or("trace", "poisson");
    let trace = trace_by_name(trace_name, n, rate, seed)?;

    let policy = Policy::by_name(a.get_or("policy", "round-robin"), vec![1.0; chains])
        .ok_or_else(|| anyhow::anyhow!("unknown policy (round-robin|jsq|weighted)"))?;
    let policy_name = policy.name();
    let batcher = BatcherConfig {
        max_batch: a.get_usize("batch", 4),
        max_wait: Duration::from_secs_f64(a.get_f64("wait-ms", 2.0) * 1e-3),
    };
    let window = a.get_usize("window", 2).max(1);
    let plan = Deployment::replicated_chains(chains, stages)
        .with_policy(policy)
        .with_batcher(batcher)
        .with_queue_depth(a.get_usize("queue", 64))
        .with_window(window);

    // one chain splits the model across its stages, so each stage serves
    // in 1/k of the full-network interval (the serve-path calibration)
    let per_item = Duration::from_secs_f64(a.get_f64("service-us", 400.0) * 1e-6 / stages as f64);
    let backend = match a.get_or("backend", "mock") {
        "mock" => SimBackend::Mock {
            base: Duration::from_secs_f64(a.get_f64("base-us", 0.0) * 1e-6),
            per_item,
        },
        "pipelined" => {
            let f = a.get_f64("xfer-frac", 0.5).clamp(0.0, 1.0);
            SimBackend::Pipelined {
                xfer_per_item: per_item.mul_f64(f),
                compute_per_item: per_item.mul_f64(1.0 - f),
            }
        }
        other => anyhow::bail!("unknown backend {other} (mock|pipelined)"),
    };

    let autoscale = a.has_flag("autoscale");
    let max_groups = a.get_usize("max", if autoscale { chains.max(1) * 4 } else { chains });
    let slo = a.get("slo-p99").map(|_| SloConfig {
        p99_budget_ms: a.get_f64("slo-p99", 50.0),
        ..SloConfig::default()
    });
    let control = if autoscale || slo.is_some() {
        Some(SimControl {
            tick: Duration::from_millis(a.get_usize("tick-ms", 25) as u64),
            signal: SignalConfig { window_ticks: a.get_usize("signal-window", 3) },
            autoscaler: autoscale.then(|| AutoscalerConfig {
                min_groups: a.get_usize("min", 1),
                max_groups,
                shed_out: a.get_f64("shed-out", 0.02),
                p99_out_ms: a.get_f64("p99-out", f64::INFINITY),
                util_in: a.get_f64("util-in", 0.25),
                cooldown_ticks: a.get_usize("cooldown", 3),
                step: a.get_usize("step", 1),
            }),
            slo,
            trailing_ticks: a.get_usize("trailing", 8),
        })
    } else {
        None
    };
    let standby = max_groups.saturating_sub(chains);
    let cfg = SimConfig {
        input_len: a.get_usize("input-len", 8),
        seed,
        control,
        obs: obs_by_args(a),
        health: health_by_args(a),
    };

    println!(
        "simulate: {chains} chain group(s) x {stages} stage(s) (+{standby} standby), \
         policy {policy_name}, trace {trace_name} ({:.0} req/s offered), window {window}",
        trace.offered_rate()
    );
    let mut fleet_sim = FleetSim::uniform_with_standby(plan, backend, standby, cfg);
    if let Some(e) = exposition_by_args(a) {
        fleet_sim.set_exposition(e);
    }
    // run() consumes the sim; keep the obs hub for the epilogue
    let sim_obs = fleet_sim.obs().clone();
    let t0 = std::time::Instant::now();
    let rep = fleet_sim.run(&trace);
    let wall = t0.elapsed();

    if !rep.events.is_empty() {
        println!("events:");
        for e in &rep.events {
            println!("  {e}");
        }
    }
    if let Some(out) = a.get("events-out") {
        save_events(&rep.events, Path::new(out))?;
        println!("journaled {} control events to {out}", rep.events.len());
    }
    println!(
        "result: submitted {} shed {} completed {} | chain groups {} -> {} (peak {}) \
         over {} ticks",
        rep.submitted,
        rep.shed,
        rep.completed,
        rep.initial_groups,
        rep.final_groups,
        rep.max_groups_seen,
        rep.ticks
    );
    println!(
        "clock: {:.3} simulated s in {:.0} ms wall ({} events, {:.0} req/s of wall time)",
        rep.sim_seconds,
        wall.as_secs_f64() * 1e3,
        rep.events_processed,
        rep.submitted as f64 / wall.as_secs_f64().max(1e-9)
    );
    println!("{}", rep.summary);
    print_obs_summary(&sim_obs);
    if let Some(p) = a.get("metrics-out") {
        println!("metrics: snapshots to {p}");
    }
    print_health_summary(a, rep.health.as_ref(), &rep.events);

    if a.has_flag("require-scale-cycle") {
        let first_out = rep.events.iter().find_map(|e| match e.kind {
            fcmp::control::ControlEventKind::ScaleOut { .. } => Some(e.tick),
            _ => None,
        });
        let first_in = rep.events.iter().find_map(|e| match e.kind {
            fcmp::control::ControlEventKind::ScaleIn { .. } => Some(e.tick),
            _ => None,
        });
        let (out_tick, in_tick) = match (first_out, first_in) {
            (Some(o), Some(i)) => (o, i),
            _ => anyhow::bail!("--require-scale-cycle: no scale-out/scale-in pair occurred"),
        };
        anyhow::ensure!(
            out_tick < in_tick,
            "--require-scale-cycle: scale-in (tick {in_tick}) preceded scale-out (tick {out_tick})"
        );
        println!("scale cycle OK: out at tick {out_tick}, in at tick {in_tick}");
    }
    Ok(())
}

/// One tenant of the model zoo, parsed from `NAME:NET:RATE:SLO_MS`.
struct ZooTenant {
    name: String,
    net: Network,
    rate: f64,
    slo_ms: f64,
}

/// Networks servable by the zoo: the CNV/RN50 catalog plus the small
/// MLP-class nets whose memories co-pack into the headroom FCMP frees.
fn zoo_network(name: &str) -> Option<Network> {
    match name {
        "sfc" | "sfc-w1a1" | "sfc_w1a1" => Some(sfc_w1a1()),
        "lfc" | "lfc-w1a1" | "lfc_w1a1" => Some(lfc_w1a1()),
        other => network_by_name(other),
    }
}

fn parse_tenants(spec: &str) -> anyhow::Result<Vec<ZooTenant>> {
    let mut out = Vec::new();
    for part in spec.split(',') {
        let f: Vec<&str> = part.split(':').collect();
        anyhow::ensure!(f.len() == 4, "tenant wants NAME:NET:RATE:SLO_MS, got {part:?}");
        let net = zoo_network(f[1])
            .ok_or_else(|| anyhow::anyhow!("unknown network {:?} for tenant {:?}", f[1], f[0]))?;
        let rate: f64 =
            f[2].parse().map_err(|_| anyhow::anyhow!("bad rate {:?} for tenant {:?}", f[2], f[0]))?;
        let slo_ms: f64 =
            f[3].parse().map_err(|_| anyhow::anyhow!("bad SLO {:?} for tenant {:?}", f[3], f[0]))?;
        anyhow::ensure!(
            rate > 0.0 && slo_ms > 0.0,
            "tenant {:?} wants positive rate and SLO",
            f[0]
        );
        out.push(ZooTenant { name: f[0].to_string(), net, rate, slo_ms });
    }
    anyhow::ensure!(!out.is_empty(), "--tenants parsed to an empty catalog");
    Ok(out)
}

/// Per-tenant goodput epilogue: completions inside the tenant's SLO over
/// everything that tenant offered (accepted + shed + deadline-shed).
fn print_zoo_goodput(tenants: &[ZooTenant], s: &FleetSummary) {
    for ts in &s.per_tenant {
        let name = tenants.get(ts.tenant).map(|t| t.name.as_str()).unwrap_or("?");
        let offered = ts.submitted + ts.shed + ts.deadline_shed;
        let frac = if offered == 0 { 1.0 } else { ts.goodput as f64 / offered as f64 };
        println!(
            "  goodput[{name}]: {}/{} offered inside {:.0} ms ({:.1}%)",
            ts.goodput,
            offered,
            ts.slo_ms.unwrap_or(f64::INFINITY),
            100.0 * frac
        );
    }
}

/// `fcmp zoo`: the multi-tenant model zoo end to end — co-pack a model
/// catalog onto one device, deploy per-tenant chain groups behind the
/// tenant-aware router, replay each tenant's trace merged onto the shared
/// fleet (threaded server by default, `--sim` for the virtual clock), and
/// report per-tenant SLO attainment. `--fifo` zeroes the service estimate
/// so admission keeps every request a queue slot can hold (the
/// deadline-aware arm's baseline).
fn cmd_zoo(a: &Args) -> anyhow::Result<()> {
    // default catalog: CNV-W2A2 + SFC on one 7020 — co-packed it fits
    // (≈260/280 BRAM18), unpacked it overflows (≈309), and a dedicated
    // fleet needs a board per tenant: packing-enabled consolidation
    let tenants = parse_tenants(a.get_or("tenants", "cnv:cnv-w2a2:250:250,sfc:sfc-w1a1:400:100"))?;
    let dev = device::by_name(a.get_or("device", "7020"))
        .ok_or_else(|| anyhow::anyhow!("unknown device"))?;
    let seed = cfg_seed(a);

    // layer 1: one packing run over the union of every tenant's columns
    let nets: Vec<&Network> = tenants.iter().map(|t| &t.net).collect();
    let hb = a.get_usize("hb", 4);
    let generations = a.get_usize("generations", 40);
    let cp = tenancy::co_pack(&nets, &dev, hb, generations, seed);
    let dedicated = tenancy::dedicated_devices(&nets, &dev, hb, generations, seed);
    println!(
        "zoo catalog on {} ({} BRAM18), engine {}:",
        cp.device, cp.device_brams, cp.report.engine
    );
    for (t, tn) in tenants.iter().enumerate() {
        println!(
            "  tenant {t} ({}): {} — {} column(s), {:.1} packed BRAM18 share, \
             {:.0} req/s, SLO {:.0} ms",
            tn.name,
            tn.net.name,
            cp.unpack_tenant(t).len(),
            cp.tenant_brams(t),
            tn.rate,
            tn.slo_ms
        );
    }
    println!(
        "co-packed: {} weight + {} excluded + {} activation = {} BRAM18 ({}) | \
         direct {} ({}) | dedicated fleet: {} device(s)",
        cp.weight_brams,
        cp.excluded_brams,
        cp.activation_brams,
        cp.total_brams(),
        if cp.fits() { "fits" } else { "OVERFLOWS" },
        cp.total_direct_brams(),
        if cp.fits_direct() { "fits" } else { "overflows" },
        dedicated
    );
    if a.has_flag("require-consolidation") {
        anyhow::ensure!(
            cp.fits(),
            "--require-consolidation: co-packed catalog overflows {}",
            cp.device
        );
        anyhow::ensure!(
            dedicated > 1,
            "--require-consolidation: the dedicated baseline also fits one device"
        );
    }

    // layer 2: per-tenant chain groups behind one tenant-aware router
    let chains = a.get_usize("chains-per-tenant", 1).max(1);
    let mut groups = Vec::with_capacity(tenants.len() * chains);
    for t in 0..tenants.len() {
        for _ in 0..chains {
            groups.push(ChainGroup::new(1).for_tenant(t));
        }
    }
    let n_groups = groups.len();
    let policy = Policy::by_name(a.get_or("policy", "jsq"), vec![1.0; n_groups])
        .ok_or_else(|| anyhow::anyhow!("unknown policy (round-robin|jsq|weighted)"))?;
    let policy_name = policy.name();
    let plan = Deployment { groups, ..Deployment::default() }
        .with_policy(policy)
        .with_batcher(BatcherConfig {
            max_batch: a.get_usize("batch", 4),
            max_wait: Duration::from_secs_f64(a.get_f64("wait-ms", 1.0) * 1e-3),
        })
        .with_queue_depth(a.get_usize("queue", 16))
        .with_window(a.get_usize("window", 2).max(1));

    // flat mock service: the zoo measures routing/admission isolation,
    // not device calibration (serve/shard own that)
    let service = Duration::from_secs_f64(a.get_f64("service-us", 400.0) * 1e-6);
    let group_svc: Vec<Duration> = vec![service; n_groups];

    // layer 3: deadline admission from each tenant's SLO budget; --fifo
    // zeroes the estimate, keeping only already-expired sheds
    let budgets: Vec<Option<Duration>> =
        tenants.iter().map(|t| Some(Duration::from_secs_f64(t.slo_ms * 1e-3))).collect();
    let est: Vec<Duration> = if a.has_flag("fifo") {
        vec![Duration::ZERO; n_groups]
    } else {
        group_svc.clone()
    };

    // one trace per tenant (per-tenant rate and seed), merged
    // deterministically with per-arrival tenant tags
    let n = a.get_usize("requests", 400);
    let trace_name = a.get_or("trace", "poisson");
    let mut parts: Vec<(usize, Trace)> = Vec::with_capacity(tenants.len());
    for (t, tn) in tenants.iter().enumerate() {
        parts.push((t, trace_by_name(trace_name, n, tn.rate, seed + t as u64)?));
    }
    let refs: Vec<(usize, &Trace)> = parts.iter().map(|(t, tr)| (*t, tr)).collect();
    let (merged, tags) = Trace::merge(&refs);
    println!(
        "fleet: {} tenant(s) x {chains} group(s), policy {policy_name}, trace {trace_name}, \
         {} merged arrival(s) ({:.0} req/s offered){}",
        tenants.len(),
        merged.len(),
        merged.offered_rate(),
        if a.has_flag("fifo") { ", fifo admission" } else { ", deadline admission" }
    );

    let ocfg = obs_by_args(a);
    let hcfg = health_by_args(a);
    let input_len = a.get_usize("input-len", 8);
    let summary = if a.has_flag("sim") {
        let cfg = SimConfig { input_len, seed, control: None, obs: ocfg, health: hcfg };
        let backends: Vec<Vec<SimBackend>> = group_svc
            .iter()
            .map(|&s| vec![SimBackend::Mock { base: Duration::ZERO, per_item: s }])
            .collect();
        let mut fs = FleetSim::new(plan, backends, cfg);
        fs.set_tenancy(budgets, est);
        if let Some(e) = exposition_by_args(a) {
            fs.set_exposition(e);
        }
        let sim_obs = fs.obs().clone();
        let rep = fs.run_tagged(&merged, &tags);
        println!(
            "result: submitted {} shed {} deadline-shed {} completed {} in {:.3} simulated s",
            rep.submitted, rep.shed, rep.deadline_shed, rep.completed, rep.sim_seconds
        );
        println!("{}", rep.summary);
        print_obs_summary(&sim_obs);
        print_health_summary(a, rep.health.as_ref(), &rep.events);
        rep.summary
    } else {
        let gs = group_svc.clone();
        let mut srv = Server::deploy_with_obs(
            move |id: WorkerId| MockBackend::with_service(Duration::ZERO, gs[id.group]),
            plan,
            &ocfg,
        );
        if let Some(e) = exposition_by_args(a) {
            srv.set_exposition(e);
        }
        if let Some(h) = hcfg {
            srv.set_health(h);
        }
        srv.set_tenancy(budgets, est);
        let fm = srv.replay_tagged(&merged, &tags, input_len, seed);
        srv.shutdown();
        let summary = fm.summary();
        println!("{summary}");
        print_obs_summary(srv.obs());
        // zoo runs no control loop: breaches correlate as unresponded
        let hj = srv.take_health();
        print_health_summary(a, hj.as_ref(), &[]);
        summary
    };
    print_zoo_goodput(&tenants, &summary);
    if let Some(min) = a.get("require-goodput") {
        let min: f64 = min.parse().map_err(|_| anyhow::anyhow!("bad --require-goodput {min:?}"))?;
        for ts in &summary.per_tenant {
            let offered = ts.submitted + ts.shed + ts.deadline_shed;
            let frac = if offered == 0 { 1.0 } else { ts.goodput as f64 / offered as f64 };
            anyhow::ensure!(
                frac >= min,
                "--require-goodput: tenant {} reached {:.3} < {min}",
                ts.tenant,
                frac
            );
        }
        println!("goodput OK: every tenant >= {min}");
    }
    Ok(())
}

/// `fcmp tracereport`: critical-path breakdown of a span trace file —
/// where each sampled request's latency went (stage-queue wait, batch
/// gather, backend compute, inter-stage link) per chain group and stage.
fn cmd_tracereport(a: &Args) -> anyhow::Result<()> {
    let path = a
        .get("spans")
        .ok_or_else(|| anyhow::anyhow!("--spans PATH required (a --spans-out JSONL file)"))?;
    let spans = tracereport::load(Path::new(path))?;
    anyhow::ensure!(!spans.is_empty(), "no spans in {path} (was --trace-sample > 0?)");
    let rep = tracereport::analyze(&spans);
    anyhow::ensure!(
        !rep.stages.is_empty(),
        "spans in {path} carry no stage stamps (all shed before admission?)"
    );
    println!(
        "tracereport [{path}]: {} completed span(s), {} shed, {} (group, stage) cell(s)",
        rep.completed,
        rep.shed,
        rep.stages.len()
    );
    println!("{}", tracereport::table(&rep).render());
    Ok(())
}

/// `fcmp healthreport`: incident attribution over a health journal —
/// join the burn-alert stream against the journaled control events,
/// date each breach via the downsampled cells, and report time to
/// detection / time to mitigation per incident.
fn cmd_healthreport(a: &Args) -> anyhow::Result<()> {
    let path = a
        .get("health")
        .ok_or_else(|| anyhow::anyhow!("--health PATH required (a --health-out JSONL journal)"))?;
    let journal = HealthJournal::load(Path::new(path))?;
    anyhow::ensure!(
        !journal.cells.is_empty(),
        "no health cells in {path} (was the run long enough to close a cell?)"
    );
    let events = match a.get("events") {
        Some(p) => load_events(Path::new(p))?,
        None => Vec::new(),
    };
    let incidents = health::correlate(&journal, &events);
    let st = health::stats(&incidents);
    println!(
        "healthreport [{path}]: {} cell(s), {} alert transition(s), {} control event(s) | \
         {} incident(s): {} mitigated, {} unresponded, mean ttd {:.1} s, mean ttm {:.1} s",
        journal.cells.len(),
        journal.alerts.len(),
        events.len(),
        st.incidents,
        st.mitigated,
        st.unresponded,
        st.mean_ttd_s,
        st.mean_ttm_s
    );
    if incidents.is_empty() {
        println!("no incidents: no burn alert fired over the journal horizon");
    } else {
        println!("{}", health::table(&incidents).render());
    }
    if a.has_flag("require-incidents") {
        anyhow::ensure!(
            !incidents.is_empty(),
            "--require-incidents: no SLO-breach incident in {path}"
        );
    }
    Ok(())
}

fn cmd_floorplan(a: &Args) -> anyhow::Result<()> {
    let net = network_by_name(a.get_or("network", "rn50-w1"))
        .ok_or_else(|| anyhow::anyhow!("unknown network"))?;
    let dev = device::by_name(a.get_or("device", "u250"))
        .ok_or_else(|| anyhow::anyhow!("unknown device"))?;
    match device::floorplan(&net, &dev) {
        None => println!("{} does not floorplan onto {}", net.name, dev.name),
        Some(fp) => {
            println!(
                "{} on {}: {} SLR crossings, bottleneck BRAM {:.0}%, LUT {:.0}%",
                net.name,
                dev.name,
                fp.crossings,
                100.0 * fp.max_bram_pressure,
                100.0 * fp.max_lut_pressure
            );
            let demands = device::floorplan::stage_demands(&net);
            for slr in 0..dev.slrs.len() {
                let members: Vec<&str> = demands
                    .iter()
                    .zip(&fp.assignment)
                    .filter(|(_, &a)| a == slr)
                    .map(|(d, _)| d.name.as_str())
                    .collect();
                println!("  SLR{slr}: {}", members.join(", "));
            }
        }
    }
    Ok(())
}

fn cmd_dse(a: &Args) -> anyhow::Result<()> {
    let net = network_by_name(a.get_or("network", "cnv-w1a1"))
        .ok_or_else(|| anyhow::anyhow!("unknown network"))?;
    let dev = device::by_name(a.get_or("device", "7020"))
        .ok_or_else(|| anyhow::anyhow!("unknown device"))?;
    let budget = a.get_f64("budget", 0.85);
    let solved = folding::solve(&net, &dev, budget);
    let r = folding::network_resources(&solved, &dev);
    let e = sim::estimate(&solved, dev.nominal_compute_mhz);
    println!(
        "{} on {}: {:.0} FPS @ {} MHz | LUT {:.0}% BRAM {:.0}% | II {}",
        solved.name,
        dev.name,
        e.fps,
        dev.nominal_compute_mhz,
        r.lut_pct(&dev),
        r.bram_pct(&dev),
        e.ii_cycles
    );
    Ok(())
}

const USAGE: &str = "\
fcmp — Frequency Compensated Memory Packing (paper reproduction)
subcommands:
  pack    pack a network's weight buffers into BRAMs (FCMP, paper section IV;
          --islands N --threads T runs the parallel island-model GA)
  report  regenerate the paper's tables/figures
          (--table 1|2|4|5|fig2|fig4|shard|all)
  perf    analytic FPS/latency of an accelerator (--network, --mhz)
  gals    cycle-level GALS streamer simulation (--nb, --rf, --static)
  golden  verify PJRT runtime against python golden outputs
  serve   unified Deployment serving (--chains N --stages k: N parallel
          k-stage chain groups behind the router; N x 1 is the flat
          replicated fleet, 1 x k a single pipeline chain, N x k the
          replicated-chain shape) --policy round-robin|jsq|weighted
          --trace poisson|bursty|heavy|diurnal|file:PATH [--trace-out
          PATH] --backend mock|pipelined|pjrt --point paper|packed;
          weighted capacity comes from the sim/timing model of each chain
          group's --devices entries, and the summary reports per-group
          e2e p99 plus the hot-path profile; --window W keeps up to W
          batches in flight per worker (pipelined backends overlap
          transfer with compute, --xfer-frac splits the service time)
  shard   pipeline-parallel multi-device sharding: partition one network
          over --devices a,b,... [--shards k] into contiguous stage shards
          (per-shard FCMP packing, --hb/--generations/--engine ga|ffd),
          model the cut links (--link-gbps/--link-us), simulate the staged
          pipeline (--frames/--fifo) and optionally serve it (--serve
          --chains N --requests R: N replicated copies of the k-stage
          chain); --kill-stage I simulates losing shard I's device
          mid-serve, re-partitions the survivors (migrating cached packed
          manifests) and splices the repaired plan into the running chains
  autoscale  adaptive control plane on a mock fleet of chain groups
          (--chains N x --stages k): SLO-driven whole-group autoscaling
          (--shed-out/--p99-out/--util-in/--cooldown, bounds --min/--max
          in groups), live SLO batching co-tuned per group (--slo-p99 MS),
          failure injection (--kill T:G,... kills chain group G), driven
          by --trace flash[:M[:S[:L]]]|diurnal|...|file:PATH; --static
          disables the autoscaler (baseline arm), --events-out PATH
          journals the ControlEvent history in the trace file convention,
          --require-scale-cycle makes the run fail unless it scaled out
          then back in (CI smoke)
  simulate  discrete-event fleet simulator: the serve/autoscale Deployment
          semantics (bounded queues, batchers, in-flight windows,
          round-robin|jsq|weighted admission, chain links, virtual-tick
          control plane) on a virtual clock — thousands of chain groups
          and millions of requests in wall-clock seconds, bit-reproducible
          for a given --seed; --chains N x --stages k [--max G] standby
          pool, --backend mock|pipelined [--xfer-frac], --service-us per
          request, --autoscale [--min/--shed-out/--p99-out/--util-in/
          --cooldown/--step], --slo-p99 MS, --tick-ms/--signal-window/
          --trailing, --events-out PATH, --require-scale-cycle (CI smoke);
          serve and simulate both take the observability flags:
          --trace-sample P samples request spans (--trace-seed/--trace-ring),
          --spans-out PATH flushes the flight recorder to JSONL (anomaly
          triggers --p99-budget MS / --shed-burst N, plus shutdown), and
          --metrics-out PATH [--metrics-interval S] exposes live
          Prometheus-text + JSONL metric snapshots
  zoo     multi-tenant model zoo: co-pack a model catalog onto one device
          (--tenants NAME:NET:RATE:SLO_MS,... --device 7020 [--hb 4]
          [--generations 40]; --require-consolidation fails unless the
          catalog fits co-packed while the dedicated baseline needs >1
          device), then serve every tenant on one shared fleet with
          per-tenant routing ([--chains-per-tenant N] [--policy jsq]),
          deadline admission from each tenant's SLO budget (--fifo for
          the keep-everything baseline), per-tenant traces merged
          deterministically ([--trace poisson|...] [--requests N] per
          tenant at its own rate) and per-tenant summary + goodput
          ([--require-goodput F] gates CI); --sim runs the identical
          semantics on the discrete-event virtual clock; takes the
          serve/simulate observability flags (--health-out, --spans-out,
          --metrics-out, ...)
  tracereport  critical-path breakdown of a span trace (--spans PATH):
          per-(group, stage) queue / gather / compute / link time table
  healthreport  incident attribution over a health journal (--health PATH
          [--events PATH] [--require-incidents]): joins SLO burn alerts
          against the control-event journal, dates each breach from the
          downsampled cells, and reports TTD/TTM per incident; serve and
          simulate write the journal with --health-out PATH (or collect
          in-memory with --health) [--shed-slo F] [--latency-slo F]
          [--health-sample S] [--health-window-scale X]
  dse     folding design-space exploration (--network, --device, --budget)
  floorplan  SLR floorplan of a network on a multi-die device (Fig. 5)";

fn main() {
    let args = Args::from_env();
    let r = match args.subcommand.as_deref() {
        Some("pack") => cmd_pack(&args),
        Some("report") => cmd_report(&args),
        Some("perf") => cmd_perf(&args),
        Some("gals") => cmd_gals(&args),
        Some("golden") => cmd_golden(&args),
        Some("serve") => cmd_serve(&args),
        Some("shard") => cmd_shard(&args),
        Some("autoscale") => cmd_autoscale(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("zoo") => cmd_zoo(&args),
        Some("tracereport") => cmd_tracereport(&args),
        Some("healthreport") => cmd_healthreport(&args),
        Some("dse") => cmd_dse(&args),
        Some("floorplan") => cmd_floorplan(&args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
