//! fcmp — command-line entry point.
//!
//! ```text
//! fcmp pack     --network cnv-w1a1|cnv-w2a2|rn50-w1|rn50-w2 --device 7020|7012s|u250|u280
//!               [--hb 4] [--engine ga|ffd|anneal] [--generations 120] [--seed 2020]
//!               [--islands 1] [--threads 0 (auto)] [--migrate 10]
//! fcmp report   --table 1|2|4|5|fig2|fig4|all [--generations 120]
//! fcmp perf     --network ... [--mhz 195]
//! fcmp gals     [--nb 4] [--rf 2.0] [--depth 128] [--cycles 10000] [--static]
//! fcmp golden   [--artifacts artifacts] [--model all|cnv_w1a1|cnv_w2a2|rn50_lite_w1a2]
//! fcmp serve    [--backend mock|pjrt] [--model cnv_w1a1] [--replicas 1]
//!               [--policy round-robin|jsq|weighted]
//!               [--trace poisson|bursty|heavy|diurnal|uniform|file:PATH]
//!               [--trace-out PATH] [--requests 256] [--rate 400] [--batch 4]
//!               [--queue 64] [--devices u250,u280,7020,7012s]
//!               [--service-us 400] [--point paper|packed]
//! fcmp shard    --network cnv-w2a2 --devices 7012s,7012s [--shards 2]
//!               [--hb 4] [--engine ga|ffd] [--generations 40]
//!               [--link-gbps 100] [--link-us 2] [--frames 400] [--fifo 8]
//!               [--serve] [--requests 256] [--rate FPS*0.8]
//! fcmp dse      --network ... --device ... [--budget 0.85]
//! ```

use fcmp::coordinator::{
    bursty, diurnal, fleet_weights, heavy_tail, poisson, replica_fps, shard_service_times,
    uniform, BatcherConfig, MockBackend, Policy, ReplicaSpec, Server, ServerConfig, Trace,
};
use fcmp::device;
use fcmp::gals::{Ratio, StreamerConfig, StreamerSim};
use fcmp::nn::{cnv, resnet50, CnvVariant, Network};
use fcmp::packing::{anneal::Anneal, ffd::Ffd, Packer};
use fcmp::sharding::{self, LinkSpec, PartitionConfig};
use fcmp::util::args::Args;
use fcmp::{folding, report, runtime, sim};
use std::path::Path;
use std::time::Duration;

fn network_by_name(name: &str) -> Option<Network> {
    match name {
        "cnv-w1a1" | "cnv_w1a1" => Some(cnv(CnvVariant::W1A1)),
        "cnv-w1a2" | "cnv_w1a2" => Some(cnv(CnvVariant::W1A2)),
        "cnv-w2a2" | "cnv_w2a2" => Some(cnv(CnvVariant::W2A2)),
        "rn50-w1" | "rn50" => Some(resnet50(1)),
        "rn50-w2" => Some(resnet50(2)),
        _ => None,
    }
}

/// Island-model execution knobs for the GA engine (CLI surface of the
/// parallel packer; see `packing::ga` for the determinism contract).
#[derive(Clone, Copy, Debug)]
struct GaTopology {
    islands: usize,
    threads: usize,
    migration_interval: usize,
}

fn engine_by_name(
    name: &str,
    net: &Network,
    generations: usize,
    seed: u64,
    topo: GaTopology,
) -> Box<dyn Packer> {
    match name {
        "ffd" => Box::new(Ffd::new()),
        "anneal" => Box::new(Anneal { seed, ..Anneal::default() }),
        _ => {
            let mut g = report::default_ga(net);
            g.params.generations = generations;
            g.params.seed = seed;
            g.params.islands = topo.islands.max(1);
            g.params.migration_interval = topo.migration_interval.max(1);
            g.threads = topo.threads;
            Box::new(g)
        }
    }
}

fn cmd_pack(a: &Args) -> anyhow::Result<()> {
    let net = network_by_name(a.get_or("network", "cnv-w1a1"))
        .ok_or_else(|| anyhow::anyhow!("unknown network"))?;
    let dev = device::by_name(a.get_or("device", "7020"))
        .ok_or_else(|| anyhow::anyhow!("unknown device"))?;
    let hb = a.get_usize("hb", 4);
    let topo = GaTopology {
        islands: a.get_usize("islands", 1),
        threads: a.get_usize("threads", 0),
        migration_interval: a.get_usize("migrate", 10),
    };
    let engine_name = a.get_or("engine", "ga");
    let engine = engine_by_name(
        engine_name,
        &net,
        a.get_usize("generations", 120),
        a.get_usize("seed", 2020) as u64,
        topo,
    );
    // only the GA engine has island/thread knobs
    let topo_note = if matches!(engine_name, "ffd" | "anneal") {
        String::new()
    } else {
        format!(
            ", islands={}, threads={}",
            topo.islands.max(1),
            if topo.threads == 0 { "auto".to_string() } else { topo.threads.to_string() }
        )
    };
    let out = report::pack_network(&net, &dev, engine.as_ref(), hb);
    println!(
        "{} on {} (H_B={hb}, R_F>={:.1}{topo_note}):",
        net.name,
        dev.name,
        hb as f64 / 2.0
    );
    println!(
        "  baseline : {:4} BRAM18  E={:5.1}%",
        out.baseline_brams,
        100.0 * out.baseline_eff
    );
    println!(
        "  packed   : {:4} BRAM18  E={:5.1}%  ({} bins, logic {:.1} kLUT, {:.2?})",
        out.report.brams,
        100.0 * out.report.efficiency,
        out.packing.bins.len(),
        out.logic_kluts,
        out.report.elapsed
    );
    println!(
        "  reduction: {:.1}%",
        100.0 * (1.0 - out.report.brams as f64 / out.baseline_brams as f64)
    );
    Ok(())
}

fn cmd_report(a: &Args) -> anyhow::Result<()> {
    let generations = a.get_usize("generations", 120);
    let which = a.get_or("table", "all");
    let show = |name: &str, t: fcmp::util::bench::Table| {
        println!("== {name} ==\n{}\n", t.render());
    };
    match which {
        "1" => show("Table I", report::table1()),
        "2" => show("Table II", report::table2()),
        "4" => show("Table IV", report::table4(generations)),
        "5" => show("Table V", report::table5(generations)),
        "fig2" => show("Fig 2", report::fig2()),
        "fig4" => show("Fig 4", report::fig4()),
        "shard" => show("Sharding", report::shard_table(generations)),
        _ => {
            show("Table I", report::table1());
            show("Fig 2", report::fig2());
            show("Table II", report::table2());
            show("Fig 4", report::fig4());
            show("Table IV", report::table4(generations));
            show("Table V", report::table5(generations));
            show("Sharding", report::shard_table(generations));
        }
    }
    Ok(())
}

fn cmd_perf(a: &Args) -> anyhow::Result<()> {
    let net = network_by_name(a.get_or("network", "rn50-w1"))
        .ok_or_else(|| anyhow::anyhow!("unknown network"))?;
    let mhz = a.get_f64("mhz", 195.0);
    let e = sim::estimate(&net, mhz);
    println!(
        "{} @ {mhz} MHz: {:.0} FPS, {:.2} ms latency, {:.1} TOp/s, II {} cycles (bottleneck {})",
        net.name, e.fps, e.latency_ms, e.tops, e.ii_cycles, e.bottleneck
    );
    Ok(())
}

fn cmd_gals(a: &Args) -> anyhow::Result<()> {
    let nb = a.get_usize("nb", 4);
    let rf = a.get_f64("rf", 2.0);
    let depth = a.get_usize("depth", 128) as u64;
    let cycles = a.get_usize("cycles", 10_000) as u64;
    let ratio = if (rf - 1.5).abs() < 1e-9 {
        Ratio::three_halves()
    } else {
        Ratio::new(rf.round() as u64, 1)
    };
    let mut cfg = if nb % 2 == 1 && (rf * 2.0).round() as usize == nb {
        StreamerConfig::fig7b(nb, depth)
    } else {
        StreamerConfig::fig7a(nb, depth, ratio)
    };
    if a.has_flag("static") {
        cfg.adaptive = false;
    }
    let r = StreamerSim::new(cfg).run(cycles);
    println!(
        "N_b={nb} R_F={rf} ({} compute cycles, {} memory cycles, {} wasted slots)",
        r.compute_cycles, r.memory_cycles, r.wasted_slots
    );
    for (i, s) in r.per_stream.iter().enumerate() {
        println!("  stream {i}: rate {:.3} words/cycle ({} stalls)", s.rate, s.stalls);
    }
    println!("  min rate {:.3} (>= 1.0 sustains full throughput)", r.min_rate());
    Ok(())
}

fn cmd_golden(a: &Args) -> anyhow::Result<()> {
    let arts = Path::new(a.get_or("artifacts", "artifacts"));
    let model = a.get_or("model", "all");
    runtime::check_mvau_unit(arts)?;
    println!("mvau_unit: golden OK");
    for m in ["cnv_w1a1", "cnv_w2a2", "rn50_lite_w1a2"] {
        if model != "all" && model != m {
            continue;
        }
        let eng = runtime::Engine::load(arts, m)?;
        eng.check_golden()?;
        println!("{m}: golden OK (batches {:?})", eng.batch_sizes());
    }
    Ok(())
}

/// Map a servable model name to its [`Network`] and the artifact name the
/// AOT exporter actually emits (`python/compile/aot.py`): only
/// artifact-backed models are accepted, and aliases (`rn50`, hyphen forms)
/// canonicalize so the `pjrt` backend never sees a name without artifacts.
fn serve_model(name: &str) -> Option<(Network, &'static str)> {
    match name {
        "cnv_w1a1" | "cnv-w1a1" => Some((cnv(CnvVariant::W1A1), "cnv_w1a1")),
        "cnv_w2a2" | "cnv-w2a2" => Some((cnv(CnvVariant::W2A2), "cnv_w2a2")),
        "rn50" | "rn50-w1" | "rn50_lite_w1a2" => Some((resnet50(1), "rn50_lite_w1a2")),
        _ => None,
    }
}

fn trace_by_name(name: &str, n: usize, rate: f64, seed: u64) -> anyhow::Result<Trace> {
    if let Some(path) = name.strip_prefix("file:") {
        return Trace::load(Path::new(path));
    }
    Ok(match name {
        "poisson" => poisson(n, rate, seed),
        "bursty" => bursty(n, rate, rate * 8.0, 32, seed),
        "heavy" | "heavy-tail" => heavy_tail(n, rate, 1.5, seed),
        // rate swings between rate/2 (trough) and 2*rate (peak), two
        // full day/night cycles over the trace
        "diurnal" => {
            let peak = rate * 2.0;
            let mean = (rate / 2.0 + peak) / 2.0;
            let period = n as f64 / mean / 2.0;
            diurnal(n, rate / 2.0, peak, period.max(1e-3), seed)
        }
        "uniform" => uniform(n, rate),
        other => {
            anyhow::bail!("unknown trace {other} (poisson|bursty|heavy|diurnal|uniform|file:PATH)")
        }
    })
}

fn cmd_serve(a: &Args) -> anyhow::Result<()> {
    let backend = a.get_or("backend", "mock");
    let replicas = a.get_usize("replicas", 1).max(1);
    let n = a.get_usize("requests", 256);
    let rate = a.get_f64("rate", 400.0); // offered requests/s
    let seed = a.get_usize("seed", 2020) as u64;
    let max_batch = a.get_usize("batch", 4);
    let queue_depth = a.get_usize("queue", 64);
    let trace_name = a.get_or("trace", "poisson");
    let (net, model) = serve_model(a.get_or("model", "cnv_w1a1")).ok_or_else(|| {
        anyhow::anyhow!("unknown model (cnv_w1a1|cnv_w2a2|rn50_lite_w1a2 or aliases)")
    })?;

    // heterogeneous fleet: replica i runs on the i-th of --devices (cycled)
    // at the paper's Table V operating point (--point paper) or at the
    // actually-packed design point (--point packed, cross-replica cached);
    // the analytic sim/timing model turns each point into the capacity
    // weight of the `weighted` policy
    let point = a.get_or("point", "paper");
    let dev_names: Vec<&str> = a.get_or("devices", "u250,u280,7020,7012s").split(',').collect();
    let mut specs = Vec::with_capacity(replicas);
    for i in 0..replicas {
        let name = dev_names[i % dev_names.len()];
        let dev = device::by_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown device {name} in --devices"))?;
        specs.push(match point {
            "paper" => ReplicaSpec::paper_point(dev),
            "packed" => ReplicaSpec::packed_point(
                &net,
                dev,
                a.get_usize("hb", 4),
                a.get_usize("generations", 40),
                seed,
            ),
            other => anyhow::bail!("unknown --point {other} (paper|packed)"),
        });
    }
    let weights = fleet_weights(&net, &specs);
    let policy = Policy::by_name(a.get_or("policy", "round-robin"), weights.clone())
        .ok_or_else(|| anyhow::anyhow!("unknown policy (round-robin|jsq|weighted)"))?;
    let policy_name = policy.name();

    let trace = trace_by_name(trace_name, n, rate, seed)?;
    if let Some(out) = a.get("trace-out") {
        trace.save(Path::new(out))?;
        println!("recorded trace ({} arrivals) to {out}", trace.len());
    }
    let cfg = ServerConfig {
        batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(2) },
        queue_depth,
        replicas,
        policy,
    };

    println!("fleet: {replicas} replicas, policy {policy_name}, trace {trace_name}");
    for (i, s) in specs.iter().enumerate() {
        println!(
            "  replica {i}: {} (R_F={:.1}, LUT {:.0}%) — analytic {:.0} FPS, weight {:.2}",
            s.device.name,
            s.rf,
            100.0 * s.lut_util,
            replica_fps(&net, s),
            weights[i]
        );
    }

    let (mut srv, fm) = match backend {
        "mock" => {
            // mock service time tracks the analytic capacity: replica i
            // serves one item in `--service-us / weight_i`, so the fleet's
            // heterogeneity is observable without hardware
            let service_us = a.get_f64("service-us", 400.0);
            let svc: Vec<Duration> = weights
                .iter()
                .map(|w| Duration::from_secs_f64(service_us * 1e-6 / w.max(1e-3)))
                .collect();
            let mut srv = Server::start(
                move |i| MockBackend::with_service(Duration::ZERO, svc[i]),
                cfg,
            );
            let fm = srv.replay(&trace, 8, seed);
            (srv, fm)
        }
        "pjrt" => {
            let arts = Path::new(a.get_or("artifacts", "artifacts")).to_path_buf();
            let probe = runtime::Engine::load(&arts, model)?;
            let per = probe.manifest.input_elements_per_sample() as usize;
            drop(probe);
            let mut srv = Server::start(
                move |_| runtime::Engine::load(&arts, model).expect("engine"),
                cfg,
            );
            let fm = srv.replay(&trace, per, seed);
            (srv, fm)
        }
        other => anyhow::bail!("unknown backend {other} (mock|pjrt)"),
    };
    srv.shutdown();
    println!(
        "serve [{model} x{replicas} {policy_name}/{trace_name}] offered {:.0} req/s:",
        trace.offered_rate()
    );
    println!("{}", fm.summary());
    Ok(())
}

/// `fcmp shard`: partition one network across a device fleet and validate
/// the staged pipeline (analytic plan, discrete-event sim, optionally the
/// stage-chain serving coordinator on calibrated mocks).
fn cmd_shard(a: &Args) -> anyhow::Result<()> {
    let net = network_by_name(a.get_or("network", "cnv-w2a2"))
        .ok_or_else(|| anyhow::anyhow!("unknown network"))?;
    let dev_names: Vec<&str> = a.get_or("devices", "7012s,7012s").split(',').collect();
    let shards = a.get_usize("shards", dev_names.len()).max(1);
    let mut devices = Vec::with_capacity(shards);
    for i in 0..shards {
        let name = dev_names[i % dev_names.len()];
        devices.push(
            device::by_name(name)
                .ok_or_else(|| anyhow::anyhow!("unknown device {name} in --devices"))?,
        );
    }
    let cfg = PartitionConfig {
        bin_height: a.get_usize("hb", 4),
        generations: if a.get_or("engine", "ga") == "ffd" {
            0
        } else {
            a.get_usize("generations", 40)
        },
        seed: a.get_usize("seed", 2020) as u64,
        link: LinkSpec {
            gbps: a.get_f64("link-gbps", 100.0),
            latency_us: a.get_f64("link-us", 2.0),
        },
    };

    // why shard at all? report single-device feasibility per distinct part
    let mut seen: Vec<&str> = Vec::new();
    for dev in &devices {
        if seen.contains(&dev.name) {
            continue;
        }
        seen.push(dev.name);
        let solo = sharding::Evaluator::new(&net, cfg).shard(0, net.stages.len(), dev);
        println!(
            "{} packed on one {}: {} of {} BRAM18, LUT {:.0}% -> {}",
            net.name,
            dev.name,
            solo.bram_demand,
            solo.bram_capacity,
            100.0 * solo.lut_util,
            if solo.fits() { "fits (sharding optional)" } else { "DOES NOT FIT" }
        );
    }

    let plan = sharding::partition(&net, &devices, cfg)?;
    println!(
        "\nplan: {} over {} shards, analytic bottleneck {:.1} us -> {:.0} FPS{}",
        plan.network,
        plan.shards.len(),
        plan.bottleneck_s * 1e6,
        plan.fps,
        if plan.bottleneck_is_link() { " (link-bound)" } else { "" }
    );
    for (j, s) in plan.shards.iter().enumerate() {
        let stages: Vec<&str> =
            net.stages[s.stages.0..s.stages.1].iter().map(|st| st.name()).collect();
        println!(
            "  shard {j} on {}: stages {}..{} [{}]",
            s.device.name,
            s.stages.0,
            s.stages.1,
            stages.join(", ")
        );
        println!(
            "    OCM {} of {} BRAM18 ({:.0}%, packed weights {}), LUT {:.0}%, \
             II {} cy @ {:.0} MHz -> {:.1} us/frame",
            s.bram_demand,
            s.bram_capacity,
            100.0 * s.bram_pressure(),
            s.packed_brams,
            100.0 * s.lut_util,
            s.ii_cycles,
            s.effective_mhz,
            s.seconds_per_frame * 1e6
        );
        if j < plan.links.len() {
            let l = &plan.links[j];
            println!(
                "    link {j}: {:.1} Kbit/frame, {:.2} us/frame, {:.0}% of bottleneck",
                l.bits_per_frame as f64 / 1e3,
                l.seconds_per_frame * 1e6,
                100.0 * plan.link_utilization()[j]
            );
        }
    }

    // the sharded sim needs a steady-state window; quietly clamp tiny values
    let frames = a.get_usize("frames", 400).max(8) as u64;
    let fifo = a.get_usize("fifo", 8) as u64;
    let r = sim::simulate_sharded(&net, &plan, frames, fifo);
    println!(
        "\nsim ({frames} frames, link FIFO {fifo}): {:.0} FPS = {:.2}% of analytic, \
         fill latency {:.1} us",
        r.fps,
        100.0 * r.vs_analytic,
        r.first_out_ns as f64 / 1e3
    );

    if a.has_flag("serve") {
        let requests = a.get_usize("requests", 256);
        let rate = a.get_f64("rate", plan.fps * 0.8);
        let svc = shard_service_times(&plan);
        let scfg = ServerConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) },
            queue_depth: fifo as usize,
            replicas: plan.shards.len(),
            policy: Policy::StageChain,
        };
        let mut srv = Server::start_chain(
            move |i| MockBackend::with_service(Duration::ZERO, svc[i]),
            scfg,
        );
        let trace = poisson(requests, rate, cfg_seed(a));
        let fm = srv.replay(&trace, 8, cfg_seed(a));
        srv.shutdown();
        println!(
            "\nchain serve [{} stages, {:.0} req/s offered]:",
            plan.shards.len(),
            trace.offered_rate()
        );
        println!("{}", fm.summary());
    }
    Ok(())
}

fn cfg_seed(a: &Args) -> u64 {
    a.get_usize("seed", 2020) as u64
}

fn cmd_floorplan(a: &Args) -> anyhow::Result<()> {
    let net = network_by_name(a.get_or("network", "rn50-w1"))
        .ok_or_else(|| anyhow::anyhow!("unknown network"))?;
    let dev = device::by_name(a.get_or("device", "u250"))
        .ok_or_else(|| anyhow::anyhow!("unknown device"))?;
    match device::floorplan(&net, &dev) {
        None => println!("{} does not floorplan onto {}", net.name, dev.name),
        Some(fp) => {
            println!(
                "{} on {}: {} SLR crossings, bottleneck BRAM {:.0}%, LUT {:.0}%",
                net.name,
                dev.name,
                fp.crossings,
                100.0 * fp.max_bram_pressure,
                100.0 * fp.max_lut_pressure
            );
            let demands = device::floorplan::stage_demands(&net);
            for slr in 0..dev.slrs.len() {
                let members: Vec<&str> = demands
                    .iter()
                    .zip(&fp.assignment)
                    .filter(|(_, &a)| a == slr)
                    .map(|(d, _)| d.name.as_str())
                    .collect();
                println!("  SLR{slr}: {}", members.join(", "));
            }
        }
    }
    Ok(())
}

fn cmd_dse(a: &Args) -> anyhow::Result<()> {
    let net = network_by_name(a.get_or("network", "cnv-w1a1"))
        .ok_or_else(|| anyhow::anyhow!("unknown network"))?;
    let dev = device::by_name(a.get_or("device", "7020"))
        .ok_or_else(|| anyhow::anyhow!("unknown device"))?;
    let budget = a.get_f64("budget", 0.85);
    let solved = folding::solve(&net, &dev, budget);
    let r = folding::network_resources(&solved, &dev);
    let e = sim::estimate(&solved, dev.nominal_compute_mhz);
    println!(
        "{} on {}: {:.0} FPS @ {} MHz | LUT {:.0}% BRAM {:.0}% | II {}",
        solved.name,
        dev.name,
        e.fps,
        dev.nominal_compute_mhz,
        r.lut_pct(&dev),
        r.bram_pct(&dev),
        e.ii_cycles
    );
    Ok(())
}

const USAGE: &str = "\
fcmp — Frequency Compensated Memory Packing (paper reproduction)
subcommands:
  pack    pack a network's weight buffers into BRAMs (FCMP, paper section IV;
          --islands N --threads T runs the parallel island-model GA)
  report  regenerate the paper's tables/figures
          (--table 1|2|4|5|fig2|fig4|shard|all)
  perf    analytic FPS/latency of an accelerator (--network, --mhz)
  gals    cycle-level GALS streamer simulation (--nb, --rf, --static)
  golden  verify PJRT runtime against python golden outputs
  serve   multi-replica sharded inference serving (--replicas N --policy
          round-robin|jsq|weighted --trace poisson|bursty|heavy|diurnal|
          file:PATH [--trace-out PATH] --backend mock|pjrt --point
          paper|packed); weighted capacity comes from the sim/timing model
          of each replica's --devices entry
  shard   pipeline-parallel multi-device sharding: partition one network
          over --devices a,b,... [--shards k] into contiguous stage shards
          (per-shard FCMP packing, --hb/--generations/--engine ga|ffd),
          model the cut links (--link-gbps/--link-us), simulate the staged
          pipeline (--frames/--fifo) and optionally serve it as a stage
          chain (--serve --requests N --rate R)
  dse     folding design-space exploration (--network, --device, --budget)
  floorplan  SLR floorplan of a network on a multi-die device (Fig. 5)";

fn main() {
    let args = Args::from_env();
    let r = match args.subcommand.as_deref() {
        Some("pack") => cmd_pack(&args),
        Some("report") => cmd_report(&args),
        Some("perf") => cmd_perf(&args),
        Some("gals") => cmd_gals(&args),
        Some("golden") => cmd_golden(&args),
        Some("serve") => cmd_serve(&args),
        Some("shard") => cmd_shard(&args),
        Some("dse") => cmd_dse(&args),
        Some("floorplan") => cmd_floorplan(&args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
