//! Live metrics exposition: periodic snapshots of the serving fleet
//! rendered two ways from one source —
//!
//! * **Prometheus text** (`--metrics-out PATH`): the whole current
//!   [`crate::coordinator::FleetSummary`] (+ the latest windowed
//!   [`crate::control::ControlSignals`], when a control plane runs) as
//!   `# HELP`/`# TYPE`/sample lines, rewritten atomically each interval
//!   like a node-exporter textfile. `ci/check_exposition.py` validates
//!   the grammar in CI.
//! * **JSONL** (`PATH.jsonl`): one appended object per emission, the
//!   machine-readable trajectory of the same snapshot for plotting.
//!
//! Emission is driven by whatever loop the driver already runs — the
//! trace-replay arrival loop in real time, the control tick in virtual
//! time — through [`Exposition::maybe_emit`] with the driver's own
//! clock, so the emitter works unchanged in both time domains.

use std::path::{Path, PathBuf};

use crate::control::ControlSignals;
use crate::coordinator::{FleetSummary, ServeSummary};

/// Render a fleet summary (+ optional control signals) as Prometheus
/// exposition text.
pub fn prometheus_text(s: &FleetSummary, signals: Option<&ControlSignals>) -> String {
    let mut out = String::with_capacity(2048);
    let mut counter = |name: &str, help: &str, v: f64| {
        out.push_str(&format!(
            "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"
        ));
    };
    counter("fcmp_submitted_total", "Requests accepted by admission control", s.submitted as f64);
    counter("fcmp_shed_total", "Requests shed by admission control", s.shed as f64);
    let completed = s.fleet.as_ref().map_or(0, |f| f.requests);
    counter("fcmp_completed_total", "Completions recorded", completed as f64);
    counter("fcmp_hot_submits_total", "Submit fast-path entries", s.hot.submits as f64);
    counter(
        "fcmp_hot_fallback_scans_total",
        "Submits that scanned fallback groups",
        s.hot.fallback_scans as f64,
    );
    counter("fcmp_pool_hits_total", "Request buffers served from the pool", s.hot.pool_hits as f64);
    counter(
        "fcmp_pool_misses_total",
        "Request buffers allocated cold (0 in steady state)",
        s.hot.pool_misses as f64,
    );

    let mut gauge = |out: &mut String, name: &str, help: &str, labels: &str, v: f64| {
        if v.is_finite() {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} gauge\n{name}{labels} {v}\n"
            ));
        }
    };
    if let Some(f) = &s.fleet {
        gauge(&mut out, "fcmp_throughput_fps", "Fleet throughput", "", f.throughput_fps);
        gauge(&mut out, "fcmp_mean_batch", "Mean ridden batch size", "", f.mean_batch);
        let mut q = String::new();
        for (p, v) in
            [("0.5", f.latency_ms.median), ("0.95", f.latency_ms.p95), ("0.99", f.latency_ms.p99)]
        {
            q.push_str(&format!("fcmp_latency_ms{{quantile=\"{p}\"}} {v}\n"));
        }
        out.push_str(&format!(
            "# HELP fcmp_latency_ms Fleet end-to-end latency quantiles\n# TYPE fcmp_latency_ms gauge\n{q}"
        ));
    }

    // per-group end-to-end views, labelled by router position
    let mut grows = String::new();
    let mut push_group = |g: usize, f: &ServeSummary| {
        grows.push_str(&format!("fcmp_group_requests{{group=\"{g}\"}} {}\n", f.requests));
        grows.push_str(&format!(
            "fcmp_group_p99_ms{{group=\"{g}\"}} {}\n",
            f.latency_ms.p99
        ));
    };
    for (g, f) in s.per_group.iter().enumerate() {
        if let Some(f) = f {
            push_group(g, f);
        }
    }
    if !grows.is_empty() {
        out.push_str(
            "# HELP fcmp_group_requests Completions per chain group\n# TYPE fcmp_group_requests gauge\n",
        );
        out.push_str(
            "# HELP fcmp_group_p99_ms Per-group end-to-end p99\n# TYPE fcmp_group_p99_ms gauge\n",
        );
        out.push_str(&grows);
    }

    if let Some(sig) = signals {
        gauge(&mut out, "fcmp_control_shed_rate", "Windowed shed rate", "", sig.shed_rate);
        gauge(
            &mut out,
            "fcmp_control_util_max",
            "Windowed max replica utilization",
            "",
            sig.max_utilization,
        );
        if let Some(p99) = sig.p99_ms {
            gauge(&mut out, "fcmp_control_p99_ms", "Windowed latency p99", "", p99);
        }
        gauge(&mut out, "fcmp_control_tick", "Last closed control tick", "", sig.tick as f64);
    }
    out
}

/// Render the same snapshot as one JSON object (a JSONL line).
pub fn json_snapshot(now_s: f64, s: &FleetSummary, signals: Option<&ControlSignals>) -> String {
    let (completed, fps, p50, p99) = match &s.fleet {
        Some(f) => (f.requests, f.throughput_fps, f.latency_ms.median, f.latency_ms.p99),
        None => (0, 0.0, 0.0, 0.0),
    };
    let mut out = format!(
        "{{\"t_s\":{:.6},\"submitted\":{},\"shed\":{},\"completed\":{},\"throughput_fps\":{:.3},\
         \"p50_ms\":{:.4},\"p99_ms\":{:.4},\"pool_misses\":{}",
        now_s, s.submitted, s.shed, completed, fps, p50, p99, s.hot.pool_misses
    );
    if let Some(sig) = signals {
        out.push_str(&format!(
            ",\"control\":{{\"tick\":{},\"shed_rate\":{:.6},\"util_max\":{:.6}",
            sig.tick, sig.shed_rate, sig.max_utilization
        ));
        match sig.p99_ms {
            Some(p) => out.push_str(&format!(",\"p99_ms\":{p:.4}}}")),
            None => out.push_str(",\"p99_ms\":null}"),
        }
    }
    out.push('}');
    out
}

/// Periodic snapshot emitter. `maybe_emit` is cheap when the interval
/// has not elapsed (one float compare), so drivers call it from their
/// existing loops without pacing logic of their own.
#[derive(Debug)]
pub struct Exposition {
    path: PathBuf,
    interval_s: f64,
    last_emit_s: Option<f64>,
    emits: usize,
}

impl Exposition {
    /// Emit to `path` (Prometheus text; JSONL goes to `path` + `.jsonl`)
    /// at most every `interval_s` driver-clock seconds.
    pub fn new(path: impl Into<PathBuf>, interval_s: f64) -> Exposition {
        Exposition {
            path: path.into(),
            interval_s: interval_s.max(0.0),
            last_emit_s: None,
            emits: 0,
        }
    }

    /// The Prometheus text path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Snapshots written so far.
    pub fn emits(&self) -> usize {
        self.emits
    }

    /// Whether a `maybe_emit` at `now_s` would write a snapshot — lets
    /// drivers skip building the (histogram-merging) summary entirely
    /// between intervals.
    pub fn due(&self, now_s: f64) -> bool {
        match self.last_emit_s {
            None => true,
            Some(last) => now_s - last >= self.interval_s,
        }
    }

    /// Emit if the interval has elapsed since the last emission (the
    /// first call always emits). Returns whether a snapshot was written.
    pub fn maybe_emit(
        &mut self,
        now_s: f64,
        s: &FleetSummary,
        signals: Option<&ControlSignals>,
    ) -> bool {
        if !self.due(now_s) {
            return false;
        }
        self.emit(now_s, s, signals);
        true
    }

    /// Unconditional emission (the final snapshot at shutdown).
    pub fn emit(&mut self, now_s: f64, s: &FleetSummary, signals: Option<&ControlSignals>) {
        self.last_emit_s = Some(now_s);
        self.emits += 1;
        // the .prom file is a rewrite (current state), the .jsonl an append
        // (trajectory); IO errors are reported once on stderr, not fatal —
        // observability must never take the serving path down
        if let Err(e) = std::fs::write(&self.path, prometheus_text(s, signals)) {
            eprintln!("metrics exposition: writing {}: {e}", self.path.display());
        }
        let jsonl = self.path.with_extension(format!(
            "{}jsonl",
            self.path
                .extension()
                .map(|e| format!("{}.", e.to_string_lossy()))
                .unwrap_or_default()
        ));
        let line = json_snapshot(now_s, s, signals) + "\n";
        let r = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&jsonl)
            .and_then(|mut f| std::io::Write::write_all(&mut f, line.as_bytes()));
        if let Err(e) = r {
            eprintln!("metrics exposition: appending {}: {e}", jsonl.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::FleetMetrics;
    use std::time::Duration;

    fn sample_summary() -> FleetSummary {
        let mut fm = FleetMetrics::new(&[2, 2]);
        fm.start();
        fm.record_submitted();
        fm.record_submitted();
        fm.record_shed();
        fm.record(&crate::coordinator::Completion {
            id: 0,
            output: vec![0.0],
            latency: Duration::from_millis(12),
            batch_size: 2,
            group: 0,
            stage: 1,
            stage_latencies: vec![Duration::from_millis(6), Duration::from_millis(6)],
            stage_batches: vec![2, 2],
            span: None,
        });
        fm.summary()
    }

    #[test]
    fn prometheus_text_has_required_families_and_parses_shape() {
        let text = prometheus_text(&sample_summary(), None);
        for name in [
            "fcmp_submitted_total",
            "fcmp_shed_total",
            "fcmp_completed_total",
            "fcmp_latency_ms{quantile=\"0.99\"}",
            "fcmp_group_p99_ms{group=\"0\"}",
            "fcmp_pool_misses_total",
        ] {
            assert!(text.contains(name), "missing {name} in:\n{text}");
        }
        // every non-comment line is `name[{labels}] value` with a finite value
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let (_, v) = line.rsplit_once(' ').expect("sample line shape");
            let v: f64 = v.parse().expect("numeric sample value");
            assert!(v.is_finite());
        }
    }

    #[test]
    fn exposition_paces_and_writes_both_files() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("fcmp-expose-{}.prom", std::process::id()));
        let jsonl = dir.join(format!("fcmp-expose-{}.prom.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&jsonl);
        let s = sample_summary();
        let mut e = Exposition::new(&path, 1.0);
        assert!(e.maybe_emit(0.0, &s, None), "first call must emit");
        assert!(!e.maybe_emit(0.5, &s, None), "inside the interval");
        assert!(e.maybe_emit(1.2, &s, None));
        assert_eq!(e.emits(), 2);
        let prom = std::fs::read_to_string(&path).unwrap();
        assert!(prom.contains("fcmp_submitted_total 2"));
        let lines = std::fs::read_to_string(&jsonl).unwrap();
        assert_eq!(lines.lines().count(), 2, "one JSONL line per emission");
        assert!(lines.lines().all(|l| l.starts_with("{\"t_s\":")));
        std::fs::remove_file(&path).unwrap();
        std::fs::remove_file(&jsonl).unwrap();
    }
}
