//! Request spans: a fixed-size, pooled per-request event timeline.
//!
//! A [`RequestSpan`] is a flat `Copy` struct — an id plus a bounded array
//! of [`SpanStamp`]s — so recording an event is two field writes, copying
//! a span into a flight-recorder ring is a memcpy, and the steady state
//! allocates nothing: spans recycle through a [`SpanPool`] primed at
//! deploy, exactly like the request payload buffers in
//! [`crate::coordinator::BufferPool`].
//!
//! Sampling is **head-based**: [`Sampler::decide`] hashes the request id
//! once at submit, so every stage of the pipeline (and the shed path)
//! agrees on whether a request is traced without coordination, and the
//! same seed reproduces the same sampled set — in the threaded server and
//! in the simulator.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Maximum stamps per span. A k-stage chain writes `2 + 4k` stamps
/// (submit, enqueue, then gather/dispatch/reap/link per stage, complete
/// replacing the last link); 32 covers chains up to 7 stages with room
/// to spare, and deeper chains saturate gracefully (extra stamps drop).
pub const MAX_EVENTS: usize = 32;

/// One lifecycle event of a request's journey through the fleet.
#[repr(u8)]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanEvent {
    /// Accepted by the submit path (sampling decided here).
    Submit = 0,
    /// Entered a chain group's stage-0 queue (router dispatch landed).
    Enqueue = 1,
    /// Pulled from a stage queue into a forming batch.
    Gather = 2,
    /// Batch handed to the backend (`submit_batch`).
    Dispatch = 3,
    /// Batch outputs reaped from the in-flight window.
    Reap = 4,
    /// Forwarded across the inter-stage link into the next stage's queue
    /// (stamped at the *sending* stage; backpressure shows up here).
    LinkHop = 5,
    /// Final-stage completion emitted.
    Complete = 6,
    /// Shed by admission control (terminal; no further stamps).
    Shed = 7,
}

impl SpanEvent {
    /// Stable lowercase name (the JSONL wire form).
    pub fn name(self) -> &'static str {
        match self {
            SpanEvent::Submit => "submit",
            SpanEvent::Enqueue => "enqueue",
            SpanEvent::Gather => "gather",
            SpanEvent::Dispatch => "dispatch",
            SpanEvent::Reap => "reap",
            SpanEvent::LinkHop => "link",
            SpanEvent::Complete => "complete",
            SpanEvent::Shed => "shed",
        }
    }

    /// Inverse of [`SpanEvent::name`].
    pub fn from_name(s: &str) -> Option<SpanEvent> {
        Some(match s {
            "submit" => SpanEvent::Submit,
            "enqueue" => SpanEvent::Enqueue,
            "gather" => SpanEvent::Gather,
            "dispatch" => SpanEvent::Dispatch,
            "reap" => SpanEvent::Reap,
            "link" => SpanEvent::LinkHop,
            "complete" => SpanEvent::Complete,
            "shed" => SpanEvent::Shed,
            _ => return None,
        })
    }

    /// Inverse of the `u8` discriminant (ring-buffer decode).
    pub fn from_u8(v: u8) -> Option<SpanEvent> {
        Some(match v {
            0 => SpanEvent::Submit,
            1 => SpanEvent::Enqueue,
            2 => SpanEvent::Gather,
            3 => SpanEvent::Dispatch,
            4 => SpanEvent::Reap,
            5 => SpanEvent::LinkHop,
            6 => SpanEvent::Complete,
            7 => SpanEvent::Shed,
            _ => return None,
        })
    }
}

/// One timestamped event: when, what, and where (group/stage).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanStamp {
    /// Nanoseconds on the driver's [`crate::obs::Clock`].
    pub t_ns: u64,
    /// Event kind.
    pub kind: SpanEvent,
    /// Chain group the event happened in (router index at event time).
    pub group: u16,
    /// Stage within the group (0 for submit/enqueue/shed).
    pub stage: u16,
}

const ZERO_STAMP: SpanStamp =
    SpanStamp { t_ns: 0, kind: SpanEvent::Submit, group: 0, stage: 0 };

/// The per-request event timeline. Fixed-size and `Copy` so it never
/// allocates after construction and memcpys into recorder rings.
#[derive(Clone, Copy, Debug)]
pub struct RequestSpan {
    /// The request id ([`crate::coordinator::Request::id`]).
    pub id: u64,
    len: u16,
    stamps: [SpanStamp; MAX_EVENTS],
}

impl RequestSpan {
    /// An empty span for request `id`.
    pub fn new(id: u64) -> RequestSpan {
        RequestSpan { id, len: 0, stamps: [ZERO_STAMP; MAX_EVENTS] }
    }

    /// Reset in place for reuse under a new request id (pool recycling).
    pub fn reset(&mut self, id: u64) {
        self.id = id;
        self.len = 0;
    }

    /// Append a stamp; silently drops past [`MAX_EVENTS`] (bounded by
    /// construction — a runaway chain cannot grow the span).
    pub fn push(&mut self, kind: SpanEvent, t_ns: u64, group: u16, stage: u16) {
        if (self.len as usize) < MAX_EVENTS {
            self.stamps[self.len as usize] = SpanStamp { t_ns, kind, group, stage };
            self.len += 1;
        }
    }

    /// The stamps recorded so far, in event order.
    pub fn stamps(&self) -> &[SpanStamp] {
        &self.stamps[..self.len as usize]
    }

    /// Whether the span reached a terminal event (complete or shed).
    pub fn is_terminal(&self) -> bool {
        matches!(
            self.stamps().last().map(|s| s.kind),
            Some(SpanEvent::Complete) | Some(SpanEvent::Shed)
        )
    }

    /// One JSONL line: `{"id":N,"ev":[["kind",t_ns,group,stage],...]}`.
    pub fn to_json(&self) -> String {
        let mut out = format!("{{\"id\":{},\"ev\":[", self.id);
        for (i, s) in self.stamps().iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "[\"{}\",{},{},{}]",
                s.kind.name(),
                s.t_ns,
                s.group,
                s.stage
            ));
        }
        out.push_str("]}");
        out
    }

    /// Parse a line written by [`RequestSpan::to_json`]. Returns `None`
    /// on anything else (flush markers, truncated tails, foreign lines) —
    /// trace readers skip those lines rather than failing the file.
    pub fn parse_json(line: &str) -> Option<RequestSpan> {
        let line = line.trim();
        let rest = line.strip_prefix("{\"id\":")?;
        let comma = rest.find(',')?;
        let id: u64 = rest[..comma].parse().ok()?;
        let rest = rest[comma..].strip_prefix(",\"ev\":[")?;
        let body = rest.strip_suffix("]}")?;
        let mut span = RequestSpan::new(id);
        if body.is_empty() {
            return Some(span);
        }
        for item in body.split("],") {
            let item = item.trim_start_matches('[').trim_end_matches(']');
            let mut parts = item.split(',');
            let kind = parts.next()?.trim_matches('"');
            let kind = SpanEvent::from_name(kind)?;
            let t_ns: u64 = parts.next()?.parse().ok()?;
            let group: u16 = parts.next()?.parse().ok()?;
            let stage: u16 = parts.next()?.parse().ok()?;
            if parts.next().is_some() {
                return None;
            }
            span.push(kind, t_ns, group, stage);
        }
        Some(span)
    }
}

/// Head-based sampling decision, derived deterministically from the
/// request id and a seed: `P(sampled) ≈ rate`, and the same `(rate,
/// seed)` samples the same id set in every driver.
#[derive(Clone, Copy, Debug)]
pub struct Sampler {
    threshold: u64,
    seed: u64,
}

/// `splitmix64` finalizer — uniform enough for a sampling hash.
fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

impl Sampler {
    /// A sampler keeping roughly `rate` of requests (clamped to [0, 1]).
    pub fn new(rate: f64, seed: u64) -> Sampler {
        let threshold = if rate >= 1.0 {
            u64::MAX
        } else if rate <= 0.0 {
            0
        } else {
            (rate * u64::MAX as f64) as u64
        };
        Sampler { threshold, seed }
    }

    /// Whether request `id` is traced.
    pub fn decide(&self, id: u64) -> bool {
        match self.threshold {
            u64::MAX => true,
            0 => false,
            t => mix(id ^ self.seed) < t,
        }
    }

    /// Whether any request can be sampled at all (tracing enabled).
    pub fn active(&self) -> bool {
        self.threshold > 0
    }
}

/// Recycles span boxes so the sampled path stops allocating once the
/// pool warms up (mirror of [`crate::coordinator::BufferPool`], but for
/// spans). `misses` counts cold allocations — zero after priming.
#[derive(Debug, Default)]
pub struct SpanPool {
    free: Mutex<Vec<Box<RequestSpan>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl SpanPool {
    /// An empty pool.
    pub fn new() -> SpanPool {
        SpanPool::default()
    }

    /// Pre-allocate `n` spans (call before the measured window).
    pub fn prime(&self, n: usize) {
        let mut free = self.free.lock().unwrap();
        while free.len() < n {
            free.push(Box::new(RequestSpan::new(0)));
        }
    }

    /// A reset span for request `id` — recycled when available,
    /// freshly allocated (and counted as a miss) otherwise.
    pub fn get(&self, id: u64) -> Box<RequestSpan> {
        let recycled = self.free.lock().unwrap().pop();
        match recycled {
            Some(mut b) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                b.reset(id);
                b
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Box::new(RequestSpan::new(id))
            }
        }
    }

    /// Return a span box for reuse.
    pub fn put(&self, span: Box<RequestSpan>) {
        self.free.lock().unwrap().push(span);
    }

    /// `(hits, misses)` counters.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_json_roundtrips() {
        let mut s = RequestSpan::new(42);
        s.push(SpanEvent::Submit, 100, 0, 0);
        s.push(SpanEvent::Enqueue, 150, 1, 0);
        s.push(SpanEvent::Gather, 300, 1, 0);
        s.push(SpanEvent::Complete, 900, 1, 0);
        let line = s.to_json();
        let back = RequestSpan::parse_json(&line).expect("parse back");
        assert_eq!(back.id, 42);
        assert_eq!(back.stamps(), s.stamps());
        assert!(back.is_terminal());
    }

    #[test]
    fn parse_rejects_foreign_lines() {
        assert!(RequestSpan::parse_json("{\"flush\":\"shutdown\"}").is_none());
        assert!(RequestSpan::parse_json("").is_none());
        assert!(RequestSpan::parse_json("{\"id\":7,\"ev\":[[\"bogus\",1,0,0]]}").is_none());
        // empty event list is a valid (submit-lost) span
        let empty = RequestSpan::parse_json("{\"id\":7,\"ev\":[]}").unwrap();
        assert_eq!(empty.stamps().len(), 0);
    }

    #[test]
    fn push_saturates_at_max_events() {
        let mut s = RequestSpan::new(1);
        for i in 0..(MAX_EVENTS + 10) {
            s.push(SpanEvent::Gather, i as u64, 0, 0);
        }
        assert_eq!(s.stamps().len(), MAX_EVENTS);
        assert_eq!(s.stamps().last().unwrap().t_ns, MAX_EVENTS as u64 - 1);
    }

    #[test]
    fn sampler_rate_is_roughly_respected_and_deterministic() {
        let s = Sampler::new(0.1, 99);
        let hits: Vec<u64> = (0..20_000).filter(|&i| s.decide(i)).collect();
        let frac = hits.len() as f64 / 20_000.0;
        assert!((0.07..0.13).contains(&frac), "sampled {frac}");
        // same (rate, seed) ⇒ identical set
        let s2 = Sampler::new(0.1, 99);
        let hits2: Vec<u64> = (0..20_000).filter(|&i| s2.decide(i)).collect();
        assert_eq!(hits, hits2);
        // a different seed samples a different set
        let s3 = Sampler::new(0.1, 100);
        let hits3: Vec<u64> = (0..20_000).filter(|&i| s3.decide(i)).collect();
        assert_ne!(hits, hits3);
    }

    #[test]
    fn sampler_edges() {
        let all = Sampler::new(1.0, 7);
        let none = Sampler::new(0.0, 7);
        assert!(all.active() && !none.active());
        for i in 0..100 {
            assert!(all.decide(i));
            assert!(!none.decide(i));
        }
    }

    #[test]
    fn span_pool_recycles_after_priming() {
        let p = SpanPool::new();
        p.prime(4);
        let a = p.get(1);
        assert_eq!(a.id, 1);
        p.put(a);
        for i in 0..8 {
            let b = p.get(i);
            p.put(b);
        }
        let (hits, misses) = p.stats();
        assert_eq!(misses, 0, "primed pool must never miss");
        assert_eq!(hits, 9);
    }
}
