//! The clock seam: one trait both time domains stamp spans through.
//!
//! The threaded [`crate::coordinator::Server`] measures real elapsed time
//! ([`MonotonicClock`], an `Instant` epoch), while the discrete-event
//! [`crate::sim::FleetSim`] advances a virtual nanosecond counter
//! ([`VirtualClock`], set by the event loop before every handler). Span
//! stamps read `now_ns()` through `Arc<dyn Clock>`, so the same
//! [`crate::obs::RequestSpan`] machinery produces comparable trace files
//! from either driver — the substrate of the server-vs-sim differential
//! span check.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Monotonic nanosecond source for span stamps. Implementations must be
/// cheap (called on the request hot path, though only for sampled
/// requests) and never go backwards within one driver.
pub trait Clock: Send + Sync {
    /// Nanoseconds since this clock's epoch.
    fn now_ns(&self) -> u64;
}

/// Real-time clock: nanoseconds since construction, via `Instant`.
#[derive(Debug)]
pub struct MonotonicClock {
    epoch: Instant,
}

impl MonotonicClock {
    /// A clock whose epoch is now.
    pub fn new() -> MonotonicClock {
        MonotonicClock { epoch: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        MonotonicClock::new()
    }
}

impl Clock for MonotonicClock {
    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }
}

/// Virtual-time clock for discrete-event drivers: holds whatever the
/// event loop last published with [`VirtualClock::set`]. Relaxed atomics
/// suffice — the simulator is single-threaded; the atomic only satisfies
/// the shared `&self` interface.
#[derive(Debug, Default)]
pub struct VirtualClock {
    now: AtomicU64,
}

impl VirtualClock {
    /// A virtual clock at t = 0.
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    /// Publish the current virtual time (call before handling each event).
    pub fn set(&self, now_ns: u64) {
        self.now.store(now_ns, Ordering::Relaxed);
    }
}

impl Clock for VirtualClock {
    fn now_ns(&self) -> u64 {
        self.now.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic_clock_advances() {
        let c = MonotonicClock::new();
        let a = c.now_ns();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let b = c.now_ns();
        assert!(b > a, "{b} must exceed {a}");
    }

    #[test]
    fn virtual_clock_holds_published_time() {
        let c = VirtualClock::new();
        assert_eq!(c.now_ns(), 0);
        c.set(1_234_567);
        assert_eq!(c.now_ns(), 1_234_567);
        // trait-object access reads the same value
        let dynref: &dyn Clock = &c;
        assert_eq!(dynref.now_ns(), 1_234_567);
    }
}
