//! Multi-window, multi-burn-rate SLO alerting over the downsampled
//! series of [`crate::obs::timeseries`] — the Google-SRE error-budget
//! construction, applied to the serving fleet's two user-facing SLOs:
//!
//! * **shed rate** — error = shed admissions, total = offered
//!   admissions, budget = the allowed shed fraction;
//! * **latency p99** — error = completions in intervals whose p99
//!   exceeded the budget ("late"), total = completions, budget = the
//!   allowed late fraction.
//!
//! The **burn rate** over a window is `(errors/total) / budget`: how
//! many times faster than allowed the error budget is being consumed.
//! Each severity pairs a **long** window (smooths noise, sets the
//! detection floor) with a **short** window (resets fast once the
//! breach ends); an alert fires only when *both* exceed the threshold,
//! and clears when the short window falls below `clear_frac ×`
//! threshold — the band between is hysteresis, holding state so a
//! signal oscillating on the threshold cannot flap.
//!
//! Alerts are edge-triggered: [`BurnAlerter::eval`] emits one
//! [`HealthAlert`] per transition (fired / cleared), never per tick.
//! The stream is what [`crate::obs::health`] joins against the
//! `ControlEvent` journal for incident attribution.

use super::timeseries::{Series, SeriesStore};

/// Which SLO a rule watches.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SloSignal {
    /// Admission-control shed fraction.
    ShedRate,
    /// End-to-end p99 latency budget.
    LatencyP99,
}

impl SloSignal {
    /// Stable journal name.
    pub fn name(self) -> &'static str {
        match self {
            SloSignal::ShedRate => "shed_rate",
            SloSignal::LatencyP99 => "latency_p99",
        }
    }

    /// Inverse of [`SloSignal::name`].
    pub fn from_name(s: &str) -> Option<SloSignal> {
        [SloSignal::ShedRate, SloSignal::LatencyP99].into_iter().find(|x| x.name() == s)
    }
}

/// Alert urgency tier, one per configured burn rule.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Fast-burn: budget gone in hours — wake someone.
    Page,
    /// Slow-burn: budget gone in days — file a ticket.
    Ticket,
}

impl Severity {
    /// Stable journal name.
    pub fn name(self) -> &'static str {
        match self {
            Severity::Page => "page",
            Severity::Ticket => "ticket",
        }
    }

    /// Inverse of [`Severity::name`].
    pub fn from_name(s: &str) -> Option<Severity> {
        [Severity::Page, Severity::Ticket].into_iter().find(|x| x.name() == s)
    }
}

/// One multiwindow burn rule: fire when the burn rate exceeds
/// `burn` over **both** windows.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BurnRule {
    /// Alert tier this rule feeds.
    pub severity: Severity,
    /// Long (detection) window, seconds.
    pub long_s: f64,
    /// Short (reset) window, seconds.
    pub short_s: f64,
    /// Burn-rate threshold (1.0 = exactly on budget).
    pub burn: f64,
}

impl BurnRule {
    /// The classic fast-burn page: 14.4× over 1 h and 5 m (2 % of a
    /// 30-day budget in one hour).
    pub fn page() -> BurnRule {
        BurnRule { severity: Severity::Page, long_s: 3600.0, short_s: 300.0, burn: 14.4 }
    }

    /// The slow-burn ticket: 6× over 6 h and 30 m.
    pub fn ticket() -> BurnRule {
        BurnRule { severity: Severity::Ticket, long_s: 21600.0, short_s: 1800.0, burn: 6.0 }
    }

    /// Both standard rules, with every window scaled by `scale` — the
    /// same multiwindow construction evaluated on a compressed horizon
    /// (short smokes and benches use `scale < 1`).
    pub fn standard(scale: f64) -> Vec<BurnRule> {
        let s = scale.max(1e-6);
        [BurnRule::page(), BurnRule::ticket()]
            .into_iter()
            .map(|r| BurnRule { long_s: r.long_s * s, short_s: r.short_s * s, ..r })
            .collect()
    }
}

/// One edge of an alert's lifecycle, as journaled.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HealthAlert {
    /// Driver-clock time of the transition, seconds.
    pub at_s: f64,
    /// Which SLO.
    pub signal: SloSignal,
    /// Which rule tier.
    pub severity: Severity,
    /// `true` = fired, `false` = cleared.
    pub firing: bool,
    /// Burn rate over the rule's long window at the transition.
    pub burn_long: f64,
    /// Burn rate over the rule's short window at the transition.
    pub burn_short: f64,
}

/// Evaluates one signal's burn rules against the store, holding per-rule
/// firing state across evaluations.
#[derive(Debug)]
pub struct BurnAlerter {
    signal: SloSignal,
    err: Series,
    total: Series,
    /// Error-budget fraction (e.g. 0.02 = 2 % of requests may be shed).
    budget: f64,
    rules: Vec<BurnRule>,
    firing: Vec<bool>,
    /// Clear when the short-window burn drops below `clear_frac × burn`;
    /// the band `[clear_frac·burn, burn)` is hysteresis.
    clear_frac: f64,
}

impl BurnAlerter {
    /// An alerter for `signal` reading `err`/`total` cells against
    /// `budget`, evaluating `rules`.
    pub fn new(
        signal: SloSignal,
        err: Series,
        total: Series,
        budget: f64,
        rules: Vec<BurnRule>,
    ) -> BurnAlerter {
        let n = rules.len();
        BurnAlerter {
            signal,
            err,
            total,
            budget: budget.max(1e-9),
            rules,
            firing: vec![false; n],
            clear_frac: 0.9,
        }
    }

    /// Burn rate of the trailing `span_s` window ending at `now_ns`:
    /// `(err_sum / total_sum) / budget`; 0 when the window saw no
    /// traffic (no traffic burns no budget).
    pub fn burn_over(&self, store: &SeriesStore, now_ns: u64, span_s: f64) -> f64 {
        let span_ns = (span_s * 1e9) as u64;
        let (err, _) = store.window(self.err, now_ns, span_ns);
        let (total, _) = store.window(self.total, now_ns, span_ns);
        if total <= 0.0 {
            return 0.0;
        }
        (err / total) / self.budget
    }

    /// Evaluate every rule at `now_ns`, appending one [`HealthAlert`]
    /// per state transition to `out`.
    pub fn eval(&mut self, store: &SeriesStore, now_ns: u64, out: &mut Vec<HealthAlert>) {
        let at_s = now_ns as f64 / 1e9;
        for (k, rule) in self.rules.iter().enumerate() {
            let burn_long = self.burn_over(store, now_ns, rule.long_s);
            let burn_short = self.burn_over(store, now_ns, rule.short_s);
            let next = if self.firing[k] {
                // hold through the hysteresis band; only a clean
                // short-window recovery clears
                burn_short >= self.clear_frac * rule.burn
            } else {
                burn_long >= rule.burn && burn_short >= rule.burn
            };
            if next != self.firing[k] {
                self.firing[k] = next;
                out.push(HealthAlert {
                    at_s,
                    signal: self.signal,
                    severity: rule.severity,
                    firing: next,
                    burn_long,
                    burn_short,
                });
            }
        }
    }

    /// Whether any rule of this alerter is currently firing.
    pub fn any_firing(&self) -> bool {
        self.firing.iter().any(|&f| f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::timeseries::SeriesConfig;

    const NS: u64 = 1_000_000_000;

    fn store() -> SeriesStore {
        SeriesStore::new(&SeriesConfig {
            resolutions: vec![(1.0, 4096)],
            persist_res_s: 1.0,
        })
    }

    fn shed_alerter(rules: Vec<BurnRule>) -> BurnAlerter {
        BurnAlerter::new(SloSignal::ShedRate, Series::Shed, Series::Offered, 0.02, rules)
    }

    /// Drive `secs` seconds of `rate` offered req/s shedding `frac`,
    /// evaluating each second; returns the emitted transitions.
    fn drive(
        st: &mut SeriesStore,
        al: &mut BurnAlerter,
        t0: &mut u64,
        secs: u64,
        frac: f64,
    ) -> Vec<HealthAlert> {
        let mut out = Vec::new();
        for _ in 0..secs {
            let t = *t0 * NS;
            st.record(Series::Offered, t, 100.0);
            st.record(Series::Shed, t, 100.0 * frac);
            al.eval(st, t, &mut out);
            *t0 += 1;
        }
        out
    }

    #[test]
    fn step_breach_trips_fast_window_then_recovery_clears() {
        let rules = vec![BurnRule {
            severity: Severity::Page,
            long_s: 60.0,
            short_s: 10.0,
            burn: 14.4,
        }];
        let (mut st, mut al, mut t) = (store(), shed_alerter(rules), 0u64);
        // healthy baseline: well under budget, nothing fires
        assert!(drive(&mut st, &mut al, &mut t, 120, 0.001).is_empty());
        // step to 50 % shed: burn = 25 ≫ 14.4 — must fire once the long
        // window's average crosses, and exactly once
        let fired = drive(&mut st, &mut al, &mut t, 120, 0.5);
        assert_eq!(fired.len(), 1, "{fired:?}");
        assert!(fired[0].firing);
        assert_eq!(fired[0].severity, Severity::Page);
        assert!(fired[0].burn_long >= 14.4 && fired[0].burn_short >= 14.4);
        // recovery: short window drains fast, alert clears exactly once
        let cleared = drive(&mut st, &mut al, &mut t, 60, 0.0);
        assert_eq!(cleared.len(), 1, "{cleared:?}");
        assert!(!cleared[0].firing);
        assert!(!al.any_firing());
    }

    #[test]
    fn slow_drift_trips_slow_window_only() {
        // 8× burn: above the ticket threshold (6) but below the page
        // threshold (14.4) — only the slow-burn rule may fire
        let rules = vec![
            BurnRule { severity: Severity::Page, long_s: 60.0, short_s: 10.0, burn: 14.4 },
            BurnRule { severity: Severity::Ticket, long_s: 120.0, short_s: 30.0, burn: 6.0 },
        ];
        let (mut st, mut al, mut t) = (store(), shed_alerter(rules), 0u64);
        let out = drive(&mut st, &mut al, &mut t, 600, 0.16); // burn 8.0
        let severities: Vec<_> = out.iter().map(|a| a.severity).collect();
        assert_eq!(severities, vec![Severity::Ticket], "{out:?}");
        assert!(out[0].firing);
    }

    #[test]
    fn no_flapping_inside_hysteresis_band() {
        let rules = vec![BurnRule {
            severity: Severity::Page,
            long_s: 30.0,
            short_s: 10.0,
            burn: 10.0,
        }];
        let (mut st, mut al, mut t) = (store(), shed_alerter(rules), 0u64);
        // fire cleanly at burn 25
        let fired = drive(&mut st, &mut al, &mut t, 60, 0.5);
        assert_eq!(fired.len(), 1);
        // oscillate inside the band [0.9·10, 10) · budget = shed frac
        // jittering around 19 % — held firing, zero transitions
        let mut out = Vec::new();
        for k in 0..120u64 {
            let frac = if k % 2 == 0 { 0.185 } else { 0.198 }; // burn 9.25 / 9.9
            out.extend(drive(&mut st, &mut al, &mut t, 1, frac));
        }
        assert!(out.is_empty(), "hysteresis must hold state: {out:?}");
        assert!(al.any_firing());
        // dropping below the clear fraction finally clears
        let cleared = drive(&mut st, &mut al, &mut t, 30, 0.05);
        assert_eq!(cleared.len(), 1);
        assert!(!cleared[0].firing);
    }

    #[test]
    fn no_traffic_burns_no_budget() {
        let rules = vec![BurnRule {
            severity: Severity::Page,
            long_s: 30.0,
            short_s: 10.0,
            burn: 10.0,
        }];
        let (mut st, mut al) = (store(), shed_alerter(rules));
        let mut out = Vec::new();
        for t in 0..60u64 {
            al.eval(&st, t * NS, &mut out); // nothing recorded at all
        }
        assert!(out.is_empty());
        assert_eq!(al.burn_over(&st, 60 * NS, 30.0), 0.0);
    }
}
