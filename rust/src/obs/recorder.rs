//! The flight recorder: lock-free per-worker span rings plus
//! anomaly-triggered JSONL flushes.
//!
//! Every worker thread owns one [`SpanRing`] it pushes terminal spans
//! into; the rings continuously hold the **last N** spans per worker, so
//! when something goes wrong — a p99 budget breach, a shed burst, a dead
//! worker — the recorder can dump the recent history that *led up to*
//! the anomaly, not just what happened after a logger was turned on.
//!
//! A ring slot is a block of `AtomicU64` words guarded by a per-slot
//! sequence counter (a seqlock built entirely from atomics, so it is
//! safe Rust with no locks on the writer path): the single-producer
//! worker bumps the sequence odd, writes the span words, bumps it even;
//! a concurrent snapshot re-checks the sequence and simply skips slots
//! that were mid-write. Readers never block writers, writers never wait.
//!
//! Flushes append to one JSONL file: a `{"flush":...}` marker line with
//! the trigger reason, then the snapshot spans. The same span can appear
//! in multiple flushes (rings are not drained); readers dedupe by id,
//! keeping the last occurrence ([`crate::obs::tracereport`]).

use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::span::{RequestSpan, SpanEvent, MAX_EVENTS};

/// `u64` words per encoded span: id, len, then `(t_ns, packed loc)` per
/// stamp.
const WORDS_PER_SPAN: usize = 2 + 2 * MAX_EVENTS;

/// Single-producer, concurrently-snapshotable bounded span ring.
#[derive(Debug)]
pub struct SpanRing {
    /// Per-slot seqlock counters (odd = write in progress).
    seqs: Vec<AtomicU64>,
    /// Slot payload words, `WORDS_PER_SPAN` per slot.
    words: Vec<AtomicU64>,
    /// Total pushes ever (monotone; slot = `head % cap`).
    head: AtomicU64,
    cap: usize,
}

impl SpanRing {
    /// A ring holding the last `cap` spans (clamped to at least 1).
    pub fn new(cap: usize) -> SpanRing {
        let cap = cap.max(1);
        SpanRing {
            seqs: (0..cap).map(|_| AtomicU64::new(0)).collect(),
            words: (0..cap * WORDS_PER_SPAN).map(|_| AtomicU64::new(0)).collect(),
            head: AtomicU64::new(0),
            cap,
        }
    }

    /// Push a span (overwrites the oldest slot once full).
    /// Allocation-free: encodes into pre-sized atomic words. Concurrent
    /// pushers claim distinct slots up front, so even the shared shed
    /// ring never interleaves two writers in one slot (they could only
    /// collide after lapping the whole ring mid-write, which the seqlock
    /// check catches on the reader side).
    pub fn push(&self, span: &RequestSpan) {
        let slot = (self.head.fetch_add(1, Ordering::Release) % self.cap as u64) as usize;
        let base = slot * WORDS_PER_SPAN;
        self.seqs[slot].fetch_add(1, Ordering::AcqRel); // odd: in progress
        let stamps = span.stamps();
        self.words[base].store(span.id, Ordering::Relaxed);
        self.words[base + 1].store(stamps.len() as u64, Ordering::Relaxed);
        for (i, s) in stamps.iter().enumerate() {
            self.words[base + 2 + 2 * i].store(s.t_ns, Ordering::Relaxed);
            let packed =
                (s.kind as u64) | ((s.group as u64) << 16) | ((s.stage as u64) << 32);
            self.words[base + 3 + 2 * i].store(packed, Ordering::Relaxed);
        }
        self.seqs[slot].fetch_add(1, Ordering::Release); // even: committed
    }

    /// Spans ever pushed (not capped at the ring size).
    pub fn pushed(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Copy out the most recent spans, oldest first. Slots mid-write (or
    /// torn by a concurrent overwrite) are skipped rather than returned
    /// corrupt.
    pub fn snapshot(&self) -> Vec<RequestSpan> {
        let head = self.head.load(Ordering::Acquire);
        let n = head.min(self.cap as u64);
        let mut out = Vec::with_capacity(n as usize);
        for k in (head - n)..head {
            let slot = (k % self.cap as u64) as usize;
            if let Some(span) = self.read_slot(slot) {
                out.push(span);
            }
        }
        out
    }

    fn read_slot(&self, slot: usize) -> Option<RequestSpan> {
        let before = self.seqs[slot].load(Ordering::Acquire);
        if before == 0 || before % 2 == 1 {
            return None; // never written, or write in progress
        }
        let base = slot * WORDS_PER_SPAN;
        let id = self.words[base].load(Ordering::Relaxed);
        let len = self.words[base + 1].load(Ordering::Relaxed) as usize;
        if len > MAX_EVENTS {
            return None;
        }
        let mut span = RequestSpan::new(id);
        for i in 0..len {
            let t_ns = self.words[base + 2 + 2 * i].load(Ordering::Relaxed);
            let packed = self.words[base + 3 + 2 * i].load(Ordering::Relaxed);
            let kind = SpanEvent::from_u8((packed & 0xff) as u8)?;
            span.push(kind, t_ns, (packed >> 16) as u16, (packed >> 32) as u16);
        }
        let after = self.seqs[slot].load(Ordering::Acquire);
        if after != before {
            return None; // torn by a concurrent overwrite
        }
        Some(span)
    }
}

/// When the recorder dumps its rings. Defaults disable every threshold
/// (flush only at shutdown); the control plane and the CLI tighten them.
#[derive(Clone, Copy, Debug)]
pub struct AnomalyConfig {
    /// Flush when an observed p99 exceeds this budget (ms).
    pub p99_budget_ms: f64,
    /// Flush when a signal window sheds at least this many requests.
    pub shed_burst: u64,
    /// Hard cap on anomaly-triggered flushes per recorder (the shutdown
    /// flush is always allowed) so a persistent breach cannot grow the
    /// trace file without bound.
    pub max_flushes: usize,
}

impl Default for AnomalyConfig {
    fn default() -> Self {
        AnomalyConfig { p99_budget_ms: f64::INFINITY, shed_burst: u64::MAX, max_flushes: 16 }
    }
}

/// The fleet-wide recorder: owns the per-worker rings, the anomaly
/// policy and the JSONL sink.
#[derive(Debug)]
pub struct FlightRecorder {
    rings: Mutex<Vec<Arc<SpanRing>>>,
    ring_cap: usize,
    out: Option<PathBuf>,
    anomaly: AnomalyConfig,
    flushes: AtomicUsize,
    /// Dead workers already accounted for (each new death triggers one
    /// flush, not one per observation).
    deaths_seen: AtomicUsize,
}

impl FlightRecorder {
    /// A recorder whose rings hold `ring_cap` spans each, flushing to
    /// `out` (`None` = rings only, nothing ever written).
    pub fn new(ring_cap: usize, out: Option<PathBuf>, anomaly: AnomalyConfig) -> FlightRecorder {
        FlightRecorder {
            rings: Mutex::new(Vec::new()),
            ring_cap: ring_cap.max(1),
            out,
            anomaly,
            flushes: AtomicUsize::new(0),
            deaths_seen: AtomicUsize::new(0),
        }
    }

    /// Allocate and register a fresh ring for one worker (called at
    /// spawn, off the hot path). Rings of retired workers stay
    /// registered so their final spans survive into later flushes.
    pub fn register(&self) -> Arc<SpanRing> {
        let ring = Arc::new(SpanRing::new(self.ring_cap));
        self.rings.lock().unwrap().push(ring.clone());
        ring
    }

    /// Where flushes go, if anywhere.
    pub fn out_path(&self) -> Option<&Path> {
        self.out.as_deref()
    }

    /// Anomaly-trigger evaluation: call with whatever the driver can
    /// observe (a control tick's signals, a replay loop's counters).
    /// `p99_ms` is the latest windowed p99, `shed_window` the sheds in
    /// that window, `dead_workers` the current
    /// [`crate::coordinator::Server::dead_groups`] count. Flushes at
    /// most once per call, and never past `max_flushes`.
    pub fn observe(&self, p99_ms: Option<f64>, shed_window: u64, dead_workers: usize) {
        let prev_deaths = self.deaths_seen.swap(dead_workers, Ordering::Relaxed);
        let reason = if dead_workers > prev_deaths {
            Some("worker-death")
        } else if shed_window >= self.anomaly.shed_burst {
            Some("shed-burst")
        } else if p99_ms.is_some_and(|p| p > self.anomaly.p99_budget_ms) {
            Some("p99-breach")
        } else {
            None
        };
        if let Some(reason) = reason {
            if self.flushes.load(Ordering::Relaxed) < self.anomaly.max_flushes {
                let _ = self.flush(reason);
            }
        }
    }

    /// Dump every ring's recent spans to the JSONL sink, preceded by a
    /// `{"flush":reason}` marker. Returns the number of spans written
    /// (0 with no sink). Terminal spans only — half-built spans still
    /// riding requests are not in any ring yet.
    pub fn flush(&self, reason: &str) -> std::io::Result<usize> {
        self.flushes.fetch_add(1, Ordering::Relaxed);
        let Some(path) = &self.out else { return Ok(0) };
        let spans = self.snapshot_all();
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        let mut buf = format!("{{\"flush\":{:?},\"spans\":{}}}\n", reason, spans.len());
        for s in &spans {
            buf.push_str(&s.to_json());
            buf.push('\n');
        }
        f.write_all(buf.as_bytes())?;
        Ok(spans.len())
    }

    /// Every ring's snapshot, concatenated in worker order.
    pub fn snapshot_all(&self) -> Vec<RequestSpan> {
        let rings = self.rings.lock().unwrap();
        rings.iter().flat_map(|r| r.snapshot()).collect()
    }

    /// Flushes performed so far (anomaly + explicit).
    pub fn flush_count(&self) -> usize {
        self.flushes.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(id: u64, t: u64) -> RequestSpan {
        let mut s = RequestSpan::new(id);
        s.push(SpanEvent::Submit, t, 0, 0);
        s.push(SpanEvent::Complete, t + 5, 0, 0);
        s
    }

    #[test]
    fn ring_wraparound_keeps_the_most_recent() {
        let r = SpanRing::new(4);
        for i in 0..10u64 {
            r.push(&span(i, i * 100));
        }
        let got = r.snapshot();
        let ids: Vec<u64> = got.iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![6, 7, 8, 9], "ring must keep the newest spans in order");
        assert_eq!(r.pushed(), 10);
        assert_eq!(got[0].stamps()[0].t_ns, 600);
    }

    #[test]
    fn ring_snapshot_under_concurrent_pushes_never_corrupts() {
        let r = Arc::new(SpanRing::new(8));
        let w = r.clone();
        let writer = std::thread::spawn(move || {
            for i in 0..50_000u64 {
                w.push(&span(i, i));
            }
        });
        let mut seen = 0usize;
        while seen < 200 {
            for s in r.snapshot() {
                // every decoded span must be internally consistent
                assert_eq!(s.stamps().len(), 2, "torn span leaked: {s:?}");
                assert_eq!(s.stamps()[0].t_ns, s.id);
                assert_eq!(s.stamps()[1].t_ns, s.id + 5);
                seen += 1;
            }
        }
        writer.join().unwrap();
    }

    #[test]
    fn recorder_flushes_to_jsonl_with_marker() {
        let path = std::env::temp_dir().join(format!("fcmp-rec-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let rec =
            FlightRecorder::new(16, Some(path.clone()), AnomalyConfig::default());
        let ring = rec.register();
        for i in 0..3 {
            ring.push(&span(i, i * 10));
        }
        let n = rec.flush("shutdown").unwrap();
        assert_eq!(n, 3);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("{\"flush\":\"shutdown\",\"spans\":3}"), "{text}");
        let parsed: Vec<_> =
            text.lines().filter_map(RequestSpan::parse_json).collect();
        assert_eq!(parsed.len(), 3);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn anomaly_triggers_and_flush_cap() {
        let rec = FlightRecorder::new(
            4,
            None,
            AnomalyConfig { p99_budget_ms: 10.0, shed_burst: 5, max_flushes: 2 },
        );
        rec.observe(Some(5.0), 0, 0); // healthy: no flush
        assert_eq!(rec.flush_count(), 0);
        rec.observe(Some(50.0), 0, 0); // p99 breach
        assert_eq!(rec.flush_count(), 1);
        rec.observe(None, 9, 0); // shed burst
        assert_eq!(rec.flush_count(), 2);
        rec.observe(Some(50.0), 9, 0); // capped
        assert_eq!(rec.flush_count(), 2);
    }

    #[test]
    fn each_worker_death_flushes_once() {
        let rec = FlightRecorder::new(4, None, AnomalyConfig::default());
        rec.observe(None, 0, 0);
        assert_eq!(rec.flush_count(), 0);
        rec.observe(None, 0, 1); // first death
        assert_eq!(rec.flush_count(), 1);
        rec.observe(None, 0, 1); // same death observed again: no re-flush
        assert_eq!(rec.flush_count(), 1);
        rec.observe(None, 0, 2); // a second death
        assert_eq!(rec.flush_count(), 2);
    }
}
