//! Fleet health: long-horizon collection, SLO burn alerting, and
//! incident attribution.
//!
//! [`HealthMonitor`] is the collection half: on the driver's snapshot
//! cadence (never per request) it diffs the cumulative fleet counters
//! and latency histogram into interval deltas, downsamples them into
//! the fixed-memory [`SeriesStore`], evaluates the burn-rate rules of
//! [`crate::obs::burn`], and streams closed cells + alert transitions
//! as a JSONL **health journal** (`--health-out`).
//!
//! [`correlate`] is the attribution half: it joins the journal's alert
//! stream against the journaled [`ControlEvent`] stream and answers,
//! per incident, the questions an operator asks after the fact — when
//! did the breach actually start (scanning the downsampled cells
//! backwards from the alert), how long until detection (TTD), did the
//! control plane respond and how long after the breach began (TTM),
//! and did the alert clear. `fcmp healthreport` renders the result;
//! week-long diurnal sweeps in the fleet simulator produce the inputs
//! in wall-clock seconds.
//!
//! Attribution anchors time-to-mitigation at **breach start**, not at
//! alert fire time: a healthy autoscaler often reacts to its own
//! windowed signals before the (deliberately conservative) burn alert
//! fires, and a mitigation that precedes detection is still a
//! mitigation.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use super::burn::{BurnAlerter, BurnRule, HealthAlert, Severity, SloSignal};
use super::timeseries::{CellRecord, Series, SeriesConfig, SeriesStore};
use crate::control::{ControlEvent, ControlEventKind};
use crate::util::bench::Table;
use crate::util::hist::LogHistogram;

/// Everything that parameterizes health collection.
#[derive(Clone, Debug)]
pub struct HealthConfig {
    /// Minimum driver-clock seconds between observations.
    pub sample_s: f64,
    /// Shed SLO: allowed shed fraction of offered admissions.
    pub shed_slo: f64,
    /// Latency SLO: allowed fraction of completions landing in
    /// intervals whose p99 exceeds the budget.
    pub latency_slo: f64,
    /// Interval-p99 budget, ms. Non-finite disables latency alerting
    /// (the p99 series is still collected).
    pub p99_budget_ms: f64,
    /// Scale factor applied to every burn-rule window — the same
    /// multiwindow construction on a compressed horizon for short runs.
    pub window_scale: f64,
    /// Downsampling ladder.
    pub series: SeriesConfig,
    /// JSONL journal path (`--health-out`); `None` keeps it in memory.
    pub out: Option<PathBuf>,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            sample_s: 1.0,
            shed_slo: 0.02,
            latency_slo: 0.05,
            p99_budget_ms: f64::INFINITY,
            window_scale: 1.0,
            series: SeriesConfig::default(),
            out: None,
        }
    }
}

/// The journaled trajectory of one run's health: config, closed cells,
/// alert transitions. Written as JSONL by [`HealthMonitor`], read back
/// by [`HealthJournal::load`] for `fcmp healthreport`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HealthJournal {
    /// Shed SLO the run alerted against.
    pub shed_slo: f64,
    /// Latency SLO fraction.
    pub latency_slo: f64,
    /// Interval-p99 budget, ms (infinite = latency alerting off).
    pub p99_budget_ms: f64,
    /// Closed downsampled cells, in close order.
    pub cells: Vec<CellRecord>,
    /// Alert transitions, in emit order.
    pub alerts: Vec<HealthAlert>,
}

/// Collects health series + evaluates burn alerts on the snapshot path.
#[derive(Debug)]
pub struct HealthMonitor {
    cfg: HealthConfig,
    store: SeriesStore,
    alerters: Vec<BurnAlerter>,
    last_obs_ns: Option<u64>,
    last_submitted: u64,
    last_shed: u64,
    last_completed: u64,
    last_hist: LogHistogram,
    journal: HealthJournal,
    wrote_header: bool,
    sink_err: bool,
}

impl HealthMonitor {
    /// Build the store and alerters; all ring memory is allocated here.
    pub fn new(cfg: HealthConfig) -> HealthMonitor {
        let store = SeriesStore::new(&cfg.series);
        let rules = BurnRule::standard(cfg.window_scale);
        let mut alerters = vec![BurnAlerter::new(
            SloSignal::ShedRate,
            Series::Shed,
            Series::Offered,
            cfg.shed_slo,
            rules.clone(),
        )];
        if cfg.p99_budget_ms.is_finite() {
            alerters.push(BurnAlerter::new(
                SloSignal::LatencyP99,
                Series::Late,
                Series::Completed,
                cfg.latency_slo,
                rules,
            ));
        }
        let journal = HealthJournal {
            shed_slo: cfg.shed_slo,
            latency_slo: cfg.latency_slo,
            p99_budget_ms: cfg.p99_budget_ms,
            ..HealthJournal::default()
        };
        HealthMonitor {
            cfg,
            store,
            alerters,
            last_obs_ns: None,
            last_submitted: 0,
            last_shed: 0,
            last_completed: 0,
            last_hist: LogHistogram::new(),
            journal,
            wrote_header: false,
            sink_err: false,
        }
    }

    /// Whether an [`HealthMonitor::observe`] at `now_ns` would record —
    /// lets drivers skip building the fleet histogram between samples.
    pub fn due(&self, now_ns: u64) -> bool {
        match self.last_obs_ns {
            None => true,
            Some(last) => now_ns.saturating_sub(last) >= (self.cfg.sample_s * 1e9) as u64,
        }
    }

    /// Feed one snapshot of the cumulative fleet counters (`submitted`,
    /// `shed`, `completed`) and the cumulative latency histogram.
    /// Interval deltas are derived here; sub-interval calls are no-ops.
    pub fn observe(
        &mut self,
        now_ns: u64,
        submitted: u64,
        shed: u64,
        completed: u64,
        hist: &LogHistogram,
    ) {
        if !self.due(now_ns) {
            return;
        }
        self.last_obs_ns = Some(now_ns);
        let d_sub = submitted.saturating_sub(self.last_submitted);
        let d_shed = shed.saturating_sub(self.last_shed);
        let d_comp = completed.saturating_sub(self.last_completed);
        (self.last_submitted, self.last_shed, self.last_completed) = (submitted, shed, completed);
        let interval = hist.diff(&self.last_hist);
        self.last_hist = hist.snapshot();

        self.store.record(Series::Offered, now_ns, (d_sub + d_shed) as f64);
        self.store.record(Series::Shed, now_ns, d_shed as f64);
        self.store.record(Series::Completed, now_ns, d_comp as f64);
        let mut late = 0u64;
        if interval.count() > 0 {
            let p99 = interval.percentile(99.0);
            self.store.record(Series::P99Ms, now_ns, p99);
            if p99 > self.cfg.p99_budget_ms {
                late = d_comp;
            }
        }
        self.store.record(Series::Late, now_ns, late as f64);

        let cells0 = self.journal.cells.len();
        self.store.take_closed(&mut self.journal.cells);
        let alerts0 = self.journal.alerts.len();
        for a in &mut self.alerters {
            a.eval(&self.store, now_ns, &mut self.journal.alerts);
        }
        self.stream(cells0, alerts0);
    }

    /// Flush still-open cells at end of run so the journal covers the
    /// whole horizon.
    pub fn finish(&mut self) {
        let cells0 = self.journal.cells.len();
        let mut tail = Vec::new();
        self.store.flush_open(&mut tail);
        self.journal.cells.append(&mut tail);
        self.stream(cells0, self.journal.alerts.len());
    }

    /// Alert transitions so far.
    pub fn alerts(&self) -> &[HealthAlert] {
        &self.journal.alerts
    }

    /// Whether any burn rule is currently firing.
    pub fn any_firing(&self) -> bool {
        self.alerters.iter().any(|a| a.any_firing())
    }

    /// The in-memory journal.
    pub fn journal(&self) -> &HealthJournal {
        &self.journal
    }

    /// Consume the monitor, yielding its journal.
    pub fn into_journal(self) -> HealthJournal {
        self.journal
    }

    /// Append the journal lines produced since the given offsets to the
    /// sink. IO errors are reported once on stderr and never fatal —
    /// health collection must not take the serving path down.
    fn stream(&mut self, cells0: usize, alerts0: usize) {
        let Some(path) = self.cfg.out.clone() else { return };
        let mut text = String::new();
        if !self.wrote_header {
            self.wrote_header = true;
            text.push_str(&header_line(&self.cfg));
            text.push('\n');
        }
        for c in &self.journal.cells[cells0..] {
            text.push_str(&cell_line(c));
            text.push('\n');
        }
        for a in &self.journal.alerts[alerts0..] {
            text.push_str(&alert_line(a));
            text.push('\n');
        }
        if text.is_empty() {
            return;
        }
        let r = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .and_then(|mut f| std::io::Write::write_all(&mut f, text.as_bytes()));
        if let Err(e) = r {
            if !self.sink_err {
                self.sink_err = true;
                eprintln!("health journal: appending {}: {e}", path.display());
            }
        }
    }
}

fn header_line(cfg: &HealthConfig) -> String {
    let budget = if cfg.p99_budget_ms.is_finite() {
        format!("{}", cfg.p99_budget_ms)
    } else {
        "null".to_string()
    };
    format!(
        "{{\"kind\":\"health\",\"version\":1,\"shed_slo\":{},\"latency_slo\":{},\
         \"p99_budget_ms\":{budget},\"sample_s\":{},\"window_scale\":{}}}",
        cfg.shed_slo, cfg.latency_slo, cfg.sample_s, cfg.window_scale
    )
}

fn cell_line(c: &CellRecord) -> String {
    format!(
        "{{\"kind\":\"cell\",\"series\":\"{}\",\"res_s\":{},\"t_s\":{},\"min\":{},\
         \"mean\":{},\"max\":{},\"count\":{},\"sum\":{}}}",
        c.series.name(),
        c.res_s,
        c.t_s,
        c.min,
        c.mean,
        c.max,
        c.count,
        c.sum
    )
}

fn alert_line(a: &HealthAlert) -> String {
    format!(
        "{{\"kind\":\"alert\",\"t_s\":{},\"signal\":\"{}\",\"severity\":\"{}\",\
         \"state\":\"{}\",\"burn_long\":{},\"burn_short\":{}}}",
        a.at_s,
        a.signal.name(),
        a.severity.name(),
        if a.firing { "firing" } else { "cleared" },
        a.burn_long,
        a.burn_short
    )
}

fn json_num(line: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\":");
    let i = line.find(&pat)? + pat.len();
    let rest = &line[i..];
    let end = rest.find([',', '}']).unwrap_or(rest.len());
    rest[..end].trim().parse().ok()
}

fn json_str<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":\"");
    let i = line.find(&pat)? + pat.len();
    let rest = &line[i..];
    Some(&rest[..rest.find('"')?])
}

impl HealthJournal {
    /// Parse a JSONL health journal back. Foreign lines are skipped;
    /// malformed cell/alert lines are errors.
    pub fn load(path: &Path) -> crate::Result<HealthJournal> {
        let text = std::fs::read_to_string(path)?;
        let mut j = HealthJournal { p99_budget_ms: f64::INFINITY, ..HealthJournal::default() };
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() {
                continue;
            }
            let bad = |what: &str| {
                anyhow::anyhow!("{}:{}: {what} in {line:?}", path.display(), ln + 1)
            };
            match json_str(line, "kind") {
                Some("health") => {
                    j.shed_slo = json_num(line, "shed_slo").ok_or_else(|| bad("missing shed_slo"))?;
                    j.latency_slo =
                        json_num(line, "latency_slo").ok_or_else(|| bad("missing latency_slo"))?;
                    j.p99_budget_ms = json_num(line, "p99_budget_ms").unwrap_or(f64::INFINITY);
                }
                Some("cell") => {
                    let series = json_str(line, "series")
                        .and_then(Series::from_name)
                        .ok_or_else(|| bad("unknown series"))?;
                    let f = |k: &str| json_num(line, k).ok_or_else(|| bad("missing cell field"));
                    j.cells.push(CellRecord {
                        series,
                        res_s: f("res_s")?,
                        t_s: f("t_s")?,
                        min: f("min")?,
                        mean: f("mean")?,
                        max: f("max")?,
                        count: f("count")? as u64,
                        sum: f("sum")?,
                    });
                }
                Some("alert") => {
                    let signal = json_str(line, "signal")
                        .and_then(SloSignal::from_name)
                        .ok_or_else(|| bad("unknown signal"))?;
                    let severity = json_str(line, "severity")
                        .and_then(Severity::from_name)
                        .ok_or_else(|| bad("unknown severity"))?;
                    let firing = match json_str(line, "state") {
                        Some("firing") => true,
                        Some("cleared") => false,
                        _ => return Err(bad("unknown alert state")),
                    };
                    let f = |k: &str| json_num(line, k).ok_or_else(|| bad("missing alert field"));
                    j.alerts.push(HealthAlert {
                        at_s: f("t_s")?,
                        signal,
                        severity,
                        firing,
                        burn_long: f("burn_long")?,
                        burn_short: f("burn_short")?,
                    });
                }
                _ => {} // foreign line (other exposition streams)
            }
        }
        Ok(j)
    }
}

/// One SLO-breach incident: an alert's fired→cleared lifetime joined
/// with the control plane's response.
#[derive(Clone, Debug, PartialEq)]
pub struct Incident {
    /// Which SLO breached.
    pub signal: SloSignal,
    /// Alert tier.
    pub severity: Severity,
    /// When the underlying series actually crossed the SLO (from the
    /// backwards cell scan), seconds.
    pub breach_start_s: f64,
    /// When the burn alert fired, seconds.
    pub fired_s: f64,
    /// When it cleared (`None` = still firing at end of journal).
    pub cleared_s: Option<f64>,
    /// Time to detection: `fired − breach_start`.
    pub ttd_s: f64,
    /// When the first mitigating [`ControlEvent`] landed, seconds.
    pub response_at_s: Option<f64>,
    /// What that event was, rendered (e.g. `scale-out 1->2`).
    pub response: Option<String>,
    /// Time to mitigation: `response − breach_start`.
    pub ttm_s: Option<f64>,
    /// Responded **and** the alert cleared.
    pub mitigated: bool,
}

/// Does `kind` plausibly mitigate a breach of `signal`? Scale-outs add
/// capacity (both SLOs); SLO retunes trade batch latency (latency only).
fn mitigates(signal: SloSignal, kind: &ControlEventKind) -> bool {
    match signal {
        SloSignal::ShedRate => matches!(kind, ControlEventKind::ScaleOut { .. }),
        SloSignal::LatencyP99 => {
            matches!(kind, ControlEventKind::ScaleOut { .. } | ControlEventKind::SloAdjust { .. })
        }
    }
}

fn render_kind(kind: &ControlEventKind) -> String {
    match kind {
        ControlEventKind::ScaleOut { from, to } => format!("scale-out {from}->{to}"),
        ControlEventKind::ScaleIn { from, to } => format!("scale-in {from}->{to}"),
        ControlEventKind::SloAdjust { group, stage, max_batch, .. } => {
            format!("slo-adjust g{group}/s{stage} b{max_batch}")
        }
        ControlEventKind::Failure { group, survivors } => {
            format!("failure g{group} ({survivors} left)")
        }
    }
}

/// Scan the journaled persist-resolution cells backwards from `fired_s`
/// for the start of the contiguous over-SLO run that tripped the alert.
/// Cells with no traffic neither extend nor break the run; if no cell
/// at or before `fired_s` breaches, the fire time itself is returned.
fn breach_start(j: &HealthJournal, signal: SloSignal, fired_s: f64) -> f64 {
    // key cells on the millisecond grid so err/total rows of the same
    // cell join exactly
    let ms = |t: f64| (t * 1e3).round() as i64;
    let mut by_t: BTreeMap<i64, (f64, f64, bool)> = BTreeMap::new(); // t -> (err, total, seen)
    for c in &j.cells {
        match signal {
            SloSignal::ShedRate => match c.series {
                Series::Shed => {
                    let e = by_t.entry(ms(c.t_s)).or_default();
                    e.0 += c.sum;
                    e.2 = true;
                }
                Series::Offered => {
                    let e = by_t.entry(ms(c.t_s)).or_default();
                    e.1 += c.sum;
                    e.2 = true;
                }
                _ => {}
            },
            SloSignal::LatencyP99 => {
                if c.series == Series::P99Ms && c.count > 0 {
                    // reuse (err, total) as (p99 mean, 1): breach when
                    // the cell's mean interval-p99 exceeds the budget
                    by_t.insert(ms(c.t_s), (c.mean, 1.0, true));
                }
            }
        }
    }
    let breaching = |err: f64, total: f64| match signal {
        SloSignal::ShedRate => total > 0.0 && err / total > j.shed_slo,
        SloSignal::LatencyP99 => err > j.p99_budget_ms,
    };
    let mut start = None;
    for (&t, &(err, total, _)) in by_t.range(..=ms(fired_s)).rev() {
        if total <= 0.0 {
            continue; // quiet cell: no evidence either way
        }
        if breaching(err, total) {
            start = Some(t as f64 / 1e3);
        } else if start.is_some() || t as f64 / 1e3 + 1e-9 < fired_s {
            break; // healthy cell ends the contiguous run
        }
    }
    start.unwrap_or(fired_s)
}

/// Join the journal's alert stream against the control-event journal
/// into the per-incident attribution table.
pub fn correlate(j: &HealthJournal, events: &[ControlEvent]) -> Vec<Incident> {
    let mut open: BTreeMap<(SloSignal, Severity), HealthAlert> = BTreeMap::new();
    let mut spans: Vec<(HealthAlert, Option<f64>)> = Vec::new();
    for a in &j.alerts {
        if a.firing {
            open.entry((a.signal, a.severity)).or_insert(*a);
        } else if let Some(fired) = open.remove(&(a.signal, a.severity)) {
            spans.push((fired, Some(a.at_s)));
        }
    }
    spans.extend(open.into_values().map(|a| (a, None)));
    spans.sort_by(|a, b| a.0.at_s.partial_cmp(&b.0.at_s).unwrap_or(std::cmp::Ordering::Equal));

    spans
        .into_iter()
        .map(|(fired, cleared_s)| {
            let bs = breach_start(j, fired.signal, fired.at_s);
            let horizon = cleared_s.unwrap_or(f64::INFINITY);
            let response = events
                .iter()
                .filter(|e| e.at_s + 1e-9 >= bs && e.at_s <= horizon)
                .find(|e| mitigates(fired.signal, &e.kind));
            Incident {
                signal: fired.signal,
                severity: fired.severity,
                breach_start_s: bs,
                fired_s: fired.at_s,
                cleared_s,
                ttd_s: fired.at_s - bs,
                response_at_s: response.map(|e| e.at_s),
                response: response.map(|e| render_kind(&e.kind)),
                ttm_s: response.map(|e| e.at_s - bs),
                mitigated: response.is_some() && cleared_s.is_some(),
            }
        })
        .collect()
}

/// Aggregate figures over an incident table.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HealthStats {
    /// Incidents (fired alerts) in the journal.
    pub incidents: usize,
    /// Incidents with a response that also cleared.
    pub mitigated: usize,
    /// Incidents with no attributable control-plane response.
    pub unresponded: usize,
    /// Mean time to detection, seconds.
    pub mean_ttd_s: f64,
    /// Mean time to mitigation over responded incidents, seconds.
    pub mean_ttm_s: f64,
}

/// Compute [`HealthStats`] from an incident table.
pub fn stats(incidents: &[Incident]) -> HealthStats {
    let mut s = HealthStats { incidents: incidents.len(), ..HealthStats::default() };
    let (mut ttm_sum, mut ttm_n) = (0.0, 0usize);
    let mut ttd_sum = 0.0;
    for i in incidents {
        ttd_sum += i.ttd_s;
        if i.mitigated {
            s.mitigated += 1;
        }
        match i.ttm_s {
            Some(t) => {
                ttm_sum += t;
                ttm_n += 1;
            }
            None => s.unresponded += 1,
        }
    }
    if s.incidents > 0 {
        s.mean_ttd_s = ttd_sum / s.incidents as f64;
    }
    if ttm_n > 0 {
        s.mean_ttm_s = ttm_sum / ttm_n as f64;
    }
    s
}

/// Render the incident table for `fcmp healthreport`.
pub fn table(incidents: &[Incident]) -> Table {
    let mut t = Table::new([
        "signal", "sev", "breach s", "fired s", "ttd s", "response", "resp s", "ttm s",
        "cleared s", "mitigated",
    ]);
    let opt = |v: Option<f64>| v.map_or("-".to_string(), |x| format!("{x:.1}"));
    for i in incidents {
        t.row([
            i.signal.name().to_string(),
            i.severity.name().to_string(),
            format!("{:.1}", i.breach_start_s),
            format!("{:.1}", i.fired_s),
            format!("{:.1}", i.ttd_s),
            i.response.clone().unwrap_or_else(|| "none".to_string()),
            opt(i.response_at_s),
            opt(i.ttm_s),
            opt(i.cleared_s),
            if i.mitigated { "yes" } else { "NO" }.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::SignalCtx;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("fcmp-health-{tag}-{}.jsonl", std::process::id()))
    }

    fn fast_cfg(out: Option<PathBuf>) -> HealthConfig {
        HealthConfig {
            sample_s: 1.0,
            shed_slo: 0.02,
            p99_budget_ms: 50.0,
            window_scale: 0.01, // page 36 s / 3 s, ticket 216 s / 18 s
            series: SeriesConfig {
                resolutions: vec![(1.0, 600), (10.0, 600)],
                persist_res_s: 10.0,
            },
            out,
            ..HealthConfig::default()
        }
    }

    /// Drive a synthetic breach through a monitor: healthy, overloaded
    /// (40 % shed), healthy again.
    fn drive_breach(mon: &mut HealthMonitor) {
        let (mut sub, mut shed) = (0u64, 0u64);
        let mut hist = LogHistogram::new();
        for t in 0..240u64 {
            let shedding = (60..120).contains(&t);
            sub += if shedding { 60 } else { 100 };
            shed += if shedding { 40 } else { 0 };
            for _ in 0..5 {
                hist.record(if shedding { 80.0 } else { 5.0 });
            }
            mon.observe(t * 1_000_000_000, sub, shed, sub, &hist);
        }
        mon.finish();
    }

    #[test]
    fn monitor_journals_cells_and_alert_lifecycle() {
        let mut mon = HealthMonitor::new(fast_cfg(None));
        drive_breach(&mut mon);
        let j = mon.journal();
        assert!(!j.cells.is_empty());
        // shed page must fire during the breach and clear after it
        let shed_edges: Vec<bool> = j
            .alerts
            .iter()
            .filter(|a| a.signal == SloSignal::ShedRate && a.severity == Severity::Page)
            .map(|a| a.firing)
            .collect();
        assert_eq!(shed_edges, vec![true, false], "{:?}", j.alerts);
        // latency page too: interval p99 jumps to ~80 ms against a 50 ms
        // budget, making every completion in the breach "late"
        assert!(j
            .alerts
            .iter()
            .any(|a| a.signal == SloSignal::LatencyP99 && a.firing));
    }

    #[test]
    fn journal_round_trips_through_jsonl() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        let mut mon = HealthMonitor::new(fast_cfg(Some(path.clone())));
        drive_breach(&mut mon);
        let mem = mon.into_journal();
        let loaded = HealthJournal::load(&path).unwrap();
        assert_eq!(loaded, mem, "disk journal must equal the in-memory one");
        std::fs::remove_file(&path).unwrap();
    }

    fn ev(at_s: f64, kind: ControlEventKind) -> ControlEvent {
        ControlEvent { tick: 0, at_s, kind, ctx: SignalCtx::default() }
    }

    #[test]
    fn correlate_attributes_and_flags_unmitigated() {
        let mut mon = HealthMonitor::new(fast_cfg(None));
        drive_breach(&mut mon);
        let j = mon.into_journal();

        // with a scale-out inside the breach: mitigated, TTM from breach start
        let events = vec![
            ev(30.0, ControlEventKind::ScaleIn { from: 2, to: 1 }), // pre-breach, wrong kind
            ev(75.0, ControlEventKind::ScaleOut { from: 1, to: 2 }),
        ];
        let incidents = correlate(&j, &events);
        assert!(!incidents.is_empty());
        let shed = incidents.iter().find(|i| i.signal == SloSignal::ShedRate).unwrap();
        assert!(shed.mitigated, "{shed:?}");
        assert_eq!(shed.response_at_s, Some(75.0));
        assert!(shed.breach_start_s >= 50.0 && shed.breach_start_s <= 75.0, "{shed:?}");
        let ttm = shed.ttm_s.unwrap();
        assert!((ttm - (75.0 - shed.breach_start_s)).abs() < 1e-9);
        assert!(shed.ttd_s >= 0.0);
        let st = stats(&incidents);
        assert_eq!(st.incidents, incidents.len());
        assert!(st.mitigated >= 1);

        // with no events at all: every incident is unmitigated
        let none = correlate(&j, &[]);
        assert!(none.iter().all(|i| !i.mitigated && i.response.is_none()));
        assert_eq!(stats(&none).unresponded, none.len());

        // rendering holds both outcomes
        let text = table(&incidents).render();
        assert!(text.contains("scale-out 1->2"), "{text}");
    }

    #[test]
    fn correlation_is_deterministic() {
        let run = || {
            let mut mon = HealthMonitor::new(fast_cfg(None));
            drive_breach(&mut mon);
            let j = mon.into_journal();
            let events = vec![ev(70.0, ControlEventKind::ScaleOut { from: 1, to: 2 })];
            (correlate(&j, &events), j)
        };
        let (a, ja) = run();
        let (b, jb) = run();
        assert_eq!(ja, jb);
        assert_eq!(a, b);
    }
}
