//! Observability layer: span tracing, the flight recorder, and live
//! metrics exposition — one seam that holds in real time (the threaded
//! [`crate::coordinator::Server`]) and in virtual time (the
//! discrete-event [`crate::sim::FleetSim`]).
//!
//! ```text
//!   submit ──sampled?──> RequestSpan (pooled, fixed-size)
//!      │ enqueue          │ rides Request through the chain
//!      v                  v
//!   stage worker: gather → dispatch → reap → link-hop ...
//!      │                                        │
//!      v  complete / shed (terminal)            v
//!   per-worker SpanRing (lock-free, last N) ──flush──> JSONL trace
//!                         ^
//!        anomaly triggers: p99 budget breach, shed burst, worker death
//! ```
//!
//! Module map: [`clock`] (the real/virtual time seam), [`span`]
//! (pooled spans + head-based sampling), [`recorder`] (seqlock rings +
//! anomaly flushes), [`expose`] (Prometheus-text / JSONL snapshot
//! emission), [`tracereport`] (trace file → critical-path breakdown) —
//! plus the long-horizon fleet-health layer: [`timeseries`]
//! (fixed-memory multi-resolution downsampling store), [`burn`]
//! (multiwindow SLO burn-rate alerting) and [`health`] (collection,
//! the JSONL health journal, and alert↔`ControlEvent` incident
//! attribution for `fcmp healthreport`).
//!
//! The hot-path contract: with tracing off, the cost is one branch per
//! stamp site; with tracing on, only sampled requests touch the span
//! pool, and the pool + rings are pre-sized, so the asserted
//! zero-allocation steady state of the serving path still holds
//! (`pool_misses == 0` with tracing at 1 % is part of the test suite).

pub mod burn;
pub mod clock;
pub mod expose;
pub mod health;
pub mod recorder;
pub mod span;
pub mod timeseries;
pub mod tracereport;

pub use burn::{BurnAlerter, BurnRule, HealthAlert, Severity, SloSignal};
pub use clock::{Clock, MonotonicClock, VirtualClock};
pub use expose::Exposition;
pub use health::{HealthConfig, HealthJournal, HealthMonitor, Incident};
pub use recorder::{AnomalyConfig, FlightRecorder, SpanRing};
pub use span::{RequestSpan, Sampler, SpanEvent, SpanPool, SpanStamp, MAX_EVENTS};
pub use timeseries::{CellRecord, Series, SeriesConfig, SeriesStore};

use std::path::PathBuf;
use std::sync::Arc;

/// Tracing configuration a driver hands to [`Obs::new`].
#[derive(Clone, Debug)]
pub struct ObsConfig {
    /// Head-based sampling probability in [0, 1]; 0 disables tracing.
    pub sample: f64,
    /// Sampling seed: the same seed samples the same request ids in
    /// every driver (the differential-check property).
    pub seed: u64,
    /// Spans each per-worker ring retains.
    pub ring: usize,
    /// JSONL trace sink; `None` keeps spans in the rings only.
    pub trace_out: Option<PathBuf>,
    /// When to flush the rings before shutdown.
    pub anomaly: AnomalyConfig,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            sample: 0.0,
            seed: 0x5eed,
            ring: 256,
            trace_out: None,
            anomaly: AnomalyConfig::default(),
        }
    }
}

impl ObsConfig {
    /// Convenience: trace `sample` of requests to `path`.
    pub fn sampled(sample: f64, path: impl Into<PathBuf>) -> ObsConfig {
        ObsConfig { sample, trace_out: Some(path.into()), ..ObsConfig::default() }
    }
}

/// The per-driver observability hub: clock, sampler, span pool and
/// recorder. Cheap to share (`Arc`) and a no-op when `sample == 0`.
pub struct Obs {
    clock: Arc<dyn Clock>,
    sampler: Sampler,
    pool: SpanPool,
    recorder: Arc<FlightRecorder>,
    /// Terminal ring for spans shed at admission (they never reach a
    /// worker ring). Multi-producer: cloned submit handles share it.
    shed_ring: Arc<SpanRing>,
}

impl Obs {
    /// A hub stamping through `clock`. Primes the span pool to the ring
    /// size so steady-state sampling allocates nothing.
    pub fn new(cfg: &ObsConfig, clock: Arc<dyn Clock>) -> Arc<Obs> {
        let recorder =
            Arc::new(FlightRecorder::new(cfg.ring, cfg.trace_out.clone(), cfg.anomaly));
        let shed_ring = recorder.register();
        let pool = SpanPool::new();
        if cfg.sample > 0.0 {
            pool.prime(cfg.ring.max(64));
        }
        Arc::new(Obs {
            clock,
            sampler: Sampler::new(cfg.sample, cfg.seed),
            pool,
            recorder,
            shed_ring,
        })
    }

    /// A disabled hub (samples nothing, records nothing) on a real
    /// clock; the default for drivers without tracing flags.
    pub fn disabled() -> Arc<Obs> {
        Obs::new(&ObsConfig::default(), Arc::new(MonotonicClock::new()))
    }

    /// Whether any request can be sampled.
    pub fn active(&self) -> bool {
        self.sampler.active()
    }

    /// Current time on this driver's clock.
    pub fn now_ns(&self) -> u64 {
        self.clock.now_ns()
    }

    /// The recorder (for flushes and anomaly observation).
    pub fn recorder(&self) -> &Arc<FlightRecorder> {
        &self.recorder
    }

    /// `(pool hits, pool misses)` of the span pool.
    pub fn span_pool_stats(&self) -> (u64, u64) {
        self.pool.stats()
    }

    /// Head-based sampling decision for request `id`: a Submit-stamped
    /// span from the pool when sampled, `None` otherwise.
    pub fn sample(&self, id: u64) -> Option<Box<RequestSpan>> {
        if !self.sampler.decide(id) {
            return None;
        }
        let mut span = self.pool.get(id);
        span.push(SpanEvent::Submit, self.clock.now_ns(), 0, 0);
        Some(span)
    }

    /// Stamp an event on a maybe-absent span (the universal stamp site:
    /// one branch when the request is unsampled).
    pub fn stamp(
        &self,
        span: &mut Option<Box<RequestSpan>>,
        kind: SpanEvent,
        group: u16,
        stage: u16,
    ) {
        if let Some(s) = span.as_deref_mut() {
            s.push(kind, self.clock.now_ns(), group, stage);
        }
    }

    /// Terminal shed: stamp, record in the shed ring, recycle the box.
    pub fn shed(&self, span: Option<Box<RequestSpan>>, group: u16) {
        if let Some(mut s) = span {
            s.push(SpanEvent::Shed, self.clock.now_ns(), group, 0);
            self.shed_ring.push(&s);
            self.pool.put(s);
        }
    }

    /// Terminal completion: stamp Complete and record in `ring`. The
    /// span box stays with the caller (it rides the
    /// [`crate::coordinator::Completion`] out) — recycle it with
    /// [`Obs::recycle`] once the completion is consumed.
    pub fn complete(
        &self,
        span: &mut Option<Box<RequestSpan>>,
        ring: &SpanRing,
        group: u16,
        stage: u16,
    ) {
        if let Some(s) = span.as_deref_mut() {
            s.push(SpanEvent::Complete, self.clock.now_ns(), group, stage);
            ring.push(s);
        }
    }

    /// Return a consumed span box to the pool.
    pub fn recycle(&self, span: Option<Box<RequestSpan>>) {
        if let Some(s) = span {
            self.pool.put(s);
        }
    }
}

impl std::fmt::Debug for Obs {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Obs").field("sampler", &self.sampler).finish_non_exhaustive()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_hub_samples_nothing() {
        let obs = Obs::disabled();
        assert!(!obs.active());
        for i in 0..50 {
            assert!(obs.sample(i).is_none());
        }
    }

    #[test]
    fn full_lifecycle_lands_in_ring_and_recycles() {
        let cfg = ObsConfig { sample: 1.0, ..ObsConfig::default() };
        let obs = Obs::new(&cfg, Arc::new(MonotonicClock::new()));
        let ring = obs.recorder().register();
        let mut span = obs.sample(9);
        assert!(span.is_some());
        obs.stamp(&mut span, SpanEvent::Enqueue, 1, 0);
        obs.stamp(&mut span, SpanEvent::Gather, 1, 0);
        obs.complete(&mut span, &ring, 1, 0);
        let got = ring.snapshot();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 9);
        assert!(got[0].is_terminal());
        obs.recycle(span);
        let (_, misses_before) = obs.span_pool_stats();
        let again = obs.sample(9);
        let (_, misses_after) = obs.span_pool_stats();
        assert_eq!(misses_before, misses_after, "recycled span must be reused");
        obs.recycle(again);
    }

    #[test]
    fn shed_spans_reach_the_shed_ring() {
        let cfg = ObsConfig { sample: 1.0, ..ObsConfig::default() };
        let obs = Obs::new(&cfg, Arc::new(MonotonicClock::new()));
        let span = obs.sample(3);
        obs.shed(span, 2);
        let all = obs.recorder().snapshot_all();
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].stamps().last().unwrap().kind, SpanEvent::Shed);
        assert_eq!(all[0].stamps().last().unwrap().group, 2);
    }
}
