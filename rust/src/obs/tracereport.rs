//! Critical-path breakdown of a span trace file: where did each
//! request's latency actually go — stage-queue wait, batch-gather wait,
//! backend compute, or inter-stage link (backpressure) — per chain group
//! and stage. This is the serving-side analogue of the paper's per-layer
//! II/occupancy analysis: the `fcmp tracereport` subcommand renders it
//! as a table, and the server-vs-sim differential test compares the
//! per-stage totals across time domains.
//!
//! Segment semantics per traversed stage, from the span's stamps:
//!
//! ```text
//!   queue   = Gather   − (Enqueue | previous LinkHop)   stage-queue wait
//!   gather  = Dispatch − Gather                         batch-formation wait
//!   compute = Reap     − Dispatch                       backend execution
//!   link    = LinkHop  − Reap                           forward backpressure
//! ```

use std::collections::BTreeMap;
use std::io::BufRead;
use std::path::Path;

use super::span::{RequestSpan, SpanEvent};
use crate::util::bench::Table;

/// Accumulated segment times for one (group, stage) cell.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageBreakdown {
    /// Spans that traversed this cell.
    pub n: u64,
    /// Total stage-queue wait, ns.
    pub queue_ns: u64,
    /// Total batch-gather wait, ns.
    pub gather_ns: u64,
    /// Total backend compute, ns.
    pub compute_ns: u64,
    /// Total link/backpressure wait, ns (0 at terminal stages).
    pub link_ns: u64,
}

impl StageBreakdown {
    /// Everything accounted to this cell, ns.
    pub fn total_ns(&self) -> u64 {
        self.queue_ns + self.gather_ns + self.compute_ns + self.link_ns
    }
}

/// The analyzed trace: per-(group, stage) breakdowns plus file-level
/// counts.
#[derive(Clone, Debug, Default)]
pub struct TraceReport {
    /// Breakdown cells keyed by (group, stage), in order.
    pub stages: BTreeMap<(u16, u16), StageBreakdown>,
    /// Distinct spans analyzed (completed requests).
    pub completed: usize,
    /// Distinct shed spans.
    pub shed: usize,
}

impl TraceReport {
    /// Sum of a segment across every cell, ns.
    pub fn segment_total_ns(&self, seg: SpanEvent) -> u64 {
        self.stages
            .values()
            .map(|b| match seg {
                SpanEvent::Enqueue => b.queue_ns,
                SpanEvent::Gather => b.gather_ns,
                SpanEvent::Dispatch => b.compute_ns,
                SpanEvent::LinkHop => b.link_ns,
                _ => 0,
            })
            .sum()
    }
}

/// Load a JSONL trace file, skipping flush markers and foreign lines,
/// deduping spans by request id (flushes can repeat a span; the **last**
/// occurrence wins — it is the most complete).
pub fn load(path: &Path) -> std::io::Result<Vec<RequestSpan>> {
    let f = std::fs::File::open(path)?;
    let mut by_id: BTreeMap<u64, RequestSpan> = BTreeMap::new();
    for line in std::io::BufReader::new(f).lines() {
        let line = line?;
        if let Some(span) = RequestSpan::parse_json(&line) {
            by_id.insert(span.id, span);
        }
    }
    Ok(by_id.into_values().collect())
}

/// Analyze spans into the per-(group, stage) critical-path breakdown.
pub fn analyze(spans: &[RequestSpan]) -> TraceReport {
    let mut rep = TraceReport::default();
    for span in spans {
        let stamps = span.stamps();
        if stamps.last().map(|s| s.kind) == Some(SpanEvent::Shed) {
            rep.shed += 1;
            continue;
        }
        let mut arrive: Option<u64> = None; // entered current stage queue
        let mut gather: Option<u64> = None;
        let mut dispatch: Option<u64> = None;
        let mut reap: Option<u64> = None;
        let mut terminal = false;
        let mut close = |cell: (u16, u16),
                         arrive: &mut Option<u64>,
                         gather: &mut Option<u64>,
                         dispatch: &mut Option<u64>,
                         reap: &mut Option<u64>,
                         link_end: Option<u64>,
                         rep: &mut TraceReport| {
            let b = rep.stages.entry(cell).or_default();
            b.n += 1;
            if let (Some(a), Some(g)) = (*arrive, *gather) {
                b.queue_ns += g.saturating_sub(a);
            }
            if let (Some(g), Some(d)) = (*gather, *dispatch) {
                b.gather_ns += d.saturating_sub(g);
            }
            if let (Some(d), Some(r)) = (*dispatch, *reap) {
                b.compute_ns += r.saturating_sub(d);
            }
            if let (Some(r), Some(l)) = (*reap, link_end) {
                b.link_ns += l.saturating_sub(r);
            }
            *arrive = link_end;
            *gather = None;
            *dispatch = None;
            *reap = None;
        };
        for s in stamps {
            match s.kind {
                SpanEvent::Submit => {}
                SpanEvent::Enqueue => arrive = Some(s.t_ns),
                SpanEvent::Gather => gather = Some(s.t_ns),
                SpanEvent::Dispatch => dispatch = Some(s.t_ns),
                SpanEvent::Reap => reap = Some(s.t_ns),
                SpanEvent::LinkHop => close(
                    (s.group, s.stage),
                    &mut arrive,
                    &mut gather,
                    &mut dispatch,
                    &mut reap,
                    Some(s.t_ns),
                    &mut rep,
                ),
                SpanEvent::Complete => {
                    close(
                        (s.group, s.stage),
                        &mut arrive,
                        &mut gather,
                        &mut dispatch,
                        &mut reap,
                        None,
                        &mut rep,
                    );
                    terminal = true;
                }
                SpanEvent::Shed => {}
            }
        }
        if terminal {
            rep.completed += 1;
        }
    }
    rep
}

/// Render the breakdown as the `fcmp tracereport` table (per-cell means
/// in ms plus a fleet totals row).
pub fn table(rep: &TraceReport) -> Table {
    let mut t = Table::new([
        "group", "stage", "spans", "queue ms", "gather ms", "compute ms", "link ms", "total ms",
    ]);
    let ms = |ns: u64, n: u64| {
        if n == 0 {
            0.0
        } else {
            ns as f64 / n as f64 / 1e6
        }
    };
    let mut fleet = StageBreakdown::default();
    for ((g, s), b) in &rep.stages {
        fleet.n += b.n;
        fleet.queue_ns += b.queue_ns;
        fleet.gather_ns += b.gather_ns;
        fleet.compute_ns += b.compute_ns;
        fleet.link_ns += b.link_ns;
        t.row([
            format!("{g}"),
            format!("{s}"),
            format!("{}", b.n),
            format!("{:.3}", ms(b.queue_ns, b.n)),
            format!("{:.3}", ms(b.gather_ns, b.n)),
            format!("{:.3}", ms(b.compute_ns, b.n)),
            format!("{:.3}", ms(b.link_ns, b.n)),
            format!("{:.3}", ms(b.total_ns(), b.n)),
        ]);
    }
    t.row([
        "all".to_string(),
        "-".to_string(),
        format!("{}", fleet.n),
        format!("{:.3}", ms(fleet.queue_ns, fleet.n)),
        format!("{:.3}", ms(fleet.gather_ns, fleet.n)),
        format!("{:.3}", ms(fleet.compute_ns, fleet.n)),
        format!("{:.3}", ms(fleet.link_ns, fleet.n)),
        format!("{:.3}", ms(fleet.total_ns(), fleet.n)),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain_span(id: u64, base: u64) -> RequestSpan {
        let mut s = RequestSpan::new(id);
        s.push(SpanEvent::Submit, base, 0, 0);
        s.push(SpanEvent::Enqueue, base + 10, 0, 0);
        s.push(SpanEvent::Gather, base + 110, 0, 0); // queue 100
        s.push(SpanEvent::Dispatch, base + 160, 0, 0); // gather 50
        s.push(SpanEvent::Reap, base + 460, 0, 0); // compute 300
        s.push(SpanEvent::LinkHop, base + 480, 0, 0); // link 20
        s.push(SpanEvent::Gather, base + 530, 0, 1); // queue 50
        s.push(SpanEvent::Dispatch, base + 550, 0, 1); // gather 20
        s.push(SpanEvent::Reap, base + 950, 0, 1); // compute 400
        s.push(SpanEvent::Complete, base + 960, 0, 1);
        s
    }

    #[test]
    fn analyze_splits_chain_segments_per_stage() {
        let spans = vec![chain_span(1, 0), chain_span(2, 1000)];
        let rep = analyze(&spans);
        assert_eq!(rep.completed, 2);
        assert_eq!(rep.shed, 0);
        let s0 = rep.stages[&(0, 0)];
        assert_eq!(s0.n, 2);
        assert_eq!(s0.queue_ns, 200);
        assert_eq!(s0.gather_ns, 100);
        assert_eq!(s0.compute_ns, 600);
        assert_eq!(s0.link_ns, 40);
        let s1 = rep.stages[&(0, 1)];
        assert_eq!(s1.queue_ns, 100);
        assert_eq!(s1.compute_ns, 800);
        assert_eq!(s1.link_ns, 0, "terminal stage has no link segment");
        assert_eq!(rep.segment_total_ns(SpanEvent::Dispatch), 1400);
    }

    #[test]
    fn analyze_counts_sheds_separately() {
        let mut shed = RequestSpan::new(9);
        shed.push(SpanEvent::Submit, 0, 0, 0);
        shed.push(SpanEvent::Shed, 5, 1, 0);
        let rep = analyze(&[shed, chain_span(1, 0)]);
        assert_eq!(rep.shed, 1);
        assert_eq!(rep.completed, 1);
    }

    #[test]
    fn load_dedupes_by_id_and_skips_markers() {
        let path =
            std::env::temp_dir().join(format!("fcmp-trrep-{}.jsonl", std::process::id()));
        let partial = {
            let mut s = RequestSpan::new(1);
            s.push(SpanEvent::Submit, 0, 0, 0);
            s
        };
        let full = chain_span(1, 0);
        let text = format!(
            "{}\n{}\n{}\n{}\n",
            "{\"flush\":\"p99-breach\",\"spans\":1}",
            partial.to_json(),
            "{\"flush\":\"shutdown\",\"spans\":2}",
            full.to_json()
        );
        std::fs::write(&path, text).unwrap();
        let spans = load(&path).unwrap();
        assert_eq!(spans.len(), 1, "duplicate ids must collapse");
        assert_eq!(spans[0].stamps().len(), full.stamps().len(), "last occurrence wins");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn table_renders_per_cell_and_totals_rows() {
        let rep = analyze(&[chain_span(1, 0)]);
        let text = table(&rep).render();
        assert!(text.contains("| all"), "{text}");
        assert_eq!(text.lines().count(), 2 + 2 + 1, "{text}"); // header + sep + 2 cells + all
    }
}
