//! Fixed-memory multi-resolution time-series store — the RRD-style
//! long-horizon memory behind fleet-health alerting.
//!
//! Every health signal (offered/shed/completed counts, interval p99)
//! is downsampled into pre-allocated rings of aggregate cells, one ring
//! per (series, resolution). The default ladder keeps **1 s cells for
//! an hour, 1 m cells for a day, 1 h cells for two weeks** — enough to
//! evaluate both the fast (minutes) and slow (hours) burn-rate windows
//! of [`crate::obs::burn`] over a 168-hour diurnal sweep without the
//! store ever growing: memory is fixed at construction and recording a
//! sample is a handful of array writes, no allocation.
//!
//! Cells hold `min/max/sum/count`, so a window query returns exact
//! sums/counts (what burn rates need) and the journal rows carry the
//! min/mean/max envelope (what the health report's breach scan needs).
//! Cells at one **persist resolution** (1 m by default) are streamed
//! out as they close — the JSONL journal `--health-out` writes and
//! `ci/check_exposition.py` validates.
//!
//! The store is fed from the same snapshot path as
//! [`crate::obs::Exposition`], in whichever time domain the driver
//! runs: timestamps are plain `t_ns` from the [`crate::obs::Clock`]
//! seam, so the server's monotonic nanoseconds and the simulator's
//! virtual nanoseconds downsample identically.

/// The health series tracked by a [`SeriesStore`]. Counts are recorded
/// as per-interval deltas (so cell sums are true totals over the cell);
/// `P99Ms` is a gauge sampled from the interval histogram diff.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Series {
    /// Admission attempts (accepted + shed) in the interval.
    Offered,
    /// Requests shed by admission control in the interval.
    Shed,
    /// Completions in the interval.
    Completed,
    /// Completions that landed in an interval whose p99 exceeded the
    /// latency budget — the error count of the latency SLO.
    Late,
    /// Interval end-to-end p99, milliseconds (gauge).
    P99Ms,
}

impl Series {
    /// Every series, in journal order.
    pub const ALL: [Series; 5] =
        [Series::Offered, Series::Shed, Series::Completed, Series::Late, Series::P99Ms];

    /// Stable journal name.
    pub fn name(self) -> &'static str {
        match self {
            Series::Offered => "offered",
            Series::Shed => "shed",
            Series::Completed => "completed",
            Series::Late => "late",
            Series::P99Ms => "p99_ms",
        }
    }

    /// Inverse of [`Series::name`].
    pub fn from_name(s: &str) -> Option<Series> {
        Series::ALL.into_iter().find(|x| x.name() == s)
    }

    fn index(self) -> usize {
        self as usize
    }
}

/// One downsampled aggregate cell as it appears in the health journal.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CellRecord {
    /// Which signal the cell belongs to.
    pub series: Series,
    /// Cell width, seconds.
    pub res_s: f64,
    /// Cell start (aligned to `res_s`), seconds.
    pub t_s: f64,
    /// Smallest sample in the cell.
    pub min: f64,
    /// Mean of the cell's samples.
    pub mean: f64,
    /// Largest sample in the cell.
    pub max: f64,
    /// Samples aggregated into the cell.
    pub count: u64,
    /// Sum of the cell's samples (what count-series window math uses).
    pub sum: f64,
}

/// The resolution ladder: `(cell width seconds, ring capacity in cells)`
/// from finest to coarsest, plus which rung streams closed cells to the
/// journal.
#[derive(Clone, Debug)]
pub struct SeriesConfig {
    /// Resolutions, finest first. Width must be strictly increasing.
    pub resolutions: Vec<(f64, usize)>,
    /// Cell width (seconds) of the rung whose closed cells are
    /// journaled. Must match one of `resolutions`.
    pub persist_res_s: f64,
}

impl Default for SeriesConfig {
    fn default() -> Self {
        SeriesConfig {
            // 1 s × 1 h, 1 m × 1 day, 1 h × 2 weeks
            resolutions: vec![(1.0, 3600), (60.0, 1440), (3600.0, 336)],
            persist_res_s: 60.0,
        }
    }
}

/// In-place aggregate for one open cell.
#[derive(Clone, Copy, Debug)]
struct Slot {
    /// Absolute cell index (`t_ns / width_ns`); `u64::MAX` = empty.
    idx: u64,
    min: f64,
    max: f64,
    sum: f64,
    count: u64,
}

impl Slot {
    const EMPTY: Slot = Slot { idx: u64::MAX, min: 0.0, max: 0.0, sum: 0.0, count: 0 };
}

/// One fixed ring of cells at a single resolution.
#[derive(Clone, Debug)]
struct Ring {
    width_ns: u64,
    slots: Vec<Slot>,
    /// Highest cell index written so far (`u64::MAX` before any write).
    head: u64,
}

impl Ring {
    fn new(width_s: f64, cap: usize) -> Ring {
        Ring {
            width_ns: (width_s * 1e9).round().max(1.0) as u64,
            slots: vec![Slot::EMPTY; cap.max(1)],
            head: u64::MAX,
        }
    }

    fn slot_of(&self, idx: u64) -> usize {
        (idx % self.slots.len() as u64) as usize
    }

    /// Record a sample; when the head cell advances, return the cell it
    /// closed (the caller journals it at the persist rung only).
    fn record(&mut self, t_ns: u64, v: f64) -> Option<(u64, Slot)> {
        let idx = t_ns / self.width_ns;
        let mut closed = None;
        if self.head == u64::MAX || idx > self.head {
            if self.head != u64::MAX {
                let old = self.slots[self.slot_of(self.head)];
                if old.idx == self.head && old.count > 0 {
                    closed = Some((self.head, old));
                }
            }
            self.head = idx;
            self.slots[self.slot_of(idx)] = Slot::EMPTY;
        } else if idx < self.head {
            // time went backwards past the open cell: fold into an older
            // cell if it is still resident, else drop (never reorder)
            let s = self.slots[self.slot_of(idx)];
            if s.idx != idx {
                return None;
            }
        }
        let at = self.slot_of(idx);
        let s = &mut self.slots[at];
        if s.idx != idx {
            *s = Slot { idx, min: v, max: v, sum: v, count: 1 };
        } else {
            s.min = s.min.min(v);
            s.max = s.max.max(v);
            s.sum += v;
            s.count += 1;
        }
        closed
    }

    /// The still-open head cell, if any.
    fn open(&self) -> Option<(u64, Slot)> {
        if self.head == u64::MAX {
            return None;
        }
        let s = self.slots[self.slot_of(self.head)];
        (s.idx == self.head && s.count > 0).then_some((self.head, s))
    }

    /// Sum/count over cells intersecting `[now_ns - span_ns, now_ns]`.
    fn window(&self, now_ns: u64, span_ns: u64) -> (f64, u64) {
        if self.head == u64::MAX {
            return (0.0, 0);
        }
        let last = now_ns / self.width_ns;
        let first = now_ns.saturating_sub(span_ns) / self.width_ns;
        // clamp to what the ring can still hold
        let first = first.max(last.saturating_sub(self.slots.len() as u64 - 1));
        let (mut sum, mut count) = (0.0, 0u64);
        for idx in first..=last {
            let s = self.slots[self.slot_of(idx)];
            if s.idx == idx {
                sum += s.sum;
                count += s.count;
            }
        }
        (sum, count)
    }
}

/// The fixed-memory store: one [`Ring`] per (series, resolution).
#[derive(Debug)]
pub struct SeriesStore {
    widths_ns: Vec<u64>,
    persist_rung: usize,
    rings: Vec<Vec<Ring>>, // [series][resolution]
    closed: Vec<CellRecord>,
}

impl SeriesStore {
    /// Build the rings; this is the only allocation the store makes.
    pub fn new(cfg: &SeriesConfig) -> SeriesStore {
        assert!(!cfg.resolutions.is_empty(), "at least one resolution");
        let persist_rung = cfg
            .resolutions
            .iter()
            .position(|&(w, _)| (w - cfg.persist_res_s).abs() < 1e-9)
            .expect("persist_res_s must name a configured resolution");
        let widths_ns =
            cfg.resolutions.iter().map(|&(w, _)| (w * 1e9).round().max(1.0) as u64).collect();
        let rings = Series::ALL
            .iter()
            .map(|_| cfg.resolutions.iter().map(|&(w, cap)| Ring::new(w, cap)).collect())
            .collect();
        SeriesStore { widths_ns, persist_rung, rings, closed: Vec::new() }
    }

    /// Total pre-allocated cell slots (fixed for the store's lifetime).
    pub fn capacity_cells(&self) -> usize {
        self.rings.iter().flatten().map(|r| r.slots.len()).sum()
    }

    /// Record one sample into every resolution rung of `series`. Closed
    /// persist-rung cells are buffered for [`SeriesStore::take_closed`].
    pub fn record(&mut self, series: Series, t_ns: u64, v: f64) {
        let si = series.index();
        for (rung, ring) in self.rings[si].iter_mut().enumerate() {
            let closed = ring.record(t_ns, v);
            if rung == self.persist_rung {
                if let Some((idx, s)) = closed {
                    self.closed.push(cell_record(series, ring.width_ns, idx, s));
                }
            }
        }
    }

    /// `(sum, count)` of `series` over the trailing `span_ns` window
    /// ending at `now_ns`, read from the coarsest rung that still gives
    /// ≥ 32 cells of detail (falling back to the finest). The current
    /// partial cell is included — burn rates must see the breach as it
    /// happens, not one cell late.
    pub fn window(&self, series: Series, now_ns: u64, span_ns: u64) -> (f64, u64) {
        let mut rung = 0;
        for (i, &w) in self.widths_ns.iter().enumerate() {
            if span_ns / w >= 32 {
                rung = i;
            }
        }
        self.rings[series.index()][rung].window(now_ns, span_ns)
    }

    /// Drain closed persist-rung cells (journal order: close time, then
    /// series) into `out`.
    pub fn take_closed(&mut self, out: &mut Vec<CellRecord>) {
        out.append(&mut self.closed);
    }

    /// Flush the still-open persist-rung cells at end of run so the last
    /// partial minute of a sweep is journaled too.
    pub fn flush_open(&mut self, out: &mut Vec<CellRecord>) {
        out.append(&mut self.closed);
        let mut last: Vec<CellRecord> = Vec::new();
        for (si, rings) in self.rings.iter().enumerate() {
            let ring = &rings[self.persist_rung];
            if let Some((idx, s)) = ring.open() {
                last.push(cell_record(Series::ALL[si], ring.width_ns, idx, s));
            }
        }
        last.sort_by(|a, b| a.series.cmp(&b.series));
        out.append(&mut last);
    }
}

fn cell_record(series: Series, width_ns: u64, idx: u64, s: Slot) -> CellRecord {
    CellRecord {
        series,
        res_s: width_ns as f64 / 1e9,
        t_s: (idx * width_ns) as f64 / 1e9,
        min: s.min,
        mean: if s.count == 0 { 0.0 } else { s.sum / s.count as f64 },
        max: s.max,
        count: s.count,
        sum: s.sum,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const NS: u64 = 1_000_000_000;

    fn small() -> SeriesStore {
        SeriesStore::new(&SeriesConfig {
            resolutions: vec![(1.0, 60), (10.0, 30)],
            persist_res_s: 10.0,
        })
    }

    #[test]
    fn window_sums_are_exact_over_counts() {
        let mut st = small();
        for t in 0..50u64 {
            st.record(Series::Shed, t * NS, 2.0);
        }
        let (sum, count) = st.window(Series::Shed, 49 * NS, 49 * NS);
        assert_eq!(count, 50);
        assert_eq!(sum, 100.0);
        let (sum5, _) = st.window(Series::Shed, 49 * NS, 4 * NS);
        assert_eq!(sum5, 10.0, "trailing 5 cells at 1 s resolution");
    }

    #[test]
    fn coarse_rung_serves_long_windows() {
        let mut st = small();
        // 600 s of data overruns the 60-cell 1 s ring but not the 10 s one
        for t in 0..600u64 {
            st.record(Series::Offered, t * NS, 1.0);
        }
        let (sum, count) = st.window(Series::Offered, 599 * NS, 599 * NS);
        assert_eq!(count, 600, "10 s rung covers the whole span");
        assert_eq!(sum, 600.0);
    }

    #[test]
    fn closed_cells_stream_in_order_with_consistent_widths() {
        let mut st = small();
        for t in 0..35u64 {
            st.record(Series::P99Ms, t * NS, t as f64);
        }
        let mut cells = Vec::new();
        st.take_closed(&mut cells);
        assert_eq!(cells.len(), 3, "three 10 s cells closed in 35 s");
        let mut last = f64::NEG_INFINITY;
        for c in &cells {
            assert_eq!(c.res_s, 10.0);
            assert_eq!(c.t_s % c.res_s, 0.0, "cell start aligned");
            assert!(c.t_s > last, "monotone close order");
            assert_eq!(c.count, 10);
            assert!(c.min <= c.mean && c.mean <= c.max);
            last = c.t_s;
        }
        let mut tail = Vec::new();
        st.flush_open(&mut tail);
        assert_eq!(tail.len(), 1, "the partial 4th cell flushes at end");
        assert_eq!(tail[0].count, 5);
    }

    #[test]
    fn memory_is_fixed_after_construction() {
        let mut st = small();
        let cap = st.capacity_cells();
        for t in 0..100_000u64 {
            st.record(Series::Completed, t * NS, 1.0);
            if t % 1000 == 0 {
                let mut sink = Vec::new();
                st.take_closed(&mut sink); // journal drained on cadence
            }
        }
        assert_eq!(st.capacity_cells(), cap, "rings never grow");
    }

    #[test]
    fn sparse_samples_skip_cells_without_interpolating() {
        let mut st = small();
        st.record(Series::Shed, 0, 5.0);
        st.record(Series::Shed, 40 * NS, 7.0);
        let (sum, count) = st.window(Series::Shed, 40 * NS, 40 * NS);
        assert_eq!(count, 2);
        assert_eq!(sum, 12.0);
        let (gap, n) = st.window(Series::Shed, 30 * NS, 20 * NS);
        assert_eq!((gap, n), (0.0, 0), "empty cells stay empty");
    }

    #[test]
    fn series_names_round_trip() {
        for s in Series::ALL {
            assert_eq!(Series::from_name(s.name()), Some(s));
        }
        assert_eq!(Series::from_name("nope"), None);
    }
}
