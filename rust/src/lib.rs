//! # fcmp — Frequency Compensated Memory Packing
//!
//! Reproduction of *"Memory-Efficient Dataflow Inference for Deep CNNs on
//! FPGA"* (Petrica et al., 2020) as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate models FINN-style custom dataflow CNN inference accelerators and
//! implements the paper's contribution — FCMP: overclocked GALS weight
//! memories whose dual BRAM ports are round-robin multiplexed to expose
//! `2·R_F` virtual ports, combined with genetic bin packing of logical weight
//! buffers into physical BRAMs — plus every substrate needed to evaluate it:
//! FPGA device models, the CNV / ResNet-50 topology zoo, the FINN folding and
//! resource model, the physical RAM mapper, four packing engines, a
//! cycle-level GALS streamer simulator, a timing-closure model, a dataflow
//! pipeline simulator, and a PJRT-backed inference runtime behind the
//! unified `Deployment` serving coordinator ([`coordinator`]): one fleet
//! abstraction — N chain groups × k stages — covering flat replicated
//! fleets, single pipeline chains and replicated chains, with a
//! group-scheduling policy router, per-worker dynamic batchers, admission
//! control, group-granular live reshaping and fleet/group/stage latency
//! metrics; plus a pipeline-parallel multi-device sharding subsystem
//! ([`sharding`]) that partitions one network across a heterogeneous
//! device fleet and serves it as chain groups, and an adaptive control
//! plane ([`control`]) that closes the loop from fleet metrics back to
//! fleet shape: an SLO-driven whole-group autoscaler, live
//! batching-window adaptation co-tuned per chain, failure-driven
//! re-partition with cached-manifest migration, and an on-disk
//! control-event journal that replays alongside arrival traces.
//!
//! The request path itself is a zero-stall pipeline: lock-free submits
//! through cloneable handles, per-worker async in-flight windows that
//! overlap batch formation and transfer with compute, recycled request
//! buffers and histogram-backed metrics for an allocation-free steady
//! state (see the hot-path profile in
//! [`coordinator::HotPathStats`]). An observability layer ([`obs`])
//! rides the same path: pooled flight-recorder request spans sampled at
//! the head, stamped through one clock seam in real (server) and
//! virtual (sim) time, flushed to JSONL on anomaly triggers, plus live
//! Prometheus-text/JSONL metrics exposition and a `tracereport`
//! critical-path breakdown.
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

pub mod config;
pub mod control;
pub mod coordinator;
pub mod device;
pub mod folding;
pub mod gals;
pub mod memory;
pub mod nn;
pub mod obs;
pub mod packing;
pub mod report;
pub mod runtime;
pub mod sharding;
pub mod sim;
pub mod tenancy;
pub mod timing;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
