//! FINN-R style resource model and folding design-space exploration.
//!
//! The folding (PE, SIMD per layer) sets both throughput (cycles/frame) and
//! cost: compute LUTs scale with PE·SIMD, weight-buffer shape scales with
//! the same product (Fig. 2's efficiency-vs-parallelism effect). The solver
//! reproduces the paper's §III.B exercise: maximize throughput subject to a
//! device's LUT/BRAM budget.
//!
//! The LUT cost model is calibrated against the paper's published totals
//! (Table I: CNV on Zynq 7020; Table II: RN50-W1A2 = 1027 kLUT on U250);
//! constants are documented at their definition.

use crate::device::Device;
use crate::memory;
use crate::nn::{Layer, Network, Stage};

/// Calibrated LUT cost constants (see module docs).
pub mod cost {
    /// LUTs per synapse-bit of compute (XNOR-popcount datapath, W1/W2).
    pub const LUT_PER_SYN_BIT: f64 = 4.5;
    /// LUTs per PE for the accumulator.
    pub const LUT_PER_PE_ACC: f64 = 60.0;
    /// LUTs per PE per threshold (the streamlined activation comparators).
    pub const LUT_PER_PE_THRESH: f64 = 20.0;
    /// Fixed per-network infrastructure (DMA, control, stream plumbing).
    pub const LUT_NETWORK_BASE: f64 = 8_000.0;
    /// Per-layer stream/window-unit overhead.
    pub const LUT_PER_LAYER: f64 = 550.0;
    /// Per-resblock stream infrastructure (duplication, elementwise add,
    /// stand-alone thresholding, bypass FIFO control — paper §III.B).
    pub const LUT_PER_RESBLOCK: f64 = 4_000.0;
    /// DSPs per PE·SIMD for 8-bit (first/last) layers.
    pub const DSP_PER_MAC8: f64 = 1.0;
    /// Multi-die interconnect/replication factor: SLR crossings, stream
    /// pipelining and P&R replication not captured by the per-layer model
    /// (calibrated so RN50-W1A2 lands near Table II's 1027 kLUT).
    pub const MULTI_DIE_LUT_FACTOR: f64 = 1.9;
}

/// Per-layer resource estimate.
#[derive(Clone, Debug, Default)]
pub struct LayerResources {
    pub luts: f64,
    pub dsps: f64,
    pub weight_brams: u64,
    pub cycles_per_frame: u64,
}

/// Estimate one layer's resources (compute + its unpacked weight buffer).
pub fn layer_resources(l: &Layer) -> LayerResources {
    let nt = if l.abits == 0 { 0 } else { (1u64 << l.abits) - 1 };
    let (luts, dsps);
    if l.wbits >= 8 {
        // 8-bit layers: MACs in DSP slices, modest LUT control
        dsps = cost::DSP_PER_MAC8 * (l.pe * l.simd) as f64;
        luts = cost::LUT_PER_LAYER
            + cost::LUT_PER_PE_ACC * l.pe as f64
            + cost::LUT_PER_PE_THRESH * (l.pe * nt) as f64;
    } else {
        dsps = 0.0;
        luts = cost::LUT_PER_LAYER
            + cost::LUT_PER_SYN_BIT * (l.pe * l.simd * l.wbits) as f64
            + cost::LUT_PER_PE_ACC * l.pe as f64
            + cost::LUT_PER_PE_THRESH * (l.pe * nt) as f64;
    }
    LayerResources {
        luts,
        dsps,
        weight_brams: memory::WeightBuffer::from_layer(l, 0).brams(),
        cycles_per_frame: l.cycles_per_frame(),
    }
}

/// Whole-accelerator resource estimate (unpacked memories).
#[derive(Clone, Debug, Default)]
pub struct NetworkResources {
    pub luts: f64,
    pub dsps: f64,
    pub weight_brams: u64,
    pub activation_brams: u64,
    pub activation_urams: u64,
    pub ii_cycles: u64,
}

impl NetworkResources {
    pub fn total_brams(&self) -> u64 {
        self.weight_brams + self.activation_brams
    }

    /// Device LUT utilization including the static platform shell.
    pub fn lut_pct(&self, dev: &Device) -> f64 {
        100.0 * (self.luts + dev.shell_luts as f64) / dev.luts as f64
    }

    pub fn bram_pct(&self, dev: &Device) -> f64 {
        100.0 * self.total_brams() as f64 / dev.bram18 as f64
    }
}

/// Estimate a whole network. On Alveo-class devices (`uram=true`)
/// activations are stored in URAM, not BRAM (paper §III.B); multi-die
/// parts pay the interconnect/replication LUT factor.
pub fn network_resources_on(net: &Network, use_uram: bool, multi_die: bool) -> NetworkResources {
    let mut r = NetworkResources::default();
    for l in net.layers() {
        let lr = layer_resources(l);
        r.luts += lr.luts;
        r.dsps += lr.dsps;
        // non-packable layers keep their weights off BRAM (URAM/DDR) on
        // Alveo; on Zynq the (small) first layer still lands in BRAM
        if !l.exclude_from_packing || !use_uram {
            r.weight_brams += lr.weight_brams;
        }
    }
    for s in &net.stages {
        if matches!(s, Stage::ResBlock { .. }) {
            r.luts += cost::LUT_PER_RESBLOCK;
        }
    }
    r.luts += cost::LUT_NETWORK_BASE;
    if multi_die {
        r.luts *= cost::MULTI_DIE_LUT_FACTOR;
    }
    if use_uram {
        r.activation_urams = memory::activation_urams(net);
    } else {
        r.activation_brams = memory::activation_brams(net);
    }
    r.ii_cycles = net.initiation_interval();
    r
}

/// Estimate a network on a specific device.
pub fn network_resources(net: &Network, dev: &Device) -> NetworkResources {
    network_resources_on(net, dev.uram > 0, !dev.is_monolithic())
}

/// Device LUT utilization of a *packed* design: compute resources plus the
/// FCMP streamer/CDC logic plus the static platform shell, over the
/// device's LUT budget. Unclamped — a value above 1.0 means the design
/// does not place. The single source for both the sharding partitioner's
/// feasibility check and the serving capacity model
/// (`ReplicaSpec::packed_point`).
pub fn packed_lut_util(res: &NetworkResources, logic_kluts: f64, dev: &Device) -> f64 {
    (res.luts + logic_kluts * 1e3 + dev.shell_luts as f64) / dev.luts as f64
}

/// Check a network fits a device (unpacked memories).
pub fn fits(net: &Network, dev: &Device) -> bool {
    let r = network_resources(net, dev);
    r.luts <= dev.luts as f64
        && r.total_brams() <= dev.bram18
        && r.activation_urams <= dev.uram
        && r.dsps <= dev.dsp as f64
}

/// Folding DSE (paper §III.B): starting from the given network, repeatedly
/// *increase* parallelism of the slowest layer (doubling PE, else SIMD)
/// while the design still fits `dev`; returns the throughput-maximal fit.
/// `lut_budget_frac` caps LUTs (placement headroom; P&R fails near 100%).
pub fn solve(net: &Network, dev: &Device, lut_budget_frac: f64) -> Network {
    let mut best = net.clone();
    loop {
        let mut cand = best.clone();
        // find slowest layer and try to speed it up
        let slowest = {
            let mut idx = None;
            let mut worst = 0u64;
            for (si, s) in cand.stages.iter().enumerate() {
                for (li, l) in s.layers().iter().enumerate() {
                    let c = l.cycles_per_frame();
                    if c > worst && can_double(l) {
                        worst = c;
                        idx = Some((si, li));
                    }
                }
            }
            idx
        };
        let Some((si, li)) = slowest else { break };
        double_layer(&mut cand.stages[si], li);
        let r = network_resources(&cand, dev);
        let fits_budget = r.luts + dev.shell_luts as f64 <= dev.luts as f64 * lut_budget_frac
            && r.total_brams() <= dev.bram18
            && r.activation_urams <= dev.uram;
        if !fits_budget {
            break;
        }
        best = cand;
    }
    best
}

fn can_double(l: &Layer) -> bool {
    (l.c_out % (l.pe * 2) == 0) || (l.synapses() % (l.simd * 2) == 0)
}

fn double_layer(stage: &mut Stage, li: usize) {
    let apply = |l: &mut Layer| {
        if l.c_out % (l.pe * 2) == 0 {
            l.pe *= 2;
        } else if l.synapses() % (l.simd * 2) == 0 {
            l.simd *= 2;
        }
    };
    match stage {
        Stage::Mvau(l) => apply(l),
        Stage::ResBlock { branch, bypass, .. } => {
            let n = branch.len();
            if li < n {
                apply(&mut branch[li]);
            } else if let Some(b) = bypass {
                apply(b);
            }
        }
        Stage::MaxPool { .. } => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{alveo_u250, zynq_7020};
    use crate::nn::{cnv, resnet50, CnvVariant};

    #[test]
    fn cnv_w1a1_fits_7020_near_table_i() {
        // Table I: CNV-W1A1 on Zynq 7020 ~ 88% BRAM, 49% LUT
        let net = cnv(CnvVariant::W1A1);
        let dev = zynq_7020();
        let r = network_resources(&net, &dev);
        let lut_pct = r.lut_pct(&dev);
        let bram_pct = r.bram_pct(&dev);
        assert!((30.0..70.0).contains(&lut_pct), "LUT% {lut_pct}");
        assert!((60.0..105.0).contains(&bram_pct), "BRAM% {bram_pct}");
    }

    #[test]
    fn cnv_w2a2_trades_throughput_for_brams() {
        // W2A2 halves PE to stay LUT-comparable, but its doubled weight
        // bits still need more BRAM (Table IV: 208 vs 126) and its II grows.
        let dev = zynq_7020();
        let n1 = cnv(CnvVariant::W1A1);
        let n2 = cnv(CnvVariant::W2A2);
        let r1 = network_resources(&n1, &dev);
        let r2 = network_resources(&n2, &dev);
        assert!(r2.weight_brams > r1.weight_brams);
        assert!((r2.luts - r1.luts).abs() / r1.luts < 0.25);
        assert!(n2.initiation_interval() >= n1.initiation_interval());
    }

    #[test]
    fn rn50_lut_scale_near_table_ii() {
        // Table II: RN50-W1A2 on U250 = 1027 kLUT (59% of 1728k), 3870
        // BRAM18 total, OCM is the bottleneck.
        let net = resnet50(1);
        let dev = alveo_u250();
        let r = network_resources(&net, &dev);
        let kluts = r.luts / 1e3;
        assert!((700.0..1400.0).contains(&kluts), "kLUT {kluts}");
        let bram_pct = r.bram_pct(&dev);
        assert!((35.0..100.0).contains(&bram_pct), "BRAM% {bram_pct}");
    }

    #[test]
    fn fold2_halves_throughput_and_shrinks_luts() {
        let net = resnet50(1);
        let f2 = net.fold2();
        assert!(f2.initiation_interval() >= 2 * net.initiation_interval() / 3);
        let dev = alveo_u250();
        let r = network_resources(&net, &dev);
        let r2 = network_resources(&f2, &dev);
        assert!(r2.luts < r.luts);
    }

    #[test]
    fn dse_improves_throughput_within_budget() {
        let mut slow = cnv(CnvVariant::W1A1);
        // de-parallelize everything
        for s in &mut slow.stages {
            if let Stage::Mvau(l) = s {
                l.pe = 1;
                l.simd = 1;
            }
        }
        let dev = zynq_7020();
        let solved = solve(&slow, &dev, 0.8);
        assert!(solved.initiation_interval() < slow.initiation_interval());
        let r = network_resources(&solved, &dev);
        assert!(r.luts <= dev.luts as f64 * 0.8);
    }

    #[test]
    fn eight_bit_layers_use_dsps() {
        let net = resnet50(1);
        let r = network_resources(&net, &alveo_u250());
        // Table II: 1611 DSPs for RN50-W1A2 on U250; ours within ~25%
        assert!((1200.0..2100.0).contains(&r.dsps), "dsps {}", r.dsps);
    }
}
