//! Offline stand-in for the `xla` crate (see DESIGN.md "Offline-dependency
//! substitutions"). The image this repo builds in has no network and no
//! xla_extension, so the default feature set compiles the runtime against
//! this module instead; the API surface is exactly the slice of xla-rs that
//! `runtime::mod` uses. Every entry point fails fast at `PjRtClient::cpu()`
//! with an actionable message, so the golden tests skip cleanly and the
//! `golden`/`serve` subcommands report why they cannot run.

use std::fmt;

/// Error type mirroring `xla::Error` closely enough for `{e}` formatting.
#[derive(Debug)]
pub struct Error(&'static str);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

const UNAVAILABLE: &str =
    "built without the `pjrt` feature: the xla crate is unavailable offline \
     (rebuild with --features pjrt and the xla-rs dependency to run models)";

/// Stand-in for `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error(UNAVAILABLE))
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error(UNAVAILABLE))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }
}

/// Stand-in for `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(Error(UNAVAILABLE))
    }
}

/// Stand-in for `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stand-in for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute(&self, _args: &[&Literal]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error(UNAVAILABLE))
    }
}

/// Stand-in for `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error(UNAVAILABLE))
    }
}

/// Stand-in for `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error(UNAVAILABLE))
    }

    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(Error(UNAVAILABLE))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error(UNAVAILABLE))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_with_actionable_message() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err}").contains("pjrt"));
    }
}
