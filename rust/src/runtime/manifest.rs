//! Parser for the plain-text artifact manifests emitted by `aot.py`.
//!
//! Format (one record per line):
//! ```text
//! model <name>
//! hlo <batch> <file>
//! param <file> <dim>...
//! arg <file> <dim>...          # micro-artifacts only
//! expect <file> <dim>...       # micro-artifacts only
//! input <batch> <dim>...
//! output <batch> <dim>...
//! golden <input-file> <output-file>
//! ```

use std::path::Path;

use crate::Result;
use anyhow::{anyhow, Context};

/// A tensor file reference with dims.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub file: String,
    pub dims: Vec<u64>,
}

impl TensorSpec {
    pub fn elements(&self) -> u64 {
        self.dims.iter().product()
    }
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub model: String,
    /// (batch, hlo file) pairs.
    pub hlo: Vec<(usize, String)>,
    pub params: Vec<TensorSpec>,
    pub args: Vec<TensorSpec>,
    pub expect: Option<TensorSpec>,
    /// Input dims including batch (dims[0] = smallest golden batch).
    pub input_dims: Vec<u64>,
    pub output_dims: Vec<u64>,
    pub golden: Option<(String, String)>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut m = Manifest::default();
        for (no, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            let tag = it.next().unwrap();
            let rest: Vec<&str> = it.collect();
            let dims = |xs: &[&str]| -> Result<Vec<u64>> {
                xs.iter()
                    .map(|x| x.parse::<u64>().with_context(|| format!("line {}: {x:?}", no + 1)))
                    .collect()
            };
            match tag {
                "model" => m.model = rest.first().unwrap_or(&"").to_string(),
                "hlo" => {
                    let batch: usize = rest
                        .first()
                        .ok_or_else(|| anyhow!("line {}: hlo wants batch", no + 1))?
                        .parse()?;
                    let file = rest
                        .get(1)
                        .ok_or_else(|| anyhow!("line {}: hlo wants file", no + 1))?;
                    m.hlo.push((batch, file.to_string()));
                }
                "param" | "arg" | "expect" => {
                    let file = rest
                        .first()
                        .ok_or_else(|| anyhow!("line {}: {tag} wants file", no + 1))?
                        .to_string();
                    let spec = TensorSpec { file, dims: dims(&rest[1..])? };
                    match tag {
                        "param" => m.params.push(spec),
                        "arg" => m.args.push(spec),
                        _ => m.expect = Some(spec),
                    }
                }
                "input" => m.input_dims = dims(&rest)?,
                "output" => m.output_dims = dims(&rest)?,
                "golden" => {
                    if rest.len() != 2 {
                        return Err(anyhow!("line {}: golden wants 2 files", no + 1));
                    }
                    m.golden = Some((rest[0].to_string(), rest[1].to_string()));
                }
                other => return Err(anyhow!("line {}: unknown tag {other:?}", no + 1)),
            }
        }
        if m.model.is_empty() {
            return Err(anyhow!("manifest has no model line"));
        }
        Ok(m)
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        Manifest::parse(&text)
    }

    /// Elements per sample (input dims without the batch dimension).
    pub fn input_elements_per_sample(&self) -> u64 {
        self.input_dims.iter().skip(1).product()
    }

    pub fn output_elements_per_sample(&self) -> u64 {
        self.output_dims.iter().skip(1).product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
model cnv_w1a1
param weights/cnv_w1a1/000.bin 27 64
param weights/cnv_w1a1/001.bin 576 64
hlo 1 cnv_w1a1.b1.hlo.txt
hlo 4 cnv_w1a1.b4.hlo.txt
input 1 32 32 3
output 1 16
golden golden/cnv_w1a1.in.bin golden/cnv_w1a1.out.bin
";

    #[test]
    fn parses_model_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.model, "cnv_w1a1");
        assert_eq!(
            m.hlo,
            vec![(1, "cnv_w1a1.b1.hlo.txt".into()), (4, "cnv_w1a1.b4.hlo.txt".into())]
        );
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].elements(), 27 * 64);
        assert_eq!(m.input_elements_per_sample(), 32 * 32 * 3);
        assert_eq!(m.output_elements_per_sample(), 16);
        assert!(m.golden.is_some());
    }

    #[test]
    fn parses_micro_manifest() {
        let text = "\
model mvau_unit
hlo 1 mvau_unit.hlo.txt
arg golden/x.bin 8 36
arg golden/w.bin 36 16
expect golden/y.bin 8 16
";
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.args.len(), 2);
        assert_eq!(m.expect.as_ref().unwrap().elements(), 128);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("bogus line here\n").is_err());
        assert!(Manifest::parse("param only-file-no-dims\nmodel x\n").is_ok());
        assert!(Manifest::parse("hlo notanumber file\nmodel x\n").is_err());
        assert!(Manifest::parse("").is_err()); // no model
    }

    #[test]
    fn real_artifacts_parse_when_present() {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        for name in ["cnv_w1a1", "cnv_w2a2", "rn50_lite_w1a2", "mvau_unit"] {
            let p = root.join(format!("{name}.manifest"));
            if p.exists() {
                let m = Manifest::load(&p).unwrap();
                assert_eq!(m.model, name);
                assert!(!m.hlo.is_empty());
            }
        }
    }
}
