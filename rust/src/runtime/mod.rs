//! PJRT inference runtime: loads the AOT artifacts emitted by
//! `python/compile/aot.py` (HLO *text* + weight `.bin`s + golden I/O) and
//! executes them on the CPU PJRT client via the `xla` crate.
//!
//! Python never runs here — the artifacts are the entire python↔rust
//! interface (see DESIGN.md: the three-layer architecture). HLO text is the
//! interchange format: jax ≥ 0.5 serialized protos carry 64-bit instruction
//! ids that xla_extension 0.5.1 rejects; the text parser reassigns ids.

pub mod manifest;
#[cfg(not(feature = "pjrt"))]
mod stub;

use std::path::{Path, PathBuf};

use crate::Result;
use anyhow::{anyhow, Context};
pub use manifest::{Manifest, TensorSpec};
#[cfg(not(feature = "pjrt"))]
use stub as xla;

/// Read a little-endian f32 `.bin` tensor file.
pub fn read_f32_bin(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    if bytes.len() % 4 != 0 {
        return Err(anyhow!("{path:?}: length {} not a multiple of 4", bytes.len()));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// One compiled model variant (a specific batch size).
pub struct CompiledModel {
    pub batch: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// The inference engine: a PJRT client plus the loaded model(s) and their
/// parameter literals (uploaded once; only the input varies per request).
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    models: Vec<CompiledModel>,
    params: Vec<xla::Literal>,
    root: PathBuf,
}

impl Engine {
    /// Load a model by name from the artifacts directory.
    pub fn load(artifacts: &Path, model: &str) -> Result<Engine> {
        let manifest = Manifest::load(&artifacts.join(format!("{model}.manifest")))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e}"))?;

        let mut models = Vec::new();
        for (batch, hlo_file) in &manifest.hlo {
            let path = artifacts.join(hlo_file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(|e| anyhow!("compiling {path:?}: {e}"))?;
            models.push(CompiledModel { batch: *batch, exe });
        }
        if models.is_empty() {
            return Err(anyhow!("{model}: no hlo variants in manifest"));
        }

        let mut params = Vec::new();
        for spec in &manifest.params {
            let data = read_f32_bin(&artifacts.join(&spec.file))?;
            if data.len() as u64 != spec.elements() {
                return Err(anyhow!(
                    "{}: file has {} elements, manifest says {}",
                    spec.file,
                    data.len(),
                    spec.elements()
                ));
            }
            params.push(literal_from_f32(&data, &spec.dims)?);
        }

        Ok(Engine { manifest, client, models, params, root: artifacts.to_path_buf() })
    }

    /// Supported batch sizes, ascending.
    pub fn batch_sizes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.models.iter().map(|m| m.batch).collect();
        v.sort_unstable();
        v
    }

    /// The smallest variant that fits `n` inputs (or the largest available).
    fn variant_for(&self, n: usize) -> &CompiledModel {
        self.models
            .iter()
            .filter(|m| m.batch >= n)
            .min_by_key(|m| m.batch)
            .unwrap_or_else(|| self.models.iter().max_by_key(|m| m.batch).unwrap())
    }

    /// Run a batch of inputs (row-major images, each of the manifest's
    /// input element count). Short batches are padded to the variant size;
    /// outputs are truncated back to `inputs.len()` rows.
    pub fn infer(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if inputs.is_empty() {
            return Ok(vec![]);
        }
        let per = self.manifest.input_elements_per_sample();
        for (i, x) in inputs.iter().enumerate() {
            if x.len() as u64 != per {
                return Err(anyhow!("input {i}: {} elements, want {per}", x.len()));
            }
        }
        let m = self.variant_for(inputs.len());
        let eff = inputs.len().min(m.batch);

        // assemble (pad by repeating the last sample)
        let mut flat = Vec::with_capacity(m.batch * per as usize);
        for i in 0..m.batch {
            flat.extend_from_slice(&inputs[i.min(inputs.len() - 1)]);
        }
        let mut dims = self.manifest.input_dims.clone();
        dims[0] = m.batch as u64;
        let input_lit = literal_from_f32(&flat, &dims)?;

        let mut args: Vec<&xla::Literal> = Vec::with_capacity(1 + self.params.len());
        args.push(&input_lit);
        args.extend(self.params.iter());

        let result = m
            .exe
            .execute(&args)
            .map_err(|e| anyhow!("execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
        let vals = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?;

        let out_per = self.manifest.output_elements_per_sample() as usize;
        Ok(vals.chunks_exact(out_per).take(eff).map(|c| c.to_vec()).collect())
    }

    /// Verify the engine against the golden I/O emitted at AOT time.
    /// All math is integer-valued f32, so the comparison is exact.
    pub fn check_golden(&self) -> Result<()> {
        let (gin, gout) = self
            .manifest
            .golden
            .as_ref()
            .ok_or_else(|| anyhow!("{}: no golden files", self.manifest.model))?;
        let x = read_f32_bin(&self.root.join(gin))?;
        let want = read_f32_bin(&self.root.join(gout))?;
        let per = self.manifest.input_elements_per_sample() as usize;
        let inputs: Vec<Vec<f32>> = x.chunks_exact(per).map(|c| c.to_vec()).collect();
        let got = self.infer(&inputs)?;
        let flat: Vec<f32> = got.into_iter().flatten().collect();
        if flat.len() != want.len() {
            return Err(anyhow!("golden length {} vs {}", flat.len(), want.len()));
        }
        for (i, (a, b)) in flat.iter().zip(want.iter()).enumerate() {
            if (a - b).abs() > 1e-4 {
                return Err(anyhow!("golden mismatch at {i}: got {a}, want {b}"));
            }
        }
        Ok(())
    }

    /// Platform description (for logs).
    pub fn platform(&self) -> String {
        format!("{} ({} devices)", self.client.platform_name(), self.client.device_count())
    }
}

fn literal_from_f32(data: &[f32], dims: &[u64]) -> Result<xla::Literal> {
    let n: u64 = dims.iter().product();
    if n != data.len() as u64 {
        return Err(anyhow!("literal shape {dims:?} wants {n} elements, got {}", data.len()));
    }
    let lit = xla::Literal::vec1(data);
    let idims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    lit.reshape(&idims).map_err(|e| anyhow!("reshape {dims:?}: {e}"))
}

/// Run the stand-alone MVAU micro artifact (kernel-level golden check
/// without a full network): returns Ok(()) iff the kernel output matches
/// python exactly.
pub fn check_mvau_unit(artifacts: &Path) -> Result<()> {
    let manifest = Manifest::load(&artifacts.join("mvau_unit.manifest"))?;
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e}"))?;
    let (_, hlo_file) = manifest
        .hlo
        .first()
        .ok_or_else(|| anyhow!("mvau_unit: no hlo"))?;
    let proto = xla::HloModuleProto::from_text_file(
        artifacts.join(hlo_file).to_str().unwrap(),
    )
    .map_err(|e| anyhow!("parse: {e}"))?;
    let exe = client
        .compile(&xla::XlaComputation::from_proto(&proto))
        .map_err(|e| anyhow!("compile: {e}"))?;

    let mut lits = Vec::new();
    for spec in &manifest.args {
        let data = read_f32_bin(&artifacts.join(&spec.file))?;
        lits.push(literal_from_f32(&data, &spec.dims)?);
    }
    let expect_spec = manifest
        .expect
        .as_ref()
        .ok_or_else(|| anyhow!("mvau_unit: no expect"))?;
    let want = read_f32_bin(&artifacts.join(&expect_spec.file))?;

    let refs: Vec<&xla::Literal> = lits.iter().collect();
    let out = exe.execute(&refs).map_err(|e| anyhow!("execute: {e}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetch: {e}"))?
        .to_tuple1()
        .map_err(|e| anyhow!("untuple: {e}"))?;
    let got = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?;
    if got.len() != want.len() {
        return Err(anyhow!("mvau_unit: length {} vs {}", got.len(), want.len()));
    }
    for (i, (a, b)) in got.iter().zip(want.iter()).enumerate() {
        if (a - b).abs() > 1e-5 {
            return Err(anyhow!("mvau_unit mismatch at {i}: {a} vs {b}"));
        }
    }
    Ok(())
}
