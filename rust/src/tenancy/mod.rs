//! Multi-tenant model zoo: several networks co-resident on one shared
//! FCMP fleet, with per-tenant routing, SLO accounting and control
//! isolation.
//!
//! The paper's memory-packing headroom argument becomes a *consolidation*
//! argument here: FCMP frees enough OCM that a second tenant's network
//! fits the same device, so a two-model catalog that would need two
//! dedicated boards co-packs onto one. The module stacks four layers on
//! that observation:
//!
//! 1. **Co-packing** ([`copack`]): one packing run over the union of
//!    every tenant's tenant-tagged column slices, per-tenant unpack, and
//!    the dedicated-device baseline the consolidation is judged against.
//! 2. **Topology**: [`crate::coordinator::ChainGroup`] carries a tenant
//!    id; the threaded router and [`crate::sim::FleetSim`] route each
//!    tenant's traffic only to that tenant's groups.
//! 3. **Admission**: requests carry a deadline from the tenant's SLO
//!    budget; the shared [`crate::coordinator::dispatch::deadline_feasible`]
//!    rule sheds infeasible work up front
//!    ([`crate::coordinator::SubmitError::DeadlineInfeasible`]) instead
//!    of letting it rot in a queue past its deadline.
//! 4. **Control** ([`control`]): per-tenant signal windows, series and
//!    burn-rate alerting — one tenant's flash crowd pages that tenant
//!    alone.
//!
//! The `fcmp zoo` subcommand drives all four layers end to end on
//! either backend; `benches/zoo_scaling.rs` measures the co-packed
//! device savings and the goodput edge of deadline-aware shedding.

pub mod control;
pub mod copack;

pub use control::{TenantAlert, TenantControl, TenantSlo};
pub use copack::{catalog_items, co_pack, dedicated_devices, CoPack};
