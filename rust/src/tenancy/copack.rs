//! Multi-network co-packing: one FCMP run over a tagged item set.
//!
//! The grouping-GA formulation (Kroes et al., arXiv:2003.12449) does not
//! care which network a memory partition came from — a bin is feasible or
//! not purely on column widths, depths and SLR locality. Co-packing a
//! model catalog is therefore *the same optimization* over the union of
//! every tenant's column slices, each tagged with its tenant id so the
//! shared packing can be unpacked per tenant afterwards. The payoff is
//! the paper's headroom argument made concrete: FCMP frees OCM that the
//! dataflow topology would otherwise waste, and the freed OCM is spent
//! hosting a second tenant's network on the same device.

use crate::device::Device;
use crate::memory::{self, PackItem};
use crate::nn::Network;
use crate::packing::{self, Constraints, PackReport, Packer, Packing};

/// Outcome of co-packing a catalog of networks onto one device.
pub struct CoPack {
    /// Tenant id → network name (catalog order).
    pub names: Vec<String>,
    /// The union item set: every tenant's weight columns, tenant-tagged,
    /// with globally unique ids in catalog order.
    pub items: Vec<PackItem>,
    /// The shared packing over `items`.
    pub packing: Packing,
    /// Engine report for the shared packing.
    pub report: PackReport,
    /// Packed BRAM18 cost of all weight buffers (== `report.brams`).
    pub weight_brams: u64,
    /// Weights of packing-excluded layers (first/last — §V keeps them in
    /// dedicated RAM), summed over the catalog.
    pub excluded_brams: u64,
    /// Activation + FIFO BRAM18 cost summed over the catalog, with the
    /// conservative HLS FIFO allocation halved — the §V porting
    /// convention, same as the sharding evaluator's.
    pub activation_brams: u64,
    /// Direct (unpacked) BRAM18 cost of the same catalog — what the
    /// device would need without FCMP.
    pub direct_brams: u64,
    /// Device BRAM18 capacity the feasibility verdict is against.
    pub device_brams: u64,
    /// Device name (for reports).
    pub device: &'static str,
}

impl CoPack {
    /// Total BRAM18 demand of the co-packed catalog.
    pub fn total_brams(&self) -> u64 {
        self.weight_brams + self.excluded_brams + self.activation_brams
    }

    /// Total BRAM18 demand without packing (the consolidation baseline).
    pub fn total_direct_brams(&self) -> u64 {
        self.direct_brams + self.excluded_brams + self.activation_brams
    }

    /// Does the whole catalog fit the device co-packed?
    pub fn fits(&self) -> bool {
        self.total_brams() <= self.device_brams
    }

    /// Would the catalog fit the device *without* packing?
    pub fn fits_direct(&self) -> bool {
        self.total_direct_brams() <= self.device_brams
    }

    /// Item ids belonging to `tenant`, gathered from the shared bins in
    /// bin order — the per-tenant unpack. Sorted by id, so it compares
    /// directly against the tenant's slice of `items`.
    pub fn unpack_tenant(&self, tenant: usize) -> Vec<usize> {
        let mut ids: Vec<usize> = self
            .packing
            .bins
            .iter()
            .flat_map(|b| b.items.iter().copied())
            .filter(|&i| self.items[i].tenant == tenant)
            .collect();
        ids.sort_unstable();
        ids
    }

    /// Packed BRAM18 attributable to `tenant`: each shared bin's cost is
    /// split pro-rata by payload bits (a bin hosting two tenants' columns
    /// bills each for its share of the physical RAMs).
    pub fn tenant_brams(&self, tenant: usize) -> f64 {
        let mut total = 0.0;
        for bin in &self.packing.bins {
            let cost = packing::bin_brams(&self.items, &bin.items) as f64;
            let bits: u64 = bin.items.iter().map(|&i| self.items[i].bits()).sum();
            if bits == 0 {
                continue;
            }
            let mine: u64 = bin
                .items
                .iter()
                .filter(|&&i| self.items[i].tenant == tenant)
                .map(|&i| self.items[i].bits())
                .sum();
            total += cost * mine as f64 / bits as f64;
        }
        total
    }
}

/// The union item set for a catalog: every network's weight columns
/// tenant-tagged and re-id'd globally (catalog order, then column order —
/// deterministic, so packings are reproducible per seed).
pub fn catalog_items(nets: &[&Network], n_slrs: usize) -> Vec<PackItem> {
    let mut out: Vec<PackItem> = Vec::new();
    for (tenant, net) in nets.iter().enumerate() {
        let bufs = memory::weight_buffers(net, n_slrs);
        for mut it in memory::all_columns(&bufs) {
            it.id = out.len();
            it.tenant = tenant;
            out.push(it);
        }
    }
    out
}

/// Co-pack a catalog onto one device. `generations == 0` selects the
/// deterministic FFD baseline; otherwise the island GA runs with that
/// budget and `seed` (Table III CNV hyper-parameters — the zoo catalogs
/// are CNV/MLP-class).
pub fn co_pack(
    nets: &[&Network],
    dev: &Device,
    bin_height: usize,
    generations: usize,
    seed: u64,
) -> CoPack {
    assert!(!nets.is_empty(), "co_pack needs at least one network");
    let items = catalog_items(nets, dev.slrs.len());
    let c = Constraints::new(bin_height, !dev.is_monolithic());
    let (packing, report) = if items.is_empty() {
        (
            Packing::default(),
            PackReport {
                engine: "empty",
                brams: 0,
                efficiency: 1.0,
                max_height: 0,
                elapsed: std::time::Duration::ZERO,
            },
        )
    } else if generations == 0 {
        packing::run_packer(&packing::ffd::Ffd::new(), &items, &c)
    } else {
        let mut ga = packing::ga::Ga::new(packing::ga::GaParams::cnv());
        ga.params.generations = generations;
        ga.params.seed = seed;
        packing::run_packer(&ga, &items, &c)
    };
    let direct: u64 = nets
        .iter()
        .map(|n| memory::direct_brams(&memory::weight_buffers(n, dev.slrs.len())))
        .sum();
    let excluded: u64 = nets
        .iter()
        .flat_map(|n| n.layers())
        .filter(|l| l.exclude_from_packing)
        .map(|l| memory::WeightBuffer::from_layer(l, 0).brams())
        .sum();
    // §V porting convention: HLS's conservative FIFO allocation is
    // re-sized (halved) when porting — keep the same rule the sharding
    // evaluator applies, so fit verdicts agree across subsystems
    let activation: u64 = nets.iter().map(|n| memory::activation_brams(n) / 2).sum();
    let weight_brams = report.brams;
    CoPack {
        names: nets.iter().map(|n| n.name.clone()).collect(),
        items,
        packing,
        report,
        weight_brams,
        excluded_brams: excluded,
        activation_brams: activation,
        direct_brams: direct,
        device_brams: dev.bram18,
        device: dev.name,
    }
}

/// Devices a *dedicated* per-tenant deployment needs: each tenant packs
/// alone (same engine budget) and occupies its own device(s) — no bin is
/// ever shared across tenants. This is the baseline the co-packed fleet
/// cost compares against.
pub fn dedicated_devices(
    nets: &[&Network],
    dev: &Device,
    bin_height: usize,
    generations: usize,
    seed: u64,
) -> usize {
    nets.iter()
        .map(|n| {
            let solo = co_pack(&[n], dev, bin_height, generations, seed);
            let need = solo.total_brams();
            let cap = dev.bram18.max(1);
            crate::util::ceil_div(need, cap).max(1) as usize
        })
        .sum()
}
