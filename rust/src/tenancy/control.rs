//! Per-tenant control-plane isolation: one [`SignalTap`], one
//! [`SeriesStore`] and one pair of [`BurnAlerter`]s *per tenant*, so a
//! flash crowd burning tenant A's error budget pages tenant A's
//! on-call and nobody else's. The shared-fleet control loop keeps its
//! single fleet-wide tap for actuation (autoscale, batching retune);
//! this layer is the per-tenant *observability* split that the zoo's
//! SLO accounting hangs off.

use std::time::Duration;

use crate::control::{ControlSignals, SignalConfig, SignalTap, SloController};
use crate::obs::{
    BurnAlerter, BurnRule, HealthAlert, Series, SeriesConfig, SeriesStore, Severity, SloSignal,
};
use crate::util::stats::percentile;

/// One tenant's SLO contract, as the control plane sees it.
#[derive(Clone, Copy, Debug)]
pub struct TenantSlo {
    /// End-to-end p99 latency budget, milliseconds.
    pub slo_ms: f64,
    /// Allowed shed fraction of offered traffic.
    pub shed_budget: f64,
    /// Allowed fraction of completions landing in late intervals.
    pub late_budget: f64,
}

impl Default for TenantSlo {
    fn default() -> TenantSlo {
        TenantSlo { slo_ms: 50.0, shed_budget: 0.02, late_budget: 0.05 }
    }
}

/// Raw per-tick counts for one tenant, reset at every tick close.
#[derive(Default)]
struct TickCounts {
    submitted: u64,
    shed: u64,
    lat_ms: Vec<f64>,
}

/// An alert transition attributed to a tenant.
#[derive(Clone, Copy, Debug)]
pub struct TenantAlert {
    /// Which tenant's budget moved.
    pub tenant: usize,
    /// The underlying burn-rate transition.
    pub alert: HealthAlert,
}

/// Per-tenant control surfaces over a shared fleet: each tenant gets
/// its own signal window, downsampled series and burn-rate alerting,
/// fed from that tenant's admission/completion stream only. Tenants
/// cannot observe — or page on — each other's traffic.
pub struct TenantControl {
    slos: Vec<TenantSlo>,
    taps: Vec<SignalTap>,
    stores: Vec<SeriesStore>,
    shed_alerters: Vec<BurnAlerter>,
    late_alerters: Vec<BurnAlerter>,
    cur: Vec<TickCounts>,
    last: Vec<Option<ControlSignals>>,
    alerts: Vec<TenantAlert>,
}

impl TenantControl {
    /// Build one control surface per entry of `slos`, with every tenant
    /// evaluating the same `rules` against its own budgets.
    pub fn new(slos: &[TenantSlo], signal: SignalConfig, rules: &[BurnRule]) -> TenantControl {
        // short sub-second cells: zoo runs are seconds long, and each
        // tenant's store only has to cover the rules' longest window
        let series = SeriesConfig { resolutions: vec![(0.05, 8192)], persist_res_s: 0.05 };
        let n = slos.len();
        TenantControl {
            slos: slos.to_vec(),
            taps: (0..n).map(|_| SignalTap::new(signal)).collect(),
            stores: (0..n).map(|_| SeriesStore::new(&series)).collect(),
            shed_alerters: slos
                .iter()
                .map(|s| {
                    BurnAlerter::new(
                        SloSignal::ShedRate,
                        Series::Shed,
                        Series::Offered,
                        s.shed_budget,
                        rules.to_vec(),
                    )
                })
                .collect(),
            late_alerters: slos
                .iter()
                .map(|s| {
                    BurnAlerter::new(
                        SloSignal::LatencyP99,
                        Series::Late,
                        Series::Completed,
                        s.late_budget,
                        rules.to_vec(),
                    )
                })
                .collect(),
            cur: (0..n).map(|_| TickCounts::default()).collect(),
            last: vec![None; n],
            alerts: Vec::new(),
        }
    }

    /// Tenants under control.
    pub fn len(&self) -> usize {
        self.slos.len()
    }

    /// True when built over an empty catalog.
    pub fn is_empty(&self) -> bool {
        self.slos.is_empty()
    }

    /// Count one accepted submission for `tenant` in the open tick.
    pub fn record_submitted(&mut self, tenant: usize) {
        if let Some(c) = self.cur.get_mut(tenant) {
            c.submitted += 1;
            self.taps[tenant].record_submitted();
        }
    }

    /// Count one shed (queue-full or deadline) for `tenant`.
    pub fn record_shed(&mut self, tenant: usize) {
        if let Some(c) = self.cur.get_mut(tenant) {
            c.shed += 1;
            self.taps[tenant].record_shed();
        }
    }

    /// Record one completion latency for `tenant`.
    pub fn record_completion(&mut self, tenant: usize, latency: Duration) {
        if let Some(c) = self.cur.get_mut(tenant) {
            c.lat_ms.push(latency.as_secs_f64() * 1e3);
            self.taps[tenant].record_completion(latency);
        }
    }

    /// Close every tenant's tick at `now_ns`: fold the tick's counts
    /// into that tenant's series, evaluate its burn rules (appending
    /// attributed transitions to the journal), and cache its windowed
    /// signals. One tenant's counts never touch another's store.
    pub fn tick(&mut self, now_ns: u64) {
        for t in 0..self.slos.len() {
            let counts = std::mem::take(&mut self.cur[t]);
            let store = &mut self.stores[t];
            store.record(Series::Offered, now_ns, (counts.submitted + counts.shed) as f64);
            store.record(Series::Shed, now_ns, counts.shed as f64);
            store.record(Series::Completed, now_ns, counts.lat_ms.len() as f64);
            if !counts.lat_ms.is_empty() {
                let mut lat = counts.lat_ms;
                lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let p99 = percentile(&lat, 99.0);
                store.record(Series::P99Ms, now_ns, p99);
                let late = if p99 > self.slos[t].slo_ms { lat.len() } else { 0 };
                store.record(Series::Late, now_ns, late as f64);
            } else {
                store.record(Series::Late, now_ns, 0.0);
            }
            let mut out = Vec::new();
            self.shed_alerters[t].eval(store, now_ns, &mut out);
            self.late_alerters[t].eval(store, now_ns, &mut out);
            self.alerts.extend(out.into_iter().map(|alert| TenantAlert { tenant: t, alert }));
            self.last[t] = Some(self.taps[t].tick());
        }
    }

    /// The tenant's most recent windowed signals (`None` before the
    /// first tick).
    pub fn signals(&self, tenant: usize) -> Option<&ControlSignals> {
        self.last.get(tenant).and_then(|s| s.as_ref())
    }

    /// Is any of `tenant`'s burn rules currently firing?
    pub fn firing(&self, tenant: usize) -> bool {
        self.shed_alerters.get(tenant).is_some_and(BurnAlerter::any_firing)
            || self.late_alerters.get(tenant).is_some_and(BurnAlerter::any_firing)
    }

    /// Has `tenant` ever fired a page-severity alert?
    pub fn paged(&self, tenant: usize) -> bool {
        self.alerts
            .iter()
            .any(|a| a.tenant == tenant && a.alert.firing && a.alert.severity == Severity::Page)
    }

    /// The attributed alert journal, in transition order.
    pub fn alerts(&self) -> &[TenantAlert] {
        &self.alerts
    }

    /// Per-tenant batching retune: adjust `cur` against the tenant's own
    /// windowed p99 with a [`SloController`] bound to that tenant's
    /// latency budget — tenant A's congestion never shrinks tenant B's
    /// batching window.
    pub fn adjust_for(
        &self,
        tenant: usize,
        slo: &SloController,
        cur: crate::coordinator::BatcherConfig,
    ) -> crate::coordinator::BatcherConfig {
        match self.signals(tenant) {
            Some(sig) => slo.adjust(sig.p99_ms, cur),
            None => cur,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TICK_NS: u64 = 50_000_000; // 50 ms, matching the series cell

    fn rules() -> Vec<BurnRule> {
        // compressed multiwindow rules sized for second-scale tests
        vec![
            BurnRule { severity: Severity::Page, long_s: 1.0, short_s: 0.25, burn: 10.0 },
            BurnRule { severity: Severity::Ticket, long_s: 2.0, short_s: 0.5, burn: 4.0 },
        ]
    }

    #[test]
    fn flash_crowd_pages_only_its_own_tenant() {
        let slos = [TenantSlo::default(), TenantSlo::default()];
        let mut tc = TenantControl::new(&slos, SignalConfig::default(), &rules());
        // 3 s: tenant 0 sheds half its traffic (burn 25 ≫ 10), tenant 1
        // is healthy the whole time
        for k in 1..=60u64 {
            for _ in 0..20 {
                tc.record_submitted(0);
            }
            for _ in 0..20 {
                tc.record_shed(0);
            }
            for _ in 0..20 {
                tc.record_submitted(1);
                tc.record_completion(1, Duration::from_millis(5));
            }
            tc.tick(k * TICK_NS);
        }
        assert!(tc.paged(0), "tenant 0's shed burn must page: {:?}", tc.alerts());
        assert!(!tc.firing(1), "tenant 1 must stay quiet");
        assert!(
            tc.alerts().iter().all(|a| a.tenant == 0),
            "no alert may attribute to the healthy tenant: {:?}",
            tc.alerts()
        );
    }

    #[test]
    fn late_completions_burn_the_latency_budget_per_tenant() {
        let slos = [
            TenantSlo { slo_ms: 10.0, ..TenantSlo::default() },
            TenantSlo { slo_ms: 200.0, ..TenantSlo::default() },
        ];
        let mut tc = TenantControl::new(&slos, SignalConfig::default(), &rules());
        // both tenants complete everything at ~50 ms: late for tenant
        // 0's 10 ms budget, comfortably inside tenant 1's 200 ms
        for k in 1..=60u64 {
            for _ in 0..20 {
                tc.record_submitted(0);
                tc.record_completion(0, Duration::from_millis(50));
                tc.record_submitted(1);
                tc.record_completion(1, Duration::from_millis(50));
            }
            tc.tick(k * TICK_NS);
        }
        assert!(
            tc.alerts().iter().any(|a| a.tenant == 0
                && a.alert.signal == SloSignal::LatencyP99
                && a.alert.firing),
            "tenant 0's latency budget must fire: {:?}",
            tc.alerts()
        );
        assert!(!tc.firing(1), "tenant 1's larger budget absorbs 50 ms completions");
    }

    #[test]
    fn windowed_signals_split_per_tenant() {
        let slos = [TenantSlo::default(), TenantSlo::default()];
        let mut tc = TenantControl::new(&slos, SignalConfig { window_ticks: 1 }, &rules());
        for _ in 0..9 {
            tc.record_submitted(0);
        }
        tc.record_shed(0);
        tc.record_submitted(1);
        tc.tick(TICK_NS);
        let s0 = tc.signals(0).unwrap();
        let s1 = tc.signals(1).unwrap();
        assert_eq!(s0.offered, 10);
        assert!((s0.shed_rate - 0.1).abs() < 1e-12);
        assert_eq!(s1.offered, 1);
        assert_eq!(s1.shed, 0);
    }
}
