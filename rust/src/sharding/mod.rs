//! Pipeline-parallel multi-device sharding.
//!
//! The paper's porting story fits one dataflow accelerator into the OCM of
//! a *single* smaller device (7020→7012S, U250→U280). This subsystem opens
//! the scenario the ROADMAP calls "multi-device floorplan-aware sharding":
//! a network that fits *no* single device — even FCMP-packed — is split
//! into `k` contiguous **stage shards** placed on a heterogeneous device
//! list and served as a staged pipeline:
//!
//! ```text
//!   frames ─> [ shard 0 on dev A ] ─link─> [ shard 1 on dev B ] ─link─> … ─> out
//!              stages 0..c1              stages c1..c2
//!              FCMP-packed per shard     bounded inter-device FIFOs
//! ```
//!
//! * [`partition()`] — exact DP over contiguous covers, minimizing the
//!   wall-clock bottleneck (shard II ÷ per-device effective clock, or a
//!   link's store-and-forward interval) subject to per-device BRAM / URAM /
//!   LUT feasibility *after* invoking the FCMP packer on every candidate
//!   shard (memoized range-wise and process-wide).
//! * [`LinkSpec`] / [`cut_traffic_bits`] — the inter-shard transport
//!   model, including the doubled stream when a resblock's bypass
//!   duplication point crosses a cut.
//! * [`crate::sim::pipeline::simulate_sharded`] — discrete-event
//!   validation that the staged pipeline's steady state matches
//!   [`ShardPlan::fps`].
//! * [`crate::coordinator::Server::deploy`] with a
//!   [`crate::coordinator::Deployment::chain`] plan — serves a plan as a
//!   chain group: every frame traverses shard 0..k-1 in order over
//!   bounded queues, with per-stage, per-group and end-to-end latency
//!   metrics; [`crate::coordinator::Deployment::replicated_chains`] puts
//!   N parallel copies of the chain behind the router once one
//!   pipeline's bottleneck is the throughput limit.
//!
//! CLI: `fcmp shard --network cnv-w2a2 --devices zynq7012s,zynq7012s
//! --shards 2 [--serve --chains N]`; bench: `shard_scaling` →
//! `BENCH_sharding.json`.

pub mod link;
pub mod partition;

pub use link::{cut_traffic_bits, LinkSpec};
pub use partition::{
    fits_packed, partition, Evaluator, Link, PartitionConfig, Shard, ShardPlan,
    LINK_FIFO_BRAMS,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{zynq_7012s, zynq_7020};
    use crate::nn::{cnv, CnvVariant};

    fn ffd_cfg() -> PartitionConfig {
        PartitionConfig { generations: 0, ..PartitionConfig::default() }
    }

    #[test]
    fn w2a2_needs_sharding_w1a1_does_not() {
        // the paper ports CNV-W1A1-P4 onto one 7012S; the doubled weight
        // bits of W2A2 overflow it even packed — the sharding scenario
        let small = zynq_7012s();
        assert!(fits_packed(&cnv(CnvVariant::W1A1), &small, ffd_cfg()));
        assert!(!fits_packed(&cnv(CnvVariant::W2A2), &small, ffd_cfg()));
    }

    #[test]
    fn two_7012s_host_what_one_cannot() {
        let net = cnv(CnvVariant::W2A2);
        let devs = [zynq_7012s(), zynq_7012s()];
        let plan = partition(&net, &devs, ffd_cfg()).expect("2-shard cover");
        assert_eq!(plan.shards.len(), 2);
        assert_eq!(plan.links.len(), 1);
        for s in &plan.shards {
            assert!(s.fits(), "shard {:?} overflows", s.stages);
            assert!(s.bram_demand <= s.bram_capacity);
        }
        // contiguous exhaustive cover
        let a = plan.assignment();
        assert_eq!(a.len(), net.stages.len());
        assert!(a.windows(2).all(|w| w[1] == w[0] || w[1] == w[0] + 1));
        assert_eq!(*a.last().unwrap(), 1);
        assert!(plan.fps > 0.0 && plan.bottleneck_s > 0.0);
    }

    #[test]
    fn plan_bottleneck_consistent_with_members() {
        let net = cnv(CnvVariant::W2A2);
        let devs = [zynq_7020(), zynq_7012s()];
        let plan = partition(&net, &devs, ffd_cfg()).unwrap();
        let worst_shard = plan.shards.iter().map(|s| s.seconds_per_frame).fold(0.0, f64::max);
        let worst_link = plan.links.iter().map(|l| l.seconds_per_frame).fold(0.0, f64::max);
        assert!((plan.bottleneck_s - worst_shard.max(worst_link)).abs() < 1e-15);
        assert!((plan.fps * plan.bottleneck_s - 1.0).abs() < 1e-12);
        for u in plan.link_utilization() {
            assert!(u > 0.0 && u <= 1.0 + 1e-12);
        }
    }

    #[test]
    fn slow_links_become_the_bottleneck() {
        let net = cnv(CnvVariant::W2A2);
        let devs = [zynq_7012s(), zynq_7012s()];
        // a near-zero-bandwidth link dominates any shard's II
        let cfg = PartitionConfig {
            generations: 0,
            link: LinkSpec { gbps: 0.0001, latency_us: 2.0 },
            ..PartitionConfig::default()
        };
        let plan = partition(&net, &devs, cfg).unwrap();
        assert!(plan.bottleneck_is_link(), "links {:?}", plan.links);
        let fast = partition(&net, &devs, ffd_cfg()).unwrap();
        assert!(plan.fps < fast.fps);
    }

    #[test]
    fn precheck_rejects_fleets_with_too_little_total_ocm() {
        // the cover-kernel pre-check fires before any packer runs: two
        // 8-BRAM devices can never host CNV-W2A2's weight bits
        let mut tiny = zynq_7012s();
        tiny.bram18 = 8;
        for slr in &mut tiny.slrs {
            slr.bram18 = 8;
        }
        let err = partition(&cnv(CnvVariant::W2A2), &[tiny.clone(), tiny], ffd_cfg());
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("OCM"));
    }

    #[test]
    fn too_many_shards_rejected() {
        let net = cnv(CnvVariant::W1A1);
        let devs: Vec<_> = (0..net.stages.len() + 1).map(|_| zynq_7020()).collect();
        assert!(partition(&net, &devs, ffd_cfg()).is_err());
        assert!(partition(&net, &[], ffd_cfg()).is_err());
    }
}
