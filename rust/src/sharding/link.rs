//! Inter-shard link model.
//!
//! A pipeline cut between two devices turns one intra-FPGA AXI stream into
//! a board-to-board transport (Aurora/QSFP in the FINN multi-FPGA setting,
//! NICs in a host-mediated one). The model is store-and-forward at frame
//! granularity: a frame occupies the link for its serialization time plus
//! a fixed per-frame latency, and back-to-back frames do not overlap — so
//! the link behaves exactly like one more pipeline stage whose initiation
//! interval is [`LinkSpec::seconds_per_frame`]. Bounded FIFOs on both ends
//! (the sharded-pipeline simulator's `link_fifo` knob) absorb jitter.
//!
//! Cut traffic comes from the activation tensor crossing the boundary
//! ([`crate::nn::Stage::output_bits_per_frame`]). When the stage *after*
//! the cut is a residual block, the tensor is consumed twice on the remote
//! device — once by the branch, once by the bypass FIFO (§III.B) — and
//! since the duplication point moves across the link, the cut carries the
//! stream twice.

use crate::nn::{Network, Stage};

/// Bandwidth/latency of one inter-device link.
#[derive(Clone, Copy, Debug)]
pub struct LinkSpec {
    /// Usable link bandwidth in Gbit/s.
    pub gbps: f64,
    /// Fixed per-frame transport latency in microseconds.
    pub latency_us: f64,
}

impl LinkSpec {
    /// A 100G Aurora/QSFP-class board-to-board link.
    pub fn default_100g() -> LinkSpec {
        LinkSpec { gbps: 100.0, latency_us: 2.0 }
    }

    /// Seconds one frame of `bits` occupies the link (serialization +
    /// fixed latency; store-and-forward, no overlap between frames).
    pub fn seconds_per_frame(&self, bits: u64) -> f64 {
        assert!(self.gbps > 0.0, "link bandwidth must be positive");
        bits as f64 / (self.gbps * 1e9) + self.latency_us * 1e-6
    }
}

/// Activation bits per frame crossing a cut placed *after* stage
/// `cut_after` (so between `cut_after` and `cut_after + 1`). Doubled when
/// the downstream stage is a residual block (its input feeds both the
/// branch and the bypass FIFO on the remote device).
pub fn cut_traffic_bits(net: &Network, cut_after: usize) -> u64 {
    assert!(
        cut_after + 1 < net.stages.len(),
        "cut after stage {cut_after} leaves no downstream stage"
    );
    let mut bits = net.stages[cut_after].output_bits_per_frame();
    if matches!(net.stages[cut_after + 1], Stage::ResBlock { .. }) {
        bits *= 2;
    }
    bits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{cnv, resnet50, CnvVariant};

    #[test]
    fn link_time_combines_serialization_and_latency() {
        let l = LinkSpec { gbps: 10.0, latency_us: 5.0 };
        // 10 Gbit at 10 Gb/s = 1 s, plus 5 us
        let t = l.seconds_per_frame(10_000_000_000);
        assert!((t - 1.000_005).abs() < 1e-9, "{t}");
        // zero payload still pays the latency
        assert!((l.seconds_per_frame(0) - 5e-6).abs() < 1e-12);
    }

    #[test]
    fn cnv_cut_traffic_shrinks_down_the_pipeline() {
        // feature maps shrink through the conv stack, so later cuts are
        // cheaper — the partitioner's incentive to cut late
        let net = cnv(CnvVariant::W2A2);
        let early = cut_traffic_bits(&net, 1); // after conv2
        let late = cut_traffic_bits(&net, net.stages.len() - 2);
        assert!(early > 50 * late, "early {early} vs late {late}");
    }

    #[test]
    fn resblock_bypass_doubles_cut_traffic() {
        let net = resnet50(1);
        // find a cut whose downstream stage is a resblock
        let i = net
            .stages
            .iter()
            .enumerate()
            .position(|(i, s)| {
                i + 1 < net.stages.len()
                    && matches!(net.stages[i + 1], crate::nn::Stage::ResBlock { .. })
                    && !matches!(s, crate::nn::Stage::ResBlock { .. })
            })
            .expect("rn50 has a non-resblock stage feeding a resblock");
        let single = net.stages[i].output_bits_per_frame();
        assert_eq!(cut_traffic_bits(&net, i), 2 * single);
    }
}
