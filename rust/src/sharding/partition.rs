//! Bottleneck-minimal contiguous partition of a network over a device fleet.
//!
//! The floorplanner's monotone linear-partition trick
//! ([`crate::device::floorplan`]) assigns stages to SLRs *within* one
//! device; here the same contiguity structure is lifted to devices *within
//! a fleet*, with three generalizations that break the binary-search
//! formulation and call for dynamic programming instead:
//!
//! 1. **Heterogeneous capacity** — every shard must fit its own device
//!    *after* FCMP packing, so shard cost is not additive in the stages:
//!    the packer runs per candidate stage range (memoized by range and
//!    device via [`crate::packing::cache`]).
//! 2. **Heterogeneous speed** — the objective is wall-clock bottleneck
//!    (seconds/frame = shard II ÷ that device's post-timing-closure
//!    clock), not a resource bottleneck.
//! 3. **Links** — each cut inserts a store-and-forward link stage whose
//!    initiation interval competes for the bottleneck
//!    ([`super::link`]).
//!
//! `dp[j][i]` = the best achievable bottleneck covering stages `[0, i)`
//! with the first `j` devices (all shards non-empty); the transition
//! scans the last cut `m` and takes
//! `max(dp[j-1][m], link(m-1), shard(m..i, device_j))`. `max`/`min`
//! compose monotonically, so the DP is exact over all contiguous covers.

use std::collections::HashMap;

use super::link::{cut_traffic_bits, LinkSpec};
use crate::device::Device;
use crate::memory;
use crate::nn::Network;
use crate::{folding, report, timing};

/// Partitioner configuration.
#[derive(Clone, Copy, Debug)]
pub struct PartitionConfig {
    /// FCMP bin height `H_B` for every shard's weight subsystem.
    pub bin_height: usize,
    /// GA generations per shard packing; `0` selects the deterministic FFD
    /// baseline (fast sweeps, property tests, benches).
    pub generations: usize,
    /// Packing seed.
    pub seed: u64,
    /// Inter-device link model applied at every cut.
    pub link: LinkSpec,
}

impl Default for PartitionConfig {
    fn default() -> Self {
        PartitionConfig {
            bin_height: 4,
            generations: 40,
            seed: 2020,
            link: LinkSpec::default_100g(),
        }
    }
}

/// BRAM18 budget one shard reserves per inter-device boundary it touches
/// (ingress/egress link FIFO, CDC).
pub const LINK_FIFO_BRAMS: u64 = 4;

/// One stage shard placed on one device.
#[derive(Clone, Debug)]
pub struct Shard {
    pub device: Device,
    /// Stage range `[start, end)` of the parent network.
    pub stages: (usize, usize),
    /// FCMP-packed weight-subsystem BRAM18 count.
    pub packed_brams: u64,
    /// Total BRAM18 demand: packed weights + packing-excluded weight
    /// buffers (BRAM-resident on Zynq-class parts) + the activation/FIFO
    /// allocation of the shard's stages + link FIFOs per touched boundary.
    pub bram_demand: u64,
    /// Device BRAM18 capacity.
    pub bram_capacity: u64,
    /// URAM demand/capacity (activations on Alveo-class parts).
    pub uram_demand: u64,
    pub uram_capacity: u64,
    /// LUT utilization (compute + streamer logic + shell) of the device.
    pub lut_util: f64,
    /// Shard initiation interval in compute cycles (slowest stage).
    pub ii_cycles: u64,
    /// Effective compute clock after timing closure and memory-side
    /// throttling at `R_F = H_B / 2`.
    pub effective_mhz: f64,
    /// Seconds per frame: `ii_cycles / (effective_mhz · 1e6)`.
    pub seconds_per_frame: f64,
}

impl Shard {
    /// Does the shard fit its device?
    pub fn fits(&self) -> bool {
        self.bram_demand <= self.bram_capacity
            && self.uram_demand <= self.uram_capacity
            && self.lut_util <= 1.0
    }

    /// BRAM pressure (demand / capacity).
    pub fn bram_pressure(&self) -> f64 {
        self.bram_demand as f64 / self.bram_capacity.max(1) as f64
    }
}

/// One inter-shard link of a plan.
#[derive(Clone, Debug)]
pub struct Link {
    /// Activation bits per frame crossing the cut.
    pub bits_per_frame: u64,
    /// Link initiation interval in seconds.
    pub seconds_per_frame: f64,
}

/// A complete sharded deployment plan.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// Parent network name.
    pub network: String,
    /// The shards in pipeline order (one per device).
    pub shards: Vec<Shard>,
    /// The `shards.len() - 1` links between consecutive shards.
    pub links: Vec<Link>,
    /// Bottleneck initiation interval in seconds (max over shards+links).
    pub bottleneck_s: f64,
    /// Steady-state frames/s = `1 / bottleneck_s`.
    pub fps: f64,
}

impl ShardPlan {
    /// Stage index → shard index.
    pub fn assignment(&self) -> Vec<usize> {
        let mut out = Vec::new();
        for (si, s) in self.shards.iter().enumerate() {
            for _ in s.stages.0..s.stages.1 {
                out.push(si);
            }
        }
        out
    }

    /// Is a link (not a shard) the pipeline bottleneck?
    pub fn bottleneck_is_link(&self) -> bool {
        self.links.iter().any(|l| l.seconds_per_frame >= self.bottleneck_s - 1e-15)
    }

    /// Per-link occupancy relative to the bottleneck (1.0 = the link IS
    /// the bottleneck).
    pub fn link_utilization(&self) -> Vec<f64> {
        self.links.iter().map(|l| l.seconds_per_frame / self.bottleneck_s).collect()
    }
}

/// Evaluates candidate shards, memoizing by `(start, end, device)`. The
/// inner packing is additionally memoized process-wide by
/// [`crate::packing::cache`], so repeated partitioning runs (benches,
/// property tests sampling alternatives) pay for each range once.
pub struct Evaluator<'a> {
    net: &'a Network,
    cfg: PartitionConfig,
    /// Keyed by `(start, end, device fingerprint)` — the fingerprint, not
    /// the name, so same-named devices with tweaked capacities never
    /// share a cached shard.
    memo: HashMap<(usize, usize, String), Shard>,
}

impl<'a> Evaluator<'a> {
    pub fn new(net: &'a Network, cfg: PartitionConfig) -> Evaluator<'a> {
        Evaluator { net, cfg, memo: HashMap::new() }
    }

    /// Evaluate stages `[start, end)` on `dev` (always returns a shard;
    /// check [`Shard::fits`] for feasibility).
    pub fn shard(&mut self, start: usize, end: usize, dev: &Device) -> Shard {
        let key = (start, end, dev.fingerprint());
        if let Some(hit) = self.memo.get(&key) {
            return hit.clone();
        }
        let s = self.evaluate(start, end, dev);
        self.memo.insert(key, s.clone());
        s
    }

    fn evaluate(&self, start: usize, end: usize, dev: &Device) -> Shard {
        let sub = self.net.slice(start, end);
        let packed = report::pack_network_cached(
            &sub,
            dev,
            self.cfg.bin_height,
            self.cfg.generations,
            self.cfg.seed,
        );
        let use_uram = dev.uram > 0;
        // packing-excluded layers: URAM/HBM/DDR on Alveo (§V), BRAM on Zynq
        let excluded: u64 = if use_uram {
            0
        } else {
            sub.layers()
                .iter()
                .filter(|l| l.exclude_from_packing)
                .map(|l| memory::WeightBuffer::from_layer(l, 0).brams())
                .sum()
        };
        // activation/FIFO storage: URAM on Alveo; on Zynq the conservative
        // HLS FIFO allocation is halved, matching the §V porting builds
        // (FIFOs are re-sized to fit when porting — port_device example)
        let (act_brams, uram_demand) = if use_uram {
            (0, memory::activation_urams(&sub))
        } else {
            (memory::activation_brams(&sub) / 2, 0)
        };
        let boundaries = (start > 0) as u64 + ((end < self.net.stages.len()) as u64);
        let bram_demand = packed.report.brams + excluded + act_brams + LINK_FIFO_BRAMS * boundaries;

        let res = folding::network_resources(&sub, dev);
        let lut_util = folding::packed_lut_util(&res, packed.logic_kluts, dev);
        let rf = self.cfg.bin_height as f64 / 2.0;
        let target = dev.nominal_compute_mhz;
        let t = timing::evaluate(dev, lut_util.min(1.0), target, rf, target);
        let ii_cycles = sub.initiation_interval().max(1);
        let seconds_per_frame = ii_cycles as f64 / (t.effective_fc_mhz * 1e6);
        Shard {
            device: dev.clone(),
            stages: (start, end),
            packed_brams: packed.report.brams,
            bram_demand,
            bram_capacity: dev.bram18,
            uram_demand,
            uram_capacity: dev.uram,
            lut_util,
            ii_cycles,
            effective_mhz: t.effective_fc_mhz,
            seconds_per_frame,
        }
    }

    /// Bottleneck (seconds/frame) of an explicit partition given by `cuts`
    /// (ascending stage indices where shard `j` is `[cuts[j-1], cuts[j])`,
    /// with implicit 0 and `n` sentinels), or `None` when any shard
    /// overflows its device. Used by the optimality property test to score
    /// sampled alternatives against the DP's choice.
    pub fn bottleneck_of(&mut self, devices: &[Device], cuts: &[usize]) -> Option<f64> {
        let n = self.net.stages.len();
        assert_eq!(cuts.len() + 1, devices.len(), "k shards need k-1 cuts");
        let mut bounds = Vec::with_capacity(devices.len() + 1);
        bounds.push(0);
        bounds.extend_from_slice(cuts);
        bounds.push(n);
        let mut worst = 0.0f64;
        for (j, dev) in devices.iter().enumerate() {
            let (s, e) = (bounds[j], bounds[j + 1]);
            if s >= e || e > n {
                return None;
            }
            let shard = self.shard(s, e, dev);
            if !shard.fits() {
                return None;
            }
            worst = worst.max(shard.seconds_per_frame);
            if j > 0 {
                let bits = cut_traffic_bits(self.net, s - 1);
                worst = worst.max(self.cfg.link.seconds_per_frame(bits));
            }
        }
        Some(worst)
    }
}

/// Does the whole network, FCMP-packed, fit a single device? (The
/// single-shard degenerate of the partitioner — the "must we shard at
/// all?" question.)
pub fn fits_packed(net: &Network, dev: &Device, cfg: PartitionConfig) -> bool {
    Evaluator::new(net, cfg).shard(0, net.stages.len(), dev).fits()
}

/// Partition `net` over `devices` (one shard per device, in order) into
/// the contiguous cover minimizing the bottleneck initiation interval,
/// subject to every shard fitting its device after FCMP packing. Errors
/// when the device list is empty, longer than the stage count, or no
/// feasible cover exists.
pub fn partition(
    net: &Network,
    devices: &[Device],
    cfg: PartitionConfig,
) -> crate::Result<ShardPlan> {
    let k = devices.len();
    let n = net.stages.len();
    anyhow::ensure!(k > 0, "sharding needs at least one device");
    anyhow::ensure!(
        k <= n,
        "{k} shards over {n} stages: every shard needs at least one stage"
    );

    // Fast infeasibility pre-check via the floorplanner's cover kernel
    // with heterogeneous caps. Per-stage floor(weight_bits / 18 Kib) is a
    // sound lower bound on any shard's packed BRAM demand on every device
    // class (summed floor divisions never exceed the shard's
    // information-theoretic bits/18Kib bound, which no packing can beat),
    // so if even these floors admit no monotone cover of the fleet's BRAM
    // capacities, no partition exists and the DP (and its packer
    // invocations) can be skipped entirely.
    let floors: Vec<u64> = net
        .stages
        .iter()
        .map(|s| {
            let bits: u64 = s
                .layers()
                .iter()
                .filter(|l| !l.exclude_from_packing)
                .map(|l| l.weight_bits())
                .sum();
            bits / crate::device::BRAM18_BITS
        })
        .collect();
    let caps: Vec<u64> = devices.iter().map(|d| d.bram18).collect();
    anyhow::ensure!(
        crate::device::contiguous_cover(&floors, &caps).is_some(),
        "{} does not partition over {:?}: total weight bits exceed the fleet's OCM",
        net.name,
        devices.iter().map(|d| d.name).collect::<Vec<_>>()
    );

    let mut ev = Evaluator::new(net, cfg);

    // dp[j][i]: best bottleneck covering stages [0, i) with j shards
    let inf = f64::INFINITY;
    let mut dp = vec![vec![inf; n + 1]; k + 1];
    let mut prev = vec![vec![usize::MAX; n + 1]; k + 1];
    dp[0][0] = 0.0;
    for j in 1..=k {
        let dev = &devices[j - 1];
        // shard j-1 spans [m, i); i is bounded so every later shard keeps
        // at least one stage, and the final layer only needs the full
        // cover (skipping it keeps a k=2 sweep at O(S) packs, not O(S²))
        let lo = if j == k { n } else { j };
        for i in lo..=(n - (k - j)) {
            for m in (j - 1)..i {
                if dp[j - 1][m].is_infinite() {
                    continue;
                }
                let shard = ev.shard(m, i, dev);
                if !shard.fits() {
                    continue;
                }
                let mut cost = dp[j - 1][m].max(shard.seconds_per_frame);
                if m > 0 {
                    let bits = cut_traffic_bits(net, m - 1);
                    cost = cost.max(cfg.link.seconds_per_frame(bits));
                }
                if cost < dp[j][i] {
                    dp[j][i] = cost;
                    prev[j][i] = m;
                }
            }
        }
    }
    anyhow::ensure!(
        dp[k][n].is_finite(),
        "{} does not partition over {:?}: no contiguous {}-shard cover fits",
        net.name,
        devices.iter().map(|d| d.name).collect::<Vec<_>>(),
        k
    );

    // reconstruct cut points
    let mut bounds = vec![n];
    let mut i = n;
    for j in (1..=k).rev() {
        i = prev[j][i];
        bounds.push(i);
    }
    bounds.reverse();
    debug_assert_eq!(bounds[0], 0);

    let mut shards = Vec::with_capacity(k);
    let mut links = Vec::with_capacity(k - 1);
    let mut bottleneck = 0.0f64;
    for j in 0..k {
        let (s, e) = (bounds[j], bounds[j + 1]);
        let shard = ev.shard(s, e, &devices[j]);
        bottleneck = bottleneck.max(shard.seconds_per_frame);
        if j > 0 {
            let bits = cut_traffic_bits(net, s - 1);
            let secs = cfg.link.seconds_per_frame(bits);
            bottleneck = bottleneck.max(secs);
            links.push(Link { bits_per_frame: bits, seconds_per_frame: secs });
        }
        shards.push(shard);
    }
    Ok(ShardPlan {
        network: net.name.clone(),
        shards,
        links,
        bottleneck_s: bottleneck,
        fps: 1.0 / bottleneck,
    })
}
