//! FPGA device models (DESIGN.md substitution for real Zynq/Alveo silicon).
//!
//! Everything the paper's claims are phrased in — LUT / BRAM18 / URAM / DSP
//! budgets, SLR (super logic region) geometry for multi-die Alveo parts, and
//! nominal clock targets — is represented here with the public datasheet
//! numbers for the four parts the paper evaluates (Zynq 7020 / 7012S, Alveo
//! U250 / U280).

pub mod bram;
pub mod floorplan;

pub use bram::{brams_for, BramMode, BRAM18_BITS, BRAM18_MODES, URAM_BITS};
pub use floorplan::{contiguous_cover, floorplan, Floorplan};

/// One super logic region (die) of a multi-SLR device.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Slr {
    pub luts: u64,
    pub bram18: u64,
    pub uram: u64,
    pub dsp: u64,
}

/// An FPGA part.
#[derive(Clone, Debug, PartialEq)]
pub struct Device {
    pub name: &'static str,
    pub family: Family,
    pub luts: u64,
    pub bram18: u64,
    pub uram: u64,
    pub dsp: u64,
    /// SLR regions; a single entry means a monolithic die.
    pub slrs: Vec<Slr>,
    /// Nominal compute-domain clock target for dataflow designs (MHz).
    pub nominal_compute_mhz: f64,
    /// Nominal (overclocked) memory-domain clock target (MHz).
    pub nominal_memory_mhz: f64,
    /// BRAM primitive specified Fmax (MHz) — the hard ceiling for R_F.
    pub bram_fmax_mhz: f64,
    /// LUTs consumed by the static platform shell (Alveo XDMA/HBM shell;
    /// zero on Zynq where the PS replaces it).
    pub shell_luts: u64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    Zynq7000,
    UltraScalePlus,
}

impl Device {
    pub fn is_monolithic(&self) -> bool {
        self.slrs.len() == 1
    }

    /// Compact identity string covering every field the packing and
    /// sharding models read. Cache/memo keys must use this rather than
    /// `name` alone — tests and callers legitimately tweak a named
    /// device's capacities in place, and a name-only key would hand them
    /// another device's cached design.
    pub fn fingerprint(&self) -> String {
        format!(
            "{}#{}l#{}b#{}u#{}d#{}slr#{}fc#{}fm#{}sh",
            self.name,
            self.luts,
            self.bram18,
            self.uram,
            self.dsp,
            self.slrs.len(),
            self.nominal_compute_mhz,
            self.nominal_memory_mhz,
            self.shell_luts
        )
    }

    /// Total OCM (BRAM only) in bits.
    pub fn bram_bits(&self) -> u64 {
        self.bram18 * BRAM18_BITS
    }

    /// Uniform split of a monolithic budget into SLR entries.
    fn split(luts: u64, bram18: u64, uram: u64, dsp: u64, n: u64) -> Vec<Slr> {
        (0..n)
            .map(|_| Slr { luts: luts / n, bram18: bram18 / n, uram: uram / n, dsp: dsp / n })
            .collect()
    }
}

/// Zynq-7020 (the BNN-Pynq target, Table I).
pub fn zynq_7020() -> Device {
    Device {
        name: "zynq-7020",
        family: Family::Zynq7000,
        luts: 53_200,
        bram18: 280, // 140 x RAMB36 = 280 x 18Kb
        uram: 0,
        dsp: 220,
        slrs: Device::split(53_200, 280, 0, 220, 1),
        nominal_compute_mhz: 100.0,
        nominal_memory_mhz: 200.0,
        bram_fmax_mhz: 388.0, // -1 speed grade block RAM spec
        shell_luts: 0,
    }
}

/// Zynq-7012S — the smaller part the paper ports CNV-W1A1-P4 onto (Table V).
pub fn zynq_7012s() -> Device {
    Device {
        name: "zynq-7012s",
        family: Family::Zynq7000,
        luts: 34_400,
        bram18: 144, // 72 x RAMB36
        uram: 0,
        dsp: 120,
        slrs: Device::split(34_400, 144, 0, 120, 1),
        nominal_compute_mhz: 100.0,
        nominal_memory_mhz: 200.0,
        bram_fmax_mhz: 388.0,
        shell_luts: 0,
    }
}

/// Alveo U250 — the paper's large RN50 target (4 SLRs).
pub fn alveo_u250() -> Device {
    Device {
        name: "alveo-u250",
        family: Family::UltraScalePlus,
        luts: 1_728_000,
        bram18: 5_376, // 2688 x RAMB36
        uram: 1_280,
        dsp: 12_288,
        slrs: Device::split(1_728_000, 5_376, 1_280, 12_288, 4),
        nominal_compute_mhz: 200.0,
        nominal_memory_mhz: 400.0,
        bram_fmax_mhz: 650.0, // UltraScale+ block RAM spec
        shell_luts: 100_000,  // XDMA shell
    }
}

/// Alveo U280 — the smaller 3-SLR + HBM card (port target, Table V).
pub fn alveo_u280() -> Device {
    Device {
        name: "alveo-u280",
        family: Family::UltraScalePlus,
        luts: 1_304_000,
        bram18: 4_032, // 2016 x RAMB36
        uram: 960,
        dsp: 9_024,
        slrs: Device::split(1_304_000, 4_032, 960, 9_024, 3),
        nominal_compute_mhz: 200.0,
        nominal_memory_mhz: 400.0,
        bram_fmax_mhz: 650.0,
        shell_luts: 160_000,  // XDMA + HBM shell
    }
}

/// Look a device up by name (CLI surface).
pub fn by_name(name: &str) -> Option<Device> {
    match name {
        "zynq-7020" | "zynq7020" | "7020" => Some(zynq_7020()),
        "zynq-7012s" | "zynq7012s" | "7012s" => Some(zynq_7012s()),
        "alveo-u250" | "alveou250" | "u250" => Some(alveo_u250()),
        "alveo-u280" | "alveou280" | "u280" => Some(alveo_u280()),
        _ => None,
    }
}

/// All modelled devices.
pub fn all() -> Vec<Device> {
    vec![zynq_7020(), zynq_7012s(), alveo_u250(), alveo_u280()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn datasheet_sanity() {
        let d = zynq_7020();
        assert_eq!(d.bram_bits(), 280 * 18 * 1024);
        assert!(d.is_monolithic());
        let u250 = alveo_u250();
        assert_eq!(u250.slrs.len(), 4);
        assert_eq!(u250.slrs.iter().map(|s| s.bram18).sum::<u64>(), 5_376);
    }

    #[test]
    fn ordering_of_sizes() {
        // the paper's porting story requires these strict orders
        assert!(zynq_7012s().bram18 < zynq_7020().bram18);
        assert!(zynq_7012s().luts < zynq_7020().luts);
        assert!(alveo_u280().bram18 < alveo_u250().bram18);
        assert!(alveo_u280().luts < alveo_u250().luts);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("u280").unwrap().name, "alveo-u280");
        assert_eq!(by_name("7020").unwrap().name, "zynq-7020");
        assert!(by_name("vu9p").is_none());
    }

    #[test]
    fn memory_overclock_within_bram_spec() {
        for d in all() {
            assert!(d.nominal_memory_mhz <= d.bram_fmax_mhz,
                "{}: memory target exceeds BRAM primitive spec", d.name);
        }
    }
}
