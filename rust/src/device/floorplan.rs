//! SLR floorplanner — the paper's future-work item ("integrating the memory
//! packing approach into a design space exploration framework to perform
//! automatic floorplanning"). Assigns pipeline stages to SLRs such that the
//! dataflow order is preserved (stages map to a monotone SLR sequence — a
//! daisy-chain crosses each SLR boundary once, Fig. 5) while minimizing the
//! maximum per-SLR resource pressure.
//!
//! With the monotone constraint the problem is a balanced-partition of a
//! sequence into `k` contiguous runs — solved exactly by binary search on
//! the bottleneck + greedy feasibility (the classic linear-partition trick).

use super::Device;
use crate::folding::layer_resources;
use crate::nn::{Network, Stage};

/// Per-stage resource demand used by the floorplanner.
#[derive(Clone, Debug)]
pub struct StageDemand {
    pub name: String,
    pub luts: f64,
    pub bram18: u64,
}

/// Extract per-stage demands from a network.
pub fn stage_demands(net: &Network) -> Vec<StageDemand> {
    net.stages
        .iter()
        .map(|s| {
            let name = match s {
                Stage::Mvau(l) => l.name.clone(),
                Stage::MaxPool { name, .. } => name.clone(),
                Stage::ResBlock { name, .. } => name.clone(),
            };
            let luts: f64 = s.layers().iter().map(|l| layer_resources(l).luts).sum();
            // excluded layers (first conv, classifier) keep weights in
            // URAM/HBM/DDR per §V and do not pressure the BRAM floorplan
            let bram: u64 = s
                .layers()
                .iter()
                .filter(|l| !l.exclude_from_packing)
                .map(|l| crate::memory::WeightBuffer::from_layer(l, 0).brams())
                .sum();
            StageDemand { name, luts, bram18: bram }
        })
        .collect()
}

/// A floorplan: stage index -> SLR.
#[derive(Clone, Debug)]
pub struct Floorplan {
    pub assignment: Vec<usize>,
    /// Max over SLRs of the BRAM pressure (fraction of SLR capacity).
    pub max_bram_pressure: f64,
    /// Max over SLRs of the LUT pressure.
    pub max_lut_pressure: f64,
    /// Number of SLR boundary crossings (== k-1 for a daisy chain).
    pub crossings: usize,
}

/// Greedy monotone cover of a demand sequence by at most `caps.len()`
/// contiguous runs, run `j` bounded by `caps[j]`. This is the linear-
/// partition feasibility kernel shared by the uniform-SLR floorplanner
/// (all caps equal, binary-searched) and, with heterogeneous capacities,
/// the multi-device sharding partitioner
/// ([`crate::sharding::partition()`]), which runs it over per-stage
/// weight-bit floors as a sound infeasibility pre-check before its DP.
/// Greedy-maximal prefix filling is complete for this feasibility
/// question (exchange argument: greedy never places an element in a
/// later run than any valid cover does). Returns the per-element run
/// index, or `None` when no monotone cover exists (a run may be skipped
/// — left empty — when its capacity cannot host the next element).
pub fn contiguous_cover(demands: &[u64], caps: &[u64]) -> Option<Vec<usize>> {
    if caps.is_empty() {
        return if demands.is_empty() { Some(Vec::new()) } else { None };
    }
    let mut assignment = Vec::with_capacity(demands.len());
    let mut run = 0usize;
    let mut acc = 0u64;
    for &d in demands {
        while acc + d > caps[run] {
            run += 1;
            acc = 0;
            if run >= caps.len() {
                return None;
            }
        }
        acc += d;
        assignment.push(run);
    }
    Some(assignment)
}

/// Can the sequence be split into `k` contiguous runs with every run's BRAM
/// demand ≤ `limit`? (Uniform-capacity [`contiguous_cover`].)
fn feasible(demands: &[StageDemand], k: usize, limit: u64) -> Option<Vec<usize>> {
    let d: Vec<u64> = demands.iter().map(|d| d.bram18).collect();
    contiguous_cover(&d, &vec![limit; k])
}

/// Compute the optimal monotone floorplan for `net` on `dev` (bottleneck
/// BRAM minimized; LUT pressure reported). Returns None if even one stage
/// exceeds an SLR.
pub fn floorplan(net: &Network, dev: &Device) -> Option<Floorplan> {
    let k = dev.slrs.len();
    let demands = stage_demands(net);
    let total: u64 = demands.iter().map(|d| d.bram18).sum();
    let (mut lo, mut hi) = (total / k as u64, total);
    let mut best: Option<Vec<usize>> = feasible(&demands, k, hi);
    best.as_ref()?;
    while lo < hi {
        let mid = (lo + hi) / 2;
        match feasible(&demands, k, mid) {
            Some(a) => {
                best = Some(a);
                hi = mid;
            }
            None => lo = mid + 1,
        }
    }
    let assignment = feasible(&demands, k, hi).or(best)?;

    // pressures per SLR
    let mut bram = vec![0u64; k];
    let mut luts = vec![0f64; k];
    for (i, d) in demands.iter().enumerate() {
        bram[assignment[i]] += d.bram18;
        luts[assignment[i]] += d.luts;
    }
    let max_bram_pressure = bram
        .iter()
        .zip(&dev.slrs)
        .map(|(&b, s)| b as f64 / s.bram18.max(1) as f64)
        .fold(0.0, f64::max);
    let max_lut_pressure = luts
        .iter()
        .zip(&dev.slrs)
        .map(|(&l, s)| l / s.luts.max(1) as f64)
        .fold(0.0, f64::max);
    let crossings = assignment.windows(2).filter(|w| w[0] != w[1]).count();
    // infeasible if the best bottleneck still exceeds an SLR's capacity
    if max_bram_pressure > 1.0 {
        return None;
    }
    Some(Floorplan { assignment, max_bram_pressure, max_lut_pressure, crossings })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{alveo_u250, alveo_u280};
    use crate::nn::resnet50;

    #[test]
    fn rn50_u250_floorplan_like_fig5() {
        let net = resnet50(1);
        let dev = alveo_u250();
        let fp = floorplan(&net, &dev).expect("feasible on U250");
        // monotone daisy-chain with at most k-1 crossings
        assert!(fp.crossings <= dev.slrs.len() - 1);
        assert!(fp.assignment.windows(2).all(|w| w[0] <= w[1]));
        // balanced enough to place
        assert!(fp.max_bram_pressure < 1.0, "pressure {}", fp.max_bram_pressure);
    }

    #[test]
    fn floorplan_beats_naive_bit_balance() {
        // the optimizer's bottleneck must be <= the memory::weight_buffers
        // bit-balanced assignment's bottleneck
        let net = resnet50(1);
        let dev = alveo_u250();
        let fp = floorplan(&net, &dev).unwrap();
        let demands = stage_demands(&net);
        let k = dev.slrs.len();
        let naive: Vec<usize> =
            (0..demands.len()).map(|i| i * k / demands.len()).collect();
        let mut naive_bram = vec![0u64; k];
        for (i, d) in demands.iter().enumerate() {
            naive_bram[naive[i]] += d.bram18;
        }
        let naive_max = *naive_bram.iter().max().unwrap() as f64
            / dev.slrs[0].bram18 as f64;
        assert!(fp.max_bram_pressure <= naive_max + 1e-9);
    }

    #[test]
    fn u280_is_tighter_than_u250() {
        let net = resnet50(1);
        let a = floorplan(&net, &alveo_u250()).unwrap();
        let b = floorplan(&net, &alveo_u280()).unwrap();
        assert!(b.max_bram_pressure > a.max_bram_pressure);
    }

    #[test]
    fn contiguous_cover_handles_heterogeneous_caps() {
        // a small first device forces the early demands onto it and the
        // bulk onto the big one; the cover stays monotone
        let a = contiguous_cover(&[3, 3, 10, 10], &[8, 32]).unwrap();
        assert_eq!(a, vec![0, 0, 1, 1]);
        // an element larger than a run's cap skips that run entirely
        let b = contiguous_cover(&[9, 1], &[4, 16]).unwrap();
        assert_eq!(b, vec![1, 1]);
        // infeasible: total demand exceeds every suffix of capacities
        assert!(contiguous_cover(&[9, 9], &[4, 9]).is_none());
        assert!(contiguous_cover(&[1], &[]).is_none());
        assert_eq!(contiguous_cover(&[], &[]), Some(vec![]));
    }

    #[test]
    fn infeasible_when_stage_too_big() {
        // a tiny fake device cannot host RN50's res5 stages
        let mut dev = alveo_u250();
        for s in &mut dev.slrs {
            s.bram18 = 50;
        }
        assert!(floorplan(&resnet50(1), &dev).is_none());
    }
}
