//! Block RAM primitive shapes and the physical mapping rule.
//!
//! Xilinx BRAM18 is a fixed 18 Kib dual-port primitive configurable into a
//! small set of aspect ratios; an arbitrary (width × depth) logical buffer
//! is realised as a grid of primitives, and the slack in that grid is
//! exactly the OCM inefficiency the paper attacks (§II.B, Eq. 1).

use crate::util::ceil_div;

/// Capacity of one BRAM18 primitive in bits (18 Kib).
pub const BRAM18_BITS: u64 = 18 * 1024;

/// Capacity of one UltraRAM block in bits (288 Kib, fixed 72 × 4096).
pub const URAM_BITS: u64 = 288 * 1024;

/// One configurable aspect ratio of the BRAM18 primitive.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BramMode {
    pub width: u64,
    pub depth: u64,
}

/// The BRAM18 aspect modes (true dual port; the 36-wide mode is
/// simple-dual-port, which suits weight buffers: written once, read always).
pub const BRAM18_MODES: [BramMode; 6] = [
    BramMode { width: 1, depth: 16384 },
    BramMode { width: 2, depth: 8192 },
    BramMode { width: 4, depth: 4096 },
    BramMode { width: 9, depth: 2048 },
    BramMode { width: 18, depth: 1024 },
    BramMode { width: 36, depth: 512 },
];

/// Number of BRAM18 primitives needed for a (width_bits × depth) buffer,
/// choosing the aspect mode that minimises the count (what a competent RTL
/// memory generator / Vivado will infer). Uncached mode search; prefer
/// [`brams_for`], which memoizes — the packers evaluate millions of bins
/// drawn from a handful of distinct shapes.
pub fn brams_for_uncached(width_bits: u64, depth: u64) -> u64 {
    if width_bits == 0 || depth == 0 {
        return 0;
    }
    BRAM18_MODES
        .iter()
        .map(|m| ceil_div(width_bits, m.width) * ceil_div(depth, m.depth))
        .min()
        .unwrap()
}

/// Entries in the per-thread direct-mapped shape cache (power of two).
const CACHE_SLOTS: usize = 1024;

thread_local! {
    /// (width, depth, count) keyed by a mixed hash of the shape. Direct
    /// mapped: a colliding shape simply overwrites the slot, so the cache
    /// is bounded and never needs invalidation. Thread-local so the island
    /// GA workers share nothing.
    static SHAPE_CACHE: std::cell::RefCell<[(u64, u64, u64); CACHE_SLOTS]> =
        std::cell::RefCell::new([(u64::MAX, u64::MAX, 0); CACHE_SLOTS]);
}

/// Memoized [`brams_for_uncached`]: the packing engines call this on every
/// bin admission probe and fitness update, but the distinct (width, depth)
/// shapes number in the hundreds, so a small per-thread table absorbs
/// nearly all of the mode searches.
pub fn brams_for(width_bits: u64, depth: u64) -> u64 {
    if width_bits == 0 || depth == 0 {
        return 0;
    }
    // splitmix-style mix of the two coordinates
    let h = width_bits
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(depth)
        .wrapping_mul(0xBF58476D1CE4E5B9);
    let slot = (h >> 32) as usize & (CACHE_SLOTS - 1);
    SHAPE_CACHE.with(|cache| {
        let mut cache = cache.borrow_mut();
        let entry = &mut cache[slot];
        if entry.0 == width_bits && entry.1 == depth {
            return entry.2;
        }
        let n = brams_for_uncached(width_bits, depth);
        *entry = (width_bits, depth, n);
        n
    })
}

/// The aspect mode achieving `brams_for` (for reporting / the packer).
pub fn best_mode(width_bits: u64, depth: u64) -> BramMode {
    *BRAM18_MODES
        .iter()
        .min_by_key(|m| ceil_div(width_bits, m.width) * ceil_div(depth, m.depth))
        .unwrap()
}

/// URAM blocks for a (width_bits × depth) buffer (fixed 72 × 4096 shape).
pub fn urams_for(width_bits: u64, depth: u64) -> u64 {
    if width_bits == 0 || depth == 0 {
        return 0;
    }
    ceil_div(width_bits, 72) * ceil_div(depth, 4096)
}

/// The paper's §II.B.b kernel-size ceiling: a K×K conv weight buffer can
/// reach at most `K² / 2^ceil(log2(K²))` efficiency from depth quantisation.
pub fn kernel_efficiency_ceiling(k: u64) -> f64 {
    let k2 = k * k;
    let pow2 = (k2 as f64).log2().ceil() as u32;
    k2 as f64 / (1u64 << pow2) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_capacities() {
        for m in BRAM18_MODES {
            let bits = m.width * m.depth;
            if m.width >= 9 {
                // parity bits usable at widths 9/18/36 -> full 18 Kib
                assert_eq!(bits, 18 * 1024, "{m:?}");
            } else {
                // narrow modes expose only the 16 Kib data array
                assert_eq!(bits, 16 * 1024, "{m:?}");
            }
        }
    }

    #[test]
    fn exact_fits_use_one_bram() {
        assert_eq!(brams_for(18, 1024), 1);
        assert_eq!(brams_for(36, 512), 1);
        assert_eq!(brams_for(1, 16384), 1);
    }

    #[test]
    fn wide_shallow_buffers_waste() {
        // 128 bits wide, 64 deep: needs ceil(128/36)=4 primitives although
        // only 8 Kib of payload — the Fig. 2 effect.
        assert_eq!(brams_for(128, 64), 4);
    }

    #[test]
    fn deep_narrow_buffers_stack() {
        assert_eq!(brams_for(18, 2048), 2);
        assert_eq!(brams_for(9, 2048), 1);
    }

    #[test]
    fn zero_cases() {
        assert_eq!(brams_for(0, 100), 0);
        assert_eq!(brams_for(100, 0), 0);
        assert_eq!(brams_for_uncached(0, 100), 0);
        assert_eq!(brams_for_uncached(100, 0), 0);
    }

    #[test]
    fn memoized_matches_uncached_over_a_dense_sweep() {
        // far more shapes than cache slots, so hits, misses and slot
        // evictions are all exercised
        for w in 1..=80u64 {
            for d in (1..=4096u64).step_by(37) {
                assert_eq!(brams_for(w, d), brams_for_uncached(w, d), "{w}x{d}");
            }
        }
        // repeated queries (the hit path) stay consistent
        for _ in 0..3 {
            assert_eq!(brams_for(36, 512), 1);
            assert_eq!(brams_for(19, 2058), 5);
        }
    }

    #[test]
    fn best_mode_consistent_with_count() {
        for (w, d) in [(36, 512), (72, 100), (7, 3000), (128, 64)] {
            let m = best_mode(w, d);
            assert_eq!(
                ceil_div(w, m.width) * ceil_div(d, m.depth),
                brams_for(w, d)
            );
        }
    }

    #[test]
    fn kernel_ceiling_matches_paper() {
        // 3x3: 9/16 = 0.5625 — "lowest for the very popular 3x3 kernel"
        assert!((kernel_efficiency_ceiling(3) - 0.5625).abs() < 1e-12);
        // 1x1 (pointwise): exactly 1.0 — "highest for the 1x1"
        assert_eq!(kernel_efficiency_ceiling(1), 1.0);
        assert!(kernel_efficiency_ceiling(5) == 25.0 / 32.0);
        assert!(kernel_efficiency_ceiling(3) < kernel_efficiency_ceiling(5));
    }

    #[test]
    fn monotone_in_depth_and_width() {
        for w in [1u64, 9, 18, 40, 100] {
            for d in [1u64, 100, 1000, 5000] {
                assert!(brams_for(w, d) <= brams_for(w + 1, d));
                assert!(brams_for(w, d) <= brams_for(w, d + 1));
            }
        }
    }

    #[test]
    fn uram_shapes() {
        assert_eq!(urams_for(72, 4096), 1);
        assert_eq!(urams_for(73, 4096), 2);
        assert_eq!(urams_for(72, 4097), 2);
    }
}
