//! Timing-closure model and the paper's δFPS calculus (Table V).
//!
//! DESIGN.md substitution: Vivado place & route is replaced by an empirical
//! frequency-degradation model — achieved frequency is the nominal target
//! scaled by a monotone penalty in LUT utilization density, with multi-die
//! (SLR-crossing) devices degrading much faster. The curves interpolate the
//! five (utilization → achieved-frequency) points the paper publishes:
//!
//! | design                | device | LUT% | Fc/target | Fm/target |
//! |-----------------------|--------|------|-----------|-----------|
//! | CNV-W1A1-P4           | 7020   | 58   | 1.00      | 1.00      |
//! | CNV-W1A1-P4           | 7012S  | 90   | 1.00      | 1.00      |
//! | RN50-W1A2-U250-P4     | U250   | 63   | 0.915     | 0.9075    |
//! | RN50-W1A2-U280-P4     | U280   | 99   | 0.69      | 0.9325    |
//! | RN50-W1A2-U280-F2     | U280   | 61   | 0.955     | —         |

use crate::device::Device;

/// Which clock domain a frequency estimate is for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Domain {
    /// The compute (LUT-dominated) domain: sensitive to density.
    Compute,
    /// The overclocked memory domain: BRAM-primitive-dominated, mostly
    /// insensitive to LUT density but pays a routing tax on multi-die parts.
    Memory,
}

/// Piecewise-linear interpolation over (x, y) knots (x ascending).
fn interp(knots: &[(f64, f64)], x: f64) -> f64 {
    if x <= knots[0].0 {
        return knots[0].1;
    }
    for w in knots.windows(2) {
        let ((x0, y0), (x1, y1)) = (w[0], w[1]);
        if x <= x1 {
            return y0 + (y1 - y0) * (x - x0) / (x1 - x0);
        }
    }
    knots.last().unwrap().1
}

/// Fraction of the nominal target the design achieves after P&R.
pub fn closure_factor(domain: Domain, dev: &Device, lut_util: f64) -> f64 {
    let u = lut_util.clamp(0.0, 1.2);
    if dev.is_monolithic() {
        // paper: "in practice it is easier than initially expected,
        // especially for monolithic FPGA devices" — CNV closes at 90% util
        match domain {
            Domain::Compute => interp(&[(0.0, 1.0), (0.92, 1.0), (1.05, 0.85)], u),
            Domain::Memory => interp(&[(0.0, 1.0), (0.95, 1.0), (1.05, 0.9)], u),
        }
    } else {
        match domain {
            // multi-die compute: calibrated on U250/U280 P4 + U280 F2 rows
            Domain::Compute => interp(
                &[(0.0, 1.0), (0.50, 1.0), (0.61, 0.955), (0.63, 0.915), (0.99, 0.69), (1.1, 0.60)],
                u,
            ),
            // multi-die memory: flat ~8% routing tax once the die is busy
            Domain::Memory => interp(&[(0.0, 1.0), (0.40, 1.0), (0.63, 0.9075), (0.99, 0.9325)], u),
        }
    }
}

/// Achieved frequency (MHz) for a target in a domain.
pub fn achieved_mhz(domain: Domain, dev: &Device, lut_util: f64, target_mhz: f64) -> f64 {
    let f = target_mhz * closure_factor(domain, dev, lut_util);
    // the memory domain can never exceed the BRAM primitive spec
    if domain == Domain::Memory {
        f.min(dev.bram_fmax_mhz)
    } else {
        f
    }
}

/// Implementation outcome of a (packed) accelerator on a device.
#[derive(Clone, Debug)]
pub struct TimingReport {
    pub fc_mhz: f64,
    pub fm_mhz: f64,
    /// The effective compute clock after memory-side throttling:
    /// `min(F_c, F_m / R_F^req)` (Table V's δFPS definition).
    pub effective_fc_mhz: f64,
    /// Relative throughput reduction vs the baseline compute clock.
    pub delta_fps_pct: f64,
}

/// Evaluate a packed design: `rf_required = H_B / 2` (Eq. 2),
/// `fc_baseline_mhz` is the original non-packed accelerator's compute clock.
pub fn evaluate(
    dev: &Device,
    lut_util: f64,
    fc_target_mhz: f64,
    rf_required: f64,
    fc_baseline_mhz: f64,
) -> TimingReport {
    let fc = achieved_mhz(Domain::Compute, dev, lut_util, fc_target_mhz);
    // rf <= 1: no overclocked memory domain exists (unpacked / folded
    // designs read weights in the compute clock; Table V prints "Fm = -")
    let (fm, effective) = if rf_required <= 1.0 {
        (fc, fc)
    } else {
        let fm = achieved_mhz(Domain::Memory, dev, lut_util, fc_target_mhz * rf_required);
        (fm, fc.min(fm / rf_required))
    };
    TimingReport {
        fc_mhz: fc,
        fm_mhz: fm,
        effective_fc_mhz: effective,
        delta_fps_pct: 100.0 * (1.0 - effective / fc_baseline_mhz),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{alveo_u250, alveo_u280, zynq_7012s, zynq_7020};

    #[test]
    fn monolithic_closes_at_high_density() {
        // CNV on 7020 (58%) and 7012S (90%): both meet 100/200 MHz
        for (dev, util) in [(zynq_7020(), 0.58), (zynq_7012s(), 0.90)] {
            let r = evaluate(&dev, util, 100.0, 2.0, 100.0);
            assert!((r.fc_mhz - 100.0).abs() < 1e-9, "{}", dev.name);
            assert!((r.fm_mhz - 200.0).abs() < 1e-9);
            assert!(r.delta_fps_pct.abs() < 1e-9);
        }
    }

    #[test]
    fn u250_p4_row_of_table_v() {
        // paper: both clocks miss by ~12% => Fc 183, Fm 363, delta 12%
        let r = evaluate(&alveo_u250(), 0.63, 200.0, 2.0, 200.0);
        assert!((r.fc_mhz - 183.0).abs() < 3.0, "Fc {}", r.fc_mhz);
        assert!((r.fm_mhz - 363.0).abs() < 4.0, "Fm {}", r.fm_mhz);
        // from the published clocks min(183, 363/2)=181.5 => 9.25%; the
        // paper rounds "both clocks ~12% off" into dFPS = 12
        assert!((8.0..13.0).contains(&r.delta_fps_pct), "dFPS {}", r.delta_fps_pct);
    }

    #[test]
    fn u280_p4_row_of_table_v() {
        // paper: Fc 138 (-32%), Fm 373; memory no longer binding
        let r = evaluate(&alveo_u280(), 0.99, 200.0, 2.0, 200.0);
        assert!((r.fc_mhz - 138.0).abs() < 3.0, "Fc {}", r.fc_mhz);
        assert!((r.fm_mhz - 373.0).abs() < 4.0, "Fm {}", r.fm_mhz);
        assert!((r.delta_fps_pct - 32.0).abs() < 2.5, "dFPS {}", r.delta_fps_pct);
        // compute-bound: effective clock set by Fc, not Fm/RF
        assert!(r.effective_fc_mhz == r.fc_mhz);
    }

    #[test]
    fn u280_f2_beats_nothing_but_closes_timing() {
        // folded design at 61% closes near target (191 MHz) but halves
        // per-cycle work: delta = 1 - (191/2)/200 = 52%
        let r = evaluate(&alveo_u280(), 0.61, 200.0, 1.0, 200.0);
        assert!((r.fc_mhz - 191.0).abs() < 3.0, "Fc {}", r.fc_mhz);
        let folded_delta = 100.0 * (1.0 - r.effective_fc_mhz / 2.0 / 200.0);
        assert!((folded_delta - 51.0).abs() < 3.0, "delta {folded_delta}");
    }

    #[test]
    fn fcmp_beats_folding_on_u280() {
        // the paper's headline: P4 (-32%) is ~38% faster than F2 (-51%)
        let p4 = evaluate(&alveo_u280(), 0.99, 200.0, 2.0, 200.0);
        let f2 = evaluate(&alveo_u280(), 0.61, 200.0, 1.0, 200.0);
        let p4_fps = p4.effective_fc_mhz; // per-cycle work identical to baseline
        let f2_fps = f2.effective_fc_mhz / 2.0; // half parallelism
        let speedup = p4_fps / f2_fps;
        assert!(
            (1.25..1.55).contains(&speedup),
            "P4 vs F2 speedup {speedup} (paper: 1.38)"
        );
    }

    #[test]
    fn memory_domain_capped_by_bram_spec() {
        let dev = zynq_7020(); // bram_fmax 388
        let f = achieved_mhz(Domain::Memory, &dev, 0.3, 500.0);
        assert!(f <= 388.0);
    }

    #[test]
    fn closure_factor_monotone_in_density() {
        let dev = alveo_u250();
        let mut prev = f64::INFINITY;
        for u in [0.0, 0.2, 0.4, 0.6, 0.8, 1.0] {
            let f = closure_factor(Domain::Compute, &dev, u);
            assert!(f <= prev + 1e-12, "not monotone at {u}");
            prev = f;
        }
    }

    #[test]
    fn rf_15_is_easier_than_rf_2() {
        // P3 (R_F=1.5) demands a 25% lower memory clock than P4 (R_F=2)
        let dev = alveo_u250();
        let p3 = evaluate(&dev, 0.63, 200.0, 1.5, 200.0);
        let p4 = evaluate(&dev, 0.63, 200.0, 2.0, 200.0);
        assert!(p3.fm_mhz < p4.fm_mhz);
        assert!(p3.effective_fc_mhz >= p4.effective_fc_mhz - 1e-9);
    }
}
