//! Failure-driven re-partition: lose a device, re-plan on the survivors,
//! splice the new plan into a running server.
//!
//! Packing is the expensive step of partitioning (the reason
//! [`crate::packing::cache`] exists), so a fleet event must not pay for
//! it again: [`replan`] re-runs the bottleneck-minimal DP
//! ([`crate::sharding::partition()`]) over the surviving `k-1` devices and
//! reports, shard by shard, whether the packed manifest was **migrated**
//! from the process-wide cache or had to be re-packed. When the surviving
//! point was already probed — by the original partition sweep, a
//! feasibility check, or an earlier repair — the re-plan is pure cache
//! lookups: zero re-packs. An infeasible survivor set (the network no
//! longer fits the remaining OCM) is a *clean* outcome, not a panic: the
//! report carries the partitioner's reason so the operator layer can page
//! instead of serving a plan that cannot exist.
//!
//! Actuation is [`Server::apply`] with a replacement
//! [`Deployment`]: every chain group of the running deployment is
//! replaced by a freshly tagged copy of the repaired plan's chain (the
//! old groups drain every in-flight frame first; the splice-unique tags
//! force the diff to respawn even when the repaired chain happens to
//! match the old shape, because the backends behind it changed).
//! [`splice_mock_chain`] calibrates the new stages' mock backends from
//! the plan's shard service intervals, as `fcmp shard --serve` does.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::coordinator::{
    shard_service_times, BatcherConfig, ChainGroup, Deployment, MockBackend, Policy, Server,
    WorkerId,
};
use crate::device::Device;
use crate::nn::Network;
use crate::packing::cache::{self, PackKey};
use crate::report::engine_tag;
use crate::sharding::{partition, PartitionConfig, ShardPlan};

/// Outcome of a failure-driven re-partition.
#[derive(Clone, Debug)]
pub struct RepairOutcome {
    /// The devices that survived the loss, in original fleet order.
    pub survivors: Vec<Device>,
    /// The repaired plan, when one exists.
    pub plan: Option<ShardPlan>,
    /// The partitioner's reason when no feasible plan exists on the
    /// survivors (the clean-infeasibility report).
    pub infeasible: Option<String>,
    /// Shards of the new plan whose packed manifest was already in the
    /// cache before re-planning (migrated, not re-packed).
    pub migrated_shards: usize,
    /// Shards of the new plan that required a fresh packing run.
    pub repacked_shards: usize,
}

impl RepairOutcome {
    /// True when a feasible plan was found.
    pub fn is_feasible(&self) -> bool {
        self.plan.is_some()
    }
}

/// Re-partition `net` over the fleet surviving the loss of
/// `devices[dead]`. Snapshots which candidate shard manifests are already
/// cached *before* invoking the partitioner, so
/// [`RepairOutcome::migrated_shards`] / [`RepairOutcome::repacked_shards`]
/// report true migrations rather than the trivially-warm state after the
/// DP ran.
pub fn replan(
    net: &Network,
    devices: &[Device],
    dead: usize,
    cfg: PartitionConfig,
) -> RepairOutcome {
    let survivors: Vec<Device> = devices
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != dead)
        .map(|(_, d)| d.clone())
        .collect();
    if survivors.is_empty() {
        return RepairOutcome {
            survivors,
            plan: None,
            infeasible: Some("no surviving devices".to_string()),
            migrated_shards: 0,
            repacked_shards: 0,
        };
    }

    // pre-partition cache census over every contiguous stage range the DP
    // could evaluate on each survivor (O(S² · k) hash lookups — cheap next
    // to a single packing run)
    let engine = engine_tag(cfg.generations);
    let n = net.stages.len();
    let mut warm: HashSet<(usize, usize, String)> = HashSet::new();
    for s in 0..n {
        for e in (s + 1)..=n {
            for d in &survivors {
                let key =
                    PackKey::new(&net.slice(s, e), d, cfg.bin_height, engine.clone(), cfg.seed);
                if cache::lookup(&key).is_some() {
                    warm.insert((s, e, d.fingerprint()));
                }
            }
        }
    }

    match partition(net, &survivors, cfg) {
        Err(e) => RepairOutcome {
            survivors,
            plan: None,
            infeasible: Some(format!("{e:#}")),
            migrated_shards: 0,
            repacked_shards: 0,
        },
        Ok(plan) => {
            let mut migrated = 0;
            let mut repacked = 0;
            for sh in &plan.shards {
                if warm.contains(&(sh.stages.0, sh.stages.1, sh.device.fingerprint())) {
                    migrated += 1;
                } else {
                    repacked += 1;
                }
            }
            RepairOutcome {
                survivors,
                plan: Some(plan),
                infeasible: None,
                migrated_shards: migrated,
                repacked_shards: repacked,
            }
        }
    }
}

/// Splice a repaired plan into a running server: every chain group of the
/// current deployment is replaced — via the group-diffing
/// [`Server::apply`], under splice-unique tags so the diff can never
/// mistake the new chain for the old one even when the shapes coincide —
/// by a copy of the repaired plan's stage chain on mock backends whose
/// per-stage service equals the plan's analytic shard intervals
/// ([`shard_service_times`]), each capped at `service_cap` so splices in
/// tests and benches stay wall-clock sane. A server running N replicated
/// chains gets N copies of the repaired chain. The old groups drain every
/// in-flight frame before the new chain spawns, so every accepted frame
/// finishes its traversal on the old plan. The spliced stages come up
/// with their batchers co-tuned against the new plan's bottleneck shard
/// ([`super::slo::co_tune_chain`] applied via [`Server::set_batcher`]):
/// the bottleneck stage serves greedily, faster stages may batch up to
/// their II ratio under `batcher`'s caps.
pub fn splice_mock_chain(
    srv: &mut Server,
    plan: &ShardPlan,
    batcher: BatcherConfig,
    queue_depth: usize,
    service_cap: Duration,
) -> crate::Result<()> {
    static SPLICE_SEQ: AtomicU64 = AtomicU64::new(0);
    let svc: Vec<Duration> =
        shard_service_times(plan).into_iter().map(|d| d.min(service_cap)).collect();
    let tuned = super::slo::co_tune_chain(&svc, batcher);
    let k = plan.shards.len().max(1);
    let chains = srv.group_count().max(1);
    let seq = SPLICE_SEQ.fetch_add(1, Ordering::Relaxed);
    let dep = Deployment {
        groups: (0..chains)
            .map(|g| ChainGroup::tagged(k, format!("splice{seq}-{g}")))
            .collect(),
        batcher,
        queue_depth,
        policy: Policy::RoundRobin,
        window: 2,
    };
    let svc_backend = svc.clone();
    srv.apply(
        move |id: WorkerId| {
            MockBackend::with_service(Duration::ZERO, svc_backend[id.stage])
        },
        dep,
    )?;
    for g in 0..srv.group_count() {
        for (stage, t) in tuned.iter().enumerate() {
            srv.set_batcher(g, stage, *t);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn losing_every_device_reports_cleanly() {
        let net = crate::nn::cnv(crate::nn::CnvVariant::W1A1);
        let devs = [crate::device::zynq_7020()];
        let out = replan(&net, &devs, 0, PartitionConfig::default());
        assert!(!out.is_feasible());
        assert!(out.survivors.is_empty());
        assert!(out.infeasible.unwrap().contains("no surviving devices"));
    }
}
