//! SLO-aware batching: adapt each replica's batching window from the live
//! windowed p99 against a latency budget.
//!
//! The dynamic batcher trades latency for amortization: a long
//! [`BatcherConfig::max_wait`] fills bigger batches but holds early
//! arrivals hostage. The [`SloController`] closes that trade-off against
//! an explicit p99 budget with a multiplicative-increase /
//! multiplicative-decrease rule and a dead band:
//!
//! * p99 **over budget** → halve `max_wait` (shed the queueing the window
//!   itself causes); once the window is already at its floor, halve
//!   `max_batch` too (the residual latency is service-time, not window).
//! * p99 **under [`SloConfig::grow_below`] × budget** → double `max_wait`
//!   and `max_batch` back toward their ceilings (idle fleets should
//!   amortize).
//! * in between → hold (the dead band is what stops flapping).
//!
//! Actuation is [`crate::coordinator::Server::set_batcher`] — live, per
//! replica, no drain. Under saturation batches fill from the backlog
//! without waiting on the window, so shrinking `max_wait` does not cost
//! steady-state throughput (the acceptance test in `tests/control.rs`
//! bounds the loss at 5%).
//!
//! For **stage chains**, [`co_tune_chain`] derives per-stage settings
//! from the plan's shard service intervals instead: the bottleneck shard
//! sets the pipeline's initiation interval, so only stages faster than it
//! can afford to batch at all.

use std::time::Duration;

use crate::coordinator::BatcherConfig;

/// SLO controller configuration.
#[derive(Clone, Copy, Debug)]
pub struct SloConfig {
    /// The latency budget: windowed p99 must come under this.
    pub p99_budget_ms: f64,
    /// Floor for `max_wait` shrinkage.
    pub min_wait: Duration,
    /// Ceiling for `max_wait` growth.
    pub max_wait: Duration,
    /// Floor for `max_batch` shrinkage.
    pub min_batch: usize,
    /// Ceiling for `max_batch` growth.
    pub max_batch: usize,
    /// Grow the window only when p99 is under this fraction of the
    /// budget; between `grow_below · budget` and `budget` the controller
    /// holds (the anti-flap dead band).
    pub grow_below: f64,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig {
            p99_budget_ms: 50.0,
            min_wait: Duration::from_micros(200),
            max_wait: Duration::from_millis(8),
            min_batch: 1,
            max_batch: 16,
            grow_below: 0.4,
        }
    }
}

/// Deterministic per-tick batching-window controller.
pub struct SloController {
    cfg: SloConfig,
}

impl SloController {
    /// Controller for the given budget and bounds.
    pub fn new(cfg: SloConfig) -> SloController {
        SloController { cfg }
    }

    /// The configured budget and bounds.
    pub fn config(&self) -> &SloConfig {
        &self.cfg
    }

    /// Per-stage batching for one chain group, clamped to this
    /// controller's bounds: the free [`co_tune_chain`] derives the
    /// per-stage settings from the group's stage service intervals, then
    /// the configured `max_batch` / `max_wait` ceilings cap them (the
    /// bottleneck stage's greedy batch-1 / zero-wait setting is always
    /// within bounds — co-tuning never floors it back up). The control
    /// loop calls this once per group per tick, with `base` already
    /// MIMD-adjusted from the windowed p99.
    pub fn co_tune_chain(
        &self,
        stage_service: &[Duration],
        base: BatcherConfig,
    ) -> Vec<BatcherConfig> {
        let hi_batch = self.cfg.max_batch.max(1);
        co_tune_chain(stage_service, base)
            .into_iter()
            .map(|c| BatcherConfig {
                max_batch: c.max_batch.min(hi_batch).max(1),
                max_wait: c.max_wait.min(self.cfg.max_wait),
            })
            .collect()
    }

    /// Next batching settings for a worker whose windowed p99 was
    /// `p99_ms` (`None` — nothing completed in the window — holds). Pure
    /// in `(p99_ms, cur)`, so the control loop stays replayable.
    pub fn adjust(&self, p99_ms: Option<f64>, cur: BatcherConfig) -> BatcherConfig {
        let Some(p99) = p99_ms else { return cur };
        let mut next = cur;
        if p99 > self.cfg.p99_budget_ms {
            if cur.max_wait > self.cfg.min_wait {
                next.max_wait = (cur.max_wait / 2).max(self.cfg.min_wait);
            } else {
                // window already at the floor: the violation is
                // service-side, trade batch amortization for latency
                next.max_batch = (cur.max_batch / 2).max(self.cfg.min_batch);
            }
        } else if p99 < self.cfg.grow_below * self.cfg.p99_budget_ms {
            next.max_wait = (cur.max_wait * 2).min(self.cfg.max_wait).max(self.cfg.min_wait);
            next.max_batch =
                (cur.max_batch * 2).min(self.cfg.max_batch).max(self.cfg.min_batch);
        }
        next
    }
}

/// Per-stage batching for a stage chain, co-tuned against the bottleneck
/// shard's initiation interval. A stage whose service interval is `s`
/// when the bottleneck's is `B ≥ s` can batch up to `⌊B / s⌋` frames and
/// still drain faster than the bottleneck admits work, so batching there
/// is free; the bottleneck stage itself (ratio 1) must serve greedily —
/// any window it holds adds directly to the pipeline's initiation
/// interval. Faster stages also never hold a partial batch longer than
/// one bottleneck interval: the next frame cannot arrive sooner, so a
/// longer wait is pure latency. Applied to live servers by
/// [`crate::control::repair::splice_mock_chain`] and, per chain group and
/// bounded by the SLO config, by [`SloController::co_tune_chain`] inside
/// the control tick — both actuate via
/// [`crate::coordinator::Server::set_batcher`].
pub fn co_tune_chain(stage_service: &[Duration], base: BatcherConfig) -> Vec<BatcherConfig> {
    let bottleneck = stage_service.iter().copied().max().unwrap_or(Duration::ZERO);
    stage_service
        .iter()
        .map(|&s| {
            if bottleneck.is_zero() {
                // degenerate all-instant chain: greedy single frames
                return BatcherConfig { max_batch: 1, max_wait: Duration::ZERO };
            }
            let ratio = if s.is_zero() {
                base.max_batch.max(1)
            } else {
                (bottleneck.as_secs_f64() / s.as_secs_f64()).floor() as usize
            };
            let max_batch = ratio.clamp(1, base.max_batch.max(1));
            let max_wait =
                if max_batch == 1 { Duration::ZERO } else { base.max_wait.min(bottleneck) };
            BatcherConfig { max_batch, max_wait }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bc(max_batch: usize, wait_us: u64) -> BatcherConfig {
        BatcherConfig { max_batch, max_wait: Duration::from_micros(wait_us) }
    }

    fn ctl() -> SloController {
        SloController::new(SloConfig {
            p99_budget_ms: 40.0,
            min_wait: Duration::from_micros(500),
            max_wait: Duration::from_millis(16),
            min_batch: 1,
            max_batch: 32,
            grow_below: 0.4,
        })
    }

    #[test]
    fn violation_halves_the_window_down_to_the_floor() {
        let c = ctl();
        let a = c.adjust(Some(90.0), bc(16, 8_000));
        assert_eq!(a.max_wait, Duration::from_micros(4_000));
        assert_eq!(a.max_batch, 16, "batch untouched while the window can shrink");
        // repeated violations walk the window to the floor...
        let mut cur = a;
        for _ in 0..8 {
            cur = c.adjust(Some(90.0), cur);
        }
        assert_eq!(cur.max_wait, Duration::from_micros(500));
        // ...then start trading batch size
        assert!(cur.max_batch < 16, "floored window must shrink the batch: {cur:?}");
        assert!(cur.max_batch >= 1);
    }

    #[test]
    fn idle_grows_back_within_bounds_and_dead_band_holds() {
        let c = ctl();
        // well under budget: grow toward the ceilings
        let g = c.adjust(Some(5.0), bc(4, 1_000));
        assert_eq!(g.max_wait, Duration::from_micros(2_000));
        assert_eq!(g.max_batch, 8);
        // growth clamps at the ceilings
        let g = c.adjust(Some(5.0), bc(32, 16_000));
        assert_eq!(g.max_wait, Duration::from_millis(16));
        assert_eq!(g.max_batch, 32);
        // dead band: between grow_below·budget (16 ms) and budget (40 ms)
        let h = c.adjust(Some(25.0), bc(4, 1_000));
        assert_eq!(h.max_batch, 4);
        assert_eq!(h.max_wait, Duration::from_micros(1_000));
        // no signal: hold
        let h = c.adjust(None, bc(4, 1_000));
        assert_eq!(h.max_batch, 4);
    }

    #[test]
    fn co_tune_gives_the_bottleneck_stage_a_greedy_batcher() {
        let svc = [
            Duration::from_micros(100),
            Duration::from_micros(400), // bottleneck
            Duration::from_micros(100),
        ];
        let base = bc(16, 2_000);
        let tuned = co_tune_chain(&svc, base);
        assert_eq!(tuned.len(), 3);
        assert_eq!(tuned[1].max_batch, 1, "bottleneck stage must serve greedily");
        assert_eq!(tuned[1].max_wait, Duration::ZERO);
        // 4x-faster stages may batch up to the II ratio
        assert_eq!(tuned[0].max_batch, 4);
        assert_eq!(tuned[2].max_batch, 4);
        // and never hold longer than one bottleneck interval
        assert_eq!(tuned[0].max_wait, Duration::from_micros(400));
    }

    #[test]
    fn controller_co_tune_caps_at_the_slo_bounds() {
        let c = ctl(); // max_batch 32, max_wait 16 ms
        let svc = [
            Duration::from_micros(10), // 100x faster than the bottleneck
            Duration::from_micros(1_000),
        ];
        // a base far beyond the SLO bounds gets capped back
        let tuned = c.co_tune_chain(&svc, bc(64, 40_000));
        assert_eq!(tuned.len(), 2);
        assert!(tuned[0].max_batch <= 32, "batch must cap at the SLO bound");
        assert!(tuned[0].max_wait <= Duration::from_millis(16));
        // the bottleneck stage stays greedy — bounds never floor it up
        assert_eq!(tuned[1].max_batch, 1);
        assert_eq!(tuned[1].max_wait, Duration::ZERO);
    }

    #[test]
    fn co_tune_clamps_to_the_base_batch_and_handles_degenerates() {
        let svc = [Duration::from_micros(1), Duration::from_micros(1_000)];
        let tuned = co_tune_chain(&svc, bc(8, 5_000));
        assert_eq!(tuned[0].max_batch, 8, "1000x ratio clamps to the base max_batch");
        assert_eq!(tuned[1].max_batch, 1);
        // all-instant chain
        let tuned = co_tune_chain(&[Duration::ZERO, Duration::ZERO], bc(8, 5_000));
        assert!(tuned.iter().all(|c| c.max_batch == 1));
        // empty chain
        assert!(co_tune_chain(&[], bc(8, 5_000)).is_empty());
    }
}
