//! Adaptive control plane: the subsystem that closes the loop between
//! fleet metrics and fleet shape.
//!
//! Everything below the coordinator picks a *static* design point — an
//! FCMP packing, a shard plan, a deployment topology. Production load is
//! not static: it drifts (diurnal), steps (flash crowds) and breaks
//! (device loss). The control plane re-picks the deployed point at
//! runtime, deterministically, on a fixed tick — and it works in units of
//! whole **chain groups** of the [`Deployment`] topology, never lone
//! mid-chain workers:
//!
//! ```text
//!   Server / FleetMetrics                 (observe)
//!        │  submits, sheds, completions, outstanding
//!        v
//!   signal::SignalTap ── windowed shed rate, p99, utilization
//!        │                               (decide, once per tick)
//!        ├─> autoscaler::Autoscaler ── hysteresis-banded Out/In/Hold
//!        │                             (adds / retires chain groups)
//!        ├─> slo::SloController ────── batching-window MIMD vs p99
//!        │                             budget, co-tuned per chain group
//!        └─> repair::replan ────────── re-partition on device loss
//!        │                               (actuate)
//!        ├─> ControlledFleet::scale_out/in  → Server::apply (group diff:
//!        │                                    untouched groups keep
//!        │                                    serving through the swap)
//!        ├─> Server::set_batcher            (live, no drain)
//!        └─> repair::splice_mock_chain      → Server::apply
//! ```
//!
//! [`run_loop`] is the driver: it replays an arrival trace open-loop
//! (like [`crate::coordinator::Server::replay`]) while firing the control
//! tick on its own cadence, applying a failure-injection schedule, and
//! journaling every decision as a [`ControlEvent`]. All controllers are
//! pure functions of the observed signal sequence, so a run is replayable
//! and the tests can assert on decisions, not just outcomes. The journal
//! itself persists to disk ([`save_events`] / [`load_events`]) in the
//! same text convention as [`Trace::save`], so a fleet's scaling history
//! replays alongside its arrival trace (`fcmp autoscale --events-out`).
//!
//! Surfaces: `fcmp autoscale` (CLI), `benches/control_loop.rs`
//! (`BENCH_control.json`), `tests/control.rs` (acceptance).

pub mod autoscaler;
pub mod repair;
pub mod signal;
pub mod slo;

pub use autoscaler::{rank_by_capacity, Autoscaler, AutoscalerConfig, ScaleDecision};
pub use repair::{replan, splice_mock_chain, RepairOutcome};
pub use signal::{ControlSignals, SignalConfig, SignalTap};
pub use slo::{co_tune_chain, SloConfig, SloController};

use std::path::Path;
use std::time::{Duration, Instant};

use crate::coordinator::{
    chain_fps, group_weights, mock_chain_service, replica_fps, BatcherConfig, ChainGroup,
    Deployment, FleetMetrics, FleetSummary, MockBackend, Policy, ReplicaSpec, Server,
    SubmitError, Trace, WorkerId,
};
use crate::nn::Network;
use crate::util::rng::Rng;

/// One scheduled device loss: at `at_s` seconds into the run, the whole
/// active chain group `group` dies (its devices leave the fleet entirely —
/// a dead group does not return to standby).
#[derive(Clone, Copy, Debug)]
pub struct FailureEvent {
    /// Seconds from the start of the replay.
    pub at_s: f64,
    /// Index into the active chain-group list at firing time.
    pub group: usize,
}

/// Driver-loop configuration.
#[derive(Clone, Debug)]
pub struct LoopConfig {
    /// Control period: signals are aggregated and decisions made once per
    /// tick.
    pub tick: Duration,
    /// Signal-window shape.
    pub signal: SignalConfig,
    /// Autoscaling policy; `None` runs a static fleet (the baseline arm).
    pub autoscaler: Option<AutoscalerConfig>,
    /// SLO batching controller; `None` leaves batchers at their baseline.
    pub slo: Option<SloConfig>,
    /// Failure-injection schedule (fired in time order).
    pub failures: Vec<FailureEvent>,
    /// Extra idle control ticks after the drain, so scale-in on a
    /// quiesced fleet is observable even when the trace ends under load.
    pub trailing_ticks: usize,
    /// Elements per synthetic request input.
    pub input_len: usize,
    /// Seed for the synthetic inputs.
    pub seed: u64,
}

impl Default for LoopConfig {
    fn default() -> Self {
        LoopConfig {
            tick: Duration::from_millis(25),
            signal: SignalConfig::default(),
            autoscaler: None,
            slo: None,
            failures: Vec::new(),
            trailing_ticks: 8,
            input_len: 8,
            seed: 2020,
        }
    }
}

/// The windowed signals a decision was looking at when it fired — the
/// "why" next to the journal's "what", so a scaling history reads
/// without replaying the run. Values are quantized at construction
/// (rates/utilization to 1e-6, p99 to 1e-4 ms) so the text journal
/// round-trips them exactly.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SignalCtx {
    /// Windowed shed rate at decision time.
    pub shed_rate: f64,
    /// Windowed latency p99 (ms); `None` when the window saw no
    /// completions.
    pub p99_ms: Option<f64>,
    /// Windowed max replica utilization.
    pub util: f64,
}

/// Quantize onto a `1/scale` grid whose decimal rendering parses back
/// to the same `f64` ([`save_events`] relies on it).
fn quant(v: f64, scale: f64) -> f64 {
    if v.is_finite() {
        (v * scale).round() / scale
    } else {
        0.0
    }
}

impl SignalCtx {
    /// Capture the decision-relevant slice of a closed signal window.
    pub fn from_signals(sig: &ControlSignals) -> SignalCtx {
        SignalCtx {
            shed_rate: quant(sig.shed_rate, 1e6),
            p99_ms: sig.p99_ms.map(|p| quant(p, 1e4)),
            util: quant(sig.max_utilization, 1e6),
        }
    }
}

/// One journaled control-plane decision: when it fired (control tick and
/// wall-clock seconds into the run, so the journal aligns with the
/// arrival trace's time base), what it did, and what it saw.
#[derive(Clone, Debug, PartialEq)]
pub struct ControlEvent {
    /// Control tick the decision fired on.
    pub tick: usize,
    /// Seconds from the start of the replay.
    pub at_s: f64,
    /// The decision itself.
    pub kind: ControlEventKind,
    /// Signals observed at decision time (all-zero for events that fire
    /// outside a signal window, e.g. scheduled failures, and for
    /// journals archived before the context fields existed).
    pub ctx: SignalCtx,
}

/// What a [`ControlEvent`] did.
#[derive(Clone, Debug, PartialEq)]
pub enum ControlEventKind {
    /// The autoscaler grew the fleet from `from` to `to` chain groups.
    ScaleOut {
        /// Chain groups before.
        from: usize,
        /// Chain groups after.
        to: usize,
    },
    /// The autoscaler shrank the fleet from `from` to `to` chain groups.
    ScaleIn {
        /// Chain groups before.
        from: usize,
        /// Chain groups after.
        to: usize,
    },
    /// The SLO controller retuned one stage's batcher.
    SloAdjust {
        /// Chain group retuned.
        group: usize,
        /// Stage within the group.
        stage: usize,
        /// New batch-size cap.
        max_batch: usize,
        /// New batching window.
        max_wait: Duration,
    },
    /// A scheduled group loss fired.
    Failure {
        /// Active index of the victim group at firing time.
        group: usize,
        /// Chain groups remaining after the loss.
        survivors: usize,
    },
}

impl std::fmt::Display for ControlEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.kind {
            ControlEventKind::ScaleOut { from, to } => {
                write!(f, "tick {}: scale-out {from} -> {to} chain groups", self.tick)
            }
            ControlEventKind::ScaleIn { from, to } => {
                write!(f, "tick {}: scale-in {from} -> {to} chain groups", self.tick)
            }
            ControlEventKind::SloAdjust { group, stage, max_batch, max_wait } => write!(
                f,
                "tick {}: slo-adjust g{group}.s{stage}: batch {max_batch}, wait {max_wait:?}",
                self.tick
            ),
            ControlEventKind::Failure { group, survivors } => {
                write!(f, "tick {}: FAILURE group {group} ({survivors} survive)", self.tick)
            }
        }
    }
}

/// Write a control-event journal as `fcmp-events v2`: a comment header
/// followed by one event per line (`at_s tick kind args… shed_rate p99
/// util`, with `-` for a p99 the window never observed), the same
/// text-file convention as [`Trace::save`] — so a run's scaling history
/// is archived next to its arrival trace and replays with it. The three
/// trailing tokens are the [`SignalCtx`]; quantization at capture makes
/// the decimal rendering round-trip bit-exactly.
pub fn save_events(events: &[ControlEvent], path: &Path) -> crate::Result<()> {
    let mut out = String::with_capacity(events.len() * 64 + 32);
    out.push_str("# fcmp-events v2\n");
    for e in events {
        match &e.kind {
            ControlEventKind::ScaleOut { from, to } => {
                out.push_str(&format!("{:.6} {} scale-out {from} {to}", e.at_s, e.tick));
            }
            ControlEventKind::ScaleIn { from, to } => {
                out.push_str(&format!("{:.6} {} scale-in {from} {to}", e.at_s, e.tick));
            }
            ControlEventKind::SloAdjust { group, stage, max_batch, max_wait } => {
                // nanoseconds: co-tuned windows derived from analytic
                // service intervals carry sub-microsecond components, and
                // the journal must round-trip them exactly
                out.push_str(&format!(
                    "{:.6} {} slo-adjust {group} {stage} {max_batch} {}",
                    e.at_s,
                    e.tick,
                    max_wait.as_nanos()
                ));
            }
            ControlEventKind::Failure { group, survivors } => {
                out.push_str(&format!("{:.6} {} failure {group} {survivors}", e.at_s, e.tick));
            }
        }
        match e.ctx.p99_ms {
            Some(p99) => out.push_str(&format!(
                " {:.6} {p99:.4} {:.6}\n",
                e.ctx.shed_rate, e.ctx.util
            )),
            None => out.push_str(&format!(" {:.6} - {:.6}\n", e.ctx.shed_rate, e.ctx.util)),
        }
    }
    std::fs::write(path, out)?;
    Ok(())
}

/// Read a journal written by [`save_events`] (`#` comments and blank
/// lines are ignored). Events must carry finite, non-negative times.
/// Both journal generations load: v2 lines carry the three
/// [`SignalCtx`] tokens, v1 lines (archived before the context existed)
/// get an all-zero context.
pub fn load_events(path: &Path) -> crate::Result<Vec<ControlEvent>> {
    let text = std::fs::read_to_string(path)?;
    let mut out = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        let bad =
            || anyhow::anyhow!("{}:{}: malformed control event {line:?}", path.display(), ln + 1);
        if toks.len() < 3 {
            return Err(bad());
        }
        let at_s: f64 = toks[0].parse().map_err(|_| bad())?;
        anyhow::ensure!(
            at_s.is_finite() && at_s >= 0.0,
            "{}:{}: event time must be finite and non-negative",
            path.display(),
            ln + 1
        );
        let tick: usize = toks[1].parse().map_err(|_| bad())?;
        let num = |i: usize| -> crate::Result<usize> {
            toks.get(i).and_then(|s| s.parse().ok()).ok_or_else(|| bad())
        };
        let (kind, want) = match toks[2] {
            "scale-out" => {
                (ControlEventKind::ScaleOut { from: num(3)?, to: num(4)? }, 5)
            }
            "scale-in" => (ControlEventKind::ScaleIn { from: num(3)?, to: num(4)? }, 5),
            "slo-adjust" => (
                ControlEventKind::SloAdjust {
                    group: num(3)?,
                    stage: num(4)?,
                    max_batch: num(5)?,
                    max_wait: Duration::from_nanos(num(6)? as u64),
                },
                7,
            ),
            "failure" => {
                (ControlEventKind::Failure { group: num(3)?, survivors: num(4)? }, 5)
            }
            _ => return Err(bad()),
        };
        let ctx = if toks.len() == want + 3 {
            let fnum = |i: usize| -> crate::Result<f64> {
                let v: f64 = toks[i].parse().map_err(|_| bad())?;
                anyhow::ensure!(
                    v.is_finite(),
                    "{}:{}: signal context must be finite",
                    path.display(),
                    ln + 1
                );
                Ok(v)
            };
            SignalCtx {
                shed_rate: fnum(want)?,
                p99_ms: match toks[want + 1] {
                    "-" => None,
                    _ => Some(fnum(want + 1)?),
                },
                util: fnum(want + 2)?,
            }
        } else {
            anyhow::ensure!(
                toks.len() == want,
                "{}:{}: trailing fields in control event",
                path.display(),
                ln + 1
            );
            SignalCtx::default()
        };
        out.push(ControlEvent { tick, at_s, kind, ctx });
    }
    Ok(out)
}

/// Result of one controlled replay.
#[derive(Debug)]
pub struct ControlReport {
    /// Fleet-wide serving summary of the whole run.
    pub summary: FleetSummary,
    /// Every control decision, in firing order.
    pub events: Vec<ControlEvent>,
    /// Control ticks fired.
    pub ticks: usize,
    /// Chain groups at the start.
    pub initial_groups: usize,
    /// Chain groups at the end.
    pub final_groups: usize,
    /// Largest fleet (in chain groups) the run reached.
    pub max_groups_seen: usize,
    /// Requests accepted.
    pub submitted: usize,
    /// Requests shed by admission control.
    pub shed: usize,
    /// Requests completed.
    pub completed: usize,
}

impl ControlReport {
    /// Scale-out decisions that took effect.
    pub fn scale_outs(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e.kind, ControlEventKind::ScaleOut { .. }))
            .count()
    }

    /// Scale-in decisions that took effect.
    pub fn scale_ins(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e.kind, ControlEventKind::ScaleIn { .. }))
            .count()
    }

    /// Failures that fired.
    pub fn failures(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e.kind, ControlEventKind::Failure { .. }))
            .count()
    }

    /// Overall shed rate: `shed / (submitted + shed)` (0 when idle).
    pub fn shed_rate(&self) -> f64 {
        let offered = self.submitted + self.shed;
        if offered == 0 {
            0.0
        } else {
            self.shed as f64 / offered as f64
        }
    }

    /// Ticks of every scale decision (out and in), in firing order — the
    /// cooldown-bound assertions read consecutive gaps off this.
    pub fn scale_ticks(&self) -> Vec<usize> {
        self.events
            .iter()
            .filter_map(|e| match e.kind {
                ControlEventKind::ScaleOut { .. } | ControlEventKind::ScaleIn { .. } => {
                    Some(e.tick)
                }
                _ => None,
            })
            .collect()
    }
}

/// One active chain group of a [`ControlledFleet`]: its diffing tag (the
/// identity [`Server::apply`] keeps it running under), the device spec
/// behind each stage, and the per-stage mock service intervals cached at
/// creation (they depend only on the specs and the fleet's calibration,
/// so the control tick never re-runs the analytic models for a group
/// that did not change).
struct FleetGroup {
    tag: String,
    specs: Vec<ReplicaSpec>,
    service: Vec<Duration>,
    /// The SLO controller's MIMD state for this group. Chain co-tuning
    /// overwrites the *actuated* per-stage settings every tick (the
    /// bottleneck stage is pinned greedy), so the adaptation must walk a
    /// base kept apart from them — reading stage 0's live config back
    /// would collapse every stage toward the bottleneck's batch-1 value.
    slo_base: BatcherConfig,
}

/// A mock-backed fleet of chain groups the control plane can reshape: a
/// [`Server`] running a real [`Deployment`] plus the [`ReplicaSpec`]s
/// behind each group (active) and the device pool scale-out draws from
/// (standby). Every group is `stages` deep; scaling works in whole
/// groups, consuming or releasing `stages` devices at a time — the
/// control plane never creates a partial chain.
///
/// Per-stage mock service times derive from the analytic capacity model
/// ([`replica_fps`]): the fastest device in the initial pool serves one
/// item in `service_us` microseconds, every other device scales up by its
/// FPS ratio, and a `k`-stage chain splits its device's service across
/// the stages — so the fleet's heterogeneity, the chain pipelining win,
/// and every capacity-aware placement decision are observable without
/// hardware. The router policy is capacity-weighted ([`Policy::Weighted`]
/// over per-group [`chain_fps`]) and re-derived on every reshape.
/// Actuation is [`Server::apply`]: groups untouched by a decision keep
/// serving straight through it (tag-matched in the diff), so a scale-out
/// no longer drains the whole fleet.
pub struct ControlledFleet {
    net: Network,
    service_us: f64,
    ref_fps: f64,
    batcher: BatcherConfig,
    queue_depth: usize,
    stages: usize,
    active: Vec<FleetGroup>,
    standby: Vec<ReplicaSpec>,
    next_uid: u64,
    srv: Server,
}

/// The deployment (and the per-group service snapshot its backends need)
/// describing `active` as it stands — the one derivation shared by the
/// initial [`Server::deploy`] and every [`Server::apply`] reshape, so the
/// two can never disagree on tags, weights or batching defaults.
fn fleet_plan(
    active: &[FleetGroup],
    stages: usize,
    batcher: BatcherConfig,
    queue_depth: usize,
) -> (Vec<Vec<Duration>>, Deployment) {
    let svc: Vec<Vec<Duration>> = active.iter().map(|g| g.service.clone()).collect();
    let plan = Deployment {
        groups: active.iter().map(|g| ChainGroup::tagged(stages, g.tag.clone())).collect(),
        batcher,
        queue_depth,
        policy: Policy::Weighted(group_weights(
            &svc.iter().map(|s| chain_fps(s)).collect::<Vec<f64>>(),
        )),
        window: 2,
    };
    (svc, plan)
}

/// The mock backend factory for a service snapshot from [`fleet_plan`].
fn mock_factory(
    svc: Vec<Vec<Duration>>,
) -> impl Fn(WorkerId) -> MockBackend + Send + Sync + 'static {
    move |id| MockBackend::with_service(Duration::ZERO, svc[id.group][id.stage])
}

impl ControlledFleet {
    /// Start a flat fleet: every entry of `active` becomes a 1-stage
    /// chain group, with `standby` devices held for scale-out.
    /// `service_us` is the per-item mock service time of the fastest
    /// device anywhere in the pool.
    pub fn start(
        net: Network,
        active: Vec<ReplicaSpec>,
        standby: Vec<ReplicaSpec>,
        service_us: f64,
        batcher: BatcherConfig,
        queue_depth: usize,
    ) -> ControlledFleet {
        let groups = active.into_iter().map(|s| vec![s]).collect();
        Self::start_chained(net, groups, standby, service_us, batcher, queue_depth)
    }

    /// Start a fleet of chain groups: `groups[g]` lists the device spec
    /// behind each stage of group `g` (all groups must share one depth —
    /// the shape scaling preserves). `standby` devices are consumed
    /// `stages` at a time when the autoscaler adds a group.
    pub fn start_chained(
        net: Network,
        groups: Vec<Vec<ReplicaSpec>>,
        standby: Vec<ReplicaSpec>,
        service_us: f64,
        batcher: BatcherConfig,
        queue_depth: usize,
    ) -> ControlledFleet {
        assert!(!groups.is_empty(), "a controlled fleet needs at least one chain group");
        let stages = groups[0].len().max(1);
        assert!(
            groups.iter().all(|g| g.len() == stages),
            "every chain group must have the same stage count"
        );
        let ref_fps = groups
            .iter()
            .flatten()
            .chain(standby.iter())
            .map(|s| replica_fps(&net, s))
            .fold(0.0f64, f64::max)
            .max(1e-9);
        let mut next_uid = 0u64;
        let active: Vec<FleetGroup> = groups
            .into_iter()
            .map(|specs| {
                let tag = format!("cg{next_uid}");
                next_uid += 1;
                let service = mock_chain_service(&net, &specs, service_us, ref_fps);
                FleetGroup { tag, specs, service, slo_base: batcher }
            })
            .collect();
        let (svc, plan) = fleet_plan(&active, stages, batcher, queue_depth);
        let srv = Server::deploy(mock_factory(svc), plan);
        ControlledFleet {
            net,
            service_us,
            ref_fps,
            batcher,
            queue_depth,
            stages,
            active,
            standby,
            next_uid,
            srv,
        }
    }

    /// Active chain-group count.
    pub fn group_count(&self) -> usize {
        self.active.len()
    }

    /// Stage depth every group runs at.
    pub fn stages(&self) -> usize {
        self.stages
    }

    /// Devices currently serving (`group_count × stages`).
    pub fn device_count(&self) -> usize {
        self.active.len() * self.stages
    }

    /// Devices still available for scale-out.
    pub fn standby_len(&self) -> usize {
        self.standby.len()
    }

    /// The device specs behind group `g`'s stages, in stage order.
    pub fn group_specs(&self, g: usize) -> &[ReplicaSpec] {
        &self.active[g].specs
    }

    /// Per-stage analytic mock service intervals of group `g` (the
    /// co-tuning input for [`SloController::co_tune_chain`]), cached at
    /// group creation.
    pub fn group_service(&self, g: usize) -> &[Duration] {
        &self.active[g].service
    }

    /// The underlying server (submit/drain directly, e.g. from tests).
    pub fn server(&mut self) -> &mut Server {
        &mut self.srv
    }

    /// Shut the fleet down (drains; the server is unusable afterwards).
    pub fn shutdown(&mut self) {
        self.srv.shutdown();
    }

    /// Per-group metrics shape covering the largest fleet this run could
    /// reach (current groups plus every whole group the standby pool
    /// could still fund) — size [`FleetMetrics::new`] with this so
    /// completions from scaled-out groups land in real collectors.
    pub fn metrics_shape(&self) -> Vec<usize> {
        let max_groups = self.active.len() + self.standby.len() / self.stages;
        vec![self.stages; max_groups.max(1)]
    }

    /// Re-derive the deployment from the active groups and diff it onto
    /// the server. Groups whose tag survived keep serving untouched.
    fn apply_plan(&mut self) -> crate::Result<()> {
        let (svc, plan) = fleet_plan(&self.active, self.stages, self.batcher, self.queue_depth);
        self.srv.apply(mock_factory(svc), plan)
    }

    /// Scale out by up to `want` whole chain groups, capacity-aware: each
    /// new group takes the `stages` fastest devices remaining in standby.
    /// Returns how many groups actually joined (bounded by the standby
    /// pool — a pool with fewer than `stages` devices left cannot fund a
    /// partial group).
    pub fn scale_out(&mut self, want: usize) -> crate::Result<usize> {
        let fundable = (self.standby.len() / self.stages).min(want);
        if fundable == 0 {
            return Ok(0);
        }
        // one capacity ranking covers every group this decision staffs:
        // consecutive `stages`-sized chunks of the fastest-first order
        // are exactly the groups the old one-rank-per-group loop built
        let picks: Vec<usize> = rank_by_capacity(&self.net, &self.standby)
            .into_iter()
            .take(fundable * self.stages)
            .collect();
        let staffed: Vec<ReplicaSpec> =
            picks.iter().map(|&i| self.standby[i].clone()).collect();
        // remove back-to-front so earlier indices stay valid
        let mut remove = picks;
        remove.sort_unstable_by(|a, b| b.cmp(a));
        for i in remove {
            self.standby.remove(i);
        }
        for chunk in staffed.chunks(self.stages) {
            let tag = format!("cg{}", self.next_uid);
            self.next_uid += 1;
            let service =
                mock_chain_service(&self.net, chunk, self.service_us, self.ref_fps);
            self.active.push(FleetGroup {
                tag,
                specs: chunk.to_vec(),
                service,
                slo_base: self.batcher,
            });
        }
        self.apply_plan()?;
        Ok(fundable)
    }

    /// Scale in by up to `want` chain groups, retiring the slowest groups
    /// first (their devices return to standby). The fleet never shrinks
    /// below one group. Returns how many groups were retired.
    pub fn scale_in(&mut self, want: usize) -> crate::Result<usize> {
        let removable = self.active.len().saturating_sub(1);
        let want = want.min(removable);
        if want == 0 {
            return Ok(0);
        }
        let fps: Vec<f64> = self.active.iter().map(|g| chain_fps(&g.service)).collect();
        let mut order: Vec<usize> = (0..self.active.len()).collect();
        // slowest first; ties retire the newest group (highest index)
        order.sort_by(|&a, &b| {
            fps[a].partial_cmp(&fps[b]).unwrap_or(std::cmp::Ordering::Equal).then(b.cmp(&a))
        });
        let mut retire: Vec<usize> = order.into_iter().take(want).collect();
        retire.sort_unstable_by(|a, b| b.cmp(a));
        for g in retire {
            let group = self.active.remove(g);
            self.standby.extend(group.specs);
        }
        self.apply_plan()?;
        Ok(want)
    }

    /// Simulated device loss: active chain group `group` leaves the fleet
    /// for good (its devices do **not** return to standby) and the plan
    /// re-applies over the survivors — who keep serving through the diff.
    /// Returns `false` (and does nothing) when the index is out of range
    /// or only one group remains — a fleet cannot be emptied, matching
    /// the partitioner's "at least one device" rule.
    pub fn kill(&mut self, group: usize) -> crate::Result<bool> {
        if group >= self.active.len() || self.active.len() <= 1 {
            return Ok(false);
        }
        self.active.remove(group);
        self.apply_plan()?;
        Ok(true)
    }
}

/// One control tick: sample utilization, close the signal window, let the
/// autoscaler reshape the fleet (whole chain groups) and the SLO
/// controller retune batchers (co-tuned per group for chains).
fn control_tick(
    fleet: &mut ControlledFleet,
    tap: &mut SignalTap,
    scaler: &mut Option<Autoscaler>,
    slo: Option<&SloController>,
    at_s: f64,
    events: &mut Vec<ControlEvent>,
) {
    tap.observe_utilization(&fleet.srv.outstanding(), fleet.queue_depth);
    let sig = tap.tick();
    let ctx = SignalCtx::from_signals(&sig);
    // anomaly triggers read the closed window: a p99 budget breach, a
    // shed burst or a dead chain group flushes the flight-recorder rings
    if fleet.srv.obs().active() {
        fleet.srv.obs().recorder().observe(sig.p99_ms, sig.shed, fleet.srv.dead_groups());
    }
    if let Some(sc) = scaler.as_mut() {
        match sc.decide(&sig, fleet.group_count()) {
            ScaleDecision::Out(k) => {
                let from = fleet.group_count();
                if let Ok(added) = fleet.scale_out(k) {
                    // the cooldown starts only when the fleet actually
                    // changed — a no-op against an exhausted standby pool
                    // must not delay later legitimate actions
                    if added > 0 {
                        sc.note_action(sig.tick);
                        events.push(ControlEvent {
                            tick: sig.tick,
                            at_s,
                            kind: ControlEventKind::ScaleOut { from, to: from + added },
                            ctx,
                        });
                    }
                }
            }
            ScaleDecision::In(k) => {
                let from = fleet.group_count();
                if let Ok(removed) = fleet.scale_in(k) {
                    if removed > 0 {
                        sc.note_action(sig.tick);
                        events.push(ControlEvent {
                            tick: sig.tick,
                            at_s,
                            kind: ControlEventKind::ScaleIn { from, to: from - removed },
                            ctx,
                        });
                    }
                }
            }
            ScaleDecision::Hold => {}
        }
    }
    if let Some(sl) = slo {
        for g in 0..fleet.group_count() {
            if fleet.stages() == 1 {
                // plain replicas: MIMD-adjust straight from the windowed p99
                if let Some(cur) = fleet.srv.batcher_config(g, 0) {
                    let next = sl.adjust(sig.p99_ms, cur);
                    if next != cur {
                        fleet.srv.set_batcher(g, 0, next);
                        events.push(ControlEvent {
                            tick: sig.tick,
                            at_s,
                            kind: ControlEventKind::SloAdjust {
                                group: g,
                                stage: 0,
                                max_batch: next.max_batch,
                                max_wait: next.max_wait,
                            },
                            ctx,
                        });
                    }
                }
            } else {
                // chain group: MIMD-adapt the group's own base (kept
                // apart from the actuated per-stage settings, which the
                // co-tuning overwrites every tick), then spread it per
                // stage against the group's bottleneck shard interval
                let next = sl.adjust(sig.p99_ms, fleet.active[g].slo_base);
                fleet.active[g].slo_base = next;
                let tuned = sl.co_tune_chain(fleet.group_service(g), next);
                for (stage, t) in tuned.into_iter().enumerate() {
                    if let Some(cur) = fleet.srv.batcher_config(g, stage) {
                        if t != cur {
                            fleet.srv.set_batcher(g, stage, t);
                            events.push(ControlEvent {
                                tick: sig.tick,
                                at_s,
                                kind: ControlEventKind::SloAdjust {
                                    group: g,
                                    stage,
                                    max_batch: t.max_batch,
                                    max_wait: t.max_wait,
                                },
                                ctx,
                            });
                        }
                    }
                }
            }
        }
    }
}

/// Fire every failure whose schedule time has passed. Checked in all
/// three phases of [`run_loop`] (arrival replay, drain, trailing ticks),
/// so a kill scheduled after the last arrival still fires.
fn fire_due_failures(
    fleet: &mut ControlledFleet,
    failures: &[FailureEvent],
    next_failure: &mut usize,
    elapsed_s: f64,
    tick_no: usize,
    events: &mut Vec<ControlEvent>,
) {
    while *next_failure < failures.len() && elapsed_s >= failures[*next_failure].at_s {
        let f = failures[*next_failure];
        *next_failure += 1;
        if fleet.kill(f.group).unwrap_or(false) {
            events.push(ControlEvent {
                tick: tick_no,
                at_s: elapsed_s,
                kind: ControlEventKind::Failure {
                    group: f.group,
                    survivors: fleet.group_count(),
                },
                // failures fire on the wall clock, between windows
                ctx: SignalCtx::default(),
            });
        }
    }
}

/// Resynchronize the tick deadline past `now`. A long actuation (a
/// drain-and-swap can take many periods) must *skip* the missed ticks,
/// not replay them back-to-back: replayed ticks would burn the
/// autoscaler's tick-denominated cooldown in zero wall time, on a signal
/// window that still reflects the pre-swap fleet.
fn skip_missed_ticks(next_tick: &mut Duration, tick: Duration, now: Duration) {
    *next_tick += tick;
    while *next_tick <= now {
        *next_tick += tick;
    }
}

/// Replay `trace` through `fleet` under closed-loop control: open-loop
/// arrival submission (sheds on overload), completion draining, control
/// ticks on the [`LoopConfig::tick`] cadence, the failure-injection
/// schedule, and [`LoopConfig::trailing_ticks`] idle ticks after the
/// drain. Returns the journaled decisions plus the fleet-wide serving
/// summary (per chain group e2e + per stage). The fleet stays running —
/// callers chain further replays (the SLO acceptance test replays a probe
/// trace through the converged fleet) or shut it down.
pub fn run_loop(fleet: &mut ControlledFleet, trace: &Trace, cfg: &LoopConfig) -> ControlReport {
    let mut rng = Rng::new(cfg.seed);
    let mut tap = SignalTap::new(cfg.signal);
    let mut scaler = cfg.autoscaler.map(Autoscaler::new);
    let slo = cfg.slo.map(SloController::new);
    let mut failures = cfg.failures.clone();
    failures.sort_by(|a, b| a.at_s.partial_cmp(&b.at_s).unwrap_or(std::cmp::Ordering::Equal));
    let mut next_failure = 0usize;
    let initial_groups = fleet.group_count();

    let mut fm = FleetMetrics::new(&fleet.metrics_shape());
    fm.start();
    let mut events: Vec<ControlEvent> = Vec::new();
    let t0 = Instant::now();
    let tick = cfg.tick.max(Duration::from_millis(1));
    let mut next_tick = tick;
    let input_len = cfg.input_len.max(1);

    'arrivals: for (idx, &due) in trace.arrivals_s.iter().enumerate() {
        loop {
            // scheduled failures fire by wall clock, ahead of control
            fire_due_failures(
                fleet,
                &failures,
                &mut next_failure,
                t0.elapsed().as_secs_f64(),
                tap.ticks(),
                &mut events,
            );
            if t0.elapsed() >= next_tick {
                let at_s = t0.elapsed().as_secs_f64();
                control_tick(fleet, &mut tap, &mut scaler, slo.as_ref(), at_s, &mut events);
                skip_missed_ticks(&mut next_tick, tick, t0.elapsed());
            }
            let now_s = t0.elapsed().as_secs_f64();
            if now_s >= due {
                break;
            }
            let wait_s = (due - now_s)
                .min((next_tick.as_secs_f64() - now_s).max(0.0))
                .min(0.005)
                .max(1e-4);
            if let Some(c) = fleet.srv.try_next_completion(Duration::from_secs_f64(wait_s)) {
                fm.record(&c);
                tap.record_completion(c.latency);
            }
        }
        let input: Vec<f32> = (0..input_len).map(|_| rng.below(256) as f32).collect();
        match fleet.srv.submit(idx as u64, input) {
            Ok(_) => {
                fm.record_submitted();
                tap.record_submitted();
            }
            Err(SubmitError::QueueFull(_)) | Err(SubmitError::Timeout(_)) => {
                fm.record_shed();
                tap.record_shed();
            }
            // untenanted control replay never stamps deadlines, but keep
            // the accounting honest if a caller wires one in
            Err(SubmitError::DeadlineInfeasible(_)) => {
                fm.record_deadline_shed(0);
                tap.record_shed();
            }
            Err(SubmitError::Closed(_)) => break 'arrivals,
        }
    }

    // drain every accepted request, still ticking so the post-trace lull
    // settles the window (stall guard mirrors Server::replay)
    let mut last_progress = Instant::now();
    while fm.completed() < fm.submitted() {
        fire_due_failures(
            fleet,
            &failures,
            &mut next_failure,
            t0.elapsed().as_secs_f64(),
            tap.ticks(),
            &mut events,
        );
        if t0.elapsed() >= next_tick {
            let at_s = t0.elapsed().as_secs_f64();
            control_tick(fleet, &mut tap, &mut scaler, slo.as_ref(), at_s, &mut events);
            skip_missed_ticks(&mut next_tick, tick, t0.elapsed());
        }
        match fleet.srv.try_next_completion(Duration::from_millis(5)) {
            Some(c) => {
                fm.record(&c);
                tap.record_completion(c.latency);
                last_progress = Instant::now();
            }
            None => {
                if last_progress.elapsed() > Duration::from_secs(10) {
                    break;
                }
            }
        }
    }
    // idle trailing ticks: a drained fleet's scale-in is part of the story
    for _ in 0..cfg.trailing_ticks {
        let now = t0.elapsed();
        if next_tick > now {
            std::thread::sleep(next_tick - now);
        }
        fire_due_failures(
            fleet,
            &failures,
            &mut next_failure,
            t0.elapsed().as_secs_f64(),
            tap.ticks(),
            &mut events,
        );
        let at_s = t0.elapsed().as_secs_f64();
        control_tick(fleet, &mut tap, &mut scaler, slo.as_ref(), at_s, &mut events);
        skip_missed_ticks(&mut next_tick, tick, t0.elapsed());
    }

    let mut max_groups_seen = initial_groups;
    for e in &events {
        if let ControlEventKind::ScaleOut { to, .. } = e.kind {
            max_groups_seen = max_groups_seen.max(to);
        }
    }
    fm.set_hot(fleet.srv.hot_stats());
    ControlReport {
        summary: fm.summary(),
        events,
        ticks: tap.ticks(),
        initial_groups,
        final_groups: fleet.group_count(),
        max_groups_seen,
        submitted: fm.submitted(),
        shed: fm.shed(),
        completed: fm.completed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{alveo_u250, alveo_u280, zynq_7020};
    use crate::nn::{cnv, CnvVariant};

    fn bc() -> BatcherConfig {
        BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) }
    }

    #[test]
    fn fleet_scaling_is_capacity_aware_and_bounded() {
        let net = cnv(CnvVariant::W1A1);
        let active = vec![ReplicaSpec::paper_point(alveo_u280())];
        let standby = vec![
            ReplicaSpec::paper_point(alveo_u280()),
            ReplicaSpec::paper_point(alveo_u250()),
        ];
        let mut fleet = ControlledFleet::start(net, active, standby, 100.0, bc(), 16);
        assert_eq!(fleet.group_count(), 1);
        assert_eq!(fleet.stages(), 1);
        // the faster U250 standby joins first
        assert_eq!(fleet.scale_out(1).unwrap(), 1);
        assert_eq!(fleet.group_specs(1)[0].device.name, "alveo-u250");
        // pool exhaustion bounds the next scale-out
        assert_eq!(fleet.scale_out(5).unwrap(), 1);
        assert_eq!(fleet.standby_len(), 0);
        // scale-in retires the slowest group (a U280) and never empties
        // the fleet
        assert_eq!(fleet.scale_in(1).unwrap(), 1);
        assert!((0..fleet.group_count())
            .any(|g| fleet.group_specs(g)[0].device.name == "alveo-u250"));
        assert_eq!(fleet.scale_in(10).unwrap(), 1);
        assert_eq!(fleet.group_count(), 1);
        assert_eq!(fleet.scale_in(1).unwrap(), 0, "last group must survive");
        // the server still serves after all that reshaping
        fleet.server().submit_blocking(1, vec![1.0]).unwrap();
        let c = fleet.server().next_completion().unwrap();
        assert_eq!(c.id, 1);
        fleet.shutdown();
    }

    #[test]
    fn chained_fleet_scales_whole_groups_only() {
        let net = cnv(CnvVariant::W1A1);
        let specs = |k: usize| -> Vec<ReplicaSpec> {
            (0..k).map(|_| ReplicaSpec::paper_point(zynq_7020())).collect()
        };
        // one 2-stage group active, 3 standby devices: only one more whole
        // group can be funded (the third device is a spare, not a shard)
        let mut fleet =
            ControlledFleet::start_chained(net, vec![specs(2)], specs(3), 100.0, bc(), 16);
        assert_eq!((fleet.group_count(), fleet.stages(), fleet.device_count()), (1, 2, 2));
        assert_eq!(fleet.scale_out(5).unwrap(), 1, "3 standby devices fund one 2-stage group");
        assert_eq!(fleet.group_count(), 2);
        assert_eq!(fleet.device_count(), 4);
        assert_eq!(fleet.standby_len(), 1, "the odd device stays in standby");
        // scale-in releases a whole group's devices back
        assert_eq!(fleet.scale_in(1).unwrap(), 1);
        assert_eq!(fleet.standby_len(), 3);
        // frames still traverse both stages end-to-end
        fleet.server().submit_blocking(9, vec![2.0]).unwrap();
        let c = fleet.server().next_completion().unwrap();
        assert_eq!(c.stage_latencies.len(), 2, "chain group must report both stages");
        fleet.shutdown();
    }

    #[test]
    fn chain_slo_base_adapts_instead_of_collapsing_to_the_bottleneck() {
        let net = cnv(CnvVariant::W1A1);
        // heterogeneous 2-stage group: the Zynq stage is the bottleneck,
        // the much faster U250 stage has co-tuning headroom
        let group = vec![
            ReplicaSpec::paper_point(zynq_7020()),
            ReplicaSpec::paper_point(alveo_u250()),
        ];
        let batcher = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) };
        let mut fleet =
            ControlledFleet::start_chained(net, vec![group], vec![], 500.0, batcher, 32);
        let svc = fleet.group_service(0).to_vec();
        assert!(svc[0] > svc[1], "stage 0 must be the bottleneck: {svc:?}");
        let sl = SloController::new(SloConfig::default()); // 50 ms budget, batch cap 16
        let mut tap = SignalTap::new(SignalConfig { window_ticks: 1 });
        let mut scaler: Option<Autoscaler> = None;
        let mut events = Vec::new();
        // quiet ticks far under budget: the per-group MIMD base must
        // *grow* toward the SLO cap even though co-tuning pins the
        // bottleneck stage greedy every tick — reading the actuated
        // stage-0 config back as the base would collapse it to 1
        for _ in 0..5 {
            tap.record_completion(Duration::from_millis(2));
            control_tick(&mut fleet, &mut tap, &mut scaler, Some(&sl), 0.0, &mut events);
        }
        assert!(
            fleet.active[0].slo_base.max_batch >= 8,
            "group MIMD base failed to grow: {:?}",
            fleet.active[0].slo_base
        );
        // the bottleneck stage stays greedy regardless
        let b0 = fleet.server().batcher_config(0, 0).unwrap();
        assert_eq!((b0.max_batch, b0.max_wait), (1, Duration::ZERO));
        // the fast stage's actuated batch never shrinks across quiet ticks
        let b1 = fleet.server().batcher_config(0, 1).unwrap();
        assert!(b1.max_batch >= 1);
        fleet.shutdown();
    }

    #[test]
    fn kill_removes_the_group_for_good() {
        let net = cnv(CnvVariant::W1A1);
        let active = vec![
            ReplicaSpec::paper_point(alveo_u250()),
            ReplicaSpec::paper_point(alveo_u280()),
        ];
        let mut fleet = ControlledFleet::start(net, active, vec![], 100.0, bc(), 16);
        assert!(fleet.kill(0).unwrap());
        assert_eq!(fleet.group_count(), 1);
        assert_eq!(fleet.standby_len(), 0, "a dead group must not rejoin via standby");
        assert!(!fleet.kill(0).unwrap(), "the last group cannot be killed");
        assert!(!fleet.kill(7).unwrap(), "out-of-range kill is a no-op");
        fleet.shutdown();
    }

    #[test]
    fn run_loop_without_controllers_replays_and_drains() {
        let net = cnv(CnvVariant::W1A1);
        let active = vec![ReplicaSpec::paper_point(alveo_u250())];
        let mut fleet = ControlledFleet::start(net, active, vec![], 50.0, bc(), 64);
        let trace = crate::coordinator::poisson(60, 800.0, 5);
        let cfg = LoopConfig { trailing_ticks: 2, ..LoopConfig::default() };
        let rep = run_loop(&mut fleet, &trace, &cfg);
        fleet.shutdown();
        assert_eq!(rep.submitted, 60);
        assert_eq!(rep.completed, 60, "every accepted request must drain");
        assert_eq!(rep.shed, 0);
        assert!(rep.ticks >= 2, "trailing ticks must fire even on short traces");
        assert!(rep.events.is_empty(), "no controllers, no events");
        assert_eq!(rep.initial_groups, 1);
        assert_eq!(rep.final_groups, 1);
    }

    #[test]
    fn event_journal_roundtrips_through_disk() {
        let events = vec![
            ControlEvent {
                tick: 4,
                at_s: 0.1125,
                kind: ControlEventKind::ScaleOut { from: 1, to: 2 },
                // values on the quantization grid, as the capture path
                // produces them (rates 1e-6, p99 1e-4)
                ctx: SignalCtx { shed_rate: 0.333_333, p99_ms: Some(12.345_7), util: 0.876_543 },
            },
            ControlEvent {
                tick: 9,
                at_s: 0.25,
                kind: ControlEventKind::SloAdjust {
                    group: 1,
                    stage: 0,
                    max_batch: 8,
                    // sub-microsecond component: the nanosecond encoding
                    // must carry it through the round-trip exactly
                    max_wait: Duration::from_nanos(1_500_417),
                },
                // an idle window: no completions, no p99
                ctx: SignalCtx { shed_rate: 0.0, p99_ms: None, util: 0.25 },
            },
            ControlEvent {
                tick: 12,
                at_s: 0.31,
                kind: ControlEventKind::Failure { group: 0, survivors: 1 },
                ctx: SignalCtx::default(),
            },
            ControlEvent {
                tick: 20,
                at_s: 0.5,
                kind: ControlEventKind::ScaleIn { from: 2, to: 1 },
                ctx: SignalCtx { shed_rate: 0.0, p99_ms: Some(1.5), util: 0.05 },
            },
        ];
        let path = std::env::temp_dir().join("fcmp_events_roundtrip_test.txt");
        save_events(&events, &path).unwrap();
        let back = load_events(&path).unwrap();
        assert_eq!(back.len(), events.len());
        for (a, b) in events.iter().zip(&back) {
            assert_eq!(a.tick, b.tick);
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.ctx, b.ctx, "signal context must round-trip bit-exactly");
            assert!((a.at_s - b.at_s).abs() < 1e-6, "{} vs {}", a.at_s, b.at_s);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn v1_journals_load_with_zero_context() {
        let path = std::env::temp_dir().join("fcmp_events_v1_compat_test.txt");
        std::fs::write(
            &path,
            "# fcmp-events v1\n0.5 3 scale-out 1 2\n0.75 5 slo-adjust 0 1 8 1500417\n",
        )
        .unwrap();
        let back = load_events(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].kind, ControlEventKind::ScaleOut { from: 1, to: 2 });
        assert_eq!(back[0].ctx, SignalCtx::default());
        assert_eq!(back[1].ctx, SignalCtx::default());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn event_journal_rejects_garbage() {
        let path = std::env::temp_dir().join("fcmp_events_bad_test.txt");
        std::fs::write(&path, "# fcmp-events v1\n0.5 3 scale-out 1\n").unwrap();
        assert!(load_events(&path).is_err(), "missing field must be rejected");
        std::fs::write(&path, "0.5 3 teleport 1 2\n").unwrap();
        assert!(load_events(&path).is_err(), "unknown kind must be rejected");
        std::fs::write(&path, "-1 3 scale-out 1 2\n").unwrap();
        assert!(load_events(&path).is_err(), "negative time must be rejected");
        std::fs::write(&path, "0.5 3 scale-out 1 2 9\n").unwrap();
        assert!(load_events(&path).is_err(), "trailing fields must be rejected");
        std::fs::write(&path, "# comment\n\n0.25 2 failure 0 1\n").unwrap();
        let ok = load_events(&path).unwrap();
        assert_eq!(ok.len(), 1);
        assert_eq!(ok[0].kind, ControlEventKind::Failure { group: 0, survivors: 1 });
        let _ = std::fs::remove_file(&path);
    }
}
