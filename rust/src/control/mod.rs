//! Adaptive control plane: the subsystem that closes the loop between
//! fleet metrics and fleet shape.
//!
//! Everything below the coordinator picks a *static* design point — an
//! FCMP packing, a shard plan, a replica count. Production load is not
//! static: it drifts (diurnal), steps (flash crowds) and breaks (device
//! loss). The control plane re-picks the deployed point at runtime,
//! deterministically, on a fixed tick:
//!
//! ```text
//!   Server / FleetMetrics                 (observe)
//!        │  submits, sheds, completions, outstanding
//!        v
//!   signal::SignalTap ── windowed shed rate, p99, utilization
//!        │                               (decide, once per tick)
//!        ├─> autoscaler::Autoscaler ── hysteresis-banded Out/In/Hold
//!        ├─> slo::SloController ────── batching-window MIMD vs p99 budget
//!        └─> repair::replan ────────── re-partition on device loss
//!        │                               (actuate)
//!        ├─> ControlledFleet::scale_out/in  → Server::reconfigure
//!        ├─> Server::set_batcher            (live, no drain)
//!        └─> repair::splice_mock_chain      → Server::reconfigure_chain
//! ```
//!
//! [`run_loop`] is the driver: it replays an arrival trace open-loop
//! (like [`crate::coordinator::Server::replay`]) while firing the control
//! tick on its own cadence, applying a failure-injection schedule, and
//! journaling every decision as a [`ControlEvent`]. All controllers are
//! pure functions of the observed signal sequence, so a run is replayable
//! and the tests can assert on decisions, not just outcomes.
//!
//! Surfaces: `fcmp autoscale` (CLI), `benches/control_loop.rs`
//! (`BENCH_control.json`), `tests/control.rs` (acceptance).

pub mod autoscaler;
pub mod repair;
pub mod signal;
pub mod slo;

pub use autoscaler::{rank_by_capacity, Autoscaler, AutoscalerConfig, ScaleDecision};
pub use repair::{replan, splice_mock_chain, RepairOutcome};
pub use signal::{ControlSignals, SignalConfig, SignalTap};
pub use slo::{co_tune_chain, SloConfig, SloController};

use std::time::{Duration, Instant};

use crate::coordinator::{
    fleet_weights, replica_fps, BatcherConfig, FleetMetrics, FleetSummary, MockBackend,
    Policy, ReplicaSpec, Server, ServerConfig, SubmitError, Trace,
};
use crate::nn::Network;
use crate::util::rng::Rng;

/// One scheduled device loss: at `at_s` seconds into the run, active
/// replica `replica` dies (it leaves the fleet entirely — a dead device
/// does not return to standby).
#[derive(Clone, Copy, Debug)]
pub struct FailureEvent {
    /// Seconds from the start of the replay.
    pub at_s: f64,
    /// Index into the active replica list at firing time.
    pub replica: usize,
}

/// Driver-loop configuration.
#[derive(Clone, Debug)]
pub struct LoopConfig {
    /// Control period: signals are aggregated and decisions made once per
    /// tick.
    pub tick: Duration,
    /// Signal-window shape.
    pub signal: SignalConfig,
    /// Autoscaling policy; `None` runs a static fleet (the baseline arm).
    pub autoscaler: Option<AutoscalerConfig>,
    /// SLO batching controller; `None` leaves batchers at their baseline.
    pub slo: Option<SloConfig>,
    /// Failure-injection schedule (fired in time order).
    pub failures: Vec<FailureEvent>,
    /// Extra idle control ticks after the drain, so scale-in on a
    /// quiesced fleet is observable even when the trace ends under load.
    pub trailing_ticks: usize,
    /// Elements per synthetic request input.
    pub input_len: usize,
    /// Seed for the synthetic inputs.
    pub seed: u64,
}

impl Default for LoopConfig {
    fn default() -> Self {
        LoopConfig {
            tick: Duration::from_millis(25),
            signal: SignalConfig::default(),
            autoscaler: None,
            slo: None,
            failures: Vec::new(),
            trailing_ticks: 8,
            input_len: 8,
            seed: 2020,
        }
    }
}

/// One journaled control-plane decision.
#[derive(Clone, Debug)]
pub enum ControlEvent {
    /// The autoscaler grew the fleet from `from` to `to` replicas.
    ScaleOut {
        /// Tick the decision fired on.
        tick: usize,
        /// Replicas before.
        from: usize,
        /// Replicas after.
        to: usize,
    },
    /// The autoscaler shrank the fleet from `from` to `to` replicas.
    ScaleIn {
        /// Tick the decision fired on.
        tick: usize,
        /// Replicas before.
        from: usize,
        /// Replicas after.
        to: usize,
    },
    /// The SLO controller retuned a replica's batcher.
    SloAdjust {
        /// Tick the adjustment fired on.
        tick: usize,
        /// Replica retuned.
        replica: usize,
        /// New batch-size cap.
        max_batch: usize,
        /// New batching window.
        max_wait: Duration,
    },
    /// A scheduled device loss fired.
    Failure {
        /// Tick count when the failure fired.
        tick: usize,
        /// Active index of the victim at firing time.
        replica: usize,
        /// Replicas remaining after the loss.
        survivors: usize,
    },
}

impl std::fmt::Display for ControlEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ControlEvent::ScaleOut { tick, from, to } => {
                write!(f, "tick {tick}: scale-out {from} -> {to} replicas")
            }
            ControlEvent::ScaleIn { tick, from, to } => {
                write!(f, "tick {tick}: scale-in {from} -> {to} replicas")
            }
            ControlEvent::SloAdjust { tick, replica, max_batch, max_wait } => write!(
                f,
                "tick {tick}: slo-adjust replica {replica}: batch {max_batch}, wait {max_wait:?}"
            ),
            ControlEvent::Failure { tick, replica, survivors } => {
                write!(f, "tick {tick}: FAILURE replica {replica} ({survivors} survive)")
            }
        }
    }
}

/// Result of one controlled replay.
#[derive(Debug)]
pub struct ControlReport {
    /// Fleet-wide serving summary of the whole run.
    pub summary: FleetSummary,
    /// Every control decision, in firing order.
    pub events: Vec<ControlEvent>,
    /// Control ticks fired.
    pub ticks: usize,
    /// Replicas at the start.
    pub initial_replicas: usize,
    /// Replicas at the end.
    pub final_replicas: usize,
    /// Largest fleet the run reached.
    pub max_replicas_seen: usize,
    /// Requests accepted.
    pub submitted: usize,
    /// Requests shed by admission control.
    pub shed: usize,
    /// Requests completed.
    pub completed: usize,
}

impl ControlReport {
    /// Scale-out decisions that took effect.
    pub fn scale_outs(&self) -> usize {
        self.events.iter().filter(|e| matches!(e, ControlEvent::ScaleOut { .. })).count()
    }

    /// Scale-in decisions that took effect.
    pub fn scale_ins(&self) -> usize {
        self.events.iter().filter(|e| matches!(e, ControlEvent::ScaleIn { .. })).count()
    }

    /// Failures that fired.
    pub fn failures(&self) -> usize {
        self.events.iter().filter(|e| matches!(e, ControlEvent::Failure { .. })).count()
    }

    /// Overall shed rate: `shed / (submitted + shed)` (0 when idle).
    pub fn shed_rate(&self) -> f64 {
        let offered = self.submitted + self.shed;
        if offered == 0 {
            0.0
        } else {
            self.shed as f64 / offered as f64
        }
    }

    /// Ticks of every scale decision (out and in), in firing order — the
    /// cooldown-bound assertions read consecutive gaps off this.
    pub fn scale_ticks(&self) -> Vec<usize> {
        self.events
            .iter()
            .filter_map(|e| match e {
                ControlEvent::ScaleOut { tick, .. } | ControlEvent::ScaleIn { tick, .. } => {
                    Some(*tick)
                }
                _ => None,
            })
            .collect()
    }
}

/// A mock-backed replicated fleet the control plane can reshape: a
/// [`Server`] plus the [`ReplicaSpec`]s behind it (active) and the device
/// pool scale-out can draw from (standby).
///
/// Per-replica mock service times derive from the analytic capacity model
/// ([`replica_fps`]): the fastest device in the initial pool serves one
/// item in `service_us` microseconds and every other device scales up by
/// its FPS ratio, so the fleet's heterogeneity — and every capacity-aware
/// placement decision — is observable without hardware. The router policy
/// is capacity-weighted ([`Policy::Weighted`]) and re-derived on every
/// reshape.
pub struct ControlledFleet {
    net: Network,
    service_us: f64,
    ref_fps: f64,
    batcher: BatcherConfig,
    queue_depth: usize,
    active: Vec<ReplicaSpec>,
    standby: Vec<ReplicaSpec>,
    srv: Server,
}

fn service_time(net: &Network, spec: &ReplicaSpec, service_us: f64, ref_fps: f64) -> Duration {
    let fps = replica_fps(net, spec).max(1e-9);
    Duration::from_secs_f64(service_us * 1e-6 * ref_fps / fps)
}

impl ControlledFleet {
    /// Start a fleet of `active` replicas with `standby` devices held for
    /// scale-out. `service_us` is the per-item mock service time of the
    /// fastest device anywhere in the pool.
    pub fn start(
        net: Network,
        active: Vec<ReplicaSpec>,
        standby: Vec<ReplicaSpec>,
        service_us: f64,
        batcher: BatcherConfig,
        queue_depth: usize,
    ) -> ControlledFleet {
        assert!(!active.is_empty(), "a controlled fleet needs at least one active replica");
        let ref_fps = active
            .iter()
            .chain(standby.iter())
            .map(|s| replica_fps(&net, s))
            .fold(0.0f64, f64::max)
            .max(1e-9);
        let weights = fleet_weights(&net, &active);
        let svc: Vec<Duration> =
            active.iter().map(|s| service_time(&net, s, service_us, ref_fps)).collect();
        let cfg = ServerConfig {
            batcher,
            queue_depth,
            replicas: active.len(),
            policy: Policy::Weighted(weights),
        };
        let srv =
            Server::start(move |i| MockBackend::with_service(Duration::ZERO, svc[i]), cfg);
        ControlledFleet {
            net,
            service_us,
            ref_fps,
            batcher,
            queue_depth,
            active,
            standby,
            srv,
        }
    }

    /// Active replica count.
    pub fn replicas(&self) -> usize {
        self.active.len()
    }

    /// Devices still available for scale-out.
    pub fn standby_len(&self) -> usize {
        self.standby.len()
    }

    /// The active replica specs, in router order.
    pub fn active_specs(&self) -> &[ReplicaSpec] {
        &self.active
    }

    /// The underlying server (submit/drain directly, e.g. from tests).
    pub fn server(&mut self) -> &mut Server {
        &mut self.srv
    }

    /// Shut the fleet down (drains; the server is unusable afterwards).
    pub fn shutdown(&mut self) {
        self.srv.shutdown();
    }

    /// Drain-and-swap the server onto the current active specs.
    fn respawn(&mut self) -> crate::Result<()> {
        let weights = fleet_weights(&self.net, &self.active);
        let svc: Vec<Duration> = self
            .active
            .iter()
            .map(|s| service_time(&self.net, s, self.service_us, self.ref_fps))
            .collect();
        let cfg = ServerConfig {
            batcher: self.batcher,
            queue_depth: self.queue_depth,
            replicas: self.active.len().max(1),
            policy: Policy::Weighted(weights),
        };
        self.srv
            .reconfigure(move |i| MockBackend::with_service(Duration::ZERO, svc[i]), cfg)
    }

    /// Scale out by up to `want` replicas, capacity-aware: the fastest
    /// standby devices join first. Returns how many actually joined
    /// (bounded by the standby pool).
    pub fn scale_out(&mut self, want: usize) -> crate::Result<usize> {
        if want == 0 || self.standby.is_empty() {
            return Ok(0);
        }
        let mut picks: Vec<usize> =
            rank_by_capacity(&self.net, &self.standby).into_iter().take(want).collect();
        let added = picks.len();
        picks.sort_unstable_by(|a, b| b.cmp(a)); // remove back-to-front
        for i in picks {
            let spec = self.standby.remove(i);
            self.active.push(spec);
        }
        self.respawn()?;
        Ok(added)
    }

    /// Scale in by up to `want` replicas, retiring the slowest first
    /// (back to standby). The fleet never shrinks below one replica.
    /// Returns how many were retired.
    pub fn scale_in(&mut self, want: usize) -> crate::Result<usize> {
        let removable = self.active.len().saturating_sub(1);
        let want = want.min(removable);
        if want == 0 {
            return Ok(0);
        }
        let mut retire: Vec<usize> = rank_by_capacity(&self.net, &self.active)
            .into_iter()
            .rev() // slowest-first
            .take(want)
            .collect();
        retire.sort_unstable_by(|a, b| b.cmp(a));
        for i in retire {
            let spec = self.active.remove(i);
            self.standby.push(spec);
        }
        self.respawn()?;
        Ok(want)
    }

    /// Simulated device loss: active replica `replica` leaves the fleet
    /// for good (it does **not** return to standby) and the survivors are
    /// respawned. Returns `false` (and does nothing) when the index is
    /// out of range or only one replica remains — a fleet cannot be
    /// emptied, matching the partitioner's "at least one device" rule.
    pub fn kill(&mut self, replica: usize) -> crate::Result<bool> {
        if replica >= self.active.len() || self.active.len() <= 1 {
            return Ok(false);
        }
        self.active.remove(replica);
        self.respawn()?;
        Ok(true)
    }
}

/// One control tick: sample utilization, close the signal window, let the
/// autoscaler reshape the fleet and the SLO controller retune batchers.
fn control_tick(
    fleet: &mut ControlledFleet,
    tap: &mut SignalTap,
    scaler: &mut Option<Autoscaler>,
    slo: Option<&SloController>,
    events: &mut Vec<ControlEvent>,
) {
    tap.observe_utilization(&fleet.srv.outstanding(), fleet.queue_depth);
    let sig = tap.tick();
    if let Some(sc) = scaler.as_mut() {
        match sc.decide(&sig, fleet.replicas()) {
            ScaleDecision::Out(k) => {
                let from = fleet.replicas();
                if let Ok(added) = fleet.scale_out(k) {
                    // the cooldown starts only when the fleet actually
                    // changed — a no-op against an exhausted standby pool
                    // must not delay later legitimate actions
                    if added > 0 {
                        sc.note_action(sig.tick);
                        events.push(ControlEvent::ScaleOut {
                            tick: sig.tick,
                            from,
                            to: from + added,
                        });
                    }
                }
            }
            ScaleDecision::In(k) => {
                let from = fleet.replicas();
                if let Ok(removed) = fleet.scale_in(k) {
                    if removed > 0 {
                        sc.note_action(sig.tick);
                        events.push(ControlEvent::ScaleIn {
                            tick: sig.tick,
                            from,
                            to: from - removed,
                        });
                    }
                }
            }
            ScaleDecision::Hold => {}
        }
    }
    if let Some(sl) = slo {
        for r in 0..fleet.srv.replica_count() {
            if let Some(cur) = fleet.srv.batcher_config(r) {
                let next = sl.adjust(sig.p99_ms, cur);
                if next.max_batch != cur.max_batch || next.max_wait != cur.max_wait {
                    fleet.srv.set_batcher(r, next);
                    events.push(ControlEvent::SloAdjust {
                        tick: sig.tick,
                        replica: r,
                        max_batch: next.max_batch,
                        max_wait: next.max_wait,
                    });
                }
            }
        }
    }
}

/// Fire every failure whose schedule time has passed. Checked in all
/// three phases of [`run_loop`] (arrival replay, drain, trailing ticks),
/// so a kill scheduled after the last arrival still fires.
fn fire_due_failures(
    fleet: &mut ControlledFleet,
    failures: &[FailureEvent],
    next_failure: &mut usize,
    elapsed_s: f64,
    tick_no: usize,
    events: &mut Vec<ControlEvent>,
) {
    while *next_failure < failures.len() && elapsed_s >= failures[*next_failure].at_s {
        let f = failures[*next_failure];
        *next_failure += 1;
        if fleet.kill(f.replica).unwrap_or(false) {
            events.push(ControlEvent::Failure {
                tick: tick_no,
                replica: f.replica,
                survivors: fleet.replicas(),
            });
        }
    }
}

/// Resynchronize the tick deadline past `now`. A long actuation (a
/// drain-and-swap can take many periods) must *skip* the missed ticks,
/// not replay them back-to-back: replayed ticks would burn the
/// autoscaler's tick-denominated cooldown in zero wall time, on a signal
/// window that still reflects the pre-swap fleet.
fn skip_missed_ticks(next_tick: &mut Duration, tick: Duration, now: Duration) {
    *next_tick += tick;
    while *next_tick <= now {
        *next_tick += tick;
    }
}

/// Replay `trace` through `fleet` under closed-loop control: open-loop
/// arrival submission (sheds on overload), completion draining, control
/// ticks on the [`LoopConfig::tick`] cadence, the failure-injection
/// schedule, and [`LoopConfig::trailing_ticks`] idle ticks after the
/// drain. Returns the journaled decisions plus the fleet-wide serving
/// summary. The fleet stays running — callers chain further replays (the
/// SLO acceptance test replays a probe trace through the converged fleet)
/// or shut it down.
pub fn run_loop(fleet: &mut ControlledFleet, trace: &Trace, cfg: &LoopConfig) -> ControlReport {
    let mut rng = Rng::new(cfg.seed);
    let mut tap = SignalTap::new(cfg.signal);
    let mut scaler = cfg.autoscaler.map(Autoscaler::new);
    let slo = cfg.slo.map(SloController::new);
    let mut failures = cfg.failures.clone();
    failures.sort_by(|a, b| a.at_s.partial_cmp(&b.at_s).unwrap_or(std::cmp::Ordering::Equal));
    let mut next_failure = 0usize;
    let initial_replicas = fleet.replicas();

    let mut fm = FleetMetrics::new(fleet.active.len() + fleet.standby.len());
    fm.start();
    let mut events: Vec<ControlEvent> = Vec::new();
    let t0 = Instant::now();
    let tick = cfg.tick.max(Duration::from_millis(1));
    let mut next_tick = tick;
    let input_len = cfg.input_len.max(1);

    'arrivals: for (idx, &due) in trace.arrivals_s.iter().enumerate() {
        loop {
            // scheduled failures fire by wall clock, ahead of control
            fire_due_failures(
                fleet,
                &failures,
                &mut next_failure,
                t0.elapsed().as_secs_f64(),
                tap.ticks(),
                &mut events,
            );
            if t0.elapsed() >= next_tick {
                control_tick(fleet, &mut tap, &mut scaler, slo.as_ref(), &mut events);
                skip_missed_ticks(&mut next_tick, tick, t0.elapsed());
            }
            let now_s = t0.elapsed().as_secs_f64();
            if now_s >= due {
                break;
            }
            let wait_s = (due - now_s)
                .min((next_tick.as_secs_f64() - now_s).max(0.0))
                .min(0.005)
                .max(1e-4);
            if let Some(c) = fleet.srv.try_next_completion(Duration::from_secs_f64(wait_s)) {
                fm.record(&c);
                tap.record_completion(c.latency);
            }
        }
        let input: Vec<f32> = (0..input_len).map(|_| rng.below(256) as f32).collect();
        match fleet.srv.submit(idx as u64, input) {
            Ok(_) => {
                fm.record_submitted();
                tap.record_submitted();
            }
            Err(SubmitError::QueueFull(_)) => {
                fm.record_shed();
                tap.record_shed();
            }
            Err(SubmitError::Closed(_)) => break 'arrivals,
        }
    }

    // drain every accepted request, still ticking so the post-trace lull
    // settles the window (stall guard mirrors Server::replay)
    let mut last_progress = Instant::now();
    while fm.completed() < fm.submitted() {
        fire_due_failures(
            fleet,
            &failures,
            &mut next_failure,
            t0.elapsed().as_secs_f64(),
            tap.ticks(),
            &mut events,
        );
        if t0.elapsed() >= next_tick {
            control_tick(fleet, &mut tap, &mut scaler, slo.as_ref(), &mut events);
            skip_missed_ticks(&mut next_tick, tick, t0.elapsed());
        }
        match fleet.srv.try_next_completion(Duration::from_millis(5)) {
            Some(c) => {
                fm.record(&c);
                tap.record_completion(c.latency);
                last_progress = Instant::now();
            }
            None => {
                if last_progress.elapsed() > Duration::from_secs(10) {
                    break;
                }
            }
        }
    }
    // idle trailing ticks: a drained fleet's scale-in is part of the story
    for _ in 0..cfg.trailing_ticks {
        let now = t0.elapsed();
        if next_tick > now {
            std::thread::sleep(next_tick - now);
        }
        fire_due_failures(
            fleet,
            &failures,
            &mut next_failure,
            t0.elapsed().as_secs_f64(),
            tap.ticks(),
            &mut events,
        );
        control_tick(fleet, &mut tap, &mut scaler, slo.as_ref(), &mut events);
        skip_missed_ticks(&mut next_tick, tick, t0.elapsed());
    }

    let mut max_replicas_seen = initial_replicas;
    for e in &events {
        if let ControlEvent::ScaleOut { to, .. } = e {
            max_replicas_seen = max_replicas_seen.max(*to);
        }
    }
    ControlReport {
        summary: fm.summary(),
        events,
        ticks: tap.ticks(),
        initial_replicas,
        final_replicas: fleet.replicas(),
        max_replicas_seen,
        submitted: fm.submitted(),
        shed: fm.shed(),
        completed: fm.completed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{alveo_u250, alveo_u280};
    use crate::nn::{cnv, CnvVariant};

    fn bc() -> BatcherConfig {
        BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) }
    }

    #[test]
    fn fleet_scaling_is_capacity_aware_and_bounded() {
        let net = cnv(CnvVariant::W1A1);
        let active = vec![ReplicaSpec::paper_point(alveo_u280())];
        let standby = vec![
            ReplicaSpec::paper_point(alveo_u280()),
            ReplicaSpec::paper_point(alveo_u250()),
        ];
        let mut fleet = ControlledFleet::start(net, active, standby, 100.0, bc(), 16);
        assert_eq!(fleet.replicas(), 1);
        // the faster U250 standby joins first
        assert_eq!(fleet.scale_out(1).unwrap(), 1);
        assert_eq!(fleet.active_specs()[1].device.name, "alveo-u250");
        // pool exhaustion bounds the next scale-out
        assert_eq!(fleet.scale_out(5).unwrap(), 1);
        assert_eq!(fleet.standby_len(), 0);
        // scale-in retires the slowest (a U280) and never empties the fleet
        assert_eq!(fleet.scale_in(1).unwrap(), 1);
        assert!(fleet.active_specs().iter().any(|s| s.device.name == "alveo-u250"));
        assert_eq!(fleet.scale_in(10).unwrap(), 1);
        assert_eq!(fleet.replicas(), 1);
        assert_eq!(fleet.scale_in(1).unwrap(), 0, "last replica must survive");
        // the server still serves after all that reshaping
        fleet.server().submit_blocking(1, vec![1.0]).unwrap();
        let c = fleet.server().next_completion().unwrap();
        assert_eq!(c.id, 1);
        fleet.shutdown();
    }

    #[test]
    fn kill_removes_the_device_for_good() {
        let net = cnv(CnvVariant::W1A1);
        let active = vec![
            ReplicaSpec::paper_point(alveo_u250()),
            ReplicaSpec::paper_point(alveo_u280()),
        ];
        let mut fleet = ControlledFleet::start(net, active, vec![], 100.0, bc(), 16);
        assert!(fleet.kill(0).unwrap());
        assert_eq!(fleet.replicas(), 1);
        assert_eq!(fleet.standby_len(), 0, "a dead device must not rejoin via standby");
        assert!(!fleet.kill(0).unwrap(), "the last replica cannot be killed");
        assert!(!fleet.kill(7).unwrap(), "out-of-range kill is a no-op");
        fleet.shutdown();
    }

    #[test]
    fn run_loop_without_controllers_replays_and_drains() {
        let net = cnv(CnvVariant::W1A1);
        let active = vec![ReplicaSpec::paper_point(alveo_u250())];
        let mut fleet = ControlledFleet::start(net, active, vec![], 50.0, bc(), 64);
        let trace = crate::coordinator::poisson(60, 800.0, 5);
        let cfg = LoopConfig { trailing_ticks: 2, ..LoopConfig::default() };
        let rep = run_loop(&mut fleet, &trace, &cfg);
        fleet.shutdown();
        assert_eq!(rep.submitted, 60);
        assert_eq!(rep.completed, 60, "every accepted request must drain");
        assert_eq!(rep.shed, 0);
        assert!(rep.ticks >= 2, "trailing ticks must fire even on short traces");
        assert!(rep.events.is_empty(), "no controllers, no events");
        assert_eq!(rep.initial_replicas, 1);
        assert_eq!(rep.final_replicas, 1);
    }
}
