//! Hysteresis-banded chain-group autoscaler.
//!
//! The unit of scaling is a whole **chain group** of the
//! [`crate::coordinator::Deployment`] topology (a k-stage pipeline; a
//! plain replica is the k=1 case). The policy is deliberately asymmetric,
//! which is where the hysteresis band comes from: **scale out** fires on
//! distress (windowed shed rate above [`AutoscalerConfig::shed_out`], or
//! windowed p99 above [`AutoscalerConfig::p99_out_ms`]), while **scale
//! in** requires the fleet to be *provably* idle — zero sheds in the
//! window, every worker's utilization under [`AutoscalerConfig::util_in`],
//! and p99 comfortably inside budget. Between the two thresholds the
//! controller holds, so a fleet hovering near capacity never flaps. A
//! cooldown of [`AutoscalerConfig::cooldown_ticks`] after every action
//! gives each decision one reconfiguration's worth of signal before the
//! next — without it, the window still reflecting pre-scale sheds would
//! trigger a second scale-out immediately.
//!
//! Placement is capacity-aware via [`rank_by_capacity`]: a scale-out
//! builds its new group from the fastest standby devices first (analytic
//! FPS from [`crate::coordinator::capacity`]), a scale-in retires the
//! slowest active group first.

use crate::coordinator::{replica_fps, ReplicaSpec};
use crate::nn::Network;

use super::signal::ControlSignals;

/// Autoscaler thresholds and bounds (in chain groups).
#[derive(Clone, Copy, Debug)]
pub struct AutoscalerConfig {
    /// Never scale below this many chain groups.
    pub min_groups: usize,
    /// Never scale above this many chain groups (also bounded by the
    /// standby device pool — a group needs `stages` devices).
    pub max_groups: usize,
    /// Scale out when the windowed shed rate exceeds this.
    pub shed_out: f64,
    /// Scale out when the windowed p99 (ms) exceeds this
    /// (`f64::INFINITY` disables the latency trigger).
    pub p99_out_ms: f64,
    /// Scale in only when every worker's windowed utilization is below
    /// this (and the window saw zero sheds).
    pub util_in: f64,
    /// Ticks to hold after any scale action before deciding again.
    pub cooldown_ticks: usize,
    /// Chain groups added/removed per decision.
    pub step: usize,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        AutoscalerConfig {
            min_groups: 1,
            max_groups: 8,
            shed_out: 0.02,
            p99_out_ms: f64::INFINITY,
            util_in: 0.25,
            cooldown_ticks: 4,
            step: 1,
        }
    }
}

/// One autoscaling decision, as a chain-group-count delta.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleDecision {
    /// No change this tick.
    Hold,
    /// Add this many chain groups.
    Out(usize),
    /// Remove this many chain groups.
    In(usize),
}

/// Deterministic tick-driven scaling controller: same signal sequence,
/// same decision sequence.
pub struct Autoscaler {
    cfg: AutoscalerConfig,
    last_action_tick: Option<usize>,
    seen_traffic: bool,
}

impl Autoscaler {
    /// Controller with the given thresholds.
    pub fn new(cfg: AutoscalerConfig) -> Autoscaler {
        Autoscaler { cfg, last_action_tick: None, seen_traffic: false }
    }

    /// The configured thresholds.
    pub fn config(&self) -> &AutoscalerConfig {
        &self.cfg
    }

    /// Decide for the tick that produced `signals`, with `current` active
    /// chain groups. Pure function of the observed signal sequence (plus
    /// the cooldown clock), so the control loop is replayable. The
    /// cooldown clock only advances via [`Autoscaler::note_action`], which
    /// the driver calls when a decision *actually* reshaped the fleet — a
    /// decision that no-ops (standby pool exhausted) must not burn the
    /// cooldown, or a later legitimate action would be delayed for no
    /// journaled reason.
    pub fn decide(&mut self, signals: &ControlSignals, current: usize) -> ScaleDecision {
        if signals.offered > 0 {
            self.seen_traffic = true;
        }
        if let Some(last) = self.last_action_tick {
            if signals.tick.saturating_sub(last) < self.cfg.cooldown_ticks {
                return ScaleDecision::Hold;
            }
        }
        let overloaded = signals.shed_rate > self.cfg.shed_out
            || signals.p99_ms.map_or(false, |p| p > self.cfg.p99_out_ms);
        if overloaded && current < self.cfg.max_groups {
            let step = self.cfg.step.max(1).min(self.cfg.max_groups - current);
            return ScaleDecision::Out(step);
        }
        // the scale-in side of the hysteresis band: provably idle only —
        // and never before the first traffic, or an empty pre-trace window
        // would fold the fleet below its provisioned size
        let idle = self.seen_traffic
            && signals.shed == 0
            && signals.max_utilization < self.cfg.util_in
            && signals.p99_ms.map_or(true, |p| p < 0.5 * self.cfg.p99_out_ms);
        if idle && current > self.cfg.min_groups {
            let step = self.cfg.step.max(1).min(current - self.cfg.min_groups);
            return ScaleDecision::In(step);
        }
        ScaleDecision::Hold
    }

    /// Start the cooldown: a decision from [`Autoscaler::decide`] was
    /// actuated at `tick` and changed the fleet.
    pub fn note_action(&mut self, tick: usize) {
        self.last_action_tick = Some(tick);
    }
}

/// Capacity-aware placement order: indices of `pool` sorted fastest-first
/// by analytic throughput of `net` at each spec (ties break toward the
/// lower index, so the order — and with it every scale decision — is
/// deterministic). Scale-out consumes this order from the front to staff
/// a new chain group; scale-in retires groups from the slow end.
pub fn rank_by_capacity(net: &Network, pool: &[ReplicaSpec]) -> Vec<usize> {
    let fps: Vec<f64> = pool.iter().map(|s| replica_fps(net, s)).collect();
    let mut idx: Vec<usize> = (0..pool.len()).collect();
    idx.sort_by(|&a, &b| {
        fps[b].partial_cmp(&fps[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    fn signals(
        tick: usize,
        shed_rate: f64,
        shed: u64,
        util: f64,
        p99: Option<f64>,
    ) -> ControlSignals {
        ControlSignals {
            tick,
            offered: 100,
            shed,
            shed_rate,
            completed: 100 - shed,
            p50_ms: p99.map(|p| p / 2.0),
            p99_ms: p99,
            utilization: vec![util],
            max_utilization: util,
        }
    }

    fn cfg() -> AutoscalerConfig {
        AutoscalerConfig {
            min_groups: 1,
            max_groups: 4,
            shed_out: 0.05,
            p99_out_ms: 100.0,
            util_in: 0.25,
            cooldown_ticks: 3,
            step: 1,
        }
    }

    #[test]
    fn sheds_trigger_scale_out_until_the_max() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.decide(&signals(0, 0.3, 30, 0.9, None), 1), ScaleDecision::Out(1));
        // at max: overloaded but can't grow
        let mut b = Autoscaler::new(cfg());
        assert_eq!(b.decide(&signals(0, 0.3, 30, 0.9, None), 4), ScaleDecision::Hold);
    }

    #[test]
    fn p99_breach_also_triggers_scale_out() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(
            a.decide(&signals(0, 0.0, 0, 0.9, Some(250.0)), 2),
            ScaleDecision::Out(1)
        );
    }

    #[test]
    fn cooldown_holds_between_actuated_actions() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.decide(&signals(0, 0.3, 30, 0.9, None), 1), ScaleDecision::Out(1));
        a.note_action(0); // the driver actuated the decision
        assert_eq!(a.decide(&signals(1, 0.3, 30, 0.9, None), 2), ScaleDecision::Hold);
        assert_eq!(a.decide(&signals(2, 0.3, 30, 0.9, None), 2), ScaleDecision::Hold);
        // cooldown of 3 ticks elapsed at tick 3
        assert_eq!(a.decide(&signals(3, 0.3, 30, 0.9, None), 2), ScaleDecision::Out(1));
    }

    #[test]
    fn unactuated_decisions_do_not_burn_the_cooldown() {
        // the driver could not actuate (standby exhausted): no note_action,
        // so the very next tick may still decide — including the other
        // direction once the overload clears
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.decide(&signals(0, 0.3, 30, 0.9, None), 2), ScaleDecision::Out(1));
        assert_eq!(a.decide(&signals(1, 0.3, 30, 0.9, None), 2), ScaleDecision::Out(1));
        assert_eq!(a.decide(&signals(2, 0.0, 0, 0.05, Some(10.0)), 2), ScaleDecision::In(1));
    }

    #[test]
    fn scale_in_requires_a_provably_idle_window_after_traffic() {
        let mut a = Autoscaler::new(cfg());
        // pre-traffic idle window must NOT fold the fleet
        let mut pre = signals(0, 0.0, 0, 0.0, None);
        pre.offered = 0;
        assert_eq!(a.decide(&pre, 3), ScaleDecision::Hold);
        // traffic seen, then an idle window: scale in
        assert_eq!(a.decide(&signals(1, 0.0, 0, 0.6, None), 3), ScaleDecision::Hold);
        assert_eq!(a.decide(&signals(2, 0.0, 0, 0.1, Some(10.0)), 3), ScaleDecision::In(1));
        // min bound: idle but already at minimum
        let mut b = Autoscaler::new(cfg());
        b.decide(&signals(0, 0.2, 20, 0.9, None), 1); // sees traffic (and scales)
        assert_eq!(b.decide(&signals(9, 0.0, 0, 0.0, None), 1), ScaleDecision::Hold);
    }

    #[test]
    fn hysteresis_band_holds_between_thresholds() {
        let mut a = Autoscaler::new(cfg());
        // busy but not shedding, p99 inside budget: neither direction
        assert_eq!(
            a.decide(&signals(0, 0.0, 0, 0.7, Some(60.0)), 2),
            ScaleDecision::Hold
        );
    }

    #[test]
    fn placement_ranks_fastest_first_deterministically() {
        let net = crate::nn::cnv(crate::nn::CnvVariant::W1A1);
        let pool = vec![
            ReplicaSpec::paper_point(crate::device::alveo_u280()),
            ReplicaSpec::paper_point(crate::device::alveo_u250()),
            ReplicaSpec::paper_point(crate::device::alveo_u280()),
        ];
        let order = rank_by_capacity(&net, &pool);
        assert_eq!(order.len(), 3);
        // Table V: the U250 point out-clocks the 99%-dense U280 point
        assert_eq!(order[0], 1, "fastest device must rank first: {order:?}");
        // equal-speed U280s tie toward the lower index
        assert_eq!(&order[1..], &[0, 2]);
        assert_eq!(order, rank_by_capacity(&net, &pool), "ranking must be stable");
    }
}
