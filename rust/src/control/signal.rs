//! Windowed control signals computed from the serving fleet's metrics
//! plumbing.
//!
//! The control plane is tick-driven: the driver loop feeds every
//! admission-control outcome and completion into a [`SignalTap`] as it
//! happens, samples per-replica utilization once per tick, and closes the
//! tick with [`SignalTap::tick`], which aggregates the last
//! [`SignalConfig::window_ticks`] ticks into one [`ControlSignals`]
//! snapshot. Windowing is what makes the downstream controllers stable:
//! a single 25 ms tick of shed requests is noise, the same shed rate
//! sustained over a window is a capacity shortfall.

use std::collections::VecDeque;
use std::time::Duration;

use crate::util::stats::percentile;

/// Signal-window configuration.
#[derive(Clone, Copy, Debug)]
pub struct SignalConfig {
    /// Ticks aggregated into each [`ControlSignals`] snapshot.
    pub window_ticks: usize,
}

impl Default for SignalConfig {
    fn default() -> Self {
        SignalConfig { window_ticks: 4 }
    }
}

/// Everything observed during one control tick.
#[derive(Clone, Debug, Default)]
struct TickSample {
    submitted: u64,
    shed: u64,
    latencies_ms: Vec<f64>,
    /// Per-replica `outstanding / queue_depth` sampled at tick close.
    utilization: Vec<f64>,
}

/// One windowed snapshot of the fleet's control signals.
#[derive(Clone, Debug)]
pub struct ControlSignals {
    /// Tick number this snapshot closed (0-based, monotonic).
    pub tick: usize,
    /// Requests offered (accepted + shed) inside the window.
    pub offered: u64,
    /// Requests shed by admission control inside the window.
    pub shed: u64,
    /// `shed / offered` (0 when nothing was offered).
    pub shed_rate: f64,
    /// Completions inside the window.
    pub completed: u64,
    /// Windowed latency median (ms); `None` when nothing completed.
    pub p50_ms: Option<f64>,
    /// Windowed latency p99 (ms); `None` when nothing completed.
    pub p99_ms: Option<f64>,
    /// Per-replica mean utilization (outstanding / queue depth) over the
    /// window, shaped to the most recent tick's replica count.
    pub utilization: Vec<f64>,
    /// Max over [`ControlSignals::utilization`] (0 when empty).
    pub max_utilization: f64,
}

/// Accumulates per-tick observations and aggregates them over a sliding
/// window; the driver loop owns one per controlled fleet.
pub struct SignalTap {
    window: usize,
    closed: VecDeque<TickSample>,
    cur: TickSample,
    ticks: usize,
}

impl SignalTap {
    /// Empty tap with the given window.
    pub fn new(cfg: SignalConfig) -> SignalTap {
        SignalTap {
            window: cfg.window_ticks.max(1),
            closed: VecDeque::new(),
            cur: TickSample::default(),
            ticks: 0,
        }
    }

    /// Ticks closed so far.
    pub fn ticks(&self) -> usize {
        self.ticks
    }

    /// Count one accepted submission in the current tick.
    pub fn record_submitted(&mut self) {
        self.cur.submitted += 1;
    }

    /// Count one shed (admission-rejected) submission in the current tick.
    pub fn record_shed(&mut self) {
        self.cur.shed += 1;
    }

    /// Record one completion's end-to-end latency in the current tick.
    pub fn record_completion(&mut self, latency: Duration) {
        self.cur.latencies_ms.push(latency.as_secs_f64() * 1e3);
    }

    /// Sample per-replica utilization (outstanding / `queue_depth`) for
    /// the current tick; the last sample before [`SignalTap::tick`] wins.
    pub fn observe_utilization(&mut self, outstanding: &[usize], queue_depth: usize) {
        let depth = queue_depth.max(1) as f64;
        self.cur.utilization = outstanding.iter().map(|&o| o as f64 / depth).collect();
    }

    /// Close the current tick and aggregate the window into one
    /// [`ControlSignals`] snapshot.
    pub fn tick(&mut self) -> ControlSignals {
        let sample = std::mem::take(&mut self.cur);
        self.closed.push_back(sample);
        while self.closed.len() > self.window {
            self.closed.pop_front();
        }
        let tick = self.ticks;
        self.ticks += 1;

        let submitted: u64 = self.closed.iter().map(|t| t.submitted).sum();
        let shed: u64 = self.closed.iter().map(|t| t.shed).sum();
        let offered = submitted + shed;
        let mut lat: Vec<f64> =
            self.closed.iter().flat_map(|t| t.latencies_ms.iter().copied()).collect();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let (p50, p99) = if lat.is_empty() {
            (None, None)
        } else {
            (Some(percentile(&lat, 50.0)), Some(percentile(&lat, 99.0)))
        };

        // utilization averages elementwise over the window, shaped to the
        // newest tick's replica count (the fleet may have been resized
        // mid-window; stale extra replicas are dropped, missing ones
        // average over the ticks that saw them)
        let replicas = self.closed.back().map(|t| t.utilization.len()).unwrap_or(0);
        let mut util = vec![0.0f64; replicas];
        let mut seen = vec![0usize; replicas];
        for t in &self.closed {
            for (i, &u) in t.utilization.iter().enumerate() {
                if i < replicas {
                    util[i] += u;
                    seen[i] += 1;
                }
            }
        }
        for i in 0..replicas {
            if seen[i] > 0 {
                util[i] /= seen[i] as f64;
            }
        }
        let max_utilization = util.iter().copied().fold(0.0f64, f64::max);

        ControlSignals {
            tick,
            offered,
            shed,
            shed_rate: if offered == 0 { 0.0 } else { shed as f64 / offered as f64 },
            completed: lat.len() as u64,
            p50_ms: p50,
            p99_ms: p99,
            utilization: util,
            max_utilization,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shed_rate_and_counts_aggregate_over_the_window() {
        let mut tap = SignalTap::new(SignalConfig { window_ticks: 2 });
        for _ in 0..8 {
            tap.record_submitted();
        }
        for _ in 0..2 {
            tap.record_shed();
        }
        let s = tap.tick();
        assert_eq!(s.tick, 0);
        assert_eq!(s.offered, 10);
        assert_eq!(s.shed, 2);
        assert!((s.shed_rate - 0.2).abs() < 1e-12);

        // next tick is quiet; window still sees the previous tick
        let s = tap.tick();
        assert_eq!(s.tick, 1);
        assert_eq!(s.offered, 10);
        // third tick evicts the loaded one: all-quiet window
        let s = tap.tick();
        assert_eq!(s.offered, 0);
        assert_eq!(s.shed_rate, 0.0);
    }

    #[test]
    fn latency_percentiles_cover_the_window() {
        let mut tap = SignalTap::new(SignalConfig { window_ticks: 3 });
        assert!(tap.tick().p99_ms.is_none(), "no completions yet");
        for ms in [10u64, 20, 30, 40] {
            tap.record_completion(Duration::from_millis(ms));
        }
        let s = tap.tick();
        assert_eq!(s.completed, 4);
        assert!((s.p50_ms.unwrap() - 25.0).abs() < 1e-9);
        assert!(s.p99_ms.unwrap() > 39.0);
        // the window keeps earlier completions until eviction
        tap.record_completion(Duration::from_millis(50));
        let s = tap.tick();
        assert_eq!(s.completed, 5);
    }

    #[test]
    fn utilization_averages_and_tracks_fleet_resizes() {
        let mut tap = SignalTap::new(SignalConfig { window_ticks: 2 });
        tap.observe_utilization(&[8, 0], 16);
        let s = tap.tick();
        assert_eq!(s.utilization.len(), 2);
        assert!((s.utilization[0] - 0.5).abs() < 1e-12);
        // fleet grew to 3 replicas; snapshot reshapes to the newest tick
        tap.observe_utilization(&[16, 8, 4], 16);
        let s = tap.tick();
        assert_eq!(s.utilization.len(), 3);
        // replica 0 averages over both ticks: (0.5 + 1.0) / 2
        assert!((s.utilization[0] - 0.75).abs() < 1e-12);
        // replica 2 only existed in the newest tick
        assert!((s.utilization[2] - 0.25).abs() < 1e-12);
        assert!((s.max_utilization - 0.75).abs() < 1e-12);
    }
}
