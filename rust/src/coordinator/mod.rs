//! Multi-replica sharded serving coordinator — the L3 request path. Rust
//! only, python never runs here (tokio is unavailable offline; std::thread +
//! bounded mpsc channels provide the async substrate, see DESIGN.md
//! substitutions).
//!
//! The fleet topology is one composable abstraction: a [`Deployment`] is
//! an ordered set of [`ChainGroup`]s, each a k-stage pipeline chain.
//! `N × 1` is the flat replicated fleet, `1 × k` a single sharded stage
//! chain, and `N × k` the replicated-chain shape that lifts sharded
//! throughput beyond one pipeline:
//!
//! ```text
//!  clients ──> Server (router)
//!                 │ admission control: bounded queues, shed on overload
//!                 │ Scheduler: round-robin | join-shortest-queue | weighted
//!                 │           (weights = analytic sim/timing capacity of
//!                 │            each group's devices + FCMP configuration)
//!        ┌────────┼──────────────────┐
//!        v        v                  v
//!    group 0   group 1     ...   group N-1      each group: k chained
//!    s0→…→sk   s0→…→sk           s0→…→sk        stages, each stage a
//!        │        │                  │          bounded queue → dynamic
//!        └────────┴────────┬─────────┘          batcher → worker thread
//!                          v                    owning its InferBackend
//!          completions (id, group, stage, e2e + per-stage latency)
//!                          │
//!                          v
//!          FleetMetrics: p50/p95/p99 fleet-wide, per group (e2e) and
//!                        per stage, submitted/shed counters
//! ```
//!
//! Frames enter a group at its stage 0; each stage's outputs forward into
//! the next stage's bounded queue (the inter-device FIFO — a full
//! downstream queue backpressures the upstream worker), and only the final
//! stage emits completions, carrying per-stage latencies plus the
//! end-to-end latency.
//!
//! Module map: [`deployment`] (the topology plan), [`policy`] (group
//! scheduling), `replica` (stage worker, private), [`capacity`] (analytic
//! capacity weights), [`server`] (router, admission control, group
//! diffing, shutdown-drain), [`batcher`] (size-or-deadline batching),
//! [`metrics`] (latency histograms), [`hotpath`] (request buffer
//! recycling + hot-path profile counters), [`workload`] (arrival traces).
//!
//! The request path is a **zero-stall execution path**: submits go
//! through a cheaply-cloneable [`SubmitHandle`] whose hot path is an
//! atomic load plus a bounded-channel `try_send` (no router lock), each
//! worker keeps up to [`Deployment::window`] batches in flight so the
//! next batch forms and transfers while the current one computes, and
//! request payload buffers recycle through a [`BufferPool`] so the
//! steady state allocates nothing per request.
//!
//! The fleet shape is **not** static: [`Server::apply`] diffs a new plan
//! against the running one at chain-group granularity — unchanged groups
//! keep serving, removed groups drain, added groups spawn on the same
//! live completion stream — and [`Server::set_batcher`] retunes a running
//! worker's batching window in place. Together they are the actuation
//! surface of the adaptive control plane ([`crate::control`]).

pub mod batcher;
pub mod capacity;
pub mod deployment;
pub mod dispatch;
pub mod hotpath;
pub mod metrics;
pub mod policy;
mod replica;
pub mod server;
pub mod workload;

pub use batcher::{Batch, BatcherConfig, SharedBatcher};
pub use capacity::{
    chain_fps, fleet_weights, group_weights, mock_chain_service, mock_chain_service_from_fps,
    mock_service_from_fps, mock_service_time, overlap_speedup, replica_fps, shard_service_times,
    ReplicaSpec,
};
pub use deployment::{ChainGroup, Deployment, WorkerId};
pub use hotpath::{BufferPool, HotPathStats};
pub use metrics::{FleetMetrics, FleetSummary, Metrics, ServeSummary, TenantSummary};
pub use policy::{Policy, Scheduler};
pub use server::{
    BatchHandle, InferBackend, MockBackend, PipelinedMockBackend, Server, SubmitError,
    SubmitHandle,
};
pub use workload::{bursty, diurnal, flash_crowd, heavy_tail, poisson, uniform, Trace};

use std::time::{Duration, Instant};

/// One inference request.
#[derive(Debug)]
pub struct Request {
    /// Caller-chosen identifier, echoed in the [`Completion`].
    pub id: u64,
    /// Flattened input image (f32, manifest sample element count).
    pub input: Vec<f32>,
    /// Submission time (end-to-end latency accounting starts here).
    pub arrival: Instant,
    /// Arrival at the *current* stage of a chain group (== `arrival` until
    /// the first hop; reset at every chain forward).
    pub stage_arrival: Instant,
    /// Per-stage latencies accumulated while traversing a chain group
    /// (empty on 1-stage groups).
    pub stage_latencies: Vec<Duration>,
    /// Batch size the frame rode in at each traversed stage (parallel to
    /// `stage_latencies`).
    pub stage_batches: Vec<usize>,
    /// Flight-recorder span when this request was sampled for tracing
    /// (`None` for the unsampled majority — one branch per stamp site).
    pub span: Option<Box<crate::obs::RequestSpan>>,
    /// Completion deadline from the submitting tenant's SLO budget
    /// (`None` = best-effort). The router's deadline-feasibility rule
    /// ([`crate::coordinator::dispatch::deadline_feasible`]) sheds the
    /// request up front when no group can plausibly meet it.
    pub deadline: Option<Instant>,
}

impl Request {
    /// A fresh request arriving now.
    pub fn new(id: u64, input: Vec<f32>) -> Request {
        let now = Instant::now();
        Request {
            id,
            input,
            arrival: now,
            stage_arrival: now,
            stage_latencies: Vec::new(),
            stage_batches: Vec::new(),
            span: None,
            deadline: None,
        }
    }

    /// Stamp a completion deadline `budget` past the arrival instant.
    pub fn with_deadline(mut self, budget: Duration) -> Request {
        self.deadline = Some(self.arrival + budget);
        self
    }
}

/// One completed inference.
#[derive(Clone, Debug)]
pub struct Completion {
    /// The [`Request::id`] this completion answers.
    pub id: u64,
    /// Flattened output row.
    pub output: Vec<f32>,
    /// Queue + batch + execute latency — end-to-end across every stage of
    /// the serving chain group.
    pub latency: std::time::Duration,
    /// Size of the batch this request rode in (at the final stage).
    pub batch_size: usize,
    /// Index of the chain group that served it, at its *current* position
    /// in the deployment (groups kept across [`Server::apply`] stamp
    /// their new index).
    pub group: usize,
    /// Stage within the group that emitted the completion (`k - 1` for a
    /// k-stage chain, `0` for a plain replica).
    pub stage: usize,
    /// Per-stage latencies for chain groups, in traversal order
    /// (`len == chain length`); empty on 1-stage groups.
    pub stage_latencies: Vec<Duration>,
    /// Per-stage batch sizes, parallel to `stage_latencies` (each stage
    /// batches independently).
    pub stage_batches: Vec<usize>,
    /// The request's flight-recorder span, terminal-stamped; recycle it
    /// via [`crate::obs::Obs::recycle`] after consuming the completion.
    pub span: Option<Box<crate::obs::RequestSpan>>,
}
