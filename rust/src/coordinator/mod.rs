//! Multi-replica sharded serving coordinator — the L3 request path. Rust
//! only, python never runs here (tokio is unavailable offline; std::thread +
//! bounded mpsc channels provide the async substrate, see DESIGN.md
//! substitutions).
//!
//! Architecture (data-center FPGA serving, scaled to this paper's porting
//! story: one accelerator design deployed on a *heterogeneous* fleet of
//! devices with different per-device throughput):
//!
//! ```text
//!  clients ──> Server (router)
//!                 │ admission control: bounded queues, shed on overload
//!                 │ Scheduler: round-robin | join-shortest-queue | weighted
//!                 │           (weights = analytic sim/timing capacity of
//!                 │            each replica's device + FCMP configuration)
//!        ┌────────┼─────────────┐
//!        v        v             v
//!   replica 0  replica 1 ... replica N-1     each: bounded queue
//!        │        │             │                  → dynamic batcher
//!        └────────┴──────┬──────┘                  → worker thread owning
//!                        v                            its InferBackend
//!              completions (id, latency, batch, replica)
//!                        │
//!                        v
//!              FleetMetrics: p50/p95/p99 per replica + fleet-wide,
//!                            submitted/shed counters
//! ```
//!
//! A replica group can also be a **stage chain** (pipeline-parallel
//! sharding, [`crate::sharding`]): [`Server::start_chain`] wires stage
//! `i`'s outputs into stage `i+1`'s bounded queue, every frame traverses
//! stages `0..k-1` in order, and the final completion carries per-stage
//! transit latencies plus the end-to-end latency ([`FleetMetrics`] then
//! reports per-stage queues and an end-to-end p99).
//!
//! Module map: [`policy`] (scheduling), `replica` (worker shard, private),
//! [`capacity`] (analytic capacity weights), [`server`] (router, admission
//! control, shutdown-drain), [`batcher`] (size-or-deadline batching),
//! [`metrics`] (latency percentiles), [`workload`] (arrival traces).
//!
//! The fleet shape is **not** static: [`Server::reconfigure`] /
//! [`Server::reconfigure_chain`] drain-and-swap the replica set on a live
//! completion stream, and [`Server::set_batcher`] retunes a running
//! replica's batching window in place — the actuation surface of the
//! adaptive control plane ([`crate::control`]).

pub mod batcher;
pub mod capacity;
pub mod metrics;
pub mod policy;
mod replica;
pub mod server;
pub mod workload;

pub use batcher::{Batch, BatcherConfig, SharedBatcher};
pub use capacity::{fleet_weights, replica_fps, shard_service_times, ReplicaSpec};
pub use metrics::{FleetMetrics, FleetSummary, Metrics, ServeSummary};
pub use policy::{Policy, Scheduler};
pub use server::{InferBackend, MockBackend, Server, ServerConfig, SubmitError};
pub use workload::{bursty, diurnal, flash_crowd, heavy_tail, poisson, uniform, Trace};

use std::time::{Duration, Instant};

/// One inference request.
#[derive(Debug)]
pub struct Request {
    /// Caller-chosen identifier, echoed in the [`Completion`].
    pub id: u64,
    /// Flattened input image (f32, manifest sample element count).
    pub input: Vec<f32>,
    /// Submission time (end-to-end latency accounting starts here).
    pub arrival: Instant,
    /// Arrival at the *current* stage of a stage chain (== `arrival` until
    /// the first hop; reset at every chain forward).
    pub stage_arrival: Instant,
    /// Per-stage latencies accumulated while traversing a stage chain
    /// (empty on replicated fleets).
    pub stage_latencies: Vec<Duration>,
    /// Batch size the frame rode in at each traversed stage (parallel to
    /// `stage_latencies`).
    pub stage_batches: Vec<usize>,
}

impl Request {
    /// A fresh request arriving now.
    pub fn new(id: u64, input: Vec<f32>) -> Request {
        let now = Instant::now();
        Request {
            id,
            input,
            arrival: now,
            stage_arrival: now,
            stage_latencies: Vec::new(),
            stage_batches: Vec::new(),
        }
    }
}

/// One completed inference.
#[derive(Clone, Debug)]
pub struct Completion {
    /// The [`Request::id`] this completion answers.
    pub id: u64,
    /// Flattened output row.
    pub output: Vec<f32>,
    /// Queue + batch + execute latency — end-to-end across every stage for
    /// chain deployments.
    pub latency: std::time::Duration,
    /// Size of the batch this request rode in (at the final stage).
    pub batch_size: usize,
    /// Index of the replica that served it (the last stage of a chain).
    pub replica: usize,
    /// Per-stage latencies for stage-chain deployments, in traversal order
    /// (`len == chain length`); empty on replicated fleets.
    pub stage_latencies: Vec<Duration>,
    /// Per-stage batch sizes, parallel to `stage_latencies` (each stage
    /// batches independently).
    pub stage_batches: Vec<usize>,
}
