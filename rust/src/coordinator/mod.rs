//! Multi-replica sharded serving coordinator — the L3 request path. Rust
//! only, python never runs here (tokio is unavailable offline; std::thread +
//! bounded mpsc channels provide the async substrate, see DESIGN.md
//! substitutions).
//!
//! Architecture (data-center FPGA serving, scaled to this paper's porting
//! story: one accelerator design deployed on a *heterogeneous* fleet of
//! devices with different per-device throughput):
//!
//! ```text
//!  clients ──> Server (router)
//!                 │ admission control: bounded queues, shed on overload
//!                 │ Scheduler: round-robin | join-shortest-queue | weighted
//!                 │           (weights = analytic sim/timing capacity of
//!                 │            each replica's device + FCMP configuration)
//!        ┌────────┼─────────────┐
//!        v        v             v
//!   replica 0  replica 1 ... replica N-1     each: bounded queue
//!        │        │             │                  → dynamic batcher
//!        └────────┴──────┬──────┘                  → worker thread owning
//!                        v                            its InferBackend
//!              completions (id, latency, batch, replica)
//!                        │
//!                        v
//!              FleetMetrics: p50/p95/p99 per replica + fleet-wide,
//!                            submitted/shed counters
//! ```
//!
//! Module map: [`policy`] (scheduling), `replica` (worker shard, private),
//! [`capacity`] (analytic capacity weights), [`server`] (router, admission
//! control, shutdown-drain), [`batcher`] (size-or-deadline batching),
//! [`metrics`] (latency percentiles), [`workload`] (arrival traces).

pub mod batcher;
pub mod capacity;
pub mod metrics;
pub mod policy;
mod replica;
pub mod server;
pub mod workload;

pub use batcher::{Batch, BatcherConfig};
pub use capacity::{fleet_weights, replica_fps, ReplicaSpec};
pub use metrics::{FleetMetrics, FleetSummary, Metrics, ServeSummary};
pub use policy::{Policy, Scheduler};
pub use server::{InferBackend, MockBackend, Server, ServerConfig, SubmitError};
pub use workload::{bursty, heavy_tail, poisson, uniform, Trace};

use std::time::Instant;

/// One inference request.
#[derive(Debug)]
pub struct Request {
    /// Caller-chosen identifier, echoed in the [`Completion`].
    pub id: u64,
    /// Flattened input image (f32, manifest sample element count).
    pub input: Vec<f32>,
    /// Submission time (latency accounting starts here).
    pub arrival: Instant,
}

/// One completed inference.
#[derive(Clone, Debug)]
pub struct Completion {
    /// The [`Request::id`] this completion answers.
    pub id: u64,
    /// Flattened output row.
    pub output: Vec<f32>,
    /// Queue + batch + execute latency.
    pub latency: std::time::Duration,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
    /// Index of the replica that served it.
    pub replica: usize,
}
