//! Inference serving coordinator: request router, dynamic batcher, worker
//! pool and metrics. This is the L3 request path — rust only, python never
//! runs here (tokio is unavailable offline; std::thread + bounded mpsc
//! channels provide the async substrate, see DESIGN.md substitutions).
//!
//! Architecture (vLLM-router-like, scaled to this paper's serving story):
//!
//! ```text
//!  clients ──> Router (bounded queue, backpressure)
//!                 │ drain up to max_batch / wait up to max_wait
//!                 v
//!              Batcher ──> worker thread (owns the PJRT Engine)
//!                 │                 │ infer(batch)
//!                 v                 v
//!              completions (per-request latency, batch size) ──> Metrics
//! ```

pub mod batcher;
pub mod metrics;
pub mod server;
pub mod workload;

pub use batcher::{Batch, BatcherConfig};
pub use metrics::{Metrics, ServeSummary};
pub use server::{InferBackend, Server, ServerConfig};
pub use workload::{bursty, poisson, uniform, Trace};

use std::time::Instant;

/// One inference request.
pub struct Request {
    pub id: u64,
    /// Flattened input image (f32, manifest sample element count).
    pub input: Vec<f32>,
    pub arrival: Instant,
}

/// One completed inference.
pub struct Completion {
    pub id: u64,
    pub output: Vec<f32>,
    /// Queue + batch + execute latency.
    pub latency: std::time::Duration,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
}
