//! The sharded serving fleet: a router dispatches requests to N worker
//! replicas by a pluggable scheduling policy; each replica owns a bounded
//! queue, a dynamic batcher and its own [`InferBackend`]; completions from
//! all replicas merge into one stream.
//!
//! ```text
//!  clients ──> Server::submit ── Scheduler (policy) picks replica
//!                 │    admission control: full fleet => QueueFull (shed)
//!                 v
//!          ┌─ replica 0: bounded queue → batcher → worker(backend 0) ─┐
//!          ├─ replica 1: bounded queue → batcher → worker(backend 1) ─┤──> completions
//!          └─ replica k: bounded queue → batcher → worker(backend k) ─┘    (+ per-replica
//!                                                                           latency metrics)
//! ```
//!
//! **Overload semantics.** Each replica's queue is bounded
//! ([`ServerConfig::queue_depth`]). A non-blocking [`Server::submit`] tries
//! the policy's preferred replica first, then the remaining replicas in
//! ascending-load order; only when *every* open queue is full does it shed
//! the request with [`SubmitError::QueueFull`] — graceful degradation, never
//! unbounded memory. After [`Server::shutdown`] (or if all workers die) the
//! error is [`SubmitError::Closed`] instead, so callers can tell "retry
//! later" from "give up". Shutdown closes the queues and *drains* them:
//! every accepted request still produces a completion before the workers
//! exit.
//!
//! The backend is a trait so tests and benches run the full coordination
//! path with [`MockBackend`] (no PJRT); `examples/serve_cifar.rs` and
//! `fcmp serve --backend pjrt` plug in the real [`crate::runtime::Engine`].

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::batcher::BatcherConfig;
use super::metrics::FleetMetrics;
use super::policy::{Policy, Scheduler};
use super::replica::{Replica, Sink, TrySubmit};
use super::workload::Trace;
use super::{Completion, Request};
use crate::util::rng::Rng;
use crate::Result;

/// Anything that can run a batch of inputs. The backend is constructed
/// *inside* each worker thread (PJRT handles are not `Send`), so only the
/// factory closure crosses threads.
pub trait InferBackend: 'static {
    /// Run one batch; `inputs[i]` is a flattened sample, the result must
    /// hold one output row per input row.
    fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;
}

impl InferBackend for crate::runtime::Engine {
    fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.infer(inputs)
    }
}

/// Deterministic mock backend for tests, benches and `fcmp serve --backend
/// mock`: each output row is `[Σ inputs, batch_size]`, and a batch of `k`
/// requests takes `base + per_item · k` of simulated service time. Scaling
/// `base`/`per_item` per replica models a heterogeneous fleet.
#[derive(Clone, Copy, Debug)]
pub struct MockBackend {
    /// Fixed per-batch overhead (amortized by batching).
    pub base: Duration,
    /// Marginal service time per request in the batch.
    pub per_item: Duration,
}

impl MockBackend {
    /// Zero service time — completes as fast as the threads can run.
    pub fn instant() -> MockBackend {
        MockBackend { base: Duration::ZERO, per_item: Duration::ZERO }
    }

    /// Mock with the given service-time model.
    pub fn with_service(base: Duration, per_item: Duration) -> MockBackend {
        MockBackend { base, per_item }
    }
}

impl InferBackend for MockBackend {
    fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let service = self.base + self.per_item * inputs.len() as u32;
        if !service.is_zero() {
            std::thread::sleep(service);
        }
        Ok(inputs
            .iter()
            .map(|x| vec![x.iter().sum::<f32>(), inputs.len() as f32])
            .collect())
    }
}

/// Fleet configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Batching policy applied independently by every replica.
    pub batcher: BatcherConfig,
    /// Per-replica router queue bound (admission control: when every open
    /// queue is full, submits shed with [`SubmitError::QueueFull`]).
    pub queue_depth: usize,
    /// Number of worker replicas, each owning its own backend.
    pub replicas: usize,
    /// Scheduling policy routing requests to replicas.
    pub policy: Policy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batcher: BatcherConfig::default(),
            queue_depth: 256,
            replicas: 1,
            policy: Policy::RoundRobin,
        }
    }
}

/// Typed submit failure. The rejected request rides back in the error so
/// callers can retry without rebuilding the input buffer, and the two
/// variants make transient overload distinguishable from terminal shutdown.
#[derive(Debug)]
pub enum SubmitError {
    /// Every open replica queue was full — admission control shed the
    /// request. Retrying after a backoff can succeed.
    QueueFull(Request),
    /// The server is shut down (or every worker died). Retrying cannot
    /// succeed.
    Closed(Request),
}

impl SubmitError {
    /// Recover the rejected request (e.g. to retry it later).
    pub fn into_request(self) -> Request {
        match self {
            SubmitError::QueueFull(r) | SubmitError::Closed(r) => r,
        }
    }

    /// True iff the failure is terminal (no retry can succeed).
    pub fn is_closed(&self) -> bool {
        matches!(self, SubmitError::Closed(_))
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull(r) => {
                write!(f, "request {} shed: every replica queue is full", r.id)
            }
            SubmitError::Closed(r) => {
                write!(f, "request {} rejected: server is shut down", r.id)
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// A running multi-replica inference server.
pub struct Server {
    replicas: Vec<Replica>,
    scheduler: Scheduler,
    completions: Receiver<Completion>,
    /// Kept open across [`Server::reconfigure`] so a swapped-in fleet keeps
    /// feeding the same completion stream; dropped on [`Server::shutdown`]
    /// so the stream terminates once drained.
    completion_tx: Option<Sender<Completion>>,
    /// The replicas form a stage chain (pipeline-parallel sharding): all
    /// ingress goes to stage 0 and the router never falls back to a
    /// mid-chain stage.
    chain: bool,
}

impl Server {
    /// Spawn `cfg.replicas` workers. `make_backend(i)` runs on worker `i`'s
    /// thread (PJRT engines are thread-affine) and a panic there surfaces on
    /// first use of that replica.
    pub fn start<B, F>(make_backend: F, cfg: ServerConfig) -> Server
    where
        B: InferBackend,
        F: Fn(usize) -> B + Send + Sync + 'static,
    {
        let n = cfg.replicas.max(1);
        // completions are unbounded: backpressure belongs on the *request*
        // queues; a bounded completion channel can deadlock shutdown (worker
        // blocks on send while the owner blocks on join without draining)
        let (ctx, crx) = channel::<Completion>();
        let factory = Arc::new(make_backend);
        let replicas = Self::spawn_replicated(&factory, &cfg, &ctx);
        Server {
            replicas,
            scheduler: Scheduler::new(cfg.policy, n),
            completions: crx,
            completion_tx: Some(ctx),
            chain: false,
        }
    }

    /// Spawn `cfg.replicas` workers as a **stage chain** (one pipeline
    /// shard per stage, [`crate::sharding`]): requests enter stage 0, each
    /// stage's outputs forward into the next stage's bounded queue (the
    /// inter-device FIFO — a full downstream queue backpressures the
    /// upstream worker), and only the final stage emits completions,
    /// carrying per-stage latencies plus the end-to-end latency.
    /// `cfg.policy` is ignored; the chain always schedules as
    /// [`Policy::StageChain`].
    pub fn start_chain<B, F>(make_backend: F, cfg: ServerConfig) -> Server
    where
        B: InferBackend,
        F: Fn(usize) -> B + Send + Sync + 'static,
    {
        let k = cfg.replicas.max(1);
        let (ctx, crx) = channel::<Completion>();
        let factory = Arc::new(make_backend);
        let replicas = Self::spawn_chain_stages(&factory, &cfg, &ctx);
        Server {
            replicas,
            scheduler: Scheduler::new(Policy::StageChain, k),
            completions: crx,
            completion_tx: Some(ctx),
            chain: true,
        }
    }

    /// Spawn a replicated fleet feeding completions into `ctx`.
    fn spawn_replicated<B, F>(
        factory: &Arc<F>,
        cfg: &ServerConfig,
        ctx: &Sender<Completion>,
    ) -> Vec<Replica>
    where
        B: InferBackend,
        F: Fn(usize) -> B + Send + Sync + 'static,
    {
        (0..cfg.replicas.max(1))
            .map(|i| {
                let f = Arc::clone(factory);
                Replica::spawn(
                    i,
                    move || (*f)(i),
                    cfg.batcher,
                    cfg.queue_depth,
                    Sink::Complete(ctx.clone()),
                )
            })
            .collect()
    }

    /// Spawn a stage chain feeding the final stage's completions into
    /// `ctx`. Stages spawn back-to-front so stage `i` can hold stage
    /// `i+1`'s queue handle.
    fn spawn_chain_stages<B, F>(
        factory: &Arc<F>,
        cfg: &ServerConfig,
        ctx: &Sender<Completion>,
    ) -> Vec<Replica>
    where
        B: InferBackend,
        F: Fn(usize) -> B + Send + Sync + 'static,
    {
        let k = cfg.replicas.max(1);
        let mut replicas: Vec<Replica> = Vec::with_capacity(k);
        let mut downstream = None;
        for i in (0..k).rev() {
            let f = Arc::clone(factory);
            let sink = match downstream.take() {
                None => Sink::Complete(ctx.clone()),
                Some((next, next_outstanding)) => Sink::Forward { next, next_outstanding },
            };
            let r = Replica::spawn(i, move || (*f)(i), cfg.batcher, cfg.queue_depth, sink);
            downstream =
                Some((r.sender().expect("fresh replica is open"), r.outstanding_handle()));
            replicas.push(r);
        }
        replicas.reverse();
        replicas
    }

    /// **Drain-and-swap reconfiguration** (the control plane's actuation
    /// path, [`crate::control`]): stop admitting to the current replicas,
    /// drain every accepted request to completion, then spawn a fresh
    /// replicated fleet per `cfg` on the *same* completion stream —
    /// completions buffered before, during and after the swap all remain
    /// readable, so a driver loop never misses one. Fails only after
    /// [`Server::shutdown`] (the completion stream is gone for good).
    pub fn reconfigure<B, F>(&mut self, make_backend: F, cfg: ServerConfig) -> crate::Result<()>
    where
        B: InferBackend,
        F: Fn(usize) -> B + Send + Sync + 'static,
    {
        let ctx = self.drain_current()?;
        let n = cfg.replicas.max(1);
        let factory = Arc::new(make_backend);
        self.replicas = Self::spawn_replicated(&factory, &cfg, &ctx);
        self.scheduler = Scheduler::new(cfg.policy, n);
        self.chain = false;
        Ok(())
    }

    /// [`Server::reconfigure`], but the new fleet is a **stage chain**
    /// (used by the failure-repair path, [`crate::control::repair`], to
    /// splice a re-partitioned plan into a running server). The old
    /// stages drain front-to-back before the new chain spawns, so every
    /// in-flight frame finishes its traversal on the old plan.
    pub fn reconfigure_chain<B, F>(
        &mut self,
        make_backend: F,
        cfg: ServerConfig,
    ) -> crate::Result<()>
    where
        B: InferBackend,
        F: Fn(usize) -> B + Send + Sync + 'static,
    {
        let ctx = self.drain_current()?;
        let k = cfg.replicas.max(1);
        let factory = Arc::new(make_backend);
        self.replicas = Self::spawn_chain_stages(&factory, &cfg, &ctx);
        self.scheduler = Scheduler::new(Policy::StageChain, k);
        self.chain = true;
        Ok(())
    }

    /// Shared drain half of the drain-and-swap: stop admitting to every
    /// replica, drain all accepted requests to completion, and hand back
    /// the live completion sender for the replacement fleet. Fails after
    /// [`Server::shutdown`].
    fn drain_current(&mut self) -> crate::Result<Sender<Completion>> {
        let ctx = match self.completion_tx.clone() {
            Some(tx) => tx,
            None => anyhow::bail!("cannot reconfigure a server after shutdown"),
        };
        for r in &mut self.replicas {
            r.close();
        }
        for r in &mut self.replicas {
            r.join();
        }
        Ok(ctx)
    }

    /// Number of worker replicas.
    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Current batching settings of replica `replica` (`None` when the
    /// index is out of range).
    pub fn batcher_config(&self, replica: usize) -> Option<BatcherConfig> {
        self.replicas.get(replica).map(|r| r.batcher())
    }

    /// Live-retune replica `replica`'s batcher (the SLO controller's
    /// actuation, [`crate::control::slo`]): the worker applies the new
    /// settings on its next batch, with no drain and no respawn. Returns
    /// `false` when the index is out of range. Note a later
    /// [`Server::reconfigure`] respawns replicas at the configured
    /// baseline, discarding live adjustments.
    pub fn set_batcher(&self, replica: usize, cfg: BatcherConfig) -> bool {
        match self.replicas.get(replica) {
            Some(r) => {
                r.set_batcher(cfg);
                true
            }
            None => false,
        }
    }

    /// Per-replica outstanding request counts (queued + executing).
    pub fn outstanding(&self) -> Vec<usize> {
        self.replicas.iter().map(|r| r.outstanding()).collect()
    }

    /// Every worker died without a shutdown (panicked backends). The
    /// completion channel stays open (the server holds a sender for
    /// [`Server::reconfigure`]), so this probe — not channel
    /// disconnection — is how replay loops detect a dead fleet.
    fn all_workers_dead(&self) -> bool {
        !self.replicas.is_empty() && self.replicas.iter().all(|r| r.is_dead())
    }

    /// Non-blocking submit. Returns the replica index the request was routed
    /// to, or a typed [`SubmitError`] (overload shed vs shutdown).
    pub fn submit(&mut self, id: u64, input: Vec<f32>) -> std::result::Result<usize, SubmitError> {
        self.dispatch(Request::new(id, input))
    }

    /// Blocking submit: when the whole fleet is full it parks on the least
    /// loaded replica's bounded queue (stage 0 for a chain; the worker
    /// wakes it when a slot frees) instead of spin-retrying; only terminal
    /// shutdown makes it fail.
    pub fn submit_blocking(
        &mut self,
        id: u64,
        input: Vec<f32>,
    ) -> std::result::Result<usize, SubmitError> {
        let mut req = Request::new(id, input);
        loop {
            req = match self.dispatch(req) {
                Ok(i) => return Ok(i),
                Err(SubmitError::Closed(r)) => return Err(SubmitError::Closed(r)),
                Err(SubmitError::QueueFull(r)) => r,
            };
            let i = if self.chain {
                0
            } else {
                self.replicas
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, r)| r.outstanding())
                    .map(|(i, _)| i)
                    .unwrap()
            };
            req = match self.replicas[i].submit_wait(req) {
                Ok(()) => return Ok(i),
                // a dead replica can look idle; back off briefly so the
                // retry loop cannot spin between dispatch and submit_wait
                Err(TrySubmit::Full(r)) | Err(TrySubmit::Closed(r)) => {
                    std::thread::sleep(Duration::from_micros(200));
                    r
                }
            };
        }
    }

    /// Route a request: the policy's preferred replica first; only if its
    /// queue is full (or it died) fall through to the remaining replicas in
    /// ascending-load order, so a full preferred queue does not shed while
    /// a sibling has room. The common accepted-first-try case pays no
    /// fallback bookkeeping. Chains never fall back: frames must enter at
    /// stage 0, so a full entry queue sheds immediately.
    fn dispatch(&mut self, req: Request) -> std::result::Result<usize, SubmitError> {
        if self.chain {
            return match self.replicas[0].try_submit(req) {
                Ok(()) => Ok(0),
                Err(TrySubmit::Full(r)) => Err(SubmitError::QueueFull(r)),
                Err(TrySubmit::Closed(r)) => Err(SubmitError::Closed(r)),
            };
        }
        // the load snapshot costs one atomic load per replica plus a Vec;
        // take it up front only for the policy that reads it (JSQ) — the
        // fallback path below re-derives it on demand
        let mut outstanding: Vec<usize> =
            if matches!(self.scheduler.policy(), Policy::JoinShortestQueue) {
                self.outstanding()
            } else {
                Vec::new()
            };
        let first = self.scheduler.pick(&outstanding);
        let mut saw_full = false;
        let mut req = match self.replicas[first].try_submit(req) {
            Ok(()) => return Ok(first),
            Err(TrySubmit::Full(r)) => {
                saw_full = true;
                r
            }
            Err(TrySubmit::Closed(r)) => r,
        };
        if outstanding.is_empty() {
            outstanding = self.outstanding();
        }
        let mut rest: Vec<usize> = (0..self.replicas.len()).filter(|&i| i != first).collect();
        rest.sort_by_key(|&i| (outstanding[i], i));
        for i in rest {
            match self.replicas[i].try_submit(req) {
                Ok(()) => return Ok(i),
                Err(TrySubmit::Full(r)) => {
                    saw_full = true;
                    req = r;
                }
                Err(TrySubmit::Closed(r)) => req = r,
            }
        }
        if saw_full {
            Err(SubmitError::QueueFull(req))
        } else {
            Err(SubmitError::Closed(req))
        }
    }

    /// Receive the next completion (blocks until one arrives, or returns
    /// `None` once the fleet has shut down and the stream is drained).
    /// The stream only terminates after [`Server::shutdown`] — a fleet
    /// whose workers all died stays open for [`Server::reconfigure`], so
    /// drive it with [`Server::try_next_completion`] if the backend can
    /// fail.
    pub fn next_completion(&self) -> Option<Completion> {
        self.completions.recv().ok()
    }

    /// Receive the next completion, waiting at most `timeout`.
    pub fn try_next_completion(&self, timeout: Duration) -> Option<Completion> {
        match self.completions.recv_timeout(timeout) {
            Ok(c) => Some(c),
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => None,
        }
    }

    /// Open-loop replay of an arrival trace: submits request `i` at
    /// `trace.arrivals_s[i]` (uniform-random synthetic inputs of
    /// `input_len` elements seeded by `seed`), drains completions while
    /// waiting, sheds on overload, and finally waits for every *accepted*
    /// request to complete. The server stays running; callers decide when
    /// to [`Server::shutdown`].
    pub fn replay(&mut self, trace: &Trace, input_len: usize, seed: u64) -> FleetMetrics {
        let mut rng = Rng::new(seed);
        let mut fm = FleetMetrics::new(self.replicas.len());
        fm.start();
        let t0 = Instant::now();
        for (i, &due) in trace.arrivals_s.iter().enumerate() {
            loop {
                let now = t0.elapsed().as_secs_f64();
                if now >= due {
                    break;
                }
                let wait = Duration::from_secs_f64((due - now).min(0.005));
                match self.completions.recv_timeout(wait) {
                    Ok(c) => fm.record(&c),
                    // every worker died (panicked backend): nothing will
                    // ever complete, so stop replaying instead of spinning
                    Err(RecvTimeoutError::Timeout) => {
                        if self.all_workers_dead() {
                            return fm;
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => return fm,
                }
            }
            let input: Vec<f32> = (0..input_len).map(|_| rng.below(256) as f32).collect();
            match self.submit(i as u64, input) {
                Ok(_) => fm.record_submitted(),
                Err(SubmitError::QueueFull(_)) => fm.record_shed(),
                Err(SubmitError::Closed(_)) => return fm,
            }
        }
        // drain: every accepted request completes unless a backend fails its
        // batch (never on the mock/PJRT paths), so guard with a stall timeout
        let mut last_progress = Instant::now();
        while fm.completed() < fm.submitted() {
            match self.completions.recv_timeout(Duration::from_millis(50)) {
                Ok(c) => {
                    fm.record(&c);
                    last_progress = Instant::now();
                }
                Err(RecvTimeoutError::Disconnected) => break,
                Err(RecvTimeoutError::Timeout) => {
                    if self.all_workers_dead()
                        || last_progress.elapsed() > Duration::from_secs(10)
                    {
                        break;
                    }
                }
            }
        }
        fm
    }

    /// Stop accepting requests and wait for every replica to drain its
    /// queue. Buffered completions remain readable afterwards; once they
    /// are drained the completion stream terminates (and the server can no
    /// longer be [`Server::reconfigure`]d).
    pub fn shutdown(&mut self) {
        for r in &mut self.replicas {
            r.close();
        }
        for r in &mut self.replicas {
            r.join();
        }
        self.completion_tx = None;
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;

    /// Mock with failure injection on every k-th batch (per replica).
    struct FlakyMock {
        delay: Duration,
        fail_every: usize,
        calls: std::sync::atomic::AtomicUsize,
    }

    impl InferBackend for FlakyMock {
        fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            let call = self.calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            if self.fail_every > 0 && (call + 1) % self.fail_every == 0 {
                anyhow::bail!("injected failure on call {call}");
            }
            MockBackend::with_service(self.delay, Duration::ZERO).infer_batch(inputs)
        }
    }

    fn single(queue_depth: usize, max_batch: usize) -> ServerConfig {
        ServerConfig {
            batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(1) },
            queue_depth,
            replicas: 1,
            policy: Policy::RoundRobin,
        }
    }

    #[test]
    fn end_to_end_all_requests_complete() {
        let mut srv = Server::start(|_| MockBackend::instant(), single(64, 4));
        let n = 40;
        for i in 0..n {
            srv.submit_blocking(i, vec![i as f32, 1.0]).unwrap();
        }
        let mut metrics = Metrics::new();
        metrics.start();
        let mut seen = vec![false; n as usize];
        for _ in 0..n {
            let c = srv.next_completion().unwrap();
            assert_eq!(c.output[0], c.id as f32 + 1.0);
            assert_eq!(c.replica, 0);
            seen[c.id as usize] = true;
            metrics.record(c.latency, c.batch_size);
        }
        assert!(seen.iter().all(|&s| s));
        assert!(metrics.summary().mean_batch >= 1.0);
        srv.shutdown();
    }

    #[test]
    fn batching_actually_batches() {
        let cfg = ServerConfig {
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(20) },
            queue_depth: 64,
            replicas: 1,
            policy: Policy::RoundRobin,
        };
        let mut srv = Server::start(
            |_| MockBackend::with_service(Duration::from_millis(5), Duration::ZERO),
            cfg,
        );
        for i in 0..16 {
            srv.submit_blocking(i, vec![1.0]).unwrap();
        }
        let mut max_batch = 0usize;
        for _ in 0..16 {
            let c = srv.next_completion().unwrap();
            max_batch = max_batch.max(c.batch_size);
        }
        assert!(max_batch >= 4, "expected batching, max batch {max_batch}");
        srv.shutdown();
    }

    #[test]
    fn failure_injection_drops_batch_but_server_survives() {
        let mut srv = Server::start(
            |_| FlakyMock {
                delay: Duration::ZERO,
                fail_every: 3,
                calls: std::sync::atomic::AtomicUsize::new(0),
            },
            single(64, 1),
        );
        let n = 30;
        for i in 0..n {
            srv.submit_blocking(i, vec![1.0]).unwrap();
        }
        srv.shutdown();
        let mut got = 0;
        while let Some(_c) = srv.next_completion() {
            got += 1;
        }
        // every 3rd single-request batch fails: 10 dropped
        assert_eq!(got, 20, "completions {got}");
    }

    #[test]
    fn backpressure_sheds_with_queue_full() {
        let mut srv = Server::start(
            |_| MockBackend::with_service(Duration::from_millis(50), Duration::ZERO),
            single(2, 1),
        );
        // worker is sleeping on the first batch; queue of 2 fills quickly
        let mut rejected = 0;
        for i in 0..20 {
            match srv.submit(i, vec![1.0]) {
                Ok(_) => {}
                Err(e) => {
                    assert!(!e.is_closed(), "open server must shed, not close: {e}");
                    rejected += 1;
                }
            }
        }
        assert!(rejected > 0, "expected admission-control sheds");
    }

    #[test]
    fn chain_traverses_stages_in_order() {
        // 3-stage chain of instant mocks at batch 1: each stage maps
        // [x, ...] -> [sum, 1], so the final output is input + 2 — proof
        // the frame passed through every stage exactly once, in order
        let cfg = ServerConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) },
            queue_depth: 16,
            replicas: 3,
            policy: Policy::RoundRobin, // ignored by start_chain
        };
        let mut srv = Server::start_chain(|_| MockBackend::instant(), cfg);
        assert_eq!(srv.replica_count(), 3);
        for i in 0..20 {
            srv.submit_blocking(i, vec![i as f32]).unwrap();
        }
        srv.shutdown();
        let mut got = 0;
        while let Some(c) = srv.next_completion() {
            got += 1;
            assert_eq!(c.output[0], c.id as f32 + 2.0, "frame {} skipped a stage", c.id);
            assert_eq!(c.replica, 2, "completions come from the last stage");
            assert_eq!(c.stage_latencies.len(), 3, "one latency per stage");
            let total: Duration = c.stage_latencies.iter().sum();
            assert!(total <= c.latency + Duration::from_millis(5));
        }
        assert_eq!(got, 20, "chain dropped frames");
    }

    #[test]
    fn reconfigure_swaps_fleet_without_losing_completions() {
        let mut srv = Server::start(|_| MockBackend::instant(), single(64, 2));
        for i in 0..10 {
            srv.submit_blocking(i, vec![i as f32]).unwrap();
        }
        // drain-and-swap to a 3-replica fleet on the same completion stream
        let cfg = ServerConfig {
            batcher: BatcherConfig { max_batch: 2, max_wait: Duration::from_millis(1) },
            queue_depth: 64,
            replicas: 3,
            policy: Policy::RoundRobin,
        };
        srv.reconfigure(|_| MockBackend::instant(), cfg).unwrap();
        assert_eq!(srv.replica_count(), 3);
        for i in 10..30 {
            srv.submit_blocking(i, vec![i as f32]).unwrap();
        }
        srv.shutdown();
        let mut ids = Vec::new();
        while let Some(c) = srv.next_completion() {
            assert_eq!(c.output[0], c.id as f32 + 1.0);
            ids.push(c.id);
        }
        ids.sort_unstable();
        assert_eq!(ids, (0..30).collect::<Vec<u64>>(), "swap lost completions");
    }

    #[test]
    fn reconfigure_after_shutdown_is_an_error() {
        let mut srv = Server::start(|_| MockBackend::instant(), single(8, 1));
        srv.shutdown();
        let err = srv.reconfigure(|_| MockBackend::instant(), single(8, 1));
        assert!(err.is_err(), "reconfiguring a shut-down server must fail");
    }

    #[test]
    fn reconfigure_chain_splices_a_new_stage_count() {
        let cfg = |k: usize| ServerConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) },
            queue_depth: 16,
            replicas: k,
            policy: Policy::RoundRobin, // ignored by the chain paths
        };
        let mut srv = Server::start_chain(|_| MockBackend::instant(), cfg(3));
        for i in 0..10 {
            srv.submit_blocking(i, vec![i as f32]).unwrap();
        }
        // splice down to a 2-stage chain (one device lost, plan repaired)
        srv.reconfigure_chain(|_| MockBackend::instant(), cfg(2)).unwrap();
        assert_eq!(srv.replica_count(), 2);
        for i in 100..110 {
            srv.submit_blocking(i, vec![i as f32]).unwrap();
        }
        srv.shutdown();
        let mut pre = 0;
        let mut post = 0;
        while let Some(c) = srv.next_completion() {
            if c.id < 100 {
                // old plan: 3 stages, each adding +1 after the first
                assert_eq!(c.output[0], c.id as f32 + 2.0);
                pre += 1;
            } else {
                // new plan: 2 stages
                assert_eq!(c.output[0], c.id as f32 + 1.0);
                post += 1;
            }
        }
        assert_eq!((pre, post), (10, 10), "splice dropped frames");
    }

    #[test]
    fn live_batcher_retune_roundtrips() {
        let srv = Server::start(|_| MockBackend::instant(), single(8, 4));
        let cur = srv.batcher_config(0).unwrap();
        assert_eq!(cur.max_batch, 4);
        let next = BatcherConfig { max_batch: 9, max_wait: Duration::from_micros(700) };
        assert!(srv.set_batcher(0, next));
        let got = srv.batcher_config(0).unwrap();
        assert_eq!(got.max_batch, 9);
        assert_eq!(got.max_wait, Duration::from_micros(700));
        assert!(!srv.set_batcher(5, next), "out-of-range index must report false");
        assert!(srv.batcher_config(5).is_none());
    }

    #[test]
    fn full_sibling_does_not_shed_while_another_replica_has_room() {
        // replica 0 is blocked for a long time; round-robin would prefer it
        // every other request, but the router falls through to replica 1
        let cfg = ServerConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(0) },
            queue_depth: 1,
            replicas: 2,
            policy: Policy::RoundRobin,
        };
        let mut srv = Server::start(
            |i| {
                if i == 0 {
                    MockBackend::with_service(Duration::from_millis(300), Duration::ZERO)
                } else {
                    MockBackend::instant()
                }
            },
            cfg,
        );
        let mut ok = 0;
        for i in 0..12 {
            if srv.submit(i, vec![1.0]).is_ok() {
                ok += 1;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        // replica 0 absorbs at most 2 (1 executing + 1 queued); the rest
        // must overflow to replica 1 instead of shedding
        assert!(ok >= 10, "only {ok}/12 accepted");
    }
}
