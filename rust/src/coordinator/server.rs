//! The serving fleet behind the unified [`Deployment`] topology: a router
//! dispatches requests into chain groups by a pluggable scheduling policy;
//! each group is a k-stage pipeline of workers, each worker owns a bounded
//! queue, a dynamic batcher and its own [`InferBackend`]; completions from
//! every group merge into one stream.
//!
//! ```text
//!  clients ──> Server::submit ── Scheduler (policy) picks a chain group
//!                 │    admission control: all entries full => QueueFull
//!                 v
//!       ┌─ group 0: stage 0 → stage 1 → … → stage k-1 ─┐
//!       ├─ group 1: stage 0 → stage 1 → … → stage k-1 ─┤──> completions
//!       └─ group N: stage 0 ──────────────────────────┘    (group, stage,
//!            (k=1 ⇒ a plain replica)                        e2e + per-stage
//!                                                           latencies)
//! ```
//!
//! **Overload semantics.** Each stage's queue is bounded
//! ([`Deployment::queue_depth`]). A non-blocking [`Server::submit`] tries
//! the policy's preferred group first, then the remaining groups in
//! ascending-load order; only when *every* open group entry is full does it
//! shed the request with [`SubmitError::QueueFull`] — graceful degradation,
//! never unbounded memory. Frames always enter a group at stage 0 and the
//! stages forward them onward themselves, so the router can never route
//! into the middle of a chain. After [`Server::shutdown`] (or if all
//! workers die) the error is [`SubmitError::Closed`] instead, so callers
//! can tell "retry later" from "give up". Shutdown closes the queues and
//! *drains* them: every accepted request still produces a completion
//! before the workers exit.
//!
//! **Reshaping.** [`Server::apply`] diffs a new [`Deployment`] against the
//! running one at chain-group granularity: unchanged groups keep serving
//! (their backends, queues and live batcher retunes survive), removed
//! groups drain to completion first, and added groups spawn fresh on the
//! same completion stream — the actuation surface of the adaptive control
//! plane ([`crate::control`]).
//!
//! The backend is a trait so tests and benches run the full coordination
//! path with [`MockBackend`] (no PJRT); `examples/serve_cifar.rs` and
//! `fcmp serve --backend pjrt` plug in the real [`crate::runtime::Engine`].

use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::batcher::BatcherConfig;
use super::deployment::{Deployment, GroupKey, WorkerId};
use super::metrics::FleetMetrics;
use super::policy::{Policy, Scheduler};
use super::replica::{Replica, Sink, TrySubmit};
use super::workload::Trace;
use super::{Completion, Request};
use crate::util::rng::Rng;
use crate::Result;

/// Anything that can run a batch of inputs. The backend is constructed
/// *inside* each worker thread (PJRT handles are not `Send`), so only the
/// factory closure crosses threads.
pub trait InferBackend: 'static {
    /// Run one batch; `inputs[i]` is a flattened sample, the result must
    /// hold one output row per input row.
    fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;
}

impl InferBackend for crate::runtime::Engine {
    fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.infer(inputs)
    }
}

/// Deterministic mock backend for tests, benches and `fcmp serve --backend
/// mock`: each output row is `[Σ inputs, batch_size]`, and a batch of `k`
/// requests takes `base + per_item · k` of simulated service time. Scaling
/// `base`/`per_item` per worker models a heterogeneous fleet.
#[derive(Clone, Copy, Debug)]
pub struct MockBackend {
    /// Fixed per-batch overhead (amortized by batching).
    pub base: Duration,
    /// Marginal service time per request in the batch.
    pub per_item: Duration,
}

impl MockBackend {
    /// Zero service time — completes as fast as the threads can run.
    pub fn instant() -> MockBackend {
        MockBackend { base: Duration::ZERO, per_item: Duration::ZERO }
    }

    /// Mock with the given service-time model.
    pub fn with_service(base: Duration, per_item: Duration) -> MockBackend {
        MockBackend { base, per_item }
    }
}

impl InferBackend for MockBackend {
    fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let service = self.base + self.per_item * inputs.len() as u32;
        if !service.is_zero() {
            std::thread::sleep(service);
        }
        Ok(inputs
            .iter()
            .map(|x| vec![x.iter().sum::<f32>(), inputs.len() as f32])
            .collect())
    }
}

/// Typed submit failure. The rejected request rides back in the error so
/// callers can retry without rebuilding the input buffer, and the two
/// variants make transient overload distinguishable from terminal shutdown.
/// Implements [`std::error::Error`], so callers can `?` it straight into
/// `anyhow::Result` instead of pattern-matching.
#[derive(Debug)]
pub enum SubmitError {
    /// Every open group entry queue was full — admission control shed the
    /// request. Retrying after a backoff can succeed.
    QueueFull(Request),
    /// The server is shut down (or every worker died). Retrying cannot
    /// succeed.
    Closed(Request),
}

impl SubmitError {
    /// Recover the rejected request (e.g. to retry it later).
    pub fn into_request(self) -> Request {
        match self {
            SubmitError::QueueFull(r) | SubmitError::Closed(r) => r,
        }
    }

    /// True iff the failure is terminal (no retry can succeed).
    pub fn is_closed(&self) -> bool {
        matches!(self, SubmitError::Closed(_))
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull(r) => {
                write!(f, "request {} shed: every chain group's entry queue is full", r.id)
            }
            SubmitError::Closed(r) => {
                write!(f, "request {} rejected: server is shut down", r.id)
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// One running chain group: its stage workers (stage 0 is the entry), the
/// shared cell holding the group's current plan position (completions read
/// it, so a group kept across [`Server::apply`] reports its new index),
/// and the diffing key it was spawned under.
struct Group {
    replicas: Vec<Replica>,
    pos: Arc<std::sync::atomic::AtomicUsize>,
    key: GroupKey,
}

impl Group {
    /// Total outstanding requests across every stage (the group load
    /// signal the policy and the fallback ordering read).
    fn outstanding(&self) -> usize {
        self.replicas.iter().map(Replica::outstanding).sum()
    }

    /// Stop admitting at every stage (front first, so drained frames flow
    /// through still-open downstream stages).
    fn close(&mut self) {
        for r in &mut self.replicas {
            r.close();
        }
    }

    /// Wait for every stage to drain (after [`Group::close`]).
    fn join(&mut self) {
        for r in &mut self.replicas {
            r.join();
        }
    }

    fn is_dead(&self) -> bool {
        !self.replicas.is_empty() && self.replicas.iter().all(Replica::is_dead)
    }

    /// Any stage's worker died (panicked backend). A chain with even one
    /// dead stage cannot carry frames end-to-end, so [`Server::apply`]
    /// must never keep such a group as a "match" — re-applying the plan
    /// is the recovery action, and it has to respawn.
    fn has_dead_worker(&self) -> bool {
        self.replicas.iter().any(Replica::is_dead)
    }
}

/// A running inference server: the live realization of a [`Deployment`].
pub struct Server {
    groups: Vec<Group>,
    scheduler: Scheduler,
    plan: Deployment,
    completions: Receiver<Completion>,
    /// Kept open across [`Server::apply`] so a reshaped fleet keeps
    /// feeding the same completion stream; dropped on [`Server::shutdown`]
    /// so the stream terminates once drained.
    completion_tx: Option<Sender<Completion>>,
}

impl Server {
    /// Spawn the fleet described by `plan`. `make_backend(id)` runs on
    /// worker `id`'s own thread (PJRT engines are thread-affine) and a
    /// panic there surfaces on first use of that worker.
    pub fn deploy<B, F>(make_backend: F, plan: Deployment) -> Server
    where
        B: InferBackend,
        F: Fn(WorkerId) -> B + Send + Sync + 'static,
    {
        let plan = plan.normalized();
        // completions are unbounded: backpressure belongs on the *request*
        // queues; a bounded completion channel can deadlock shutdown (worker
        // blocks on send while the owner blocks on join without draining)
        let (ctx, crx) = channel::<Completion>();
        let factory = Arc::new(make_backend);
        let groups: Vec<Group> = (0..plan.groups.len())
            .map(|g| Self::spawn_group(&factory, &plan, g, &ctx))
            .collect();
        Server {
            scheduler: Scheduler::new(plan.policy.clone(), groups.len()),
            groups,
            plan,
            completions: crx,
            completion_tx: Some(ctx),
        }
    }

    /// **Group-granular drain-and-swap** (the control plane's actuation
    /// path, [`crate::control`]): diff `plan` against the running
    /// deployment. Groups whose [`crate::coordinator::ChainGroup`] spec is
    /// unchanged (same tag, stage count, batcher and queue depth) are
    /// *kept running* — no drain, no backend respawn, live batcher
    /// retunes survive, only their position cell updates. Groups absent
    /// from the new plan drain every accepted request to completion
    /// first; then the added groups spawn on the *same* completion
    /// stream, so completions buffered before, during and after the swap
    /// all remain readable and a driver loop never misses one.
    ///
    /// A matching spec keeps the *old backends*: callers replacing the
    /// backends behind an identical shape must change the group's
    /// [`crate::coordinator::ChainGroup::tag`]. Fails only after
    /// [`Server::shutdown`] (the completion stream is gone for good).
    pub fn apply<B, F>(&mut self, make_backend: F, plan: Deployment) -> crate::Result<()>
    where
        B: InferBackend,
        F: Fn(WorkerId) -> B + Send + Sync + 'static,
    {
        let ctx = match self.completion_tx.clone() {
            Some(tx) => tx,
            None => anyhow::bail!("cannot apply a new plan after shutdown"),
        };
        let plan = plan.normalized();
        let factory = Arc::new(make_backend);
        // match running groups to new slots by key: first unused match, in
        // plan order, so N identical untagged groups keep min(old, new).
        // A group with any dead worker never matches — re-applying the
        // same plan is the recovery action, so it must respawn the group
        // instead of silently keeping a corpse
        let old: Vec<Group> = std::mem::take(&mut self.groups);
        let mut pool: Vec<Option<Group>> = old.into_iter().map(Some).collect();
        let mut slots: Vec<Option<Group>> = Vec::with_capacity(plan.groups.len());
        for g in 0..plan.groups.len() {
            let key = plan.group_key(g);
            let hit = pool
                .iter_mut()
                .find(|s| {
                    s.as_ref().map_or(false, |grp| grp.key == key && !grp.has_dead_worker())
                })
                .and_then(Option::take);
            slots.push(hit);
        }
        // groups leaving the plan drain first: every accepted frame
        // completes on the old topology before replacement capacity spawns
        let mut leaving: Vec<Group> = pool.into_iter().flatten().collect();
        for grp in &mut leaving {
            grp.close();
        }
        for grp in &mut leaving {
            grp.join();
        }
        self.groups = slots
            .into_iter()
            .enumerate()
            .map(|(g, slot)| match slot {
                Some(grp) => {
                    // kept group: serving the whole time, new position
                    grp.pos.store(g, Ordering::SeqCst);
                    grp
                }
                None => Self::spawn_group(&factory, &plan, g, &ctx),
            })
            .collect();
        self.scheduler = Scheduler::new(plan.policy.clone(), self.groups.len());
        self.plan = plan;
        Ok(())
    }

    /// Spawn chain group `g` of `plan`, feeding final-stage completions
    /// into `ctx`. Stages spawn back-to-front so stage `i` can hold stage
    /// `i+1`'s queue handle.
    fn spawn_group<B, F>(
        factory: &Arc<F>,
        plan: &Deployment,
        g: usize,
        ctx: &Sender<Completion>,
    ) -> Group
    where
        B: InferBackend,
        F: Fn(WorkerId) -> B + Send + Sync + 'static,
    {
        let k = plan.groups[g].stages.max(1);
        let batcher = plan.group_batcher(g);
        let pos = Arc::new(std::sync::atomic::AtomicUsize::new(g));
        let mut replicas: Vec<Replica> = Vec::with_capacity(k);
        let mut downstream = None;
        for stage in (0..k).rev() {
            let f = Arc::clone(factory);
            let id = WorkerId { group: g, stage };
            let sink = match downstream.take() {
                None => Sink::Complete { tx: ctx.clone(), group: Arc::clone(&pos) },
                Some((next, next_outstanding)) => Sink::Forward { next, next_outstanding },
            };
            let r = Replica::spawn(id, move || (*f)(id), batcher, plan.queue_depth, sink);
            downstream =
                Some((r.sender().expect("fresh replica is open"), r.outstanding_handle()));
            replicas.push(r);
        }
        replicas.reverse();
        Group { replicas, pos, key: plan.group_key(g) }
    }

    /// The deployment currently being served.
    pub fn plan(&self) -> &Deployment {
        &self.plan
    }

    /// Number of chain groups.
    pub fn group_count(&self) -> usize {
        self.groups.len()
    }

    /// Stage counts per group, in router order.
    pub fn group_sizes(&self) -> Vec<usize> {
        self.groups.iter().map(|g| g.replicas.len()).collect()
    }

    /// Total workers across every group.
    pub fn replica_count(&self) -> usize {
        self.groups.iter().map(|g| g.replicas.len()).sum()
    }

    /// Current batching settings of stage `stage` of group `group`
    /// (`None` when either index is out of range).
    pub fn batcher_config(&self, group: usize, stage: usize) -> Option<BatcherConfig> {
        self.groups.get(group).and_then(|g| g.replicas.get(stage)).map(Replica::batcher)
    }

    /// Live-retune one worker's batcher (the SLO controller's actuation,
    /// [`crate::control::slo`]): the worker applies the new settings on
    /// its next batch, with no drain and no respawn. Returns `false` when
    /// an index is out of range. Live adjustments survive a
    /// [`Server::apply`] that keeps the group; a swap that respawns it
    /// restarts from the plan's baseline.
    pub fn set_batcher(&self, group: usize, stage: usize, cfg: BatcherConfig) -> bool {
        match self.groups.get(group).and_then(|g| g.replicas.get(stage)) {
            Some(r) => {
                r.set_batcher(cfg);
                true
            }
            None => false,
        }
    }

    /// Per-worker outstanding request counts (queued + executing), flat
    /// in group-then-stage order.
    pub fn outstanding(&self) -> Vec<usize> {
        self.groups
            .iter()
            .flat_map(|g| g.replicas.iter().map(Replica::outstanding))
            .collect()
    }

    /// Per-group outstanding request counts (summed over the group's
    /// stages) — the load signal group-granular scheduling reads.
    pub fn group_outstanding(&self) -> Vec<usize> {
        self.groups.iter().map(Group::outstanding).collect()
    }

    /// Every worker died without a shutdown (panicked backends). The
    /// completion channel stays open (the server holds a sender for
    /// [`Server::apply`]), so this probe — not channel disconnection — is
    /// how replay loops detect a dead fleet.
    fn all_workers_dead(&self) -> bool {
        !self.groups.is_empty() && self.groups.iter().all(Group::is_dead)
    }

    /// Non-blocking submit. Returns the chain-group index the request
    /// entered (frames always enter at the group's stage 0), or a typed
    /// [`SubmitError`] (overload shed vs shutdown).
    pub fn submit(&mut self, id: u64, input: Vec<f32>) -> std::result::Result<usize, SubmitError> {
        self.dispatch(Request::new(id, input))
    }

    /// Blocking submit: when every group entry is full it parks on the
    /// least loaded group's bounded entry queue (the worker wakes it when
    /// a slot frees) instead of spin-retrying; only terminal shutdown
    /// makes it fail.
    pub fn submit_blocking(
        &mut self,
        id: u64,
        input: Vec<f32>,
    ) -> std::result::Result<usize, SubmitError> {
        let mut req = Request::new(id, input);
        loop {
            req = match self.dispatch(req) {
                Ok(g) => return Ok(g),
                Err(SubmitError::Closed(r)) => return Err(SubmitError::Closed(r)),
                Err(SubmitError::QueueFull(r)) => r,
            };
            let g = self
                .groups
                .iter()
                .enumerate()
                .min_by_key(|(_, grp)| grp.outstanding())
                .map(|(g, _)| g)
                .unwrap();
            req = match self.groups[g].replicas[0].submit_wait(req) {
                Ok(()) => return Ok(g),
                // a dead group can look idle; back off briefly so the
                // retry loop cannot spin between dispatch and submit_wait
                Err(TrySubmit::Full(r)) | Err(TrySubmit::Closed(r)) => {
                    std::thread::sleep(Duration::from_micros(200));
                    r
                }
            };
        }
    }

    /// Route a request: the policy's preferred group first; only if its
    /// entry queue is full (or its workers died) fall through to the
    /// remaining groups in ascending-load order, so a full preferred
    /// entry does not shed while a sibling group has room. The common
    /// accepted-first-try case pays no fallback bookkeeping. A
    /// single-group deployment (one chain) has no siblings, so a full
    /// entry queue sheds immediately — frames can never enter a chain
    /// mid-pipeline.
    fn dispatch(&mut self, req: Request) -> std::result::Result<usize, SubmitError> {
        // the load snapshot costs one atomic load per worker plus a Vec;
        // take it up front only for the policy that reads it (JSQ) — the
        // fallback path below re-derives it on demand
        let mut loads: Vec<usize> =
            if matches!(self.scheduler.policy(), Policy::JoinShortestQueue) {
                self.group_outstanding()
            } else {
                Vec::new()
            };
        let first = self.scheduler.pick(&loads);
        let mut saw_full = false;
        let mut req = match self.groups[first].replicas[0].try_submit(req) {
            Ok(()) => return Ok(first),
            Err(TrySubmit::Full(r)) => {
                saw_full = true;
                r
            }
            Err(TrySubmit::Closed(r)) => r,
        };
        if loads.is_empty() {
            loads = self.group_outstanding();
        }
        let mut rest: Vec<usize> = (0..self.groups.len()).filter(|&g| g != first).collect();
        rest.sort_by_key(|&g| (loads[g], g));
        for g in rest {
            match self.groups[g].replicas[0].try_submit(req) {
                Ok(()) => return Ok(g),
                Err(TrySubmit::Full(r)) => {
                    saw_full = true;
                    req = r;
                }
                Err(TrySubmit::Closed(r)) => req = r,
            }
        }
        if saw_full {
            Err(SubmitError::QueueFull(req))
        } else {
            Err(SubmitError::Closed(req))
        }
    }

    /// Receive the next completion (blocks until one arrives, or returns
    /// `None` once the fleet has shut down and the stream is drained).
    /// The stream only terminates after [`Server::shutdown`] — a fleet
    /// whose workers all died stays open for [`Server::apply`], so drive
    /// it with [`Server::try_next_completion`] if the backend can fail.
    pub fn next_completion(&self) -> Option<Completion> {
        self.completions.recv().ok()
    }

    /// Receive the next completion, waiting at most `timeout`.
    pub fn try_next_completion(&self, timeout: Duration) -> Option<Completion> {
        match self.completions.recv_timeout(timeout) {
            Ok(c) => Some(c),
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => None,
        }
    }

    /// Open-loop replay of an arrival trace: submits request `i` at
    /// `trace.arrivals_s[i]` (uniform-random synthetic inputs of
    /// `input_len` elements seeded by `seed`), drains completions while
    /// waiting, sheds on overload, and finally waits for every *accepted*
    /// request to complete. The returned [`FleetMetrics`] is shaped to
    /// the current plan, so chain deployments report per-group e2e
    /// percentiles alongside the per-stage breakdown. The server stays
    /// running; callers decide when to [`Server::shutdown`].
    pub fn replay(&mut self, trace: &Trace, input_len: usize, seed: u64) -> FleetMetrics {
        let mut rng = Rng::new(seed);
        let mut fm = FleetMetrics::new(&self.group_sizes());
        fm.start();
        let t0 = Instant::now();
        for (i, &due) in trace.arrivals_s.iter().enumerate() {
            loop {
                let now = t0.elapsed().as_secs_f64();
                if now >= due {
                    break;
                }
                let wait = Duration::from_secs_f64((due - now).min(0.005));
                match self.completions.recv_timeout(wait) {
                    Ok(c) => fm.record(&c),
                    // every worker died (panicked backend): nothing will
                    // ever complete, so stop replaying instead of spinning
                    Err(RecvTimeoutError::Timeout) => {
                        if self.all_workers_dead() {
                            return fm;
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => return fm,
                }
            }
            let input: Vec<f32> = (0..input_len).map(|_| rng.below(256) as f32).collect();
            match self.submit(i as u64, input) {
                Ok(_) => fm.record_submitted(),
                Err(SubmitError::QueueFull(_)) => fm.record_shed(),
                Err(SubmitError::Closed(_)) => return fm,
            }
        }
        // drain: every accepted request completes unless a backend fails its
        // batch (never on the mock/PJRT paths), so guard with a stall timeout
        let mut last_progress = Instant::now();
        while fm.completed() < fm.submitted() {
            match self.completions.recv_timeout(Duration::from_millis(50)) {
                Ok(c) => {
                    fm.record(&c);
                    last_progress = Instant::now();
                }
                Err(RecvTimeoutError::Disconnected) => break,
                Err(RecvTimeoutError::Timeout) => {
                    if self.all_workers_dead()
                        || last_progress.elapsed() > Duration::from_secs(10)
                    {
                        break;
                    }
                }
            }
        }
        fm
    }

    /// Stop accepting requests and wait for every group to drain its
    /// queues. Buffered completions remain readable afterwards; once they
    /// are drained the completion stream terminates (and no further plan
    /// can be [`Server::apply`]d).
    pub fn shutdown(&mut self) {
        for g in &mut self.groups {
            g.close();
        }
        for g in &mut self.groups {
            g.join();
        }
        self.completion_tx = None;
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;
    use std::sync::atomic::AtomicUsize;

    /// Mock with failure injection on every k-th batch (per worker).
    struct FlakyMock {
        delay: Duration,
        fail_every: usize,
        calls: AtomicUsize,
    }

    impl InferBackend for FlakyMock {
        fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            let call = self.calls.fetch_add(1, Ordering::SeqCst);
            if self.fail_every > 0 && (call + 1) % self.fail_every == 0 {
                anyhow::bail!("injected failure on call {call}");
            }
            MockBackend::with_service(self.delay, Duration::ZERO).infer_batch(inputs)
        }
    }

    fn single(queue_depth: usize, max_batch: usize) -> Deployment {
        Deployment::replicated(1)
            .with_batcher(BatcherConfig { max_batch, max_wait: Duration::from_millis(1) })
            .with_queue_depth(queue_depth)
    }

    #[test]
    fn end_to_end_all_requests_complete() {
        let mut srv = Server::deploy(|_| MockBackend::instant(), single(64, 4));
        let n = 40;
        for i in 0..n {
            srv.submit_blocking(i, vec![i as f32, 1.0]).unwrap();
        }
        let mut metrics = Metrics::new();
        metrics.start();
        let mut seen = vec![false; n as usize];
        for _ in 0..n {
            let c = srv.next_completion().unwrap();
            assert_eq!(c.output[0], c.id as f32 + 1.0);
            assert_eq!((c.group, c.stage), (0, 0));
            seen[c.id as usize] = true;
            metrics.record(c.latency, c.batch_size);
        }
        assert!(seen.iter().all(|&s| s));
        assert!(metrics.summary().mean_batch >= 1.0);
        srv.shutdown();
    }

    #[test]
    fn batching_actually_batches() {
        let plan = Deployment::replicated(1)
            .with_batcher(BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(20) })
            .with_queue_depth(64);
        let mut srv = Server::deploy(
            |_| MockBackend::with_service(Duration::from_millis(5), Duration::ZERO),
            plan,
        );
        for i in 0..16 {
            srv.submit_blocking(i, vec![1.0]).unwrap();
        }
        let mut max_batch = 0usize;
        for _ in 0..16 {
            let c = srv.next_completion().unwrap();
            max_batch = max_batch.max(c.batch_size);
        }
        assert!(max_batch >= 4, "expected batching, max batch {max_batch}");
        srv.shutdown();
    }

    #[test]
    fn failure_injection_drops_batch_but_server_survives() {
        let mut srv = Server::deploy(
            |_| FlakyMock {
                delay: Duration::ZERO,
                fail_every: 3,
                calls: AtomicUsize::new(0),
            },
            single(64, 1),
        );
        let n = 30;
        for i in 0..n {
            srv.submit_blocking(i, vec![1.0]).unwrap();
        }
        srv.shutdown();
        let mut got = 0;
        while let Some(_c) = srv.next_completion() {
            got += 1;
        }
        // every 3rd single-request batch fails: 10 dropped
        assert_eq!(got, 20, "completions {got}");
    }

    #[test]
    fn backpressure_sheds_with_queue_full() {
        let mut srv = Server::deploy(
            |_| MockBackend::with_service(Duration::from_millis(50), Duration::ZERO),
            single(2, 1),
        );
        // worker is sleeping on the first batch; queue of 2 fills quickly
        let mut rejected = 0;
        for i in 0..20 {
            match srv.submit(i, vec![1.0]) {
                Ok(_) => {}
                Err(e) => {
                    assert!(!e.is_closed(), "open server must shed, not close: {e}");
                    rejected += 1;
                }
            }
        }
        assert!(rejected > 0, "expected admission-control sheds");
    }

    #[test]
    fn chain_traverses_stages_in_order() {
        // 3-stage chain of instant mocks at batch 1: each stage maps
        // [x, ...] -> [sum, 1], so the final output is input + 2 — proof
        // the frame passed through every stage exactly once, in order
        let plan = Deployment::chain(3)
            .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) })
            .with_queue_depth(16);
        let mut srv = Server::deploy(|_| MockBackend::instant(), plan);
        assert_eq!(srv.group_count(), 1);
        assert_eq!(srv.replica_count(), 3);
        for i in 0..20 {
            srv.submit_blocking(i, vec![i as f32]).unwrap();
        }
        srv.shutdown();
        let mut got = 0;
        while let Some(c) = srv.next_completion() {
            got += 1;
            assert_eq!(c.output[0], c.id as f32 + 2.0, "frame {} skipped a stage", c.id);
            assert_eq!(c.group, 0);
            assert_eq!(c.stage, 2, "completions come from the last stage");
            assert_eq!(c.stage_latencies.len(), 3, "one latency per stage");
            let total: Duration = c.stage_latencies.iter().sum();
            assert!(total <= c.latency + Duration::from_millis(5));
        }
        assert_eq!(got, 20, "chain dropped frames");
    }

    #[test]
    fn apply_swaps_fleet_without_losing_completions() {
        let mut srv = Server::deploy(|_| MockBackend::instant(), single(64, 2));
        for i in 0..10 {
            srv.submit_blocking(i, vec![i as f32]).unwrap();
        }
        // grow to a 3-group fleet on the same completion stream
        let plan = Deployment::replicated(3)
            .with_batcher(BatcherConfig { max_batch: 2, max_wait: Duration::from_millis(1) })
            .with_queue_depth(64);
        srv.apply(|_| MockBackend::instant(), plan).unwrap();
        assert_eq!(srv.group_count(), 3);
        for i in 10..30 {
            srv.submit_blocking(i, vec![i as f32]).unwrap();
        }
        srv.shutdown();
        let mut ids = Vec::new();
        while let Some(c) = srv.next_completion() {
            assert_eq!(c.output[0], c.id as f32 + 1.0);
            ids.push(c.id);
        }
        ids.sort_unstable();
        assert_eq!(ids, (0..30).collect::<Vec<u64>>(), "swap lost completions");
    }

    #[test]
    fn apply_keeps_unchanged_groups_running_without_respawn() {
        // count backend constructions: a kept group must not rebuild one
        static BUILDS: AtomicUsize = AtomicUsize::new(0);
        let plan = |n: usize| {
            let mut p = Deployment::replicated(n).with_queue_depth(16);
            for (g, grp) in p.groups.iter_mut().enumerate() {
                grp.tag = Some(format!("keep-{g}"));
            }
            p
        };
        let mut srv = Server::deploy(
            |_| {
                BUILDS.fetch_add(1, Ordering::SeqCst);
                MockBackend::instant()
            },
            plan(2),
        );
        // give the spawned workers time to run their factories
        srv.submit_blocking(0, vec![1.0]).unwrap();
        srv.submit_blocking(1, vec![1.0]).unwrap();
        let _ = srv.next_completion();
        let _ = srv.next_completion();
        let before = BUILDS.load(Ordering::SeqCst);
        assert!(before >= 2, "two workers must have built backends");
        // a live retune on group 0 must survive the apply below
        let tuned = BatcherConfig { max_batch: 11, max_wait: Duration::from_micros(900) };
        assert!(srv.set_batcher(0, 0, tuned));
        // same tags + one new group: only the new group spawns a backend
        srv.apply(
            |_| {
                BUILDS.fetch_add(1, Ordering::SeqCst);
                MockBackend::instant()
            },
            plan(3),
        )
        .unwrap();
        assert_eq!(srv.group_count(), 3);
        srv.submit_blocking(2, vec![1.0]).unwrap();
        let _ = srv.next_completion();
        let after = BUILDS.load(Ordering::SeqCst);
        assert!(
            after <= before + 1,
            "kept groups respawned backends: {before} -> {after}"
        );
        assert_eq!(srv.batcher_config(0, 0), Some(tuned), "live retune lost across apply");
        srv.shutdown();
    }

    #[test]
    fn apply_respawns_a_dead_group_even_when_the_spec_matches() {
        // group 1's first backend construction panics, killing its worker;
        // re-applying the *identical* plan is the recovery action and must
        // respawn the dead group rather than keep the corpse as a "match"
        static G1_BUILDS: AtomicUsize = AtomicUsize::new(0);
        let plan = || {
            let mut p = Deployment::replicated(2).with_queue_depth(8);
            for (g, grp) in p.groups.iter_mut().enumerate() {
                grp.tag = Some(format!("heal-{g}"));
            }
            p
        };
        let factory = |id: crate::coordinator::WorkerId| {
            if id.group == 1 && G1_BUILDS.fetch_add(1, Ordering::SeqCst) == 0 {
                panic!("injected backend construction failure");
            }
            MockBackend::instant()
        };
        let mut srv = Server::deploy(factory, plan());
        // let group 1's worker thread hit the panic
        std::thread::sleep(Duration::from_millis(100));
        srv.apply(factory, plan()).unwrap();
        for i in 0..20 {
            srv.submit_blocking(i, vec![1.0]).unwrap();
        }
        srv.shutdown();
        let mut per_group = [0usize; 2];
        while let Some(c) = srv.next_completion() {
            per_group[c.group] += 1;
        }
        assert_eq!(per_group[0] + per_group[1], 20);
        assert!(per_group[1] > 0, "dead group was kept, not respawned: {per_group:?}");
    }

    #[test]
    fn apply_repositions_kept_groups_completion_stamps() {
        // group tagged "b" starts at position 1 and moves to position 0:
        // completions after the apply must carry the new group index
        let mk = |tags: &[&str]| {
            let mut p = Deployment::replicated(tags.len()).with_queue_depth(16);
            for (g, grp) in p.groups.iter_mut().enumerate() {
                grp.tag = Some(tags[g].to_string());
            }
            p
        };
        let mut srv = Server::deploy(|_| MockBackend::instant(), mk(&["a", "b"]));
        srv.apply(|_| MockBackend::instant(), mk(&["b"])).unwrap();
        assert_eq!(srv.group_count(), 1);
        srv.submit_blocking(7, vec![1.0]).unwrap();
        srv.shutdown();
        let c = srv.next_completion().expect("completion");
        assert_eq!(c.group, 0, "kept group must stamp its new position");
    }

    #[test]
    fn apply_after_shutdown_is_an_error() {
        let mut srv = Server::deploy(|_| MockBackend::instant(), single(8, 1));
        srv.shutdown();
        let err = srv.apply(|_| MockBackend::instant(), single(8, 1));
        assert!(err.is_err(), "applying to a shut-down server must fail");
    }

    #[test]
    fn apply_splices_a_new_chain_length() {
        let plan = |k: usize| {
            Deployment::chain(k)
                .with_batcher(BatcherConfig {
                    max_batch: 1,
                    max_wait: Duration::from_millis(1),
                })
                .with_queue_depth(16)
        };
        let mut srv = Server::deploy(|_| MockBackend::instant(), plan(3));
        for i in 0..10 {
            srv.submit_blocking(i, vec![i as f32]).unwrap();
        }
        // splice down to a 2-stage chain (one device lost, plan repaired)
        srv.apply(|_| MockBackend::instant(), plan(2)).unwrap();
        assert_eq!(srv.replica_count(), 2);
        for i in 100..110 {
            srv.submit_blocking(i, vec![i as f32]).unwrap();
        }
        srv.shutdown();
        let mut pre = 0;
        let mut post = 0;
        while let Some(c) = srv.next_completion() {
            if c.id < 100 {
                // old plan: 3 stages, each adding +1 after the first
                assert_eq!(c.output[0], c.id as f32 + 2.0);
                pre += 1;
            } else {
                // new plan: 2 stages
                assert_eq!(c.output[0], c.id as f32 + 1.0);
                post += 1;
            }
        }
        assert_eq!((pre, post), (10, 10), "splice dropped frames");
    }

    #[test]
    fn live_batcher_retune_roundtrips() {
        let srv = Server::deploy(|_| MockBackend::instant(), single(8, 4));
        let cur = srv.batcher_config(0, 0).unwrap();
        assert_eq!(cur.max_batch, 4);
        let next = BatcherConfig { max_batch: 9, max_wait: Duration::from_micros(700) };
        assert!(srv.set_batcher(0, 0, next));
        let got = srv.batcher_config(0, 0).unwrap();
        assert_eq!(got.max_batch, 9);
        assert_eq!(got.max_wait, Duration::from_micros(700));
        assert!(!srv.set_batcher(5, 0, next), "out-of-range group must report false");
        assert!(!srv.set_batcher(0, 3, next), "out-of-range stage must report false");
        assert!(srv.batcher_config(5, 0).is_none());
    }

    #[test]
    fn full_sibling_does_not_shed_while_another_group_has_room() {
        // group 0 is blocked for a long time; round-robin would prefer it
        // every other request, but the router falls through to group 1
        let plan = Deployment::replicated(2)
            .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(0) })
            .with_queue_depth(1);
        let mut srv = Server::deploy(
            |id| {
                if id.group == 0 {
                    MockBackend::with_service(Duration::from_millis(300), Duration::ZERO)
                } else {
                    MockBackend::instant()
                }
            },
            plan,
        );
        let mut ok = 0;
        for i in 0..12 {
            if srv.submit(i, vec![1.0]).is_ok() {
                ok += 1;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        // group 0 absorbs at most 2 (1 executing + 1 queued); the rest
        // must overflow to group 1 instead of shedding
        assert!(ok >= 10, "only {ok}/12 accepted");
    }

    #[test]
    fn replicated_chains_serve_all_groups_end_to_end() {
        // 2 groups × 2 stages: every frame traverses exactly one group's
        // two stages (output = input + 1) and both groups serve under
        // round-robin
        let plan = Deployment::replicated_chains(2, 2)
            .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) })
            .with_queue_depth(32);
        let mut srv = Server::deploy(|_| MockBackend::instant(), plan);
        assert_eq!(srv.group_count(), 2);
        assert_eq!(srv.replica_count(), 4);
        let n = 40u64;
        for i in 0..n {
            srv.submit_blocking(i, vec![i as f32]).unwrap();
        }
        srv.shutdown();
        let mut per_group = [0usize; 2];
        let mut got = 0;
        while let Some(c) = srv.next_completion() {
            got += 1;
            assert_eq!(c.output[0], c.id as f32 + 1.0, "frame {} broke its chain", c.id);
            assert_eq!(c.stage, 1, "completions come from the last stage");
            assert_eq!(c.stage_latencies.len(), 2);
            per_group[c.group] += 1;
        }
        assert_eq!(got, n as usize, "replicated chains dropped frames");
        assert!(per_group[0] > 0 && per_group[1] > 0, "a group idled: {per_group:?}");
    }

    #[test]
    fn submit_error_is_anyhow_compatible() {
        // the satellite contract: callers can `?` a SubmitError into
        // anyhow::Result instead of pattern-matching
        fn shed() -> anyhow::Result<()> {
            Err(SubmitError::QueueFull(Request::new(3, vec![])))?;
            Ok(())
        }
        let err = shed().unwrap_err();
        assert!(format!("{err}").contains("request 3"), "{err}");
        assert!(format!("{err}").contains("shed"), "{err}");
    }
}
