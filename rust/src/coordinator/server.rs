//! The serving fleet behind the unified [`Deployment`] topology: a router
//! dispatches requests into chain groups by a pluggable scheduling policy;
//! each group is a k-stage pipeline of workers, each worker owns a bounded
//! queue, a dynamic batcher and its own [`InferBackend`]; completions from
//! every group merge into one stream.
//!
//! ```text
//!  clients ──> Server::submit ── Scheduler (policy) picks a chain group
//!                 │    admission control: all entries full => QueueFull
//!                 v
//!       ┌─ group 0: stage 0 → stage 1 → … → stage k-1 ─┐
//!       ├─ group 1: stage 0 → stage 1 → … → stage k-1 ─┤──> completions
//!       └─ group N: stage 0 ──────────────────────────┘    (group, stage,
//!            (k=1 ⇒ a plain replica)                        e2e + per-stage
//!                                                           latencies)
//! ```
//!
//! **Overload semantics.** Each stage's queue is bounded
//! ([`Deployment::queue_depth`]). A non-blocking [`Server::submit`] tries
//! the policy's preferred group first, then the remaining groups in
//! ascending-load order; only when *every* open group entry is full does it
//! shed the request with [`SubmitError::QueueFull`] — graceful degradation,
//! never unbounded memory. Frames always enter a group at stage 0 and the
//! stages forward them onward themselves, so the router can never route
//! into the middle of a chain. After [`Server::shutdown`] (or if all
//! workers die) the error is [`SubmitError::Closed`] instead, so callers
//! can tell "retry later" from "give up". Shutdown closes the queues and
//! *drains* them: every accepted request still produces a completion
//! before the workers exit.
//!
//! **Reshaping.** [`Server::apply`] diffs a new [`Deployment`] against the
//! running one at chain-group granularity: unchanged groups keep serving
//! (their backends, queues and live batcher retunes survive), removed
//! groups drain to completion first, and added groups spawn fresh on the
//! same completion stream — the actuation surface of the adaptive control
//! plane ([`crate::control`]).
//!
//! The backend is a trait so tests and benches run the full coordination
//! path with [`MockBackend`] (no PJRT); `examples/serve_cifar.rs` and
//! `fcmp serve --backend pjrt` plug in the real [`crate::runtime::Engine`].

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Weak};
use std::time::{Duration, Instant};

use super::batcher::BatcherConfig;
use super::deployment::{Deployment, GroupKey, WorkerId};
use super::hotpath::{BufferPool, HotCounters, HotPathStats};
use super::metrics::FleetMetrics;
use super::policy::{Policy, Scheduler};
use super::replica::{Replica, Sink};
use super::workload::Trace;
use super::{Completion, Request};
use crate::obs::{
    Exposition, HealthConfig, HealthJournal, HealthMonitor, Obs, ObsConfig, SpanEvent,
};
use crate::util::rng::Rng;
use crate::Result;

/// The pending result of one submitted batch — what
/// [`InferBackend::submit_batch`] hands the worker's submit/reap loop.
///
/// Three flavors cover every backend style:
/// * [`BatchHandle::ready`] — the work already ran (the default blocking
///   wrapper around [`InferBackend::infer_batch`]).
/// * [`BatchHandle::completes_at`] — the result is computed but embargoed
///   until a known completion instant (simulated device compute
///   overlapping the next batch's transfer — [`PipelinedMockBackend`]).
/// * [`BatchHandle::wait_with`] — the result needs a blocking call to
///   collect (a real async device queue).
pub struct BatchHandle(HandleInner);

enum HandleInner {
    Ready(Result<Vec<Vec<f32>>>),
    At { ready_at: Instant, result: Result<Vec<Vec<f32>>> },
    Wait(Box<dyn FnOnce() -> Result<Vec<Vec<f32>>> + Send>),
}

impl BatchHandle {
    /// A handle whose result is available immediately.
    pub fn ready(result: Result<Vec<Vec<f32>>>) -> BatchHandle {
        BatchHandle(HandleInner::Ready(result))
    }

    /// A handle whose result becomes available at `ready_at`;
    /// [`BatchHandle::wait`] sleeps out the remainder.
    pub fn completes_at(ready_at: Instant, result: Result<Vec<Vec<f32>>>) -> BatchHandle {
        BatchHandle(HandleInner::At { ready_at, result })
    }

    /// A handle that produces its result by running `collect` (a blocking
    /// completion call into the device runtime) at reap time.
    pub fn wait_with(
        collect: impl FnOnce() -> Result<Vec<Vec<f32>>> + Send + 'static,
    ) -> BatchHandle {
        BatchHandle(HandleInner::Wait(Box::new(collect)))
    }

    /// Would [`BatchHandle::wait`] return without blocking? (`Wait`
    /// handles are conservatively never "ready".)
    pub fn is_ready(&self) -> bool {
        match &self.0 {
            HandleInner::Ready(_) => true,
            HandleInner::At { ready_at, .. } => Instant::now() >= *ready_at,
            HandleInner::Wait(_) => false,
        }
    }

    /// Expected time until the result is available: zero when ready,
    /// `None` when unknown (`Wait` handles). The worker sizes its batcher
    /// polling window with this.
    pub fn eta(&self) -> Option<Duration> {
        match &self.0 {
            HandleInner::Ready(_) => Some(Duration::ZERO),
            HandleInner::At { ready_at, .. } => {
                Some(ready_at.saturating_duration_since(Instant::now()))
            }
            HandleInner::Wait(_) => None,
        }
    }

    /// Block until the batch result is available and return it.
    pub fn wait(self) -> Result<Vec<Vec<f32>>> {
        match self.0 {
            HandleInner::Ready(result) => result,
            HandleInner::At { ready_at, result } => {
                let now = Instant::now();
                if ready_at > now {
                    std::thread::sleep(ready_at - now);
                }
                result
            }
            HandleInner::Wait(collect) => collect(),
        }
    }
}

/// Anything that can run a batch of inputs. The backend is constructed
/// *inside* each worker thread (PJRT handles are not `Send`), so only the
/// factory closure crosses threads.
pub trait InferBackend: 'static {
    /// Run one batch; `inputs[i]` is a flattened sample, the result must
    /// hold one output row per input row.
    fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;

    /// Start one batch and return a completion handle, letting the worker
    /// keep up to [`Deployment::window`] batches in flight. `inputs` is
    /// only valid for the duration of the call: an overlapping backend
    /// must copy (the "transfer") before returning, and the returned
    /// handle must not borrow it. The default wraps the blocking
    /// [`InferBackend::infer_batch`] — the batch runs to completion right
    /// here and the handle is immediately ready — so purely synchronous
    /// backends ([`MockBackend`], the PJRT engine) behave identically
    /// under any window.
    fn submit_batch(&self, inputs: &[Vec<f32>]) -> Result<BatchHandle> {
        Ok(BatchHandle::ready(self.infer_batch(inputs)))
    }
}

impl InferBackend for crate::runtime::Engine {
    fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.infer(inputs)
    }
}

/// Boxed backends work too (factories that pick a backend at runtime).
/// Both methods delegate, so a boxed overlapping backend keeps its
/// overlap — the default `submit_batch` would silently serialize it.
impl InferBackend for Box<dyn InferBackend> {
    fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        (**self).infer_batch(inputs)
    }

    fn submit_batch(&self, inputs: &[Vec<f32>]) -> Result<BatchHandle> {
        (**self).submit_batch(inputs)
    }
}

/// Deterministic mock backend for tests, benches and `fcmp serve --backend
/// mock`: each output row is `[Σ inputs, batch_size]`, and a batch of `k`
/// requests takes `base + per_item · k` of simulated service time. Scaling
/// `base`/`per_item` per worker models a heterogeneous fleet.
#[derive(Clone, Copy, Debug)]
pub struct MockBackend {
    /// Fixed per-batch overhead (amortized by batching).
    pub base: Duration,
    /// Marginal service time per request in the batch.
    pub per_item: Duration,
}

impl MockBackend {
    /// Zero service time — completes as fast as the threads can run.
    pub fn instant() -> MockBackend {
        MockBackend { base: Duration::ZERO, per_item: Duration::ZERO }
    }

    /// Mock with the given service-time model.
    pub fn with_service(base: Duration, per_item: Duration) -> MockBackend {
        MockBackend { base, per_item }
    }
}

impl InferBackend for MockBackend {
    fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let service = self.base + self.per_item * inputs.len() as u32;
        if !service.is_zero() {
            std::thread::sleep(service);
        }
        Ok(inputs
            .iter()
            .map(|x| vec![x.iter().sum::<f32>(), inputs.len() as f32])
            .collect())
    }
}

/// Mock backend with a two-phase service model that rewards in-flight
/// windows: each batch costs `xfer_per_item · k` of *transfer* (occupies
/// the submitter — the host-to-device copy) plus `compute_per_item · k`
/// of *device compute* (occupies a single serial device queue). Under
/// [`InferBackend::submit_batch`] the transfer of batch `N+1` overlaps
/// the compute of batch `N`, exactly like a filled hardware pipeline, so
/// with `xfer == compute` a window ≥ 2 doubles throughput; the blocking
/// [`InferBackend::infer_batch`] path runs the two phases back-to-back
/// (what a window of 1 degenerates to). Outputs match [`MockBackend`]:
/// `[Σ inputs, batch_size]`.
#[derive(Debug)]
pub struct PipelinedMockBackend {
    /// Per-request transfer time (blocks the submitting worker).
    pub xfer_per_item: Duration,
    /// Per-request device compute time (serial device queue).
    pub compute_per_item: Duration,
    /// When the simulated device queue drains (backends are thread-local
    /// to their worker, so a `Cell` suffices).
    device_free: Cell<Option<Instant>>,
}

impl PipelinedMockBackend {
    /// A backend whose transfer and compute phases can overlap across
    /// consecutive batches.
    pub fn overlapped(xfer_per_item: Duration, compute_per_item: Duration) -> Self {
        PipelinedMockBackend { xfer_per_item, compute_per_item, device_free: Cell::new(None) }
    }

    fn outputs(inputs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        inputs.iter().map(|x| vec![x.iter().sum::<f32>(), inputs.len() as f32]).collect()
    }
}

impl InferBackend for PipelinedMockBackend {
    fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let k = inputs.len() as u32;
        let service = (self.xfer_per_item + self.compute_per_item) * k;
        if !service.is_zero() {
            std::thread::sleep(service);
        }
        Ok(Self::outputs(inputs))
    }

    fn submit_batch(&self, inputs: &[Vec<f32>]) -> Result<BatchHandle> {
        let k = inputs.len() as u32;
        let xfer = self.xfer_per_item * k;
        if !xfer.is_zero() {
            // the transfer occupies the submitter (and "copies" inputs —
            // we compute the outputs eagerly, honoring the borrow rule)
            std::thread::sleep(xfer);
        }
        let outputs = Self::outputs(inputs);
        let now = Instant::now();
        let start = self.device_free.get().map_or(now, |free| free.max(now));
        let ready_at = start + self.compute_per_item * k;
        self.device_free.set(Some(ready_at));
        Ok(BatchHandle::completes_at(ready_at, Ok(outputs)))
    }
}

/// Typed submit failure. The rejected request rides back in the error so
/// callers can retry without rebuilding the input buffer, and the two
/// variants make transient overload distinguishable from terminal shutdown.
/// Implements [`std::error::Error`], so callers can `?` it straight into
/// `anyhow::Result` instead of pattern-matching.
#[derive(Debug)]
pub enum SubmitError {
    /// Every open group entry queue was full — admission control shed the
    /// request. Retrying after a backoff can succeed.
    QueueFull(Request),
    /// The server is shut down (or every worker died). Retrying cannot
    /// succeed.
    Closed(Request),
    /// A deadline-capped submit ([`Server::submit_within`]) exhausted its
    /// backoff budget with every entry queue still full. Retrying later
    /// can succeed — the fleet is overloaded, not gone.
    Timeout(Request),
    /// The request's completion deadline cannot plausibly be met by any
    /// of its tenant's groups
    /// ([`crate::coordinator::dispatch::deadline_feasible`]), so
    /// admission control refused it *before* it occupied a queue slot.
    /// Disjoint from [`SubmitError::QueueFull`]: the fleet may have
    /// room, but queued work ahead already spends the SLO budget.
    DeadlineInfeasible(Request),
}

impl SubmitError {
    /// Recover the rejected request (e.g. to retry it later).
    pub fn into_request(self) -> Request {
        match self {
            SubmitError::QueueFull(r)
            | SubmitError::Closed(r)
            | SubmitError::Timeout(r)
            | SubmitError::DeadlineInfeasible(r) => r,
        }
    }

    /// True iff the failure is terminal (no retry can succeed).
    pub fn is_closed(&self) -> bool {
        matches!(self, SubmitError::Closed(_))
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull(r) => {
                write!(f, "request {} shed: every chain group's entry queue is full", r.id)
            }
            SubmitError::Closed(r) => {
                write!(f, "request {} rejected: server is shut down", r.id)
            }
            SubmitError::Timeout(r) => {
                write!(f, "request {} timed out: entry queues stayed full past the deadline", r.id)
            }
            SubmitError::DeadlineInfeasible(r) => {
                write!(
                    f,
                    "request {} shed: no group of its tenant can meet its deadline",
                    r.id
                )
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// One running chain group: its stage workers (stage 0 is the entry), the
/// shared cell holding the group's current plan position (completions read
/// it, so a group kept across [`Server::apply`] reports its new index),
/// and the diffing key it was spawned under.
struct Group {
    replicas: Vec<Replica>,
    pos: Arc<std::sync::atomic::AtomicUsize>,
    key: GroupKey,
}

impl Group {
    /// Total outstanding requests across every stage (the group load
    /// signal the policy and the fallback ordering read).
    fn outstanding(&self) -> usize {
        self.replicas.iter().map(Replica::outstanding).sum()
    }

    /// Stop admitting at every stage (front first, so drained frames flow
    /// through still-open downstream stages).
    fn close(&mut self) {
        for r in &mut self.replicas {
            r.close();
        }
    }

    /// Wait for every stage to drain (after [`Group::close`]).
    fn join(&mut self) {
        for r in &mut self.replicas {
            r.join();
        }
    }

    fn is_dead(&self) -> bool {
        !self.replicas.is_empty() && self.replicas.iter().all(Replica::is_dead)
    }

    /// Any stage's worker died (panicked backend). A chain with even one
    /// dead stage cannot carry frames end-to-end, so [`Server::apply`]
    /// must never keep such a group as a "match" — re-applying the plan
    /// is the recovery action, and it has to respawn.
    fn has_dead_worker(&self) -> bool {
        self.replicas.iter().any(Replica::is_dead)
    }
}

/// One chain group as the router sees it: the entry stage's bounded
/// sender, its outstanding counter (incremented before every send, the
/// same discipline the old per-replica submit used), and every stage's
/// counter for the group load signal.
struct GroupEntry {
    tx: SyncSender<Request>,
    entry_outstanding: Arc<AtomicUsize>,
    stage_outstanding: Vec<Arc<AtomicUsize>>,
}

impl GroupEntry {
    /// Outstanding requests across the group's stages (JSQ / fallback
    /// ordering signal).
    fn load(&self) -> usize {
        self.stage_outstanding.iter().map(|c| c.load(Ordering::SeqCst)).sum()
    }
}

/// The lock-free submit path, shared by [`Server`] and every cloned
/// [`SubmitHandle`]. The steady-state dispatch is: one atomic policy
/// pick, one counter increment, one bounded-channel `try_send` — no
/// locks, no allocation, no `&mut`. The [`Server`] holds the only strong
/// `Arc`; handles hold `Weak`s, so replacing the router (on
/// [`Server::apply`] / [`Server::shutdown`]) drops the entry senders at
/// once — worker channels can disconnect and drain — and stale handles
/// report [`SubmitError::Closed`].
struct RouterCore {
    entries: Vec<GroupEntry>,
    scheduler: Scheduler,
    /// Tenant → the groups carrying its networks (global indices,
    /// ascending). One entry (holding every group) in single-tenant
    /// plans, so untenanted and tenant-0 dispatch agree.
    tenant_groups: Vec<Vec<usize>>,
    /// Per-tenant schedulers over tenant-*local* index spaces — one
    /// tenant's RR cursor / SWRR credits never move on another tenant's
    /// traffic.
    tenant_schedulers: Vec<Scheduler>,
    /// Per-group service-time estimate (ns) for the deadline-feasibility
    /// rule; zeros degrade the rule to "shed only if already expired".
    est_service_ns: Vec<u64>,
    counters: Arc<HotCounters>,
    /// Observability hub: head-based sampling happens at dispatch, the
    /// Enqueue stamp right before the entry `try_send`. A disabled hub
    /// costs one branch per dispatch.
    obs: Arc<Obs>,
}

/// Exponential-backoff bounds for blocking/deadline submits parked-out on
/// a saturated fleet.
const BACKOFF_START: Duration = Duration::from_micros(50);
const BACKOFF_CAP: Duration = Duration::from_millis(5);

impl RouterCore {
    /// A router with no entries: every dispatch reports `Closed`. Swapped
    /// in *before* a shutdown/reshape closes worker queues, so the old
    /// core's entry senders drop and the workers' channels can disconnect.
    fn detached(policy: Policy, counters: Arc<HotCounters>, obs: Arc<Obs>) -> RouterCore {
        RouterCore {
            entries: Vec::new(),
            scheduler: Scheduler::new(policy, 1),
            tenant_groups: Vec::new(),
            tenant_schedulers: Vec::new(),
            est_service_ns: Vec::new(),
            counters,
            obs,
        }
    }

    /// Non-blocking entry submit with increment-before-send counter
    /// discipline (a decrement-first interleaving could wrap the counter
    /// and corrupt the JSQ load signal; the transient +1 on failure is
    /// harmless).
    fn try_entry(&self, g: usize, mut req: Request) -> std::result::Result<(), (Request, bool)> {
        // stamped before the send (the request is gone on success); a
        // shed-and-retried request re-stamps and the analyzer keeps the
        // last Enqueue — the one that actually landed
        self.obs.stamp(&mut req.span, SpanEvent::Enqueue, g as u16, 0);
        let e = &self.entries[g];
        e.entry_outstanding.fetch_add(1, Ordering::SeqCst);
        match e.tx.try_send(req) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(r)) => {
                e.entry_outstanding.fetch_sub(1, Ordering::SeqCst);
                Err((r, true))
            }
            Err(TrySendError::Disconnected(r)) => {
                e.entry_outstanding.fetch_sub(1, Ordering::SeqCst);
                Err((r, false))
            }
        }
    }

    /// Route a request: the policy's preferred group first; only if its
    /// entry queue is full (or its workers died) fall through to the
    /// remaining groups in ascending-load order, so a full preferred
    /// entry does not shed while a sibling group has room. The common
    /// accepted-first-try case is the allocation-free hot path (JSQ's
    /// argmin runs inline over the atomic counters — no load snapshot
    /// `Vec`). A single-group deployment has no siblings, so a full entry
    /// queue sheds immediately — frames can never enter a chain
    /// mid-pipeline.
    fn dispatch(&self, mut req: Request) -> std::result::Result<usize, SubmitError> {
        self.counters.submits.fetch_add(1, Ordering::Relaxed);
        // head-based sampling: decided once per request id (idempotent
        // across the blocking-submit retry loop — the span survives in
        // the returned request)
        if self.obs.active() && req.span.is_none() {
            req.span = self.obs.sample(req.id);
        }
        if self.entries.is_empty() {
            return Err(SubmitError::Closed(req));
        }
        let first = super::dispatch::preferred_group(&self.scheduler, self.entries.len(), |g| {
            self.entries[g].load()
        });
        let mut saw_full = false;
        let mut req = match self.try_entry(first, req) {
            Ok(()) => {
                self.counters.accepted_first_try.fetch_add(1, Ordering::Relaxed);
                return Ok(first);
            }
            Err((r, full)) => {
                saw_full |= full;
                r
            }
        };
        // cold path: scan the siblings in ascending-load order (the sort
        // allocates, but only when the preferred entry already failed)
        self.counters.fallback_scans.fetch_add(1, Ordering::Relaxed);
        let rest = super::dispatch::fallback_order(first, self.entries.len(), |g| {
            self.entries[g].load()
        });
        for g in rest {
            match self.try_entry(g, req) {
                Ok(()) => return Ok(g),
                Err((r, full)) => {
                    saw_full |= full;
                    req = r;
                }
            }
        }
        if saw_full {
            Err(SubmitError::QueueFull(req))
        } else {
            Err(SubmitError::Closed(req))
        }
    }

    /// Route a request for `tenant`: the same preferred-then-fallback
    /// order as [`RouterCore::dispatch`], but restricted to the tenant's
    /// own groups — driven through the [`super::dispatch`] seam over the
    /// tenant-*local* index space, so the discrete-event simulator can
    /// mirror the order exactly. A deadline-carrying request is first
    /// checked against [`super::dispatch::deadline_feasible`] on the
    /// tenant's least-loaded group and shed with
    /// [`SubmitError::DeadlineInfeasible`] when its SLO budget cannot
    /// cover the estimated sojourn.
    fn dispatch_tenant(
        &self,
        tenant: usize,
        mut req: Request,
    ) -> std::result::Result<usize, SubmitError> {
        self.counters.submits.fetch_add(1, Ordering::Relaxed);
        let members = match self.tenant_groups.get(tenant) {
            Some(m) if !m.is_empty() => m,
            _ => return Err(SubmitError::Closed(req)),
        };
        if let Some(deadline) = req.deadline {
            let (min_load, best) = members
                .iter()
                .map(|&g| (self.entries[g].load(), g))
                .min()
                .expect("members is non-empty");
            let remaining: i64 = match deadline.checked_duration_since(Instant::now()) {
                Some(left) => left.as_nanos().min(i64::MAX as u128) as i64,
                None => -1, // already expired
            };
            let est = self.est_service_ns.get(best).copied().unwrap_or(0);
            if !super::dispatch::deadline_feasible(remaining, min_load, est) {
                self.counters.deadline_sheds.fetch_add(1, Ordering::Relaxed);
                return Err(SubmitError::DeadlineInfeasible(req));
            }
        }
        if self.obs.active() && req.span.is_none() {
            req.span = self.obs.sample(req.id);
        }
        let load = |i: usize| self.entries[members[i]].load();
        let first =
            super::dispatch::preferred_group(&self.tenant_schedulers[tenant], members.len(), load);
        let mut saw_full = false;
        let mut req = match self.try_entry(members[first], req) {
            Ok(()) => {
                self.counters.accepted_first_try.fetch_add(1, Ordering::Relaxed);
                return Ok(members[first]);
            }
            Err((r, full)) => {
                saw_full |= full;
                r
            }
        };
        self.counters.fallback_scans.fetch_add(1, Ordering::Relaxed);
        for i in super::dispatch::fallback_order(first, members.len(), load) {
            match self.try_entry(members[i], req) {
                Ok(()) => return Ok(members[i]),
                Err((r, full)) => {
                    saw_full |= full;
                    req = r;
                }
            }
        }
        if saw_full {
            Err(SubmitError::QueueFull(req))
        } else {
            Err(SubmitError::Closed(req))
        }
    }

    /// Blocking entry submit (parks on the bounded queue); fails only on
    /// a disconnected (dead) worker.
    fn wait_entry(&self, g: usize, mut req: Request) -> std::result::Result<(), Request> {
        self.obs.stamp(&mut req.span, SpanEvent::Enqueue, g as u16, 0);
        let e = &self.entries[g];
        e.entry_outstanding.fetch_add(1, Ordering::SeqCst);
        match e.tx.send(req) {
            Ok(()) => Ok(()),
            Err(err) => {
                e.entry_outstanding.fetch_sub(1, Ordering::SeqCst);
                Err(err.0)
            }
        }
    }

    /// Shared blocking-submit loop. With no deadline it parks on the
    /// least-loaded entry queue (the worker wakes it when a slot frees),
    /// falling back to bounded exponential backoff only on the
    /// dead-group-looks-idle race. With a deadline it polls
    /// [`RouterCore::dispatch`] under the same backoff schedule and
    /// returns [`SubmitError::Timeout`] once the deadline passes — `std`
    /// bounded channels have no `send_timeout`, so the deadline path
    /// never parks unboundedly.
    fn submit_until(
        &self,
        req: Request,
        deadline: Option<Instant>,
    ) -> std::result::Result<usize, SubmitError> {
        let mut req = req;
        let mut backoff = BACKOFF_START;
        loop {
            req = match self.dispatch(req) {
                Ok(g) => return Ok(g),
                Err(SubmitError::Closed(r)) => return Err(SubmitError::Closed(r)),
                // waiting cannot make an infeasible deadline feasible
                Err(e @ SubmitError::DeadlineInfeasible(_)) => return Err(e),
                Err(SubmitError::QueueFull(r)) | Err(SubmitError::Timeout(r)) => r,
            };
            match deadline {
                None => {
                    let g = (0..self.entries.len())
                        .min_by_key(|&g| (self.entries[g].load(), g))
                        .expect("dispatch returned QueueFull, so entries exist");
                    req = match self.wait_entry(g, req) {
                        Ok(()) => return Ok(g),
                        Err(r) => {
                            // a dead group can look idle; back off so the
                            // retry loop cannot spin between dispatch and
                            // the park
                            self.counters.backoff_sleeps.fetch_add(1, Ordering::Relaxed);
                            std::thread::sleep(backoff);
                            backoff = (backoff * 2).min(BACKOFF_CAP);
                            r
                        }
                    };
                }
                Some(deadline) => {
                    let now = Instant::now();
                    if now >= deadline {
                        return Err(SubmitError::Timeout(req));
                    }
                    self.counters.backoff_sleeps.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(backoff.min(deadline - now));
                    backoff = (backoff * 2).min(BACKOFF_CAP);
                }
            }
        }
    }
}

/// A cheaply-cloneable, `Send + Sync` submit endpoint: the lock-free hot
/// path of the zero-stall design, detached from the [`Server`]'s `&mut`
/// lifecycle API so any number of threads can submit concurrently.
/// Handles hold a `Weak` reference to the router — after a
/// [`Server::apply`] or [`Server::shutdown`] replaces it, every
/// outstanding handle reports [`SubmitError::Closed`] (grab a fresh one
/// with [`Server::submit_handle`]). The handle also exposes the server's
/// [`BufferPool`] so submitters can recycle payload buffers.
#[derive(Clone)]
pub struct SubmitHandle {
    core: Weak<RouterCore>,
    pool: Arc<BufferPool>,
}

impl SubmitHandle {
    /// Non-blocking submit; see [`Server::submit`].
    pub fn submit(&self, id: u64, input: Vec<f32>) -> std::result::Result<usize, SubmitError> {
        match self.core.upgrade() {
            Some(core) => core.dispatch(Request::new(id, input)),
            None => Err(SubmitError::Closed(Request::new(id, input))),
        }
    }

    /// Blocking submit; see [`Server::submit_blocking`].
    pub fn submit_blocking(
        &self,
        id: u64,
        input: Vec<f32>,
    ) -> std::result::Result<usize, SubmitError> {
        match self.core.upgrade() {
            Some(core) => core.submit_until(Request::new(id, input), None),
            None => Err(SubmitError::Closed(Request::new(id, input))),
        }
    }

    /// Deadline-capped blocking submit; see [`Server::submit_within`].
    pub fn submit_within(
        &self,
        id: u64,
        input: Vec<f32>,
        timeout: Duration,
    ) -> std::result::Result<usize, SubmitError> {
        match self.core.upgrade() {
            Some(core) => {
                core.submit_until(Request::new(id, input), Some(Instant::now() + timeout))
            }
            None => Err(SubmitError::Closed(Request::new(id, input))),
        }
    }

    /// The fleet's shared request-buffer pool (recycle payload `Vec`s
    /// through it to keep the submit path allocation-free).
    pub fn buffer_pool(&self) -> &BufferPool {
        &self.pool
    }
}

/// A running inference server: the live realization of a [`Deployment`].
pub struct Server {
    groups: Vec<Group>,
    plan: Deployment,
    completions: Receiver<Completion>,
    /// Kept open across [`Server::apply`] so a reshaped fleet keeps
    /// feeding the same completion stream; dropped on [`Server::shutdown`]
    /// so the stream terminates once drained.
    completion_tx: Option<Sender<Completion>>,
    /// The lock-free submit path. The server holds the only strong `Arc`
    /// (handles hold `Weak`s): swapping in a detached core is how
    /// shutdown/reshape releases the entry senders so worker channels can
    /// disconnect and drain.
    router: Arc<RouterCore>,
    pool: Arc<BufferPool>,
    counters: Arc<HotCounters>,
    obs: Arc<Obs>,
    exposition: Option<Exposition>,
    /// Long-horizon health collection, fed on the snapshot cadence of
    /// the replay loop (never per request).
    health: Option<HealthMonitor>,
    /// Sheds since the last anomaly observation (replay's shed-burst
    /// window).
    shed_window: u64,
    /// Per-tenant SLO budgets ([`Server::set_tenancy`]): tenant `t`'s
    /// requests carry `arrival + budgets[t]` as their deadline; `None`
    /// entries submit best-effort. Persists across [`Server::apply`].
    tenant_budgets: Vec<Option<Duration>>,
    /// Per-group service-time estimates (ns) feeding the
    /// deadline-feasibility rule; resized with zeros to the group count
    /// on every router rebuild.
    est_service_ns: Vec<u64>,
}

impl Server {
    /// Spawn the fleet described by `plan`. `make_backend(id)` runs on
    /// worker `id`'s own thread (PJRT engines are thread-affine) and a
    /// panic there surfaces on first use of that worker. Tracing is off;
    /// use [`Server::deploy_with_obs`] to sample request spans.
    pub fn deploy<B, F>(make_backend: F, plan: Deployment) -> Server
    where
        B: InferBackend,
        F: Fn(WorkerId) -> B + Send + Sync + 'static,
    {
        Self::deploy_with_obs(make_backend, plan, &ObsConfig::default())
    }

    /// [`Server::deploy`] with flight-recorder tracing: requests are
    /// head-sampled per `cfg`, stamped through the monotonic clock at
    /// every lifecycle point, and terminal spans land in per-worker
    /// recorder rings (flushed to `cfg.trace_out` on anomalies and at
    /// shutdown).
    pub fn deploy_with_obs<B, F>(make_backend: F, plan: Deployment, cfg: &ObsConfig) -> Server
    where
        B: InferBackend,
        F: Fn(WorkerId) -> B + Send + Sync + 'static,
    {
        let obs = Obs::new(cfg, Arc::new(crate::obs::MonotonicClock::new()));
        let plan = plan.normalized();
        // completions are unbounded: backpressure belongs on the *request*
        // queues; a bounded completion channel can deadlock shutdown (worker
        // blocks on send while the owner blocks on join without draining)
        let (ctx, crx) = channel::<Completion>();
        let counters = Arc::new(HotCounters::default());
        let pool = Arc::new(BufferPool::new(Self::pool_capacity(&plan)));
        let factory = Arc::new(make_backend);
        let groups: Vec<Group> = (0..plan.groups.len())
            .map(|g| Self::spawn_group(&factory, &plan, g, &ctx, &pool, &obs))
            .collect();
        let router = Arc::new(RouterCore::detached(
            plan.policy.clone(),
            Arc::clone(&counters),
            Arc::clone(&obs),
        ));
        let mut srv = Server {
            groups,
            plan,
            completions: crx,
            completion_tx: Some(ctx),
            router,
            pool,
            counters,
            obs,
            exposition: None,
            health: None,
            shed_window: 0,
            tenant_budgets: Vec::new(),
            est_service_ns: Vec::new(),
        };
        srv.rebuild_router();
        srv
    }

    /// How many free payload buffers the pool may retain: enough to cover
    /// every buffer that can be in flight at once (queued + windowed per
    /// stage) plus headroom, capped so a pathological plan cannot pin
    /// unbounded memory.
    fn pool_capacity(plan: &Deployment) -> usize {
        let mut total = 64usize;
        for g in 0..plan.groups.len() {
            let stages = plan.groups[g].stages.max(1);
            let max_batch = plan.group_batcher(g).max_batch.max(1);
            total = total.saturating_add(
                stages * (plan.queue_depth.max(1) + plan.window.max(1) * max_batch),
            );
        }
        total.min(16384)
    }

    /// **Group-granular drain-and-swap** (the control plane's actuation
    /// path, [`crate::control`]): diff `plan` against the running
    /// deployment. Groups whose [`crate::coordinator::ChainGroup`] spec is
    /// unchanged (same tag, stage count, batcher and queue depth) are
    /// *kept running* — no drain, no backend respawn, live batcher
    /// retunes survive, only their position cell updates. Groups absent
    /// from the new plan drain every accepted request to completion
    /// first; then the added groups spawn on the *same* completion
    /// stream, so completions buffered before, during and after the swap
    /// all remain readable and a driver loop never misses one.
    ///
    /// A matching spec keeps the *old backends*: callers replacing the
    /// backends behind an identical shape must change the group's
    /// [`crate::coordinator::ChainGroup::tag`]. Fails only after
    /// [`Server::shutdown`] (the completion stream is gone for good).
    pub fn apply<B, F>(&mut self, make_backend: F, plan: Deployment) -> crate::Result<()>
    where
        B: InferBackend,
        F: Fn(WorkerId) -> B + Send + Sync + 'static,
    {
        let ctx = match self.completion_tx.clone() {
            Some(tx) => tx,
            None => anyhow::bail!("cannot apply a new plan after shutdown"),
        };
        let plan = plan.normalized();
        let factory = Arc::new(make_backend);
        // detach the router first: the old core holds clones of every
        // entry sender, and leaving groups can only drain once those
        // drop. Outstanding SubmitHandles go Closed here by design.
        self.router = Arc::new(RouterCore::detached(
            plan.policy.clone(),
            Arc::clone(&self.counters),
            Arc::clone(&self.obs),
        ));
        // match running groups to new slots by key: first unused match, in
        // plan order, so N identical untagged groups keep min(old, new).
        // A group with any dead worker never matches — re-applying the
        // same plan is the recovery action, so it must respawn the group
        // instead of silently keeping a corpse
        let old: Vec<Group> = std::mem::take(&mut self.groups);
        let mut pool: Vec<Option<Group>> = old.into_iter().map(Some).collect();
        let mut slots: Vec<Option<Group>> = Vec::with_capacity(plan.groups.len());
        for g in 0..plan.groups.len() {
            let key = plan.group_key(g);
            let hit = pool
                .iter_mut()
                .find(|s| {
                    s.as_ref().is_some_and(|grp| grp.key == key && !grp.has_dead_worker())
                })
                .and_then(Option::take);
            slots.push(hit);
        }
        // groups leaving the plan drain first: every accepted frame
        // completes on the old topology before replacement capacity spawns
        let mut leaving: Vec<Group> = pool.into_iter().flatten().collect();
        for grp in &mut leaving {
            grp.close();
        }
        for grp in &mut leaving {
            grp.join();
        }
        self.groups = slots
            .into_iter()
            .enumerate()
            .map(|(g, slot)| match slot {
                Some(grp) => {
                    // kept group: serving the whole time, new position
                    grp.pos.store(g, Ordering::SeqCst);
                    grp
                }
                None => Self::spawn_group(&factory, &plan, g, &ctx, &self.pool, &self.obs),
            })
            .collect();
        self.plan = plan;
        self.rebuild_router();
        Ok(())
    }

    /// Point the lock-free submit path at the current groups (fresh
    /// scheduler state, fresh entry senders). Called after every
    /// deploy/apply; [`SubmitHandle`]s minted before this keep the old
    /// `Weak` and report `Closed`.
    fn rebuild_router(&mut self) {
        let entries: Vec<GroupEntry> = self
            .groups
            .iter()
            .map(|g| GroupEntry {
                tx: g.replicas[0].sender().expect("fresh group entry is open"),
                entry_outstanding: g.replicas[0].outstanding_handle(),
                stage_outstanding: g.replicas.iter().map(Replica::outstanding_handle).collect(),
            })
            .collect();
        let tenants: Vec<usize> = (0..entries.len()).map(|g| self.plan.tenant_of(g)).collect();
        let n_tenants = tenants.iter().copied().max().unwrap_or(0) + 1;
        let mut tenant_groups = vec![Vec::new(); n_tenants];
        for (g, &t) in tenants.iter().enumerate() {
            tenant_groups[t].push(g);
        }
        let tenant_schedulers = tenant_groups
            .iter()
            .map(|m: &Vec<usize>| Scheduler::new(self.plan.policy.clone(), m.len().max(1)))
            .collect();
        let mut est_service_ns = self.est_service_ns.clone();
        est_service_ns.resize(entries.len(), 0);
        self.router = Arc::new(RouterCore {
            entries,
            scheduler: Scheduler::new(self.plan.policy.clone(), self.groups.len().max(1)),
            tenant_groups,
            tenant_schedulers,
            est_service_ns,
            counters: Arc::clone(&self.counters),
            obs: Arc::clone(&self.obs),
        });
    }

    /// Spawn chain group `g` of `plan`, feeding final-stage completions
    /// into `ctx`. Stages spawn back-to-front so stage `i` can hold stage
    /// `i+1`'s queue handle.
    fn spawn_group<B, F>(
        factory: &Arc<F>,
        plan: &Deployment,
        g: usize,
        ctx: &Sender<Completion>,
        pool: &Arc<BufferPool>,
        obs: &Arc<Obs>,
    ) -> Group
    where
        B: InferBackend,
        F: Fn(WorkerId) -> B + Send + Sync + 'static,
    {
        let k = plan.groups[g].stages.max(1);
        let batcher = plan.group_batcher(g);
        let pos = Arc::new(std::sync::atomic::AtomicUsize::new(g));
        let mut replicas: Vec<Replica> = Vec::with_capacity(k);
        let mut downstream = None;
        for stage in (0..k).rev() {
            let f = Arc::clone(factory);
            let id = WorkerId { group: g, stage };
            let sink = match downstream.take() {
                None => Sink::Complete { tx: ctx.clone(), group: Arc::clone(&pos) },
                Some((next, next_outstanding)) => Sink::Forward { next, next_outstanding },
            };
            let r = Replica::spawn(
                id,
                move || (*f)(id),
                batcher,
                plan.queue_depth,
                plan.window,
                sink,
                Arc::clone(pool),
                Arc::clone(obs),
                obs.recorder().register(),
            );
            downstream =
                Some((r.sender().expect("fresh replica is open"), r.outstanding_handle()));
            replicas.push(r);
        }
        replicas.reverse();
        Group { replicas, pos, key: plan.group_key(g) }
    }

    /// The deployment currently being served.
    pub fn plan(&self) -> &Deployment {
        &self.plan
    }

    /// Number of chain groups.
    pub fn group_count(&self) -> usize {
        self.groups.len()
    }

    /// Stage counts per group, in router order.
    pub fn group_sizes(&self) -> Vec<usize> {
        self.groups.iter().map(|g| g.replicas.len()).collect()
    }

    /// Total workers across every group.
    pub fn replica_count(&self) -> usize {
        self.groups.iter().map(|g| g.replicas.len()).sum()
    }

    /// Current batching settings of stage `stage` of group `group`
    /// (`None` when either index is out of range).
    pub fn batcher_config(&self, group: usize, stage: usize) -> Option<BatcherConfig> {
        self.groups.get(group).and_then(|g| g.replicas.get(stage)).map(Replica::batcher)
    }

    /// Live-retune one worker's batcher (the SLO controller's actuation,
    /// [`crate::control::slo`]): the worker applies the new settings on
    /// its next batch, with no drain and no respawn. Returns `false` when
    /// an index is out of range. Live adjustments survive a
    /// [`Server::apply`] that keeps the group; a swap that respawns it
    /// restarts from the plan's baseline.
    pub fn set_batcher(&self, group: usize, stage: usize, cfg: BatcherConfig) -> bool {
        match self.groups.get(group).and_then(|g| g.replicas.get(stage)) {
            Some(r) => {
                r.set_batcher(cfg);
                true
            }
            None => false,
        }
    }

    /// Per-worker outstanding request counts (queued + executing), flat
    /// in group-then-stage order.
    pub fn outstanding(&self) -> Vec<usize> {
        self.groups
            .iter()
            .flat_map(|g| g.replicas.iter().map(Replica::outstanding))
            .collect()
    }

    /// Per-group outstanding request counts (summed over the group's
    /// stages) — the load signal group-granular scheduling reads.
    pub fn group_outstanding(&self) -> Vec<usize> {
        self.groups.iter().map(Group::outstanding).collect()
    }

    /// Number of chain groups with at least one dead worker (a panicked
    /// backend, never a normal drain). Such a group cannot carry frames
    /// end-to-end; re-applying the plan respawns it.
    pub fn dead_groups(&self) -> usize {
        self.groups.iter().filter(|g| g.has_dead_worker()).count()
    }

    /// Every worker died without a shutdown (panicked backends). The
    /// completion channel stays open (the server holds a sender for
    /// [`Server::apply`]), so this probe — not channel disconnection — is
    /// how replay loops detect a dead fleet.
    fn all_workers_dead(&self) -> bool {
        !self.groups.is_empty() && self.groups.iter().all(Group::is_dead)
    }

    /// Non-blocking submit. Returns the chain-group index the request
    /// entered (frames always enter at the group's stage 0), or a typed
    /// [`SubmitError`] (overload shed vs shutdown). Delegates to the
    /// lock-free router core — `&mut self` is kept only for API
    /// continuity; concurrent submitters should clone a
    /// [`Server::submit_handle`].
    pub fn submit(&mut self, id: u64, input: Vec<f32>) -> std::result::Result<usize, SubmitError> {
        self.router.dispatch(Request::new(id, input))
    }

    /// Configure multi-tenant admission: `budgets[t]` is tenant `t`'s
    /// SLO budget (requests carry `arrival + budget` as their deadline;
    /// `None` = best-effort, no deadline sheds) and `est_service[g]` the
    /// per-group service-time estimate the deadline-feasibility rule
    /// multiplies by queue depth ahead (see
    /// [`crate::coordinator::capacity::mock_chain_service_from_fps`] for
    /// deriving it from the capacity model). Rebuilds the router, so
    /// outstanding [`SubmitHandle`]s go stale; the config persists
    /// across [`Server::apply`].
    pub fn set_tenancy(&mut self, budgets: Vec<Option<Duration>>, est_service: Vec<Duration>) {
        self.tenant_budgets = budgets;
        self.est_service_ns = est_service
            .iter()
            .map(|d| d.as_nanos().min(u64::MAX as u128) as u64)
            .collect();
        self.rebuild_router();
    }

    /// Non-blocking submit on behalf of `tenant`: stamps the tenant's
    /// SLO deadline (when configured via [`Server::set_tenancy`]) and
    /// routes only to the groups carrying that tenant's networks. A
    /// tenant with no groups in the current plan gets
    /// [`SubmitError::Closed`].
    pub fn submit_for(
        &mut self,
        tenant: usize,
        id: u64,
        input: Vec<f32>,
    ) -> std::result::Result<usize, SubmitError> {
        let mut req = Request::new(id, input);
        if let Some(&Some(budget)) = self.tenant_budgets.get(tenant) {
            req = req.with_deadline(budget);
        }
        self.router.dispatch_tenant(tenant, req)
    }

    /// Blocking submit: when every group entry is full it parks on the
    /// least loaded group's bounded entry queue (the worker wakes it when
    /// a slot frees) instead of spin-retrying; only terminal shutdown
    /// makes it fail.
    pub fn submit_blocking(
        &mut self,
        id: u64,
        input: Vec<f32>,
    ) -> std::result::Result<usize, SubmitError> {
        self.router.submit_until(Request::new(id, input), None)
    }

    /// Blocking submit with a total-deadline cap: retries under bounded
    /// exponential backoff while the fleet is saturated and returns
    /// [`SubmitError::Timeout`] (request included, retryable) once
    /// `timeout` elapses — so trace replay at overload cannot spin a core
    /// or park forever.
    pub fn submit_within(
        &mut self,
        id: u64,
        input: Vec<f32>,
        timeout: Duration,
    ) -> std::result::Result<usize, SubmitError> {
        self.router.submit_until(Request::new(id, input), Some(Instant::now() + timeout))
    }

    /// A cheaply-cloneable, thread-safe submit endpoint sharing this
    /// server's router and buffer pool. Valid until the next
    /// [`Server::apply`] or [`Server::shutdown`] replaces the router
    /// (stale handles report [`SubmitError::Closed`]).
    pub fn submit_handle(&self) -> SubmitHandle {
        SubmitHandle { core: Arc::downgrade(&self.router), pool: Arc::clone(&self.pool) }
    }

    /// Cumulative hot-path profile: router dispatch counters merged with
    /// the buffer pool's hit/miss/return traffic. Counters are monotone —
    /// diff two snapshots to profile an interval.
    pub fn hot_stats(&self) -> HotPathStats {
        let mut stats = self.counters.snapshot();
        self.pool.merge_into(&mut stats);
        stats
    }

    /// The fleet's shared request-buffer pool (prime it before a
    /// latency-critical run to start in the allocation-free regime).
    pub fn buffer_pool(&self) -> &BufferPool {
        &self.pool
    }

    /// The observability hub (sampler, span pool, flight recorder) this
    /// fleet stamps through.
    pub fn obs(&self) -> &Arc<Obs> {
        &self.obs
    }

    /// Attach a live metrics emitter: [`Server::replay`] drives it on
    /// the arrival loop's clock, and the final snapshot is emitted when
    /// the replay drains.
    pub fn set_exposition(&mut self, e: Exposition) {
        self.exposition = Some(e);
    }

    /// The attached metrics emitter, if any.
    pub fn exposition(&self) -> Option<&Exposition> {
        self.exposition.as_ref()
    }

    /// Attach long-horizon health collection: [`Server::replay`] feeds
    /// the downsampling store and SLO burn alerters on its snapshot
    /// cadence (all ring memory is allocated here, up front).
    pub fn set_health(&mut self, cfg: HealthConfig) {
        self.health = Some(HealthMonitor::new(cfg));
    }

    /// Detach the health monitor, flushing still-open cells, and yield
    /// its journal (for `fcmp healthreport` correlation in-process).
    pub fn take_health(&mut self) -> Option<HealthJournal> {
        self.health.take().map(|mut h| {
            h.finish();
            h.into_journal()
        })
    }

    /// Receive the next completion (blocks until one arrives, or returns
    /// `None` once the fleet has shut down and the stream is drained).
    /// The stream only terminates after [`Server::shutdown`] — a fleet
    /// whose workers all died stays open for [`Server::apply`], so drive
    /// it with [`Server::try_next_completion`] if the backend can fail.
    pub fn next_completion(&self) -> Option<Completion> {
        self.completions.recv().ok()
    }

    /// Receive the next completion, waiting at most `timeout`.
    pub fn try_next_completion(&self, timeout: Duration) -> Option<Completion> {
        match self.completions.recv_timeout(timeout) {
            Ok(c) => Some(c),
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => None,
        }
    }

    /// Open-loop replay of an arrival trace: submits request `i` at
    /// `trace.arrivals_s[i]` (uniform-random synthetic inputs of
    /// `input_len` elements seeded by `seed`), drains completions while
    /// waiting, sheds on overload, and finally waits for every *accepted*
    /// request to complete. The returned [`FleetMetrics`] is shaped to
    /// the current plan, so chain deployments report per-group e2e
    /// percentiles alongside the per-stage breakdown. The server stays
    /// running; callers decide when to [`Server::shutdown`].
    pub fn replay(&mut self, trace: &Trace, input_len: usize, seed: u64) -> FleetMetrics {
        let mut fm = self.replay_inner(trace, None, input_len, seed);
        fm.set_hot(self.hot_stats());
        fm
    }

    /// [`Server::replay`] over a merged multi-tenant trace: `tags[i]` is
    /// the tenant submitting arrival `i` (see [`Trace::merge`]; missing
    /// tags default to tenant 0). Requests carry their tenant's SLO
    /// deadline (when configured via [`Server::set_tenancy`]), route
    /// only to that tenant's groups, and the returned metrics split the
    /// admission counters, latency percentiles and goodput per tenant.
    pub fn replay_tagged(
        &mut self,
        trace: &Trace,
        tags: &[usize],
        input_len: usize,
        seed: u64,
    ) -> FleetMetrics {
        let mut fm = self.replay_inner(trace, Some(tags), input_len, seed);
        fm.set_hot(self.hot_stats());
        fm
    }

    /// The replay loop proper. Payload buffers cycle through the fleet's
    /// [`BufferPool`]: each submit fills a recycled buffer, workers
    /// return input buffers after their batch completes, and drained
    /// completion outputs flow back too — so once the pool is warm the
    /// steady-state submit path allocates nothing per request (the
    /// pool-miss counter in [`Server::hot_stats`] is the proof).
    fn replay_inner(
        &mut self,
        trace: &Trace,
        tags: Option<&[usize]>,
        input_len: usize,
        seed: u64,
    ) -> FleetMetrics {
        let mut rng = Rng::new(seed);
        let mut fm = FleetMetrics::new(&self.group_sizes());
        if tags.is_some() {
            fm.set_tenants((0..self.groups.len()).map(|g| self.plan.tenant_of(g)).collect());
            fm.set_tenant_slos_ms(
                self.tenant_budgets
                    .iter()
                    .map(|b| b.map_or(f64::NAN, |d| d.as_secs_f64() * 1e3))
                    .collect(),
            );
        }
        fm.start();
        let t0 = Instant::now();
        for (i, &due) in trace.arrivals_s.iter().enumerate() {
            loop {
                let now = t0.elapsed().as_secs_f64();
                if now >= due {
                    break;
                }
                let wait = Duration::from_secs_f64((due - now).min(0.005));
                match self.completions.recv_timeout(wait) {
                    Ok(mut c) => {
                        fm.record(&c);
                        self.obs.recycle(c.span.take());
                        self.pool.put(c.output);
                    }
                    // every worker died (panicked backend): nothing will
                    // ever complete, so stop replaying instead of spinning
                    Err(RecvTimeoutError::Timeout) => {
                        if self.all_workers_dead() {
                            return fm;
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => return fm,
                }
            }
            let mut input = self.pool.get(input_len);
            input.extend((0..input_len).map(|_| rng.below(256) as f32));
            let tenant = tags.map(|t| t.get(i).copied().unwrap_or(0));
            let outcome = match tenant {
                Some(t) => self.submit_for(t, i as u64, input),
                None => self.submit(i as u64, input),
            };
            match outcome {
                Ok(_) => match tenant {
                    Some(t) => fm.record_submitted_for(t),
                    None => fm.record_submitted(),
                },
                Err(SubmitError::QueueFull(mut r)) | Err(SubmitError::Timeout(mut r)) => {
                    match tenant {
                        Some(t) => fm.record_shed_for(t),
                        None => fm.record_shed(),
                    }
                    self.shed_window += 1;
                    // a shed request never reached a group; its span (if
                    // sampled) is finalized into the shed ring under the
                    // router's view (group 0)
                    self.obs.shed(r.span.take(), 0);
                    // the shed request's buffer goes straight back
                    self.pool.put(r.input);
                }
                Err(SubmitError::DeadlineInfeasible(mut r)) => {
                    fm.record_deadline_shed(tenant.unwrap_or(0));
                    self.shed_window += 1;
                    self.obs.shed(r.span.take(), 0);
                    self.pool.put(r.input);
                }
                Err(SubmitError::Closed(_)) => return fm,
            }
            self.observe_anomalies();
            let now_s = t0.elapsed().as_secs_f64();
            self.emit_snapshot(&fm, now_s, false);
            self.observe_health(&fm, now_s);
        }
        // drain: every accepted request completes unless a backend fails its
        // batch (never on the mock/PJRT paths), so guard with a stall timeout
        let mut last_progress = Instant::now();
        while fm.completed() < fm.submitted() {
            match self.completions.recv_timeout(Duration::from_millis(50)) {
                Ok(mut c) => {
                    fm.record(&c);
                    self.obs.recycle(c.span.take());
                    self.pool.put(c.output);
                    last_progress = Instant::now();
                }
                Err(RecvTimeoutError::Disconnected) => break,
                Err(RecvTimeoutError::Timeout) => {
                    self.observe_anomalies();
                    let now_s = t0.elapsed().as_secs_f64();
                    self.emit_snapshot(&fm, now_s, false);
                    self.observe_health(&fm, now_s);
                    if self.all_workers_dead()
                        || last_progress.elapsed() > Duration::from_secs(10)
                    {
                        break;
                    }
                }
            }
        }
        // final snapshot: the drained end state, emitted unconditionally
        let now_s = t0.elapsed().as_secs_f64();
        self.emit_snapshot(&fm, now_s, true);
        self.observe_health(&fm, now_s);
        fm
    }

    /// Feed the flight recorder's anomaly detector from replay-loop
    /// state: sheds since the last anomaly flush plus dead chain groups.
    /// The shed window resets whenever a flush fires, so one sustained
    /// overload burst triggers one capture, not one per arrival.
    fn observe_anomalies(&mut self) {
        if !self.obs.active() {
            return;
        }
        let before = self.obs.recorder().flush_count();
        self.obs.recorder().observe(None, self.shed_window, self.dead_groups());
        if self.obs.recorder().flush_count() != before {
            self.shed_window = 0;
        }
    }

    /// Feed the attached health monitor (when due) one snapshot of the
    /// replay's cumulative counters + the merged fleet latency
    /// histogram. The `due` gate keeps the histogram merge off the
    /// steady-state arrival path between samples.
    fn observe_health(&mut self, fm: &FleetMetrics, now_s: f64) {
        let now_ns = (now_s * 1e9) as u64;
        if !self.health.as_ref().is_some_and(|h| h.due(now_ns)) {
            return;
        }
        let hist = fm.latency_histogram();
        if let Some(h) = self.health.as_mut() {
            h.observe(
                now_ns,
                fm.submitted() as u64,
                fm.shed() as u64,
                fm.completed() as u64,
                &hist,
            );
        }
    }

    /// Emit a live metrics snapshot when an emitter is attached and its
    /// interval has elapsed (or `force`d, for the final end-of-replay
    /// state). Gated on [`Exposition::due`] first so the steady-state
    /// arrival path never pays for histogram-merging summary
    /// construction between intervals.
    fn emit_snapshot(&mut self, fm: &FleetMetrics, now_s: f64, force: bool) {
        if !self.exposition.as_ref().is_some_and(|e| force || e.due(now_s)) {
            return;
        }
        let mut hot = self.counters.snapshot();
        self.pool.merge_into(&mut hot);
        let mut s = fm.summary();
        s.hot = hot;
        if let Some(e) = self.exposition.as_mut() {
            e.emit(now_s, &s, None);
        }
    }

    /// Stop accepting requests and wait for every group to drain its
    /// queues. Buffered completions remain readable afterwards; once they
    /// are drained the completion stream terminates (and no further plan
    /// can be [`Server::apply`]d).
    pub fn shutdown(&mut self) {
        let was_open = self.completion_tx.is_some();
        // the router holds clones of every entry sender: swap in a
        // detached core first so the worker channels can actually
        // disconnect once the groups close (outstanding SubmitHandles go
        // Closed, which is exactly the contract)
        self.router = Arc::new(RouterCore::detached(
            self.plan.policy.clone(),
            Arc::clone(&self.counters),
            Arc::clone(&self.obs),
        ));
        for g in &mut self.groups {
            g.close();
        }
        for g in &mut self.groups {
            g.join();
        }
        self.completion_tx = None;
        // final flight-recorder flush: whatever the rings still hold is
        // appended once (Drop re-enters shutdown, hence the gate)
        if was_open && self.obs.active() {
            let _ = self.obs.recorder().flush("shutdown");
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;
    use std::sync::atomic::AtomicUsize;

    /// Mock with failure injection on every k-th batch (per worker).
    struct FlakyMock {
        delay: Duration,
        fail_every: usize,
        calls: AtomicUsize,
    }

    impl InferBackend for FlakyMock {
        fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            let call = self.calls.fetch_add(1, Ordering::SeqCst);
            if self.fail_every > 0 && (call + 1) % self.fail_every == 0 {
                anyhow::bail!("injected failure on call {call}");
            }
            MockBackend::with_service(self.delay, Duration::ZERO).infer_batch(inputs)
        }
    }

    fn single(queue_depth: usize, max_batch: usize) -> Deployment {
        Deployment::replicated(1)
            .with_batcher(BatcherConfig { max_batch, max_wait: Duration::from_millis(1) })
            .with_queue_depth(queue_depth)
    }

    #[test]
    fn end_to_end_all_requests_complete() {
        let mut srv = Server::deploy(|_| MockBackend::instant(), single(64, 4));
        let n = 40;
        for i in 0..n {
            srv.submit_blocking(i, vec![i as f32, 1.0]).unwrap();
        }
        let mut metrics = Metrics::new();
        metrics.start();
        let mut seen = vec![false; n as usize];
        for _ in 0..n {
            let c = srv.next_completion().unwrap();
            assert_eq!(c.output[0], c.id as f32 + 1.0);
            assert_eq!((c.group, c.stage), (0, 0));
            seen[c.id as usize] = true;
            metrics.record(c.latency, c.batch_size);
        }
        assert!(seen.iter().all(|&s| s));
        assert!(metrics.summary().mean_batch >= 1.0);
        srv.shutdown();
    }

    #[test]
    fn batching_actually_batches() {
        let plan = Deployment::replicated(1)
            .with_batcher(BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(20) })
            .with_queue_depth(64);
        let mut srv = Server::deploy(
            |_| MockBackend::with_service(Duration::from_millis(5), Duration::ZERO),
            plan,
        );
        for i in 0..16 {
            srv.submit_blocking(i, vec![1.0]).unwrap();
        }
        let mut max_batch = 0usize;
        for _ in 0..16 {
            let c = srv.next_completion().unwrap();
            max_batch = max_batch.max(c.batch_size);
        }
        assert!(max_batch >= 4, "expected batching, max batch {max_batch}");
        srv.shutdown();
    }

    #[test]
    fn failure_injection_drops_batch_but_server_survives() {
        let mut srv = Server::deploy(
            |_| FlakyMock {
                delay: Duration::ZERO,
                fail_every: 3,
                calls: AtomicUsize::new(0),
            },
            single(64, 1),
        );
        let n = 30;
        for i in 0..n {
            srv.submit_blocking(i, vec![1.0]).unwrap();
        }
        srv.shutdown();
        let mut got = 0;
        while let Some(_c) = srv.next_completion() {
            got += 1;
        }
        // every 3rd single-request batch fails: 10 dropped
        assert_eq!(got, 20, "completions {got}");
    }

    #[test]
    fn backpressure_sheds_with_queue_full() {
        let mut srv = Server::deploy(
            |_| MockBackend::with_service(Duration::from_millis(50), Duration::ZERO),
            single(2, 1),
        );
        // worker is sleeping on the first batch; queue of 2 fills quickly
        let mut rejected = 0;
        for i in 0..20 {
            match srv.submit(i, vec![1.0]) {
                Ok(_) => {}
                Err(e) => {
                    assert!(!e.is_closed(), "open server must shed, not close: {e}");
                    rejected += 1;
                }
            }
        }
        assert!(rejected > 0, "expected admission-control sheds");
    }

    #[test]
    fn chain_traverses_stages_in_order() {
        // 3-stage chain of instant mocks at batch 1: each stage maps
        // [x, ...] -> [sum, 1], so the final output is input + 2 — proof
        // the frame passed through every stage exactly once, in order
        let plan = Deployment::chain(3)
            .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) })
            .with_queue_depth(16);
        let mut srv = Server::deploy(|_| MockBackend::instant(), plan);
        assert_eq!(srv.group_count(), 1);
        assert_eq!(srv.replica_count(), 3);
        for i in 0..20 {
            srv.submit_blocking(i, vec![i as f32]).unwrap();
        }
        srv.shutdown();
        let mut got = 0;
        while let Some(c) = srv.next_completion() {
            got += 1;
            assert_eq!(c.output[0], c.id as f32 + 2.0, "frame {} skipped a stage", c.id);
            assert_eq!(c.group, 0);
            assert_eq!(c.stage, 2, "completions come from the last stage");
            assert_eq!(c.stage_latencies.len(), 3, "one latency per stage");
            let total: Duration = c.stage_latencies.iter().sum();
            assert!(total <= c.latency + Duration::from_millis(5));
        }
        assert_eq!(got, 20, "chain dropped frames");
    }

    #[test]
    fn apply_swaps_fleet_without_losing_completions() {
        let mut srv = Server::deploy(|_| MockBackend::instant(), single(64, 2));
        for i in 0..10 {
            srv.submit_blocking(i, vec![i as f32]).unwrap();
        }
        // grow to a 3-group fleet on the same completion stream
        let plan = Deployment::replicated(3)
            .with_batcher(BatcherConfig { max_batch: 2, max_wait: Duration::from_millis(1) })
            .with_queue_depth(64);
        srv.apply(|_| MockBackend::instant(), plan).unwrap();
        assert_eq!(srv.group_count(), 3);
        for i in 10..30 {
            srv.submit_blocking(i, vec![i as f32]).unwrap();
        }
        srv.shutdown();
        let mut ids = Vec::new();
        while let Some(c) = srv.next_completion() {
            assert_eq!(c.output[0], c.id as f32 + 1.0);
            ids.push(c.id);
        }
        ids.sort_unstable();
        assert_eq!(ids, (0..30).collect::<Vec<u64>>(), "swap lost completions");
    }

    #[test]
    fn apply_keeps_unchanged_groups_running_without_respawn() {
        // count backend constructions: a kept group must not rebuild one
        static BUILDS: AtomicUsize = AtomicUsize::new(0);
        let plan = |n: usize| {
            let mut p = Deployment::replicated(n).with_queue_depth(16);
            for (g, grp) in p.groups.iter_mut().enumerate() {
                grp.tag = Some(format!("keep-{g}"));
            }
            p
        };
        let mut srv = Server::deploy(
            |_| {
                BUILDS.fetch_add(1, Ordering::SeqCst);
                MockBackend::instant()
            },
            plan(2),
        );
        // give the spawned workers time to run their factories
        srv.submit_blocking(0, vec![1.0]).unwrap();
        srv.submit_blocking(1, vec![1.0]).unwrap();
        let _ = srv.next_completion();
        let _ = srv.next_completion();
        let before = BUILDS.load(Ordering::SeqCst);
        assert!(before >= 2, "two workers must have built backends");
        // a live retune on group 0 must survive the apply below
        let tuned = BatcherConfig { max_batch: 11, max_wait: Duration::from_micros(900) };
        assert!(srv.set_batcher(0, 0, tuned));
        // same tags + one new group: only the new group spawns a backend
        srv.apply(
            |_| {
                BUILDS.fetch_add(1, Ordering::SeqCst);
                MockBackend::instant()
            },
            plan(3),
        )
        .unwrap();
        assert_eq!(srv.group_count(), 3);
        srv.submit_blocking(2, vec![1.0]).unwrap();
        let _ = srv.next_completion();
        let after = BUILDS.load(Ordering::SeqCst);
        assert!(
            after <= before + 1,
            "kept groups respawned backends: {before} -> {after}"
        );
        assert_eq!(srv.batcher_config(0, 0), Some(tuned), "live retune lost across apply");
        srv.shutdown();
    }

    #[test]
    fn apply_respawns_a_dead_group_even_when_the_spec_matches() {
        // group 1's first backend construction panics, killing its worker;
        // re-applying the *identical* plan is the recovery action and must
        // respawn the dead group rather than keep the corpse as a "match"
        static G1_BUILDS: AtomicUsize = AtomicUsize::new(0);
        let plan = || {
            let mut p = Deployment::replicated(2).with_queue_depth(8);
            for (g, grp) in p.groups.iter_mut().enumerate() {
                grp.tag = Some(format!("heal-{g}"));
            }
            p
        };
        let factory = |id: crate::coordinator::WorkerId| {
            if id.group == 1 && G1_BUILDS.fetch_add(1, Ordering::SeqCst) == 0 {
                panic!("injected backend construction failure");
            }
            MockBackend::instant()
        };
        let mut srv = Server::deploy(factory, plan());
        // let group 1's worker thread hit the panic
        std::thread::sleep(Duration::from_millis(100));
        srv.apply(factory, plan()).unwrap();
        for i in 0..20 {
            srv.submit_blocking(i, vec![1.0]).unwrap();
        }
        srv.shutdown();
        let mut per_group = [0usize; 2];
        while let Some(c) = srv.next_completion() {
            per_group[c.group] += 1;
        }
        assert_eq!(per_group[0] + per_group[1], 20);
        assert!(per_group[1] > 0, "dead group was kept, not respawned: {per_group:?}");
    }

    #[test]
    fn apply_repositions_kept_groups_completion_stamps() {
        // group tagged "b" starts at position 1 and moves to position 0:
        // completions after the apply must carry the new group index
        let mk = |tags: &[&str]| {
            let mut p = Deployment::replicated(tags.len()).with_queue_depth(16);
            for (g, grp) in p.groups.iter_mut().enumerate() {
                grp.tag = Some(tags[g].to_string());
            }
            p
        };
        let mut srv = Server::deploy(|_| MockBackend::instant(), mk(&["a", "b"]));
        srv.apply(|_| MockBackend::instant(), mk(&["b"])).unwrap();
        assert_eq!(srv.group_count(), 1);
        srv.submit_blocking(7, vec![1.0]).unwrap();
        srv.shutdown();
        let c = srv.next_completion().expect("completion");
        assert_eq!(c.group, 0, "kept group must stamp its new position");
    }

    #[test]
    fn apply_after_shutdown_is_an_error() {
        let mut srv = Server::deploy(|_| MockBackend::instant(), single(8, 1));
        srv.shutdown();
        let err = srv.apply(|_| MockBackend::instant(), single(8, 1));
        assert!(err.is_err(), "applying to a shut-down server must fail");
    }

    #[test]
    fn apply_splices_a_new_chain_length() {
        let plan = |k: usize| {
            Deployment::chain(k)
                .with_batcher(BatcherConfig {
                    max_batch: 1,
                    max_wait: Duration::from_millis(1),
                })
                .with_queue_depth(16)
        };
        let mut srv = Server::deploy(|_| MockBackend::instant(), plan(3));
        for i in 0..10 {
            srv.submit_blocking(i, vec![i as f32]).unwrap();
        }
        // splice down to a 2-stage chain (one device lost, plan repaired)
        srv.apply(|_| MockBackend::instant(), plan(2)).unwrap();
        assert_eq!(srv.replica_count(), 2);
        for i in 100..110 {
            srv.submit_blocking(i, vec![i as f32]).unwrap();
        }
        srv.shutdown();
        let mut pre = 0;
        let mut post = 0;
        while let Some(c) = srv.next_completion() {
            if c.id < 100 {
                // old plan: 3 stages, each adding +1 after the first
                assert_eq!(c.output[0], c.id as f32 + 2.0);
                pre += 1;
            } else {
                // new plan: 2 stages
                assert_eq!(c.output[0], c.id as f32 + 1.0);
                post += 1;
            }
        }
        assert_eq!((pre, post), (10, 10), "splice dropped frames");
    }

    #[test]
    fn live_batcher_retune_roundtrips() {
        let srv = Server::deploy(|_| MockBackend::instant(), single(8, 4));
        let cur = srv.batcher_config(0, 0).unwrap();
        assert_eq!(cur.max_batch, 4);
        let next = BatcherConfig { max_batch: 9, max_wait: Duration::from_micros(700) };
        assert!(srv.set_batcher(0, 0, next));
        let got = srv.batcher_config(0, 0).unwrap();
        assert_eq!(got.max_batch, 9);
        assert_eq!(got.max_wait, Duration::from_micros(700));
        assert!(!srv.set_batcher(5, 0, next), "out-of-range group must report false");
        assert!(!srv.set_batcher(0, 3, next), "out-of-range stage must report false");
        assert!(srv.batcher_config(5, 0).is_none());
    }

    #[test]
    fn full_sibling_does_not_shed_while_another_group_has_room() {
        // group 0 is blocked for a long time; round-robin would prefer it
        // every other request, but the router falls through to group 1
        let plan = Deployment::replicated(2)
            .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(0) })
            .with_queue_depth(1);
        let mut srv = Server::deploy(
            |id| {
                if id.group == 0 {
                    MockBackend::with_service(Duration::from_millis(300), Duration::ZERO)
                } else {
                    MockBackend::instant()
                }
            },
            plan,
        );
        let mut ok = 0;
        for i in 0..12 {
            if srv.submit(i, vec![1.0]).is_ok() {
                ok += 1;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        // group 0 absorbs at most 2 (1 executing + 1 queued); the rest
        // must overflow to group 1 instead of shedding
        assert!(ok >= 10, "only {ok}/12 accepted");
    }

    #[test]
    fn replicated_chains_serve_all_groups_end_to_end() {
        // 2 groups × 2 stages: every frame traverses exactly one group's
        // two stages (output = input + 1) and both groups serve under
        // round-robin
        let plan = Deployment::replicated_chains(2, 2)
            .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) })
            .with_queue_depth(32);
        let mut srv = Server::deploy(|_| MockBackend::instant(), plan);
        assert_eq!(srv.group_count(), 2);
        assert_eq!(srv.replica_count(), 4);
        let n = 40u64;
        for i in 0..n {
            srv.submit_blocking(i, vec![i as f32]).unwrap();
        }
        srv.shutdown();
        let mut per_group = [0usize; 2];
        let mut got = 0;
        while let Some(c) = srv.next_completion() {
            got += 1;
            assert_eq!(c.output[0], c.id as f32 + 1.0, "frame {} broke its chain", c.id);
            assert_eq!(c.stage, 1, "completions come from the last stage");
            assert_eq!(c.stage_latencies.len(), 2);
            per_group[c.group] += 1;
        }
        assert_eq!(got, n as usize, "replicated chains dropped frames");
        assert!(per_group[0] > 0 && per_group[1] > 0, "a group idled: {per_group:?}");
    }

    #[test]
    fn submit_error_is_anyhow_compatible() {
        // the satellite contract: callers can `?` a SubmitError into
        // anyhow::Result instead of pattern-matching
        fn shed() -> anyhow::Result<()> {
            Err(SubmitError::QueueFull(Request::new(3, vec![])))?;
            Ok(())
        }
        let err = shed().unwrap_err();
        assert!(format!("{err}").contains("request 3"), "{err}");
        assert!(format!("{err}").contains("shed"), "{err}");
        // the timeout variant is retryable, not terminal
        let t = SubmitError::Timeout(Request::new(9, vec![]));
        assert!(!t.is_closed());
        assert_eq!(t.into_request().id, 9);
    }

    #[test]
    fn batch_handle_flavors_report_readiness() {
        let h = BatchHandle::ready(Ok(vec![vec![1.0]]));
        assert!(h.is_ready());
        assert_eq!(h.eta(), Some(Duration::ZERO));
        assert_eq!(h.wait().unwrap(), vec![vec![1.0]]);
        let h = BatchHandle::completes_at(
            Instant::now() + Duration::from_millis(40),
            Ok(vec![vec![3.0]]),
        );
        assert!(!h.is_ready());
        assert!(h.eta().unwrap() > Duration::ZERO);
        let t0 = Instant::now();
        assert_eq!(h.wait().unwrap(), vec![vec![3.0]]);
        assert!(t0.elapsed() >= Duration::from_millis(35), "wait returned early");
        let h = BatchHandle::wait_with(|| Ok(vec![vec![2.0]]));
        assert!(!h.is_ready(), "Wait handles are conservatively never ready");
        assert!(h.eta().is_none());
        assert_eq!(h.wait().unwrap(), vec![vec![2.0]]);
    }

    #[test]
    fn pipelined_mock_overlaps_compute_with_the_next_transfer() {
        let be = PipelinedMockBackend::overlapped(
            Duration::from_millis(10),
            Duration::from_millis(10),
        );
        // two back-to-back submits: batch 2's transfer overlaps batch 1's
        // compute, so the pair finishes in ~3 legs (30ms), not 4 (40ms)
        let t0 = Instant::now();
        let h1 = be.submit_batch(&[vec![1.0]]).unwrap();
        let h2 = be.submit_batch(&[vec![2.0]]).unwrap();
        assert_eq!(h1.wait().unwrap()[0], vec![1.0, 1.0]);
        assert_eq!(h2.wait().unwrap()[0], vec![2.0, 1.0]);
        let elapsed = t0.elapsed();
        assert!(elapsed >= Duration::from_millis(28), "finished too fast: {elapsed:?}");
        assert!(elapsed < Duration::from_millis(38), "no overlap happened: {elapsed:?}");
        // the blocking path is strictly sequential
        let t1 = Instant::now();
        be.infer_batch(&[vec![1.0]]).unwrap();
        assert!(t1.elapsed() >= Duration::from_millis(19));
    }

    #[test]
    fn submit_within_times_out_under_saturation_and_keeps_the_request() {
        let mut srv = Server::deploy(
            |_| MockBackend::with_service(Duration::from_millis(300), Duration::ZERO),
            single(1, 1),
        );
        // saturate: one batch executing plus a queue of depth 1
        let mut accepted = 0;
        for i in 0..10 {
            if srv.submit(i, vec![1.0]).is_ok() {
                accepted += 1;
            }
        }
        assert!(accepted >= 1);
        let t0 = Instant::now();
        match srv.submit_within(99, vec![7.0], Duration::from_millis(40)) {
            Err(SubmitError::Timeout(r)) => {
                assert_eq!(r.id, 99, "timeout must hand the request back");
                assert_eq!(r.input, vec![7.0]);
            }
            Ok(_) => panic!("saturated fleet accepted within the deadline"),
            Err(other) => panic!("expected Timeout, got {other}"),
        }
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(40), "gave up early: {waited:?}");
        assert!(
            waited < Duration::from_millis(250),
            "timed-out submit waited for service completion: {waited:?}"
        );
        let stats = srv.hot_stats();
        assert!(stats.backoff_sleeps > 0, "deadline path must back off, not spin");
    }

    #[test]
    fn tenant_submits_route_only_to_their_own_groups() {
        let mut plan = Deployment::replicated(2).with_queue_depth(64);
        plan.groups[1].tenant = 1;
        let mut srv = Server::deploy(|_| MockBackend::instant(), plan);
        for i in 0..10 {
            assert_eq!(srv.submit_for(0, i, vec![1.0]).unwrap(), 0);
            assert_eq!(srv.submit_for(1, 100 + i, vec![1.0]).unwrap(), 1);
        }
        // a tenant with no groups in the plan is Closed, not shed
        match srv.submit_for(7, 999, vec![1.0]) {
            Err(SubmitError::Closed(r)) => assert_eq!(r.id, 999),
            other => {
                panic!("tenant without groups must be Closed, got ok={:?}", other.is_ok())
            }
        }
        srv.shutdown();
        let mut per_group = [0usize; 2];
        while let Some(c) = srv.next_completion() {
            per_group[c.group] += 1;
        }
        assert_eq!(per_group, [10, 10], "tenant traffic crossed group boundaries");
    }

    #[test]
    fn infeasible_deadline_sheds_before_occupying_a_queue_slot() {
        let mut plan = Deployment::replicated(2).with_queue_depth(64);
        plan.groups[1].tenant = 1;
        let mut srv = Server::deploy(|_| MockBackend::instant(), plan);
        // tenant 1's 1ms budget cannot cover the estimated 50ms service;
        // tenant 0 stays best-effort (no deadline)
        srv.set_tenancy(
            vec![None, Some(Duration::from_millis(1))],
            vec![Duration::from_millis(50), Duration::from_millis(50)],
        );
        match srv.submit_for(1, 1, vec![1.0]) {
            Err(SubmitError::DeadlineInfeasible(r)) => {
                assert_eq!(r.id, 1);
                assert!(!SubmitError::DeadlineInfeasible(r).is_closed());
            }
            other => panic!("want DeadlineInfeasible, got ok={:?}", other.is_ok()),
        }
        // one tenant's infeasibility never touches the other's admission
        assert_eq!(srv.submit_for(0, 2, vec![1.0]).unwrap(), 0);
        assert_eq!(srv.hot_stats().deadline_sheds, 1);
        srv.shutdown();
    }

    #[test]
    fn tagged_replay_splits_metrics_per_tenant() {
        let mut plan = Deployment::replicated(2).with_queue_depth(256);
        plan.groups[1].tenant = 1;
        let mut srv = Server::deploy(|_| MockBackend::instant(), plan);
        srv.set_tenancy(
            vec![Some(Duration::from_millis(250)), Some(Duration::from_millis(250))],
            vec![Duration::from_micros(10), Duration::from_micros(10)],
        );
        let a = crate::coordinator::workload::uniform(30, 2000.0);
        let b = crate::coordinator::workload::uniform(20, 1500.0);
        let (merged, tags) = Trace::merge(&[(0, &a), (1, &b)]);
        let fm = srv.replay_tagged(&merged, &tags, 4, 7);
        let s = fm.summary();
        assert_eq!(s.per_tenant.len(), 2);
        assert_eq!(s.per_tenant[0].submitted + s.per_tenant[0].shed, 30);
        assert_eq!(s.per_tenant[1].submitted + s.per_tenant[1].shed, 20);
        // generous budgets + instant mocks: everything lands in SLO
        assert_eq!(s.per_tenant[0].goodput, s.per_tenant[0].completed);
        assert_eq!(s.per_tenant[1].goodput, s.per_tenant[1].completed);
        assert_eq!(s.per_tenant[0].slo_ms, Some(250.0));
        srv.shutdown();
    }

    #[test]
    fn submit_handle_is_concurrent_and_goes_closed_after_shutdown() {
        let mut srv = Server::deploy(|_| MockBackend::instant(), single(256, 4));
        let handle = srv.submit_handle();
        let threads: Vec<_> = (0..4u64)
            .map(|t| {
                let h = handle.clone();
                std::thread::spawn(move || {
                    let mut ok = 0usize;
                    for i in 0..50u64 {
                        if h.submit_blocking(t * 1000 + i, vec![1.0]).is_ok() {
                            ok += 1;
                        }
                    }
                    ok
                })
            })
            .collect();
        let accepted: usize = threads.into_iter().map(|t| t.join().unwrap()).sum();
        assert_eq!(accepted, 200, "live handle must accept every blocking submit");
        let mut got = 0;
        for _ in 0..accepted {
            assert!(srv.next_completion().is_some());
            got += 1;
        }
        assert_eq!(got, 200);
        srv.shutdown();
        match handle.submit(9999, vec![1.0]) {
            Err(SubmitError::Closed(r)) => assert_eq!(r.id, 9999),
            other => panic!("stale handle must be Closed, got {:?}", other.is_ok()),
        }
    }
}
