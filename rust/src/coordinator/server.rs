//! The serving loop: router queue → dynamic batcher → worker thread that
//! owns the inference backend → completion stream → metrics.
//!
//! The backend is a trait so tests can run the full coordination path with
//! a mock (no PJRT); `examples/serve_cifar.rs` plugs in the real
//! [`crate::runtime::Engine`].

use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::thread::JoinHandle;
use std::time::Instant;

use super::batcher::{next_batch, BatcherConfig};
use super::{Completion, Request};
use crate::Result;

/// Anything that can run a batch of inputs. The backend is constructed
/// *inside* the worker thread (PJRT handles are not `Send`), so only the
/// factory closure crosses threads.
pub trait InferBackend: 'static {
    fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;
}

impl InferBackend for crate::runtime::Engine {
    fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.infer(inputs)
    }
}

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    /// Router queue bound (backpressure: submit fails when full).
    pub queue_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { batcher: BatcherConfig::default(), queue_depth: 256 }
    }
}

/// A running inference server (single worker owning the engine).
pub struct Server {
    tx: Option<SyncSender<Request>>,
    completions: Receiver<Completion>,
    worker: Option<JoinHandle<()>>,
}

// completions are unbounded: backpressure belongs on the *request* queue;
// a bounded completion channel can deadlock shutdown (worker blocks on
// send while the owner blocks on join without draining)
type CompletionTx = Sender<Completion>;

impl Server {
    /// Spawn the worker thread; `make_backend` runs on the worker (PJRT
    /// engines are thread-affine) and a panic there surfaces on first use.
    pub fn start<B, F>(make_backend: F, cfg: ServerConfig) -> Server
    where
        B: InferBackend,
        F: FnOnce() -> B + Send + 'static,
    {
        let (tx, rx) = sync_channel::<Request>(cfg.queue_depth);
        let (ctx, crx): (CompletionTx, _) = channel();
        let batcher = cfg.batcher;
        let worker = std::thread::Builder::new()
            .name("fcmp-worker".into())
            .spawn(move || {
                let backend = make_backend();
                while let Some(mut batch) = next_batch(&rx, &batcher) {
                    // move inputs out (no per-request copy on the hot path)
                    let inputs: Vec<Vec<f32>> = batch
                        .requests
                        .iter_mut()
                        .map(|r| std::mem::take(&mut r.input))
                        .collect();
                    match backend.infer_batch(&inputs) {
                        Ok(outputs) => {
                            let n = batch.requests.len();
                            for (req, output) in batch.requests.into_iter().zip(outputs) {
                                let _ = ctx.send(Completion {
                                    id: req.id,
                                    output,
                                    latency: req.arrival.elapsed(),
                                    batch_size: n,
                                });
                            }
                        }
                        Err(e) => {
                            // failure injection path: drop the batch but keep
                            // serving; completions for it never appear
                            eprintln!("worker: batch failed: {e:#}");
                        }
                    }
                }
            })
            .expect("spawn worker");
        Server { tx: Some(tx), completions: crx, worker: Some(worker) }
    }

    /// Submit a request; `Err` means the queue is full (backpressure) or
    /// the server is shutting down.
    pub fn submit(&self, id: u64, input: Vec<f32>) -> std::result::Result<(), Request> {
        let req = Request { id, input, arrival: Instant::now() };
        match self.tx.as_ref() {
            None => Err(req),
            Some(tx) => match tx.try_send(req) {
                Ok(()) => Ok(()),
                Err(TrySendError::Full(r)) | Err(TrySendError::Disconnected(r)) => Err(r),
            },
        }
    }

    /// Blocking submit (waits for queue space).
    pub fn submit_blocking(&self, id: u64, input: Vec<f32>) -> Result<()> {
        let req = Request { id, input, arrival: Instant::now() };
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("server closed"))?
            .send(req)
            .map_err(|_| anyhow::anyhow!("worker gone"))
    }

    /// Receive the next completion (blocks until one arrives or the worker
    /// exits after shutdown).
    pub fn next_completion(&self) -> Option<Completion> {
        self.completions.recv().ok()
    }

    /// Stop accepting requests; the worker drains the queue and exits.
    pub fn shutdown(&mut self) {
        self.tx = None;
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;
    use std::time::Duration;

    /// Mock backend: output = input sum + batch-size marker; optional
    /// failure injection on a chosen batch index.
    struct Mock {
        delay: Duration,
        fail_every: Option<usize>,
        calls: std::sync::atomic::AtomicUsize,
    }

    impl InferBackend for Mock {
        fn infer_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            let call = self.calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            if let Some(k) = self.fail_every {
                if k > 0 && (call + 1) % k == 0 {
                    anyhow::bail!("injected failure on call {call}");
                }
            }
            std::thread::sleep(self.delay);
            Ok(inputs
                .iter()
                .map(|x| vec![x.iter().sum::<f32>(), inputs.len() as f32])
                .collect())
        }
    }

    fn mock(delay_ms: u64, fail_every: Option<usize>) -> Mock {
        Mock {
            delay: Duration::from_millis(delay_ms),
            fail_every,
            calls: std::sync::atomic::AtomicUsize::new(0),
        }
    }

    #[test]
    fn end_to_end_all_requests_complete() {
        let cfg = ServerConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            queue_depth: 64,
        };
        let mut srv = Server::start(|| mock(0, None), cfg);
        let n = 40;
        for i in 0..n {
            srv.submit_blocking(i, vec![i as f32, 1.0]).unwrap();
        }
        let mut metrics = Metrics::new();
        metrics.start();
        let mut seen = vec![false; n as usize];
        for _ in 0..n {
            let c = srv.next_completion().unwrap();
            assert_eq!(c.output[0], c.id as f32 + 1.0);
            seen[c.id as usize] = true;
            metrics.record(c.latency, c.batch_size);
        }
        assert!(seen.iter().all(|&s| s));
        let s = metrics.summary();
        assert!(s.mean_batch >= 1.0);
        srv.shutdown();
    }

    #[test]
    fn batching_actually_batches() {
        let cfg = ServerConfig {
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(20) },
            queue_depth: 64,
        };
        let mut srv = Server::start(|| mock(5, None), cfg);
        for i in 0..16 {
            srv.submit_blocking(i, vec![1.0]).unwrap();
        }
        let mut max_batch = 0usize;
        for _ in 0..16 {
            let c = srv.next_completion().unwrap();
            max_batch = max_batch.max(c.batch_size);
        }
        assert!(max_batch >= 4, "expected batching, max batch {max_batch}");
        srv.shutdown();
    }

    #[test]
    fn failure_injection_drops_batch_but_server_survives() {
        let cfg = ServerConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(0) },
            queue_depth: 64,
        };
        let mut srv = Server::start(|| mock(0, Some(3)), cfg);
        let n = 30;
        for i in 0..n {
            srv.submit_blocking(i, vec![1.0]).unwrap();
        }
        srv.tx = None; // stop accepting; worker drains
        let mut got = 0;
        while let Some(_c) = srv.next_completion() {
            got += 1;
        }
        // every 3rd single-request batch fails: 10 dropped
        assert_eq!(got, 20, "completions {got}");
        srv.shutdown();
    }

    #[test]
    fn backpressure_on_full_queue() {
        let cfg = ServerConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(0) },
            queue_depth: 2,
        };
        let srv = Server::start(|| mock(50, None), cfg);
        // worker is sleeping on the first batch; queue of 2 fills quickly
        let mut rejected = 0;
        for i in 0..20 {
            if srv.submit(i, vec![1.0]).is_err() {
                rejected += 1;
            }
        }
        assert!(rejected > 0, "expected backpressure rejections");
    }
}
