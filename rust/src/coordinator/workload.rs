//! Workload generators for serving experiments: open-loop Poisson arrivals,
//! bursty (on/off) traffic, and a closed-loop (fixed-concurrency) driver
//! model. Deterministic via the crate PRNG.

use crate::util::rng::Rng;

/// An arrival trace: request release times in seconds from t=0.
#[derive(Clone, Debug)]
pub struct Trace {
    pub arrivals_s: Vec<f64>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.arrivals_s.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals_s.is_empty()
    }

    /// Mean offered rate (req/s) over the trace span.
    pub fn offered_rate(&self) -> f64 {
        if self.arrivals_s.len() < 2 {
            return 0.0;
        }
        let span = self.arrivals_s.last().unwrap() - self.arrivals_s[0];
        (self.arrivals_s.len() - 1) as f64 / span.max(1e-9)
    }
}

/// Open-loop Poisson arrivals at `rate` req/s.
pub fn poisson(n: usize, rate: f64, seed: u64) -> Trace {
    assert!(rate > 0.0);
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut arrivals = Vec::with_capacity(n);
    for _ in 0..n {
        t += rng.exp(rate);
        arrivals.push(t);
    }
    Trace { arrivals_s: arrivals }
}

/// Bursty on/off traffic: `burst_len` back-to-back requests at `peak_rate`,
/// then an idle gap so the long-run average is `avg_rate`.
pub fn bursty(n: usize, avg_rate: f64, peak_rate: f64, burst_len: usize, seed: u64) -> Trace {
    assert!(peak_rate >= avg_rate && burst_len >= 1);
    let mut rng = Rng::new(seed);
    let mut arrivals = Vec::with_capacity(n);
    let mut t = 0.0;
    let burst_span = burst_len as f64 / peak_rate;
    let period = burst_len as f64 / avg_rate;
    while arrivals.len() < n {
        let burst_start = t + rng.f64() * 0.1 * period; // jitter
        for i in 0..burst_len {
            if arrivals.len() >= n {
                break;
            }
            arrivals.push(burst_start + i as f64 / peak_rate);
        }
        t = burst_start + period.max(burst_span);
    }
    Trace { arrivals_s: arrivals }
}

/// Uniform (fixed-interval) arrivals — the closed-form baseline.
pub fn uniform(n: usize, rate: f64) -> Trace {
    Trace { arrivals_s: (0..n).map(|i| i as f64 / rate).collect() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_converges() {
        let t = poisson(20_000, 250.0, 1);
        assert!((t.offered_rate() - 250.0).abs() / 250.0 < 0.05, "{}", t.offered_rate());
        // strictly increasing
        assert!(t.arrivals_s.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn poisson_deterministic_per_seed() {
        assert_eq!(poisson(100, 10.0, 7).arrivals_s, poisson(100, 10.0, 7).arrivals_s);
        assert_ne!(poisson(100, 10.0, 7).arrivals_s, poisson(100, 10.0, 8).arrivals_s);
    }

    #[test]
    fn bursty_preserves_average_rate() {
        let t = bursty(5_000, 50.0, 500.0, 20, 3);
        assert!((t.offered_rate() - 50.0).abs() / 50.0 < 0.2, "{}", t.offered_rate());
    }

    #[test]
    fn bursty_has_peaks() {
        let t = bursty(1_000, 50.0, 500.0, 25, 4);
        // within a burst, inter-arrival = 1/peak
        let min_gap = t
            .arrivals_s
            .windows(2)
            .map(|w| w[1] - w[0])
            .fold(f64::INFINITY, f64::min);
        assert!(min_gap < 1.5 / 500.0, "min gap {min_gap}");
    }

    #[test]
    fn uniform_exact() {
        let t = uniform(11, 100.0);
        assert_eq!(t.len(), 11);
        assert!((t.offered_rate() - 100.0).abs() < 1e-9);
    }
}
