//! Workload generators for serving experiments: open-loop Poisson arrivals,
//! bursty (on/off) traffic, heavy-tailed (Pareto inter-arrival) traffic,
//! a diurnal (rate-modulated Poisson) day/night cycle, a flash-crowd
//! step/burst (the autoscaler stressor), and a fixed-interval baseline.
//! Deterministic via the crate PRNG.
//!
//! Traces also round-trip to disk ([`Trace::save`] / [`Trace::load`]) in a
//! one-arrival-per-line text format, so captures of real traffic can drive
//! `fcmp serve --trace file:PATH` and the `serve_scaling` /
//! `shard_scaling` benches.

use std::path::Path;

use crate::util::rng::Rng;

/// An arrival trace: request release times in seconds from t=0.
#[derive(Clone, Debug)]
pub struct Trace {
    pub arrivals_s: Vec<f64>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.arrivals_s.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals_s.is_empty()
    }

    /// Mean offered rate (req/s) over the trace span.
    pub fn offered_rate(&self) -> f64 {
        if self.arrivals_s.len() < 2 {
            return 0.0;
        }
        let span = self.arrivals_s.last().unwrap() - self.arrivals_s[0];
        (self.arrivals_s.len() - 1) as f64 / span.max(1e-9)
    }

    /// Write the trace as `fcmp-trace v1`: a comment header followed by
    /// one arrival time (seconds, 9 decimal places) per line.
    pub fn save(&self, path: &Path) -> crate::Result<()> {
        let mut out = String::with_capacity(self.arrivals_s.len() * 14 + 32);
        out.push_str("# fcmp-trace v1\n");
        for t in &self.arrivals_s {
            out.push_str(&format!("{t:.9}\n"));
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    /// Read a trace written by [`Trace::save`] (or any text file with one
    /// arrival-second per line; `#` comments and blank lines are ignored).
    /// Arrivals must be non-decreasing — replay submits them in order.
    pub fn load(path: &Path) -> crate::Result<Trace> {
        let text = std::fs::read_to_string(path)?;
        let mut arrivals = Vec::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let t: f64 = line.parse().map_err(|_| {
                anyhow::anyhow!("{}:{}: bad arrival time {line:?}", path.display(), ln + 1)
            })?;
            anyhow::ensure!(
                t.is_finite() && t >= 0.0,
                "{}:{}: arrival must be finite and non-negative",
                path.display(),
                ln + 1
            );
            arrivals.push(t);
        }
        anyhow::ensure!(
            arrivals.windows(2).all(|w| w[1] >= w[0]),
            "{}: arrivals must be non-decreasing",
            path.display()
        );
        Ok(Trace { arrivals_s: arrivals })
    }

    /// Deterministically interleave per-tenant traces into one merged
    /// trace by timestamp, tagging every merged arrival with the tenant
    /// it came from. Ties break by tenant id (stable), and each tenant's
    /// own arrival order is preserved exactly — a k-way stable merge, so
    /// the result is a pure function of the inputs (seed-reproducible
    /// whenever the inputs are). Returns the merged trace plus a parallel
    /// `tenant_of[i]` vector.
    pub fn merge(parts: &[(usize, &Trace)]) -> (Trace, Vec<usize>) {
        let total: usize = parts.iter().map(|(_, t)| t.len()).sum();
        let mut arrivals = Vec::with_capacity(total);
        let mut tenants = Vec::with_capacity(total);
        // cursor per part; pick the (time, tenant, part-order) minimum
        let mut cursors = vec![0usize; parts.len()];
        for _ in 0..total {
            let mut best: Option<usize> = None;
            for (p, &(tenant, trace)) in parts.iter().enumerate() {
                let c = cursors[p];
                if c >= trace.len() {
                    continue;
                }
                let t = trace.arrivals_s[c];
                let better = match best {
                    None => true,
                    Some(b) => {
                        let (bt, btr) = (parts[b].1.arrivals_s[cursors[b]], parts[b].0);
                        t < bt || (t == bt && tenant < btr)
                    }
                };
                if better {
                    best = Some(p);
                }
            }
            let p = best.expect("total counted a remaining arrival");
            arrivals.push(parts[p].1.arrivals_s[cursors[p]]);
            tenants.push(parts[p].0);
            cursors[p] += 1;
        }
        (Trace { arrivals_s: arrivals }, tenants)
    }
}

/// Open-loop Poisson arrivals at `rate` req/s.
pub fn poisson(n: usize, rate: f64, seed: u64) -> Trace {
    assert!(rate > 0.0);
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut arrivals = Vec::with_capacity(n);
    for _ in 0..n {
        t += rng.exp(rate);
        arrivals.push(t);
    }
    Trace { arrivals_s: arrivals }
}

/// Bursty on/off traffic: `burst_len` back-to-back requests at `peak_rate`,
/// then an idle gap so the long-run average is `avg_rate`.
pub fn bursty(n: usize, avg_rate: f64, peak_rate: f64, burst_len: usize, seed: u64) -> Trace {
    assert!(peak_rate >= avg_rate && burst_len >= 1);
    let mut rng = Rng::new(seed);
    let mut arrivals = Vec::with_capacity(n);
    let mut t = 0.0;
    let burst_span = burst_len as f64 / peak_rate;
    let period = burst_len as f64 / avg_rate;
    while arrivals.len() < n {
        let burst_start = t + rng.f64() * 0.1 * period; // jitter
        for i in 0..burst_len {
            if arrivals.len() >= n {
                break;
            }
            arrivals.push(burst_start + i as f64 / peak_rate);
        }
        t = burst_start + period.max(burst_span);
    }
    Trace { arrivals_s: arrivals }
}

/// Heavy-tailed arrivals: Pareto(`alpha`) inter-arrival gaps scaled so the
/// long-run rate is `rate`. `alpha <= 2` has infinite variance — the
/// serving story's worst case: long quiet stretches punctuated by deep
/// backlogs that stress admission control far harder than Poisson traffic.
/// Requires `alpha > 1` (finite mean, so the rate normalization exists).
pub fn heavy_tail(n: usize, rate: f64, alpha: f64, seed: u64) -> Trace {
    assert!(rate > 0.0 && alpha > 1.0);
    let mut rng = Rng::new(seed);
    // Pareto with x_m = 1 has mean alpha/(alpha-1); scale gaps to `rate`
    let mean_raw = alpha / (alpha - 1.0);
    let scale = 1.0 / (rate * mean_raw);
    let mut t = 0.0;
    let mut arrivals = Vec::with_capacity(n);
    for _ in 0..n {
        let u = loop {
            let u = rng.f64();
            if u > 0.0 {
                break u;
            }
        };
        // inverse CDF: x = x_m * u^(-1/alpha) for u uniform in (0, 1]
        t += scale * u.powf(-1.0 / alpha);
        arrivals.push(t);
    }
    Trace { arrivals_s: arrivals }
}

/// Uniform (fixed-interval) arrivals — the closed-form baseline.
pub fn uniform(n: usize, rate: f64) -> Trace {
    Trace { arrivals_s: (0..n).map(|i| i as f64 / rate).collect() }
}

/// Diurnal traffic: a non-homogeneous Poisson process whose instantaneous
/// rate swings sinusoidally between `base_rate` (night trough) and
/// `peak_rate` (day peak) with period `period_s`, via Lewis–Shedler
/// thinning of a `peak_rate` Poisson stream. The day/night cycle is the
/// canonical serving-capacity planning input: autoscaling and SLO
/// experiments need load that *drifts* rather than bursts.
pub fn diurnal(n: usize, base_rate: f64, peak_rate: f64, period_s: f64, seed: u64) -> Trace {
    assert!(base_rate > 0.0 && peak_rate >= base_rate && period_s > 0.0);
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut arrivals = Vec::with_capacity(n);
    while arrivals.len() < n {
        t += rng.exp(peak_rate);
        // phase 0..1: trough at t=0, peak at period/2
        let phase = 0.5 - 0.5 * (2.0 * std::f64::consts::PI * t / period_s).cos();
        let rate = base_rate + (peak_rate - base_rate) * phase;
        if rng.f64() < rate / peak_rate {
            arrivals.push(t);
        }
    }
    Trace { arrivals_s: arrivals }
}

/// Flash crowd: Poisson arrivals at `base_rate`, except inside the burst
/// window `[burst_start_s, burst_start_s + burst_len_s)` where the rate
/// steps to `base_rate · burst_mult` (Lewis–Shedler thinning of a
/// peak-rate stream, like [`diurnal`], but with a step instead of a
/// sinusoid). The step edge is the canonical autoscaler stressor: unlike
/// the diurnal drift there is no ramp to track, so the controller's
/// reaction time — cooldown, window length, hysteresis — is fully exposed
/// in the shed counters. CLI surface: `--trace
/// flash[:MULT[:START_S[:LEN_S]]]` on `fcmp serve` / `fcmp autoscale`.
pub fn flash_crowd(
    n: usize,
    base_rate: f64,
    burst_mult: f64,
    burst_start_s: f64,
    burst_len_s: f64,
    seed: u64,
) -> Trace {
    assert!(
        base_rate > 0.0 && burst_mult >= 1.0 && burst_start_s >= 0.0 && burst_len_s >= 0.0,
        "flash_crowd wants base_rate > 0, burst_mult >= 1, non-negative window"
    );
    let peak = base_rate * burst_mult;
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut arrivals = Vec::with_capacity(n);
    while arrivals.len() < n {
        t += rng.exp(peak);
        let in_burst = t >= burst_start_s && t < burst_start_s + burst_len_s;
        let rate = if in_burst { peak } else { base_rate };
        if rng.f64() < rate / peak {
            arrivals.push(t);
        }
    }
    Trace { arrivals_s: arrivals }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_converges() {
        let t = poisson(20_000, 250.0, 1);
        assert!((t.offered_rate() - 250.0).abs() / 250.0 < 0.05, "{}", t.offered_rate());
        // strictly increasing
        assert!(t.arrivals_s.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn poisson_deterministic_per_seed() {
        assert_eq!(poisson(100, 10.0, 7).arrivals_s, poisson(100, 10.0, 7).arrivals_s);
        assert_ne!(poisson(100, 10.0, 7).arrivals_s, poisson(100, 10.0, 8).arrivals_s);
    }

    #[test]
    fn bursty_preserves_average_rate() {
        let t = bursty(5_000, 50.0, 500.0, 20, 3);
        assert!((t.offered_rate() - 50.0).abs() / 50.0 < 0.2, "{}", t.offered_rate());
    }

    #[test]
    fn bursty_has_peaks() {
        let t = bursty(1_000, 50.0, 500.0, 25, 4);
        // within a burst, inter-arrival = 1/peak
        let min_gap = t
            .arrivals_s
            .windows(2)
            .map(|w| w[1] - w[0])
            .fold(f64::INFINITY, f64::min);
        assert!(min_gap < 1.5 / 500.0, "min gap {min_gap}");
    }

    #[test]
    fn heavy_tail_rate_converges_when_variance_is_finite() {
        // alpha = 2.5 has finite variance, so the sample mean converges
        let t = heavy_tail(40_000, 200.0, 2.5, 5);
        assert!((t.offered_rate() - 200.0).abs() / 200.0 < 0.1, "{}", t.offered_rate());
        assert!(t.arrivals_s.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn heavy_tail_is_deterministic_per_seed() {
        assert_eq!(
            heavy_tail(200, 50.0, 1.5, 9).arrivals_s,
            heavy_tail(200, 50.0, 1.5, 9).arrivals_s
        );
        assert_ne!(
            heavy_tail(200, 50.0, 1.5, 9).arrivals_s,
            heavy_tail(200, 50.0, 1.5, 10).arrivals_s
        );
    }

    #[test]
    fn heavy_tail_is_heavier_than_poisson() {
        // max/median inter-arrival gap: the Pareto tail dwarfs the
        // exponential one at the same offered rate
        let gap_ratio = |t: &Trace| {
            let mut gaps: Vec<f64> = t.arrivals_s.windows(2).map(|w| w[1] - w[0]).collect();
            gaps.sort_by(|a, b| a.partial_cmp(b).unwrap());
            gaps[gaps.len() - 1] / gaps[gaps.len() / 2]
        };
        let heavy = gap_ratio(&heavy_tail(5_000, 100.0, 1.5, 6));
        let light = gap_ratio(&poisson(5_000, 100.0, 6));
        assert!(heavy > 20.0, "heavy tail ratio {heavy}");
        assert!(heavy > 2.0 * light, "heavy {heavy} vs poisson {light}");
    }

    #[test]
    fn uniform_exact() {
        let t = uniform(11, 100.0);
        assert_eq!(t.len(), 11);
        assert!((t.offered_rate() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn diurnal_mean_rate_between_trough_and_peak() {
        let t = diurnal(30_000, 100.0, 500.0, 10.0, 7);
        let r = t.offered_rate();
        // sinusoidal modulation averages to (base+peak)/2 = 300
        assert!((r - 300.0).abs() / 300.0 < 0.1, "rate {r}");
        assert!(t.arrivals_s.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn diurnal_peaks_are_denser_than_troughs() {
        // count arrivals in the first trough half-period vs the following
        // peak half-period
        let period = 20.0;
        let t = diurnal(20_000, 50.0, 800.0, period, 9);
        let in_window = |lo: f64, hi: f64| {
            t.arrivals_s.iter().filter(|&&a| a >= lo && a < hi).count()
        };
        let trough = in_window(0.0, 0.25 * period) + in_window(0.75 * period, period);
        let peak = in_window(0.25 * period, 0.75 * period);
        assert!(peak > 3 * trough, "peak {peak} vs trough {trough}");
    }

    #[test]
    fn diurnal_deterministic_per_seed() {
        assert_eq!(
            diurnal(500, 50.0, 200.0, 5.0, 3).arrivals_s,
            diurnal(500, 50.0, 200.0, 5.0, 3).arrivals_s
        );
        assert_ne!(
            diurnal(500, 50.0, 200.0, 5.0, 3).arrivals_s,
            diurnal(500, 50.0, 200.0, 5.0, 4).arrivals_s
        );
    }

    #[test]
    fn flash_crowd_burst_window_is_denser_by_the_multiplier() {
        // base 100/s, 8x burst over [2, 3): compare arrival densities
        let t = flash_crowd(2_000, 100.0, 8.0, 2.0, 1.0, 13);
        let in_window = |lo: f64, hi: f64| {
            t.arrivals_s.iter().filter(|&&a| a >= lo && a < hi).count() as f64 / (hi - lo)
        };
        let before = in_window(0.0, 2.0);
        let burst = in_window(2.0, 3.0);
        assert!((before - 100.0).abs() / 100.0 < 0.25, "baseline density {before}");
        assert!((burst - 800.0).abs() / 800.0 < 0.15, "burst density {burst}");
        assert!(t.arrivals_s.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn flash_crowd_deterministic_per_seed() {
        assert_eq!(
            flash_crowd(300, 50.0, 6.0, 1.0, 0.5, 3).arrivals_s,
            flash_crowd(300, 50.0, 6.0, 1.0, 0.5, 3).arrivals_s
        );
        assert_ne!(
            flash_crowd(300, 50.0, 6.0, 1.0, 0.5, 3).arrivals_s,
            flash_crowd(300, 50.0, 6.0, 1.0, 0.5, 4).arrivals_s
        );
    }

    #[test]
    fn flash_crowd_without_burst_is_plain_poisson_rate() {
        let t = flash_crowd(10_000, 200.0, 5.0, 1e9, 1.0, 21);
        assert!((t.offered_rate() - 200.0).abs() / 200.0 < 0.05, "{}", t.offered_rate());
    }

    #[test]
    fn merge_is_sorted_reproducible_and_order_preserving_per_tenant() {
        let a = poisson(400, 120.0, 11);
        let b = diurnal(600, 40.0, 200.0, 5.0, 12);
        let (m1, t1) = Trace::merge(&[(0, &a), (1, &b)]);
        let (m2, t2) = Trace::merge(&[(0, &a), (1, &b)]);
        // pure function of the inputs: same seeds => bit-identical merge
        assert_eq!(m1.arrivals_s, m2.arrivals_s);
        assert_eq!(t1, t2);
        assert_eq!(m1.len(), a.len() + b.len());
        assert_eq!(t1.len(), m1.len());
        assert!(m1.arrivals_s.windows(2).all(|w| w[1] >= w[0]));
        // each tenant's own arrivals come back in their original order
        let back_a: Vec<f64> = m1
            .arrivals_s
            .iter()
            .zip(&t1)
            .filter(|(_, &t)| t == 0)
            .map(|(&s, _)| s)
            .collect();
        let back_b: Vec<f64> = m1
            .arrivals_s
            .iter()
            .zip(&t1)
            .filter(|(_, &t)| t == 1)
            .map(|(&s, _)| s)
            .collect();
        assert_eq!(back_a, a.arrivals_s);
        assert_eq!(back_b, b.arrivals_s);
    }

    #[test]
    fn merge_breaks_ties_by_tenant_id() {
        let x = Trace { arrivals_s: vec![1.0, 2.0] };
        let y = Trace { arrivals_s: vec![1.0, 2.0] };
        // tenant 2 listed first, tenant 1 second: ties still order 1 < 2
        let (_, tags) = Trace::merge(&[(2, &x), (1, &y)]);
        assert_eq!(tags, vec![1, 2, 1, 2]);
    }

    #[test]
    fn trace_roundtrips_through_disk() {
        let t = poisson(500, 120.0, 77);
        let dir = std::env::temp_dir();
        let path = dir.join("fcmp_trace_roundtrip_test.txt");
        t.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(back.len(), t.len());
        for (a, b) in t.arrivals_s.iter().zip(&back.arrivals_s) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn trace_load_rejects_garbage_and_disorder() {
        let dir = std::env::temp_dir();
        let bad = dir.join("fcmp_trace_bad_test.txt");
        std::fs::write(&bad, "# fcmp-trace v1\n0.5\nnot-a-number\n").unwrap();
        assert!(Trace::load(&bad).is_err());
        std::fs::write(&bad, "2.0\n1.0\n").unwrap();
        assert!(Trace::load(&bad).is_err(), "disorder must be rejected");
        std::fs::write(&bad, "# comment\n\n0.25\n0.50\n").unwrap();
        let t = Trace::load(&bad).unwrap();
        assert_eq!(t.arrivals_s, vec![0.25, 0.50]);
        let _ = std::fs::remove_file(&bad);
    }
}
