//! Dynamic batcher: greedily drains a replica's request queue up to
//! `max_batch`, waiting at most `max_wait` for stragglers once the first
//! request of a batch has arrived (the classic size-or-deadline policy).
//! Every replica of the fleet runs its own batcher over its own bounded
//! queue, so batch formation never crosses replicas.
//!
//! The settings are *live*: each replica publishes its policy through a
//! [`SharedBatcher`], which the worker re-reads before forming every
//! batch — the actuation path of the SLO-aware batching controller
//! ([`crate::control::slo`]), which shrinks the batching window under
//! backlog and grows it when idle without restarting the replica.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use super::Request;

/// Batching policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatcherConfig {
    /// Maximum requests per batch (match the engine's largest variant).
    pub max_batch: usize,
    /// Maximum time to hold an open batch waiting for more requests.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(2) }
    }
}

/// Live-tunable batching settings shared between a replica's worker thread
/// and the control plane. The worker snapshots the settings with
/// [`SharedBatcher::load`] before forming each batch, so a
/// [`SharedBatcher::store`] from the SLO controller takes effect on the
/// very next batch — no drain, no respawn. Both fields live in one
/// packed atomic so a snapshot is genuinely atomic: a concurrent store
/// can never hand the worker a torn config mixing old and new settings.
#[derive(Debug)]
pub struct SharedBatcher {
    /// `(max_batch << WAIT_BITS) | max_wait_us`.
    packed: AtomicU64,
}

/// Bits of the packed word holding `max_wait` in microseconds (~8.9
/// years — far beyond any sane batching window); the remaining 16 bits
/// hold `max_batch`.
const WAIT_BITS: u32 = 48;
const WAIT_MASK: u64 = (1 << WAIT_BITS) - 1;

impl SharedBatcher {
    /// Publish `cfg` as the initial settings.
    pub fn new(cfg: BatcherConfig) -> SharedBatcher {
        let s = SharedBatcher { packed: AtomicU64::new(1 << WAIT_BITS) };
        s.store(cfg);
        s
    }

    /// Snapshot the current settings (one atomic load).
    pub fn load(&self) -> BatcherConfig {
        let packed = self.packed.load(Ordering::SeqCst);
        BatcherConfig {
            max_batch: ((packed >> WAIT_BITS) as usize).max(1),
            max_wait: Duration::from_micros(packed & WAIT_MASK),
        }
    }

    /// Replace the settings (one atomic store); the owning worker picks
    /// them up on its next batch. `max_batch` is clamped to 1..=65535 so
    /// a worker can never be configured into forming empty batches and
    /// the packed encoding cannot overflow.
    pub fn store(&self, cfg: BatcherConfig) {
        let batch = cfg.max_batch.clamp(1, u16::MAX as usize) as u64;
        let us = (cfg.max_wait.as_micros().min(u128::from(WAIT_MASK))) as u64;
        self.packed.store((batch << WAIT_BITS) | us, Ordering::SeqCst);
    }
}

/// A formed batch.
pub struct Batch {
    pub requests: Vec<Request>,
    /// When the batch was closed (for queue-latency accounting).
    pub formed_at: Instant,
}

/// Drain the next batch from `rx`. **Parks** on the channel for the
/// first request (zero CPU at an idle fleet); then gathers more until
/// `max_batch` or `max_wait` elapses. Returns `None` when the channel is
/// closed and empty. A worker with batches in flight must not park here
/// — it uses [`poll_batch`] so it can reap completions promptly.
pub fn next_batch(rx: &Receiver<Request>, cfg: &BatcherConfig) -> Option<Batch> {
    next_batch_traced(rx, cfg, &mut |_| {})
}

/// [`next_batch`] with a per-request pull hook: `on_pull` runs the
/// moment each request leaves the stage queue and joins the forming
/// batch — the observability layer's Gather stamp site, so a span
/// records the *individual* pull time (first-in requests wait out the
/// straggler window; the hook is what makes that wait measurable).
pub fn next_batch_traced(
    rx: &Receiver<Request>,
    cfg: &BatcherConfig,
    on_pull: &mut dyn FnMut(&mut Request),
) -> Option<Batch> {
    let mut first = rx.recv().ok()?;
    on_pull(&mut first);
    let deadline = Instant::now() + cfg.max_wait;
    let requests = gather(rx, cfg, first, deadline, on_pull);
    Some(Batch { requests, formed_at: Instant::now() })
}

/// Outcome of one bounded [`poll_batch`] window.
pub enum BatchPoll {
    /// A batch formed within the window.
    Batch(Batch),
    /// The window elapsed with no request arriving.
    Idle,
    /// The channel is closed and empty.
    Closed,
}

/// Like [`next_batch`] but bounded: wait at most `limit` for the first
/// request, then gather stragglers until `max_batch`, `max_wait`, or the
/// end of the window — whichever comes first. The submit/reap worker
/// loop calls this while it has batches in flight, sizing `limit` to the
/// oldest batch's expected completion so batch `N+1` forms while batch
/// `N` executes without delaying its reap.
pub fn poll_batch(rx: &Receiver<Request>, cfg: &BatcherConfig, limit: Duration) -> BatchPoll {
    poll_batch_traced(rx, cfg, limit, &mut |_| {})
}

/// [`poll_batch`] with the same per-request pull hook as
/// [`next_batch_traced`].
pub fn poll_batch_traced(
    rx: &Receiver<Request>,
    cfg: &BatcherConfig,
    limit: Duration,
    on_pull: &mut dyn FnMut(&mut Request),
) -> BatchPoll {
    let window_end = Instant::now() + limit;
    let mut first = match rx.recv_timeout(limit) {
        Ok(r) => r,
        Err(RecvTimeoutError::Timeout) => return BatchPoll::Idle,
        Err(RecvTimeoutError::Disconnected) => return BatchPoll::Closed,
    };
    on_pull(&mut first);
    let deadline = (Instant::now() + cfg.max_wait).min(window_end);
    let requests = gather(rx, cfg, first, deadline, on_pull);
    BatchPoll::Batch(Batch { requests, formed_at: Instant::now() })
}

/// Shared straggler-gathering tail: drain `rx` after `first` until
/// `max_batch` or `deadline`.
fn gather(
    rx: &Receiver<Request>,
    cfg: &BatcherConfig,
    first: Request,
    deadline: Instant,
    on_pull: &mut dyn FnMut(&mut Request),
) -> Vec<Request> {
    let mut requests = vec![first];
    while requests.len() < cfg.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(mut r) => {
                on_pull(&mut r);
                requests.push(r);
            }
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    requests
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Instant;

    fn req(id: u64) -> Request {
        Request::new(id, vec![0.0; 4])
    }

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(req(i)).unwrap();
        }
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) };
        let b = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b.requests.len(), 4);
        assert_eq!(b.requests[0].id, 0);
        let b2 = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b2.requests[0].id, 4);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(0)).unwrap();
        let cfg = BatcherConfig { max_batch: 64, max_wait: Duration::from_millis(5) };
        let t0 = Instant::now();
        let b = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b.requests.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4));
        drop(tx);
    }

    #[test]
    fn closed_empty_channel_yields_none() {
        let (tx, rx) = mpsc::channel::<Request>();
        drop(tx);
        let cfg = BatcherConfig::default();
        assert!(next_batch(&rx, &cfg).is_none());
    }

    #[test]
    fn shared_batcher_roundtrips_and_clamps() {
        let s = SharedBatcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(3),
        });
        let c = s.load();
        assert_eq!(c.max_batch, 8);
        assert_eq!(c.max_wait, Duration::from_millis(3));
        s.store(BatcherConfig { max_batch: 0, max_wait: Duration::from_micros(250) });
        let c = s.load();
        assert_eq!(c.max_batch, 1, "zero batch must clamp to 1");
        assert_eq!(c.max_wait, Duration::from_micros(250));
        // oversized values clamp instead of corrupting the packed word
        s.store(BatcherConfig { max_batch: usize::MAX, max_wait: Duration::from_secs(1) });
        let c = s.load();
        assert_eq!(c.max_batch, u16::MAX as usize);
        assert_eq!(c.max_wait, Duration::from_secs(1));
    }

    #[test]
    fn poll_batch_reports_idle_after_the_window() {
        let (tx, rx) = mpsc::channel::<Request>();
        let cfg = BatcherConfig::default();
        let t0 = Instant::now();
        match poll_batch(&rx, &cfg, Duration::from_millis(5)) {
            BatchPoll::Idle => {}
            _ => panic!("empty open channel must report Idle"),
        }
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(4), "returned early: {waited:?}");
        assert!(waited < Duration::from_millis(200), "overstayed the window: {waited:?}");
        drop(tx);
    }

    #[test]
    fn poll_batch_reports_closed_and_forms_batches() {
        let (tx, rx) = mpsc::channel();
        for i in 0..3 {
            tx.send(req(i)).unwrap();
        }
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) };
        match poll_batch(&rx, &cfg, Duration::from_millis(50)) {
            BatchPoll::Batch(b) => {
                assert_eq!(b.requests.len(), 3);
                assert_eq!(b.requests[0].id, 0);
            }
            _ => panic!("queued requests must form a batch"),
        }
        drop(tx);
        match poll_batch(&rx, &cfg, Duration::from_millis(50)) {
            BatchPoll::Closed => {}
            _ => panic!("closed empty channel must report Closed"),
        }
    }

    #[test]
    fn poll_batch_window_caps_the_straggler_wait() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(0)).unwrap();
        // max_wait far beyond the polling window: the window must win
        let cfg = BatcherConfig { max_batch: 64, max_wait: Duration::from_secs(5) };
        let t0 = Instant::now();
        match poll_batch(&rx, &cfg, Duration::from_millis(10)) {
            BatchPoll::Batch(b) => assert_eq!(b.requests.len(), 1),
            _ => panic!("queued request must form a batch"),
        }
        assert!(t0.elapsed() < Duration::from_secs(1), "gather ignored the window cap");
        drop(tx);
    }

    #[test]
    fn traced_pull_hook_sees_every_request_once() {
        let (tx, rx) = mpsc::channel();
        for i in 0..5 {
            tx.send(req(i)).unwrap();
        }
        let cfg = BatcherConfig { max_batch: 5, max_wait: Duration::from_millis(1) };
        let mut pulled = Vec::new();
        let b = next_batch_traced(&rx, &cfg, &mut |r| pulled.push(r.id)).unwrap();
        assert_eq!(b.requests.len(), 5);
        assert_eq!(pulled, vec![0, 1, 2, 3, 4]);
        drop(tx);
    }

    #[test]
    fn stragglers_join_within_window() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(0)).unwrap();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(2));
            tx.send(req(1)).unwrap();
        });
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(40) };
        let b = next_batch(&rx, &cfg).unwrap();
        handle.join().unwrap();
        assert!(b.requests.len() >= 2, "straggler missed the batch");
    }
}
