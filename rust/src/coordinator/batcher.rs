//! Dynamic batcher: greedily drains a replica's request queue up to
//! `max_batch`, waiting at most `max_wait` for stragglers once the first
//! request of a batch has arrived (the classic size-or-deadline policy).
//! Every replica of the fleet runs its own batcher over its own bounded
//! queue, so batch formation never crosses replicas.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use super::Request;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Maximum requests per batch (match the engine's largest variant).
    pub max_batch: usize,
    /// Maximum time to hold an open batch waiting for more requests.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(2) }
    }
}

/// A formed batch.
pub struct Batch {
    pub requests: Vec<Request>,
    /// When the batch was closed (for queue-latency accounting).
    pub formed_at: Instant,
}

/// Drain the next batch from `rx`. Blocks for the first request; then
/// gathers more until `max_batch` or `max_wait` elapses. Returns `None`
/// when the channel is closed and empty.
pub fn next_batch(rx: &Receiver<Request>, cfg: &BatcherConfig) -> Option<Batch> {
    let first = rx.recv().ok()?;
    let deadline = Instant::now() + cfg.max_wait;
    let mut requests = vec![first];
    while requests.len() < cfg.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(r) => requests.push(r),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(Batch { requests, formed_at: Instant::now() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Instant;

    fn req(id: u64) -> Request {
        Request::new(id, vec![0.0; 4])
    }

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(req(i)).unwrap();
        }
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) };
        let b = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b.requests.len(), 4);
        assert_eq!(b.requests[0].id, 0);
        let b2 = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b2.requests[0].id, 4);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(0)).unwrap();
        let cfg = BatcherConfig { max_batch: 64, max_wait: Duration::from_millis(5) };
        let t0 = Instant::now();
        let b = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b.requests.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4));
        drop(tx);
    }

    #[test]
    fn closed_empty_channel_yields_none() {
        let (tx, rx) = mpsc::channel::<Request>();
        drop(tx);
        let cfg = BatcherConfig::default();
        assert!(next_batch(&rx, &cfg).is_none());
    }

    #[test]
    fn stragglers_join_within_window() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(0)).unwrap();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(2));
            tx.send(req(1)).unwrap();
        });
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(40) };
        let b = next_batch(&rx, &cfg).unwrap();
        handle.join().unwrap();
        assert!(b.requests.len() >= 2, "straggler missed the batch");
    }
}
