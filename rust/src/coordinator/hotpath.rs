//! Hot-path instrumentation and the recycling request-buffer pool — the
//! allocation-free steady state of the zero-stall execution path.
//!
//! Two pieces:
//!
//! * [`BufferPool`] recycles the per-request `Vec<f32>` payload buffers.
//!   A replay loop `get`s a buffer, fills it, and submits; the worker
//!   returns the buffer to the pool after the batch completes (and
//!   completion outputs can flow back too). Once the pool is warm the
//!   submit path performs **zero heap allocations per request** — the
//!   miss counter is the proof, and a test asserts it stays flat.
//! * [`HotCounters`] / [`HotPathStats`]: relaxed atomic counters on the
//!   router and backoff paths (submits, first-try accepts, fallback
//!   scans, backoff sleeps) merged with the pool's counters into one
//!   profile snapshot surfaced in
//!   [`crate::coordinator::FleetSummary::hot`].
//!
//! The counters are `Relaxed`: they are a profile, not a synchronization
//! edge, and the hot path must not pay for ordering it does not need.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, TryLockError};

/// Cumulative hot-path profile: router dispatch counters plus buffer-pool
/// traffic. Snapshot of monotone counters — diff two snapshots to profile
/// an interval.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HotPathStats {
    /// Dispatch attempts through the router core.
    pub submits: u64,
    /// Dispatches accepted by the policy's preferred group on the first
    /// `try_send` — the no-bookkeeping fast path.
    pub accepted_first_try: u64,
    /// Dispatches that fell through to the sorted sibling scan (preferred
    /// entry full or closed).
    pub fallback_scans: u64,
    /// Backoff sleeps taken by blocking/deadline submits while every
    /// entry queue stayed full.
    pub backoff_sleeps: u64,
    /// Dispatches shed by the deadline-feasibility admission rule
    /// ([`crate::coordinator::SubmitError::DeadlineInfeasible`]):
    /// the tenant's SLO budget could not cover the estimated sojourn, so
    /// the router refused the request *before* it occupied a queue slot.
    /// Disjoint from queue-full sheds.
    pub deadline_sheds: u64,
    /// Pool `get`s served from a recycled buffer.
    pub pool_hits: u64,
    /// Pool `get`s that had to allocate fresh (cold pool, or more buffers
    /// in flight than the pool has seen back).
    pub pool_misses: u64,
    /// Buffers returned to the pool.
    pub pool_returns: u64,
    /// Returned buffers dropped because their capacity was below the
    /// pool's request high-water mark (e.g. small completion outputs) or
    /// the pool was full.
    pub pool_rejected: u64,
    /// Lock contention events on the pool (a `get`/`put` that had to wait
    /// behind another thread).
    pub lock_waits: u64,
}

/// Router-side half of [`HotPathStats`] (the pool keeps its own).
#[derive(Debug, Default)]
pub(crate) struct HotCounters {
    pub(crate) submits: AtomicU64,
    pub(crate) accepted_first_try: AtomicU64,
    pub(crate) fallback_scans: AtomicU64,
    pub(crate) backoff_sleeps: AtomicU64,
    pub(crate) deadline_sheds: AtomicU64,
}

impl HotCounters {
    /// Snapshot the router counters into a [`HotPathStats`] with zeroed
    /// pool fields (the pool merges its own via [`BufferPool::merge_into`]).
    pub(crate) fn snapshot(&self) -> HotPathStats {
        HotPathStats {
            submits: self.submits.load(Ordering::Relaxed),
            accepted_first_try: self.accepted_first_try.load(Ordering::Relaxed),
            fallback_scans: self.fallback_scans.load(Ordering::Relaxed),
            backoff_sleeps: self.backoff_sleeps.load(Ordering::Relaxed),
            deadline_sheds: self.deadline_sheds.load(Ordering::Relaxed),
            ..HotPathStats::default()
        }
    }
}

/// Recycling pool of request payload buffers (`Vec<f32>`).
///
/// `get(len)` pops a recycled buffer (cleared, with its capacity intact)
/// or allocates fresh on a miss; `put` returns a buffer for reuse. The
/// pool tracks the largest length ever requested and rejects returned
/// buffers with less capacity, so a recycled buffer never triggers a
/// regrow on the submit path — after one warm cycle, steady state is
/// allocation-free and the miss counter stays flat.
///
/// A plain mutex guards the free list: a push/pop critical section is a
/// few nanoseconds, contention merely shows up in
/// [`HotPathStats::lock_waits`] (never a dropped buffer), and the router
/// dispatch path itself never touches the pool.
#[derive(Debug)]
pub struct BufferPool {
    free: Mutex<Vec<Vec<f32>>>,
    /// Max buffers kept; returns beyond it are dropped (counted).
    capacity: usize,
    /// High-water mark of requested lengths; smaller returned buffers are
    /// rejected so `get` never hands out a buffer that must regrow.
    target_len: AtomicUsize,
    hits: AtomicU64,
    misses: AtomicU64,
    returns: AtomicU64,
    rejected: AtomicU64,
    lock_waits: AtomicU64,
}

impl BufferPool {
    /// Empty pool keeping at most `capacity` free buffers.
    pub fn new(capacity: usize) -> BufferPool {
        BufferPool {
            free: Mutex::new(Vec::with_capacity(capacity.min(4096))),
            capacity: capacity.max(1),
            target_len: AtomicUsize::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            returns: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            lock_waits: AtomicU64::new(0),
        }
    }

    /// Pre-fill with `count` buffers of capacity `len` (counted as
    /// neither hits nor misses) — lets a test or a latency-critical
    /// caller start in the warm, allocation-free regime.
    pub fn prime(&self, count: usize, len: usize) {
        self.target_len.fetch_max(len, Ordering::Relaxed);
        let mut free = self.lock();
        for _ in 0..count.min(self.capacity.saturating_sub(free.len())) {
            free.push(Vec::with_capacity(len));
        }
    }

    /// A cleared buffer with capacity at least `len` in steady state
    /// (recycled when possible, freshly allocated on a miss).
    pub fn get(&self, len: usize) -> Vec<f32> {
        self.target_len.fetch_max(len, Ordering::Relaxed);
        let popped = self.lock().pop();
        match popped {
            Some(mut buf) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                buf.clear();
                if buf.capacity() < len {
                    // only possible for buffers primed/returned before the
                    // high-water mark rose to `len`; counted as a miss
                    // because it reallocates
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    buf.reserve(len - buf.capacity());
                }
                buf
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(len)
            }
        }
    }

    /// Return a buffer for reuse. Undersized buffers (capacity below the
    /// request high-water mark) and returns beyond the pool capacity are
    /// dropped and counted — recycling them would just reintroduce a
    /// regrow allocation on the next `get`.
    pub fn put(&self, buf: Vec<f32>) {
        if buf.capacity() < self.target_len.load(Ordering::Relaxed) {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let mut free = self.lock();
        if free.len() >= self.capacity {
            drop(free);
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return;
        }
        free.push(buf);
        drop(free);
        self.returns.fetch_add(1, Ordering::Relaxed);
    }

    /// Free buffers currently pooled.
    pub fn free_count(&self) -> usize {
        self.lock().len()
    }

    /// Merge the pool counters into `stats` (see [`HotPathStats`]).
    pub fn merge_into(&self, stats: &mut HotPathStats) {
        stats.pool_hits += self.hits.load(Ordering::Relaxed);
        stats.pool_misses += self.misses.load(Ordering::Relaxed);
        stats.pool_returns += self.returns.load(Ordering::Relaxed);
        stats.pool_rejected += self.rejected.load(Ordering::Relaxed);
        stats.lock_waits += self.lock_waits.load(Ordering::Relaxed);
    }

    /// Lock the free list, counting contention; a poisoned lock (worker
    /// panicked elsewhere) still yields the list — a pool of plain
    /// buffers has no invariant a panic can break.
    fn lock(&self) -> std::sync::MutexGuard<'_, Vec<Vec<f32>>> {
        match self.free.try_lock() {
            Ok(g) => g,
            Err(TryLockError::WouldBlock) => {
                self.lock_waits.fetch_add(1, Ordering::Relaxed);
                match self.free.lock() {
                    Ok(g) => g,
                    Err(poisoned) => poisoned.into_inner(),
                }
            }
            Err(TryLockError::Poisoned(poisoned)) => poisoned.into_inner(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_get_misses_then_recycles() {
        let pool = BufferPool::new(8);
        let buf = pool.get(16);
        assert_eq!(buf.capacity(), 16);
        let mut s = HotPathStats::default();
        pool.merge_into(&mut s);
        assert_eq!((s.pool_hits, s.pool_misses), (0, 1));
        pool.put(buf);
        let buf2 = pool.get(16);
        assert!(buf2.capacity() >= 16);
        assert!(buf2.is_empty(), "recycled buffers come back cleared");
        let mut s = HotPathStats::default();
        pool.merge_into(&mut s);
        assert_eq!((s.pool_hits, s.pool_misses, s.pool_returns), (1, 1, 1));
    }

    #[test]
    fn primed_pool_never_misses_within_capacity() {
        let pool = BufferPool::new(32);
        pool.prime(8, 8);
        assert_eq!(pool.free_count(), 8);
        for _ in 0..50 {
            let mut b = pool.get(8);
            b.extend([1.0; 8]);
            pool.put(b);
        }
        let mut s = HotPathStats::default();
        pool.merge_into(&mut s);
        assert_eq!(s.pool_misses, 0, "warm pool must stay allocation-free");
        assert_eq!(s.pool_hits, 50);
    }

    #[test]
    fn undersized_returns_are_rejected() {
        let pool = BufferPool::new(8);
        let b = pool.get(32); // raises the high-water mark
        pool.put(b);
        pool.put(Vec::with_capacity(2)); // e.g. a tiny completion output
        assert_eq!(pool.free_count(), 1);
        let mut s = HotPathStats::default();
        pool.merge_into(&mut s);
        assert_eq!(s.pool_rejected, 1);
        // the next get therefore never hands out an undersized buffer
        assert!(pool.get(32).capacity() >= 32);
    }

    #[test]
    fn pool_capacity_bounds_retained_buffers() {
        let pool = BufferPool::new(2);
        for _ in 0..5 {
            pool.put(Vec::with_capacity(4));
        }
        assert_eq!(pool.free_count(), 2);
        let mut s = HotPathStats::default();
        pool.merge_into(&mut s);
        assert_eq!(s.pool_returns, 2);
        assert_eq!(s.pool_rejected, 3);
    }

    #[test]
    fn grown_request_on_a_small_recycled_buffer_counts_as_miss() {
        let pool = BufferPool::new(8);
        pool.prime(1, 4);
        let b = pool.get(16); // primed-at-4 buffer must regrow
        assert!(b.capacity() >= 16);
        let mut s = HotPathStats::default();
        pool.merge_into(&mut s);
        assert_eq!((s.pool_hits, s.pool_misses), (1, 1));
    }
}
