//! The fleet topology plan: one composable abstraction for every shape
//! the coordinator can serve.
//!
//! A [`Deployment`] is an ordered set of [`ChainGroup`]s behind the
//! router. Each group is a `k`-stage pipeline chain; the degenerate
//! shapes the old API hard-coded fall out as special cases:
//!
//! ```text
//!   N groups × 1 stage   — the flat replicated fleet (PR-2 `start`)
//!   1 group  × k stages  — the single stage chain   (PR-3 `start_chain`)
//!   N groups × k stages  — replicated chains: the new diagonal of the
//!                          design space (policy picks a chain, frames
//!                          traverse it, throughput scales past one
//!                          pipeline)
//! ```
//!
//! [`crate::coordinator::Server::deploy`] spawns a plan;
//! [`crate::coordinator::Server::apply`] diffs a new plan against the
//! running one at **chain-group granularity**: groups whose
//! [`ChainGroup`] spec is unchanged keep serving (no drain, live batcher
//! retunes survive), removed groups drain to completion, added groups
//! spawn fresh. Give groups distinct [`ChainGroup::tag`]s when specs
//! look identical but the backends behind them must differ (the control
//! plane tags every group it creates, so scale-in retires exactly the
//! group it chose).

use super::batcher::BatcherConfig;
use super::policy::Policy;

/// Identifies one worker of a deployment: stage `stage` of chain group
/// `group`. Backend factories receive the id of the worker they are
/// building for (on that worker's own thread — PJRT handles are
/// thread-affine).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkerId {
    /// Chain-group index within the deployment, in plan order.
    pub group: usize,
    /// Stage index within the group (`0` is the entry stage).
    pub stage: usize,
}

/// One chain group of a [`Deployment`]: a `k`-stage pipeline behind the
/// router. `stages == 1` is a plain replica.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChainGroup {
    /// Pipeline depth of this group (clamped to at least 1 at deploy).
    pub stages: usize,
    /// Per-group batching baseline; `None` inherits
    /// [`Deployment::batcher`].
    pub batcher: Option<BatcherConfig>,
    /// Identity label for [`crate::coordinator::Server::apply`] diffing:
    /// two groups match (and the running one is kept, backends and all)
    /// only when their tags are equal alongside the rest of the spec.
    /// `None` groups match each other by shape alone.
    pub tag: Option<String>,
    /// Tenant this group serves ([`crate::tenancy`]): the router routes a
    /// tenant's traffic only to groups carrying its id, and metrics /
    /// control signals split on it. Single-tenant plans leave every group
    /// at tenant `0` — the default — and behave exactly as before.
    pub tenant: usize,
}

impl ChainGroup {
    /// A `stages`-deep chain group inheriting the deployment's batcher.
    pub fn new(stages: usize) -> ChainGroup {
        ChainGroup { stages, batcher: None, tag: None, tenant: 0 }
    }

    /// Same group with an identity tag (see [`ChainGroup::tag`]).
    pub fn tagged(stages: usize, tag: impl Into<String>) -> ChainGroup {
        ChainGroup { stages, batcher: None, tag: Some(tag.into()), tenant: 0 }
    }

    /// Same group owned by `tenant` (builder style).
    pub fn for_tenant(mut self, tenant: usize) -> ChainGroup {
        self.tenant = tenant;
        self
    }
}

/// The fleet topology the coordinator serves: an ordered set of chain
/// groups plus the routing policy and the shared defaults. Replaces the
/// old `ServerConfig` + `start`/`start_chain` split.
#[derive(Clone, Debug, PartialEq)]
pub struct Deployment {
    /// The chain groups, in router order (a plan with zero groups is
    /// normalized to one 1-stage group at deploy time).
    pub groups: Vec<ChainGroup>,
    /// Default batching policy for groups without their own.
    pub batcher: BatcherConfig,
    /// Bound of every stage's request queue (admission control: when
    /// every open group entry is full, submits shed with
    /// [`crate::coordinator::SubmitError::QueueFull`]).
    pub queue_depth: usize,
    /// Scheduling policy picking the *chain group* each request enters.
    pub policy: Policy,
    /// Per-worker in-flight window: how many batches a stage may have
    /// submitted to its backend before it must reap one. `1` reproduces
    /// the old fully synchronous worker; `2`+ lets batch `N+1` form (and
    /// transfer, for backends that overlap) while batch `N` executes —
    /// the zero-stall pipeline. Clamped to at least 1 at deploy.
    pub window: usize,
}

impl Default for Deployment {
    fn default() -> Self {
        Deployment {
            groups: vec![ChainGroup::new(1)],
            batcher: BatcherConfig::default(),
            queue_depth: 256,
            policy: Policy::RoundRobin,
            window: 2,
        }
    }
}

impl Deployment {
    /// The flat replicated fleet: `n` groups of one stage each.
    pub fn replicated(n: usize) -> Deployment {
        Deployment::replicated_chains(n, 1)
    }

    /// A single `k`-stage chain (pipeline-parallel sharding,
    /// [`crate::sharding`]).
    pub fn chain(k: usize) -> Deployment {
        Deployment::replicated_chains(1, k)
    }

    /// `n` parallel copies of a `k`-stage chain behind the router — the
    /// replicated-chain shape that lifts sharded throughput beyond one
    /// pipeline.
    pub fn replicated_chains(n: usize, k: usize) -> Deployment {
        Deployment {
            groups: (0..n.max(1)).map(|_| ChainGroup::new(k.max(1))).collect(),
            ..Deployment::default()
        }
    }

    /// Same plan with `policy` (builder style).
    pub fn with_policy(mut self, policy: Policy) -> Deployment {
        self.policy = policy;
        self
    }

    /// Same plan with the default batcher `b`.
    pub fn with_batcher(mut self, b: BatcherConfig) -> Deployment {
        self.batcher = b;
        self
    }

    /// Same plan with per-stage queue bound `depth`.
    pub fn with_queue_depth(mut self, depth: usize) -> Deployment {
        self.queue_depth = depth;
        self
    }

    /// Same plan with per-worker in-flight window `window` (see
    /// [`Deployment::window`]).
    pub fn with_window(mut self, window: usize) -> Deployment {
        self.window = window;
        self
    }

    /// Number of chain groups (after normalization: at least 1).
    pub fn group_count(&self) -> usize {
        self.groups.len().max(1)
    }

    /// Stage counts per group, in plan order.
    pub fn group_sizes(&self) -> Vec<usize> {
        if self.groups.is_empty() {
            return vec![1];
        }
        self.groups.iter().map(|g| g.stages.max(1)).collect()
    }

    /// Total workers across every group.
    pub fn total_stages(&self) -> usize {
        self.group_sizes().iter().sum()
    }

    /// The batcher group `g` actually runs (its own, or the default).
    pub fn group_batcher(&self, g: usize) -> BatcherConfig {
        self.groups.get(g).and_then(|grp| grp.batcher).unwrap_or(self.batcher)
    }

    /// Tenant owning group `g` (out-of-range groups read as tenant 0).
    pub fn tenant_of(&self, g: usize) -> usize {
        self.groups.get(g).map(|grp| grp.tenant).unwrap_or(0)
    }

    /// Group index → owning tenant, in plan order.
    pub fn group_tenants(&self) -> Vec<usize> {
        if self.groups.is_empty() {
            return vec![0];
        }
        self.groups.iter().map(|g| g.tenant).collect()
    }

    /// Number of tenants the plan serves: `max(tenant) + 1` (tenant ids
    /// are dense by convention; the zoo assigns them in catalog order).
    pub fn tenant_count(&self) -> usize {
        self.groups.iter().map(|g| g.tenant).max().unwrap_or(0) + 1
    }

    /// Clamp the plan into a servable shape: at least one group, every
    /// group at least one stage, queue depth at least 1.
    pub(crate) fn normalized(mut self) -> Deployment {
        if self.groups.is_empty() {
            self.groups.push(ChainGroup::new(1));
        }
        for g in &mut self.groups {
            g.stages = g.stages.max(1);
        }
        self.queue_depth = self.queue_depth.max(1);
        self.window = self.window.max(1);
        self
    }

    /// Diffing identity of group `g` for [`crate::coordinator::Server::apply`]:
    /// a running group is kept only when its key equals the new plan's.
    pub(crate) fn group_key(&self, g: usize) -> GroupKey {
        GroupKey {
            tag: self.groups.get(g).and_then(|grp| grp.tag.clone()),
            stages: self.groups.get(g).map(|grp| grp.stages.max(1)).unwrap_or(1),
            batcher: self.group_batcher(g),
            queue_depth: self.queue_depth.max(1),
            window: self.window.max(1),
            tenant: self.tenant_of(g),
        }
    }
}

/// Everything that must match for a running group to survive an
/// [`crate::coordinator::Server::apply`] without a respawn.
#[derive(Clone, Debug, PartialEq, Eq)]
pub(crate) struct GroupKey {
    pub(crate) tag: Option<String>,
    pub(crate) stages: usize,
    pub(crate) batcher: BatcherConfig,
    pub(crate) queue_depth: usize,
    pub(crate) window: usize,
    pub(crate) tenant: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn constructors_cover_the_three_shapes() {
        let flat = Deployment::replicated(3);
        assert_eq!(flat.group_sizes(), vec![1, 1, 1]);
        assert_eq!(flat.total_stages(), 3);
        let chain = Deployment::chain(4);
        assert_eq!(chain.group_sizes(), vec![4]);
        let rc = Deployment::replicated_chains(2, 3);
        assert_eq!(rc.group_sizes(), vec![3, 3]);
        assert_eq!(rc.total_stages(), 6);
    }

    #[test]
    fn normalization_clamps_degenerates() {
        let d = Deployment { groups: vec![], queue_depth: 0, ..Deployment::default() }
            .normalized();
        assert_eq!(d.group_count(), 1);
        assert_eq!(d.queue_depth, 1);
        let d = Deployment {
            groups: vec![ChainGroup::new(0)],
            ..Deployment::default()
        }
        .normalized();
        assert_eq!(d.group_sizes(), vec![1]);
        // degenerate constructor args clamp too
        assert_eq!(Deployment::replicated(0).group_count(), 1);
        assert_eq!(Deployment::chain(0).group_sizes(), vec![1]);
    }

    #[test]
    fn group_keys_diff_on_tag_shape_and_batcher() {
        let base = Deployment::replicated_chains(2, 2);
        assert_eq!(base.group_key(0), base.group_key(1), "untagged same-shape groups match");
        let mut tagged = base.clone();
        tagged.groups[1].tag = Some("g1".into());
        assert_ne!(tagged.group_key(0), tagged.group_key(1));
        let mut other = base.clone();
        other.groups[1].stages = 3;
        assert_ne!(base.group_key(1), other.group_key(1));
        let mut batched = base.clone();
        batched.groups[1].batcher =
            Some(BatcherConfig { max_batch: 9, max_wait: Duration::from_millis(1) });
        assert_ne!(base.group_key(1), batched.group_key(1));
        // a queue-depth change invalidates every key (full swap on apply)
        let deeper = base.clone().with_queue_depth(base.queue_depth + 1);
        assert_ne!(base.group_key(0), deeper.group_key(0));
        // so does an in-flight-window change (workers must respawn)
        let wider = base.clone().with_window(base.window + 2);
        assert_ne!(base.group_key(0), wider.group_key(0));
    }

    #[test]
    fn tenant_splits_group_keys_and_maps() {
        let mut d = Deployment::replicated(3);
        d.groups[2] = d.groups[2].clone().for_tenant(1);
        assert_eq!(d.group_tenants(), vec![0, 0, 1]);
        assert_eq!(d.tenant_count(), 2);
        assert_eq!(d.tenant_of(2), 1);
        assert_eq!(d.tenant_of(99), 0);
        // groups differing only in tenant must not match on apply
        assert_ne!(d.group_key(0), d.group_key(2));
        assert_eq!(d.group_key(0), d.group_key(1));
    }

    #[test]
    fn window_defaults_and_clamps() {
        assert_eq!(Deployment::default().window, 2);
        assert_eq!(Deployment::replicated(2).with_window(0).normalized().window, 1);
        assert_eq!(Deployment::chain(2).with_window(4).window, 4);
    }

    #[test]
    fn group_batcher_falls_back_to_the_default() {
        let own = BatcherConfig { max_batch: 7, max_wait: Duration::from_micros(300) };
        let mut d = Deployment::replicated(2);
        d.groups[1].batcher = Some(own);
        assert_eq!(d.group_batcher(0), d.batcher);
        assert_eq!(d.group_batcher(1), own);
        // out of range falls back too (callers guard separately)
        assert_eq!(d.group_batcher(9), d.batcher);
    }
}
