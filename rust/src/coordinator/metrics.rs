//! Serving metrics: latency percentiles, throughput and batch-size
//! statistics — per replica and fleet-wide — plus the admission-control
//! counters (submitted / shed) the overload experiments report.

use std::time::Duration;

use super::Completion;
use crate::util::stats::{summarize, Summary};

/// Collects per-request completions for one stream (one replica, or the
/// whole fleet when driven through [`FleetMetrics`]).
#[derive(Default)]
pub struct Metrics {
    latencies_ms: Vec<f64>,
    batch_sizes: Vec<usize>,
    started: Option<std::time::Instant>,
    finished: Option<std::time::Instant>,
}

/// Final serving summary for one stream: request count, wall-clock span,
/// throughput, the latency distribution (p50/p95/p99 via
/// [`crate::util::stats::Summary`]) and the mean ridden batch size.
#[derive(Clone, Debug)]
pub struct ServeSummary {
    /// Completions recorded.
    pub requests: usize,
    /// Wall-clock seconds from [`Metrics::start`] to the last completion.
    pub wall_s: f64,
    /// `requests / wall_s`.
    pub throughput_fps: f64,
    /// Latency distribution in milliseconds (median = p50, plus p95/p99).
    pub latency_ms: Summary,
    /// Mean size of the batches the requests rode in.
    pub mean_batch: f64,
}

impl Metrics {
    /// Empty collector.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Mark the start of the measurement window.
    pub fn start(&mut self) {
        self.started = Some(std::time::Instant::now());
    }

    /// Record one completion.
    pub fn record(&mut self, latency: Duration, batch_size: usize) {
        self.latencies_ms.push(latency.as_secs_f64() * 1e3);
        self.batch_sizes.push(batch_size);
        self.finished = Some(std::time::Instant::now());
    }

    /// Completions recorded so far.
    pub fn count(&self) -> usize {
        self.latencies_ms.len()
    }

    /// Summarize; panics when nothing was recorded (see
    /// [`Metrics::try_summary`] for the non-panicking form).
    pub fn summary(&self) -> ServeSummary {
        assert!(!self.latencies_ms.is_empty(), "no completions recorded");
        let wall = match (self.started, self.finished) {
            (Some(a), Some(b)) => (b - a).as_secs_f64(),
            _ => 0.0,
        };
        ServeSummary {
            requests: self.latencies_ms.len(),
            wall_s: wall,
            throughput_fps: self.latencies_ms.len() as f64 / wall.max(1e-9),
            latency_ms: summarize(&self.latencies_ms),
            mean_batch: self.batch_sizes.iter().sum::<usize>() as f64
                / self.batch_sizes.len() as f64,
        }
    }

    /// Summarize, or `None` when nothing was recorded (idle replicas).
    pub fn try_summary(&self) -> Option<ServeSummary> {
        if self.latencies_ms.is_empty() {
            None
        } else {
            Some(self.summary())
        }
    }
}

impl std::fmt::Display for ServeSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} requests in {:.2}s => {:.1} FPS | latency ms: p50 {:.2} p95 {:.2} p99 {:.2} max {:.2} | mean batch {:.2}",
            self.requests,
            self.wall_s,
            self.throughput_fps,
            self.latency_ms.median,
            self.latency_ms.p95,
            self.latency_ms.p99,
            self.latency_ms.max,
            self.mean_batch
        )
    }
}

/// Fleet-wide metrics: one [`Metrics`] per replica, one for the whole
/// fleet, and the admission-control counters.
pub struct FleetMetrics {
    fleet: Metrics,
    per_replica: Vec<Metrics>,
    submitted: usize,
    shed: usize,
}

/// Fleet summary: the fleet-wide view plus per-replica breakdowns (idle
/// replicas report `None`) and the admission-control counters.
#[derive(Clone, Debug)]
pub struct FleetSummary {
    /// Fleet-wide summary; `None` when nothing completed.
    pub fleet: Option<ServeSummary>,
    /// Per-replica summaries; `None` for replicas that served nothing.
    pub per_replica: Vec<Option<ServeSummary>>,
    /// Requests accepted by admission control.
    pub submitted: usize,
    /// Requests shed because every replica queue was full.
    pub shed: usize,
}

impl FleetMetrics {
    /// Empty collectors for a fleet of `replicas` workers.
    pub fn new(replicas: usize) -> FleetMetrics {
        FleetMetrics {
            fleet: Metrics::new(),
            per_replica: (0..replicas).map(|_| Metrics::new()).collect(),
            submitted: 0,
            shed: 0,
        }
    }

    /// Mark the start of the measurement window on every collector.
    pub fn start(&mut self) {
        self.fleet.start();
        for m in &mut self.per_replica {
            m.start();
        }
    }

    /// Record a completion against the fleet and its serving replica.
    ///
    /// Stage-chain completions (non-empty [`Completion::stage_latencies`])
    /// split differently: the fleet collector sees the end-to-end latency
    /// while each per-replica collector sees that *stage's* transit
    /// latency, so per-replica percentiles localize the slow stage and the
    /// fleet percentiles answer the SLO question.
    pub fn record(&mut self, c: &Completion) {
        self.fleet.record(c.latency, c.batch_size);
        if c.stage_latencies.is_empty() {
            if let Some(m) = self.per_replica.get_mut(c.replica) {
                m.record(c.latency, c.batch_size);
            }
        } else {
            for (i, &lat) in c.stage_latencies.iter().enumerate() {
                if let Some(m) = self.per_replica.get_mut(i) {
                    let batch = c.stage_batches.get(i).copied().unwrap_or(c.batch_size);
                    m.record(lat, batch);
                }
            }
        }
    }

    /// Count one accepted submission.
    pub fn record_submitted(&mut self) {
        self.submitted += 1;
    }

    /// Count one shed (admission-control rejected) submission.
    pub fn record_shed(&mut self) {
        self.shed += 1;
    }

    /// Completions recorded so far.
    pub fn completed(&self) -> usize {
        self.fleet.count()
    }

    /// Accepted submissions so far.
    pub fn submitted(&self) -> usize {
        self.submitted
    }

    /// Shed submissions so far.
    pub fn shed(&self) -> usize {
        self.shed
    }

    /// Summarize fleet and replicas.
    pub fn summary(&self) -> FleetSummary {
        FleetSummary {
            fleet: self.fleet.try_summary(),
            per_replica: self.per_replica.iter().map(Metrics::try_summary).collect(),
            submitted: self.submitted,
            shed: self.shed,
        }
    }
}

impl std::fmt::Display for FleetSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.fleet {
            Some(s) => write!(f, "fleet: {s} | submitted {} shed {}", self.submitted, self.shed)?,
            None => write!(
                f,
                "fleet: no completions | submitted {} shed {}",
                self.submitted, self.shed
            )?,
        }
        for (i, s) in self.per_replica.iter().enumerate() {
            match s {
                Some(s) => write!(f, "\n  replica {i}: {s}")?,
                None => write!(f, "\n  replica {i}: idle")?,
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_math() {
        let mut m = Metrics::new();
        m.start();
        for i in 0..10 {
            m.record(Duration::from_millis(10 + i), 2);
        }
        let s = m.summary();
        assert_eq!(s.requests, 10);
        assert_eq!(s.mean_batch, 2.0);
        assert!(s.latency_ms.median >= 10.0);
        assert!(s.throughput_fps > 0.0);
    }

    #[test]
    #[should_panic]
    fn empty_summary_panics() {
        Metrics::new().summary();
    }

    #[test]
    fn try_summary_is_total() {
        assert!(Metrics::new().try_summary().is_none());
        let mut m = Metrics::new();
        m.start();
        m.record(Duration::from_millis(3), 1);
        assert_eq!(m.try_summary().unwrap().requests, 1);
    }

    fn completion(id: u64, replica: usize, ms: u64, batch: usize) -> Completion {
        Completion {
            id,
            output: vec![0.0],
            latency: Duration::from_millis(ms),
            batch_size: batch,
            replica,
            stage_latencies: Vec::new(),
            stage_batches: Vec::new(),
        }
    }

    #[test]
    fn fleet_metrics_split_by_replica() {
        let mut fm = FleetMetrics::new(3);
        fm.start();
        for i in 0..6 {
            fm.record_submitted();
            fm.record(&completion(i, (i % 2) as usize, 5 + i, 2));
        }
        fm.record_shed();
        assert_eq!(fm.completed(), 6);
        assert_eq!(fm.submitted(), 6);
        assert_eq!(fm.shed(), 1);
        let s = fm.summary();
        assert_eq!(s.fleet.as_ref().unwrap().requests, 6);
        assert_eq!(s.per_replica[0].as_ref().unwrap().requests, 3);
        assert_eq!(s.per_replica[1].as_ref().unwrap().requests, 3);
        assert!(s.per_replica[2].is_none(), "replica 2 never served");
        // the display renders fleet and per-replica lines
        let text = format!("{s}");
        assert!(text.contains("replica 2: idle"), "{text}");
        assert!(text.contains("shed 1"), "{text}");
    }

    #[test]
    fn out_of_range_replica_ignored_gracefully() {
        let mut fm = FleetMetrics::new(1);
        fm.start();
        fm.record(&completion(0, 5, 1, 1));
        assert_eq!(fm.completed(), 1);
        assert!(fm.summary().per_replica[0].is_none());
    }

    #[test]
    fn chain_completions_split_per_stage_and_end_to_end() {
        let mut fm = FleetMetrics::new(3);
        fm.start();
        for i in 0..4 {
            let mut c = completion(i, 2, 60, 1);
            c.stage_latencies = vec![
                Duration::from_millis(10),
                Duration::from_millis(40),
                Duration::from_millis(10),
            ];
            c.stage_batches = vec![4, 2, 1];
            fm.record(&c);
        }
        let s = fm.summary();
        // the fleet sees end-to-end latency...
        assert!((s.fleet.as_ref().unwrap().latency_ms.median - 60.0).abs() < 1e-9);
        // ...while each stage collector sees its own transit latency, so
        // the bottleneck stage is visible in the per-replica percentiles
        let stage_medians: Vec<f64> = s
            .per_replica
            .iter()
            .map(|r| r.as_ref().unwrap().latency_ms.median)
            .collect();
        assert!((stage_medians[0] - 10.0).abs() < 1e-9);
        assert!((stage_medians[1] - 40.0).abs() < 1e-9);
        assert!((stage_medians[2] - 10.0).abs() < 1e-9);
        // each stage reports its own batch size, not the final stage's
        let stage_batches: Vec<f64> = s
            .per_replica
            .iter()
            .map(|r| r.as_ref().unwrap().mean_batch)
            .collect();
        assert_eq!(stage_batches, vec![4.0, 2.0, 1.0]);
    }
}
