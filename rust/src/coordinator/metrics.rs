//! Serving metrics: latency distribution, throughput, batch-size histogram.

use std::time::Duration;

use crate::util::stats::{summarize, Summary};

/// Collects per-request completions.
#[derive(Default)]
pub struct Metrics {
    latencies_ms: Vec<f64>,
    batch_sizes: Vec<usize>,
    started: Option<std::time::Instant>,
    finished: Option<std::time::Instant>,
}

/// Final serving summary (the e2e numbers EXPERIMENTS.md records).
#[derive(Clone, Debug)]
pub struct ServeSummary {
    pub requests: usize,
    pub wall_s: f64,
    pub throughput_fps: f64,
    pub latency_ms: Summary,
    pub mean_batch: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn start(&mut self) {
        self.started = Some(std::time::Instant::now());
    }

    pub fn record(&mut self, latency: Duration, batch_size: usize) {
        self.latencies_ms.push(latency.as_secs_f64() * 1e3);
        self.batch_sizes.push(batch_size);
        self.finished = Some(std::time::Instant::now());
    }

    pub fn count(&self) -> usize {
        self.latencies_ms.len()
    }

    pub fn summary(&self) -> ServeSummary {
        assert!(!self.latencies_ms.is_empty(), "no completions recorded");
        let wall = match (self.started, self.finished) {
            (Some(a), Some(b)) => (b - a).as_secs_f64(),
            _ => 0.0,
        };
        ServeSummary {
            requests: self.latencies_ms.len(),
            wall_s: wall,
            throughput_fps: self.latencies_ms.len() as f64 / wall.max(1e-9),
            latency_ms: summarize(&self.latencies_ms),
            mean_batch: self.batch_sizes.iter().sum::<usize>() as f64
                / self.batch_sizes.len() as f64,
        }
    }
}

impl std::fmt::Display for ServeSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} requests in {:.2}s => {:.1} FPS | latency ms: p50 {:.2} p95 {:.2} p99 {:.2} max {:.2} | mean batch {:.2}",
            self.requests,
            self.wall_s,
            self.throughput_fps,
            self.latency_ms.median,
            self.latency_ms.p95,
            self.latency_ms.p99,
            self.latency_ms.max,
            self.mean_batch
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_math() {
        let mut m = Metrics::new();
        m.start();
        for i in 0..10 {
            m.record(Duration::from_millis(10 + i), 2);
        }
        let s = m.summary();
        assert_eq!(s.requests, 10);
        assert_eq!(s.mean_batch, 2.0);
        assert!(s.latency_ms.median >= 10.0);
        assert!(s.throughput_fps > 0.0);
    }

    #[test]
    #[should_panic]
    fn empty_summary_panics() {
        Metrics::new().summary();
    }
}
