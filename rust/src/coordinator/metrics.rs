//! Serving metrics: latency percentiles, throughput and batch-size
//! statistics — fleet-wide, per chain group (end-to-end) and per worker
//! (per-stage transit for chains) — plus the admission-control counters
//! (submitted / shed) the overload experiments report and the hot-path
//! profile ([`crate::coordinator::HotPathStats`]).
//!
//! Latencies stream into fixed-bucket log-scale histograms
//! ([`crate::util::hist::LogHistogram`]) rather than a growing `Vec`:
//! recording a completion is allocation-free and summarizing never
//! sorts. Percentiles are exact to within one bucket width (±2.2 %
//! relative); count, mean, stddev, min and max stay exact.

use std::time::Duration;

use super::hotpath::HotPathStats;
use super::Completion;
use crate::util::hist::LogHistogram;
use crate::util::stats::Summary;

/// Collects per-request completions for one stream (one worker, one chain
/// group, or the whole fleet when driven through [`FleetMetrics`]).
/// Fixed-size: a `LogHistogram` plus a few counters, no per-completion
/// growth.
#[derive(Default)]
pub struct Metrics {
    hist: LogHistogram,
    batch_sum: u64,
    started: Option<std::time::Instant>,
    finished: Option<std::time::Instant>,
    /// Virtual-time span override (seconds). Wall-clock `Instant`s are
    /// meaningless to a discrete-event driver, so the simulator sets the
    /// span explicitly and `summary` prefers it over `started..finished`.
    span_override: Option<f64>,
}

/// Final serving summary for one stream: request count, wall-clock span,
/// throughput, the latency distribution (p50/p95/p99 via
/// [`crate::util::stats::Summary`]) and the mean ridden batch size.
#[derive(Clone, Debug)]
pub struct ServeSummary {
    /// Completions recorded.
    pub requests: usize,
    /// Wall-clock seconds from [`Metrics::start`] to the last completion.
    pub wall_s: f64,
    /// `requests / wall_s`.
    pub throughput_fps: f64,
    /// Latency distribution in milliseconds (median = p50, plus p95/p99).
    pub latency_ms: Summary,
    /// Mean size of the batches the requests rode in.
    pub mean_batch: f64,
}

impl Metrics {
    /// Empty collector.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Mark the start of the measurement window. Never clobbers a
    /// virtual-time span set with [`Metrics::set_span_s`], so the two
    /// calls compose in either order.
    pub fn start(&mut self) {
        self.started = Some(std::time::Instant::now());
    }

    /// Override the measurement span with `span_s` virtual seconds.
    ///
    /// Virtual-time drivers ([`crate::sim::fleet::FleetSim`]) record
    /// simulated latencies but cannot use wall-clock `Instant`s for the
    /// wall span; this pins `wall_s` (and hence `throughput_fps`) to the
    /// simulated clock instead.
    pub fn set_span_s(&mut self, span_s: f64) {
        self.span_override = Some(span_s.max(0.0));
    }

    /// Record one completion: two array writes into the histogram plus
    /// counter bumps — no allocation, no growth. A collector that was
    /// never [`Metrics::start`]ed anchors its window at the first
    /// recorded completion, so summaries stay finite in any call order.
    pub fn record(&mut self, latency: Duration, batch_size: usize) {
        self.hist.record(latency.as_secs_f64() * 1e3);
        self.batch_sum += batch_size as u64;
        let now = std::time::Instant::now();
        if self.started.is_none() {
            self.started = Some(now);
        }
        self.finished = Some(now);
    }

    /// Completions recorded so far.
    pub fn count(&self) -> usize {
        self.hist.count() as usize
    }

    /// Summarize; panics when nothing was recorded (see
    /// [`Metrics::try_summary`] for the non-panicking form). Percentiles
    /// come from the histogram (within one bucket width of exact);
    /// mean/min/max/stddev are exact.
    pub fn summary(&self) -> ServeSummary {
        let n = self.count();
        assert!(n > 0, "no completions recorded");
        let wall = self.span_override.unwrap_or(match (self.started, self.finished) {
            (Some(a), Some(b)) => (b - a).as_secs_f64(),
            _ => 0.0,
        });
        ServeSummary {
            requests: n,
            wall_s: wall,
            throughput_fps: n as f64 / wall.max(1e-9),
            latency_ms: self.hist.summary(),
            mean_batch: self.batch_sum as f64 / n as f64,
        }
    }

    /// Summarize, or `None` when nothing was recorded (idle workers).
    pub fn try_summary(&self) -> Option<ServeSummary> {
        if self.count() == 0 {
            None
        } else {
            Some(self.summary())
        }
    }

    /// Fold another collector into this one: bucket-exact histogram
    /// aggregation via [`LogHistogram::merge`], summed batch mass, and
    /// the widest `started..finished` window covering both.
    fn absorb(&mut self, other: &Metrics) {
        self.hist.merge(&other.hist);
        self.batch_sum += other.batch_sum;
        self.started = match (self.started, other.started) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        self.finished = match (self.finished, other.finished) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, b) => a.or(b),
        };
    }
}

impl std::fmt::Display for ServeSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} requests in {:.2}s => {:.1} FPS | latency ms: p50 {:.2} p95 {:.2} p99 {:.2} max {:.2} | mean batch {:.2}",
            self.requests,
            self.wall_s,
            self.throughput_fps,
            self.latency_ms.median,
            self.latency_ms.p95,
            self.latency_ms.p99,
            self.latency_ms.max,
            self.mean_batch
        )
    }
}

/// Fleet-wide metrics shaped to a deployment: one [`Metrics`] per worker,
/// one per chain group (end-to-end), one for the whole fleet, and the
/// admission-control counters.
pub struct FleetMetrics {
    /// Window anchor for the derived fleet view (`start` /
    /// `set_span_s`); completions themselves land in `per_group` or
    /// `orphans` and the fleet summary merges their histograms, so
    /// fleet percentiles keep full bucket precision with no
    /// double-recording.
    fleet: Metrics,
    per_group: Vec<Metrics>,
    /// Completions from outside the configured shape (unknown group):
    /// counted fleet-wide, attributed to no group or worker.
    orphans: Metrics,
    per_replica: Vec<Metrics>,
    /// Flat worker offset of each group (`per_replica[offsets[g] + s]` is
    /// stage `s` of group `g`).
    offsets: Vec<usize>,
    /// Configured stage count per group — per-stage writes are bounded by
    /// it so a shape-mismatched completion can never bleed into the next
    /// group's worker slots.
    sizes: Vec<usize>,
    submitted: usize,
    shed: usize,
    /// Sheds by the deadline-feasibility admission rule — disjoint from
    /// the queue-full `shed` counter, so overload and infeasibility stay
    /// distinguishable in the summary and the journal.
    deadline_shed: usize,
    hot: HotPathStats,
    /// Group → owning tenant ([`crate::tenancy`]); empty = single-tenant
    /// (every per-tenant surface stays silent).
    tenants: Vec<usize>,
    /// Per-tenant end-to-end collectors (indexed by tenant id).
    per_tenant: Vec<Metrics>,
    /// Per-tenant admission counters, parallel to `per_tenant`.
    t_submitted: Vec<usize>,
    t_shed: Vec<usize>,
    t_deadline_shed: Vec<usize>,
    /// Completions inside the tenant's SLO budget (goodput numerator).
    t_goodput: Vec<usize>,
    /// Per-tenant SLO budget (ms) goodput is judged against; `NAN`
    /// entries count every completion as good.
    t_slo_ms: Vec<f64>,
}

/// Per-tenant slice of a [`FleetSummary`]: admission counters, the
/// latency view over the tenant's own groups, and goodput — completions
/// that landed inside the tenant's SLO budget.
#[derive(Clone, Debug)]
pub struct TenantSummary {
    /// Tenant id (dense, catalog order).
    pub tenant: usize,
    /// Accepted submissions for this tenant.
    pub submitted: usize,
    /// Queue-full sheds for this tenant.
    pub shed: usize,
    /// Deadline-infeasible sheds for this tenant.
    pub deadline_shed: usize,
    /// Completions recorded against this tenant's groups.
    pub completed: usize,
    /// Completions whose end-to-end latency was within the tenant's SLO
    /// budget — the goodput numerator (== `completed` when no budget was
    /// configured).
    pub goodput: usize,
    /// The SLO budget (ms) goodput was judged against, if configured.
    pub slo_ms: Option<f64>,
    /// Latency/throughput view over the tenant's completions.
    pub latency: Option<ServeSummary>,
}

/// Fleet summary: the fleet-wide view, the per-chain-group end-to-end
/// breakdown (the replicated-chain experiments read group p99 here), the
/// per-worker breakdown (per-stage transit for chains; idle workers
/// report `None`) and the admission-control counters.
#[derive(Clone, Debug)]
pub struct FleetSummary {
    /// Fleet-wide summary; `None` when nothing completed.
    pub fleet: Option<ServeSummary>,
    /// Per-chain-group **end-to-end** summaries (queue + every stage +
    /// links), in router order; `None` for groups that served nothing.
    pub per_group: Vec<Option<ServeSummary>>,
    /// Per-worker summaries, flat in group-then-stage order; for chain
    /// groups each entry is that *stage's* transit latency, so the slow
    /// stage is localizable while [`FleetSummary::per_group`] answers the
    /// SLO question.
    pub per_replica: Vec<Option<ServeSummary>>,
    /// Requests accepted by admission control.
    pub submitted: usize,
    /// Requests shed because every group entry queue was full.
    pub shed: usize,
    /// Requests shed by the deadline-feasibility rule (multi-tenant
    /// admission; zero unless deadlines were configured).
    pub deadline_shed: usize,
    /// Per-tenant breakdown, indexed by tenant id; empty for
    /// single-tenant runs that never called [`FleetMetrics::set_tenants`].
    pub per_tenant: Vec<TenantSummary>,
    /// Hot-path profile: submit fast-path hit rate, fallback scans,
    /// backoff sleeps and buffer-pool recycling counters (see
    /// [`crate::coordinator::HotPathStats`]). All zero unless the driver
    /// installed a snapshot via [`FleetMetrics::set_hot`].
    pub hot: HotPathStats,
}

impl FleetMetrics {
    /// Empty collectors for a deployment with the given per-group stage
    /// counts (`group_sizes[g]` workers in group `g`); `&[1, 1, 1]` is a
    /// flat 3-replica fleet, `&[3]` a single 3-stage chain.
    pub fn new(group_sizes: &[usize]) -> FleetMetrics {
        let mut offsets = Vec::with_capacity(group_sizes.len());
        let mut total = 0usize;
        for &k in group_sizes {
            offsets.push(total);
            total += k.max(1);
        }
        FleetMetrics {
            fleet: Metrics::new(),
            per_group: group_sizes.iter().map(|_| Metrics::new()).collect(),
            orphans: Metrics::new(),
            per_replica: (0..total).map(|_| Metrics::new()).collect(),
            offsets,
            sizes: group_sizes.iter().map(|&k| k.max(1)).collect(),
            submitted: 0,
            shed: 0,
            deadline_shed: 0,
            hot: HotPathStats::default(),
            tenants: Vec::new(),
            per_tenant: Vec::new(),
            t_submitted: Vec::new(),
            t_shed: Vec::new(),
            t_deadline_shed: Vec::new(),
            t_goodput: Vec::new(),
            t_slo_ms: Vec::new(),
        }
    }

    /// Enable per-tenant accounting: `tenants[g]` is the tenant owning
    /// group `g` (see [`crate::coordinator::Deployment::group_tenants`]).
    /// Sizes every per-tenant surface to `max(tenant) + 1`.
    pub fn set_tenants(&mut self, tenants: Vec<usize>) {
        let n = tenants.iter().copied().max().unwrap_or(0) + 1;
        self.tenants = tenants;
        self.per_tenant = (0..n).map(|_| Metrics::new()).collect();
        self.t_submitted = vec![0; n];
        self.t_shed = vec![0; n];
        self.t_deadline_shed = vec![0; n];
        self.t_goodput = vec![0; n];
        if self.t_slo_ms.len() != n {
            self.t_slo_ms = vec![f64::NAN; n];
        }
    }

    /// Per-tenant SLO budgets (ms) goodput is judged against; call after
    /// [`FleetMetrics::set_tenants`]. Missing entries count everything
    /// as good.
    pub fn set_tenant_slos_ms(&mut self, slos: Vec<f64>) {
        self.t_slo_ms = slos;
        if self.t_slo_ms.len() < self.per_tenant.len() {
            self.t_slo_ms.resize(self.per_tenant.len(), f64::NAN);
        }
    }

    /// Tenant owning group `g` (0 when per-tenant accounting is off).
    fn tenant_of(&self, g: usize) -> usize {
        self.tenants.get(g).copied().unwrap_or(0)
    }

    /// Collectors for a flat fleet of `workers` 1-stage groups.
    pub fn flat(workers: usize) -> FleetMetrics {
        FleetMetrics::new(&vec![1; workers])
    }

    /// Mark the start of the measurement window on every collector.
    pub fn start(&mut self) {
        self.fleet.start();
        self.orphans.start();
        for m in &mut self.per_group {
            m.start();
        }
        for m in &mut self.per_replica {
            m.start();
        }
        for m in &mut self.per_tenant {
            m.start();
        }
    }

    /// Override the measurement span on every collector with `span_s`
    /// virtual seconds (see [`Metrics::set_span_s`]). Used by the
    /// discrete-event simulator so throughput reads in simulated, not
    /// host, time.
    pub fn set_span_s(&mut self, span_s: f64) {
        self.fleet.set_span_s(span_s);
        self.orphans.set_span_s(span_s);
        for m in &mut self.per_group {
            m.set_span_s(span_s);
        }
        for m in &mut self.per_replica {
            m.set_span_s(span_s);
        }
        for m in &mut self.per_tenant {
            m.set_span_s(span_s);
        }
    }

    /// Record a completion against the fleet, its chain group and its
    /// serving worker(s).
    ///
    /// The group collector sees the end-to-end latency; the fleet view
    /// is *derived* at summary time by merging every group histogram
    /// (plus the orphan bucket) via [`LogHistogram::merge`], so nothing
    /// is recorded twice and fleet percentiles keep full bucket
    /// precision. Chain completions (non-empty
    /// [`Completion::stage_latencies`]) split the worker view
    /// differently: each stage's collector sees that *stage's* transit
    /// latency, so per-worker percentiles localize the slow stage.
    /// Completions from outside the configured shape — an unknown
    /// group, or stages beyond the group's configured depth — are
    /// counted fleet-wide only (never attributed to a neighbouring
    /// group's worker slots).
    pub fn record(&mut self, c: &Completion) {
        match self.per_group.get_mut(c.group) {
            Some(m) => m.record(c.latency, c.batch_size),
            None => {
                self.orphans.record(c.latency, c.batch_size);
                return;
            }
        }
        if !self.per_tenant.is_empty() {
            let t = self.tenant_of(c.group);
            if let Some(m) = self.per_tenant.get_mut(t) {
                m.record(c.latency, c.batch_size);
                let slo = self.t_slo_ms.get(t).copied().unwrap_or(f64::NAN);
                // an unconfigured (NaN) budget counts everything as good
                if slo.is_nan() || c.latency.as_secs_f64() * 1e3 <= slo {
                    self.t_goodput[t] += 1;
                }
            }
        }
        let Some(&base) = self.offsets.get(c.group) else { return };
        let size = self.sizes[c.group];
        if c.stage_latencies.is_empty() {
            if c.stage < size {
                if let Some(m) = self.per_replica.get_mut(base + c.stage) {
                    m.record(c.latency, c.batch_size);
                }
            }
        } else {
            for (i, &lat) in c.stage_latencies.iter().take(size).enumerate() {
                if let Some(m) = self.per_replica.get_mut(base + i) {
                    let batch = c.stage_batches.get(i).copied().unwrap_or(c.batch_size);
                    m.record(lat, batch);
                }
            }
        }
    }

    /// Count one accepted submission.
    pub fn record_submitted(&mut self) {
        self.submitted += 1;
    }

    /// Count one shed (admission-control rejected) submission.
    pub fn record_shed(&mut self) {
        self.shed += 1;
    }

    /// Count one accepted submission for `tenant` (also counts
    /// fleet-wide).
    pub fn record_submitted_for(&mut self, tenant: usize) {
        self.submitted += 1;
        if let Some(c) = self.t_submitted.get_mut(tenant) {
            *c += 1;
        }
    }

    /// Count one queue-full shed for `tenant` (also counts fleet-wide).
    pub fn record_shed_for(&mut self, tenant: usize) {
        self.shed += 1;
        if let Some(c) = self.t_shed.get_mut(tenant) {
            *c += 1;
        }
    }

    /// Count one deadline-infeasible shed for `tenant`. Kept disjoint
    /// from [`FleetMetrics::record_shed`] so the summary distinguishes
    /// overload (queue full) from infeasibility (budget can't cover the
    /// estimated sojourn).
    pub fn record_deadline_shed(&mut self, tenant: usize) {
        self.deadline_shed += 1;
        if let Some(c) = self.t_deadline_shed.get_mut(tenant) {
            *c += 1;
        }
    }

    /// Deadline-infeasible sheds so far.
    pub fn deadline_shed(&self) -> usize {
        self.deadline_shed
    }

    /// Completions recorded so far (every group plus out-of-shape
    /// orphans).
    pub fn completed(&self) -> usize {
        self.per_group.iter().map(Metrics::count).sum::<usize>() + self.orphans.count()
    }

    /// Accepted submissions so far.
    pub fn submitted(&self) -> usize {
        self.submitted
    }

    /// Shed submissions so far.
    pub fn shed(&self) -> usize {
        self.shed
    }

    /// The cumulative fleet-wide end-to-end latency histogram: every
    /// per-group histogram plus the orphan bucket merged bucket-exactly
    /// via [`LogHistogram::merge`]. Allocates one histogram per call, so
    /// callers sample it on a snapshot cadence (the health monitor's
    /// interval-percentile diffs), never per request.
    pub fn latency_histogram(&self) -> LogHistogram {
        let mut h = LogHistogram::new();
        for m in &self.per_group {
            h.merge(&m.hist);
        }
        h.merge(&self.orphans.hist);
        h
    }

    /// Install a hot-path profile snapshot (typically
    /// [`crate::coordinator::Server::hot_stats`] taken at the end of the
    /// run) so it rides along in the [`FleetSummary`].
    pub fn set_hot(&mut self, hot: HotPathStats) {
        self.hot = hot;
    }

    /// Summarize fleet, groups and workers. The fleet view is built
    /// here by folding every per-group histogram (and the orphan
    /// bucket) into one collector with [`LogHistogram::merge`] — same
    /// buckets, element-wise counts, exact moment sums — anchored to
    /// the window marked on the fleet collector by
    /// [`FleetMetrics::start`] / [`FleetMetrics::set_span_s`].
    pub fn summary(&self) -> FleetSummary {
        let mut fleet = Metrics::new();
        fleet.started = self.fleet.started;
        fleet.span_override = self.fleet.span_override;
        for m in &self.per_group {
            fleet.absorb(m);
        }
        fleet.absorb(&self.orphans);
        let per_tenant = self
            .per_tenant
            .iter()
            .enumerate()
            .map(|(t, m)| {
                let slo = self.t_slo_ms.get(t).copied().unwrap_or(f64::NAN);
                TenantSummary {
                    tenant: t,
                    submitted: self.t_submitted[t],
                    shed: self.t_shed[t],
                    deadline_shed: self.t_deadline_shed[t],
                    completed: m.count(),
                    goodput: self.t_goodput[t],
                    slo_ms: if slo.is_finite() { Some(slo) } else { None },
                    latency: m.try_summary(),
                }
            })
            .collect();
        FleetSummary {
            fleet: fleet.try_summary(),
            per_group: self.per_group.iter().map(Metrics::try_summary).collect(),
            per_replica: self.per_replica.iter().map(Metrics::try_summary).collect(),
            submitted: self.submitted,
            shed: self.shed,
            deadline_shed: self.deadline_shed,
            per_tenant,
            hot: self.hot,
        }
    }
}

impl std::fmt::Display for FleetSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.fleet {
            Some(s) => write!(f, "fleet: {s} | submitted {} shed {}", self.submitted, self.shed)?,
            None => write!(
                f,
                "fleet: no completions | submitted {} shed {}",
                self.submitted, self.shed
            )?,
        }
        if self.deadline_shed > 0 {
            write!(f, " deadline-shed {}", self.deadline_shed)?;
        }
        for t in &self.per_tenant {
            write!(
                f,
                "\n  tenant {}: submitted {} shed {} deadline-shed {} completed {} goodput {}",
                t.tenant, t.submitted, t.shed, t.deadline_shed, t.completed, t.goodput
            )?;
            if let Some(slo) = t.slo_ms {
                write!(f, " (slo {slo:.1} ms)")?;
            }
            if let Some(s) = &t.latency {
                write!(f, "\n    {s}")?;
            }
        }
        // the group view adds information only when groups are chains
        // (for flat fleets it would duplicate the per-worker lines)
        if self.per_group.len() != self.per_replica.len() {
            for (g, s) in self.per_group.iter().enumerate() {
                match s {
                    Some(s) => write!(f, "\n  group {g} (e2e): {s}")?,
                    None => write!(f, "\n  group {g} (e2e): idle")?,
                }
            }
        }
        for (i, s) in self.per_replica.iter().enumerate() {
            match s {
                Some(s) => write!(f, "\n  replica {i}: {s}")?,
                None => write!(f, "\n  replica {i}: idle")?,
            }
        }
        if self.hot.submits > 0 {
            write!(
                f,
                "\n  hot path: {} submits ({} first-try, {} fallback scans, {} backoff sleeps) | pool: {} hits {} misses {} returns ({} rejected, {} lock waits)",
                self.hot.submits,
                self.hot.accepted_first_try,
                self.hot.fallback_scans,
                self.hot.backoff_sleeps,
                self.hot.pool_hits,
                self.hot.pool_misses,
                self.hot.pool_returns,
                self.hot.pool_rejected,
                self.hot.lock_waits,
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Percentiles now come off the log histogram, whose bucket width is
    /// ±2.2 % relative — assert within 3 % instead of exactly.
    fn close(got: f64, want: f64) -> bool {
        (got - want).abs() <= want * 0.03
    }

    #[test]
    fn summary_math() {
        let mut m = Metrics::new();
        m.start();
        for i in 0..10 {
            m.record(Duration::from_millis(10 + i), 2);
        }
        let s = m.summary();
        assert_eq!(s.requests, 10);
        assert_eq!(s.mean_batch, 2.0);
        assert!(s.latency_ms.median >= 10.0);
        assert!(s.throughput_fps > 0.0);
    }

    #[test]
    #[should_panic]
    fn empty_summary_panics() {
        Metrics::new().summary();
    }

    #[test]
    fn try_summary_is_total() {
        assert!(Metrics::new().try_summary().is_none());
        let mut m = Metrics::new();
        m.start();
        m.record(Duration::from_millis(3), 1);
        assert_eq!(m.try_summary().unwrap().requests, 1);
    }

    fn completion(id: u64, group: usize, ms: u64, batch: usize) -> Completion {
        Completion {
            id,
            output: vec![0.0],
            latency: Duration::from_millis(ms),
            batch_size: batch,
            group,
            stage: 0,
            stage_latencies: Vec::new(),
            stage_batches: Vec::new(),
            span: None,
        }
    }

    #[test]
    fn fleet_metrics_split_by_group() {
        let mut fm = FleetMetrics::flat(3);
        fm.start();
        for i in 0..6 {
            fm.record_submitted();
            fm.record(&completion(i, (i % 2) as usize, 5 + i, 2));
        }
        fm.record_shed();
        assert_eq!(fm.completed(), 6);
        assert_eq!(fm.submitted(), 6);
        assert_eq!(fm.shed(), 1);
        let s = fm.summary();
        assert_eq!(s.fleet.as_ref().unwrap().requests, 6);
        assert_eq!(s.per_replica[0].as_ref().unwrap().requests, 3);
        assert_eq!(s.per_replica[1].as_ref().unwrap().requests, 3);
        assert!(s.per_replica[2].is_none(), "group 2 never served");
        // flat fleets mirror the worker view in the group view
        assert_eq!(s.per_group[0].as_ref().unwrap().requests, 3);
        // the display renders fleet and per-worker lines (group lines are
        // suppressed for flat fleets — they would be duplicates)
        let text = format!("{s}");
        assert!(text.contains("replica 2: idle"), "{text}");
        assert!(!text.contains("group 2"), "{text}");
        assert!(text.contains("shed 1"), "{text}");
    }

    #[test]
    fn out_of_range_group_ignored_gracefully() {
        let mut fm = FleetMetrics::flat(1);
        fm.start();
        fm.record(&completion(0, 5, 1, 1));
        assert_eq!(fm.completed(), 1);
        assert!(fm.summary().per_replica[0].is_none());
        assert!(fm.summary().per_group[0].is_none());
    }

    #[test]
    fn stage_overflow_never_bleeds_into_the_next_group() {
        // two 1-stage groups; a malformed completion claiming group 0 ran
        // 2 chain stages (or a flat stage index of 1) must not land its
        // extra latency in group 1's worker slot
        let mut fm = FleetMetrics::new(&[1, 1]);
        fm.start();
        let mut chained = completion(0, 0, 20, 1);
        chained.stage_latencies = vec![Duration::from_millis(10), Duration::from_millis(10)];
        chained.stage_batches = vec![1, 1];
        fm.record(&chained);
        let mut flat = completion(1, 0, 5, 1);
        flat.stage = 1;
        fm.record(&flat);
        let s = fm.summary();
        // both counted fleet-wide and against group 0's e2e view...
        assert_eq!(s.fleet.as_ref().unwrap().requests, 2);
        assert_eq!(s.per_group[0].as_ref().unwrap().requests, 2);
        // ...group 0's worker saw only its one in-shape stage, and group
        // 1's worker saw nothing at all
        assert_eq!(s.per_replica[0].as_ref().unwrap().requests, 1);
        assert!(s.per_replica[1].is_none(), "stage overflow bled into group 1");
    }

    #[test]
    fn chain_completions_split_per_stage_and_end_to_end() {
        let mut fm = FleetMetrics::new(&[3]);
        fm.start();
        for i in 0..4 {
            let mut c = completion(i, 0, 60, 1);
            c.stage = 2;
            c.stage_latencies = vec![
                Duration::from_millis(10),
                Duration::from_millis(40),
                Duration::from_millis(10),
            ];
            c.stage_batches = vec![4, 2, 1];
            fm.record(&c);
        }
        let s = fm.summary();
        // the fleet and the group see the end-to-end latency...
        assert!(close(s.fleet.as_ref().unwrap().latency_ms.median, 60.0));
        assert!(close(s.per_group[0].as_ref().unwrap().latency_ms.median, 60.0));
        // ...while each stage collector sees its own transit latency, so
        // the bottleneck stage is visible in the per-worker percentiles
        let stage_medians: Vec<f64> = s
            .per_replica
            .iter()
            .map(|r| r.as_ref().unwrap().latency_ms.median)
            .collect();
        assert!(close(stage_medians[0], 10.0), "{stage_medians:?}");
        assert!(close(stage_medians[1], 40.0), "{stage_medians:?}");
        assert!(close(stage_medians[2], 10.0), "{stage_medians:?}");
        // each stage reports its own batch size, not the final stage's
        let stage_batches: Vec<f64> = s
            .per_replica
            .iter()
            .map(|r| r.as_ref().unwrap().mean_batch)
            .collect();
        assert_eq!(stage_batches, vec![4.0, 2.0, 1.0]);
        // chained shape: the display carries the group e2e line
        let text = format!("{s}");
        assert!(text.contains("group 0 (e2e)"), "{text}");
    }

    #[test]
    fn replicated_chains_report_per_group_e2e_p99() {
        // 2 groups × 2 stages; group 1 is twice as slow end-to-end
        let mut fm = FleetMetrics::new(&[2, 2]);
        fm.start();
        for i in 0..8 {
            let g = (i % 2) as usize;
            let ms = if g == 0 { 20 } else { 40 };
            let mut c = completion(i, g, ms, 1);
            c.stage = 1;
            c.stage_latencies =
                vec![Duration::from_millis(ms / 2), Duration::from_millis(ms / 2)];
            c.stage_batches = vec![1, 1];
            fm.record(&c);
        }
        let s = fm.summary();
        assert_eq!(s.per_group.len(), 2);
        assert_eq!(s.per_replica.len(), 4);
        let g0 = s.per_group[0].as_ref().unwrap();
        let g1 = s.per_group[1].as_ref().unwrap();
        assert!(close(g0.latency_ms.p99, 20.0), "{}", g0.latency_ms.p99);
        assert!(close(g1.latency_ms.p99, 40.0), "{}", g1.latency_ms.p99);
        // group 1's stages land in flat worker slots 2 and 3
        assert!(close(s.per_replica[2].as_ref().unwrap().latency_ms.median, 20.0));
    }

    #[test]
    fn histogram_summary_tracks_exact_percentiles() {
        // cross-check the Metrics-level view against the exact sorted
        // computation (the histogram itself is cross-checked at scale in
        // util::hist); min/max/mean are exact, percentiles within bucket
        // tolerance
        let mut m = Metrics::new();
        m.start();
        let samples: Vec<f64> = (1..=200).map(|i| i as f64 * 0.37).collect();
        for &ms in &samples {
            m.record(Duration::from_secs_f64(ms * 1e-3), 1);
        }
        let got = m.summary().latency_ms;
        let exact = crate::util::stats::summarize(&samples);
        assert_eq!(got.min, exact.min);
        assert_eq!(got.max, exact.max);
        assert!((got.mean - exact.mean).abs() < 1e-6);
        assert!(close(got.median, exact.median), "{} vs {}", got.median, exact.median);
        assert!(close(got.p95, exact.p95), "{} vs {}", got.p95, exact.p95);
        assert!(close(got.p99, exact.p99), "{} vs {}", got.p99, exact.p99);
    }

    #[test]
    fn fleet_view_is_the_bucket_exact_merge_of_group_histograms() {
        // two groups with disjoint latency ranges plus one orphan; the
        // fleet percentiles must match recording the same values into a
        // single collector (merge is element-wise on identical buckets)
        let mut fm = FleetMetrics::flat(2);
        let mut whole = Metrics::new();
        fm.start();
        whole.start();
        for i in 0..40u64 {
            let ms = 5 + (i % 20) * 7;
            fm.record(&completion(i, (i % 2) as usize, ms, 1));
            whole.record(Duration::from_millis(ms), 1);
        }
        fm.record(&completion(99, 9, 250, 1)); // unknown group → orphan
        whole.record(Duration::from_millis(250), 1);
        assert_eq!(fm.completed(), 41);
        let got = fm.summary().fleet.unwrap().latency_ms;
        let want = whole.summary().latency_ms;
        assert_eq!(got.median, want.median);
        assert_eq!(got.p99, want.p99);
        assert_eq!(got.min, want.min);
        assert_eq!(got.max, want.max);
    }

    #[test]
    fn span_override_survives_any_call_order() {
        // set_span_s before start (the sim configures its virtual span
        // up front, then the driver calls start) must behave exactly
        // like the reverse order: the virtual span wins
        let mut a = FleetMetrics::flat(1);
        a.set_span_s(2.0);
        a.start();
        a.record(&completion(0, 0, 5, 1));
        let mut b = FleetMetrics::flat(1);
        b.start();
        b.set_span_s(2.0);
        b.record(&completion(0, 0, 5, 1));
        let (sa, sb) = (a.summary().fleet.unwrap(), b.summary().fleet.unwrap());
        assert_eq!(sa.wall_s, 2.0);
        assert_eq!(sb.wall_s, 2.0);
        assert_eq!(sa.throughput_fps, sb.throughput_fps);
    }

    #[test]
    fn record_without_start_anchors_the_window_and_stays_finite() {
        let mut m = Metrics::new();
        m.record(Duration::from_millis(5), 1);
        std::thread::sleep(Duration::from_millis(2));
        m.record(Duration::from_millis(5), 1);
        let s = m.summary();
        assert!(s.wall_s > 0.0, "window anchored at first record");
        assert!(s.throughput_fps.is_finite());
        // the fleet aggregate inherits the same ordering independence
        let mut fm = FleetMetrics::flat(1);
        fm.record(&completion(0, 0, 5, 1));
        assert!(fm.summary().fleet.unwrap().throughput_fps.is_finite());
    }

    #[test]
    fn tenant_accounting_splits_counters_and_goodput() {
        // groups 0,1 belong to tenant 0; group 2 to tenant 1
        let mut fm = FleetMetrics::flat(3);
        fm.set_tenants(vec![0, 0, 1]);
        fm.set_tenant_slos_ms(vec![10.0, 25.0]);
        fm.start();
        // tenant 0: one fast (in SLO), one slow (out of SLO)
        fm.record_submitted_for(0);
        fm.record(&completion(0, 0, 5, 1));
        fm.record_submitted_for(0);
        fm.record(&completion(1, 1, 50, 1));
        // tenant 1: one fast, plus one queue-full and one deadline shed
        fm.record_submitted_for(1);
        fm.record(&completion(2, 2, 20, 1));
        fm.record_shed_for(1);
        fm.record_deadline_shed(1);
        assert_eq!(fm.submitted(), 3);
        assert_eq!(fm.shed(), 1);
        assert_eq!(fm.deadline_shed(), 1);
        let s = fm.summary();
        assert_eq!(s.per_tenant.len(), 2);
        let (t0, t1) = (&s.per_tenant[0], &s.per_tenant[1]);
        assert_eq!((t0.submitted, t0.completed, t0.goodput), (2, 2, 1));
        assert_eq!((t0.shed, t0.deadline_shed), (0, 0));
        assert_eq!((t1.submitted, t1.completed, t1.goodput), (1, 1, 1));
        assert_eq!((t1.shed, t1.deadline_shed), (1, 1));
        assert_eq!(t0.slo_ms, Some(10.0));
        assert_eq!(t1.latency.as_ref().unwrap().requests, 1);
        let text = format!("{s}");
        assert!(text.contains("deadline-shed 1"), "{text}");
        assert!(text.contains("tenant 0: submitted 2"), "{text}");
        assert!(text.contains("tenant 1: submitted 1"), "{text}");
    }

    #[test]
    fn single_tenant_summary_keeps_tenant_surfaces_silent() {
        let mut fm = FleetMetrics::flat(2);
        fm.start();
        fm.record_submitted();
        fm.record(&completion(0, 0, 5, 1));
        let s = fm.summary();
        assert!(s.per_tenant.is_empty());
        assert_eq!(s.deadline_shed, 0);
        let text = format!("{s}");
        assert!(!text.contains("tenant"), "{text}");
        assert!(!text.contains("deadline-shed"), "{text}");
    }

    #[test]
    fn hot_path_profile_rides_the_fleet_summary() {
        let mut fm = FleetMetrics::flat(1);
        fm.start();
        fm.record(&completion(0, 0, 5, 1));
        // before a snapshot is installed the line is suppressed
        assert!(!format!("{}", fm.summary()).contains("hot path"));
        let hot = HotPathStats {
            submits: 10,
            accepted_first_try: 9,
            pool_hits: 7,
            pool_misses: 3,
            ..HotPathStats::default()
        };
        fm.set_hot(hot);
        let s = fm.summary();
        assert_eq!(s.hot, hot);
        let text = format!("{s}");
        assert!(text.contains("hot path: 10 submits (9 first-try"), "{text}");
    }
}
