//! Pluggable scheduling policies for the multi-replica router.
//!
//! The router calls [`Scheduler::pick`] with the current per-replica
//! outstanding-request counts and gets back the replica index to try first.
//! All three policies are **deterministic**: given the same sequence of
//! `pick` calls with the same observed counts they produce the same replica
//! sequence, which is what the policy unit tests and the serving integration
//! tests assert exact dispatch counts against.
//!
//! * [`Policy::RoundRobin`] — cycle through replicas in fixed order,
//!   ignoring load. Optimal for a homogeneous fleet under smooth arrivals.
//! * [`Policy::JoinShortestQueue`] — send each request to the replica with
//!   the fewest outstanding requests (queued + executing), ties broken
//!   toward the lowest index. Adapts to heterogeneous service rates without
//!   knowing them.
//! * [`Policy::Weighted`] — smooth weighted round-robin (the nginx SWRR
//!   algorithm) over per-replica capacity weights. For heterogeneous fleets
//!   the weights come from the analytic `sim`/`timing` throughput model of
//!   each replica's device + FCMP operating point
//!   (see [`crate::coordinator::capacity`]).

/// Which replica the router hands the next request to.
#[derive(Clone, Debug, PartialEq)]
pub enum Policy {
    /// Fixed-order cycling, load-blind.
    RoundRobin,
    /// Least outstanding requests (queued + executing); ties to lowest index.
    JoinShortestQueue,
    /// Smooth weighted round-robin over per-replica capacity weights
    /// (requests/s from the analytic model; any positive scale works).
    Weighted(Vec<f64>),
    /// The replicas form a pipeline-parallel stage chain
    /// ([`crate::coordinator::Server::start_chain`]): every new frame
    /// enters stage 0 and the stages forward it 0→1→…→k-1 themselves, so
    /// the router always picks 0 and never falls back to a mid-chain
    /// stage.
    StageChain,
}

impl Policy {
    /// Parse a CLI policy name. `weights` are the capacity weights consumed
    /// by the `weighted` policy and ignored by the other two.
    /// [`Policy::StageChain`] is deliberately not parseable: it only makes
    /// sense for fleets built by `Server::start_chain`, which sets it
    /// itself — on a replicated fleet it would silently pin every request
    /// to replica 0.
    pub fn by_name(name: &str, weights: Vec<f64>) -> Option<Policy> {
        match name {
            "rr" | "round-robin" | "round_robin" => Some(Policy::RoundRobin),
            "jsq" | "shortest" | "join-shortest-queue" => Some(Policy::JoinShortestQueue),
            "weighted" | "capacity" => Some(Policy::Weighted(weights)),
            _ => None,
        }
    }

    /// Short display name (bench rows, log lines).
    pub fn name(&self) -> &'static str {
        match self {
            Policy::RoundRobin => "round-robin",
            Policy::JoinShortestQueue => "jsq",
            Policy::Weighted(_) => "weighted",
            Policy::StageChain => "stage-chain",
        }
    }
}

/// Mutable picker state for one fleet: owns the round-robin cursor and the
/// SWRR credit vector so [`Policy`] itself stays an immutable description.
#[derive(Clone, Debug)]
pub struct Scheduler {
    policy: Policy,
    replicas: usize,
    rr_next: usize,
    weights: Vec<f64>,
    swrr_credit: Vec<f64>,
}

impl Scheduler {
    /// Build a scheduler for `replicas` workers. Weighted policies are
    /// normalized to the fleet size: missing weights default to 1.0, extra
    /// weights are dropped, and non-positive weights are clamped up so no
    /// replica is starved forever.
    pub fn new(policy: Policy, replicas: usize) -> Scheduler {
        assert!(replicas > 0, "scheduler needs at least one replica");
        let mut weights = match &policy {
            Policy::Weighted(w) => w.clone(),
            _ => vec![1.0; replicas],
        };
        weights.resize(replicas, 1.0);
        for w in &mut weights {
            if !w.is_finite() || *w <= 0.0 {
                *w = 1e-3;
            }
        }
        Scheduler {
            policy,
            replicas,
            rr_next: 0,
            swrr_credit: vec![0.0; replicas],
            weights,
        }
    }

    /// The policy this scheduler runs.
    pub fn policy(&self) -> &Policy {
        &self.policy
    }

    /// Pick the preferred replica for the next request. `outstanding[i]`
    /// is replica `i`'s current outstanding-request count (queued +
    /// executing); only [`Policy::JoinShortestQueue`] reads it, so callers
    /// running a load-blind policy may pass an empty slice to skip the
    /// snapshot (JSQ treats an empty slice as all-idle and picks 0).
    pub fn pick(&mut self, outstanding: &[usize]) -> usize {
        debug_assert!(
            outstanding.is_empty() || outstanding.len() == self.replicas,
            "load snapshot arity mismatch"
        );
        match self.policy {
            Policy::RoundRobin => {
                let i = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.replicas;
                i
            }
            Policy::JoinShortestQueue => {
                let mut best = 0;
                for i in 1..outstanding.len().min(self.replicas) {
                    if outstanding[i] < outstanding[best] {
                        best = i;
                    }
                }
                best
            }
            Policy::Weighted(_) => {
                let total: f64 = self.weights.iter().sum();
                let mut best = 0;
                for i in 0..self.replicas {
                    self.swrr_credit[i] += self.weights[i];
                    if self.swrr_credit[i] > self.swrr_credit[best] {
                        best = i;
                    }
                }
                self.swrr_credit[best] -= total;
                best
            }
            // chains always ingest at stage 0; the stages forward onward
            Policy::StageChain => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut s = Scheduler::new(Policy::RoundRobin, 3);
        let picks: Vec<usize> = (0..7).map(|_| s.pick(&[0, 0, 0])).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn jsq_picks_least_outstanding_with_low_index_ties() {
        let mut s = Scheduler::new(Policy::JoinShortestQueue, 3);
        assert_eq!(s.pick(&[4, 1, 2]), 1);
        assert_eq!(s.pick(&[0, 0, 0]), 0);
        assert_eq!(s.pick(&[2, 1, 1]), 1);
        assert_eq!(s.pick(&[3, 3, 0]), 2);
    }

    #[test]
    fn swrr_matches_weight_ratio_exactly() {
        // weights 3:1 => pattern of period 4 with 3 picks of replica 0
        let mut s = Scheduler::new(Policy::Weighted(vec![3.0, 1.0]), 2);
        let picks: Vec<usize> = (0..40).map(|_| s.pick(&[0, 0])).collect();
        let c0 = picks.iter().filter(|&&p| p == 0).count();
        assert_eq!(c0, 30, "picks {picks:?}");
        // smooth: never more than 3 consecutive picks of the heavy replica
        let max_run = picks
            .windows(4)
            .filter(|w| w.iter().all(|&p| p == 0))
            .count();
        assert_eq!(max_run, 0, "SWRR must interleave, got {picks:?}");
    }

    #[test]
    fn swrr_equal_weights_degenerates_to_round_robin() {
        let mut s = Scheduler::new(Policy::Weighted(vec![1.0, 1.0, 1.0]), 3);
        let picks: Vec<usize> = (0..6).map(|_| s.pick(&[0, 0, 0])).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn weight_vector_is_normalized_to_fleet_size() {
        // short vector pads with 1.0; bad weights are clamped positive
        let mut s = Scheduler::new(Policy::Weighted(vec![2.0]), 3);
        let picks: Vec<usize> = (0..8).map(|_| s.pick(&[0, 0, 0])).collect();
        for r in 0..3 {
            assert!(picks.contains(&r), "replica {r} starved: {picks:?}");
        }
        let mut s = Scheduler::new(Policy::Weighted(vec![-1.0, f64::NAN, 1.0]), 3);
        let picks: Vec<usize> = (0..2000).map(|_| s.pick(&[0, 0, 0])).collect();
        assert!(picks.contains(&0) && picks.contains(&1));
    }

    #[test]
    fn policy_names_round_trip() {
        for name in ["round-robin", "jsq", "weighted"] {
            let p = Policy::by_name(name, vec![1.0]).unwrap();
            assert_eq!(p.name(), name);
        }
        assert!(Policy::by_name("magic", vec![]).is_none());
        // stage-chain is not a router policy users can pick for a
        // replicated fleet; only Server::start_chain installs it
        assert!(Policy::by_name("stage-chain", vec![]).is_none());
        assert_eq!(Policy::StageChain.name(), "stage-chain");
    }

    #[test]
    fn stage_chain_always_enters_at_stage_zero() {
        let mut s = Scheduler::new(Policy::StageChain, 4);
        for _ in 0..10 {
            assert_eq!(s.pick(&[5, 0, 0, 0]), 0);
        }
    }

    #[test]
    fn deterministic_for_identical_call_sequences() {
        let mut a = Scheduler::new(Policy::Weighted(vec![1.5, 0.5, 1.0]), 3);
        let mut b = Scheduler::new(Policy::Weighted(vec![1.5, 0.5, 1.0]), 3);
        for _ in 0..100 {
            assert_eq!(a.pick(&[1, 2, 3]), b.pick(&[1, 2, 3]));
        }
    }
}
