//! Pluggable scheduling policies for the deployment router.
//!
//! The router calls [`Scheduler::pick`] with the current per-chain-group
//! outstanding-request counts and gets back the *group* index to try
//! first (frames always enter a group at its stage 0; the stages forward
//! them onward themselves). All three policies are **deterministic**:
//! given the same sequence of `pick` calls with the same observed counts
//! they produce the same group sequence, which is what the policy unit
//! tests and the serving integration tests assert exact dispatch counts
//! against. A single-group deployment (one chain) trivially always picks
//! group 0 under every policy.
//!
//! * [`Policy::RoundRobin`] — cycle through groups in fixed order,
//!   ignoring load. Optimal for a homogeneous fleet under smooth arrivals.
//! * [`Policy::JoinShortestQueue`] — send each request to the group with
//!   the fewest outstanding requests (queued + executing, summed over the
//!   group's stages), ties broken toward the lowest index. Adapts to
//!   heterogeneous service rates without knowing them.
//! * [`Policy::Weighted`] — smooth weighted round-robin (the nginx SWRR
//!   algorithm) over per-group capacity weights. For heterogeneous fleets
//!   the weights come from the analytic `sim`/`timing` throughput model
//!   of each group's devices + FCMP operating points — per-replica via
//!   [`crate::coordinator::capacity::fleet_weights`], per-chain via
//!   [`crate::coordinator::capacity::chain_fps`] over
//!   [`crate::coordinator::capacity::shard_service_times`].

use std::sync::atomic::{AtomicI64, AtomicUsize, Ordering};

/// Which chain group the router hands the next request to.
#[derive(Clone, Debug, PartialEq)]
pub enum Policy {
    /// Fixed-order cycling, load-blind.
    RoundRobin,
    /// Least outstanding requests (queued + executing); ties to lowest index.
    JoinShortestQueue,
    /// Smooth weighted round-robin over per-group capacity weights
    /// (requests/s from the analytic model; any positive scale works).
    Weighted(Vec<f64>),
}

impl Policy {
    /// Parse a CLI policy name. `weights` are the per-group capacity
    /// weights consumed by the `weighted` policy and ignored by the other
    /// two.
    pub fn by_name(name: &str, weights: Vec<f64>) -> Option<Policy> {
        match name {
            "rr" | "round-robin" | "round_robin" => Some(Policy::RoundRobin),
            "jsq" | "shortest" | "join-shortest-queue" => Some(Policy::JoinShortestQueue),
            "weighted" | "capacity" => Some(Policy::Weighted(weights)),
            _ => None,
        }
    }

    /// Short display name (bench rows, log lines).
    pub fn name(&self) -> &'static str {
        match self {
            Policy::RoundRobin => "round-robin",
            Policy::JoinShortestQueue => "jsq",
            Policy::Weighted(_) => "weighted",
        }
    }
}

/// Picker state for one deployment: owns the round-robin cursor and the
/// SWRR credit vector so [`Policy`] itself stays an immutable
/// description. All state is atomic, so [`Scheduler::pick`] takes
/// `&self` and concurrent submitters (cloned
/// [`crate::coordinator::SubmitHandle`]s) never serialize on a lock.
/// Single-threaded call sequences are **bit-identical** to the old
/// mutable scheduler: the RR cursor is one `fetch_add`, and SWRR credits
/// are fixed-point integers (`weight × 2^20`, exact for the rational
/// weights the capacity model emits at test precision), updated
/// add-then-scan exactly as before with ties to the lowest index. Under
/// concurrency interleaved SWRR picks may reorder, but credits are
/// conserved, so long-run dispatch shares still match the weights.
#[derive(Debug)]
pub struct Scheduler {
    policy: Policy,
    groups: usize,
    rr_next: AtomicUsize,
    /// Fixed-point weights (`round(w × FP_SCALE)`, clamped ≥ 1).
    w_fp: Vec<i64>,
    /// `Σ w_fp` — subtracted from the winner's credit each pick.
    total_fp: i64,
    swrr_credit: Vec<AtomicI64>,
}

/// Fixed-point scale for SWRR credits: 2^20 keeps three decimal digits
/// of weight resolution exact while leaving 43 bits of credit headroom.
const FP_SCALE: f64 = (1u64 << 20) as f64;

impl Clone for Scheduler {
    fn clone(&self) -> Scheduler {
        Scheduler {
            policy: self.policy.clone(),
            groups: self.groups,
            rr_next: AtomicUsize::new(self.rr_next.load(Ordering::Relaxed)),
            w_fp: self.w_fp.clone(),
            total_fp: self.total_fp,
            swrr_credit: self
                .swrr_credit
                .iter()
                .map(|c| AtomicI64::new(c.load(Ordering::Relaxed)))
                .collect(),
        }
    }
}

impl Scheduler {
    /// Build a scheduler over `groups` chain groups. Weighted policies
    /// are normalized to the group count: missing weights default to 1.0,
    /// extra weights are dropped, and non-positive weights are clamped up
    /// so no group is starved forever.
    pub fn new(policy: Policy, groups: usize) -> Scheduler {
        assert!(groups > 0, "scheduler needs at least one chain group");
        let mut weights = match &policy {
            Policy::Weighted(w) => w.clone(),
            _ => vec![1.0; groups],
        };
        weights.resize(groups, 1.0);
        for w in &mut weights {
            if !w.is_finite() || *w <= 0.0 {
                *w = 1e-3;
            }
        }
        let w_fp: Vec<i64> =
            weights.iter().map(|w| ((w * FP_SCALE).round() as i64).max(1)).collect();
        let total_fp = w_fp.iter().sum();
        Scheduler {
            policy,
            groups,
            rr_next: AtomicUsize::new(0),
            w_fp,
            total_fp,
            swrr_credit: (0..groups).map(|_| AtomicI64::new(0)).collect(),
        }
    }

    /// The policy this scheduler runs.
    pub fn policy(&self) -> &Policy {
        &self.policy
    }

    /// Pick the preferred chain group for the next request.
    /// `outstanding[g]` is group `g`'s current outstanding-request count
    /// (queued + executing, summed over its stages); only
    /// [`Policy::JoinShortestQueue`] reads it, so callers running a
    /// load-blind policy may pass an empty slice to skip the snapshot
    /// (JSQ treats an empty slice as all-idle and picks 0).
    pub fn pick(&self, outstanding: &[usize]) -> usize {
        debug_assert!(
            outstanding.is_empty() || outstanding.len() == self.groups,
            "load snapshot arity mismatch"
        );
        match self.policy {
            Policy::RoundRobin => self.rr_next.fetch_add(1, Ordering::Relaxed) % self.groups,
            Policy::JoinShortestQueue => {
                let mut best = 0;
                for i in 1..outstanding.len().min(self.groups) {
                    if outstanding[i] < outstanding[best] {
                        best = i;
                    }
                }
                best
            }
            Policy::Weighted(_) => {
                let mut best = 0;
                let mut best_credit = i64::MIN;
                for i in 0..self.groups {
                    let credit =
                        self.swrr_credit[i].fetch_add(self.w_fp[i], Ordering::Relaxed)
                            + self.w_fp[i];
                    if credit > best_credit {
                        best_credit = credit;
                        best = i;
                    }
                }
                self.swrr_credit[best].fetch_sub(self.total_fp, Ordering::Relaxed);
                best
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let s = Scheduler::new(Policy::RoundRobin, 3);
        let picks: Vec<usize> = (0..7).map(|_| s.pick(&[0, 0, 0])).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn jsq_picks_least_outstanding_with_low_index_ties() {
        let s = Scheduler::new(Policy::JoinShortestQueue, 3);
        assert_eq!(s.pick(&[4, 1, 2]), 1);
        assert_eq!(s.pick(&[0, 0, 0]), 0);
        assert_eq!(s.pick(&[2, 1, 1]), 1);
        assert_eq!(s.pick(&[3, 3, 0]), 2);
    }

    #[test]
    fn swrr_matches_weight_ratio_exactly() {
        // weights 3:1 => pattern of period 4 with 3 picks of group 0
        let s = Scheduler::new(Policy::Weighted(vec![3.0, 1.0]), 2);
        let picks: Vec<usize> = (0..40).map(|_| s.pick(&[0, 0])).collect();
        let c0 = picks.iter().filter(|&&p| p == 0).count();
        assert_eq!(c0, 30, "picks {picks:?}");
        // smooth: never more than 3 consecutive picks of the heavy group
        let max_run = picks
            .windows(4)
            .filter(|w| w.iter().all(|&p| p == 0))
            .count();
        assert_eq!(max_run, 0, "SWRR must interleave, got {picks:?}");
    }

    #[test]
    fn swrr_equal_weights_degenerates_to_round_robin() {
        let s = Scheduler::new(Policy::Weighted(vec![1.0, 1.0, 1.0]), 3);
        let picks: Vec<usize> = (0..6).map(|_| s.pick(&[0, 0, 0])).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn weight_vector_is_normalized_to_group_count() {
        // short vector pads with 1.0; bad weights are clamped positive
        let s = Scheduler::new(Policy::Weighted(vec![2.0]), 3);
        let picks: Vec<usize> = (0..8).map(|_| s.pick(&[0, 0, 0])).collect();
        for g in 0..3 {
            assert!(picks.contains(&g), "group {g} starved: {picks:?}");
        }
        let s = Scheduler::new(Policy::Weighted(vec![-1.0, f64::NAN, 1.0]), 3);
        let picks: Vec<usize> = (0..2000).map(|_| s.pick(&[0, 0, 0])).collect();
        assert!(picks.contains(&0) && picks.contains(&1));
    }

    #[test]
    fn policy_names_round_trip() {
        for name in ["round-robin", "jsq", "weighted"] {
            let p = Policy::by_name(name, vec![1.0]).unwrap();
            assert_eq!(p.name(), name);
        }
        assert!(Policy::by_name("magic", vec![]).is_none());
        // the old chain pseudo-policy is gone: a chain is a 1-group
        // deployment, and every policy picks group 0 there
        assert!(Policy::by_name("stage-chain", vec![]).is_none());
    }

    #[test]
    fn single_group_deployments_always_pick_zero() {
        for policy in [
            Policy::RoundRobin,
            Policy::JoinShortestQueue,
            Policy::Weighted(vec![2.5]),
        ] {
            let s = Scheduler::new(policy, 1);
            for _ in 0..10 {
                assert_eq!(s.pick(&[5]), 0);
            }
        }
    }

    #[test]
    fn deterministic_for_identical_call_sequences() {
        let a = Scheduler::new(Policy::Weighted(vec![1.5, 0.5, 1.0]), 3);
        let b = Scheduler::new(Policy::Weighted(vec![1.5, 0.5, 1.0]), 3);
        for _ in 0..100 {
            assert_eq!(a.pick(&[1, 2, 3]), b.pick(&[1, 2, 3]));
        }
    }

    #[test]
    fn concurrent_weighted_picks_conserve_the_ratio() {
        use std::sync::Arc;
        // 4 submitters hammer one shared scheduler; interleavings may
        // reorder individual picks but the dispatch share must still
        // match the 3:1 weights (credits are conserved atomically)
        let s = Arc::new(Scheduler::new(Policy::Weighted(vec![3.0, 1.0]), 2));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || (0..1000).filter(|_| s.pick(&[]) == 0).count())
            })
            .collect();
        let zero: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        let frac = zero as f64 / 4000.0;
        assert!((frac - 0.75).abs() < 0.05, "group-0 share drifted to {frac}");
    }

    #[test]
    fn cloned_scheduler_snapshots_cursor_state() {
        let a = Scheduler::new(Policy::RoundRobin, 3);
        assert_eq!(a.pick(&[]), 0);
        let b = a.clone();
        // both resume from the snapshot independently
        assert_eq!(a.pick(&[]), 1);
        assert_eq!(b.pick(&[]), 1);
    }
}
