//! Dispatch-order seam shared by the thread-backed router and the
//! discrete-event fleet simulator.
//!
//! [`crate::coordinator::Server`]'s lock-free `RouterCore` and
//! [`crate::sim::fleet::FleetSim`] must pick chain groups in *exactly*
//! the same order or differential tests can never line up accepted/shed
//! counts. The two functions here are that order, factored out of the
//! router's hot path: first choice by policy (with JSQ's inline argmin
//! over live load), then the least-loaded fallback scan used when the
//! preferred group's queue is full. Both are pure given a load snapshot
//! function, so the simulator can drive them from virtual-time state
//! while the router drives them from live atomics.

use super::policy::{Policy, Scheduler};

/// Pick the preferred chain group for the next request.
///
/// Join-shortest-queue reads the load snapshot inline (argmin, strict
/// `<`, ties to the lowest index); every other policy delegates to the
/// scheduler's atomic state (RR cursor / SWRR credits), which never
/// looks at load.
pub fn preferred_group(
    scheduler: &Scheduler,
    groups: usize,
    load: impl Fn(usize) -> usize,
) -> usize {
    match scheduler.policy() {
        Policy::JoinShortestQueue => {
            let mut best = 0usize;
            let mut best_load = usize::MAX;
            for g in 0..groups {
                let l = load(g);
                if l < best_load {
                    best_load = l;
                    best = g;
                }
            }
            best
        }
        _ => scheduler.pick(&[]),
    }
}

/// Fallback scan order after the preferred group rejected a request:
/// every other group, least-loaded first, ties to the lowest index.
pub fn fallback_order(first: usize, groups: usize, load: impl Fn(usize) -> usize) -> Vec<usize> {
    let mut rest: Vec<usize> = (0..groups).filter(|&g| g != first).collect();
    rest.sort_by_key(|&g| (load(g), g));
    rest
}

/// Deadline-feasibility admission rule shared by the router and the
/// simulator ([`crate::tenancy`]): a request with `remaining_ns` of its
/// tenant SLO budget left is admitted only if the *best* group available
/// to its tenant can plausibly serve it in time — estimated sojourn =
/// `(queued_ahead + 1) × est_service` for the least-loaded candidate.
/// Both time domains evaluate this identical integer expression, so
/// differential tests line up shed counts exactly. A zero `est_service`
/// degenerates to "shed only if the deadline already passed".
pub fn deadline_feasible(remaining_ns: i64, min_load: usize, est_service_ns: u64) -> bool {
    let est = (min_load as u64 + 1).saturating_mul(est_service_ns);
    remaining_ns >= 0 && est <= remaining_ns as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsq_argmin_ties_low() {
        let s = Scheduler::new(Policy::JoinShortestQueue, 4);
        let loads = [3usize, 1, 1, 2];
        assert_eq!(preferred_group(&s, 4, |g| loads[g]), 1);
        // strictly-less comparison: a later equal load never wins
        let flat = [5usize; 4];
        assert_eq!(preferred_group(&s, 4, |g| flat[g]), 0);
    }

    #[test]
    fn rr_ignores_load() {
        let s = Scheduler::new(Policy::RoundRobin, 3);
        let picks: Vec<usize> = (0..6).map(|_| preferred_group(&s, 3, |_| 9)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn fallback_sorts_by_load_then_index() {
        let loads = [7usize, 2, 5, 2, 0];
        assert_eq!(fallback_order(2, 5, |g| loads[g]), vec![4, 1, 3, 0]);
    }

    #[test]
    fn fallback_excludes_first_even_when_least_loaded() {
        let loads = [0usize, 9, 9];
        assert_eq!(fallback_order(0, 3, |g| loads[g]), vec![1, 2]);
    }

    #[test]
    fn deadline_rule_boundaries() {
        // expired budget always sheds, even with instant service
        assert!(!deadline_feasible(-1, 0, 0));
        // zero est_service admits anything still inside its budget
        assert!(deadline_feasible(0, 100, 0));
        // exact fit admits (<=), one ns short sheds
        assert!(deadline_feasible(3_000, 2, 1_000));
        assert!(!deadline_feasible(2_999, 2, 1_000));
        // queue ahead scales the estimate linearly
        assert!(deadline_feasible(1_000, 0, 1_000));
        assert!(!deadline_feasible(1_000, 1, 1_000));
    }
}
