//! One worker of a deployment: a bounded request queue, its own dynamic
//! batcher, and an [`InferBackend`] constructed *inside* the worker
//! thread (PJRT handles are thread-affine, so only the factory closure
//! crosses threads). The router sees a replica as (bounded sender,
//! outstanding-request counter); completions from every group merge into
//! the fleet-wide completion channel.
//!
//! A replica's output side is a [`Sink`]: the final stage of a chain
//! group emits [`Completion`]s stamped with the group's *current*
//! position (groups can move when [`crate::coordinator::Server::apply`]
//! reshapes the plan around them, so the position lives in a shared
//! atomic rather than being baked in at spawn); mid-chain stages forward
//! each output as the next stage's [`Request`] over that stage's bounded
//! queue — the blocking send *is* the inter-device FIFO backpressure.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{next_batch_traced, poll_batch_traced, BatchPoll, BatcherConfig, SharedBatcher};
use super::deployment::WorkerId;
use super::hotpath::BufferPool;
use super::server::{BatchHandle, InferBackend};
use super::{Completion, Request};
use crate::obs::{Obs, SpanEvent, SpanRing};

/// Where a replica's outputs go.
pub(crate) enum Sink {
    /// Final stage of a chain group: emit completions onto the
    /// fleet-wide stream, stamped with the group's current position
    /// (read from the shared cell at send time).
    Complete {
        tx: Sender<Completion>,
        group: Arc<AtomicUsize>,
    },
    /// Mid-chain stage: forward each output as the next stage's request.
    /// The downstream outstanding counter is incremented before the
    /// send, the same increment-before-send discipline the router uses
    /// at group entries.
    Forward { next: SyncSender<Request>, next_outstanding: Arc<AtomicUsize> },
}

/// A running replica: router-side handle plus the worker thread.
pub(crate) struct Replica {
    tx: Option<SyncSender<Request>>,
    /// Requests accepted but not yet completed (queued + executing).
    outstanding: Arc<AtomicUsize>,
    /// Live batching settings; the worker re-reads them per batch, so the
    /// SLO controller can retune a running replica.
    batcher: Arc<SharedBatcher>,
    worker: Option<JoinHandle<()>>,
}

/// One submitted-but-not-reaped batch in the worker's in-flight window.
struct Inflight {
    requests: Vec<Request>,
    /// The payload buffers moved out of the requests — held until the
    /// reap so they can flow back to the pool, never freed per batch.
    inputs: Vec<Vec<f32>>,
    handle: BatchHandle,
}

/// Floor for the in-flight polling window so a near-due batch never
/// degenerates the batcher into a zero-wait spin.
const MIN_POLL: Duration = Duration::from_micros(500);

impl Replica {
    /// Spawn the worker for `id`. The worker runs a **submit/reap loop**:
    /// it keeps up to `window` batches submitted to the backend at once
    /// (via [`InferBackend::submit_batch`]) so batch `N+1` can form — and
    /// transfer, for overlapping backends — while batch `N` executes.
    /// `window == 1` reproduces the old fully synchronous worker. When
    /// nothing is in flight the worker parks on its request channel (no
    /// idle spin); with work in flight it polls the batcher with a window
    /// sized to the oldest batch's expected completion. On close the loop
    /// runs an explicit **drain barrier**: every submitted batch is
    /// reaped in FIFO order before the thread exits, so a group drain
    /// never drops accepted requests. A failed batch is dropped (its
    /// completions never appear) but the replica keeps serving. The
    /// thread name reflects the spawn-time position; completions track
    /// the group's live position via [`Sink::Complete`].
    pub(crate) fn spawn<B, F>(
        id: WorkerId,
        make_backend: F,
        batcher: BatcherConfig,
        queue_depth: usize,
        window: usize,
        sink: Sink,
        pool: Arc<BufferPool>,
        obs: Arc<Obs>,
        ring: Arc<SpanRing>,
    ) -> Replica
    where
        B: InferBackend,
        F: FnOnce() -> B + Send + 'static,
    {
        let window = window.max(1);
        let (tx, rx) = sync_channel::<Request>(queue_depth.max(1));
        let outstanding = Arc::new(AtomicUsize::new(0));
        let counter = Arc::clone(&outstanding);
        let shared = Arc::new(SharedBatcher::new(batcher));
        let shared_worker = Arc::clone(&shared);
        let worker = std::thread::Builder::new()
            .name(format!("fcmp-g{}-s{}", id.group, id.stage))
            .spawn(move || {
                let backend = make_backend();
                let (g, s) = (id.group as u16, id.stage as u16);
                let mut inflight: VecDeque<Inflight> = VecDeque::with_capacity(window);
                // Gather stamp at the moment each request leaves the stage
                // queue; a no-op hook when tracing is off keeps the formed
                // batch path identical
                let mut on_pull: Box<dyn FnMut(&mut Request)> = if obs.active() {
                    let obs = Arc::clone(&obs);
                    Box::new(move |r: &mut Request| {
                        obs.stamp(&mut r.span, SpanEvent::Gather, g, s);
                    })
                } else {
                    Box::new(|_| {})
                };
                loop {
                    // reap everything already done, oldest first
                    while inflight.front().is_some_and(|fl| fl.handle.is_ready()) {
                        let fl = inflight.pop_front().expect("non-empty front");
                        reap(fl, &sink, id, &counter, &pool, &obs, &ring);
                    }
                    // window full: the oldest batch gates further submits
                    if inflight.len() >= window {
                        if let Some(fl) = inflight.pop_front() {
                            reap(fl, &sink, id, &counter, &pool, &obs, &ring);
                        }
                        continue;
                    }
                    let cfg = shared_worker.load();
                    let batch = if inflight.is_empty() {
                        // idle: park on the channel, zero CPU
                        match next_batch_traced(&rx, &cfg, &mut on_pull) {
                            Some(b) => b,
                            None => break,
                        }
                    } else {
                        // bounded poll: back to reaping by the time the
                        // oldest in-flight batch is expected to finish
                        let limit = inflight
                            .front()
                            .and_then(|fl| fl.handle.eta())
                            .unwrap_or(cfg.max_wait)
                            .max(MIN_POLL);
                        match poll_batch_traced(&rx, &cfg, limit, &mut on_pull) {
                            BatchPoll::Batch(b) => b,
                            BatchPoll::Idle => continue,
                            BatchPoll::Closed => break,
                        }
                    };
                    let mut batch = batch;
                    if obs.active() {
                        for r in &mut batch.requests {
                            obs.stamp(&mut r.span, SpanEvent::Dispatch, g, s);
                        }
                    }
                    // move inputs out (no per-request copy on the hot path)
                    let inputs: Vec<Vec<f32>> = batch
                        .requests
                        .iter_mut()
                        .map(|r| std::mem::take(&mut r.input))
                        .collect();
                    match backend.submit_batch(&inputs) {
                        Ok(handle) => inflight.push_back(Inflight {
                            requests: batch.requests,
                            inputs,
                            handle,
                        }),
                        Err(e) => {
                            eprintln!(
                                "worker g{}.s{}: submit failed: {e:#}",
                                id.group, id.stage
                            );
                            counter.fetch_sub(batch.requests.len(), Ordering::SeqCst);
                            for mut r in batch.requests {
                                obs.recycle(r.span.take());
                            }
                            for input in inputs {
                                pool.put(input);
                            }
                        }
                    }
                }
                // drain barrier: reap every submitted batch in FIFO order
                for fl in inflight {
                    reap(fl, &sink, id, &counter, &pool, &obs, &ring);
                }
            })
            .expect("spawn replica worker");
        Replica { tx: Some(tx), outstanding, batcher: shared, worker: Some(worker) }
    }

    /// Outstanding requests (queued + executing) — the JSQ load signal.
    pub(crate) fn outstanding(&self) -> usize {
        self.outstanding.load(Ordering::SeqCst)
    }

    /// True when the worker thread exited while the replica was still
    /// nominally open (a panicked backend, never a normal close-drain).
    /// The server's completion sender keeps the completion channel open
    /// even then, so liveness checks must ask the thread, not the
    /// channel.
    pub(crate) fn is_dead(&self) -> bool {
        self.tx.is_some() && self.worker.as_ref().is_some_and(|h| h.is_finished())
    }

    /// Snapshot of the replica's current batching settings.
    pub(crate) fn batcher(&self) -> BatcherConfig {
        self.batcher.load()
    }

    /// Live-retune the replica's batcher; the worker applies the new
    /// settings on its next batch.
    pub(crate) fn set_batcher(&self, cfg: BatcherConfig) {
        self.batcher.store(cfg);
    }

    /// Clone of the bounded request sender (chain wiring: the upstream
    /// stage forwards into this queue). `None` once closed.
    pub(crate) fn sender(&self) -> Option<SyncSender<Request>> {
        self.tx.clone()
    }

    /// Shared outstanding counter (chain wiring pairs it with
    /// [`Replica::sender`]).
    pub(crate) fn outstanding_handle(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.outstanding)
    }

    /// Stop accepting requests; the worker drains what is already queued.
    pub(crate) fn close(&mut self) {
        self.tx = None;
    }

    /// Wait for the worker to finish draining (after [`Replica::close`]).
    pub(crate) fn join(&mut self) {
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

/// Complete one in-flight batch: wait for its handle, emit through the
/// sink, recycle the input buffers, and release the outstanding count.
/// The counter is decremented *after* emission (same ordering as the old
/// synchronous loop), so JSQ never undercounts work still being routed.
fn reap(
    fl: Inflight,
    sink: &Sink,
    id: WorkerId,
    counter: &AtomicUsize,
    pool: &BufferPool,
    obs: &Obs,
    ring: &SpanRing,
) {
    let Inflight { mut requests, inputs, handle } = fl;
    let n = requests.len();
    let (g, s) = (id.group as u16, id.stage as u16);
    match handle.wait() {
        Ok(outputs) => {
            if obs.active() {
                for r in &mut requests {
                    obs.stamp(&mut r.span, SpanEvent::Reap, g, s);
                }
            }
            match sink {
                Sink::Complete { tx, group } => {
                    for (mut req, output) in requests.into_iter().zip(outputs) {
                        let mut stage_latencies = req.stage_latencies;
                        let mut stage_batches = req.stage_batches;
                        // chain frames log the final hop too, so len == chain
                        // length; 1-stage-group completions keep the empty
                        // marker
                        if !stage_latencies.is_empty() {
                            stage_latencies.push(req.stage_arrival.elapsed());
                            stage_batches.push(n);
                        }
                        obs.complete(&mut req.span, ring, g, s);
                        let _ = tx.send(Completion {
                            id: req.id,
                            output,
                            latency: req.arrival.elapsed(),
                            batch_size: n,
                            group: group.load(Ordering::SeqCst),
                            stage: id.stage,
                            stage_latencies,
                            stage_batches,
                            span: req.span,
                        });
                    }
                }
                Sink::Forward { next, next_outstanding } => {
                    for (mut req, output) in requests.into_iter().zip(outputs) {
                        req.stage_latencies.push(req.stage_arrival.elapsed());
                        req.stage_batches.push(n);
                        req.input = output;
                        req.stage_arrival = Instant::now();
                        // stamped at the *sending* stage as the frame is
                        // handed to the link; when the send below blocks
                        // on a full downstream queue, the wait lands in
                        // the next stage's queue segment (and in the link
                        // segment of this batch's trailing frames)
                        obs.stamp(&mut req.span, SpanEvent::LinkHop, g, s);
                        next_outstanding.fetch_add(1, Ordering::SeqCst);
                        // blocking send: the bounded downstream queue is the
                        // inter-stage FIFO, so a full next stage
                        // backpressures this one
                        if next.send(req).is_err() {
                            next_outstanding.fetch_sub(1, Ordering::SeqCst);
                        }
                    }
                }
            }
        }
        Err(e) => {
            eprintln!("worker g{}.s{}: batch failed: {e:#}", id.group, id.stage);
            for mut req in requests {
                obs.recycle(req.span.take());
            }
        }
    }
    for input in inputs {
        pool.put(input);
    }
    counter.fetch_sub(n, Ordering::SeqCst);
}
