//! One worker of a deployment: a bounded request queue, its own dynamic
//! batcher, and an [`InferBackend`] constructed *inside* the worker
//! thread (PJRT handles are thread-affine, so only the factory closure
//! crosses threads). The router sees a replica as (bounded sender,
//! outstanding-request counter); completions from every group merge into
//! the fleet-wide completion channel.
//!
//! A replica's output side is a [`Sink`]: the final stage of a chain
//! group emits [`Completion`]s stamped with the group's *current*
//! position (groups can move when [`crate::coordinator::Server::apply`]
//! reshapes the plan around them, so the position lives in a shared
//! atomic rather than being baked in at spawn); mid-chain stages forward
//! each output as the next stage's [`Request`] over that stage's bounded
//! queue — the blocking send *is* the inter-device FIFO backpressure.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::batcher::{next_batch, BatcherConfig, SharedBatcher};
use super::deployment::WorkerId;
use super::server::InferBackend;
use super::{Completion, Request};

/// Outcome of a non-blocking submit to one replica. The request rides back
/// in the error so the router can try another group without copying.
pub(crate) enum TrySubmit {
    /// The replica's bounded queue is full (transient overload).
    Full(Request),
    /// The replica stopped accepting work (shutdown or dead worker).
    Closed(Request),
}

/// Where a replica's outputs go.
pub(crate) enum Sink {
    /// Final stage of a chain group: emit completions onto the
    /// fleet-wide stream, stamped with the group's current position
    /// (read from the shared cell at send time).
    Complete {
        tx: Sender<Completion>,
        group: Arc<AtomicUsize>,
    },
    /// Mid-chain stage: forward each output as the next stage's request.
    /// The downstream outstanding counter is incremented before the
    /// send, the same discipline as [`Replica::try_submit`].
    Forward { next: SyncSender<Request>, next_outstanding: Arc<AtomicUsize> },
}

/// A running replica: router-side handle plus the worker thread.
pub(crate) struct Replica {
    tx: Option<SyncSender<Request>>,
    /// Requests accepted but not yet completed (queued + executing).
    outstanding: Arc<AtomicUsize>,
    /// Live batching settings; the worker re-reads them per batch, so the
    /// SLO controller can retune a running replica.
    batcher: Arc<SharedBatcher>,
    worker: Option<JoinHandle<()>>,
}

impl Replica {
    /// Spawn the worker for `id`. The worker loops `next_batch ->
    /// infer_batch -> sink` until the request channel is closed *and*
    /// drained, so a group drain never drops accepted requests. A failed
    /// batch is dropped (its completions never appear) but the replica
    /// keeps serving. The thread name reflects the spawn-time position;
    /// completions track the group's live position via [`Sink::Complete`].
    pub(crate) fn spawn<B, F>(
        id: WorkerId,
        make_backend: F,
        batcher: BatcherConfig,
        queue_depth: usize,
        sink: Sink,
    ) -> Replica
    where
        B: InferBackend,
        F: FnOnce() -> B + Send + 'static,
    {
        let (tx, rx) = sync_channel::<Request>(queue_depth.max(1));
        let outstanding = Arc::new(AtomicUsize::new(0));
        let counter = Arc::clone(&outstanding);
        let shared = Arc::new(SharedBatcher::new(batcher));
        let shared_worker = Arc::clone(&shared);
        let worker = std::thread::Builder::new()
            .name(format!("fcmp-g{}-s{}", id.group, id.stage))
            .spawn(move || {
                let backend = make_backend();
                while let Some(mut batch) = next_batch(&rx, &shared_worker.load()) {
                    // move inputs out (no per-request copy on the hot path)
                    let inputs: Vec<Vec<f32>> = batch
                        .requests
                        .iter_mut()
                        .map(|r| std::mem::take(&mut r.input))
                        .collect();
                    let n = batch.requests.len();
                    match backend.infer_batch(&inputs) {
                        Ok(outputs) => match &sink {
                            Sink::Complete { tx, group } => {
                                for (req, output) in
                                    batch.requests.into_iter().zip(outputs)
                                {
                                    let mut stage_latencies = req.stage_latencies;
                                    let mut stage_batches = req.stage_batches;
                                    // chain frames log the final hop too, so
                                    // len == chain length; 1-stage-group
                                    // completions keep the empty marker
                                    if !stage_latencies.is_empty() {
                                        stage_latencies.push(req.stage_arrival.elapsed());
                                        stage_batches.push(n);
                                    }
                                    let _ = tx.send(Completion {
                                        id: req.id,
                                        output,
                                        latency: req.arrival.elapsed(),
                                        batch_size: n,
                                        group: group.load(Ordering::SeqCst),
                                        stage: id.stage,
                                        stage_latencies,
                                        stage_batches,
                                    });
                                }
                            }
                            Sink::Forward { next, next_outstanding } => {
                                for (mut req, output) in
                                    batch.requests.into_iter().zip(outputs)
                                {
                                    req.stage_latencies.push(req.stage_arrival.elapsed());
                                    req.stage_batches.push(n);
                                    req.input = output;
                                    req.stage_arrival = Instant::now();
                                    next_outstanding.fetch_add(1, Ordering::SeqCst);
                                    // blocking send: the bounded downstream
                                    // queue is the inter-stage FIFO, so a
                                    // full next stage backpressures this one
                                    if next.send(req).is_err() {
                                        next_outstanding.fetch_sub(1, Ordering::SeqCst);
                                    }
                                }
                            }
                        },
                        Err(e) => {
                            eprintln!(
                                "worker g{}.s{}: batch failed: {e:#}",
                                id.group, id.stage
                            );
                        }
                    }
                    counter.fetch_sub(n, Ordering::SeqCst);
                }
            })
            .expect("spawn replica worker");
        Replica { tx: Some(tx), outstanding, batcher: shared, worker: Some(worker) }
    }

    /// Outstanding requests (queued + executing) — the JSQ load signal.
    pub(crate) fn outstanding(&self) -> usize {
        self.outstanding.load(Ordering::SeqCst)
    }

    /// True when the worker thread exited while the replica was still
    /// nominally open (a panicked backend, never a normal close-drain).
    /// The server's completion sender keeps the completion channel open
    /// even then, so liveness checks must ask the thread, not the
    /// channel.
    pub(crate) fn is_dead(&self) -> bool {
        self.tx.is_some() && self.worker.as_ref().map_or(false, |h| h.is_finished())
    }

    /// Snapshot of the replica's current batching settings.
    pub(crate) fn batcher(&self) -> BatcherConfig {
        self.batcher.load()
    }

    /// Live-retune the replica's batcher; the worker applies the new
    /// settings on its next batch.
    pub(crate) fn set_batcher(&self, cfg: BatcherConfig) {
        self.batcher.store(cfg);
    }

    /// Clone of the bounded request sender (chain wiring: the upstream
    /// stage forwards into this queue). `None` once closed.
    pub(crate) fn sender(&self) -> Option<SyncSender<Request>> {
        self.tx.clone()
    }

    /// Shared outstanding counter (chain wiring pairs it with
    /// [`Replica::sender`]).
    pub(crate) fn outstanding_handle(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.outstanding)
    }

    /// Non-blocking submit. The counter is incremented *before* the send
    /// (and rolled back on failure) so the worker can never decrement a
    /// counter that has not yet seen its increment — a decrement-first
    /// interleaving would wrap the `AtomicUsize` and corrupt the JSQ load
    /// signal. The transient +1 on the failure path is harmless.
    pub(crate) fn try_submit(&self, req: Request) -> Result<(), TrySubmit> {
        match &self.tx {
            None => Err(TrySubmit::Closed(req)),
            Some(tx) => {
                self.outstanding.fetch_add(1, Ordering::SeqCst);
                match tx.try_send(req) {
                    Ok(()) => Ok(()),
                    Err(TrySendError::Full(r)) => {
                        self.outstanding.fetch_sub(1, Ordering::SeqCst);
                        Err(TrySubmit::Full(r))
                    }
                    Err(TrySendError::Disconnected(r)) => {
                        self.outstanding.fetch_sub(1, Ordering::SeqCst);
                        Err(TrySubmit::Closed(r))
                    }
                }
            }
        }
    }

    /// Blocking submit: parks on the bounded queue until the worker frees a
    /// slot. Same increment-before-send counter discipline as
    /// [`Replica::try_submit`]; only a dead replica makes it fail.
    pub(crate) fn submit_wait(&self, req: Request) -> Result<(), TrySubmit> {
        match &self.tx {
            None => Err(TrySubmit::Closed(req)),
            Some(tx) => {
                self.outstanding.fetch_add(1, Ordering::SeqCst);
                match tx.send(req) {
                    Ok(()) => Ok(()),
                    Err(e) => {
                        self.outstanding.fetch_sub(1, Ordering::SeqCst);
                        Err(TrySubmit::Closed(e.0))
                    }
                }
            }
        }
    }

    /// Stop accepting requests; the worker drains what is already queued.
    pub(crate) fn close(&mut self) {
        self.tx = None;
    }

    /// Wait for the worker to finish draining (after [`Replica::close`]).
    pub(crate) fn join(&mut self) {
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}
