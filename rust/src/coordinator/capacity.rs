//! Analytic per-replica capacity estimates for heterogeneous fleets.
//!
//! The paper's headline result is porting one accelerator across devices by
//! trading throughput for OCM (FCMP), so a realistic deployment is a fleet
//! of replicas with *different* per-device throughput: a U250 replica at 63%
//! LUT density closes timing near target while the same design squeezed onto
//! a U280 at 99% density gives up ~32% of its clock (Table V). This module
//! turns each replica's deployment point into a requests/s capacity via the
//! analytic [`crate::timing`] closure model and [`crate::sim`] pipeline
//! estimate, and those capacities become the weights of the
//! throughput-weighted scheduling policy
//! ([`crate::coordinator::policy::Policy::Weighted`]).

use crate::device::Device;
use crate::nn::Network;
use crate::{sim, timing};

/// One replica's deployment configuration: the device it runs on and the
/// FCMP operating point reached on that device.
#[derive(Clone, Debug)]
pub struct ReplicaSpec {
    /// The FPGA part hosting this replica.
    pub device: Device,
    /// Required memory/compute frequency ratio `R_F = H_B / 2` (Eq. 2);
    /// 1.0 means the unpacked design with no overclocked memory domain.
    pub rf: f64,
    /// Post-P&R LUT utilization density driving the timing-closure model.
    pub lut_util: f64,
}

impl ReplicaSpec {
    /// The paper's Table V operating point for a device: H_B = 4 packing
    /// (`R_F = 2`) at the published post-P&R LUT density of the FCMP design
    /// evaluated on that part (58% on the 7020, 90% on the 7012S, 63% on
    /// the U250, 99% on the U280; 70% for unknown parts).
    pub fn paper_point(device: Device) -> ReplicaSpec {
        let lut_util = match device.name {
            "zynq-7020" => 0.58,
            "zynq-7012s" => 0.90,
            "alveo-u250" => 0.63,
            "alveo-u280" => 0.99,
            _ => 0.70,
        };
        ReplicaSpec { device, rf: 2.0, lut_util }
    }

    /// Operating point derived from actually packing `net` on `device` at
    /// `bin_height`: the LUT density comes from the resource model plus the
    /// packed design's streamer/CDC logic. The packing is fetched through
    /// the process-wide [`crate::packing::cache`], so a fleet of N
    /// identical replicas packs once, not N times.
    pub fn packed_point(
        net: &Network,
        device: Device,
        bin_height: usize,
        generations: usize,
        seed: u64,
    ) -> ReplicaSpec {
        let packed =
            crate::report::pack_network_cached(net, &device, bin_height, generations, seed);
        let res = crate::folding::network_resources(net, &device);
        // clamp: the timing model wants a density in [0, 1]; feasibility
        // (util > 1.0) is the sharding partitioner's job, not capacity's
        let lut_util = crate::folding::packed_lut_util(&res, packed.logic_kluts, &device).min(1.0);
        ReplicaSpec { device, rf: bin_height as f64 / 2.0, lut_util }
    }
}

/// Analytic throughput (frames/s) of `net` deployed at `spec`: the timing
/// model yields the effective compute clock after memory-side throttling
/// (`min(F_c, F_m / R_F)`), and the pipeline model converts clock to FPS.
pub fn replica_fps(net: &Network, spec: &ReplicaSpec) -> f64 {
    let target = spec.device.nominal_compute_mhz;
    let t = timing::evaluate(&spec.device, spec.lut_util, target, spec.rf, target);
    sim::estimate(net, t.effective_fc_mhz).fps
}

/// Capacity weights for a heterogeneous flat fleet (1-stage chain
/// groups), mean-normalized via [`group_weights`].
pub fn fleet_weights(net: &Network, specs: &[ReplicaSpec]) -> Vec<f64> {
    let fps: Vec<f64> = specs.iter().map(|s| replica_fps(net, s)).collect();
    group_weights(&fps)
}

/// Mean-normalize per-chain-group capacities (frames/s, any positive
/// scale) into weights for [`crate::coordinator::Policy::Weighted`] group
/// scheduling: normalization to mean 1.0 keeps the SWRR credit arithmetic
/// well-conditioned no matter how large the absolute FPS numbers are.
pub fn group_weights(group_fps: &[f64]) -> Vec<f64> {
    if group_fps.is_empty() {
        return Vec::new();
    }
    let mean = group_fps.iter().sum::<f64>() / group_fps.len() as f64;
    group_fps.iter().map(|f| f / mean.max(1e-12)).collect()
}

/// Analytic throughput (frames/s) of one chain group from its per-stage
/// service intervals: the slowest stage sets the pipeline's initiation
/// interval. Returns 0.0 for empty or all-instant chains (no meaningful
/// capacity signal; the scheduler clamps non-positive weights anyway).
pub fn chain_fps(stage_service: &[std::time::Duration]) -> f64 {
    let bottleneck = stage_service.iter().copied().max().unwrap_or_default();
    if bottleneck.is_zero() {
        0.0
    } else {
        1.0 / bottleneck.as_secs_f64()
    }
}

/// Per-item mock service interval of one device for serving experiments:
/// the fastest device anywhere in the pool (analytic `ref_fps`) serves
/// one item in `service_us` microseconds and every other device scales
/// up by its FPS ratio, so fleet heterogeneity — and every
/// capacity-aware decision built on it — is observable without hardware.
/// The one calibration formula shared by `fcmp serve --backend mock` and
/// the control plane's [`crate::control::ControlledFleet`].
pub fn mock_service_time(
    net: &Network,
    spec: &ReplicaSpec,
    service_us: f64,
    ref_fps: f64,
) -> std::time::Duration {
    mock_service_from_fps(replica_fps(net, spec), service_us, ref_fps)
}

/// The calibration core of [`mock_service_time`] over a precomputed
/// analytic throughput — callers that already ran [`replica_fps`] (e.g.
/// to print a capacity table) avoid evaluating the analytic model twice.
pub fn mock_service_from_fps(fps: f64, service_us: f64, ref_fps: f64) -> std::time::Duration {
    std::time::Duration::from_secs_f64(service_us * 1e-6 * ref_fps.max(1e-9) / fps.max(1e-9))
}

/// Per-stage mock service of one chain group: each of the `k` stages
/// hosts `1/k` of the network, so its interval is its device's
/// full-network [`mock_service_time`] divided by the chain depth.
pub fn mock_chain_service(
    net: &Network,
    specs: &[ReplicaSpec],
    service_us: f64,
    ref_fps: f64,
) -> Vec<std::time::Duration> {
    let fps: Vec<f64> = specs.iter().map(|s| replica_fps(net, s)).collect();
    mock_chain_service_from_fps(&fps, service_us, ref_fps)
}

/// [`mock_chain_service`] over precomputed per-stage throughputs.
pub fn mock_chain_service_from_fps(
    stage_fps: &[f64],
    service_us: f64,
    ref_fps: f64,
) -> Vec<std::time::Duration> {
    let k = stage_fps.len().max(1) as u32;
    stage_fps.iter().map(|&f| mock_service_from_fps(f, service_us, ref_fps) / k).collect()
}

/// Analytic speedup of the async in-flight window over the synchronous
/// worker for a backend whose per-item service splits into a host→device
/// transfer leg (`xfer_s`) and a compute leg (`compute_s`).
///
/// With `window <= 1` the worker reaps each batch before submitting the
/// next, so every item pays `xfer + compute` — speedup 1.0. With a window
/// of 2+ the next batch's transfer overlaps the current batch's compute
/// (double buffering), so the steady-state interval collapses to the
/// longer leg and the speedup is `(xfer + compute) / max(xfer, compute)`
/// — up to 2.0 when the legs are balanced. Windows beyond 2 add no
/// further analytic speedup (one transfer can hide behind one compute);
/// they only absorb jitter.
pub fn overlap_speedup(xfer_s: f64, compute_s: f64, window: usize) -> f64 {
    let seq = xfer_s + compute_s;
    if window <= 1 || seq <= 0.0 {
        1.0
    } else {
        seq / xfer_s.max(compute_s)
    }
}

/// Per-stage service times of a sharded pipeline plan — shard `j` serves
/// one frame every `seconds_per_frame(j)`. Calibrates the mock backends
/// of chain-group deployments ([`crate::coordinator::Server::deploy`]
/// with a [`crate::coordinator::Deployment::chain`] or
/// [`crate::coordinator::Deployment::replicated_chains`] plan) so chain
/// serving experiments reflect the analytic plan without hardware, and
/// feeds [`chain_fps`] for per-group scheduling weights.
pub fn shard_service_times(plan: &crate::sharding::ShardPlan) -> Vec<std::time::Duration> {
    plan.shards
        .iter()
        .map(|s| std::time::Duration::from_secs_f64(s.seconds_per_frame))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{alveo_u250, alveo_u280, zynq_7012s, zynq_7020};
    use crate::nn::{cnv, resnet50, CnvVariant};

    #[test]
    fn dense_u280_port_is_slower_than_u250() {
        // Table V: U250 P4 loses ~9-12% of its clock, U280 P4 loses ~32%
        let net = resnet50(1);
        let specs = [
            ReplicaSpec::paper_point(alveo_u250()),
            ReplicaSpec::paper_point(alveo_u280()),
        ];
        let w = fleet_weights(&net, &specs);
        assert!(w[0] > w[1], "U250 {} should out-weigh U280 {}", w[0], w[1]);
        let mean: f64 = w.iter().sum::<f64>() / w.len() as f64;
        assert!((mean - 1.0).abs() < 1e-9, "weights must be mean-normalized");
    }

    #[test]
    fn embedded_ports_close_timing_and_match() {
        // both Zynq parts close at 100/200 MHz => identical capacity
        let net = cnv(CnvVariant::W1A1);
        let a = replica_fps(&net, &ReplicaSpec::paper_point(zynq_7020()));
        let b = replica_fps(&net, &ReplicaSpec::paper_point(zynq_7012s()));
        assert!(a > 0.0);
        assert!((a - b).abs() < 1e-6, "7020 {a} vs 7012s {b}");
    }

    #[test]
    fn capacity_is_deterministic_and_positive() {
        let net = cnv(CnvVariant::W2A2);
        for dev in crate::device::all() {
            let spec = ReplicaSpec::paper_point(dev);
            let a = replica_fps(&net, &spec);
            let b = replica_fps(&net, &spec);
            assert!(a > 0.0, "{}: fps {a}", spec.device.name);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn unpacked_rf1_never_slower_than_packed_rf2() {
        // dropping the overclocked memory domain can only relax the clock
        let net = resnet50(1);
        for dev in [alveo_u250(), alveo_u280()] {
            let packed = ReplicaSpec { device: dev.clone(), rf: 2.0, lut_util: 0.63 };
            let unpacked = ReplicaSpec { device: dev, rf: 1.0, lut_util: 0.63 };
            assert!(replica_fps(&net, &unpacked) >= replica_fps(&net, &packed) - 1e-9);
        }
    }

    #[test]
    fn empty_fleet_has_no_weights() {
        assert!(fleet_weights(&cnv(CnvVariant::W1A1), &[]).is_empty());
        assert!(group_weights(&[]).is_empty());
    }

    #[test]
    fn chain_fps_is_set_by_the_bottleneck_stage() {
        use std::time::Duration;
        let svc = [
            Duration::from_micros(100),
            Duration::from_micros(400), // bottleneck: 2500 fps
            Duration::from_micros(200),
        ];
        assert!((chain_fps(&svc) - 2500.0).abs() < 1e-6);
        assert_eq!(chain_fps(&[]), 0.0);
        assert_eq!(chain_fps(&[Duration::ZERO]), 0.0);
    }

    #[test]
    fn mock_service_splits_evenly_across_chain_stages() {
        let net = cnv(CnvVariant::W1A1);
        let spec = ReplicaSpec::paper_point(zynq_7020());
        let ref_fps = replica_fps(&net, &spec);
        // the reference device itself serves at exactly service_us
        let solo = mock_service_time(&net, &spec, 800.0, ref_fps);
        assert!((solo.as_secs_f64() - 800e-6).abs() < 1e-9);
        // a 2-stage chain of the same device halves the per-stage interval
        let chain = mock_chain_service(&net, &[spec.clone(), spec], 800.0, ref_fps);
        assert_eq!(chain.len(), 2);
        for s in &chain {
            assert!((s.as_secs_f64() - 400e-6).abs() < 1e-9);
        }
        // and the chain's capacity doubles the single stage's
        assert!((chain_fps(&chain) - 2.0 / solo.as_secs_f64()).abs() < 1e-3);
    }

    #[test]
    fn group_weights_are_mean_normalized() {
        let w = group_weights(&[100.0, 300.0]);
        assert!((w[0] - 0.5).abs() < 1e-12);
        assert!((w[1] - 1.5).abs() < 1e-12);
        let mean: f64 = w.iter().sum::<f64>() / w.len() as f64;
        assert!((mean - 1.0).abs() < 1e-12);
    }

    #[test]
    fn packed_point_is_cached_across_replicas() {
        // spinning up N identical replicas must reuse one packed design:
        // the second fetch returns the *same* Arc (pointer equality is
        // immune to other tests inserting into the global cache in
        // parallel)
        let net = cnv(CnvVariant::W1A1);
        let a = crate::report::pack_network_cached(&net, &zynq_7020(), 4, 0, 987_654);
        let b = crate::report::pack_network_cached(&net, &zynq_7020(), 4, 0, 987_654);
        assert!(std::sync::Arc::ptr_eq(&a, &b), "second spin-up re-packed");
        let spec = ReplicaSpec::packed_point(&net, zynq_7020(), 4, 0, 987_654);
        assert_eq!(spec.rf, 2.0);
        assert!(spec.lut_util > 0.0 && spec.lut_util <= 1.0);
    }

    #[test]
    fn overlap_speedup_peaks_at_balanced_legs() {
        // balanced legs: double buffering hides half the work
        assert!((overlap_speedup(1.0, 1.0, 2) - 2.0).abs() < 1e-12);
        // lopsided legs: bounded by the dominant leg
        assert!((overlap_speedup(1.0, 3.0, 2) - 4.0 / 3.0).abs() < 1e-12);
        assert!((overlap_speedup(3.0, 1.0, 4) - 4.0 / 3.0).abs() < 1e-12);
        // window 1 is the synchronous worker, and degenerate inputs are 1.0
        assert_eq!(overlap_speedup(1.0, 1.0, 1), 1.0);
        assert_eq!(overlap_speedup(0.0, 0.0, 4), 1.0);
        // deeper windows add nothing beyond double buffering
        assert_eq!(overlap_speedup(1.0, 2.0, 2), overlap_speedup(1.0, 2.0, 8));
    }

    #[test]
    fn shard_service_times_match_the_plan() {
        let net = cnv(CnvVariant::W2A2);
        let devs = [zynq_7012s(), zynq_7012s()];
        let cfg = crate::sharding::PartitionConfig {
            generations: 0,
            ..crate::sharding::PartitionConfig::default()
        };
        let plan = crate::sharding::partition(&net, &devs, cfg).unwrap();
        let times = shard_service_times(&plan);
        assert_eq!(times.len(), plan.shards.len());
        for (t, s) in times.iter().zip(&plan.shards) {
            assert!((t.as_secs_f64() - s.seconds_per_frame).abs() < 1e-12);
        }
    }
}
