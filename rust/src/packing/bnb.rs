//! Branch-and-bound packer — the MemPacker approach (Karchmer & Rose,
//! ICCAD'94; paper §II.C notes its "high worst-case time complexity").
//! Exact optimum; use only for small item sets (≲ 14) and as the ground
//! truth oracle in packing tests.

use super::{bin_brams, Bin, Constraints, Packer, Packing};
use crate::device::bram::{brams_for, BRAM18_BITS};
use crate::memory::PackItem;
use crate::util::ceil_div;

/// Exact branch-and-bound packer.
#[derive(Clone, Copy, Debug)]
pub struct Bnb {
    /// Safety cap on explored nodes (guards accidental large inputs).
    pub node_limit: u64,
}

impl Default for Bnb {
    fn default() -> Self {
        Bnb { node_limit: 20_000_000 }
    }
}

struct Search<'a> {
    items: &'a [PackItem],
    c: &'a Constraints,
    best: Vec<Bin>,
    best_cost: u64,
    nodes: u64,
    node_limit: u64,
}

impl<'a> Search<'a> {
    /// Lower bound on the *additional* cost of placing `rest`: their total
    /// bits minus the slack still available in open bins (items may slot
    /// into existing BRAMs for free), over BRAM capacity.
    fn lower_bound(&self, rest: &[usize], bins: &[Bin]) -> u64 {
        let bits: u64 = rest.iter().map(|&i| self.items[i].bits()).sum();
        let slack: u64 = bins
            .iter()
            .filter(|b| b.items.len() < self.c.max_bin_height)
            .map(|b| {
                let used: u64 = b.items.iter().map(|&i| self.items[i].bits()).sum();
                (bin_brams(self.items, &b.items) * BRAM18_BITS).saturating_sub(used)
            })
            .sum();
        ceil_div(bits.saturating_sub(slack), BRAM18_BITS)
    }

    fn dfs(&mut self, rest: &[usize], bins: &mut Vec<Bin>, cost: u64) {
        self.nodes += 1;
        if self.nodes > self.node_limit {
            return;
        }
        if rest.is_empty() {
            if cost < self.best_cost {
                self.best_cost = cost;
                self.best = bins.clone();
            }
            return;
        }
        if cost + self.lower_bound(rest, bins) >= self.best_cost {
            return; // prune
        }
        let item = rest[0];
        let tail = &rest[1..];

        // place into each existing bin (dedup identical bins by shape)
        let mut tried: Vec<(u64, u64, usize)> = Vec::new();
        for bi in 0..bins.len() {
            let b = &bins[bi];
            if b.items.len() >= self.c.max_bin_height {
                continue;
            }
            if self.c.same_slr && self.items[b.items[0]].slr != self.items[item].slr {
                continue;
            }
            let (w, d) = super::bin_shape(self.items, &b.items);
            if tried.iter().any(|&(tw, td, th)| tw == w && td == d && th == b.items.len()) {
                continue; // symmetric bin, same subtree
            }
            tried.push((w, d, b.items.len()));

            // cost the placement from the shape already derived for the
            // symmetry check — no second member-list walk
            let it = &self.items[item];
            let old = brams_for(w, d);
            let new = brams_for(w.max(it.width_bits), d + it.depth);
            bins[bi].items.push(item);
            self.dfs(tail, bins, cost - old + new);
            bins[bi].items.pop();
        }
        // open a new bin
        let solo = bin_brams(self.items, &[item]);
        bins.push(Bin { items: vec![item] });
        self.dfs(tail, bins, cost + solo);
        bins.pop();
    }
}

impl Packer for Bnb {
    fn name(&self) -> &'static str {
        "bnb"
    }

    fn pack(&self, items: &[PackItem], c: &Constraints) -> Packing {
        if items.is_empty() {
            return Packing::default();
        }
        // order deepest-first: better early bounds
        let mut order: Vec<usize> = (0..items.len()).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(items[i].depth));

        let ffd = super::ffd::Ffd::new().pack(items, c);
        let ffd_cost = ffd.total_brams(items);
        let mut s = Search {
            items,
            c,
            best: ffd.bins,
            best_cost: ffd_cost,
            nodes: 0,
            node_limit: self.node_limit,
        };
        let mut bins = Vec::new();
        s.dfs(&order, &mut bins, 0);
        Packing { bins: s.best }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::{run_packer, test_items, Packer as _};
    use crate::util::rng::Rng;

    #[test]
    fn bnb_optimal_on_known_case() {
        // 4x 36x128 + 2x 36x256: optimum is 36x512 bins => 2 BRAMs
        let items = test_items(&[(36, 128), (36, 128), (36, 128), (36, 128), (36, 256), (36, 256)]);
        let c = Constraints::new(4, false);
        let (_, r) = run_packer(&Bnb::default(), &items, &c);
        assert_eq!(r.brams, 2);
    }

    #[test]
    fn bnb_at_least_as_good_as_ffd_and_ga_random() {
        let mut rng = Rng::new(99);
        for trial in 0..6 {
            let n = 6 + (trial % 4);
            let specs: Vec<(u64, u64)> = (0..n)
                .map(|_| (36, 32 + rng.below(600)))
                .collect();
            let items = test_items(&specs);
            let c = Constraints::new(4, false);
            let (_, exact) = run_packer(&Bnb::default(), &items, &c);
            let (_, ffd) = run_packer(&super::super::ffd::Ffd::new(), &items, &c);
            assert!(exact.brams <= ffd.brams, "trial {trial}");
            let ga = super::super::ga::Ga::new(super::super::ga::GaParams {
                generations: 60,
                ..super::super::ga::GaParams::cnv()
            });
            let gp = ga.pack(&items, &c);
            assert!(exact.brams <= gp.total_brams(&items), "trial {trial}");
        }
    }

    #[test]
    fn bnb_respects_height() {
        let items = test_items(&[(36, 64); 8]);
        let c = Constraints::new(2, false);
        let (p, r) = run_packer(&Bnb::default(), &items, &c);
        assert!(p.max_height() <= 2);
        assert_eq!(r.brams, 4);
    }
}
