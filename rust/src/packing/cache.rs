//! Cross-replica / cross-shard packed-design cache.
//!
//! Every replica of a serving fleet — and every candidate stage range the
//! sharding partitioner probes — would otherwise re-run the same
//! deterministic packing engine on the same inputs. Packings are pure
//! functions of `(network, device, H_B, engine parameters, seed)`, so a
//! process-wide read-only cache turns fleet spin-up from `O(N · pack)`
//! into `O(pack)` and makes the partitioner's `O(S²)` range sweep pay for
//! each distinct range once.
//!
//! The cache is keyed by a [`PackKey`] that fingerprints the network
//! (name, total weight bits, layer count — `Network::slice` embeds the
//! stage range in the name, so shard slices key distinctly) together with
//! the device, bin height and engine identity. Values are shared as
//! `Arc<CachedPack>`; callers never mutate them.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::{PackReport, Packing};
use crate::device::Device;
use crate::nn::Network;

/// Identity of one packed design.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PackKey {
    /// Network fingerprint (name + weight bits + layer count).
    pub network: String,
    /// Device fingerprint ([`crate::device::Device::fingerprint`] — name
    /// alone would collide when a named device's capacities are tweaked).
    pub device: String,
    /// Bin height `H_B`.
    pub bin_height: usize,
    /// Engine tag + parameters that select the packing (e.g. `"ga/120"`,
    /// `"ffd"`).
    pub engine: String,
    /// Engine seed (0 for deterministic engines).
    pub seed: u64,
}

impl PackKey {
    /// Key for packing `net` on `dev` at `bin_height` with the engine
    /// described by `engine`/`seed`.
    pub fn new(
        net: &Network,
        dev: &Device,
        bin_height: usize,
        engine: String,
        seed: u64,
    ) -> PackKey {
        PackKey {
            network: format!(
                "{}#{}w#{}l",
                net.name,
                net.total_weight_bits(),
                net.layers().len()
            ),
            device: dev.fingerprint(),
            bin_height,
            engine,
            seed,
        }
    }
}

/// One cached packed design (the shareable subset of
/// [`crate::report::PackOutcome`]).
#[derive(Clone, Debug)]
pub struct CachedPack {
    pub packing: Packing,
    pub report: PackReport,
    /// Direct (unpacked) BRAM18 cost of the same buffers.
    pub baseline_brams: u64,
    /// Streamer + CDC logic overhead in kLUT.
    pub logic_kluts: f64,
}

static CACHE: OnceLock<Mutex<HashMap<PackKey, Arc<CachedPack>>>> = OnceLock::new();

fn cache() -> &'static Mutex<HashMap<PackKey, Arc<CachedPack>>> {
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Look a packed design up without building it.
pub fn lookup(key: &PackKey) -> Option<Arc<CachedPack>> {
    cache().lock().unwrap().get(key).cloned()
}

/// Fetch the packed design for `key`, running `build` on a miss. `build`
/// executes outside the cache lock (packing can take seconds), so two
/// racing builders may both pack — the engines are deterministic, so both
/// produce the same design and the first insert wins.
pub fn get_or_pack<F>(key: PackKey, build: F) -> Arc<CachedPack>
where
    F: FnOnce() -> CachedPack,
{
    if let Some(hit) = lookup(&key) {
        return hit;
    }
    let built = Arc::new(build());
    let mut map = cache().lock().unwrap();
    Arc::clone(map.entry(key).or_insert(built))
}

/// Number of designs currently cached (diagnostics).
pub fn len() -> usize {
    cache().lock().unwrap().len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{cnv, CnvVariant};
    use crate::packing::Bin;

    fn dummy_pack(brams: u64) -> CachedPack {
        CachedPack {
            packing: Packing { bins: vec![Bin { items: vec![0] }] },
            report: PackReport {
                engine: "test",
                brams,
                efficiency: 1.0,
                max_height: 1,
                elapsed: std::time::Duration::ZERO,
            },
            baseline_brams: brams,
            logic_kluts: 0.0,
        }
    }

    #[test]
    fn second_fetch_reuses_the_first_build() {
        let net = cnv(CnvVariant::W1A1);
        let dev = crate::device::zynq_7020();
        let key = PackKey::new(&net, &dev, 4, "unit-test-reuse".into(), 1);
        let mut builds = 0;
        let a = get_or_pack(key.clone(), || {
            builds += 1;
            dummy_pack(7)
        });
        let b = get_or_pack(key.clone(), || {
            builds += 1;
            dummy_pack(7)
        });
        assert_eq!(builds, 1, "second fetch must hit the cache");
        assert!(Arc::ptr_eq(&a, &b), "both fetches share one design");
        assert!(lookup(&key).is_some());
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        let net = cnv(CnvVariant::W1A1);
        let dev = crate::device::zynq_7020();
        let k1 = PackKey::new(&net, &dev, 4, "unit-test-distinct".into(), 1);
        let k2 = PackKey::new(&net, &dev, 3, "unit-test-distinct".into(), 1);
        assert_ne!(k1, k2);
        let a = get_or_pack(k1, || dummy_pack(1));
        let b = get_or_pack(k2, || dummy_pack(2));
        assert_ne!(a.report.brams, b.report.brams);
    }

    #[test]
    fn same_name_different_capacity_keys_distinctly() {
        let net = cnv(CnvVariant::W1A1);
        let a = crate::device::zynq_7020();
        let mut b = crate::device::zynq_7020();
        b.bram18 = 8;
        let ka = PackKey::new(&net, &a, 4, "unit-test-fp".into(), 1);
        let kb = PackKey::new(&net, &b, 4, "unit-test-fp".into(), 1);
        assert_ne!(ka, kb, "capacity tweak must not reuse the cached design");
    }

    #[test]
    fn sliced_networks_key_distinctly() {
        let net = cnv(CnvVariant::W1A1);
        let dev = crate::device::zynq_7020();
        let n = net.stages.len();
        let ka = PackKey::new(&net.slice(0, 3), &dev, 4, "ga/40".into(), 2020);
        let kb = PackKey::new(&net.slice(3, n), &dev, 4, "ga/40".into(), 2020);
        let kf = PackKey::new(&net, &dev, 4, "ga/40".into(), 2020);
        assert_ne!(ka, kb);
        assert_ne!(ka, kf);
    }
}
