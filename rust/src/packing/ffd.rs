//! First-fit-decreasing / best-fit-decreasing packing baseline.
//!
//! Deterministic and fast: sort slices by depth descending, then place each
//! into the existing bin whose BRAM cost grows least (best-fit), opening a
//! new bin when no placement beats a singleton. This is the "reasonable
//! hand-rolled allocator" the GA of [18] must beat.

use super::{Bin, Constraints, Packer, Packing};
use crate::device::bram::brams_for;
use crate::memory::PackItem;

/// Best-fit-decreasing packer.
#[derive(Clone, Copy, Debug, Default)]
pub struct Ffd {
    /// Only co-locate slices of equal width (avoids max-width waste;
    /// mirrors the GA's `P_adm_w = 0` setting in Table III).
    pub match_width: bool,
}

impl Ffd {
    /// The default engine: width-matched best-fit-decreasing (the baseline
    /// every stochastic engine is seeded with and measured against).
    pub fn new() -> Ffd {
        Ffd { match_width: true }
    }
}

impl Packer for Ffd {
    fn name(&self) -> &'static str {
        "ffd"
    }

    fn pack(&self, items: &[PackItem], c: &Constraints) -> Packing {
        let mut order: Vec<usize> = (0..items.len()).collect();
        order.sort_by_key(|&i| std::cmp::Reverse((items[i].depth, items[i].width_bits)));

        let mut bins: Vec<Bin> = Vec::new();
        // cached (max-width, Σdepth, cost) per bin: candidate costs come
        // from one memoized brams_for lookup instead of cloning the member
        // list and re-deriving its shape
        let mut shapes: Vec<(u64, u64, u64)> = Vec::new();

        for i in order {
            let it = &items[i];
            let solo = it.solo_brams();
            let mut best: Option<(usize, u64)> = None; // (bin, delta)
            for (bi, b) in bins.iter().enumerate() {
                if b.items.len() >= c.max_bin_height {
                    continue;
                }
                if c.same_slr && items[b.items[0]].slr != it.slr {
                    continue;
                }
                if self.match_width
                    && items[b.items[0]].width_bits != it.width_bits
                {
                    continue;
                }
                let (w, d, cost) = shapes[bi];
                let new_cost = brams_for(w.max(it.width_bits), d + it.depth);
                let delta = new_cost.saturating_sub(cost);
                if delta < solo && best.map_or(true, |(_, best_d)| delta < best_d) {
                    best = Some((bi, delta));
                }
            }
            match best {
                Some((bi, _)) => {
                    bins[bi].items.push(i);
                    let (w, d, _) = shapes[bi];
                    let (nw, nd) = (w.max(it.width_bits), d + it.depth);
                    shapes[bi] = (nw, nd, brams_for(nw, nd));
                }
                None => {
                    bins.push(Bin { items: vec![i] });
                    shapes.push((it.width_bits, it.depth, solo));
                }
            }
        }
        Packing { bins }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::{run_packer, test_items, Packing};

    #[test]
    fn ffd_coalesces_shallow_slices() {
        // 8 slices of 36x100: singletons cost 8, optimal is 2 bins of 4
        // (36x400 each) = 2 BRAMs at H_B=4
        let items = test_items(&[(36, 100); 8]);
        let c = Constraints::new(4, false);
        let (p, r) = run_packer(&Ffd::new(), &items, &c);
        assert_eq!(r.brams, 2, "{p:?}");
    }

    #[test]
    fn ffd_respects_height() {
        let items = test_items(&[(36, 10); 9]);
        let c = Constraints::new(3, false);
        let (p, _) = run_packer(&Ffd::new(), &items, &c);
        assert!(p.max_height() <= 3);
        assert_eq!(p.total_brams(&items), 3);
    }

    #[test]
    fn ffd_never_worse_than_singletons() {
        let items = test_items(&[
            (36, 700),
            (36, 100),
            (18, 300),
            (18, 900),
            (36, 50),
            (9, 2000),
            (36, 512),
            (4, 128),
        ]);
        let c = Constraints::new(4, false);
        let (p, r) = run_packer(&Ffd::new(), &items, &c);
        let single = Packing::singletons(items.len()).total_brams(&items);
        assert!(r.brams <= single, "{} > {}", r.brams, single);
        assert!(p.validate(&items, &c).is_ok());
    }

    #[test]
    fn width_matching_respected() {
        let items = test_items(&[(36, 100), (4, 100), (36, 100), (4, 100)]);
        let c = Constraints::new(4, false);
        let (p, _) = run_packer(&Ffd::new(), &items, &c);
        for b in &p.bins {
            let w0 = items[b.items[0]].width_bits;
            assert!(b.items.iter().all(|&i| items[i].width_bits == w0));
        }
    }

    #[test]
    fn slr_locality_respected() {
        let mut items = test_items(&[(36, 100); 6]);
        for (k, it) in items.iter_mut().enumerate() {
            it.slr = k % 2;
        }
        let c = Constraints::new(4, true);
        let (p, _) = run_packer(&Ffd::new(), &items, &c);
        for b in &p.bins {
            let s0 = items[b.items[0]].slr;
            assert!(b.items.iter().all(|&i| items[i].slr == s0));
        }
    }

    #[test]
    fn empty_input() {
        let items = test_items(&[]);
        let c = Constraints::new(4, false);
        let (p, r) = run_packer(&Ffd::new(), &items, &c);
        assert_eq!(p.bins.len(), 0);
        assert_eq!(r.brams, 0);
    }
}
