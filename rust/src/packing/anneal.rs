//! Simulated-annealing packer — the MPack approach (Vasiljevic & Chow,
//! FPL'14; paper §II.C). Starts from the FFD solution and explores
//! move/swap neighbourhoods under a geometric cooling schedule.

use super::{bin_brams, bin_shape, Bin, Constraints, Packer, Packing};
use crate::device::bram::brams_for;
use crate::memory::PackItem;
use crate::util::rng::Rng;

/// Simulated-annealing packer.
#[derive(Clone, Copy, Debug)]
pub struct Anneal {
    /// Total move/swap proposals to evaluate.
    pub iterations: usize,
    /// Initial temperature (BRAM18 cost units).
    pub t0: f64,
    /// Geometric cooling factor applied per iteration.
    pub cooling: f64,
    /// PRNG seed (runs are deterministic per seed).
    pub seed: u64,
}

impl Default for Anneal {
    fn default() -> Self {
        Anneal { iterations: 20_000, t0: 4.0, cooling: 0.9995, seed: 2020 }
    }
}

fn hard_ok(items: &[PackItem], bin: &Bin, item: usize, c: &Constraints) -> bool {
    if bin.items.len() >= c.max_bin_height {
        return false;
    }
    let head = bin.items[0];
    if c.same_slr && items[head].slr != items[item].slr {
        return false;
    }
    true
}

impl Packer for Anneal {
    fn name(&self) -> &'static str {
        "anneal"
    }

    fn pack(&self, items: &[PackItem], c: &Constraints) -> Packing {
        if items.is_empty() {
            return Packing::default();
        }
        let mut rng = Rng::new(self.seed);
        let mut cur = super::ffd::Ffd::new().pack(items, c).bins;
        let mut cur_cost: i64 = cur.iter().map(|b| bin_brams(items, &b.items) as i64).sum();
        let mut best = cur.clone();
        let mut best_cost = cur_cost;
        let mut t = self.t0;

        for _ in 0..self.iterations {
            t *= self.cooling;
            if cur.is_empty() {
                break;
            }
            // propose: move one random item to another bin (or a new bin)
            let from = rng.range(0, cur.len());
            let idx_in = rng.range(0, cur[from].items.len());
            let item = cur[from].items[idx_in];
            let to_new = rng.chance(0.15);
            let to = if to_new { usize::MAX } else { rng.range(0, cur.len()) };
            if !to_new && (to == from || !hard_ok(items, &cur[to], item, c)) {
                continue;
            }

            let old_from = bin_brams(items, &cur[from].items) as i64;
            // destination cost before/after from its cached shape — no
            // member-list clone on the proposal path
            let (old_to, new_to) = if to_new {
                (0, items[item].solo_brams() as i64)
            } else {
                let (w, d) = bin_shape(items, &cur[to].items);
                let grown =
                    brams_for(w.max(items[item].width_bits), d + items[item].depth);
                (brams_for(w, d) as i64, grown as i64)
            };

            // apply tentatively
            cur[from].items.swap_remove(idx_in);
            let new_from = bin_brams(items, &cur[from].items) as i64;
            let delta = (new_from + new_to) - (old_from + old_to);
            let accept = delta <= 0 || rng.f64() < (-(delta as f64) / t.max(1e-9)).exp();
            if accept {
                if to_new {
                    cur.push(Bin { items: vec![item] });
                } else {
                    cur[to].items.push(item);
                }
                if cur[from].items.is_empty() {
                    cur.swap_remove(from);
                }
                cur_cost += delta;
                if cur_cost < best_cost {
                    best = cur.clone();
                    best_cost = cur_cost;
                }
            } else {
                // revert
                cur[from].items.push(item);
            }
        }
        Packing { bins: best }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::{run_packer, test_items};

    #[test]
    fn anneal_never_worse_than_ffd() {
        let depths = [36u64, 72, 144, 288, 36, 450, 100, 260, 36, 512, 90, 64];
        let specs: Vec<(u64, u64)> = depths.iter().map(|&d| (36, d)).collect();
        let items = test_items(&specs);
        let c = Constraints::new(4, false);
        let (_, sa) = run_packer(&Anneal::default(), &items, &c);
        let (_, ffd) = run_packer(&super::super::ffd::Ffd::new(), &items, &c);
        assert!(sa.brams <= ffd.brams, "sa {} vs ffd {}", sa.brams, ffd.brams);
    }

    #[test]
    fn anneal_respects_constraints() {
        let mut items = test_items(&[(36, 100); 10]);
        for (k, it) in items.iter_mut().enumerate() {
            it.slr = k % 2;
        }
        let c = Constraints::new(3, true);
        let (p, _) = run_packer(&Anneal::default(), &items, &c);
        assert!(p.max_height() <= 3);
        for b in &p.bins {
            let s0 = items[b.items[0]].slr;
            assert!(b.items.iter().all(|&i| items[i].slr == s0));
        }
    }

    #[test]
    fn anneal_deterministic_for_seed() {
        let items = test_items(&[(36, 77), (36, 400), (18, 123), (36, 333), (9, 999)]);
        let c = Constraints::new(4, false);
        let (_, a) = run_packer(&Anneal::default(), &items, &c);
        let (_, b) = run_packer(&Anneal::default(), &items, &c);
        assert_eq!(a.brams, b.brams);
    }
}
