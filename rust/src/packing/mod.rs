//! Buffer-to-BRAM bin packing (paper §II.C, §IV and [18]).
//!
//! Items are the ≤36-bit column slices of the MVAU weight buffers
//! ([`crate::memory::PackItem`]). A *bin* is one physical BRAM structure
//! holding up to `H_B` co-located slices stacked in depth (Fig. 7); its cost
//! is the BRAM18 count of the combined (max-width × Σdepth) shape. `H_B` is
//! bounded by the virtual ports the GALS streamer exposes: `H_B ≤ 2·R_F`
//! (Eq. 2).
//!
//! Four engines, matching the paper's §II.C landscape:
//! * [`ffd`]    — first-fit-decreasing (fast deterministic baseline);
//! * [`anneal`] — simulated annealing (MPack, Vasiljevic & Chow);
//! * [`bnb`]    — branch-and-bound (MemPacker, Karchmer & Rose; exact,
//!                exponential — small inputs only);
//! * [`ga`]     — the grouping genetic algorithm of [18] (Kroes et al.),
//!                with the Table III hyper-parameters as defaults, extended
//!                to a parallel island model (`GaParams::islands` demes on
//!                scoped worker threads, deterministic ring migration) with
//!                incremental delta-cost fitness. See the module docs for
//!                the determinism contract.
//!
//! All engines cost bins through the memoized
//! [`crate::device::bram::brams_for`] shape table and run behind the same
//! [`Packer`]/[`run_packer`] interface, so sweeps over (topology × H_B ×
//! device) points swap engines freely.

pub mod anneal;
pub mod bnb;
pub mod cache;
pub mod ffd;
pub mod ga;

use crate::device::bram::brams_for;
use crate::memory::PackItem;

/// Packing constraints (paper §IV / §V).
#[derive(Clone, Copy, Debug)]
pub struct Constraints {
    /// Max logical buffers per BRAM (`H_B ≤ 2·R_F`, Eq. 2).
    pub max_bin_height: usize,
    /// Inter-layer packing only within one SLR (Alveo floorplanning, §V).
    pub same_slr: bool,
}

impl Constraints {
    pub fn new(max_bin_height: usize, same_slr: bool) -> Constraints {
        Constraints { max_bin_height, same_slr }
    }

    /// The memory/compute frequency ratio this bin height requires (Eq. 2).
    pub fn required_rf(&self) -> f64 {
        self.max_bin_height as f64 / 2.0
    }
}

/// One physical BRAM structure holding co-located item slices.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Bin {
    /// Indices into the packing's item slice.
    pub items: Vec<usize>,
}

/// Cost/shape of a bin over a set of items.
pub fn bin_shape(items: &[PackItem], members: &[usize]) -> (u64, u64) {
    let width = members.iter().map(|&i| items[i].width_bits).max().unwrap_or(0);
    let depth = members.iter().map(|&i| items[i].depth).sum();
    (width, depth)
}

/// BRAM18 count of a bin (combined max-width × Σdepth shape).
pub fn bin_brams(items: &[PackItem], members: &[usize]) -> u64 {
    let (w, d) = bin_shape(items, members);
    brams_for(w, d)
}

/// A complete packing solution. Equality is structural (bin-by-bin, in
/// order), which is what the island-GA determinism contract asserts on:
/// identical `(seed, islands)` must yield *byte-identical* packings.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Packing {
    pub bins: Vec<Bin>,
}

impl Packing {
    /// Trivial solution: one item per bin (the unpacked baseline).
    pub fn singletons(n: usize) -> Packing {
        Packing { bins: (0..n).map(|i| Bin { items: vec![i] }).collect() }
    }

    pub fn total_brams(&self, items: &[PackItem]) -> u64 {
        self.bins.iter().map(|b| bin_brams(items, &b.items)).sum()
    }

    /// Eq. 1 efficiency of the packed subsystem.
    pub fn efficiency(&self, items: &[PackItem]) -> f64 {
        let bits: u64 = items.iter().map(|i| i.bits()).sum();
        crate::memory::efficiency(bits, self.total_brams(items))
    }

    /// Tallest bin (drives the required R_F).
    pub fn max_height(&self) -> usize {
        self.bins.iter().map(|b| b.items.len()).max().unwrap_or(0)
    }

    /// Validate structural invariants: every item in exactly one bin,
    /// heights within H_B, SLR-locality if required.
    pub fn validate(&self, items: &[PackItem], c: &Constraints) -> Result<(), String> {
        let mut seen = vec![false; items.len()];
        for (bi, b) in self.bins.iter().enumerate() {
            if b.items.is_empty() {
                return Err(format!("bin {bi} is empty"));
            }
            if b.items.len() > c.max_bin_height {
                return Err(format!(
                    "bin {bi} height {} > H_B {}",
                    b.items.len(),
                    c.max_bin_height
                ));
            }
            if c.same_slr {
                let slr = items[b.items[0]].slr;
                if b.items.iter().any(|&i| items[i].slr != slr) {
                    return Err(format!("bin {bi} crosses SLRs"));
                }
            }
            for &i in &b.items {
                if i >= items.len() {
                    return Err(format!("bin {bi} references item {i} out of range"));
                }
                if seen[i] {
                    return Err(format!("item {i} placed twice"));
                }
                seen[i] = true;
            }
        }
        if let Some(missing) = seen.iter().position(|&s| !s) {
            return Err(format!("item {missing} not placed"));
        }
        Ok(())
    }
}

/// A packing engine.
pub trait Packer {
    fn name(&self) -> &'static str;
    fn pack(&self, items: &[PackItem], constraints: &Constraints) -> Packing;
}

/// Summary of a packing run (for Table IV and the ablation bench).
#[derive(Clone, Debug)]
pub struct PackReport {
    pub engine: &'static str,
    pub brams: u64,
    pub efficiency: f64,
    pub max_height: usize,
    pub elapsed: std::time::Duration,
}

/// Run a packer and summarise.
pub fn run_packer(
    p: &dyn Packer,
    items: &[PackItem],
    c: &Constraints,
) -> (Packing, PackReport) {
    let t0 = std::time::Instant::now();
    let packing = p.pack(items, c);
    let elapsed = t0.elapsed();
    packing
        .validate(items, c)
        .unwrap_or_else(|e| panic!("{} produced invalid packing: {e}", p.name()));
    let report = PackReport {
        engine: p.name(),
        brams: packing.total_brams(items),
        efficiency: packing.efficiency(items),
        max_height: packing.max_height(),
        elapsed,
    };
    (packing, report)
}

#[cfg(test)]
pub(crate) fn test_items(specs: &[(u64, u64)]) -> Vec<PackItem> {
    specs
        .iter()
        .enumerate()
        .map(|(i, &(w, d))| PackItem {
            id: i,
            layer: format!("l{i}"),
            width_bits: w,
            depth: d,
            slr: 0,
            tenant: 0,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn singleton_packing_is_direct_mapping() {
        let items = test_items(&[(36, 100), (18, 600), (36, 512)]);
        let p = Packing::singletons(3);
        assert_eq!(p.total_brams(&items), 1 + 1 + 1);
        p.validate(&items, &Constraints::new(4, false)).unwrap();
    }

    #[test]
    fn coalescing_shallow_buffers_saves_brams() {
        // four 36x100 slices: solo 4 BRAMs; packed in one bin: 36x400 -> 1
        let items = test_items(&[(36, 100); 4]);
        let packed = Packing { bins: vec![Bin { items: vec![0, 1, 2, 3] }] };
        assert_eq!(packed.total_brams(&items), 1);
        assert_eq!(Packing::singletons(4).total_brams(&items), 4);
        assert!(packed.efficiency(&items) > 0.7);
    }

    #[test]
    fn validate_catches_height_violation() {
        let items = test_items(&[(36, 10); 5]);
        let p = Packing { bins: vec![Bin { items: vec![0, 1, 2, 3, 4] }] };
        assert!(p.validate(&items, &Constraints::new(4, false)).is_err());
        assert!(p.validate(&items, &Constraints::new(5, false)).is_ok());
    }

    #[test]
    fn validate_catches_duplicates_and_missing() {
        let items = test_items(&[(36, 10), (36, 20)]);
        let dup = Packing { bins: vec![Bin { items: vec![0, 0] }, Bin { items: vec![1] }] };
        assert!(dup.validate(&items, &Constraints::new(4, false)).is_err());
        let missing = Packing { bins: vec![Bin { items: vec![0] }] };
        assert!(missing.validate(&items, &Constraints::new(4, false)).is_err());
    }

    #[test]
    fn validate_catches_slr_crossing() {
        let mut items = test_items(&[(36, 10), (36, 20)]);
        items[1].slr = 1;
        let p = Packing { bins: vec![Bin { items: vec![0, 1] }] };
        assert!(p.validate(&items, &Constraints::new(4, true)).is_err());
        assert!(p.validate(&items, &Constraints::new(4, false)).is_ok());
    }

    #[test]
    fn required_rf_follows_eq2() {
        assert_eq!(Constraints::new(4, false).required_rf(), 2.0);
        assert_eq!(Constraints::new(3, false).required_rf(), 1.5);
        assert_eq!(Constraints::new(2, false).required_rf(), 1.0);
    }

    #[test]
    fn mixed_width_bin_pays_max_width() {
        // (36 x 800) = 2 BRAMs; separate: 1 + 1 = 2 — co-locating a narrow
        // slice under a wide one gains nothing (the narrow words are padded
        // to the bin width), which is why Table III sets P_adm_w = 0
        let items = test_items(&[(36, 400), (4, 400)]);
        let together = Packing { bins: vec![Bin { items: vec![0, 1] }] };
        assert_eq!(together.total_brams(&items), 2);
        assert!(together.efficiency(&items) <= Packing::singletons(2).efficiency(&items));
        // same-width slices DO gain: 2 BRAMs -> 1
        let same = test_items(&[(36, 256), (36, 256)]);
        let t2 = Packing { bins: vec![Bin { items: vec![0, 1] }] };
        assert!(t2.total_brams(&same) < Packing::singletons(2).total_brams(&same));
    }
}
