//! Grouping genetic algorithm packer — the engine of [18] (Kroes et al.,
//! GECCO'20) that the paper uses for FCMP (§IV, Table III hyper-parameters),
//! extended to a parallel island model with incremental delta-cost fitness.
//!
//! Representation: Falkenauer-style *grouping* GA. An individual is a bin
//! assignment; crossover inherits whole bins from both parents (bins are the
//! meaningful building blocks, not item positions) and repairs the rest with
//! randomized first-fit; mutation dissolves random bins and re-inserts.
//!
//! The admission probabilities of Table III steer insertion:
//! * `p_adm_w` — probability of admitting an item into a bin of different
//!   column width (max-width cost: usually wasteful, 0 for both networks);
//! * `p_adm_h` — probability of admitting an item into a bin whose combined
//!   depth spills past the current BRAM row boundary (occasionally useful:
//!   the spill may be absorbed by a deeper aspect mode).
//!
//! # Island model
//!
//! With `islands > 1` the population is split into that many demes, each
//! evolving independently on its own [`Rng::for_stream`] stream. Every
//! `migration_interval` generations the demes synchronize and exchange
//! elites along a fixed ring (deme *i* receives the best of deme *i−1*,
//! replacing its current worst). Because the demes are data-independent
//! between migrations, epochs can run on scoped worker threads
//! ([`std::thread::scope`]) and the result is **bit-identical** for a fixed
//! `(seed, islands)` regardless of the thread count — the determinism
//! contract DESIGN.md documents and `tests/prop_invariants.rs` enforces.
//!
//! # Incremental fitness
//!
//! Each bin carries its (max-width, Σdepth, BRAM18 cost) alongside the
//! member list, so admission probes compare against the cached depth instead
//! of re-summing members, insertions update one bin's cost with a single
//! memoized [`brams_for`] lookup, and crossover inherits untouched bins —
//! costs included — without ever re-deriving them. `full_recompute` restores
//! the legacy whole-individual re-evaluation as an ablation arm for
//! `benches/packer_ablation.rs`.

use super::{Bin, Constraints, Packer, Packing};
use crate::device::bram::brams_for;
use crate::memory::PackItem;
use crate::util::rng::Rng;

/// GA hyper-parameters (paper Table III plus the island-model extensions).
#[derive(Clone, Copy, Debug)]
pub struct GaParams {
    /// Population size N_p (split across islands when `islands > 1`).
    pub population: usize,
    /// Tournament selection group size N_t.
    pub tournament: usize,
    /// Per-individual mutation probability P_mut.
    pub p_mut: f64,
    /// Width-mismatch admission probability P_adm^w.
    pub p_adm_w: f64,
    /// Depth-spill admission probability P_adm^h.
    pub p_adm_h: f64,
    /// Generations to run.
    pub generations: usize,
    /// PRNG seed (deterministic runs).
    pub seed: u64,
    /// Independently evolving demes (1 = the classic sequential GA).
    pub islands: usize,
    /// Generations between elite migrations along the ring.
    pub migration_interval: usize,
    /// Ablation arm: re-evaluate every bin of every offspring from scratch
    /// (the pre-incremental fitness path). Only the ablation bench sets it.
    pub full_recompute: bool,
}

impl GaParams {
    /// Table III row "CNV": N_p=50, N_t=5, P_adm_w=0, P_adm_h=0.1, P_mut=0.3.
    pub fn cnv() -> GaParams {
        GaParams {
            population: 50,
            tournament: 5,
            p_mut: 0.3,
            p_adm_w: 0.0,
            p_adm_h: 0.1,
            generations: 120,
            seed: 2020,
            islands: 1,
            migration_interval: 10,
            full_recompute: false,
        }
    }

    /// Table III row "RN50": N_p=75, N_t=5, P_adm_w=0, P_adm_h=0.1, P_mut=0.4.
    pub fn rn50() -> GaParams {
        GaParams {
            population: 75,
            tournament: 5,
            p_mut: 0.4,
            p_adm_w: 0.0,
            p_adm_h: 0.1,
            generations: 120,
            seed: 2020,
            islands: 1,
            migration_interval: 10,
            full_recompute: false,
        }
    }

    /// Island-model variant: split the population across `islands` demes.
    pub fn with_islands(mut self, islands: usize) -> GaParams {
        self.islands = islands.max(1);
        self
    }
}

/// The GA packer.
#[derive(Clone, Copy, Debug)]
pub struct Ga {
    pub params: GaParams,
    /// Worker threads for island epochs; 0 = `available_parallelism`.
    /// Purely an execution knob — the packing is a function of
    /// `(params, items, constraints)` only, never of `threads`.
    pub threads: usize,
}

impl Ga {
    pub fn new(params: GaParams) -> Ga {
        Ga { params, threads: 0 }
    }

    pub fn with_threads(mut self, threads: usize) -> Ga {
        self.threads = threads;
        self
    }

    fn worker_count(&self, islands: usize) -> usize {
        let t = if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        };
        t.min(islands).max(1)
    }
}

/// One bin plus its cached shape and cost. `width`/`depth` are maintained
/// incrementally on insertion, so admission checks and cost updates are O(1)
/// in the bin height instead of re-summing the member list.
#[derive(Clone, Debug)]
struct BinState {
    items: Vec<usize>,
    width: u64,
    depth: u64,
    cost: u64,
}

impl BinState {
    fn singleton(items: &[PackItem], i: usize) -> BinState {
        let it = &items[i];
        BinState {
            items: vec![i],
            width: it.width_bits,
            depth: it.depth,
            cost: brams_for(it.width_bits, it.depth),
        }
    }

    fn from_members(items: &[PackItem], members: Vec<usize>) -> BinState {
        let (width, depth) = super::bin_shape(items, &members);
        BinState { items: members, width, depth, cost: brams_for(width, depth) }
    }

    /// Admit `i`, updating shape and cost in place (one memoized lookup).
    fn push(&mut self, items: &[PackItem], i: usize) {
        let it = &items[i];
        self.items.push(i);
        self.width = self.width.max(it.width_bits);
        self.depth += it.depth;
        self.cost = brams_for(self.width, self.depth);
    }
}

/// One individual: bins with cached per-bin costs plus the cached total.
#[derive(Clone)]
struct Indiv {
    bins: Vec<BinState>,
    cost: u64,
}

impl Indiv {
    fn from_packing(items: &[PackItem], bins: Vec<Bin>) -> Indiv {
        let bins: Vec<BinState> =
            bins.into_iter().map(|b| BinState::from_members(items, b.items)).collect();
        let cost = bins.iter().map(|b| b.cost).sum();
        Indiv { bins, cost }
    }
}

/// Full re-derivation of the total cost (debug cross-checks + the
/// `full_recompute` ablation arm).
fn total_cost(items: &[PackItem], bins: &[BinState]) -> u64 {
    bins.iter().map(|b| super::bin_brams(items, &b.items)).sum()
}

/// Legacy whole-individual re-evaluation (ablation arm only).
fn refit_full(items: &[PackItem], ind: &mut Indiv) {
    let mut cost = 0;
    for b in &mut ind.bins {
        *b = BinState::from_members(items, std::mem::take(&mut b.items));
        cost += b.cost;
    }
    ind.cost = cost;
}

/// Can `item` join `bin` under hard constraints + stochastic admission?
/// Uses the bin's cached depth — no member re-summation on the probe path.
fn admits(
    items: &[PackItem],
    bin: &BinState,
    item: usize,
    c: &Constraints,
    p: &GaParams,
    rng: &mut Rng,
) -> bool {
    if bin.items.len() >= c.max_bin_height {
        return false;
    }
    let head = bin.items[0];
    if c.same_slr && items[head].slr != items[item].slr {
        return false;
    }
    if items[head].width_bits != items[item].width_bits && !rng.chance(p.p_adm_w) {
        return false;
    }
    // depth spill: combined depth crossing the next 512-word row boundary.
    // The legacy arm re-sums the member depths like the original code did.
    let depth = if p.full_recompute {
        bin.items.iter().map(|&i| items[i].depth).sum()
    } else {
        bin.depth
    };
    let spills = (depth % 512 != 0) && (depth % 512 + items[item].depth > 512);
    if spills && !rng.chance(p.p_adm_h) {
        return false;
    }
    true
}

/// Randomized first-fit insertion used by construction, repair and mutation.
/// Every touched bin's cached cost is updated in place and the running total
/// in `cost` is kept consistent — callers never re-sum.
fn insert_all(
    items: &[PackItem],
    bins: &mut Vec<BinState>,
    mut todo: Vec<usize>,
    c: &Constraints,
    p: &GaParams,
    rng: &mut Rng,
    cost: &mut u64,
) {
    rng.shuffle(&mut todo);
    for item in todo {
        let start = if bins.is_empty() { 0 } else { rng.range(0, bins.len()) };
        let n = bins.len();
        let mut placed = false;
        for k in 0..n {
            let bi = (start + k) % n;
            if admits(items, &bins[bi], item, c, p, rng) {
                *cost -= bins[bi].cost;
                bins[bi].push(items, item);
                *cost += bins[bi].cost;
                placed = true;
                break;
            }
        }
        if !placed {
            let b = BinState::singleton(items, item);
            *cost += b.cost;
            bins.push(b);
        }
    }
}

fn random_individual(
    items: &[PackItem],
    c: &Constraints,
    p: &GaParams,
    rng: &mut Rng,
) -> Indiv {
    let mut bins = Vec::new();
    let mut cost = 0;
    insert_all(items, &mut bins, (0..items.len()).collect(), c, p, rng, &mut cost);
    Indiv { bins, cost }
}

/// Grouping crossover: child inherits a random subset of parent A's bins,
/// then parent B's bins whose items are all still free, then first-fit
/// repair. Inherited bins keep their cached shape and cost — `bin_brams` is
/// never called on them.
fn crossover(
    items: &[PackItem],
    a: &Indiv,
    b: &Indiv,
    c: &Constraints,
    p: &GaParams,
    rng: &mut Rng,
) -> Indiv {
    let mut used = vec![false; items.len()];
    let mut bins: Vec<BinState> = Vec::new();
    let mut cost = 0u64;
    for bin in &a.bins {
        if rng.chance(0.5) {
            for &i in &bin.items {
                used[i] = true;
            }
            cost += bin.cost;
            bins.push(bin.clone());
        }
    }
    for bin in &b.bins {
        if bin.items.iter().all(|&i| !used[i]) {
            for &i in &bin.items {
                used[i] = true;
            }
            cost += bin.cost;
            bins.push(bin.clone());
        }
    }
    let todo: Vec<usize> = (0..items.len()).filter(|&i| !used[i]).collect();
    insert_all(items, &mut bins, todo, c, p, rng, &mut cost);
    Indiv { bins, cost }
}

/// Mutation: dissolve a few random bins and re-insert their items.
fn mutate(items: &[PackItem], ind: &mut Indiv, c: &Constraints, p: &GaParams, rng: &mut Rng) {
    if ind.bins.is_empty() {
        return;
    }
    let n_dissolve = 1 + rng.range(0, (ind.bins.len() / 8).max(1));
    let mut todo = Vec::new();
    for _ in 0..n_dissolve {
        if ind.bins.is_empty() {
            break;
        }
        let bi = rng.range(0, ind.bins.len());
        let b = ind.bins.swap_remove(bi);
        ind.cost -= b.cost;
        todo.extend(b.items);
    }
    insert_all(items, &mut ind.bins, todo, c, p, rng, &mut ind.cost);
}

fn tournament<'a>(pop: &'a [Indiv], k: usize, rng: &mut Rng) -> &'a Indiv {
    let mut best = &pop[rng.range(0, pop.len())];
    for _ in 1..k {
        let cand = &pop[rng.range(0, pop.len())];
        if cand.cost < best.cost {
            best = cand;
        }
    }
    best
}

/// One deme of the island model: its own population, elite and RNG stream.
struct Island {
    pop: Vec<Indiv>,
    best: Indiv,
    rng: Rng,
}

fn init_island(
    items: &[PackItem],
    c: &Constraints,
    p: &GaParams,
    island_pop: usize,
    ffd: &Indiv,
    isl: &mut Island,
) {
    // randomized constructions plus one deterministic FFD solution per deme
    // (no deme ever starts worse than the baseline)
    isl.pop = (0..island_pop.max(2) - 1)
        .map(|_| random_individual(items, c, p, &mut isl.rng))
        .collect();
    isl.pop.push(ffd.clone());
    let bi = (0..isl.pop.len()).min_by_key(|&i| isl.pop[i].cost).unwrap();
    isl.best = isl.pop[bi].clone();
}

fn evolve(items: &[PackItem], c: &Constraints, p: &GaParams, isl: &mut Island, gens: usize) {
    for _gen in 0..gens {
        let mut next = Vec::with_capacity(isl.pop.len());
        next.push(isl.best.clone()); // elitism
        while next.len() < isl.pop.len() {
            let a = tournament(&isl.pop, p.tournament, &mut isl.rng);
            let b = tournament(&isl.pop, p.tournament, &mut isl.rng);
            let mut child = crossover(items, a, b, c, p, &mut isl.rng);
            if isl.rng.chance(p.p_mut) {
                mutate(items, &mut child, c, p, &mut isl.rng);
            }
            if p.full_recompute {
                refit_full(items, &mut child);
            }
            next.push(child);
        }
        isl.pop = next;
        let gi = (0..isl.pop.len()).min_by_key(|&i| isl.pop[i].cost).unwrap();
        if isl.pop[gi].cost < isl.best.cost {
            isl.best = isl.pop[gi].clone();
        }
    }
}

/// Deterministic ring migration: deme `i` receives the elite of deme `i−1`
/// (mod N), replacing its current worst individual.
fn migrate(islands: &mut [Island]) {
    let elites: Vec<Indiv> = islands.iter().map(|isl| isl.best.clone()).collect();
    let n = islands.len();
    for (i, isl) in islands.iter_mut().enumerate() {
        let migrant = &elites[(i + n - 1) % n];
        if let Some(wi) = (0..isl.pop.len()).max_by_key(|&j| isl.pop[j].cost) {
            isl.pop[wi] = migrant.clone();
        }
        if migrant.cost < isl.best.cost {
            isl.best = migrant.clone();
        }
    }
}

/// Apply `f` to every island, fanning out across at most `threads` scoped
/// workers. Demes are data-independent, so the schedule cannot affect the
/// result — only the wall clock.
fn for_each_island<F>(islands: &mut [Island], threads: usize, f: F)
where
    F: Fn(&mut Island) + Sync,
{
    if threads <= 1 || islands.len() <= 1 {
        for isl in islands.iter_mut() {
            f(isl);
        }
        return;
    }
    let chunk = (islands.len() + threads - 1) / threads;
    let fr = &f;
    std::thread::scope(|s| {
        for part in islands.chunks_mut(chunk) {
            s.spawn(move || {
                for isl in part {
                    fr(isl);
                }
            });
        }
    });
}

impl Packer for Ga {
    fn name(&self) -> &'static str {
        "ga"
    }

    fn pack(&self, items: &[PackItem], c: &Constraints) -> Packing {
        if items.is_empty() {
            return Packing::default();
        }
        let p = self.params;
        let n_islands = p.islands.max(1);
        let epoch = p.migration_interval.max(1);
        // demes share the Table III population budget (Kroes-style split);
        // a floor keeps tournament selection meaningful in small demes
        let island_pop = if n_islands == 1 {
            p.population.max(2)
        } else {
            (p.population / n_islands).max(8)
        };
        let threads = self.worker_count(n_islands);

        let ffd = super::ffd::Ffd::new().pack(items, c);
        let ffd_ind = Indiv::from_packing(items, ffd.bins);
        // the cached-cost path must agree with a from-scratch re-derivation
        debug_assert_eq!(ffd_ind.cost, total_cost(items, &ffd_ind.bins));

        let mut islands: Vec<Island> = (0..n_islands)
            .map(|i| Island {
                pop: Vec::new(),
                best: ffd_ind.clone(),
                rng: Rng::for_stream(p.seed, i as u64),
            })
            .collect();

        let ffd_ref = &ffd_ind;
        for_each_island(&mut islands, threads, |isl| {
            init_island(items, c, &p, island_pop, ffd_ref, isl)
        });

        let mut done = 0;
        while done < p.generations {
            let gens = epoch.min(p.generations - done);
            for_each_island(&mut islands, threads, |isl| evolve(items, c, &p, isl, gens));
            done += gens;
            if done < p.generations && n_islands > 1 {
                migrate(&mut islands);
            }
        }

        let best = islands.iter().map(|isl| &isl.best).min_by_key(|b| b.cost).unwrap();
        debug_assert_eq!(best.cost, total_cost(items, &best.bins));
        Packing {
            bins: best.bins.iter().map(|b| Bin { items: b.items.clone() }).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::{run_packer, test_items};

    fn quick(seed: u64) -> GaParams {
        GaParams { generations: 40, seed, ..GaParams::cnv() }
    }

    #[test]
    fn ga_finds_optimal_on_uniform_slices() {
        let items = test_items(&[(36, 128); 16]);
        let c = Constraints::new(4, false);
        let (_, r) = run_packer(&Ga::new(quick(1)), &items, &c);
        assert_eq!(r.brams, 4); // 16 slices, 4 per bin, 512 deep = 1 BRAM each
        assert!((r.efficiency - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ga_beats_or_matches_ffd() {
        // heterogeneous depths: grouping matters
        let depths = [36u64, 72, 144, 288, 36, 72, 450, 100, 260, 36, 512, 90];
        let specs: Vec<(u64, u64)> = depths.iter().map(|&d| (36, d)).collect();
        let items = test_items(&specs);
        let c = Constraints::new(4, false);
        let (_, ga) = run_packer(&Ga::new(quick(2)), &items, &c);
        let (_, ffd) = run_packer(&super::super::ffd::Ffd::new(), &items, &c);
        assert!(ga.brams <= ffd.brams, "ga {} vs ffd {}", ga.brams, ffd.brams);
    }

    #[test]
    fn ga_is_deterministic_for_seed() {
        let items = test_items(&[(36, 100), (36, 412), (18, 300), (36, 80), (9, 950)]);
        let c = Constraints::new(3, false);
        let (_, a) = run_packer(&Ga::new(quick(7)), &items, &c);
        let (_, b) = run_packer(&Ga::new(quick(7)), &items, &c);
        assert_eq!(a.brams, b.brams);
    }

    #[test]
    fn ga_respects_h3() {
        let items = test_items(&[(36, 128); 9]);
        let c = Constraints::new(3, false);
        let (p, r) = run_packer(&Ga::new(quick(3)), &items, &c);
        assert!(p.max_height() <= 3);
        assert_eq!(r.brams, 3);
    }

    #[test]
    fn width_admission_zero_keeps_bins_uniform() {
        let items = test_items(&[(36, 60), (4, 60), (36, 60), (4, 60), (36, 60), (4, 60)]);
        let c = Constraints::new(4, false);
        let (p, _) = run_packer(&Ga::new(quick(4)), &items, &c);
        for b in &p.bins {
            let w0 = items[b.items[0]].width_bits;
            assert!(
                b.items.iter().all(|&i| items[i].width_bits == w0),
                "P_adm_w=0 must keep widths uniform: {b:?}"
            );
        }
    }

    #[test]
    fn island_ga_beats_or_matches_ffd() {
        let depths = [36u64, 72, 144, 288, 36, 72, 450, 100, 260, 36, 512, 90, 64, 200];
        let specs: Vec<(u64, u64)> = depths.iter().map(|&d| (36, d)).collect();
        let items = test_items(&specs);
        let c = Constraints::new(4, false);
        let params = quick(5).with_islands(4);
        let (p, r) = run_packer(&Ga::new(params), &items, &c);
        let (_, ffd) = run_packer(&super::super::ffd::Ffd::new(), &items, &c);
        assert!(r.brams <= ffd.brams, "island ga {} vs ffd {}", r.brams, ffd.brams);
        assert!(p.validate(&items, &c).is_ok());
    }

    #[test]
    fn island_ga_identical_across_thread_counts() {
        let items = test_items(&[
            (36, 100),
            (36, 412),
            (18, 300),
            (36, 80),
            (9, 950),
            (36, 220),
            (18, 64),
            (36, 500),
        ]);
        let c = Constraints::new(4, false);
        let params = GaParams { generations: 24, seed: 9, ..GaParams::cnv() }.with_islands(3);
        let a = Ga::new(params).with_threads(1).pack(&items, &c);
        let b = Ga::new(params).with_threads(2).pack(&items, &c);
        let d = Ga::new(params).with_threads(8).pack(&items, &c);
        assert_eq!(a, b, "1 vs 2 threads diverged");
        assert_eq!(b, d, "2 vs 8 threads diverged");
    }

    #[test]
    fn full_recompute_arm_matches_incremental_cost_quality() {
        // the ablation arm changes how fitness is computed, not what it is:
        // both paths must report costs that re-derive exactly
        let items = test_items(&[(36, 90), (36, 320), (18, 700), (36, 128), (9, 1800), (36, 40)]);
        let c = Constraints::new(3, false);
        for full in [false, true] {
            let params = GaParams {
                generations: 20,
                population: 16,
                full_recompute: full,
                ..GaParams::cnv()
            };
            let (p, r) = run_packer(&Ga::new(params), &items, &c);
            assert_eq!(p.total_brams(&items), r.brams, "full={full}");
            assert!(p.validate(&items, &c).is_ok(), "full={full}");
        }
    }
}
