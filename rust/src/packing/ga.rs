//! Grouping genetic algorithm packer — the engine of [18] (Kroes et al.,
//! GECCO'20) that the paper uses for FCMP (§IV, Table III hyper-parameters).
//!
//! Representation: Falkenauer-style *grouping* GA. An individual is a bin
//! assignment; crossover inherits whole bins from both parents (bins are the
//! meaningful building blocks, not item positions) and repairs the rest with
//! randomized first-fit; mutation dissolves random bins and re-inserts.
//!
//! The admission probabilities of Table III steer insertion:
//! * `p_adm_w` — probability of admitting an item into a bin of different
//!   column width (max-width cost: usually wasteful, 0 for both networks);
//! * `p_adm_h` — probability of admitting an item into a bin whose combined
//!   depth spills past the current BRAM row boundary (occasionally useful:
//!   the spill may be absorbed by a deeper aspect mode).

use super::{bin_brams, Bin, Constraints, Packer, Packing};
use crate::memory::PackItem;
use crate::util::rng::Rng;

/// GA hyper-parameters (paper Table III).
#[derive(Clone, Copy, Debug)]
pub struct GaParams {
    /// Population size N_p.
    pub population: usize,
    /// Tournament selection group size N_t.
    pub tournament: usize,
    /// Per-individual mutation probability P_mut.
    pub p_mut: f64,
    /// Width-mismatch admission probability P_adm^w.
    pub p_adm_w: f64,
    /// Depth-spill admission probability P_adm^h.
    pub p_adm_h: f64,
    /// Generations to run.
    pub generations: usize,
    /// PRNG seed (deterministic runs).
    pub seed: u64,
}

impl GaParams {
    /// Table III row "CNV": N_p=50, N_t=5, P_adm_w=0, P_adm_h=0.1, P_mut=0.3.
    pub fn cnv() -> GaParams {
        GaParams {
            population: 50,
            tournament: 5,
            p_mut: 0.3,
            p_adm_w: 0.0,
            p_adm_h: 0.1,
            generations: 120,
            seed: 2020,
        }
    }

    /// Table III row "RN50": N_p=75, N_t=5, P_adm_w=0, P_adm_h=0.1, P_mut=0.4.
    pub fn rn50() -> GaParams {
        GaParams {
            population: 75,
            tournament: 5,
            p_mut: 0.4,
            p_adm_w: 0.0,
            p_adm_h: 0.1,
            generations: 120,
            seed: 2020,
        }
    }
}

/// The GA packer.
#[derive(Clone, Copy, Debug)]
pub struct Ga {
    pub params: GaParams,
}

impl Ga {
    pub fn new(params: GaParams) -> Ga {
        Ga { params }
    }
}

/// One individual: a packing plus per-bin cached costs (the fitness
/// evaluation is the GA hot path; recomputing every bin's BRAM cost per
/// offspring dominated the profile before caching).
#[derive(Clone)]
struct Indiv {
    bins: Vec<Bin>,
    bin_costs: Vec<u64>,
    cost: u64,
}

impl Indiv {
    fn from_bins(items: &[PackItem], bins: Vec<Bin>) -> Indiv {
        let bin_costs: Vec<u64> =
            bins.iter().map(|b| bin_brams(items, &b.items)).collect();
        let cost = bin_costs.iter().sum();
        Indiv { bins, bin_costs, cost }
    }
}

fn total_cost(items: &[PackItem], bins: &[Bin]) -> u64 {
    bins.iter().map(|b| bin_brams(items, &b.items)).sum()
}

/// Can `item` join `bin` under hard constraints + stochastic admission?
fn admits(
    items: &[PackItem],
    bin: &Bin,
    item: usize,
    c: &Constraints,
    p: &GaParams,
    rng: &mut Rng,
) -> bool {
    if bin.items.len() >= c.max_bin_height {
        return false;
    }
    let head = bin.items[0];
    if c.same_slr && items[head].slr != items[item].slr {
        return false;
    }
    if items[head].width_bits != items[item].width_bits && !rng.chance(p.p_adm_w) {
        return false;
    }
    // depth spill: combined depth crossing the next 512-word row boundary
    let depth: u64 = bin.items.iter().map(|&i| items[i].depth).sum();
    let spills = (depth % 512 != 0) && (depth % 512 + items[item].depth > 512);
    if spills && !rng.chance(p.p_adm_h) {
        return false;
    }
    true
}

/// Randomized first-fit insertion used by construction, repair and mutation.
/// Touched bins are tracked so callers can refresh only their cached costs.
fn insert_all(
    items: &[PackItem],
    bins: &mut Vec<Bin>,
    mut todo: Vec<usize>,
    c: &Constraints,
    p: &GaParams,
    rng: &mut Rng,
    touched: &mut Vec<usize>,
) {
    rng.shuffle(&mut todo);
    for item in todo {
        let start = if bins.is_empty() { 0 } else { rng.range(0, bins.len()) };
        let n = bins.len();
        let mut placed = false;
        for k in 0..n {
            let bi = (start + k) % n;
            if admits(items, &bins[bi], item, c, p, rng) {
                bins[bi].items.push(item);
                touched.push(bi);
                placed = true;
                break;
            }
        }
        if !placed {
            bins.push(Bin { items: vec![item] });
            touched.push(bins.len() - 1);
        }
    }
}

fn random_individual(
    items: &[PackItem],
    c: &Constraints,
    p: &GaParams,
    rng: &mut Rng,
) -> Indiv {
    let mut bins = Vec::new();
    let mut touched = Vec::new();
    insert_all(items, &mut bins, (0..items.len()).collect(), c, p, rng, &mut touched);
    Indiv::from_bins(items, bins)
}

/// Grouping crossover: child inherits a random subset of parent A's bins,
/// then parent B's bins filtered of used items, then first-fit repair.
fn crossover(
    items: &[PackItem],
    a: &Indiv,
    b: &Indiv,
    c: &Constraints,
    p: &GaParams,
    rng: &mut Rng,
) -> Indiv {
    let mut used = vec![false; items.len()];
    let mut bins: Vec<Bin> = Vec::new();
    let mut bin_costs: Vec<u64> = Vec::new();
    for (bi, bin) in a.bins.iter().enumerate() {
        if rng.chance(0.5) {
            for &i in &bin.items {
                used[i] = true;
            }
            bins.push(bin.clone());
            bin_costs.push(a.bin_costs[bi]); // inherited bins keep costs
        }
    }
    for (bi, bin) in b.bins.iter().enumerate() {
        let free: Vec<usize> =
            bin.items.iter().copied().filter(|&i| !used[i]).collect();
        if free.len() == bin.items.len() {
            for &i in &free {
                used[i] = true;
            }
            bins.push(Bin { items: free });
            bin_costs.push(b.bin_costs[bi]);
        }
    }
    let todo: Vec<usize> = (0..items.len()).filter(|&i| !used[i]).collect();
    let mut touched = Vec::new();
    insert_all(items, &mut bins, todo, c, p, rng, &mut touched);
    bin_costs.resize(bins.len(), 0);
    touched.sort_unstable();
    touched.dedup();
    for bi in touched {
        bin_costs[bi] = bin_brams(items, &bins[bi].items);
    }
    let cost = bin_costs.iter().sum();
    Indiv { bins, bin_costs, cost }
}

/// Mutation: dissolve a few random bins and re-insert their items.
fn mutate(items: &[PackItem], ind: &mut Indiv, c: &Constraints, p: &GaParams, rng: &mut Rng) {
    if ind.bins.is_empty() {
        return;
    }
    let n_dissolve = 1 + rng.range(0, (ind.bins.len() / 8).max(1));
    let mut todo = Vec::new();
    for _ in 0..n_dissolve {
        if ind.bins.is_empty() {
            break;
        }
        let bi = rng.range(0, ind.bins.len());
        todo.extend(ind.bins.swap_remove(bi).items);
        ind.bin_costs.swap_remove(bi);
    }
    let mut touched = Vec::new();
    insert_all(items, &mut ind.bins, todo, c, p, rng, &mut touched);
    ind.bin_costs.resize(ind.bins.len(), 0);
    touched.sort_unstable();
    touched.dedup();
    for bi in touched {
        ind.bin_costs[bi] = bin_brams(items, &ind.bins[bi].items);
    }
    ind.cost = ind.bin_costs.iter().sum();
}

fn tournament<'a>(pop: &'a [Indiv], k: usize, rng: &mut Rng) -> &'a Indiv {
    let mut best = &pop[rng.range(0, pop.len())];
    for _ in 1..k {
        let cand = &pop[rng.range(0, pop.len())];
        if cand.cost < best.cost {
            best = cand;
        }
    }
    best
}

impl Packer for Ga {
    fn name(&self) -> &'static str {
        "ga"
    }

    fn pack(&self, items: &[PackItem], c: &Constraints) -> Packing {
        if items.is_empty() {
            return Packing::default();
        }
        let p = &self.params;
        let mut rng = Rng::new(p.seed);

        // seed the population with randomized constructions plus one
        // deterministic FFD solution (never start worse than the baseline)
        let mut pop: Vec<Indiv> = (0..p.population.max(2) - 1)
            .map(|_| random_individual(items, c, p, &mut rng))
            .collect();
        let ffd = super::ffd::Ffd::new().pack(items, c);
        debug_assert_eq!(total_cost(items, &ffd.bins), Indiv::from_bins(items, ffd.bins.clone()).cost);
        pop.push(Indiv::from_bins(items, ffd.bins));

        let mut best = pop.iter().min_by_key(|i| i.cost).unwrap().clone();
        for _gen in 0..p.generations {
            let mut next = Vec::with_capacity(pop.len());
            next.push(best.clone()); // elitism
            while next.len() < pop.len() {
                let a = tournament(&pop, p.tournament, &mut rng);
                let b = tournament(&pop, p.tournament, &mut rng);
                let mut child = crossover(items, a, b, c, p, &mut rng);
                if rng.chance(p.p_mut) {
                    mutate(items, &mut child, c, p, &mut rng);
                }
                next.push(child);
            }
            pop = next;
            let gen_best = pop.iter().min_by_key(|i| i.cost).unwrap();
            if gen_best.cost < best.cost {
                best = gen_best.clone();
            }
        }
        Packing { bins: best.bins }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::{run_packer, test_items};

    fn quick(seed: u64) -> GaParams {
        GaParams { generations: 40, seed, ..GaParams::cnv() }
    }

    #[test]
    fn ga_finds_optimal_on_uniform_slices() {
        let items = test_items(&[(36, 128); 16]);
        let c = Constraints::new(4, false);
        let (_, r) = run_packer(&Ga::new(quick(1)), &items, &c);
        assert_eq!(r.brams, 4); // 16 slices, 4 per bin, 512 deep = 1 BRAM each
        assert!((r.efficiency - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ga_beats_or_matches_ffd() {
        // heterogeneous depths: grouping matters
        let depths = [36u64, 72, 144, 288, 36, 72, 450, 100, 260, 36, 512, 90];
        let specs: Vec<(u64, u64)> = depths.iter().map(|&d| (36, d)).collect();
        let items = test_items(&specs);
        let c = Constraints::new(4, false);
        let (_, ga) = run_packer(&Ga::new(quick(2)), &items, &c);
        let (_, ffd) = run_packer(&super::super::ffd::Ffd::new(), &items, &c);
        assert!(ga.brams <= ffd.brams, "ga {} vs ffd {}", ga.brams, ffd.brams);
    }

    #[test]
    fn ga_is_deterministic_for_seed() {
        let items = test_items(&[(36, 100), (36, 412), (18, 300), (36, 80), (9, 950)]);
        let c = Constraints::new(3, false);
        let (_, a) = run_packer(&Ga::new(quick(7)), &items, &c);
        let (_, b) = run_packer(&Ga::new(quick(7)), &items, &c);
        assert_eq!(a.brams, b.brams);
    }

    #[test]
    fn ga_respects_h3() {
        let items = test_items(&[(36, 128); 9]);
        let c = Constraints::new(3, false);
        let (p, r) = run_packer(&Ga::new(quick(3)), &items, &c);
        assert!(p.max_height() <= 3);
        assert_eq!(r.brams, 3);
    }

    #[test]
    fn width_admission_zero_keeps_bins_uniform() {
        let items = test_items(&[(36, 60), (4, 60), (36, 60), (4, 60), (36, 60), (4, 60)]);
        let c = Constraints::new(4, false);
        let (p, _) = run_packer(&Ga::new(quick(4)), &items, &c);
        for b in &p.bins {
            let w0 = items[b.items[0]].width_bits;
            assert!(
                b.items.iter().all(|&i| items[i].width_bits == w0),
                "P_adm_w=0 must keep widths uniform: {b:?}"
            );
        }
    }
}
